//! `lumos lint` — dependency-free determinism & concurrency static
//! analysis over the repo's own Rust sources.
//!
//! Every headline number (Table IV speedups, availability tables, netsim
//! baselines) rests on the contract that output is byte-identical across
//! `--jobs N` and reproducible from `--seed`. The [`rules`] engine makes
//! that contract structural: ambient hash order, wall-clock reads,
//! un-seeded entropy, arrival-order float reduction, unjustified panics
//! and undocumented `unsafe` are findings, not conventions. Exemptions
//! are inline and self-documenting:
//!
//! ```text
//! // lumos: allow(<rule>[, <rule>]*) -- <reason>
//! ```
//!
//! written on the offending line, or alone on the line(s) above it.
//!
//! The scanner itself honours the contract it enforces: files are listed
//! in sorted order, scanned in parallel on
//! [`crate::sweep::engine::run_indexed`] (index-ordered results), and the
//! report is identical for any `--jobs N`.

pub mod lexer;
pub mod rules;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::sweep::engine::run_indexed;
use crate::util::json::Json;

/// One lint finding. The derived ordering (file, line, rule, message) is
/// the report order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Aggregate result of a lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// findings silenced by `lumos: allow` directives
    pub suppressed: usize,
}

/// Lint one source string (`file` is only a label). Returns surviving
/// findings and the suppressed count.
pub fn lint_source(file: &str, src: &str, only: &[String]) -> (Vec<Finding>, usize) {
    rules::scan_lexed(file, &lexer::lex(src), only)
}

/// Lint `.rs` files under `paths` (files or directories) with `jobs`
/// scanner threads. File order is sorted-deterministic; the report is
/// identical for any job count.
pub fn lint_paths(paths: &[PathBuf], only: &[String], jobs: usize) -> Result<LintReport> {
    let mut files = Vec::new();
    for p in paths {
        ensure!(p.exists(), "no such path: {}", p.display());
        files.extend(collect_rs_files(p)?);
    }
    files.sort();
    files.dedup();
    ensure!(!files.is_empty(), "no .rs files under the given paths");

    // Read serially in sorted order (I/O error paths stay simple);
    // scanning — the expensive part — fans out index-ordered.
    let mut sources = Vec::with_capacity(files.len());
    for f in &files {
        sources.push(
            std::fs::read_to_string(f).with_context(|| format!("reading {}", f.display()))?,
        );
    }
    let labels: Vec<String> = files.iter().map(|f| f.display().to_string()).collect();
    let per_file = run_indexed(files.len(), jobs, |i| {
        lint_source(&labels[i], &sources[i], only)
    });

    let mut report =
        LintReport { findings: Vec::new(), files_scanned: files.len(), suppressed: 0 };
    for (found, suppressed) in per_file {
        report.findings.extend(found);
        report.suppressed += suppressed;
    }
    report.findings.sort();
    Ok(report)
}

/// Modules allowed to read the host clock anywhere in the file
/// (DESIGN.md §Observability): the quarantined [`crate::obs::profile`]
/// timers and the bench harness. Matched as `/`-normalized path
/// suffixes. The runtime/trainer measurement paths route through
/// [`crate::obs::record::Stopwatch`] and are deliberately *not* here.
pub const WALLCLOCK_ALLOWED: &[&str] = &["obs/profile.rs", "util/bench.rs"];

/// Files where clock reads are allowed only inside explicit
/// `lumos: wallclock-capture-begin` / `-end` marker comments: the flight
/// recorder's capture helper. A clock read in these files *outside* a
/// marked region still fails the audit.
pub const WALLCLOCK_CAPTURE_SCOPED: &[&str] = &["obs/record.rs"];

const CAPTURE_BEGIN: &str = "lumos: wallclock-capture-begin";
const CAPTURE_END: &str = "lumos: wallclock-capture-end";

/// The marker-bounded capture regions of a source file, as inclusive
/// 1-indexed `(begin_line, end_line)` pairs. An unclosed `begin` extends
/// to EOF (conservative: the region is where reads are *allowed*, and an
/// unmatched marker is caught by [`wallclock_audit`]'s error below).
pub fn wallclock_capture_regions(src: &str) -> Result<Vec<(usize, usize)>> {
    let mut out = Vec::new();
    let mut open: Option<usize> = None;
    let mut last = 0usize;
    for (i, line) in src.lines().enumerate() {
        let n = i + 1;
        last = n;
        let t = line.trim_start();
        let marked = |m: &str| t.starts_with("//") && t[2..].trim_start().starts_with(m);
        if marked(CAPTURE_BEGIN) {
            ensure!(open.is_none(), "line {n}: nested wallclock-capture-begin");
            open = Some(n);
        } else if marked(CAPTURE_END) {
            let b = open.take().context(format!("line {n}: wallclock-capture-end without begin"))?;
            out.push((b, n));
        }
    }
    if let Some(b) = open {
        out.push((b, last));
    }
    Ok(out)
}

/// The `lumos lint --audit-wallclock` gate: every wall-clock read site
/// under `paths` whose file is *not* in [`WALLCLOCK_ALLOWED`] — annotated
/// or not — plus any site in a [`WALLCLOCK_CAPTURE_SCOPED`] file that
/// falls outside its marker-bounded capture regions. Inline
/// `lumos: allow(wallclock)` directives justify a site to the regular
/// lint; the audit additionally pins *where* such sites may exist, so a
/// new clock consumer needs a deliberate allowlist change, not just an
/// annotation.
pub fn wallclock_audit(paths: &[PathBuf], jobs: usize) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    for p in paths {
        ensure!(p.exists(), "no such path: {}", p.display());
        files.extend(collect_rs_files(p)?);
    }
    files.sort();
    files.dedup();
    ensure!(!files.is_empty(), "no .rs files under the given paths");
    let suffix_match = |label: &str, list: &[&str]| {
        let norm = label.replace('\\', "/");
        list.iter().any(|a| norm.ends_with(a))
    };
    let mut sources = Vec::with_capacity(files.len());
    for f in &files {
        sources.push(
            std::fs::read_to_string(f).with_context(|| format!("reading {}", f.display()))?,
        );
    }
    let labels: Vec<String> = files.iter().map(|f| f.display().to_string()).collect();
    let per_file = run_indexed(files.len(), jobs, |i| {
        if suffix_match(&labels[i], WALLCLOCK_ALLOWED) {
            return Ok(Vec::new());
        }
        let sites = rules::wallclock_sites(&labels[i], &lexer::lex(&sources[i]));
        if !suffix_match(&labels[i], WALLCLOCK_CAPTURE_SCOPED) {
            return Ok(sites);
        }
        let regions = wallclock_capture_regions(&sources[i])
            .with_context(|| format!("bad capture markers in {}", labels[i]))?;
        Ok(sites
            .into_iter()
            .filter(|f| !regions.iter().any(|&(b, e)| b <= f.line && f.line <= e))
            .collect())
    });
    let mut out: Vec<Finding> = Vec::new();
    for r in per_file {
        out.extend(r?);
    }
    out.sort();
    Ok(out)
}

/// All `.rs` files under `path` (itself, if it is a file), sorted.
pub fn collect_rs_files(path: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if path.is_file() {
        out.push(path.to_path_buf());
        return Ok(out);
    }
    walk(path, &mut out)?;
    out.sort();
    Ok(out)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    let iter =
        std::fs::read_dir(dir).with_context(|| format!("reading directory {}", dir.display()))?;
    for e in iter {
        entries.push(e.with_context(|| format!("reading directory {}", dir.display()))?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Default lint root when no paths are given: the crate sources, whether
/// invoked from the repo root or from `rust/`.
pub fn default_root() -> Result<PathBuf> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return Ok(p);
        }
    }
    bail!("no rust/src or src directory here; pass explicit paths to `lumos lint`")
}

/// Deterministic JSON form of the report (the CI gate diffs this across
/// `--jobs` values).
pub fn report_json(r: &LintReport) -> Json {
    Json::obj(vec![
        ("files_scanned", Json::num(r.files_scanned as f64)),
        ("suppressed", Json::num(r.suppressed as f64)),
        (
            "findings",
            Json::arr(r.findings.iter().map(|f| {
                Json::obj(vec![
                    ("file", Json::str(&f.file)),
                    ("line", Json::num(f.line as f64)),
                    ("rule", Json::str(f.rule)),
                    ("message", Json::str(&f.message)),
                ])
            })),
        ),
    ])
}

/// Human-readable rule registry (`lumos lint --list`).
pub fn rule_table() -> String {
    let mut out = String::from("lint rules (suppress: `// lumos: allow(<rule>) -- <reason>`):\n");
    for r in rules::RULES {
        out.push_str(&format!("  {:14} {}\n{:17}{}\n", r.id, r.fires_on, "", r.why));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_source_reports_and_sorts() {
        let src = "use std::collections::HashMap;\nfn f() { x.unwrap(); }\n";
        let (fs, sup) = lint_source("a.rs", src, &[]);
        assert_eq!(sup, 0);
        assert_eq!(fs.len(), 2);
        assert!(fs[0].line <= fs[1].line);
        assert_eq!(fs[0].rule, "hash-iter");
        let shown = fs[0].to_string();
        assert!(shown.starts_with("a.rs:1: [hash-iter]"), "{shown}");
    }

    #[test]
    fn report_json_shape() {
        let (findings, suppressed) = lint_source("a.rs", "fn f() { panic!(\"x\") }\n", &[]);
        let r = LintReport { findings, files_scanned: 1, suppressed };
        let j = report_json(&r);
        assert_eq!(j.get("files_scanned").as_usize(), Some(1));
        let arr = j.get("findings").as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("rule").as_str(), Some("panic-path"));
    }

    #[test]
    fn tree_passes_the_wallclock_audit() {
        // the whole crate keeps host-clock reads inside WALLCLOCK_ALLOWED;
        // jobs=2 also exercises the index-ordered fan-out
        let root = default_root().unwrap();
        let fs = wallclock_audit(&[root], 2).unwrap();
        assert!(fs.is_empty(), "clock reads outside the allowlist: {fs:?}");
    }

    #[test]
    fn audit_reports_non_allowlisted_sites() {
        // this very file is not allowlisted: a clock read here would fail
        // the audit even though it is in a test (the audit masks tests, so
        // instead feed the scanner a synthetic non-test source)
        let lexed = lexer::lex("fn f() { let t = Instant::now(); }\n");
        let sites = rules::wallclock_sites("netsim/dep.rs", &lexed);
        assert_eq!(sites.len(), 1);
        let allowed = |label: &str| {
            WALLCLOCK_ALLOWED.iter().any(|a| label.replace('\\', "/").ends_with(a))
        };
        assert!(!allowed("rust/src/netsim/dep.rs"));
        assert!(allowed("rust/src/obs/profile.rs"));
        // the former blanket entries now route through the recorder
        assert!(!allowed("rust/src/runtime/engine.rs"));
        assert!(!allowed("rust/src/trainer/mod.rs"));
    }

    #[test]
    fn capture_regions_parse_markers() {
        let src = "a\n// lumos: wallclock-capture-begin\nb\nc\n// lumos: wallclock-capture-end\nd\n";
        assert_eq!(wallclock_capture_regions(src).unwrap(), vec![(2, 5)]);
        assert_eq!(wallclock_capture_regions("no markers\n").unwrap(), vec![]);
        // unclosed begin extends to EOF
        let open = "x\n// lumos: wallclock-capture-begin\ny\n";
        assert_eq!(wallclock_capture_regions(open).unwrap(), vec![(2, 3)]);
        // end without begin is an error
        assert!(wallclock_capture_regions("// lumos: wallclock-capture-end\n").is_err());
    }

    #[test]
    fn scoped_file_permits_reads_only_inside_markers() {
        // mirror of the audit's filtering logic on a synthetic record.rs
        let src = "\
// lumos: wallclock-capture-begin
fn inside() -> std::time::Instant { std::time::Instant::now() }
// lumos: wallclock-capture-end
fn outside() -> std::time::Instant { std::time::Instant::now() }
";
        let sites = rules::wallclock_sites("obs/record.rs", &lexer::lex(src));
        assert_eq!(sites.len(), 2, "{sites:?}");
        let regions = wallclock_capture_regions(src).unwrap();
        let escaped: Vec<&Finding> = sites
            .iter()
            .filter(|f| !regions.iter().any(|&(b, e)| b <= f.line && f.line <= e))
            .collect();
        assert_eq!(escaped.len(), 1);
        assert_eq!(escaped[0].line, 4);
    }

    #[test]
    fn the_real_recorder_keeps_reads_inside_its_markers() {
        // the canary contract CI relies on: obs/record.rs has marked
        // regions, its clock reads all sit inside them, and a read
        // appended at EOF would escape.
        let root = default_root().unwrap();
        let path = root.join("obs").join("record.rs");
        let src = std::fs::read_to_string(&path).unwrap();
        let regions = wallclock_capture_regions(&src).unwrap();
        assert!(!regions.is_empty());
        let sites = rules::wallclock_sites("obs/record.rs", &lexer::lex(&src));
        assert!(!sites.is_empty(), "the capture helper reads the clock");
        for f in &sites {
            assert!(
                regions.iter().any(|&(b, e)| b <= f.line && f.line <= e),
                "clock read at line {} escapes the capture region",
                f.line
            );
        }
        let n_lines = src.lines().count();
        assert!(
            regions.iter().all(|&(_, e)| e < n_lines),
            "capture region must not extend to EOF"
        );
    }

    #[test]
    fn rule_table_lists_every_rule() {
        let t = rule_table();
        for r in rules::RULES {
            assert!(t.contains(r.id), "missing {}", r.id);
        }
    }
}
