//! Minimal Rust lexer for the lint pass: source text → comment records +
//! a comment-free token stream.
//!
//! This is deliberately not a full Rust grammar — just enough token
//! fidelity that the rules in [`super::rules`] can match
//! identifier/punctuation shapes without being fooled by string literals,
//! char literals, lifetimes, raw strings, or (doc) comments. It is
//! dependency-free like the rest of the substrate (DESIGN.md §Environment
//! deviations): no proc-macro2/syn, just a hand-rolled cursor.

/// Token classes the rule engine distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (raw `r#ident`s included, prefix stripped).
    Ident,
    /// `'a`, `'static`, `'_`, loop labels — lifetimes, not char literals.
    Lifetime,
    /// Numeric literal (any base, float exponents, type suffixes).
    Num,
    /// String literal of any flavour (`"…"`, `r#"…"#`, `b"…"`).
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Single non-bracket punctuation character (`::` is two tokens).
    Punct,
    /// `(`, `[`, `{`.
    Open,
    /// `)`, `]`, `}`.
    Close,
}

/// One token: kind, verbatim text, and 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// One comment (line, block, or doc) with its text including delimiters;
/// block comments may span `line..=end_line`.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub end_line: usize,
    pub text: String,
}

/// Lex result: the comment-free token stream plus every comment.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    pub comments: Vec<Comment>,
}

struct Cursor {
    chars: Vec<char>,
    pos: usize,
    line: usize,
}

impl Cursor {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn peek_at(&self, k: usize) -> Option<char> {
        self.chars.get(self.pos + k).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
        }
        Some(c)
    }

    fn bump_into(&mut self, text: &mut String) {
        if let Some(c) = self.bump() {
            text.push(c);
        }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens and comments. Never fails: unknown bytes become
/// single-character [`TokKind::Punct`] tokens, so the scan degrades
/// gracefully on pathological input instead of erroring.
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor { chars: src.chars().collect(), pos: 0, line: 1 };
    let mut out = Lexed::default();
    while let Some(c) = cur.peek() {
        if c.is_whitespace() {
            cur.bump();
        } else if c == '/' && cur.peek_at(1) == Some('/') {
            line_comment(&mut cur, &mut out);
        } else if c == '/' && cur.peek_at(1) == Some('*') {
            block_comment(&mut cur, &mut out);
        } else if is_ident_start(c) {
            ident_or_prefixed(&mut cur, &mut out);
        } else if c.is_ascii_digit() {
            number(&mut cur, &mut out);
        } else if c == '"' {
            string_lit(&mut cur, &mut out, String::new());
        } else if c == '\'' {
            quote(&mut cur, &mut out);
        } else {
            let line = cur.line;
            cur.bump();
            let kind = match c {
                '(' | '[' | '{' => TokKind::Open,
                ')' | ']' | '}' => TokKind::Close,
                _ => TokKind::Punct,
            };
            out.tokens.push(Tok { kind, text: c.to_string(), line });
        }
    }
    out
}

fn line_comment(cur: &mut Cursor, out: &mut Lexed) {
    let line = cur.line;
    let mut text = String::new();
    while let Some(c) = cur.peek() {
        if c == '\n' {
            break;
        }
        text.push(c);
        cur.bump();
    }
    out.comments.push(Comment { line, end_line: line, text });
}

fn block_comment(cur: &mut Cursor, out: &mut Lexed) {
    let line = cur.line;
    let mut text = String::new();
    cur.bump_into(&mut text); // '/'
    cur.bump_into(&mut text); // '*'
    let mut depth = 1usize;
    while depth > 0 {
        match cur.peek() {
            None => break,
            Some('/') if cur.peek_at(1) == Some('*') => {
                depth += 1;
                cur.bump_into(&mut text);
                cur.bump_into(&mut text);
            }
            Some('*') if cur.peek_at(1) == Some('/') => {
                depth -= 1;
                cur.bump_into(&mut text);
                cur.bump_into(&mut text);
            }
            Some(_) => cur.bump_into(&mut text),
        }
    }
    out.comments.push(Comment { line, end_line: cur.line, text });
}

/// Identifier, or one of the prefixed literal forms that start with an
/// identifier character: `r"…"`, `r#"…"#`, `r#ident`, `b"…"`, `b'…'`,
/// `br"…"`, `br#"…"#`.
fn ident_or_prefixed(cur: &mut Cursor, out: &mut Lexed) {
    let c0 = cur.peek();
    if c0 == Some('r') {
        let mut hashes = 0usize;
        while cur.peek_at(1 + hashes) == Some('#') {
            hashes += 1;
        }
        if cur.peek_at(1 + hashes) == Some('"') {
            raw_string(cur, out, 1, hashes);
            return;
        }
        if hashes == 1 && cur.peek_at(2).is_some_and(is_ident_start) {
            cur.bump(); // 'r'
            cur.bump(); // '#'
            plain_ident(cur, out);
            return;
        }
    } else if c0 == Some('b') {
        match cur.peek_at(1) {
            Some('"') => {
                let mut text = String::new();
                cur.bump_into(&mut text); // 'b'
                string_lit(cur, out, text);
                return;
            }
            Some('\'') => {
                let line = cur.line;
                let mut text = String::new();
                cur.bump_into(&mut text); // 'b'
                char_lit(cur, &mut text);
                out.tokens.push(Tok { kind: TokKind::Char, text, line });
                return;
            }
            Some('r') => {
                let mut hashes = 0usize;
                while cur.peek_at(2 + hashes) == Some('#') {
                    hashes += 1;
                }
                if cur.peek_at(2 + hashes) == Some('"') {
                    raw_string(cur, out, 2, hashes);
                    return;
                }
            }
            _ => {}
        }
    }
    plain_ident(cur, out);
}

fn plain_ident(cur: &mut Cursor, out: &mut Lexed) {
    let line = cur.line;
    let mut text = String::new();
    while let Some(c) = cur.peek() {
        if !is_ident_continue(c) {
            break;
        }
        text.push(c);
        cur.bump();
    }
    out.tokens.push(Tok { kind: TokKind::Ident, text, line });
}

/// `prefix_len` chars (`r` or `br`), then `hashes` `#`s, then the quoted
/// body, closed by `"` followed by the same number of `#`s.
fn raw_string(cur: &mut Cursor, out: &mut Lexed, prefix_len: usize, hashes: usize) {
    let line = cur.line;
    let mut text = String::new();
    for _ in 0..prefix_len + hashes + 1 {
        cur.bump_into(&mut text);
    }
    loop {
        match cur.peek() {
            None => break,
            Some('"') => {
                let closes = (0..hashes).all(|k| cur.peek_at(1 + k) == Some('#'));
                cur.bump_into(&mut text);
                if closes {
                    for _ in 0..hashes {
                        cur.bump_into(&mut text);
                    }
                    break;
                }
            }
            Some(_) => cur.bump_into(&mut text),
        }
    }
    out.tokens.push(Tok { kind: TokKind::Str, text, line });
}

/// Ordinary (or byte) string starting at `"`; `text` may carry a `b`
/// prefix already consumed by the caller.
fn string_lit(cur: &mut Cursor, out: &mut Lexed, mut text: String) {
    let line = cur.line;
    cur.bump_into(&mut text); // opening '"'
    while let Some(c) = cur.peek() {
        if c == '\\' {
            cur.bump_into(&mut text);
            cur.bump_into(&mut text);
        } else if c == '"' {
            cur.bump_into(&mut text);
            break;
        } else {
            cur.bump_into(&mut text);
        }
    }
    out.tokens.push(Tok { kind: TokKind::Str, text, line });
}

/// `'` starts either a char literal or a lifetime/label. It is a char
/// literal iff the next char is an escape, or the char after next closes
/// the quote (`'x'`); everything else (`'a`, `'static`, `'_`, `'outer:`)
/// is a lifetime.
fn quote(cur: &mut Cursor, out: &mut Lexed) {
    let line = cur.line;
    let is_char = cur.peek_at(1) == Some('\\') || cur.peek_at(2) == Some('\'');
    let mut text = String::new();
    if is_char {
        char_lit(cur, &mut text);
        out.tokens.push(Tok { kind: TokKind::Char, text, line });
    } else {
        cur.bump_into(&mut text); // '\''
        while let Some(c) = cur.peek() {
            if !is_ident_continue(c) {
                break;
            }
            text.push(c);
            cur.bump();
        }
        out.tokens.push(Tok { kind: TokKind::Lifetime, text, line });
    }
}

/// Body of a char/byte-char literal, cursor on the opening `'`.
fn char_lit(cur: &mut Cursor, text: &mut String) {
    cur.bump_into(text); // opening '\''
    while let Some(c) = cur.peek() {
        if c == '\\' {
            cur.bump_into(text);
            cur.bump_into(text);
        } else if c == '\'' {
            cur.bump_into(text);
            break;
        } else {
            cur.bump_into(text);
        }
    }
}

/// Number: digits/`_`/base prefixes/type suffixes, one `.` if followed by
/// a digit (so `0..n` stays a range), and `e±dd` exponents on non-hex
/// literals.
fn number(cur: &mut Cursor, out: &mut Lexed) {
    let line = cur.line;
    let mut text = String::new();
    while let Some(c) = cur.peek() {
        if c.is_ascii_alphanumeric() || c == '_' {
            text.push(c);
            cur.bump();
        } else if c == '.'
            && !text.contains('.')
            && cur.peek_at(1).is_some_and(|d| d.is_ascii_digit())
        {
            text.push(c);
            cur.bump();
        } else if (c == '+' || c == '-')
            && (text.ends_with('e') || text.ends_with('E'))
            && !text.starts_with("0x")
            && cur.peek_at(1).is_some_and(|d| d.is_ascii_digit())
        {
            text.push(c);
            cur.bump();
        } else {
            break;
        }
    }
    out.tokens.push(Tok { kind: TokKind::Num, text, line });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).tokens.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_and_brackets() {
        let ts = kinds("fn f(x: usize) -> usize { x + 1 }");
        assert_eq!(ts[0], (TokKind::Ident, "fn".into()));
        assert_eq!(ts[2], (TokKind::Open, "(".into()));
        assert!(ts.iter().any(|t| *t == (TokKind::Num, "1".into())));
        assert_eq!(ts.last().map(|t| t.0), Some(TokKind::Close));
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let l = lex("a // trailing\n/* block\nspans */ b");
        assert_eq!(l.tokens.len(), 2);
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].line, 1);
        assert_eq!(l.comments[1].line, 2);
        assert_eq!(l.comments[1].end_line, 3);
        assert_eq!(l.tokens[1].line, 3);
    }

    #[test]
    fn nested_block_comments() {
        let l = lex("/* a /* b */ c */ x");
        assert_eq!(l.tokens.len(), 1);
        assert_eq!(l.tokens[0].text, "x");
    }

    #[test]
    fn strings_hide_their_contents() {
        let ts = kinds(r#"let s = "HashMap // not a comment";"#);
        assert!(ts.iter().all(|t| t.1 != "HashMap"));
        assert_eq!(ts.iter().filter(|t| t.0 == TokKind::Str).count(), 1);
    }

    #[test]
    fn raw_and_byte_strings() {
        let l = lex("r#\"raw \" inner\"# b\"bytes\" br\"rawbytes\"");
        assert_eq!(l.tokens.len(), 3);
        assert!(l.tokens.iter().all(|t| t.kind == TokKind::Str));
        let ts = kinds("r#match x");
        assert_eq!(ts[0], (TokKind::Ident, "match".into()));
        assert_eq!(ts[1], (TokKind::Ident, "x".into()));
    }

    #[test]
    fn char_vs_lifetime() {
        let ts = kinds("let c = 'a'; let b = b'\\n'; fn f<'a>(x: &'a str) {}");
        let chars: Vec<_> = ts.iter().filter(|t| t.0 == TokKind::Char).collect();
        let lifes: Vec<_> = ts.iter().filter(|t| t.0 == TokKind::Lifetime).collect();
        assert_eq!(chars.len(), 2);
        assert_eq!(lifes.len(), 2);
        let ts = kinds("'outer: loop { break 'outer; }");
        assert_eq!(ts.iter().filter(|t| t.0 == TokKind::Lifetime).count(), 2);
    }

    #[test]
    fn escaped_quote_chars() {
        let ts = kinds(r"let q = '\''; let u = '\u{8}'; let sp = b' ';");
        assert_eq!(ts.iter().filter(|t| t.0 == TokKind::Char).count(), 3);
    }

    #[test]
    fn numbers_ranges_exponents() {
        let ts = kinds("1.9e-15 0..n 0xFFF0 1_000 2.5f64");
        let nums: Vec<_> =
            ts.iter().filter(|t| t.0 == TokKind::Num).map(|t| t.1.clone()).collect();
        assert_eq!(nums, vec!["1.9e-15", "0", "0xFFF0", "1_000", "2.5f64"]);
        assert!(ts.iter().any(|t| t.1 == "n" && t.0 == TokKind::Ident));
    }

    #[test]
    fn lines_are_one_based_and_tracked() {
        let l = lex("a\nb\n\nc");
        let lines: Vec<usize> = l.tokens.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }
}
