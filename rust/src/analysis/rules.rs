//! The lint rule engine: determinism & concurrency rules over the token
//! stream from [`super::lexer`], `#[cfg(test)]`/`#[test]` masking, and
//! `lumos: allow(<rule>) -- <reason>` suppression directives.
//!
//! Every rule is wired to a real repo invariant (DESIGN.md §Determinism
//! invariants & lint rules): results must be byte-identical across
//! `--jobs N` and reproducible from `--seed`, so ambient hash order,
//! wall clocks, ambient entropy, and arrival-order float reduction are
//! all structural hazards, not style nits.

use std::collections::BTreeSet;

use super::lexer::{Comment, Lexed, Tok, TokKind};
use super::Finding;

/// One rule: stable id (the `--rule` / `allow(...)` key), what it fires
/// on, and the invariant it protects.
#[derive(Debug, Clone, Copy)]
pub struct RuleDef {
    pub id: &'static str,
    pub fires_on: &'static str,
    pub why: &'static str,
}

/// The rule registry (`lumos lint --list`).
pub const RULES: &[RuleDef] = &[
    RuleDef {
        id: "hash-iter",
        fires_on: "HashMap / HashSet / RandomState / DefaultHasher",
        why: "std hash iteration order varies per process; ordered collections \
              keep every table/figure byte-identical",
    },
    RuleDef {
        id: "wallclock",
        fires_on: "Instant::now / SystemTime",
        why: "wall-clock reads leak host timing into results; only measurement \
              harnesses may read clocks, and each site says why",
    },
    RuleDef {
        id: "entropy",
        fires_on: "thread_rng / rand::random / OsRng / from_entropy",
        why: "all randomness must flow from the seeded, index-order-forked \
              util::rng streams (--seed reproducibility)",
    },
    RuleDef {
        id: "float-reduce",
        fires_on: "accumulation over arrival-order channel receives",
        why: "float addition is not associative; reduce in index order \
              (sweep::engine::run_indexed) so --jobs N is bit-stable",
    },
    RuleDef {
        id: "panic-path",
        fires_on: ".unwrap() / .expect() / panic! outside tests",
        why: "library panics must be structurally impossible (say why inline) \
              or become Result propagation",
    },
    RuleDef {
        id: "unsafe-safety",
        fires_on: "`unsafe` without a nearby SAFETY comment",
        why: "every unsafe site documents the invariant that makes it sound",
    },
    RuleDef {
        id: "lint-directive",
        fires_on: "malformed or dangling `lumos:` comments",
        why: "a suppression that does not parse silently suppresses nothing",
    },
];

/// Is `id` a known rule id?
pub fn is_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// Scan one lexed file. Returns the findings that survive suppression
/// (sorted by line, deduplicated per (line, rule)) and the count of
/// findings suppressed by `lumos: allow` directives. `only` restricts to
/// the listed rule ids; empty means all rules.
pub fn scan_lexed(file: &str, lexed: &Lexed, only: &[String]) -> (Vec<Finding>, usize) {
    let toks = &lexed.tokens;
    let masked = test_mask(toks);
    let enabled = |id: &str| only.is_empty() || only.iter().any(|o| o == id);

    let mut raw: Vec<Finding> = Vec::new();
    if enabled("hash-iter") {
        ident_rule(file, toks, &masked, "hash-iter", &mut raw);
    }
    if enabled("entropy") {
        ident_rule(file, toks, &masked, "entropy", &mut raw);
    }
    if enabled("wallclock") {
        rule_wallclock(file, toks, &masked, &mut raw);
    }
    if enabled("panic-path") {
        rule_panic_path(file, toks, &masked, &mut raw);
    }
    if enabled("unsafe-safety") {
        rule_unsafe_safety(file, toks, &masked, &lexed.comments, &mut raw);
    }
    if enabled("float-reduce") {
        rule_float_reduce(file, toks, &masked, &mut raw);
    }

    let (suppress, problems) = directive_map(toks, &lexed.comments);
    if enabled("lint-directive") {
        for (line, msg) in problems {
            raw.push(Finding {
                file: file.to_string(),
                line,
                rule: "lint-directive",
                message: msg,
            });
        }
    }

    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for f in raw {
        if suppress.contains(&(f.line, f.rule.to_string())) {
            suppressed += 1;
        } else {
            kept.push(f);
        }
    }
    kept.sort();
    kept.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);
    (kept, suppressed)
}

/// Every wall-clock read site in `lexed`, with suppression directives
/// deliberately ignored (test-masked code stays excluded): the input of
/// the `--audit-wallclock` gate ([`super::wallclock_audit`]), which then
/// checks each site's file against the module allowlist. An *annotated*
/// clock read in a non-allowlisted module passes the regular lint but
/// fails the audit — the quarantine is a module boundary, not a per-site
/// judgment call.
pub fn wallclock_sites(file: &str, lexed: &Lexed) -> Vec<Finding> {
    let toks = &lexed.tokens;
    let masked = test_mask(toks);
    let mut out = Vec::new();
    rule_wallclock(file, toks, &masked, &mut out);
    out.sort();
    out.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);
    out
}

// ---------------------------------------------------------------------
// Token-tree helpers
// ---------------------------------------------------------------------

/// Index one past the `Close` matching the `Open` at `open`.
fn group_end(toks: &[Tok], open: usize) -> usize {
    let mut depth = 1usize;
    let mut i = open + 1;
    while i < toks.len() && depth > 0 {
        match toks[i].kind {
            TokKind::Open => depth += 1,
            TokKind::Close => depth -= 1,
            _ => {}
        }
        i += 1;
    }
    i
}

/// End of the item starting at `start`: one past the first depth-0 `;`,
/// or one past the close of the first depth-0 `{…}` body.
fn item_end(toks: &[Tok], start: usize) -> usize {
    let mut i = start;
    while i < toks.len() {
        let t = &toks[i];
        match t.kind {
            TokKind::Open => {
                if t.text == "{" {
                    return group_end(toks, i);
                }
                i = group_end(toks, i);
            }
            // the enclosing block closed before the item did — stop here
            TokKind::Close => return i,
            _ => {
                if t.text == ";" {
                    return i + 1;
                }
                i += 1;
            }
        }
    }
    toks.len()
}

/// Token mask covering `#[test]` / `#[cfg(test)]`-attributed items (and
/// any attributes stacked on them): panics and clocks are fine in tests.
/// `#[cfg(not(test))]` does NOT mask (the `not` ident opts back in).
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut masked = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].kind == TokKind::Punct && toks[i].text == "#") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if j < toks.len() && toks[j].kind == TokKind::Punct && toks[j].text == "!" {
            j += 1;
        }
        if !(j < toks.len() && toks[j].kind == TokKind::Open && toks[j].text == "[") {
            i += 1;
            continue;
        }
        let attr_end = group_end(toks, j);
        let mut has_test = false;
        let mut has_not = false;
        for t in &toks[j + 1..attr_end.saturating_sub(1)] {
            if t.kind == TokKind::Ident {
                has_test |= t.text == "test";
                has_not |= t.text == "not";
            }
        }
        if !(has_test && !has_not) {
            i = attr_end;
            continue;
        }
        // swallow further stacked attributes, then the attributed item
        let mut k = attr_end;
        while k + 1 < toks.len()
            && toks[k].kind == TokKind::Punct
            && toks[k].text == "#"
            && toks[k + 1].kind == TokKind::Open
            && toks[k + 1].text == "["
        {
            k = group_end(toks, k + 1);
        }
        let end = item_end(toks, k);
        for m in masked.iter_mut().take(end).skip(i) {
            *m = true;
        }
        i = end;
    }
    masked
}

// ---------------------------------------------------------------------
// Suppression directives
// ---------------------------------------------------------------------

/// Parse every `lumos:` comment. Returns the suppression set — (code
/// line, rule id) pairs — plus (line, message) problems for malformed or
/// dangling directives.
#[allow(clippy::type_complexity)]
fn directive_map(
    toks: &[Tok],
    comments: &[Comment],
) -> (BTreeSet<(usize, String)>, Vec<(usize, String)>) {
    let code_lines: BTreeSet<usize> = toks.iter().map(|t| t.line).collect();
    let mut suppress = BTreeSet::new();
    let mut problems = Vec::new();
    for c in comments {
        let body = c.text.trim_start_matches(['/', '!', '*']).trim_start();
        let Some(rest) = body.strip_prefix("lumos:") else {
            continue;
        };
        match parse_allow(rest.trim()) {
            Err(e) => problems.push((c.line, format!("malformed lint directive: {e}"))),
            Ok(rules) => {
                // a trailing directive covers its own line; a standalone
                // one covers the next line that has code on it
                let target = if code_lines.contains(&c.line) {
                    Some(c.line)
                } else {
                    code_lines.range(c.end_line + 1..).next().copied()
                };
                match target {
                    Some(t) => {
                        for r in rules {
                            suppress.insert((t, r));
                        }
                    }
                    None => problems.push((
                        c.line,
                        "lint directive does not precede any code".to_string(),
                    )),
                }
            }
        }
    }
    (suppress, problems)
}

/// Grammar after the `lumos:` marker:
/// `allow(<rule>[, <rule>]*) -- <reason>` with a nonempty reason.
fn parse_allow(rest: &str) -> Result<Vec<String>, String> {
    let rest = rest
        .strip_prefix("allow")
        .ok_or("expected `allow(<rule>[, <rule>]*) -- <reason>`")?;
    let rest = rest.trim_start().strip_prefix('(').ok_or("expected `(` after `allow`")?;
    let (ids, rest) = rest.split_once(')').ok_or("missing `)` after the rule list")?;
    let mut rules = Vec::new();
    for id in ids.split(',') {
        let id = id.trim();
        if id.is_empty() {
            return Err("empty rule id in allow(...)".to_string());
        }
        if !is_rule(id) {
            return Err(format!("unknown rule '{id}' (see `lumos lint --list`)"));
        }
        rules.push(id.to_string());
    }
    let rest = rest.trim_start();
    let reason = rest.strip_prefix("--").ok_or("missing `-- <reason>` justification")?;
    if reason.trim().is_empty() {
        return Err("empty justification after `--`".to_string());
    }
    Ok(rules)
}

// ---------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------

fn push(out: &mut Vec<Finding>, file: &str, line: usize, rule: &'static str, message: String) {
    out.push(Finding { file: file.to_string(), line, rule, message });
}

/// hash-iter and entropy are plain banned-identifier rules.
fn ident_rule(
    file: &str,
    toks: &[Tok],
    masked: &[bool],
    rule: &'static str,
    out: &mut Vec<Finding>,
) {
    let hash_idents = ["HashMap", "HashSet", "RandomState", "DefaultHasher"];
    let entropy_idents = ["thread_rng", "ThreadRng", "OsRng", "from_entropy"];
    for (i, t) in toks.iter().enumerate() {
        if masked[i] || t.kind != TokKind::Ident {
            continue;
        }
        if rule == "hash-iter" && hash_idents.contains(&t.text.as_str()) {
            push(
                out,
                file,
                t.line,
                rule,
                format!(
                    "std hash collection `{}` — iteration order is nondeterministic; \
                     use BTreeMap/BTreeSet",
                    t.text
                ),
            );
        }
        if rule == "entropy" {
            let rand_random = t.text == "random"
                && i >= 3
                && toks[i - 1].text == ":"
                && toks[i - 2].text == ":"
                && toks[i - 3].text == "rand";
            if entropy_idents.contains(&t.text.as_str()) || rand_random {
                push(
                    out,
                    file,
                    t.line,
                    rule,
                    format!(
                        "`{}` draws ambient entropy — all randomness must flow from \
                         the seeded util::rng streams",
                        t.text
                    ),
                );
            }
        }
    }
}

fn rule_wallclock(file: &str, toks: &[Tok], masked: &[bool], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if masked[i] || t.kind != TokKind::Ident {
            continue;
        }
        let instant_now = t.text == "Instant"
            && i + 3 < toks.len()
            && toks[i + 1].text == ":"
            && toks[i + 2].text == ":"
            && toks[i + 3].text == "now";
        if instant_now || t.text == "SystemTime" {
            let what = if instant_now { "Instant::now" } else { "SystemTime" };
            push(
                out,
                file,
                t.line,
                "wallclock",
                format!(
                    "`{what}` reads the wall clock — deterministic modules must not; \
                     measurement harnesses say why inline"
                ),
            );
        }
    }
}

fn rule_panic_path(file: &str, toks: &[Tok], masked: &[bool], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if masked[i] || t.kind != TokKind::Ident {
            continue;
        }
        let method_call = (t.text == "unwrap" || t.text == "expect")
            && i >= 1
            && toks[i - 1].kind == TokKind::Punct
            && toks[i - 1].text == "."
            && i + 1 < toks.len()
            && toks[i + 1].kind == TokKind::Open
            && toks[i + 1].text == "(";
        if method_call {
            push(
                out,
                file,
                t.line,
                "panic-path",
                format!(
                    "`.{}()` can panic in library code — propagate a Result or \
                     justify the invariant",
                    t.text
                ),
            );
        }
        let macro_call = t.text == "panic"
            && i + 1 < toks.len()
            && toks[i + 1].kind == TokKind::Punct
            && toks[i + 1].text == "!";
        if macro_call {
            push(
                out,
                file,
                t.line,
                "panic-path",
                "`panic!` in library code — return an error or justify the invariant"
                    .to_string(),
            );
        }
    }
}

/// An `unsafe` token needs a comment containing `SAFETY` ending on its
/// own line or within the 3 lines above it.
fn rule_unsafe_safety(
    file: &str,
    toks: &[Tok],
    masked: &[bool],
    comments: &[Comment],
    out: &mut Vec<Finding>,
) {
    for (i, t) in toks.iter().enumerate() {
        if masked[i] || t.kind != TokKind::Ident || t.text != "unsafe" {
            continue;
        }
        let lo = t.line.saturating_sub(3);
        let justified = comments
            .iter()
            .any(|c| c.text.contains("SAFETY") && c.end_line >= lo && c.end_line <= t.line);
        if !justified {
            push(
                out,
                file,
                t.line,
                "unsafe-safety",
                "`unsafe` without a `// SAFETY:` comment on or within 3 lines above it"
                    .to_string(),
            );
        }
    }
}

/// Arrival-order receives: `.recv()` / `.try_recv()` with no arguments,
/// `.recv_timeout(…)`, or a `for … in <receiver-ish>` header. Selective
/// receives with arguments (e.g. the coordinator's tagged
/// `self.recv(src, tag)`) are deterministic and do not count.
fn arrival_order_recv(toks: &[Tok], i: usize) -> bool {
    let t = &toks[i];
    if t.kind != TokKind::Ident {
        return false;
    }
    let after_dot = i >= 1 && toks[i - 1].kind == TokKind::Punct && toks[i - 1].text == ".";
    let empty_call = |k: usize| {
        toks.get(k).is_some_and(|o| o.kind == TokKind::Open && o.text == "(")
            && toks.get(k + 1).is_some_and(|c| c.kind == TokKind::Close && c.text == ")")
    };
    if after_dot && (t.text == "recv" || t.text == "try_recv") && empty_call(i + 1) {
        return true;
    }
    if after_dot
        && t.text == "recv_timeout"
        && toks.get(i + 1).is_some_and(|o| o.kind == TokKind::Open && o.text == "(")
    {
        return true;
    }
    // `for (i, r) in res_rx { … }` — iterating a receiver yields
    // completion order
    let receiver_ish = t.text == "rx"
        || t.text.ends_with("_rx")
        || t.text.starts_with("rx_")
        || t.text.contains("receiver");
    receiver_ish && i >= 1 && toks[i - 1].kind == TokKind::Ident && toks[i - 1].text == "in"
}

/// Float accumulation shapes: compound assignment (`+=` `-=` `*=` `/=`)
/// or `.sum(` / `.fold(` / `.product(`.
fn is_accumulation(toks: &[Tok], j: usize) -> bool {
    let t = &toks[j];
    if t.kind == TokKind::Punct
        && matches!(t.text.as_str(), "+" | "-" | "*" | "/")
        && toks.get(j + 1).is_some_and(|n| n.kind == TokKind::Punct && n.text == "=")
    {
        return true;
    }
    t.kind == TokKind::Ident
        && matches!(t.text.as_str(), "sum" | "fold" | "product")
        && j >= 1
        && toks[j - 1].kind == TokKind::Punct
        && toks[j - 1].text == "."
        && toks.get(j + 1).is_some_and(|n| n.kind == TokKind::Open && n.text == "(")
}

/// For every arrival-order receive, look for an accumulation in the rest
/// of its enclosing block; receiving in completion order and folding the
/// results changes the bits across worker counts.
fn rule_float_reduce(file: &str, toks: &[Tok], masked: &[bool], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        if masked[i] || !arrival_order_recv(toks, i) {
            continue;
        }
        let mut depth = 0usize;
        let mut j = i + 1;
        while j < toks.len() {
            match toks[j].kind {
                TokKind::Open => depth += 1,
                TokKind::Close => {
                    if depth == 0 {
                        break; // enclosing block closed
                    }
                    depth -= 1;
                }
                _ => {}
            }
            if !masked[j] && is_accumulation(toks, j) {
                push(
                    out,
                    file,
                    toks[j].line,
                    "float-reduce",
                    format!(
                        "float accumulation over arrival-order results (receive at \
                         line {}) — restore index order before reducing \
                         (sweep::engine::run_indexed)",
                        toks[i].line
                    ),
                );
                break;
            }
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn findings(src: &str) -> Vec<(usize, &'static str)> {
        let (fs, _) = scan_lexed("t.rs", &lex(src), &[]);
        fs.into_iter().map(|f| (f.line, f.rule)).collect()
    }

    #[test]
    fn test_items_are_masked() {
        let src = "#[test]\nfn t() { x.unwrap(); }\nfn lib() { y.unwrap(); }\n";
        assert_eq!(findings(src), vec![(3, "panic-path")]);
    }

    #[test]
    fn cfg_test_mod_is_masked_but_not_cfg_not_test() {
        let src = "#[cfg(test)]\nmod tests { fn t() { panic!(\"x\") } }\n";
        assert!(findings(src).is_empty());
        let src = "#[cfg(not(test))]\nfn lib() { panic!(\"x\") }\n";
        assert_eq!(findings(src), vec![(2, "panic-path")]);
    }

    #[test]
    fn stacked_attributes_extend_the_mask() {
        let src = "#[test]\n#[ignore]\nfn t() { q.unwrap(); }\n";
        assert!(findings(src).is_empty());
    }

    #[test]
    fn suppression_targets_next_code_line() {
        let src = "// lumos: allow(panic-path) -- structurally nonempty\nfn f() { x.unwrap(); }\n";
        let (fs, sup) = scan_lexed("t.rs", &lex(src), &[]);
        assert!(fs.is_empty());
        assert_eq!(sup, 1);
    }

    #[test]
    fn trailing_suppression_covers_its_own_line() {
        let src = "fn f() { x.unwrap() } // lumos: allow(panic-path) -- infallible\n";
        let (fs, sup) = scan_lexed("t.rs", &lex(src), &[]);
        assert!(fs.is_empty());
        assert_eq!(sup, 1);
    }

    #[test]
    fn malformed_directives_are_findings() {
        let src = "// lumos: allow(panic-path)\nfn f() {}\n";
        assert_eq!(findings(src), vec![(1, "lint-directive")]);
        let src = "// lumos: allow(no-such-rule) -- why\nfn f() {}\n";
        assert_eq!(findings(src), vec![(1, "lint-directive")]);
        let src = "fn f() {}\n// lumos: allow(panic-path) -- dangles\n";
        assert_eq!(findings(src), vec![(2, "lint-directive")]);
    }

    #[test]
    fn only_filter_restricts_rules() {
        let src = "fn f() { let m: HashMap<u8, u8> = x.unwrap(); }\n";
        let (fs, _) = scan_lexed("t.rs", &lex(src), &["hash-iter".to_string()]);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "hash-iter");
    }

    #[test]
    fn selective_recv_is_not_float_reduce() {
        // the coordinator's tagged recv + accumulate shape must stay clean
        let src = "fn ar(&mut self) { let inc = self.recv(prev, tag); \
                   for (d, s) in dst.iter_mut().zip(&inc) { *d += s; } }\n";
        assert!(findings(src).is_empty());
    }

    #[test]
    fn arrival_order_accumulation_fires() {
        let src = "fn f() { let mut t = 0.0; for v in res_rx { t += v; } }\n";
        assert_eq!(findings(src), vec![(1, "float-reduce")]);
        let src = "fn f() { let v = rx.recv().unwrap();\n s += v; }\n";
        let (fs, _) = scan_lexed("t.rs", &lex(src), &["float-reduce".to_string()]);
        assert_eq!(fs.len(), 1);
    }

    #[test]
    fn indexed_store_is_clean() {
        // run_indexed's own shape: receiver iterated, results stored by index
        let src = "fn f() { for (i, r) in res_rx { out[i] = Some(r); } }\n";
        assert!(findings(src).is_empty());
    }

    #[test]
    fn wallclock_sites_ignore_suppression_but_mask_tests() {
        let src = "// lumos: allow(wallclock) -- annotated harness\n\
                   fn f() { let t = Instant::now(); }\n\
                   #[test]\n\
                   fn t() { let s = Instant::now(); }\n";
        // the regular lint accepts the annotated site...
        let (fs, sup) = scan_lexed("t.rs", &lex(src), &["wallclock".to_string()]);
        assert!(fs.is_empty());
        assert_eq!(sup, 1);
        // ...the audit still reports it; the test item stays masked
        let sites = wallclock_sites("t.rs", &lex(src));
        assert_eq!(sites.len(), 1);
        assert_eq!((sites[0].line, sites[0].rule), (2, "wallclock"));
    }

    #[test]
    fn safety_comment_window() {
        let src = "// SAFETY: the artifact pins the layout\nunsafe { go() }\n";
        assert!(findings(src).is_empty());
        let src = "fn f() { unsafe { go() } }\n";
        assert_eq!(findings(src), vec![(1, "unsafe-safety")]);
    }
}
