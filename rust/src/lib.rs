//! # LUMOS
//!
//! A co-design framework for frontier MoE training over 3D integrated
//! optics scale-up fabrics — a full reproduction of *"Accelerating Frontier
//! MoE Training with 3D Integrated Optics"* (Lightmatter, HOTI 2025).
//!
//! The crate has three groups of subsystems (see DESIGN.md):
//!
//! - **Analytical stack** (the paper's contribution): [`hw`] technology
//!   models, [`topology`] fabrics, [`collectives`] Hockney schedules,
//!   [`model`] workload costing, [`parallel`] 4D parallelism mapping and
//!   [`perf`] the end-to-end time-to-train engine; [`sweep`] expresses
//!   every paper table/figure (and arbitrary pod-size × bandwidth ×
//!   granularity grids) as ordered grids of pure evaluation jobs executed
//!   by the [`sweep::engine`] worker pool (`lumos sweep --jobs N` —
//!   deterministic, byte-identical output for any worker count); and
//!   [`planner`], which searches the full legal (TP, PP, DP, microbatch,
//!   experts-per-rank) mapping space for any (workload, cluster) pair and
//!   returns a deterministically ranked plan (`lumos plan`).
//! - **Validation stack**: [`netsim`] flow-level fabric simulation — an
//!   incremental max-min engine that re-allocates only the affected
//!   component on each completion ([`netsim::Simulator`], with
//!   [`netsim::simulate_reference`] as the full-recompute oracle) plus a
//!   dependency-driven engine ([`netsim::dep`]) that admits flows the
//!   moment their predecessors finish; [`timeline`], the discrete-event
//!   training-step simulator that lowers a (workload, mapping, cluster)
//!   triple to a task DAG and cross-checks the analytical step time
//!   (`lumos validate`); [`resilience`], which converts the
//!   [`hw::reliability`] FIT composition into availability-adjusted
//!   effective time-to-train — seeded failure traces, fail-in-place
//!   degraded fabrics re-priced by both models, Young/Daly
//!   checkpoint-restart (`lumos resilience`); and the [`coordinator`]
//!   miniature distributed-training runtime with real rust collectives,
//!   plus [`trainer`] driving real AOT-compiled MoE training steps through
//!   [`runtime`] (PJRT).
//! - **Substrate**: [`util`] (JSON, RNG, property testing, CLI, stats,
//!   tables, bench harness — the vendored crate set is minimal: the only
//!   dependencies are the `vendor/` shims for `anyhow` and the `xla` API);
//!   [`analysis`], the determinism & concurrency lint (`lumos lint`)
//!   that makes the byte-identical `--jobs N` / seeded-reproducibility
//!   contract structural instead of conventional; [`obs`],
//!   deterministic simulated-time tracing (Perfetto-loadable Chrome trace
//!   JSON, `lumos trace`), the `"metrics"` counters of every `--json`
//!   output, and the quarantined opt-in wall-clock profiler; and
//!   [`chaos`], the seeded deterministic fault planner behind
//!   `lumos run --chaos` — logical-coordinate fault injection with
//!   supervised recovery, cross-checked against the [`resilience`] model.

pub mod analysis;
pub mod chaos;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod hw;
pub mod model;
pub mod netsim;
pub mod obs;
pub mod parallel;
pub mod perf;
pub mod planner;
pub mod resilience;
pub mod runtime;
pub mod sweep;
pub mod timeline;
pub mod topology;
pub mod trainer;
pub mod util;
