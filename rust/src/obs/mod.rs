//! Observability: deterministic tracing, counters, and opt-in wall-clock
//! profiling for the planner/simulator stack.
//!
//! Three layers with sharply different determinism contracts:
//!
//! - [`trace`] — spans/instants/counters keyed on **simulated** time and
//!   logical ids (rank, pipeline stage, link, fault lane), sunk to Chrome
//!   trace-event JSON that Perfetto and `chrome://tracing` load directly.
//!   Byte-identical for any `--jobs N`: nothing in a trace depends on the
//!   host, the clock, or scheduling.
//! - [`metrics`] — monotonic counters and min/max/sum histograms of work
//!   the tools actually did (DAG nodes lowered, simulator events, cache
//!   reuse). Aggregated in deterministic (worker-index) order and surfaced
//!   under the stable `"metrics"` key of every `--json` output.
//! - [`profile`] — the one place allowed to read the host clock: opt-in
//!   wall-clock stage timers feeding `BENCH_*.json`-style side files,
//!   never the deterministic artifacts. The `lumos lint` wallclock audit
//!   keeps every other module clock-free.
//!
//! The trace event schema and the determinism argument are documented in
//! `rust/DESIGN.md` §Observability; `tests/obs_prop.rs` pins byte-identity
//! across job counts, span-nesting well-formedness, and the agreement of
//! per-stage span sums with `lumos validate`'s phase breakdown.

pub mod metrics;
pub mod profile;
pub mod trace;

pub use metrics::{Hist, Metrics};
pub use profile::StageProfiler;
pub use trace::{
    check_chrome_trace, resilience_trace, step_trace, StepTrace, Trace, TraceCheck, TraceEvent,
    PID_FABRIC, PID_RESILIENCE, PID_STEP,
};
