//! Observability: deterministic tracing, counters, and opt-in wall-clock
//! profiling for the planner/simulator stack.
//!
//! Three layers with sharply different determinism contracts:
//!
//! - [`trace`] — spans/instants/counters keyed on **simulated** time and
//!   logical ids (rank, pipeline stage, link, fault lane), sunk to Chrome
//!   trace-event JSON that Perfetto and `chrome://tracing` load directly.
//!   Byte-identical for any `--jobs N`: nothing in a trace depends on the
//!   host, the clock, or scheduling.
//! - [`metrics`] — monotonic counters and min/max/sum histograms of work
//!   the tools actually did (DAG nodes lowered, simulator events, cache
//!   reuse). Aggregated in deterministic (worker-index) order and surfaced
//!   under the stable `"metrics"` key of every `--json` output.
//! - [`profile`] — opt-in wall-clock stage timers feeding
//!   `BENCH_*.json`-style side files, never the deterministic artifacts.
//! - [`record`] — the execution flight recorder: per-rank wall-clock
//!   deltas captured by ONE quarantined [`record::Stopwatch`] helper and
//!   normalized *at capture* to origin-relative time and logical ids
//!   (rank/stage/expert), so recorded traces are schema-valid and
//!   structurally identical across hosts (only durations vary). Together
//!   with [`profile`] these are the only modules allowed to read the
//!   host clock; the `lumos lint --audit-wallclock` gate keeps every
//!   other module clock-free.
//! - [`diff`] — aligns two trace artifacts (simulated vs executed, or
//!   any pair) by (track, span name, occurrence) and reports per-phase
//!   deltas plus unmatched spans (`lumos trace --diff`).
//!
//! The trace event schema and the determinism argument are documented in
//! `rust/DESIGN.md` §Observability and §Execution observability;
//! `tests/obs_prop.rs` pins byte-identity across job counts,
//! span-nesting well-formedness, and the agreement of per-stage span
//! sums with `lumos validate`'s phase breakdown; `tests/obs_record_prop.rs`
//! pins the recorder/diff invariants.

pub mod diff;
pub mod metrics;
pub mod profile;
pub mod record;
pub mod trace;

pub use diff::{diff_json, diff_parsed, diff_table, diff_traces, parse_chrome_trace, TraceDiff};
pub use metrics::{Hist, Metrics};
pub use profile::StageProfiler;
pub use record::{to_trace, Recorder, Recording, Stopwatch, PID_EXEC, TID_CHAOS_OFFSET};
pub use trace::{
    check_chrome_trace, resilience_trace, step_trace, StepTrace, Trace, TraceCheck, TraceEvent,
    PID_FABRIC, PID_RESILIENCE, PID_STEP,
};
