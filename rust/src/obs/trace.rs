//! Deterministic event tracing with a Chrome trace-event sink.
//!
//! A [`Trace`] is an ordered list of spans, instants and counter samples
//! keyed on **simulated** time and logical ids (stage, link, task,
//! phase) — never wall-clock — so the serialized artifact is
//! byte-identical across `--jobs N` and across machines. The sink is the
//! Chrome trace-event JSON format (a `{"traceEvents": [...]}` object of
//! `ph: "X" | "i" | "C" | "M"` records, timestamps in microseconds),
//! loadable in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`; since it is built on [`crate::util::json::Json`]
//! the same value doubles as the repo-native JSON artifact.
//!
//! High-level builders:
//!
//! - [`step_trace`] — the per-stage 1F1B task timeline of one simulated
//!   training step (tracks partition the step exactly; the stage-0 track
//!   *is* `lumos validate`'s phase breakdown), plus fabric counter
//!   samples taken at the dependency engine's settlement points.
//! - [`resilience_trace`] — failure/repair intervals and checkpoint
//!   instants from a seeded fault trace.
//!
//! [`check_chrome_trace`] is the minimal in-tree schema checker CI runs
//! against every emitted trace: event-level field/type checks, `B`/`E`
//! balance, and per-track span nesting well-formedness.

use std::collections::BTreeMap;

use crate::model::Workload;
use crate::netsim::{simulate_dag_observed, DepObserver};
use crate::parallel::Mapping;
use crate::perf::PerfKnobs;
use crate::resilience::{FaultEvent, FaultKind};
use crate::timeline::{
    lower_step_traced, stage_spans, spans_breakdown, Phase, PhaseBreakdown, TimelineError,
    TimelineReport,
};
use crate::topology::cluster::Cluster;
use crate::util::json::Json;

/// One trace record (see [`Trace`] for the model).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    pub cat: String,
    /// Chrome phase: `'X'` complete span, `'i'` instant, `'C'` counter.
    pub ph: char,
    /// Simulated start time, seconds.
    pub ts_s: f64,
    /// Span duration, seconds (`'X'` only).
    pub dur_s: f64,
    pub pid: usize,
    pub tid: usize,
    pub args: Vec<(String, f64)>,
}

/// An ordered, deterministic event timeline (module docs have the
/// contract; [`Trace::to_chrome_json`] is the sink).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    processes: Vec<(usize, String)>,
    threads: Vec<(usize, usize, String)>,
    events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    /// Name a process (a top-level track group).
    pub fn process(&mut self, pid: usize, name: &str) {
        self.processes.push((pid, name.to_string()));
    }

    /// Name a thread (one track inside a process).
    pub fn thread(&mut self, pid: usize, tid: usize, name: &str) {
        self.threads.push((pid, tid, name.to_string()));
    }

    /// A complete span (`ph: "X"`) over simulated `[start_s, end_s]`.
    pub fn span(&mut self, pid: usize, tid: usize, name: &str, cat: &str, start_s: f64, end_s: f64) {
        self.span_args(pid, tid, name, cat, start_s, end_s, &[]);
    }

    /// [`Trace::span`] with numeric args attached.
    #[allow(clippy::too_many_arguments)]
    pub fn span_args(
        &mut self,
        pid: usize,
        tid: usize,
        name: &str,
        cat: &str,
        start_s: f64,
        end_s: f64,
        args: &[(&str, f64)],
    ) {
        self.events.push(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: 'X',
            ts_s: start_s,
            dur_s: end_s - start_s,
            pid,
            tid,
            args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// A thread-scoped instant event (`ph: "i"`).
    pub fn instant(&mut self, pid: usize, tid: usize, name: &str, cat: &str, ts_s: f64) {
        self.events.push(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: 'i',
            ts_s,
            dur_s: 0.0,
            pid,
            tid,
            args: Vec::new(),
        });
    }

    /// A counter sample (`ph: "C"`): the named counter track takes value
    /// `value` at simulated `ts_s`.
    pub fn counter(&mut self, pid: usize, name: &str, ts_s: f64, value: f64) {
        self.events.push(TraceEvent {
            name: name.to_string(),
            cat: "counter".to_string(),
            ph: 'C',
            ts_s,
            dur_s: 0.0,
            pid,
            tid: 0,
            args: vec![("value".to_string(), value)],
        });
    }

    /// Number of recorded events (metadata excluded).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Serialize to the Chrome trace-event JSON object: metadata records
    /// first (process/thread names), then events in recording order,
    /// timestamps converted to microseconds.
    pub fn to_chrome_json(&self) -> Json {
        let mut evs: Vec<Json> = Vec::with_capacity(self.events.len() + 8);
        for (pid, name) in &self.processes {
            evs.push(Json::obj(vec![
                ("args", Json::obj(vec![("name", Json::str(name))])),
                ("name", Json::str("process_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(*pid as f64)),
                ("tid", Json::num(0.0)),
            ]));
        }
        for (pid, tid, name) in &self.threads {
            evs.push(Json::obj(vec![
                ("args", Json::obj(vec![("name", Json::str(name))])),
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(*pid as f64)),
                ("tid", Json::num(*tid as f64)),
            ]));
        }
        for e in &self.events {
            let mut fields: Vec<(&str, Json)> = vec![
                ("cat", Json::str(&e.cat)),
                ("name", Json::str(&e.name)),
                ("ph", Json::str(&e.ph.to_string())),
                ("pid", Json::num(e.pid as f64)),
                ("tid", Json::num(e.tid as f64)),
                ("ts", Json::num(e.ts_s * 1e6)),
            ];
            if e.ph == 'X' {
                fields.push(("dur", Json::num(e.dur_s * 1e6)));
            }
            if e.ph == 'i' {
                // thread scope
                fields.push(("s", Json::str("t")));
            }
            if !e.args.is_empty() {
                let args: Vec<(&str, Json)> =
                    e.args.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect();
                fields.push(("args", Json::obj(args)));
            }
            evs.push(Json::obj(fields));
        }
        Json::obj(vec![
            ("displayTimeUnit", Json::str("ms")),
            ("traceEvents", Json::Arr(evs)),
        ])
    }

    /// Write the Chrome trace-event JSON to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut text = self.to_chrome_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
    }
}

// ---- schema checker --------------------------------------------------------

/// What [`check_chrome_trace`] counted while validating.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceCheck {
    /// Non-metadata events.
    pub events: usize,
    pub spans: usize,
    pub counters: usize,
    pub instants: usize,
    /// Distinct `(pid, tid)` span tracks.
    pub tracks: usize,
}

fn field_num(e: &Json, key: &str, i: usize) -> Result<f64, String> {
    e.get(key)
        .as_f64()
        .filter(|v| v.is_finite())
        .ok_or_else(|| format!("event {i}: missing/non-finite numeric \"{key}\""))
}

/// Minimal in-tree Chrome trace-event schema checker (pure Rust): field
/// and type checks per event, `B`/`E` balance per track, and — for `X`
/// spans — per-track nesting well-formedness (spans may nest or be
/// disjoint, never partially overlap). Returns counts on success.
pub fn check_chrome_trace(doc: &Json) -> Result<TraceCheck, String> {
    let evs = doc
        .get("traceEvents")
        .as_arr()
        .ok_or_else(|| "top level must be an object with a \"traceEvents\" array".to_string())?;
    let mut check = TraceCheck::default();
    let mut spans: BTreeMap<(i64, i64), Vec<(f64, f64)>> = BTreeMap::new();
    let mut open: BTreeMap<(i64, i64), usize> = BTreeMap::new();
    for (i, e) in evs.iter().enumerate() {
        if e.as_obj().is_none() {
            return Err(format!("event {i}: not an object"));
        }
        let name = e
            .get("name")
            .as_str()
            .ok_or_else(|| format!("event {i}: missing string \"name\""))?;
        let ph = e
            .get("ph")
            .as_str()
            .ok_or_else(|| format!("event {i}: missing string \"ph\""))?;
        let pid = field_num(e, "pid", i)? as i64;
        if ph == "M" {
            let known = ["process_name", "thread_name", "process_sort_index", "thread_sort_index"];
            if !known.contains(&name) {
                return Err(format!("event {i}: unknown metadata record \"{name}\""));
            }
            if name.ends_with("_name") && e.get("args").get("name").as_str().is_none() {
                return Err(format!("event {i}: metadata \"{name}\" lacks args.name"));
            }
            continue;
        }
        let tid = field_num(e, "tid", i)? as i64;
        let ts = field_num(e, "ts", i)?;
        check.events += 1;
        match ph {
            "X" => {
                let dur = field_num(e, "dur", i)?;
                if dur < 0.0 {
                    return Err(format!("event {i}: negative dur {dur}"));
                }
                spans.entry((pid, tid)).or_default().push((ts, ts + dur));
                check.spans += 1;
            }
            "B" => {
                *open.entry((pid, tid)).or_insert(0) += 1;
                check.spans += 1;
            }
            "E" => {
                let depth = open.entry((pid, tid)).or_insert(0);
                if *depth == 0 {
                    return Err(format!("event {i}: E without a matching B on pid {pid} tid {tid}"));
                }
                *depth -= 1;
            }
            "i" => {
                check.instants += 1;
            }
            "C" => {
                let args = e
                    .get("args")
                    .as_obj()
                    .ok_or_else(|| format!("event {i}: counter lacks args object"))?;
                if args.is_empty() || args.values().any(|v| v.as_f64().is_none()) {
                    return Err(format!("event {i}: counter args must be non-empty numerics"));
                }
                check.counters += 1;
            }
            other => return Err(format!("event {i}: unsupported ph \"{other}\"")),
        }
    }
    if let Some(((pid, tid), depth)) = open.iter().find(|(_, &d)| d > 0) {
        return Err(format!("{depth} unmatched B event(s) on pid {pid} tid {tid}"));
    }
    // Per-track nesting: sorted by (start asc, end desc), a stack walk
    // must never see a span that starts inside the enclosing span but
    // ends outside it.
    let scale = spans
        .values()
        .flatten()
        .map(|&(_, e)| e.abs())
        .fold(1.0f64, f64::max);
    let tol = 1e-9 * scale;
    for ((pid, tid), track) in &mut spans {
        track.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut stack: Vec<f64> = Vec::new();
        for &(s, e) in track.iter() {
            if e < s - tol {
                return Err(format!("span ends before it starts on pid {pid} tid {tid}"));
            }
            while let Some(&top) = stack.last() {
                if s >= top - tol {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&top) = stack.last() {
                if e > top + tol {
                    return Err(format!(
                        "partial span overlap on pid {pid} tid {tid}: \
                         [{s}, {e}] vs enclosing end {top}"
                    ));
                }
            }
            stack.push(e);
        }
    }
    check.tracks = spans.len();
    Ok(check)
}

// ---- step trace ------------------------------------------------------------

/// Process id of the per-stage 1F1B task timeline.
pub const PID_STEP: usize = 1;
/// Process id of the fabric counter tracks.
pub const PID_FABRIC: usize = 2;
/// Process id of the resilience failure/repair/checkpoint tracks.
pub const PID_RESILIENCE: usize = 3;

/// One fabric allocation sample, taken at a settlement point.
struct FillSample {
    t: f64,
    active: usize,
    mean_util: f64,
}

/// [`DepObserver`] that records settlement-point allocation samples and
/// (optionally) per-flow admit/settle/finish instants.
struct FabricRecorder {
    want_flows: bool,
    samples: Vec<FillSample>,
    /// `(t, kind, node)` with kind `"admit" | "settle" | "finish"`.
    flow_events: Vec<(f64, &'static str, usize)>,
}

impl DepObserver for FabricRecorder {
    const UTILIZATION: bool = true;

    fn flow_admitted(&mut self, node: usize, now: f64) {
        if self.want_flows {
            self.flow_events.push((now, "admit", node));
        }
    }

    fn flow_settled(&mut self, node: usize, now: f64, _rate: f64) {
        if self.want_flows {
            self.flow_events.push((now, "settle", node));
        }
    }

    fn flow_finished(&mut self, node: usize, now: f64) {
        if self.want_flows {
            self.flow_events.push((now, "finish", node));
        }
    }

    fn refill(&mut self, now: f64, active_flows: usize, _touched_links: usize, mean_util: f64) {
        self.samples.push(FillSample { t: now, active: active_flows, mean_util });
    }
}

fn span_label(phase: Option<Phase>) -> (&'static str, &'static str) {
    match phase {
        None => ("bubble", "bubble"),
        Some(Phase::Compute) => ("compute", "compute"),
        Some(Phase::TpComm) => ("tp all-reduce", "tp"),
        Some(Phase::EpComm) => ("ep all-to-all", "ep"),
        Some(Phase::PpComm) => ("pp send", "pp"),
        Some(Phase::DpComm) => ("dp sync", "dp"),
    }
}

/// A traced simulated training step: the Chrome-exportable [`Trace`], the
/// step report (bit-identical to `timeline::simulate_step` on the same
/// point), and the per-stage phase breakdowns behind the tracks.
pub struct StepTrace {
    pub trace: Trace,
    pub report: TimelineReport,
    /// Per-stage breakdowns, index = pipeline stage; entry 0 equals
    /// `report.phases` (the `lumos validate` attribution).
    pub stages: Vec<PhaseBreakdown>,
}

/// Lower `(w, map)` on `cluster` with the full per-stage chain, simulate
/// it once on the dependency engine with a recording observer, and build
/// the step timeline: one span track per pipeline stage whose
/// compute/TP/EP/PP/DP/bubble spans partition `[0, step_time]` exactly,
/// plus fabric counter tracks (active flows, mean link utilization of the
/// re-filled component) sampled at settlement points. With `flow_events`,
/// per-flow admit/settle/finish instants are included as well.
pub fn step_trace(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
    flow_events: bool,
) -> Result<StepTrace, TimelineError> {
    let dag = lower_step_traced(w, cluster, map, knobs).map_err(TimelineError::TooLarge)?;
    let mut rec =
        FabricRecorder { want_flows: flow_events, samples: Vec::new(), flow_events: Vec::new() };
    let (result, dep) = simulate_dag_observed(&dag.net, &dag.nodes, &mut rec);

    let n_stages = dag.chain.iter().map(|t| t.stage + 1).max().unwrap_or(1);
    let mut trace = Trace::new();
    trace.process(PID_STEP, "step timeline (1F1B pipeline stages)");
    trace.process(PID_FABRIC, "fabric");
    let mut stages: Vec<PhaseBreakdown> = Vec::with_capacity(n_stages);
    for s in 0..n_stages {
        trace.thread(PID_STEP, s, &format!("stage {s}"));
        let spans = stage_spans(&dag.chain, s, &result.finish, result.makespan);
        for sp in &spans {
            let (name, cat) = span_label(sp.phase);
            trace.span(PID_STEP, s, name, cat, sp.start, sp.end);
        }
        stages.push(spans_breakdown(&spans));
    }
    trace.thread(PID_FABRIC, 0, "allocation");
    for s in &rec.samples {
        trace.counter(PID_FABRIC, "active flows", s.t, s.active as f64);
        trace.counter(PID_FABRIC, "mean link utilization", s.t, s.mean_util);
    }
    for &(t, kind, node) in &rec.flow_events {
        trace.instant(PID_FABRIC, 0, &format!("{kind} flow {node}"), kind, t);
    }

    let report = TimelineReport {
        step_time: result.makespan,
        time_to_train_s: result.makespan * w.steps_to_target(),
        phases: stages.first().cloned().unwrap_or_default(),
        nodes: dag.nodes.len(),
        events: result.events,
        dep,
    };
    Ok(StepTrace { trace, report, stages })
}

// ---- resilience trace ------------------------------------------------------

fn fault_label(kind: FaultKind) -> &'static str {
    match kind {
        FaultKind::ScaleUpLink => "scale-up link fault",
        FaultKind::ScaleOutLink => "scale-out link fault",
        FaultKind::GpuTray => "gpu tray fault",
    }
}

/// At most this many checkpoint instants are emitted (a short Young/Daly
/// interval over a long horizon would otherwise flood the track).
pub const MAX_CHECKPOINT_EVENTS: usize = 1_000;

/// Build the failure/repair/checkpoint timeline of one seeded fault
/// trace: a span per fault covering its repair window (overlapping
/// repairs of the same kind are laid out on extra lanes so every track
/// stays well-nested) and an instant per Young/Daly checkpoint, capped
/// at [`MAX_CHECKPOINT_EVENTS`].
pub fn resilience_trace(events: &[FaultEvent], ckpt_interval_s: f64, horizon_h: f64) -> Trace {
    let mut trace = Trace::new();
    trace.process(PID_RESILIENCE, "resilience (failure/repair/checkpoint)");
    // Greedy lane assignment per kind: deterministic first-fit over the
    // time-ordered events keeps overlapping repair windows on separate
    // tids. Lane tids: kind_index * LANES + lane; checkpoints after.
    const LANES: usize = 64;
    let kinds = [FaultKind::ScaleUpLink, FaultKind::ScaleOutLink, FaultKind::GpuTray];
    let mut lane_ends: Vec<Vec<f64>> = vec![Vec::new(); kinds.len()];
    let mut named: Vec<Vec<bool>> = vec![Vec::new(); kinds.len()];
    for ev in events {
        let k = match ev.kind {
            FaultKind::ScaleUpLink => 0,
            FaultKind::ScaleOutLink => 1,
            FaultKind::GpuTray => 2,
        };
        let start = ev.at_h * 3600.0;
        let end = (ev.at_h + ev.repair_h) * 3600.0;
        let lanes = &mut lane_ends[k];
        let lane = match lanes.iter().position(|&e| e <= start) {
            Some(i) => i,
            None => {
                lanes.push(0.0);
                lanes.len() - 1
            }
        };
        if lane >= LANES {
            // saturated: drop the event (64 concurrent repairs of one
            // kind is far beyond any sampled horizon)
            continue;
        }
        lanes[lane] = end;
        let tid = k * LANES + lane;
        if named[k].len() <= lane {
            named[k].resize(lane + 1, false);
        }
        if !named[k][lane] {
            let suffix = if lane == 0 { String::new() } else { format!(" (lane {lane})") };
            trace.thread(PID_RESILIENCE, tid, &format!("{}{suffix}", fault_label(ev.kind)));
            named[k][lane] = true;
        }
        trace.span_args(
            PID_RESILIENCE,
            tid,
            fault_label(ev.kind),
            "fault",
            start,
            end,
            &[("gpu", ev.gpu as f64), ("repair_h", ev.repair_h)],
        );
    }
    let ckpt_tid = kinds.len() * LANES;
    trace.thread(PID_RESILIENCE, ckpt_tid, "checkpoints (Young/Daly)");
    if ckpt_interval_s > 0.0 {
        let horizon_s = horizon_h * 3600.0;
        let mut t = ckpt_interval_s;
        let mut count = 0usize;
        while t <= horizon_s && count < MAX_CHECKPOINT_EVENTS {
            trace.instant(PID_RESILIENCE, ckpt_tid, "checkpoint", "checkpoint", t);
            t += ckpt_interval_s;
            count += 1;
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrome_sink_and_checker_roundtrip() {
        let mut tr = Trace::new();
        tr.process(1, "p");
        tr.thread(1, 0, "t0");
        tr.span(1, 0, "outer", "c", 0.0, 10.0);
        tr.span(1, 0, "inner", "c", 2.0, 5.0);
        tr.span(1, 0, "later", "c", 6.0, 9.0);
        tr.instant(1, 0, "mark", "c", 3.0);
        tr.counter(2, "flows", 1.0, 4.0);
        let doc = tr.to_chrome_json();
        let check = check_chrome_trace(&doc).unwrap();
        assert_eq!(check.spans, 3);
        assert_eq!(check.instants, 1);
        assert_eq!(check.counters, 1);
        assert_eq!(check.tracks, 1);
        // serialization is stable
        assert_eq!(doc.to_string_pretty(), tr.to_chrome_json().to_string_pretty());
        // and parses back
        let parsed = Json::parse(&doc.to_string_pretty()).unwrap();
        assert!(check_chrome_trace(&parsed).is_ok());
    }

    #[test]
    fn checker_rejects_malformed_traces() {
        // not an object / missing traceEvents
        assert!(check_chrome_trace(&Json::Arr(vec![])).is_err());
        // partial overlap
        let mut tr = Trace::new();
        tr.span(1, 0, "a", "c", 0.0, 5.0);
        tr.span(1, 0, "b", "c", 3.0, 8.0);
        let err = check_chrome_trace(&tr.to_chrome_json()).unwrap_err();
        assert!(err.contains("overlap"), "{err}");
        // negative duration
        let mut tr = Trace::new();
        tr.span(1, 0, "a", "c", 5.0, 3.0);
        assert!(check_chrome_trace(&tr.to_chrome_json()).is_err());
        // unmatched B
        let doc = Json::parse(
            r#"{"traceEvents": [{"name": "x", "ph": "B", "ts": 0, "pid": 1, "tid": 1}]}"#,
        )
        .unwrap();
        let err = check_chrome_trace(&doc).unwrap_err();
        assert!(err.contains("unmatched B"), "{err}");
        // B/E balance accepted
        let doc = Json::parse(
            r#"{"traceEvents": [
                {"name": "x", "ph": "B", "ts": 0, "pid": 1, "tid": 1},
                {"name": "x", "ph": "E", "ts": 4, "pid": 1, "tid": 1}
            ]}"#,
        )
        .unwrap();
        assert!(check_chrome_trace(&doc).is_ok());
        // counter without numeric args
        let doc = Json::parse(
            r#"{"traceEvents": [{"name": "c", "ph": "C", "ts": 0, "pid": 1, "tid": 0,
                                 "args": {"v": "high"}}]}"#,
        )
        .unwrap();
        assert!(check_chrome_trace(&doc).is_err());
    }

    #[test]
    fn resilience_trace_lanes_never_partially_overlap() {
        use crate::resilience::{sample_trace, FabricReliability, RepairModel};
        use crate::util::rng::Rng;
        let events = sample_trace(
            &FabricReliability::passage(),
            &RepairModel::default(),
            32_768,
            48.0,
            Rng::new(7),
        );
        assert!(!events.is_empty());
        let tr = resilience_trace(&events, 1800.0, 48.0);
        let check = check_chrome_trace(&tr.to_chrome_json()).unwrap();
        assert!(check.spans > 0 && check.instants > 0);
        // byte-identical on rebuild (pure function of the sampled trace)
        let again = resilience_trace(&events, 1800.0, 48.0);
        assert_eq!(
            tr.to_chrome_json().to_string_pretty(),
            again.to_chrome_json().to_string_pretty()
        );
    }
}
