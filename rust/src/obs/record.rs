//! Execution flight recorder: wall-clock capture quarantined behind a
//! normalize-at-capture boundary.
//!
//! This is the first module where *real* time enters the repo's
//! artifacts, so the boundary is explicit and lint-audited:
//!
//! - The only clock reads live in [`Stopwatch`], inside the
//!   `wallclock-capture-begin` / `wallclock-capture-end` marker comments
//!   below. `lumos lint --audit-wallclock` rejects a clock-read site in
//!   this file *outside* that region (see
//!   `analysis::wallclock_capture_regions`), and rejects one in any
//!   other module not on `analysis::WALLCLOCK_ALLOWED` at all.
//! - **Normalize at capture:** a [`Recorder`] never stores absolute
//!   timestamps. Every lap is folded into a logical cursor relative to
//!   the recording origin, and every span/instant/counter is keyed on
//!   logical ids (rank, stage, microbatch, expert) — so a recorded trace
//!   has the same shape on every host (same tracks, names, categories,
//!   event counts and ordering; only the float durations differ) and is
//!   schema-valid under [`crate::obs::check_chrome_trace`].
//! - **Partition by construction:** [`Recorder::cut`] closes the span
//!   `[cursor, cursor + lap]` and advances the cursor, so the spans of
//!   one rank's track tile `[0, end]` exactly — the same invariant the
//!   simulated step trace guarantees, which is what makes recorded and
//!   simulated traces diffable phase-by-phase (`obs::diff`).
//!
//! Per-rank [`Recording`]s are merged (in rank order) into one
//! [`Trace`] under [`PID_EXEC`] by [`to_trace`].

use crate::obs::trace::Trace;

/// Process id of executed per-rank tracks (the simulated step uses
/// [`crate::obs::trace::PID_STEP`]; 1–3 are taken).
pub const PID_EXEC: usize = 4;

/// Tid offset of the per-rank chaos tracks under [`PID_EXEC`]: rank
/// `r`'s fault/detect/repair/failover instants render on
/// `TID_CHAOS_OFFSET + r`, separate from its span track so the span
/// tiling invariant stays visible. Far above any real rank count.
pub const TID_CHAOS_OFFSET: usize = 1000;

// lumos: wallclock-capture-begin
//
// The ONLY clock reads allowed in this file. Everything below the
// matching `end` marker sees time exclusively as `f64` deltas already
// normalized to the recording origin.

/// Monotonic lap timer: the single normalize-at-capture helper. Reads
/// the host clock, hands out only origin-relative `f64` seconds.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    origin: std::time::Instant,
    last: std::time::Instant,
}

impl Stopwatch {
    /// Start the watch; the origin of all reported times.
    pub fn start() -> Stopwatch {
        // lumos: allow(wallclock) -- the flight recorder's quarantined capture helper
        let now = std::time::Instant::now();
        Stopwatch { origin: now, last: now }
    }

    /// Seconds since the previous `lap()` (or since `start`), and reset
    /// the lap marker. Non-negative by `Instant`'s monotonicity.
    pub fn lap(&mut self) -> f64 {
        // lumos: allow(wallclock) -- the flight recorder's quarantined capture helper
        let now = std::time::Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }

    /// Seconds since `start`, without resetting the lap marker.
    pub fn total(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }
}

// lumos: wallclock-capture-end

/// One recorded span, origin-relative seconds.
#[derive(Debug, Clone)]
pub struct RecSpan {
    pub name: String,
    pub cat: String,
    pub start_s: f64,
    pub end_s: f64,
    pub args: Vec<(String, f64)>,
}

/// One rank's finished flight recording: spans partition
/// `[0, end_s]`, instants and counter samples ride along.
#[derive(Debug, Clone)]
pub struct Recording {
    pub rank: usize,
    /// Logical end of the recording = sum of all lap deltas.
    pub end_s: f64,
    pub spans: Vec<RecSpan>,
    /// `(name, cat, ts)` thread-scoped instants.
    pub instants: Vec<(String, String, f64)>,
    /// `(name, ts, value)` counter samples.
    pub counters: Vec<(String, f64, f64)>,
}

/// Per-rank flight recorder (module docs have the capture contract).
///
/// Drivers call [`Recorder::cut`] after each phase of work; the elapsed
/// wall time since the previous cut becomes that phase's span. Time is
/// never attributed twice and never dropped: whatever ran between two
/// cuts belongs to the second cut's label.
#[derive(Debug)]
pub struct Recorder {
    rank: usize,
    watch: Stopwatch,
    cursor: f64,
    spans: Vec<RecSpan>,
    instants: Vec<(String, String, f64)>,
    counters: Vec<(String, f64, f64)>,
}

impl Recorder {
    /// Start recording rank `rank`; time zero is now.
    pub fn start(rank: usize) -> Recorder {
        Recorder {
            rank,
            watch: Stopwatch::start(),
            cursor: 0.0,
            spans: Vec::new(),
            instants: Vec::new(),
            counters: Vec::new(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Logical time of the recording cursor (sum of laps so far).
    pub fn now(&self) -> f64 {
        self.cursor
    }

    /// Close the span covering everything since the previous cut.
    pub fn cut(&mut self, name: &str, cat: &str) {
        self.cut_args(name, cat, &[]);
    }

    /// [`Recorder::cut`] with numeric args attached to the span.
    pub fn cut_args(&mut self, name: &str, cat: &str, args: &[(&str, f64)]) {
        let dt = self.watch.lap();
        let start = self.cursor;
        self.cursor = start + dt;
        self.spans.push(RecSpan {
            name: name.to_string(),
            cat: cat.to_string(),
            start_s: start,
            end_s: self.cursor,
            args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// Drop a zero-duration instant at the cursor (does not lap: the
    /// elapsed time stays attributed to the next cut).
    pub fn mark(&mut self, name: &str, cat: &str) {
        self.instants.push((name.to_string(), cat.to_string(), self.cursor));
    }

    /// Sample a counter track at the cursor.
    pub fn counter(&mut self, name: &str, value: f64) {
        self.counters.push((name.to_string(), self.cursor, value));
    }

    /// Finish: the recording ends at the current cursor. Any wall time
    /// after the last cut is deliberately not attributed.
    pub fn finish(self) -> Recording {
        Recording {
            rank: self.rank,
            end_s: self.cursor,
            spans: self.spans,
            instants: self.instants,
            counters: self.counters,
        }
    }
}

/// Merge per-rank recordings into one executed-step [`Trace`]: process
/// [`PID_EXEC`], one span track per rank (tid = rank), counter tracks
/// named by the recording. A rank with chaos instants (cat `"chaos"`)
/// additionally gets a `rank N chaos` instant track at
/// [`TID_CHAOS_OFFSET`]` + N`; chaos *spans* (stall, failover) stay on
/// the rank's span track so the tiling invariant is preserved.
/// Recordings are sorted by rank so the artifact layout is independent
/// of worker completion order.
pub fn to_trace(recordings: &[Recording]) -> Trace {
    let mut order: Vec<&Recording> = recordings.iter().collect();
    order.sort_by_key(|r| r.rank);
    let mut t = Trace::new();
    t.process(PID_EXEC, "exec");
    for rec in &order {
        t.thread(PID_EXEC, rec.rank, &format!("rank {}", rec.rank));
    }
    for rec in &order {
        if rec.instants.iter().any(|(_, cat, _)| cat == "chaos") {
            t.thread(PID_EXEC, TID_CHAOS_OFFSET + rec.rank, &format!("rank {} chaos", rec.rank));
        }
    }
    for rec in &order {
        for s in &rec.spans {
            let args: Vec<(&str, f64)> = s.args.iter().map(|(k, v)| (k.as_str(), *v)).collect();
            t.span_args(PID_EXEC, rec.rank, &s.name, &s.cat, s.start_s, s.end_s, &args);
        }
        for (name, cat, ts) in &rec.instants {
            let tid =
                if cat == "chaos" { TID_CHAOS_OFFSET + rec.rank } else { rec.rank };
            t.instant(PID_EXEC, tid, name, cat, *ts);
        }
        for (name, ts, value) in &rec.counters {
            t.counter(PID_EXEC, &format!("rank {} {}", rec.rank, name), *ts, *value);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::check_chrome_trace;

    #[test]
    fn laps_are_non_negative_and_sum_to_total() {
        let mut w = Stopwatch::start();
        let mut sum = 0.0;
        for _ in 0..100 {
            let dt = w.lap();
            assert!(dt >= 0.0);
            sum += dt;
        }
        assert!(w.total() >= sum);
    }

    #[test]
    fn cuts_partition_the_recording() {
        let mut r = Recorder::start(3);
        r.mark("step 0", "step");
        for i in 0..50 {
            let mut x = 1.0f64;
            for k in 0..100 {
                x += (k as f64).sqrt();
            }
            r.cut_args(&format!("phase {}", i % 5), "compute", &[("x", x)]);
            r.counter("work", i as f64);
        }
        let rec = r.finish();
        assert_eq!(rec.rank, 3);
        assert_eq!(rec.spans.len(), 50);
        // Exact contiguity: each span starts where the previous ended.
        let mut cursor = 0.0;
        for s in &rec.spans {
            assert_eq!(s.start_s, cursor);
            assert!(s.end_s >= s.start_s);
            cursor = s.end_s;
        }
        assert_eq!(cursor, rec.end_s);
    }

    #[test]
    fn merged_trace_passes_the_schema_checker() {
        let mut recs = Vec::new();
        for rank in (0..4).rev() {
            let mut r = Recorder::start(rank);
            r.mark("step 0", "step");
            r.cut("fwd", "compute");
            r.cut("a2a", "ep");
            r.counter("bytes sent", 128.0);
            r.cut("bwd", "compute");
            recs.push(r.finish());
        }
        let trace = to_trace(&recs);
        let doc = trace.to_chrome_json();
        let check = check_chrome_trace(&doc).expect("recorded trace is schema-valid");
        assert_eq!(check.spans, 12);
        assert_eq!(check.tracks, 4);
        assert_eq!(check.instants, 4);
        assert_eq!(check.counters, 4);
    }

    #[test]
    fn chaos_instants_land_on_their_own_track() {
        let mut r = Recorder::start(1);
        r.mark("step 0", "step");
        r.cut("fwd", "compute");
        r.mark("inject drop rank 1 -> 0", "chaos");
        r.cut("stall", "chaos");
        r.cut("bwd", "compute");
        let chaotic = r.finish();
        let mut q = Recorder::start(0);
        q.cut("fwd", "compute");
        let quiet = q.finish();

        let trace = to_trace(&[chaotic, quiet]);
        let doc = trace.to_chrome_json();
        let check = check_chrome_trace(&doc).expect("chaos trace is schema-valid");
        // span tracks: rank 0 and rank 1 (chaos instants carry no spans)
        assert_eq!(check.tracks, 2);
        assert_eq!(check.instants, 2);
        let text = doc.to_string_compact();
        assert!(text.contains("rank 1 chaos"), "chaos thread registered");
        assert!(!text.contains("rank 0 chaos"), "quiet rank gets no chaos track");
        // chaos spans (the stall) stay on the rank's span track
        assert_eq!(check.spans, 4);
    }
}
