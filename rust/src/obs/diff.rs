//! Trace diff: align two Chrome trace-event artifacts and report
//! per-phase deltas and unmatched spans.
//!
//! Both sides of a diff are artifacts this repo emits — the simulated
//! step timeline ([`crate::obs::trace::step_trace`]), the executed
//! flight recording ([`crate::obs::record::to_trace`]), or any prior
//! copy of either — so the alignment key is the contract those builders
//! share: **(track, span name, occurrence index)**, where a track is the
//! metadata-resolved `process/thread` name pair (logical ids: pipeline
//! stage, rank), and the occurrence index is the span's ordinal among
//! same-named spans on its track ordered by start time. Nothing aligns
//! on timestamps, so traces with wildly different time bases (simulated
//! seconds vs. host-miniature wall seconds) still pair span-for-span.
//!
//! Durations aggregate by span category into the repo's six step phases
//! (`compute` / `tp` / `ep` / `pp` / `dp` / `bubble`, anything else
//! under `other`), mirroring `timeline::PhaseBreakdown` — the per-phase
//! table is therefore directly comparable with `lumos validate` output.
//! Because absolute magnitudes differ across sides, the table leads with
//! each phase's **share of its own trace's total**; the delta column is
//! the share delta in percentage points.
//!
//! `diff(A, A)` is empty (zero deltas, no unmatched spans) and
//! `diff(A, B)` mirrors `diff(B, A)` up to sign/side swap — both pinned
//! in `tests/obs_record_prop.rs`.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// The canonical step phases, in report order (`other` collects any
/// category outside the six).
pub const PHASE_ORDER: [&str; 7] = ["compute", "tp", "ep", "pp", "dp", "bubble", "other"];

/// One span pulled out of a Chrome trace-event document.
#[derive(Debug, Clone)]
pub struct ParsedSpan {
    /// `process/thread` display names (falls back to `pid N/tid M`).
    pub track: String,
    pub name: String,
    pub cat: String,
    pub ts_s: f64,
    pub dur_s: f64,
}

/// The span content of one trace artifact (metadata resolved, counters
/// and instants dropped — the diff is about where time went).
#[derive(Debug, Clone, Default)]
pub struct ParsedTrace {
    pub spans: Vec<ParsedSpan>,
}

/// Extract the `ph: "X"` spans of a Chrome trace-event document,
/// resolving pid/tid to display names via the `M` metadata records.
pub fn parse_chrome_trace(doc: &Json) -> Result<ParsedTrace, String> {
    let evs = doc
        .get("traceEvents")
        .as_arr()
        .ok_or_else(|| "top level must be an object with a \"traceEvents\" array".to_string())?;
    let mut procs: BTreeMap<i64, String> = BTreeMap::new();
    let mut threads: BTreeMap<(i64, i64), String> = BTreeMap::new();
    for e in evs {
        if e.get("ph").as_str() != Some("M") {
            continue;
        }
        let pid = e.get("pid").as_f64().unwrap_or(0.0) as i64;
        let tid = e.get("tid").as_f64().unwrap_or(0.0) as i64;
        if let Some(name) = e.get("args").get("name").as_str() {
            match e.get("name").as_str() {
                Some("process_name") => {
                    procs.insert(pid, name.to_string());
                }
                Some("thread_name") => {
                    threads.insert((pid, tid), name.to_string());
                }
                _ => {}
            }
        }
    }
    let mut spans = Vec::new();
    for (i, e) in evs.iter().enumerate() {
        if e.get("ph").as_str() != Some("X") {
            continue;
        }
        let name = e
            .get("name")
            .as_str()
            .ok_or_else(|| format!("event {i}: span lacks a string \"name\""))?;
        let ts = e
            .get("ts")
            .as_f64()
            .ok_or_else(|| format!("event {i}: span lacks numeric \"ts\""))?;
        let dur = e
            .get("dur")
            .as_f64()
            .ok_or_else(|| format!("event {i}: span lacks numeric \"dur\""))?;
        let pid = e.get("pid").as_f64().unwrap_or(0.0) as i64;
        let tid = e.get("tid").as_f64().unwrap_or(0.0) as i64;
        let pname = procs.get(&pid).cloned().unwrap_or_else(|| format!("pid {pid}"));
        let tname = threads
            .get(&(pid, tid))
            .cloned()
            .unwrap_or_else(|| format!("tid {tid}"));
        spans.push(ParsedSpan {
            track: format!("{pname}/{tname}"),
            name: name.to_string(),
            cat: e.get("cat").as_str().unwrap_or("").to_string(),
            ts_s: ts / 1e6,
            dur_s: dur / 1e6,
        });
    }
    Ok(ParsedTrace { spans })
}

/// Per-phase durations on both sides, seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseDelta {
    pub a_s: f64,
    pub b_s: f64,
}

impl PhaseDelta {
    /// Share of this phase in `total` (0 if the trace is empty).
    fn share(secs: f64, total: f64) -> f64 {
        if total > 0.0 {
            secs / total
        } else {
            0.0
        }
    }
}

/// The aligned diff of two traces (module docs have the alignment key).
#[derive(Debug, Clone, Default)]
pub struct TraceDiff {
    /// Phase → durations, keyed by [`PHASE_ORDER`] entries.
    pub phases: BTreeMap<String, PhaseDelta>,
    /// Spans paired by (track, name, occurrence).
    pub matched: usize,
    /// `(track/name, count)` of spans only present in A, sorted.
    pub only_a: Vec<(String, usize)>,
    /// Likewise for B.
    pub only_b: Vec<(String, usize)>,
}

impl TraceDiff {
    /// Total span seconds on side A.
    pub fn total_a(&self) -> f64 {
        self.phases.values().map(|p| p.a_s).sum()
    }

    /// Total span seconds on side B.
    pub fn total_b(&self) -> f64 {
        self.phases.values().map(|p| p.b_s).sum()
    }

    /// True when nothing differs structurally and every phase delta is
    /// exactly zero — the `diff(A, A)` case.
    pub fn is_empty(&self) -> bool {
        self.only_a.is_empty()
            && self.only_b.is_empty()
            && self.phases.values().all(|p| p.a_s == p.b_s)
    }
}

fn canonical_phase(cat: &str) -> &'static str {
    PHASE_ORDER[..6].iter().find(|p| **p == cat).copied().unwrap_or("other")
}

fn unmatched(
    counts: &BTreeMap<(String, String), (usize, usize)>,
    side_a: bool,
) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for ((track, name), (na, nb)) in counts {
        let extra = if side_a { na.saturating_sub(*nb) } else { nb.saturating_sub(*na) };
        if extra > 0 {
            out.push((format!("{track}/{name}"), extra));
        }
    }
    out
}

/// Align `a` and `b` by (track, name, occurrence) and aggregate matched
/// span durations per phase; excess occurrences on either side are
/// reported unmatched (their durations still count toward their own
/// side's phase totals, so phase shares describe the whole trace).
pub fn diff_parsed(a: &ParsedTrace, b: &ParsedTrace) -> TraceDiff {
    let mut counts: BTreeMap<(String, String), (usize, usize)> = BTreeMap::new();
    for s in &a.spans {
        counts.entry((s.track.clone(), s.name.clone())).or_default().0 += 1;
    }
    for s in &b.spans {
        counts.entry((s.track.clone(), s.name.clone())).or_default().1 += 1;
    }
    let mut diff = TraceDiff::default();
    for p in PHASE_ORDER {
        diff.phases.insert(p.to_string(), PhaseDelta::default());
    }
    for s in &a.spans {
        if let Some(p) = diff.phases.get_mut(canonical_phase(&s.cat)) {
            p.a_s += s.dur_s;
        }
    }
    for s in &b.spans {
        if let Some(p) = diff.phases.get_mut(canonical_phase(&s.cat)) {
            p.b_s += s.dur_s;
        }
    }
    diff.matched = counts.values().map(|(na, nb)| na.min(nb)).sum();
    diff.only_a = unmatched(&counts, true);
    diff.only_b = unmatched(&counts, false);
    diff
}

/// [`diff_parsed`] over raw Chrome trace-event documents.
pub fn diff_traces(a: &Json, b: &Json) -> Result<TraceDiff, String> {
    Ok(diff_parsed(&parse_chrome_trace(a)?, &parse_chrome_trace(b)?))
}

/// Render the diff as a fixed-width table. Durations are each side's
/// absolute seconds; `share` columns are the phase's fraction of its own
/// trace total, and `Δshare` is their difference in percentage points —
/// the cross-time-base comparison the module docs motivate.
pub fn diff_table(d: &TraceDiff, label_a: &str, label_b: &str) -> String {
    let (ta, tb) = (d.total_a(), d.total_b());
    let mut out = String::new();
    out.push_str(&format!(
        "{:<8}  {:>12}  {:>7}  {:>12}  {:>7}  {:>8}\n",
        "phase",
        format!("{label_a} (s)"),
        "share",
        format!("{label_b} (s)"),
        "share",
        "Δshare"
    ));
    for key in PHASE_ORDER {
        let p = d.phases.get(key).copied().unwrap_or_default();
        if key == "other" && p.a_s == 0.0 && p.b_s == 0.0 {
            continue;
        }
        let sa = PhaseDelta::share(p.a_s, ta);
        let sb = PhaseDelta::share(p.b_s, tb);
        out.push_str(&format!(
            "{:<8}  {:>12.6}  {:>6.1}%  {:>12.6}  {:>6.1}%  {:>+7.1}pp\n",
            key,
            p.a_s,
            100.0 * sa,
            p.b_s,
            100.0 * sb,
            100.0 * (sb - sa)
        ));
    }
    out.push_str(&format!(
        "{:<8}  {:>12.6}  {:>6.1}%  {:>12.6}  {:>6.1}%\n",
        "total", ta, 100.0, tb, 100.0
    ));
    out.push_str(&format!("matched spans: {}\n", d.matched));
    for (what, list) in [(label_a, &d.only_a), (label_b, &d.only_b)] {
        if !list.is_empty() {
            let items: Vec<String> =
                list.iter().map(|(k, n)| format!("{k} ×{n}")).collect();
            out.push_str(&format!("only in {}: {}\n", what, items.join(", ")));
        }
    }
    out
}

/// JSON artifact form of the diff (same content as [`diff_table`]).
pub fn diff_json(d: &TraceDiff, label_a: &str, label_b: &str) -> Json {
    let (ta, tb) = (d.total_a(), d.total_b());
    let mut phases: Vec<(&str, Json)> = Vec::new();
    for key in PHASE_ORDER {
        let p = d.phases.get(key).copied().unwrap_or_default();
        phases.push((
            key,
            Json::obj(vec![
                ("a_s", Json::num(p.a_s)),
                ("b_s", Json::num(p.b_s)),
                ("a_share", Json::num(PhaseDelta::share(p.a_s, ta))),
                ("b_share", Json::num(PhaseDelta::share(p.b_s, tb))),
            ]),
        ));
    }
    let side = |list: &[(String, usize)]| {
        Json::Arr(
            list.iter()
                .map(|(k, n)| {
                    Json::obj(vec![("span", Json::str(k)), ("count", Json::num(*n as f64))])
                })
                .collect(),
        )
    };
    Json::obj(vec![
        ("a", Json::str(label_a)),
        ("b", Json::str(label_b)),
        ("total_a_s", Json::num(ta)),
        ("total_b_s", Json::num(tb)),
        ("phases", Json::obj(phases)),
        ("matched_spans", Json::num(d.matched as f64)),
        ("only_a", side(&d.only_a)),
        ("only_b", side(&d.only_b)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::record::{to_trace, Recorder};

    fn sample(ranks: usize) -> Json {
        let mut recs = Vec::new();
        for rank in 0..ranks {
            let mut r = Recorder::start(rank);
            r.cut("fwd 0", "compute");
            r.cut("dispatch a2a 0", "ep");
            r.cut("bwd 0", "compute");
            r.cut("grad all-reduce", "dp");
            recs.push(r.finish());
        }
        to_trace(&recs).to_chrome_json()
    }

    #[test]
    fn self_diff_is_empty() {
        let doc = sample(2);
        let d = diff_traces(&doc, &doc).expect("parse");
        assert!(d.is_empty());
        assert_eq!(d.matched, 8);
        for p in d.phases.values() {
            assert_eq!(p.a_s, p.b_s);
        }
    }

    #[test]
    fn diff_is_symmetric_up_to_side_swap() {
        let da = diff_traces(&sample(2), &sample(3)).expect("parse");
        let db = diff_traces(&sample(3), &sample(2)).expect("parse");
        assert_eq!(da.matched, db.matched);
        assert_eq!(da.only_a, db.only_b);
        assert_eq!(da.only_b, db.only_a);
        for key in PHASE_ORDER {
            let pa = da.phases[key];
            let pb = db.phases[key];
            assert_eq!(pa.a_s, pb.b_s);
            assert_eq!(pa.b_s, pb.a_s);
        }
    }

    #[test]
    fn unmatched_spans_are_reported_per_track() {
        let da = diff_traces(&sample(2), &sample(3)).expect("parse");
        assert!(da.only_a.is_empty());
        assert_eq!(da.only_b.len(), 4);
        assert!(da.only_b.iter().all(|(k, n)| k.starts_with("exec/rank 2/") && *n == 1));
    }

    #[test]
    fn table_and_json_render() {
        let d = diff_traces(&sample(2), &sample(3)).expect("parse");
        let table = diff_table(&d, "sim", "exec");
        assert!(table.contains("compute"));
        assert!(table.contains("matched spans: 8"));
        assert!(table.contains("only in exec"));
        let j = diff_json(&d, "sim", "exec");
        assert_eq!(j.get("matched_spans").as_f64(), Some(8.0));
        assert!(j.get("phases").get("ep").get("a_s").as_f64().is_some());
    }
}
