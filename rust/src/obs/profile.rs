//! Opt-in wall-clock stage timers — the *only* place outside the bench
//! and runtime harnesses allowed to read the host clock.
//!
//! Everything else in `obs` is keyed on simulated time so traces and
//! metrics stay byte-identical across `--jobs N` and across machines.
//! Self-profiling (how long did lowering vs. simulation vs. emission
//! take *on this host*) is inherently wall-clock, so it is quarantined
//! here behind explicit opt-in flags (`lumos trace --profile <path>`),
//! written to `BENCH_*.json`-style side files, and never mixed into
//! deterministic stdout/trace artifacts. The `lumos lint` wallclock
//! audit (`--audit-wallclock`) enforces the quarantine: clock reads
//! outside the allowlisted modules fail CI even when annotated.

use std::time::Instant;

use crate::util::json::Json;

/// Wall-clock stage timer: mark the end of each pipeline stage and get a
/// named duration series, in stage order.
#[derive(Debug)]
pub struct StageProfiler {
    last: Instant,
    stages: Vec<(String, f64)>,
}

impl StageProfiler {
    /// Start the clock.
    pub fn start() -> StageProfiler {
        // lumos: allow(wallclock) -- opt-in self-profiling harness; output is quarantined to BENCH side files
        let now = Instant::now();
        StageProfiler { last: now, stages: Vec::new() }
    }

    /// End the current stage, recording the wall time since the previous
    /// mark (or since [`StageProfiler::start`]) under `name`.
    pub fn stage(&mut self, name: &str) {
        // lumos: allow(wallclock) -- opt-in self-profiling harness; output is quarantined to BENCH side files
        let now = Instant::now();
        let secs = now.duration_since(self.last).as_secs_f64();
        self.stages.push((name.to_string(), secs));
        self.last = now;
    }

    /// Stage names and durations, in stage order.
    pub fn stages(&self) -> &[(String, f64)] {
        &self.stages
    }

    /// `BENCH_*.json`-style artifact: `{"series": [{"name", "secs"}],
    /// "total_s": ...}` where `total_s` sums the recorded stages.
    pub fn to_json(&self) -> Json {
        let series: Vec<Json> = self
            .stages
            .iter()
            .map(|(name, secs)| {
                Json::obj(vec![("name", Json::str(name)), ("secs", Json::num(*secs))])
            })
            .collect();
        let total: f64 = self.stages.iter().map(|(_, s)| s).sum();
        Json::obj(vec![("series", Json::Arr(series)), ("total_s", Json::num(total))])
    }

    /// Write the artifact to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stages_accumulate_in_order() {
        let mut p = StageProfiler::start();
        p.stage("lower");
        p.stage("simulate");
        let names: Vec<&str> = p.stages().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["lower", "simulate"]);
        assert!(p.stages().iter().all(|&(_, s)| s >= 0.0));
        let j = p.to_json();
        assert_eq!(j.get("series").as_arr().map(|a| a.len()), Some(2));
        assert!(j.get("total_s").as_f64().is_some());
    }
}
