//! Deterministic monotonic counters and histograms.
//!
//! A [`Metrics`] set is a sorted map of named counters (`u64`, monotonic
//! by construction: [`Metrics::inc`] only adds) and histograms
//! (count/sum/min/max summaries fed by [`Metrics::observe`]). Everything
//! about it is deterministic:
//!
//! - storage is `BTreeMap`, so serialization order is key-sorted, never
//!   insertion- or hash-ordered;
//! - merging ([`Metrics::merge`]) is performed by the *caller* in item
//!   index order — the same contract `sweep::engine::run_indexed` gives
//!   its results — so `--jobs N` cannot reorder float accumulation;
//! - values are derived from simulated quantities or item counts, never
//!   from wall-clock time (that lives in [`crate::obs::profile`]).
//!
//! Surfaced under the stable `"metrics"` key of every `--json` artifact
//! (`lumos plan|validate|resilience --json`).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Count/sum/min/max summary of a series of observations.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Hist {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Hist {
    fn observe(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            if v < self.min {
                self.min = v;
            }
            if v > self.max {
                self.max = v;
            }
        }
        self.count += 1;
        self.sum += v;
    }

    fn merge(&mut self, other: &Hist) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = *other;
            return;
        }
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// A named set of monotonic counters and histograms (see module docs for
/// the determinism contract).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    hists: BTreeMap<String, Hist>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `by` to counter `name` (creating it at zero). Counters only
    /// ever increase — monotonicity is structural.
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record one observation into histogram `name`.
    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists.entry(name.to_string()).or_default().observe(v);
    }

    /// Current value of counter `name` (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Histogram summary for `name`, if any observation was recorded.
    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    /// Fold `other` into `self`. Callers aggregating per-item metric
    /// deltas must call this in item index order (the `run_indexed`
    /// result order) so float sums are order-stable across `--jobs N`.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    /// True when no counter or histogram was ever touched.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.hists.is_empty()
    }

    /// The `"metrics"` JSON object: counters as numbers, histograms as
    /// `{count, sum, min, max}` objects; keys sorted.
    pub fn to_json(&self) -> Json {
        let mut obj: BTreeMap<String, Json> = BTreeMap::new();
        for (k, v) in &self.counters {
            obj.insert(k.clone(), Json::num(*v as f64));
        }
        for (k, h) in &self.hists {
            obj.insert(
                k.clone(),
                Json::obj(vec![
                    ("count", Json::num(h.count as f64)),
                    ("sum", Json::num(h.sum)),
                    ("min", Json::num(h.min)),
                    ("max", Json::num(h.max)),
                ]),
            );
        }
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotonic_and_merge_adds() {
        let mut a = Metrics::new();
        a.inc("x", 2);
        a.inc("x", 3);
        assert_eq!(a.counter("x"), 5);
        let mut b = Metrics::new();
        b.inc("x", 1);
        b.inc("y", 7);
        a.merge(&b);
        assert_eq!(a.counter("x"), 6);
        assert_eq!(a.counter("y"), 7);
        assert_eq!(a.counter("missing"), 0);
    }

    #[test]
    fn histograms_summarize_and_merge() {
        let mut m = Metrics::new();
        for v in [3.0, 1.0, 2.0] {
            m.observe("sz", v);
        }
        let h = m.hist("sz").unwrap();
        assert_eq!((h.count, h.sum, h.min, h.max), (3, 6.0, 1.0, 3.0));
        assert_eq!(h.mean(), 2.0);
        let mut n = Metrics::new();
        n.observe("sz", 10.0);
        m.merge(&n);
        let h = m.hist("sz").unwrap();
        assert_eq!((h.count, h.max), (4, 10.0));
    }

    #[test]
    fn json_is_key_sorted_and_stable() {
        let mut m = Metrics::new();
        m.inc("zeta", 1);
        m.inc("alpha", 2);
        m.observe("mid", 4.5);
        let s = m.to_json().to_string_compact();
        let a = s.find("\"alpha\"").unwrap();
        let mid = s.find("\"mid\"").unwrap();
        let z = s.find("\"zeta\"").unwrap();
        assert!(a < mid && mid < z, "{s}");
        assert_eq!(s, m.clone().to_json().to_string_compact());
    }
}
