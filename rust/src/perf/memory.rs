//! Memory-capacity feasibility model (supports §V–VI).
//!
//! The analytical timing model assumes each configuration actually *fits*:
//! parameters + optimizer state sharded over TP×PP×EP, plus 1F1B's bounded
//! activation working set, must fit the 16-stack HBM4 capacity of the 2028
//! GPU. This module checks that, and exposes the per-GPU breakdown the
//! `lumos model` CLI prints.

use crate::model::Workload;
use crate::parallel::Mapping;

/// HBM capacity of the paper's 2028 GPU: 16 stacks × 24 GB HBM4 (8-Hi).
pub const HBM_BYTES_PER_GPU: f64 = 16.0 * 24.0 * 1e9;

/// Per-GPU memory breakdown, bytes.
#[derive(Debug, Clone)]
pub struct MemoryBreakdown {
    /// Attention/router/embedding params + grads + optimizer state.
    pub shared_state: f64,
    /// This GPU's expert shard's params + grads + optimizer state.
    pub expert_state: f64,
    /// 1F1B activation working set (≤ pp microbatches in flight).
    pub activations: f64,
    /// Dispatch/combine buffers for the routed tokens (k× expansion).
    pub routing_buffers: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.shared_state + self.expert_state + self.activations + self.routing_buffers
    }

    pub fn fits(&self) -> bool {
        self.total() <= HBM_BYTES_PER_GPU
    }

    pub fn utilization(&self) -> f64 {
        self.total() / HBM_BYTES_PER_GPU
    }
}

/// Compute the per-GPU breakdown for a workload/mapping (the mapping's own
/// `microbatch_seqs` sets the activation working-set grain).
pub fn memory_breakdown(w: &Workload, map: &Mapping) -> MemoryBreakdown {
    let par = map.par;
    let microbatch_seqs = map.microbatch_seqs;
    let layers_per_stage = w.n_layers as f64 / par.pp as f64;
    let state_bpp = w.state_bytes_per_param();

    let shared_params = (w.attn_params_per_layer() + w.router_params_per_layer())
        * layers_per_stage
        / par.tp as f64
        + w.embedding_params() / (par.tp * par.pp) as f64;

    // Each GPU holds experts_per_dp_rank experts per layer, each sharded
    // over its expert-TP subgroup — i.e. E/(ep_dp_ranks·tp) of the layer's
    // expert parameters.
    let expert_params = w.expert_params_per_layer() * layers_per_stage
        / (map.ep_dp_ranks() * par.tp) as f64;

    // 1F1B keeps at most min(pp, n_micro) microbatches of activations
    // alive per stage (coordinator::pipeline asserts the pp bound; with
    // fewer microbatches than stages only n_micro are ever in flight —
    // the planner searches that regime, so the bound must be tight).
    let mb_tokens = (microbatch_seqs * w.seq_len) as f64;
    let n_micro = map.n_micro(w);
    let act_per_micro =
        mb_tokens * w.activation_bytes_per_token_layer() * layers_per_stage / par.tp as f64;
    let activations = act_per_micro * par.pp.min(n_micro) as f64;

    // GShard dense dispatch: E × capacity × d_model per MoE layer, with
    // capacity ≈ tokens·k/E (unit capacity factor), live for one layer at
    // a time (fwd) plus its saved input for bwd.
    let routing = 2.0
        * mb_tokens
        * w.moe.active_per_token as f64
        * w.token_bytes()
        / par.tp as f64;

    MemoryBreakdown {
        shared_state: shared_params * state_bpp,
        expert_state: expert_params * state_bpp,
        activations,
        routing_buffers: routing,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MoeConfig;
    use crate::parallel::{Mapping, Parallelism};

    fn mapping(cfg: usize) -> (Workload, Mapping) {
        let w = Workload::paper_gpt_4p7t(cfg);
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(cfg));
        (w, m)
    }

    #[test]
    fn paper_configs_fit_hbm() {
        for cfg in 1..=4 {
            let (w, m) = mapping(cfg);
            let mem = memory_breakdown(&w, &m);
            assert!(
                mem.fits(),
                "config {cfg} needs {:.0} GB of {:.0} GB",
                mem.total() / 1e9,
                HBM_BYTES_PER_GPU / 1e9
            );
            // but not absurdly empty either — a 4.7T model is heavy
            assert!(mem.utilization() > 0.05, "config {cfg}: {}", mem.utilization());
        }
    }

    #[test]
    fn expert_state_invariant_across_configs() {
        // Total expert params are constant (E·d_ff/m invariant) and the EP
        // sharding denominator (ep_dp_ranks·tp = 512) is too.
        let (w1, m1) = mapping(1);
        let (w4, m4) = mapping(4);
        let a = memory_breakdown(&w1, &m1).expert_state;
        let b = memory_breakdown(&w4, &m4).expert_state;
        assert!((a - b).abs() / a < 1e-9);
    }

    #[test]
    fn routing_buffers_grow_with_k() {
        let (w1, m1) = mapping(1);
        let (w4, m4) = mapping(4);
        let a = memory_breakdown(&w1, &m1).routing_buffers;
        let b = memory_breakdown(&w4, &m4).routing_buffers;
        assert!((b / a - 8.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_microbatch_costs_activation_memory() {
        let (w, m) = mapping(2);
        // mb 1: 16 microbatches, min(pp 8, 16) = 8 in flight.
        let a = memory_breakdown(&w, &m);
        // mb 4: 4x the tokens per micro but only min(pp 8, 4) = 4 in
        // flight — net 2x the activation working set.
        let b = memory_breakdown(&w, &m.clone().with_microbatch(4));
        assert!((b.activations / a.activations - 2.0).abs() < 1e-9);
        assert_eq!(a.shared_state, b.shared_state);
    }

    #[test]
    fn in_flight_microbatches_capped_by_their_count() {
        // One giant microbatch (mb = all 16 seqs/rank): 1F1B has exactly
        // one microbatch in flight, not pp of them.
        let (w, m) = mapping(2);
        let one = memory_breakdown(&w, &m.clone().with_microbatch(16));
        let base = memory_breakdown(&w, &m);
        // 16x tokens/micro x 1 in flight vs 1x tokens x 8 in flight = 2x.
        assert!((one.activations / base.activations - 2.0).abs() < 1e-9);
    }

    #[test]
    fn without_expert_sharding_it_would_not_fit() {
        // Sanity: the full 4.7T model state (12 B/param) over only TP×PP
        // (no expert sharding) needs ~441 GB/GPU — EP is load-bearing.
        let (w, _) = mapping(1);
        let naive = w.total_params() * w.state_bytes_per_param() / (16.0 * 8.0);
        assert!(naive > 0.5 * HBM_BYTES_PER_GPU * 0.5, "{naive}");
        assert!(naive / 1e9 > 400.0);
    }
}
