//! Analytical time-to-train engine (paper §V.A, results §VI).
//!
//! Decomposes one training step into compute, tensor-parallel collectives,
//! expert all-to-all, pipeline transfers and data-parallel gradient sync,
//! each costed with the Hockney models on the cluster's two network
//! domains, then rolls up a 1F1B pipeline into step time and time-to-train.
//!
//! Calibration knobs (documented in EXPERIMENTS.md §Calibration):
//! - `mfu`: achieved fraction of peak BF16 FLOPs (0.40 default — frontier
//!   MoE training MFU).
//! - `comm_dtype_bytes`: activation/gradient bytes on the wire for
//!   collectives (4.0: fp32 accumulation for TP all-reduce, Megatron
//!   default).
//! - overlap fractions: how much of each communication class hides under
//!   compute. EP dispatch blocks expert compute (0 overlap by default);
//!   DP gradient sync overlaps the backward pass (0.9).
//!
//! The microbatch grain is *not* a knob: it lives on
//! [`Mapping::microbatch_seqs`] because the planner searches it per point
//! (it trades activation memory against pipeline bubble).
//!
//! [`check_feasible`] / [`evaluate_feasible`] expose the model's
//! preconditions (divisibility + HBM capacity) as a checkable result
//! instead of a panic — the [`crate::planner`] prunes on it.

pub mod memory;

use crate::collectives as coll;
use crate::model::Workload;
use crate::parallel::{Mapping, MappingError};
use crate::perf::memory::{memory_breakdown, MemoryBreakdown, HBM_BYTES_PER_GPU};
use crate::topology::cluster::{Cluster, Domain};

/// Calibration knobs.
#[derive(Debug, Clone)]
pub struct PerfKnobs {
    pub mfu: f64,
    pub comm_dtype_bytes: f64,
    pub dp_overlap: f64,
    pub ep_overlap: f64,
}

impl Default for PerfKnobs {
    fn default() -> Self {
        PerfKnobs {
            mfu: 0.40,
            comm_dtype_bytes: 4.0,
            dp_overlap: 0.9,
            // The combine-direction all-to-all pipelines with expert
            // compute (§VI: overlap keeps compute from idling); dispatch
            // stays on the critical path.
            ep_overlap: 0.25,
        }
    }
}

/// Why a (workload, mapping) point cannot be evaluated.
#[derive(Debug, Clone, PartialEq)]
pub enum Infeasible {
    /// The mapping's own divisibility predicate failed.
    Mapping(MappingError),
    /// `global_batch` does not split evenly over the DP ranks.
    BatchIndivisible { global_batch: usize, dp: usize },
    /// The per-rank sequence count is not a whole number of microbatches.
    MicrobatchIndivisible { seqs_per_rank: usize, microbatch_seqs: usize },
    /// Parameter/optimizer state + activations exceed HBM capacity.
    OverCapacity { needed_bytes: f64, capacity_bytes: f64 },
}

impl std::fmt::Display for Infeasible {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Infeasible::Mapping(e) => write!(f, "{e}"),
            Infeasible::BatchIndivisible { global_batch, dp } => {
                write!(f, "global batch {global_batch} does not divide over dp {dp}")
            }
            Infeasible::MicrobatchIndivisible { seqs_per_rank, microbatch_seqs } => write!(
                f,
                "{seqs_per_rank} seqs/rank is not a whole number of {microbatch_seqs}-seq \
                 microbatches"
            ),
            Infeasible::OverCapacity { needed_bytes, capacity_bytes } => write!(
                f,
                "needs {:.0} GB of {:.0} GB HBM",
                needed_bytes / 1e9,
                capacity_bytes / 1e9
            ),
        }
    }
}

impl std::error::Error for Infeasible {}

/// Check everything [`evaluate`] asserts, plus HBM capacity, returning the
/// memory breakdown on success. Deliberately does *not* require
/// `mapping.n_gpus() == cluster.n_gpus` — the §VI precedent evaluates the
/// 32,768-GPU paper mapping on the 32,256-GPU electrical cluster (a 1.5%
/// size delta); exact partitioning is [`crate::parallel::enumerate_candidates`]'s
/// job.
pub fn check_feasible(w: &Workload, map: &Mapping) -> Result<MemoryBreakdown, Infeasible> {
    Mapping::try_with_microbatch(map.par, map.moe, map.microbatch_seqs)
        .map_err(Infeasible::Mapping)?;
    if w.global_batch % map.par.dp != 0 {
        return Err(Infeasible::BatchIndivisible { global_batch: w.global_batch, dp: map.par.dp });
    }
    let seqs_per_rank = w.global_batch / map.par.dp;
    if seqs_per_rank % map.microbatch_seqs != 0 {
        return Err(Infeasible::MicrobatchIndivisible {
            seqs_per_rank,
            microbatch_seqs: map.microbatch_seqs,
        });
    }
    let mem = memory_breakdown(w, map);
    if !mem.fits() {
        return Err(Infeasible::OverCapacity {
            needed_bytes: mem.total(),
            capacity_bytes: HBM_BYTES_PER_GPU,
        });
    }
    Ok(mem)
}

/// Feasibility-aware evaluation: `Err` instead of a panic on an illegal
/// point, plus the memory breakdown that proved it fits.
pub fn evaluate_feasible(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
) -> Result<(PerfReport, MemoryBreakdown), Infeasible> {
    let mem = check_feasible(w, map)?;
    Ok((evaluate(w, cluster, map, knobs), mem))
}

/// Where the EP all-to-all ran and how it was costed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpPlacement {
    /// Whole EP group inside one pod.
    ScaleUp,
    /// EP group spans pods; cross-pod fraction rides Ethernet.
    Hierarchical,
}

/// Per-step cost breakdown (seconds, per GPU critical path).
#[derive(Debug, Clone)]
pub struct StepBreakdown {
    /// Matmul time per microbatch (fwd+bwd), already TP-sharded.
    pub compute_per_micro: f64,
    /// TP collectives per microbatch (attention + expert-TP all-reduces).
    pub tp_comm_per_micro: f64,
    /// EP all-to-all per microbatch (dispatch+combine, fwd+bwd).
    pub ep_a2a_per_micro: f64,
    /// Pipeline p2p per microbatch.
    pub pp_comm_per_micro: f64,
    /// DP gradient all-reduce per step (before overlap discount).
    pub dp_comm_per_step: f64,
    pub n_micro: usize,
    pub pp: usize,
    pub ep_placement: EpPlacement,
}

impl StepBreakdown {
    pub fn micro_time(&self) -> f64 {
        self.compute_per_micro + self.tp_comm_per_micro + self.ep_a2a_per_micro
            + self.pp_comm_per_micro
    }

    /// 1F1B: (n_micro + pp - 1) microbatch slots on the critical stage.
    pub fn pipeline_slots(&self) -> f64 {
        (self.n_micro + self.pp - 1) as f64
    }

    pub fn bubble_fraction(&self) -> f64 {
        (self.pp - 1) as f64 / self.pipeline_slots()
    }
}

/// Full evaluation result.
#[derive(Debug, Clone)]
pub struct PerfReport {
    pub cluster: String,
    pub config_name: String,
    pub breakdown: StepBreakdown,
    pub step_time: f64,
    pub time_to_train_s: f64,
    /// Fraction of the step spent in non-overlapped communication.
    pub comm_fraction: f64,
    /// Model FLOPs utilization implied by the step time.
    pub achieved_mfu: f64,
}

/// All-to-all startup term: peers are contacted from parallel NIC queues,
/// so latency composes logarithmically rather than serially.
pub(crate) fn a2a_alpha(latency_s: f64, n: usize) -> f64 {
    latency_s * (n.max(2) as f64).log2().ceil()
}

/// The per-step work and wire volumes one (workload, cluster, mapping)
/// point generates — the quantities both [`evaluate`] and the
/// [`crate::timeline`] lowering price, factored out so the analytical
/// model and the discrete-event simulator cannot drift apart.
#[derive(Debug, Clone)]
pub struct StepVolumes {
    /// 1F1B microbatches per step per DP rank.
    pub n_micro: usize,
    /// Tokens per microbatch.
    pub mb_tokens: f64,
    /// (Possibly fractional) transformer layers per pipeline stage.
    pub layers_per_stage: f64,
    /// Matmul time per microbatch (fwd+bwd), TP-sharded, at `mfu`.
    pub compute_per_micro: f64,
    /// Payload of one TP (or expert-TP) all-reduce.
    pub act_bytes: f64,
    /// Per-GPU payload of one EP all-to-all (dispatch or combine).
    pub a2a_bytes: f64,
    /// Pipeline activation/gradient transfer per microbatch per boundary.
    pub pp_bytes: f64,
    /// Per-GPU shared (attention+router+embedding) gradient bytes.
    pub shared_grad_bytes: f64,
    /// Per-GPU expert gradient bytes.
    pub expert_grad_bytes: f64,
}

/// Compute [`StepVolumes`] for a point. Callers must have checked the
/// divisibility preconditions ([`check_feasible`]); this asserts them.
pub fn step_volumes(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
) -> StepVolumes {
    let par = map.par;
    assert!(w.global_batch % par.dp == 0);
    let seqs_per_rank = w.global_batch / par.dp;
    assert!(seqs_per_rank % map.microbatch_seqs == 0);
    let n_micro = seqs_per_rank / map.microbatch_seqs;
    let mb_tokens = (map.microbatch_seqs * w.seq_len) as f64;
    let layers_per_stage = w.n_layers as f64 / par.pp as f64;

    let flops_per_token_layer =
        w.attn_flops_per_token_layer() + w.expert_flops_per_token_layer();
    let emb_flops = 2.0 * w.embedding_params() / par.pp as f64; // spread
    let fwd_flops_micro =
        mb_tokens * (layers_per_stage * flops_per_token_layer + emb_flops) / par.tp as f64;
    let compute_per_micro = 3.0 * fwd_flops_micro / (cluster.spec.gpu.flops * knobs.mfu);

    let act_bytes = mb_tokens * w.d_model as f64 * knobs.comm_dtype_bytes;
    let a2a_bytes = mb_tokens * w.moe.active_per_token as f64 * w.d_model as f64
        * knobs.comm_dtype_bytes
        / par.tp as f64;
    let pp_bytes = mb_tokens * w.d_model as f64 * w.dtype_bytes / par.tp as f64;

    let grad_bytes = 4.0; // fp32 gradient accumulation buffers
    let shared_params_per_gpu = (w.attn_params_per_layer() + w.router_params_per_layer())
        * layers_per_stage
        / par.tp as f64
        + w.embedding_params() / (par.tp * par.pp) as f64;
    let expert_params_per_gpu = w.expert_params_per_layer() * layers_per_stage
        / (map.ep_dp_ranks() * par.tp) as f64;

    StepVolumes {
        n_micro,
        mb_tokens,
        layers_per_stage,
        compute_per_micro,
        act_bytes,
        a2a_bytes,
        pp_bytes,
        shared_grad_bytes: shared_params_per_gpu * grad_bytes,
        expert_grad_bytes: expert_params_per_gpu * grad_bytes,
    }
}

/// Evaluate one (workload, cluster, mapping) point.
pub fn evaluate(w: &Workload, cluster: &Cluster, map: &Mapping, knobs: &PerfKnobs) -> PerfReport {
    let par = map.par;
    let vols = step_volumes(w, cluster, map, knobs);
    let n_micro = vols.n_micro;
    let layers_per_stage = vols.layers_per_stage;
    let compute_per_micro = vols.compute_per_micro;
    let up = cluster.domain(Domain::ScaleUp);
    let out = cluster.domain(Domain::ScaleOut);

    // ---- TP collectives ----------------------------------------------------
    // Megatron: one all-reduce after attention and one after the expert FFN
    // per direction. The expert all-reduce runs in the expert-TP subgroup
    // (size tp/m): fewer ranks => smaller (g-1)/g factor — the §VI effect
    // where finer configs relieve bandwidth pressure on the alternative.
    let act_bytes = vols.act_bytes;
    let tp_ar = coll::all_reduce_time(up, par.tp, act_bytes);
    let etp_ar = coll::all_reduce_time(up, map.expert_tp(), act_bytes);
    let tp_comm_per_micro = 2.0 * (tp_ar + etp_ar) * layers_per_stage;

    // ---- EP all-to-all -----------------------------------------------------
    // Dispatch + combine, forward and backward: 4 per layer. Per-GPU payload
    // is the TP shard of (tokens × k × token_bytes).
    let a2a_bytes = vols.a2a_bytes;
    let span = map.ep_span_gpus();
    let (ep_one, placement) = if span <= cluster.spec.pod_size {
        let t = (span as f64 - 1.0) / span as f64 * a2a_bytes
            / (up.bytes_per_sec() * up.a2a_efficiency)
            + a2a_alpha(up.latency_s, span);
        (t, EpPlacement::ScaleUp)
    } else {
        let cross = cluster.cross_pod_fraction(span);
        let t_up = (1.0 - cross) * a2a_bytes / (up.bytes_per_sec() * up.a2a_efficiency)
            + a2a_alpha(up.latency_s, cluster.spec.pod_size);
        let t_out = cross * a2a_bytes / (out.bytes_per_sec() * out.a2a_efficiency)
            + a2a_alpha(out.latency_s, span);
        (t_up.max(t_out), EpPlacement::Hierarchical)
    };
    let ep_a2a_per_micro =
        4.0 * ep_one * layers_per_stage * (1.0 - knobs.ep_overlap);

    // ---- pipeline p2p ------------------------------------------------------
    // Stage boundaries sit dp×tp GPUs apart => scale-out. One activation
    // send forward + one gradient send backward per microbatch.
    let pp_comm_per_micro =
        if par.pp > 1 { 2.0 * coll::p2p_time(out, vols.pp_bytes) } else { 0.0 };

    // ---- DP gradient sync --------------------------------------------------
    // Shared (attention + router) gradients sync across all DP ranks;
    // expert gradients only across complete expert sets (§V.B).
    let shared_t = coll::hierarchical_all_reduce_time(
        cluster,
        map.dp_span_gpus().min(cluster.spec.n_gpus),
        vols.shared_grad_bytes,
    );
    let n_sets = map.n_complete_expert_sets();
    let expert_t = coll::all_reduce_time(out, n_sets, vols.expert_grad_bytes);
    let dp_comm_per_step = shared_t + expert_t;

    let breakdown = StepBreakdown {
        compute_per_micro,
        tp_comm_per_micro,
        ep_a2a_per_micro,
        pp_comm_per_micro,
        dp_comm_per_step,
        n_micro,
        pp: par.pp,
        ep_placement: placement,
    };

    let step_time = breakdown.pipeline_slots() * breakdown.micro_time()
        + (1.0 - knobs.dp_overlap) * dp_comm_per_step;
    let time_to_train_s = step_time * w.steps_to_target();

    let comm_per_micro =
        breakdown.tp_comm_per_micro + breakdown.ep_a2a_per_micro + breakdown.pp_comm_per_micro;
    let comm_fraction = (breakdown.pipeline_slots() * comm_per_micro
        + (1.0 - knobs.dp_overlap) * dp_comm_per_step)
        / step_time;
    let ideal_flops = 3.0 * w.fwd_flops_per_token() * w.tokens_per_batch();
    let achieved_mfu =
        ideal_flops / (step_time * par.n_gpus() as f64 * cluster.spec.gpu.flops);

    PerfReport {
        cluster: cluster.spec.name.clone(),
        config_name: format!(
            "E{}/k{}/m{}",
            w.moe.total_experts, w.moe.active_per_token, w.moe.granularity
        ),
        breakdown,
        step_time,
        time_to_train_s,
        comm_fraction,
        achieved_mfu,
    }
}

/// Evaluate the paper's Config `i` (Table IV) on `cluster`.
pub fn evaluate_paper_config(cluster: &Cluster, i: usize, knobs: &PerfKnobs) -> PerfReport {
    use crate::model::MoeConfig;
    use crate::parallel::Parallelism;
    let w = Workload::paper_gpt_4p7t(i);
    let map = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(i));
    evaluate(&w, cluster, &map, knobs)
}

/// The three evaluation clusters of §VI, sized to tile 32,768 GPUs
/// (electrical pods of 144 tile 32,256 — the nearest pod-aligned size, a
/// 1.5% cluster-size delta the relative results are insensitive to).
pub fn paper_clusters() -> (Cluster, Cluster, Cluster) {
    (
        Cluster::passage_512(32_768),
        Cluster::electrical_512(32_768),
        Cluster::electrical_144(32_256),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn passage() -> Cluster {
        Cluster::passage_512(32_768)
    }

    #[test]
    fn step_time_positive_and_finite() {
        for i in 1..=4 {
            let r = evaluate_paper_config(&passage(), i, &PerfKnobs::default());
            assert!(r.step_time > 0.0 && r.step_time.is_finite());
            assert!(r.time_to_train_s > 86_400.0, "ttt suspiciously small");
            assert!(r.achieved_mfu > 0.1 && r.achieved_mfu < 0.6);
        }
    }

    #[test]
    fn passage_ep_stays_in_pod_alternative_spills() {
        let r_p = evaluate_paper_config(&passage(), 4, &PerfKnobs::default());
        assert_eq!(r_p.breakdown.ep_placement, EpPlacement::ScaleUp);
        let alt = Cluster::electrical_144(32_256);
        let r_a = evaluate_paper_config(&alt, 4, &PerfKnobs::default());
        assert_eq!(r_a.breakdown.ep_placement, EpPlacement::Hierarchical);
        assert!(r_a.breakdown.ep_a2a_per_micro > 5.0 * r_p.breakdown.ep_a2a_per_micro);
    }

    #[test]
    fn more_bandwidth_never_hurts() {
        let knobs = PerfKnobs::default();
        for i in 1..=4 {
            let hi = evaluate_paper_config(&passage(), i, &knobs);
            let lo = evaluate_paper_config(&Cluster::electrical_512(32_768), i, &knobs);
            assert!(lo.step_time > hi.step_time, "config {i}");
        }
    }

    #[test]
    fn bubble_fraction_matches_1f1b() {
        let r = evaluate_paper_config(&passage(), 1, &PerfKnobs::default());
        let b = &r.breakdown;
        assert_eq!(b.n_micro, 16);
        assert!((b.bubble_fraction() - 7.0 / 23.0).abs() < 1e-12);
    }

    #[test]
    fn compute_dominates_on_passage() {
        let r = evaluate_paper_config(&passage(), 1, &PerfKnobs::default());
        assert!(r.comm_fraction < 0.5, "comm fraction {}", r.comm_fraction);
    }

    #[test]
    fn finer_experts_shrink_expert_tp_allreduce() {
        let knobs = PerfKnobs::default();
        let c1 = evaluate_paper_config(&passage(), 1, &knobs);
        let c4 = evaluate_paper_config(&passage(), 4, &knobs);
        assert!(c4.breakdown.tp_comm_per_micro < c1.breakdown.tp_comm_per_micro);
    }

    #[test]
    fn feasibility_is_a_result_not_a_panic() {
        use crate::model::MoeConfig;
        use crate::parallel::{Mapping, Parallelism};
        let w = Workload::paper_gpt_4p7t(4);
        let m = Mapping::new(Parallelism::paper(), w.moe);
        assert!(check_feasible(&w, &m).is_ok());
        // microbatch must divide the 16 seqs/rank
        let ragged = m.clone().with_microbatch(5);
        assert!(matches!(
            check_feasible(&w, &ragged),
            Err(Infeasible::MicrobatchIndivisible { seqs_per_rank: 16, microbatch_seqs: 5 })
        ));
        // unsharded model state (tp 1, pp 1, one expert set per 256 ranks)
        // needs ~1 TB/GPU — must be rejected, not crash
        let moe = MoeConfig { experts_per_dp_rank: 1, ..w.moe };
        let huge =
            Mapping::try_new(Parallelism { tp: 1, pp: 1, dp: 4096 }, moe).unwrap();
        assert!(matches!(check_feasible(&w, &huge), Err(Infeasible::OverCapacity { .. })));
        // dp that does not divide the global batch
        let odd = Mapping::try_new(Parallelism { tp: 16, pp: 8, dp: 3 }, MoeConfig {
            total_experts: 3,
            active_per_token: 1,
            granularity: 1,
            experts_per_dp_rank: 1,
        })
        .unwrap();
        assert!(matches!(
            check_feasible(&w, &odd),
            Err(Infeasible::BatchIndivisible { global_batch: 4096, dp: 3 })
        ));
    }

    #[test]
    fn evaluate_feasible_matches_evaluate_on_legal_points() {
        let w = Workload::paper_gpt_4p7t(4);
        let cluster = passage();
        let knobs = PerfKnobs::default();
        use crate::model::MoeConfig;
        use crate::parallel::{Mapping, Parallelism};
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(4));
        let (r, mem) = evaluate_feasible(&w, &cluster, &m, &knobs).unwrap();
        let plain = evaluate(&w, &cluster, &m, &knobs);
        assert_eq!(r.step_time.to_bits(), plain.step_time.to_bits());
        assert!(mem.fits());
    }

    #[test]
    fn microbatch_grain_trades_bubble_for_per_micro_comm() {
        // Same mapping at a coarser microbatch: fewer, fatter microbatches
        // => fewer alpha terms but a larger pipeline bubble fraction.
        let w = Workload::paper_gpt_4p7t(1);
        let cluster = passage();
        let knobs = PerfKnobs::default();
        use crate::model::MoeConfig;
        use crate::parallel::{Mapping, Parallelism};
        let m1 = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(1));
        let m4 = m1.clone().with_microbatch(4);
        let r1 = evaluate(&w, &cluster, &m1, &knobs);
        let r4 = evaluate(&w, &cluster, &m4, &knobs);
        assert_eq!(r1.breakdown.n_micro, 16);
        assert_eq!(r4.breakdown.n_micro, 4);
        assert!(r4.breakdown.bubble_fraction() > r1.breakdown.bubble_fraction());
    }
}
