//! End-to-end training driver: real MoE training steps (AOT-compiled JAX +
//! Pallas, executed via PJRT) orchestrated by the Rust coordinator.
//!
//! Three modes:
//! - [`train_single`]: one worker runs the fused `train_step` executable.
//! - [`train_dp`]: N data-parallel workers each run `grad_step` on their
//!   own shard of the synthetic corpus, ring-all-reduce the gradients
//!   through [`crate::coordinator::comm`] (real Rust collectives, real
//!   f32 payloads), then apply identical Adam updates via `apply_update`
//!   — the miniature version of the paper's DP dimension.
//! - [`mapped::run_mapped`]: a planner-chosen PP×DP mapping executed
//!   rank-for-rank (1F1B schedule, expert dispatch/combine over real
//!   all-to-alls) with a per-rank flight recorder — `lumos run`.
//!
//! Python never runs here: everything executes from `artifacts/` (PJRT)
//! or the always-available pure-Rust host backend
//! ([`crate::runtime::Engine::host`]). Step wall times are captured via
//! the quarantined [`crate::obs::record::Stopwatch`] helper.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::comm;
use crate::obs::record::Stopwatch;
use crate::runtime::{Artifact, CompiledEntry, Engine, LitVal, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

pub mod corpus;
pub mod mapped;

pub use corpus::Corpus;
pub use mapped::{run_mapped, run_mapped_chaos, MiniMapping, RunOutcome};

/// One logged training step.
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    pub ce_loss: f64,
    pub aux_loss: f64,
    pub wall_secs: f64,
    /// bytes moved through rust collectives this step (0 in single mode)
    pub comm_bytes: u64,
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub mode: String,
    pub steps: Vec<StepLog>,
    pub total_secs: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f64 {
        self.steps.first().map_or(f64::NAN, |s| s.ce_loss)
    }

    pub fn last_loss(&self) -> f64 {
        self.steps.last().map_or(f64::NAN, |s| s.ce_loss)
    }

    /// Mean step wall time, excluding the first (compile-warm) step.
    pub fn steady_step_secs(&self) -> f64 {
        let tail: Vec<f64> = self.steps.iter().skip(1).map(|s| s.wall_secs).collect();
        if tail.is_empty() {
            return self.steps.first().map_or(0.0, |s| s.wall_secs);
        }
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    /// JSON artifact form: summary fields + per-step rows (same columns
    /// as [`TrainReport::to_csv`]), consistent with every other `--json`
    /// surface. NaN-valued summaries (empty runs) are omitted — the
    /// repo's JSON writer has no NaN representation.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("mode", Json::str(&self.mode)),
            ("n_steps", Json::num(self.steps.len() as f64)),
            ("total_secs", Json::num(self.total_secs)),
        ];
        for (key, v) in [
            ("first_loss", self.first_loss()),
            ("last_loss", self.last_loss()),
            ("steady_step_secs", self.steady_step_secs()),
        ] {
            if v.is_finite() {
                fields.push((key, Json::num(v)));
            }
        }
        let rows: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("step", Json::num(s.step as f64)),
                    ("ce_loss", Json::num(s.ce_loss)),
                    ("aux_loss", Json::num(s.aux_loss)),
                    ("wall_secs", Json::num(s.wall_secs)),
                    ("comm_bytes", Json::num(s.comm_bytes as f64)),
                ])
            })
            .collect();
        fields.push(("steps", Json::Arr(rows)));
        Json::obj(fields)
    }

    /// CSV of the loss curve (EXPERIMENTS.md appendix).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,ce_loss,aux_loss,wall_secs,comm_bytes\n");
        for s in &self.steps {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.4},{}\n",
                s.step, s.ce_loss, s.aux_loss, s.wall_secs, s.comm_bytes
            ));
        }
        out
    }
}

fn batch_tensor(art: &Artifact, corpus: &Corpus, rng: &mut Rng) -> Result<Tensor> {
    let batch = art.cfg_usize("batch")?;
    let seq = art.cfg_usize("seq_len")?;
    let mut data = Vec::with_capacity(batch * (seq + 1));
    for _ in 0..batch {
        data.extend(corpus.sample_sequence(seq + 1, rng).into_iter().map(|t| t as i32));
    }
    Ok(Tensor::I32(data, vec![batch, seq + 1]))
}

/// Single-worker training with the fused `train_step` entry.
pub fn train_single(
    engine: &Engine,
    art: &Artifact,
    steps: usize,
    seed: u64,
    verbose: bool,
) -> Result<TrainReport> {
    let init = engine.load(art, "init")?;
    let train = engine.load(art, "train_step")?;
    let vocab = art.cfg_usize("vocab")?;
    let corpus = Corpus::markov(vocab, seed ^ 0xC0FFEE);
    let mut rng = Rng::new(seed);

    let watch_all = Stopwatch::start();
    // Literal-form state loop (§Perf-L3: skips Tensor<->Vec copies of the
    // ~3P-array state every step; see EXPERIMENTS.md).
    let mut state: Vec<LitVal> = init
        .execute(&[Tensor::scalar_u32(seed as u32)])?
        .iter()
        .map(LitVal::from_tensor)
        .collect::<Result<_>>()?;
    let mut logs = Vec::with_capacity(steps);
    for step in 0..steps {
        let mut step_watch = Stopwatch::start();
        let tokens = LitVal::from_tensor(&batch_tensor(art, &corpus, &mut rng)?)?;
        let mut inputs: Vec<&LitVal> = state.iter().collect();
        inputs.push(&tokens);
        let mut out = train.execute_literals(&inputs)?;
        let aux = out.pop().context("missing aux")?.scalar_f32()?;
        let ce = out.pop().context("missing ce")?.scalar_f32()?;
        state = out;
        let log = StepLog {
            step,
            ce_loss: ce,
            aux_loss: aux,
            wall_secs: step_watch.lap(),
            comm_bytes: 0,
        };
        if verbose && (step < 5 || step % 10 == 0) {
            eprintln!(
                "[train] step {:>4}  ce {:.4}  aux {:.4}  ({:.2}s)",
                step, ce, aux, log.wall_secs
            );
        }
        logs.push(log);
    }
    Ok(TrainReport { mode: "single".into(), steps: logs, total_secs: watch_all.total() })
}

/// Data-parallel training: `n_workers` threads, each with its own corpus
/// shard, gradients ring-all-reduced in rust between `grad_step` and
/// `apply_update`. Returns rank-0's report.
pub fn train_dp(
    engine: &Engine,
    art: &Artifact,
    n_workers: usize,
    steps: usize,
    seed: u64,
    verbose: bool,
) -> Result<TrainReport> {
    if n_workers == 0 {
        bail!("n_workers must be >= 1");
    }
    let init = engine.load(art, "init")?;
    let grad = engine.load(art, "grad_step")?;
    let apply = engine.load(art, "apply_update")?;
    let vocab = art.cfg_usize("vocab")?;
    let n_params = art.n_params;

    // Identical initial state on every worker (same seed through init).
    let state0 = init.execute(&[Tensor::scalar_u32(seed as u32)])?;

    let watch_all = Stopwatch::start();
    let art = Arc::new(art.clone());
    let grad: Arc<CompiledEntry> = grad;
    let apply: Arc<CompiledEntry> = apply;
    let state0 = Arc::new(state0);

    let reports = comm::run_workers(n_workers, move |mut ep| -> Result<Vec<StepLog>> {
        let rank = ep.rank;
        let corpus = Corpus::markov(vocab, seed ^ 0xC0FFEE);
        // distinct data shard per worker
        let mut rng = Rng::new(seed.wrapping_add(1 + rank as u64 * 7919));
        let mut state: Vec<Tensor> = (*state0).clone();
        let mut logs = Vec::with_capacity(steps);

        for step in 0..steps {
            let mut step_watch = Stopwatch::start();
            let bytes_before = ep.bytes_sent;
            let tokens = batch_tensor(&art, &corpus, &mut rng)?;

            // local gradients
            let mut grad_inputs: Vec<Tensor> = state[..n_params].to_vec();
            grad_inputs.push(tokens);
            let mut gout = grad.execute(&grad_inputs)?;
            let aux = gout.pop().context("aux")?.scalar_value()?;
            let ce = gout.pop().context("ce")?.scalar_value()?;

            // ring all-reduce each gradient tensor, then average
            let nw = ep.n_ranks as f32;
            for (gi, gt) in gout.iter_mut().enumerate() {
                let data = gt.as_f32_mut()?;
                ep.all_reduce_sum(data, (step as u64) << 20 | (gi as u64) << 4)?;
                for v in data.iter_mut() {
                    *v /= nw;
                }
            }

            // identical Adam update everywhere
            let mut apply_inputs = state.clone();
            apply_inputs.extend(gout);
            state = apply.execute(&apply_inputs)?;

            // mean losses across workers (tiny all-reduce)
            let mut stats = vec![ce as f32, aux as f32];
            ep.all_reduce_sum(&mut stats, (step as u64) << 20 | 0xFFF0)?;
            let log = StepLog {
                step,
                ce_loss: (stats[0] / nw) as f64,
                aux_loss: (stats[1] / nw) as f64,
                wall_secs: step_watch.lap(),
                comm_bytes: ep.bytes_sent - bytes_before,
            };
            if verbose && rank == 0 && (step < 5 || step % 10 == 0) {
                eprintln!(
                    "[train-dp x{}] step {:>4}  ce {:.4}  aux {:.4}  ({:.2}s, {} MB comm)",
                    ep.n_ranks,
                    step,
                    log.ce_loss,
                    log.aux_loss,
                    log.wall_secs,
                    log.comm_bytes / 1_000_000
                );
            }
            logs.push(log);
        }
        Ok(logs)
    });

    let mut per_rank: Vec<Vec<StepLog>> = Vec::with_capacity(n_workers);
    for r in reports {
        per_rank.push(r?);
    }
    // Workers must agree on the (averaged) loss trajectory.
    for r in 1..per_rank.len() {
        for (a, b) in per_rank[0].iter().zip(&per_rank[r]) {
            if (a.ce_loss - b.ce_loss).abs() > 1e-4 * a.ce_loss.abs().max(1.0) {
                bail!(
                    "rank {} diverged at step {}: {} vs {}",
                    r,
                    a.step,
                    a.ce_loss,
                    b.ce_loss
                );
            }
        }
    }
    Ok(TrainReport {
        mode: format!("dp{n_workers}"),
        steps: per_rank.swap_remove(0),
        total_secs: watch_all.total(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_helpers() {
        let r = TrainReport {
            mode: "single".into(),
            steps: vec![
                StepLog { step: 0, ce_loss: 5.0, aux_loss: 1.0, wall_secs: 2.0, comm_bytes: 0 },
                StepLog { step: 1, ce_loss: 4.0, aux_loss: 1.0, wall_secs: 1.0, comm_bytes: 8 },
                StepLog { step: 2, ce_loss: 3.0, aux_loss: 1.0, wall_secs: 1.2, comm_bytes: 8 },
            ],
            total_secs: 4.2,
        };
        assert_eq!(r.first_loss(), 5.0);
        assert_eq!(r.last_loss(), 3.0);
        assert!((r.steady_step_secs() - 1.1).abs() < 1e-12);
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("step,"));
        let j = r.to_json();
        assert_eq!(j.get("mode").as_str(), Some("single"));
        assert_eq!(j.get("n_steps").as_f64(), Some(3.0));
        assert_eq!(j.get("first_loss").as_f64(), Some(5.0));
        assert_eq!(j.get("last_loss").as_f64(), Some(3.0));
        let rows = j.get("steps").as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[1].get("comm_bytes").as_f64(), Some(8.0));
    }

    #[test]
    fn empty_report_json_omits_nan_summaries() {
        let r = TrainReport { mode: "single".into(), steps: Vec::new(), total_secs: 0.0 };
        let j = r.to_json();
        assert!(j.get("first_loss").as_f64().is_none());
        assert_eq!(j.get("n_steps").as_f64(), Some(0.0));
    }
}
