//! `lumos run`'s execution driver: a planner-chosen PP×DP mapping
//! executed rank-for-rank on the miniature cluster, every phase timed by
//! the per-rank flight recorder ([`crate::obs::record`]).
//!
//! Rank layout is stage-major: `rank = stage * dp + group`, so a
//! pipeline stage's DP peers (`stage` fixed, `group` varying) are
//! contiguous and form that stage's expert-parallel group — experts are
//! partitioned `n_experts / dp` per peer, dispatch/combine run as real
//! group all-to-alls ([`Endpoint::all_to_all_group`]) with
//! manifest-carrying payloads, and the stage follows its 1F1B schedule
//! ([`crate::coordinator::pipeline::one_f_one_b`]) with real blocking
//! p2p activation/gradient sends between stages.
//!
//! **Miniature simplification (by design):** the host model is one MoE
//! block, so pipeline stages cannot split layers. Every rank holds the
//! full model; stages of one DP group run the *same* microbatch (the
//! tokens are a pure function of `(group, step, micro)`), and the
//! inter-stage payloads are real activation-sized tensors that enforce
//! the schedule's dependencies without being consumed numerically. The
//! stage decomposition therefore shapes the *schedule and
//! communication* — what the flight recorder observes — while the
//! numerics stay pure data-parallel: gradients are averaged over
//! microbatches, ring-all-reduced over the active fabric, and applied as
//! identical Adam updates, exactly like [`super::train_dp`]. The driver
//! cross-checks itself every backward: the distributed forward's
//! cross-entropy (through routing, dispatch, expert MLPs, combine) must
//! match the fused `grad_step` entry's loss on the same microbatch.
//!
//! # Chaos supervision ([`run_mapped_chaos`])
//!
//! With a [`FaultPlan`] armed, the driver becomes a supervised system:
//! every step attempt runs under typed [`CommError`]s instead of
//! panics, the endpoint injects the plan's message faults
//! (drop/corrupt/degrade, repaired in the comm layer), and the worker
//! injects its own stall/crash/hang faults at the planned (step, micro,
//! purpose) coordinate. Recovery is checkpoint-rewind: every
//! `ckpt_every` steps each rank snapshots its full state in memory;
//! when a rank dies, survivors abort the step on the
//! [`CommError::Failover`] notice, retire the dead rank's whole DP
//! group, rewind to the **plan-derived** checkpoint
//! `K * floor((crash_step - 1) / K)` (survivors may observe the notice
//! one step apart — only a plan-derived target keeps them bit-aligned),
//! and re-execute one DP replica short with experts re-spilled over the
//! survivors ([`crate::chaos::degraded_owners`]). Retired ranks park
//! until the survivors' end-of-run shutdown so no channel closes while
//! failover frames are in flight. Everything lands in the flight
//! recorder under the `chaos` category, and the aggregate
//! [`ChaosReport`] is a pure function of the plan — byte-identical
//! across `--jobs` and reruns.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::chaos::{degraded_owners, ChaosReport, FaultKind, FaultPlan, PlannedFault};
use crate::coordinator::comm::{self, CommError, Endpoint};
use crate::coordinator::pipeline::{self, one_f_one_b, Action};
use crate::coordinator::router::{unpack_a2a_manifest, Router, RouterConfig};
use crate::obs::record::{Recorder, Recording};
use crate::runtime::{host, Artifact, Engine, HostCfg, Tensor};
use crate::trainer::{Corpus, StepLog, TrainReport};
use crate::util::rng::Rng;

/// How long an injected hang sleeps: longer than the survivors' default
/// retry budget, so the unsupervised-fault canary fails in bounded time.
const HANG_MS: u64 = 10_000;

/// A miniature execution mapping: `pp` pipeline stages × `dp`
/// data-parallel groups (= expert-parallel width), `n_micro`
/// microbatches per step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MiniMapping {
    pub pp: usize,
    pub dp: usize,
    pub n_micro: usize,
}

impl MiniMapping {
    pub fn ranks(&self) -> usize {
        self.pp * self.dp
    }

    pub fn stage_of(&self, rank: usize) -> usize {
        rank / self.dp
    }

    pub fn group_of(&self, rank: usize) -> usize {
        rank % self.dp
    }

    pub fn rank_of(&self, stage: usize, group: usize) -> usize {
        stage * self.dp + group
    }

    /// The expert-parallel group of `rank`: its stage's DP peers, in
    /// ascending rank order. Position in the group == `group_of`.
    pub fn ep_group(&self, rank: usize) -> Vec<usize> {
        let s = self.stage_of(rank);
        (0..self.dp).map(|g| self.rank_of(s, g)).collect()
    }

    /// Scale a planner-chosen pipeline depth down to `ranks` host
    /// workers: the largest divisor of `ranks` not exceeding
    /// `target_pp` becomes `pp`, the rest is DP width.
    pub fn scale(target_pp: usize, ranks: usize, n_micro: usize) -> MiniMapping {
        assert!(ranks >= 1 && n_micro >= 1);
        let mut pp = 1;
        for d in 1..=ranks {
            if ranks % d == 0 && d <= target_pp.max(1) {
                pp = d;
            }
        }
        MiniMapping { pp, dp: ranks / pp, n_micro }
    }
}

/// What one mapped run produces: the loss trajectory plus every rank's
/// flight recording (merge with [`crate::obs::record::to_trace`]) and,
/// for chaos runs, the executed recovery report.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub report: TrainReport,
    pub recordings: Vec<Recording>,
    /// Present iff the run was driven by a fault plan.
    pub chaos: Option<ChaosReport>,
}

impl RunOutcome {
    /// Total recorded seconds per span category, summed over all ranks —
    /// the executed-side column of the three-way gap report.
    pub fn cat_totals(&self) -> BTreeMap<String, f64> {
        let mut out: BTreeMap<String, f64> = BTreeMap::new();
        for rec in &self.recordings {
            for s in &rec.spans {
                *out.entry(s.cat.clone()).or_default() += s.end_s - s.start_s;
            }
        }
        out
    }
}

/// Why one step attempt ended early: a typed comm failure the
/// supervisor can act on (rewind on failover, fail the job on an
/// exhausted retry budget), or a terminal driver error.
enum StepErr {
    Comm(CommError),
    Other(anyhow::Error),
}

impl From<CommError> for StepErr {
    fn from(e: CommError) -> Self {
        StepErr::Comm(e)
    }
}

impl From<anyhow::Error> for StepErr {
    fn from(e: anyhow::Error) -> Self {
        StepErr::Other(e)
    }
}

/// Per-worker context shared by the forward/backward handlers.
struct Worker {
    cfg: HostCfg,
    m: MiniMapping,
    stage: usize,
    group: usize,
    /// Surviving DP group ids, ascending. Starts as `0..dp`; failover
    /// removes the dead rank's group on every survivor identically.
    active_groups: Vec<usize>,
    /// This stage's surviving EP peers (global ranks, ascending).
    ep_group: Vec<usize>,
    router: Router,
}

/// The expert router for the (possibly degraded) set of active DP
/// groups: with everyone alive this is the healthy partition; after a
/// retirement the retired groups' experts are re-spilled round-robin
/// over the survivors via the router remap.
fn make_router(cfg: &HostCfg, m: MiniMapping, active: &[usize]) -> Router {
    let remap = if active.len() == m.dp {
        None
    } else {
        Some((degraded_owners(cfg.n_experts, m.dp, active), active.len()))
    };
    Router::new(RouterConfig {
        n_experts: cfg.n_experts,
        top_k: cfg.top_k,
        experts_per_rank: cfg.n_experts / m.dp,
        // every token fits: a token hits an expert at most once
        capacity: cfg.predictions(),
        max_devices_per_token: None,
        remap,
    })
}

/// Match the next unfired worker-side fault (stall/crash/hang) against
/// this action's logical coordinate; consume and return it.
fn fire_worker_fault(
    faults: &mut [(PlannedFault, bool)],
    step: usize,
    action: &Action,
) -> Option<PlannedFault> {
    for (f, fired) in faults.iter_mut() {
        if !*fired && f.step == step && f.micro == action.micro() && f.purpose == action.purpose()
        {
            *fired = true;
            return Some(*f);
        }
    }
    None
}

/// Forward state handed from a microbatch's forward to its backward.
struct MicroFwd {
    dist_ce: f64,
}

impl Worker {
    /// The microbatch token tensor: a pure function of
    /// `(group, step, micro)`, so all stages of one DP group see
    /// identical data while groups shard the corpus.
    fn micro_tokens(&self, corpus: &Corpus, seed: u64, step: usize, micro: usize) -> Tensor {
        let mix = 0x9E37_79B9_7F4A_7C15u64
            .wrapping_mul(1 + self.group as u64)
            .wrapping_add((step as u64) << 24)
            .wrapping_add(micro as u64);
        let mut rng = Rng::new(seed ^ mix);
        let row = self.cfg.seq_len + 1;
        let mut data = Vec::with_capacity(self.cfg.batch * row);
        for _ in 0..self.cfg.batch {
            data.extend(corpus.sample_sequence(row, &mut rng).into_iter().map(|t| t as i32));
        }
        Tensor::I32(data, vec![self.cfg.batch, row])
    }

    /// Retire a DP group after failover: shrink the active set, rebuild
    /// this stage's EP peer list and the degraded router. Deterministic
    /// and identical on every survivor.
    fn retire_group(&mut self, dead_group: usize) {
        self.active_groups.retain(|&g| g != dead_group);
        self.ep_group =
            self.active_groups.iter().map(|&g| self.m.rank_of(self.stage, g)).collect();
        self.router = make_router(&self.cfg, self.m, &self.active_groups);
    }

    /// All surviving global ranks (every stage × every active group),
    /// ascending — the group the data-parallel collectives run over.
    fn fabric_ranks(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.m.pp * self.active_groups.len());
        for s in 0..self.m.pp {
            for &g in &self.active_groups {
                out.push(self.m.rank_of(s, g));
            }
        }
        out.sort_unstable();
        out
    }

    /// The distributed forward of one microbatch: gate locally, dispatch
    /// tokens to their expert owners over the group all-to-all, run the
    /// local experts, combine the returns, and score the next-token
    /// cross-entropy. Every phase is a recorder cut.
    fn forward(
        &self,
        ep: &mut Endpoint,
        rec: &mut Recorder,
        params: &host::HostParams,
        tokens: &Tensor,
        step: usize,
        micro: usize,
    ) -> Result<MicroFwd, StepErr> {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let ids = tokens.as_i32()?;
        let row = cfg.seq_len + 1;

        if self.stage > 0 {
            let src = self.m.rank_of(self.stage - 1, self.group);
            let _upstream = ep.recv(src, pipeline::tag(step, micro, pipeline::TAG_FWD))?;
            rec.cut(&format!("recv fwd {micro}"), "bubble");
        }

        // Gate every prediction position: embedding, router softmax,
        // deterministic top-k.
        let n_tok = cfg.predictions();
        let mut xs: Vec<Vec<f64>> = Vec::with_capacity(n_tok);
        let mut probs: Vec<Vec<f64>> = Vec::with_capacity(n_tok);
        let mut choices: Vec<Vec<usize>> = Vec::with_capacity(n_tok);
        for b in 0..cfg.batch {
            for t in 0..cfg.seq_len {
                let tok = ids[b * row + t] as usize;
                let x = host::embed_vec(cfg, params, tok);
                let pr = host::gate_probs(cfg, params, &x);
                choices.push(host::top_k_experts(&pr, cfg.top_k));
                probs.push(pr);
                xs.push(x);
            }
        }
        rec.cut(&format!("gate {micro}"), "compute");

        // Dispatch: manifest-carrying all-to-all to the expert owners.
        let route = self.router.route(&choices);
        let feats: Vec<Vec<f32>> =
            xs.iter().map(|x| x.iter().map(|&v| v as f32).collect()).collect();
        let packed = self.router.pack_a2a_manifest(&route, &feats);
        let tag = pipeline::tag(step, micro, pipeline::TAG_DISPATCH);
        let recvd = ep.all_to_all_group(&self.ep_group, packed, tag)?;
        rec.cut(&format!("dispatch a2a {micro}"), "ep");

        // Expert compute on everything received, reply in sender order.
        let mut replies: Vec<Vec<f32>> = Vec::with_capacity(recvd.len());
        let mut n_routed = 0usize;
        for payload in &recvd {
            let routed = unpack_a2a_manifest(payload, d);
            let mut out = Vec::with_capacity(routed.len() * d);
            for rt in &routed {
                let x: Vec<f64> = rt.features.iter().map(|&v| v as f64).collect();
                let y = host::expert_forward(cfg, params, rt.expert, &x);
                out.extend(y.iter().map(|&v| v as f32));
                n_routed += 1;
            }
            replies.push(out);
        }
        rec.cut_args(
            &format!("expert fwd {micro}"),
            "compute",
            &[("routed_tokens", n_routed as f64)],
        );

        let tag = pipeline::tag(step, micro, pipeline::TAG_COMBINE);
        let returned = ep.all_to_all_group(&self.ep_group, replies, tag)?;
        rec.cut(&format!("combine a2a {micro}"), "ep");

        // Combine: pair each reply chunk with this rank's assignments in
        // route order, weight by the renormalized gate, add residual,
        // score cross-entropy.
        let mut ys: Vec<Vec<f64>> = vec![vec![0.0; d]; n_tok];
        let mut pos = vec![0usize; self.ep_group.len()];
        for a in &route.assignments {
            let off = pos[a.rank] * d;
            pos[a.rank] += 1;
            let chunk = &returned[a.rank][off..off + d];
            let topk = &choices[a.token];
            let w = host::renorm_weights(&probs[a.token], topk);
            let wi = topk
                .iter()
                .position(|&e| e == a.expert)
                // lumos: allow(panic-path) -- the router only grants experts the token chose
                .expect("assignment expert not in the token's top-k");
            for (di, &v) in chunk.iter().enumerate() {
                ys[a.token][di] += w[wi] * v as f64;
            }
        }
        let mut ce = 0.0;
        let mut h_flat: Vec<f32> = Vec::with_capacity(n_tok * d);
        for (ti, x) in xs.iter().enumerate() {
            let (b, t) = (ti / cfg.seq_len, ti % cfg.seq_len);
            let target = ids[b * row + t + 1] as usize;
            let h: Vec<f64> = x.iter().zip(&ys[ti]).map(|(a, b)| a + b).collect();
            ce += host::output_ce(cfg, params, &h, target);
            h_flat.extend(h.iter().map(|&v| v as f32));
        }
        ce /= n_tok as f64;
        rec.cut_args(
            &format!("fwd {micro}"),
            "compute",
            &[("ce", ce), ("dropped", route.dropped.len() as f64)],
        );

        if self.stage + 1 < self.m.pp {
            let dst = self.m.rank_of(self.stage + 1, self.group);
            ep.send(dst, pipeline::tag(step, micro, pipeline::TAG_FWD), h_flat)?;
            rec.cut(&format!("send fwd {micro}"), "pp");
        }
        Ok(MicroFwd { dist_ce: ce })
    }
}

/// What one worker thread hands back to the driver.
struct WorkerOut {
    logs: Vec<StepLog>,
    rec: Recording,
    crashed: bool,
    retired: bool,
    /// Surviving DP group ids at the end of the run.
    active: Vec<usize>,
    rewinds: usize,
    steps_rolled_back: usize,
    degraded_steps: usize,
    dead_seen: Vec<usize>,
    injected: BTreeMap<String, usize>,
    corruptions: usize,
    repairs: usize,
}

/// Execute `steps` training steps of `art` under mapping `m` on
/// `m.ranks()` worker threads. Returns the designated rank's report plus
/// every rank's flight recording.
pub fn run_mapped(
    engine: &Engine,
    art: &Artifact,
    m: MiniMapping,
    steps: usize,
    seed: u64,
    verbose: bool,
) -> Result<RunOutcome> {
    run_mapped_chaos(engine, art, m, steps, seed, verbose, None)
}

/// [`run_mapped`] under chaos supervision: with `plan == None` this is
/// bit-identical to the plain driver; with a plan, faults are injected
/// at their logical coordinates and the run must survive every
/// supervised fault kind (module docs).
pub fn run_mapped_chaos(
    engine: &Engine,
    art: &Artifact,
    m: MiniMapping,
    steps: usize,
    seed: u64,
    verbose: bool,
    plan: Option<&FaultPlan>,
) -> Result<RunOutcome> {
    if m.pp == 0 || m.dp == 0 || m.n_micro == 0 {
        bail!("mapping must have pp, dp, n_micro >= 1");
    }
    let cfg = HostCfg {
        vocab: art.cfg_usize("vocab")?,
        d_model: art.cfg_usize("d_model")?,
        d_ff: art.cfg_usize("d_ff")?,
        n_experts: art.cfg_usize("n_experts")?,
        top_k: art.cfg_usize("top_k")?,
        batch: art.cfg_usize("batch")?,
        seq_len: art.cfg_usize("seq_len")?,
    };
    if cfg.total_param_elements() != art.total_param_elements {
        bail!("mapped driver needs a host-shaped artifact (param layout mismatch)");
    }
    if cfg.n_experts % m.dp != 0 {
        bail!("dp={} must divide n_experts={} for expert placement", m.dp, cfg.n_experts);
    }
    if let Some(p) = plan {
        if p.ckpt_every == 0 {
            bail!("chaos plan needs ckpt_every >= 1");
        }
        for f in &p.faults {
            if f.rank >= m.ranks() || f.step >= steps || f.micro >= m.n_micro {
                bail!("planned fault {f:?} is outside the (rank, step, micro) grid");
            }
            if f.kind == FaultKind::Crash && (m.dp < 2 || f.step == 0) {
                bail!("a crash fault needs dp >= 2 and a committed step before it");
            }
        }
    }

    let init = engine.load(art, "init")?;
    let grad = engine.load(art, "grad_step")?;
    let apply = engine.load(art, "apply_update")?;
    let n_params = art.n_params;
    let n_ranks = m.ranks();
    let plan_owned: Option<FaultPlan> = plan.cloned();

    // Identical initial state on every rank (same seed through init).
    let state0 = Arc::new(init.execute(&[Tensor::scalar_u32(seed as u32)])?);

    let results = comm::run_workers(n_ranks, move |mut ep| -> Result<WorkerOut> {
        let rank = ep.rank;
        let chaos_on = plan_owned.is_some();
        let out = {
            let mut body = || -> Result<WorkerOut> {
                let mut w = Worker {
                    cfg,
                    m,
                    stage: m.stage_of(rank),
                    group: m.group_of(rank),
                    active_groups: (0..m.dp).collect(),
                    ep_group: m.ep_group(rank),
                    router: make_router(&cfg, m, &(0..m.dp).collect::<Vec<_>>()),
                };
                let corpus = Corpus::markov(cfg.vocab, seed ^ 0xC0FFEE);
                let sched = one_f_one_b(m.pp, w.stage, m.n_micro);
                let mut state: Vec<Tensor> = (*state0).clone();
                let mut rec = Recorder::start(rank);
                let mut logs: Vec<StepLog> = Vec::with_capacity(steps);

                // Chaos arming: the comm layer owns message faults, the
                // worker owns stall/crash/hang; the fail-stop fault is
                // read from the *full* plan so every survivor derives
                // the same rewind target without coordination.
                let ckpt_every = plan_owned.as_ref().map(|p| p.ckpt_every.max(1)).unwrap_or(1);
                let failstop: Option<PlannedFault> = plan_owned.as_ref().and_then(|p| {
                    p.faults
                        .iter()
                        .find(|f| matches!(f.kind, FaultKind::Crash | FaultKind::Hang))
                        .copied()
                });
                let mut local_faults: Vec<(PlannedFault, bool)> = Vec::new();
                if let Some(p) = plan_owned.as_ref() {
                    let mine = p.for_rank(rank);
                    ep.enable_chaos(
                        mine.iter()
                            .filter(|f| {
                                matches!(
                                    f.kind,
                                    FaultKind::Drop | FaultKind::Corrupt | FaultKind::LinkDegrade
                                )
                            })
                            .copied()
                            .collect(),
                    );
                    local_faults = mine
                        .into_iter()
                        .filter(|f| {
                            matches!(f.kind, FaultKind::Stall | FaultKind::Crash | FaultKind::Hang)
                        })
                        .map(|f| (f, false))
                        .collect();
                }
                let mut snaps: BTreeMap<usize, Vec<Tensor>> = BTreeMap::new();
                let mut local_injected: BTreeMap<String, usize> = BTreeMap::new();
                let (mut crashed, mut retired) = (false, false);
                let mut rewinds = 0usize;
                let mut steps_rolled_back = 0usize;
                let mut degraded_steps = 0usize;
                let mut dead_seen: Vec<usize> = Vec::new();

                let mut step = 0usize;
                while step < steps {
                    if chaos_on && step % ckpt_every == 0 {
                        // In-memory checkpoint: full state (params +
                        // optimizer moments). Re-inserted identically on
                        // re-execution after a rewind.
                        snaps.insert(step, state.clone());
                    }
                    let attempt = {
                        let mut go = || -> Result<Option<StepLog>, StepErr> {
                            let act = w.fabric_ranks();
                            let step_t0 = rec.now();
                            let bytes0 = ep.bytes_sent;
                            rec.mark(&format!("step {step}"), "step");
                            let params = host::HostParams::from_tensors(&state[..n_params])?;
                            let mut grads_acc = host::zero_grads(&cfg);
                            let mut fwd: Vec<Option<MicroFwd>> =
                                (0..m.n_micro).map(|_| None).collect();
                            let (mut ce_sum, mut aux_sum) = (0.0, 0.0);

                            for action in &sched {
                                let micro = action.micro();
                                if let Some(f) = fire_worker_fault(&mut local_faults, step, action)
                                {
                                    match f.kind {
                                        FaultKind::Crash => {
                                            *local_injected
                                                .entry("crash".to_string())
                                                .or_insert(0) += 1;
                                            rec.mark(
                                                &format!(
                                                    "inject crash rank {rank} step {step} at {}",
                                                    action.label()
                                                ),
                                                "chaos",
                                            );
                                            // fail-stop: abandon the run;
                                            // the dropped channel is the
                                            // peers' death certificate.
                                            return Ok(None);
                                        }
                                        FaultKind::Hang => {
                                            *local_injected
                                                .entry("hang".to_string())
                                                .or_insert(0) += 1;
                                            rec.mark(
                                                &format!(
                                                    "inject hang rank {rank} step {step} at {}",
                                                    action.label()
                                                ),
                                                "chaos",
                                            );
                                            std::thread::sleep(Duration::from_millis(HANG_MS));
                                            return Err(StepErr::Other(anyhow!(
                                                "rank {rank} hung at step {step} \
                                                 (unsupervised fault)"
                                            )));
                                        }
                                        FaultKind::Stall => {
                                            *local_injected
                                                .entry("stall".to_string())
                                                .or_insert(0) += 1;
                                            rec.mark(
                                                &format!(
                                                    "inject stall rank {rank} step {step} \
                                                     +{} ms",
                                                    f.amount
                                                ),
                                                "chaos",
                                            );
                                            std::thread::sleep(Duration::from_millis(f.amount));
                                            rec.cut("stall", "chaos");
                                        }
                                        _ => {}
                                    }
                                }
                                match action {
                                    Action::Forward(_) => {
                                        let tokens = w.micro_tokens(&corpus, seed, step, micro);
                                        fwd[micro] = Some(w.forward(
                                            &mut ep, &mut rec, &params, &tokens, step, micro,
                                        )?);
                                    }
                                    Action::Backward(_) => {
                                        if w.stage + 1 < m.pp {
                                            let src = m.rank_of(w.stage + 1, w.group);
                                            let _g = ep.recv(
                                                src,
                                                pipeline::tag(step, micro, pipeline::TAG_BWD),
                                            )?;
                                            rec.cut(&format!("recv bwd {micro}"), "bubble");
                                        }
                                        let tokens = w.micro_tokens(&corpus, seed, step, micro);
                                        let mut inputs: Vec<Tensor> = state[..n_params].to_vec();
                                        inputs.push(tokens);
                                        let mut gout = grad.execute(&inputs)?;
                                        let aux = gout.pop().context("aux")?.scalar_value()?;
                                        let ce = gout.pop().context("ce")?.scalar_value()?;
                                        // Self-check: the distributed
                                        // forward and the fused entry saw
                                        // the same microbatch — their
                                        // losses must agree.
                                        let dist = fwd[micro]
                                            .as_ref()
                                            .context("backward before forward")?;
                                        if (ce - dist.dist_ce).abs() > 1e-3 * ce.abs().max(1e-3) {
                                            return Err(StepErr::Other(anyhow!(
                                                "rank {rank} step {step} micro {micro}: \
                                                 distributed fwd ce {:.6} != entry ce {ce:.6}",
                                                dist.dist_ce
                                            )));
                                        }
                                        ce_sum += ce;
                                        aux_sum += aux;
                                        for (acc, gt) in grads_acc.iter_mut().zip(&gout) {
                                            for (a, &v) in acc.iter_mut().zip(gt.as_f32()?) {
                                                *a += v as f64;
                                            }
                                        }
                                        rec.cut_args(
                                            &format!("bwd {micro}"),
                                            "compute",
                                            &[("ce", ce)],
                                        );
                                        if w.stage > 0 {
                                            let dst = m.rank_of(w.stage - 1, w.group);
                                            let proxy =
                                                vec![0.0f32; cfg.predictions() * cfg.d_model];
                                            ep.send(
                                                dst,
                                                pipeline::tag(step, micro, pipeline::TAG_BWD),
                                                proxy,
                                            )?;
                                            rec.cut(&format!("send bwd {micro}"), "pp");
                                        }
                                    }
                                }
                            }

                            // Average over microbatches, all-reduce over
                            // the active fabric (stages hold duplicate
                            // grads; /act.len() yields the mean over the
                            // surviving dp data shards), identical Adam
                            // update everywhere.
                            let mut grad_tensors: Vec<Tensor> = grads_acc
                                .iter()
                                .zip(cfg.param_shapes())
                                .map(|(buf, (_, shape))| {
                                    let data = buf
                                        .iter()
                                        .map(|&v| (v / m.n_micro as f64) as f32)
                                        .collect();
                                    Tensor::F32(data, shape)
                                })
                                .collect();
                            for (gi, gt) in grad_tensors.iter_mut().enumerate() {
                                let data = gt.as_f32_mut()?;
                                ep.all_reduce_sum_group(
                                    &act,
                                    data,
                                    pipeline::tag(step, gi, pipeline::TAG_GRADS),
                                )?;
                                for v in data.iter_mut() {
                                    *v /= act.len() as f32;
                                }
                            }
                            rec.cut("grad all-reduce", "dp");
                            let mut inputs = state.clone();
                            inputs.extend(grad_tensors);
                            state = apply.execute(&inputs)?;
                            rec.cut("apply", "compute");

                            let nm = m.n_micro as f64;
                            let mut stats = vec![(ce_sum / nm) as f32, (aux_sum / nm) as f32];
                            ep.all_reduce_sum_group(
                                &act,
                                &mut stats,
                                pipeline::tag(step, n_params, pipeline::TAG_STATS),
                            )?;
                            rec.cut("stats all-reduce", "dp");
                            rec.counter("bytes sent", ep.bytes_sent as f64);

                            Ok(Some(StepLog {
                                step,
                                ce_loss: (stats[0] / act.len() as f32) as f64,
                                aux_loss: (stats[1] / act.len() as f32) as f64,
                                wall_secs: rec.now() - step_t0,
                                comm_bytes: ep.bytes_sent - bytes0,
                            }))
                        };
                        go()
                    };
                    for mk in ep.take_chaos_marks() {
                        rec.mark(&mk, "chaos");
                    }
                    match attempt {
                        Ok(Some(log)) => {
                            if w.active_groups.len() < m.dp {
                                degraded_steps += 1;
                            }
                            if verbose && rank == 0 && (step < 5 || step % 10 == 0) {
                                eprintln!(
                                    "[run pp{} dp{} mb{}] step {:>4}  ce {:.4}  aux {:.4}  \
                                     ({:.3}s, {} kB comm)",
                                    m.pp,
                                    m.dp,
                                    m.n_micro,
                                    step,
                                    log.ce_loss,
                                    log.aux_loss,
                                    log.wall_secs,
                                    log.comm_bytes / 1000
                                );
                            }
                            logs.push(log);
                            step += 1;
                        }
                        Ok(None) => {
                            crashed = true;
                            break;
                        }
                        Err(StepErr::Comm(CommError::Failover { dead })) => {
                            if !dead_seen.contains(&dead) {
                                dead_seen.push(dead);
                            }
                            let dead_group = m.group_of(dead);
                            if dead_group == w.group {
                                // My own replica lost a stage: the whole
                                // group retires and parks until the
                                // survivors' end-of-run shutdown.
                                rec.mark(
                                    &format!(
                                        "retire rank {rank}: DP group {dead_group} leaves \
                                         with dead rank {dead}"
                                    ),
                                    "chaos",
                                );
                                rec.cut("failover retire", "chaos");
                                retired = true;
                                break;
                            }
                            if !w.active_groups.contains(&dead_group) {
                                return Err(anyhow!(
                                    "rank {dead}: duplicate failover for already-retired \
                                     group {dead_group}"
                                ));
                            }
                            let fs = failstop.ok_or_else(|| {
                                anyhow!("rank {dead} died without a planned fail-stop fault")
                            })?;
                            ep.complete_failover(dead);
                            w.retire_group(dead_group);
                            // Plan-derived rewind target: survivors can
                            // observe the death one step apart, so the
                            // checkpoint is chosen from the planned crash
                            // step, not from local progress.
                            let c_star = ckpt_every * (fs.step.saturating_sub(1) / ckpt_every);
                            let snap = snaps.get(&c_star).ok_or_else(|| {
                                anyhow!("no snapshot at rewind target step {c_star}")
                            })?;
                            state = snap.clone();
                            logs.truncate(c_star);
                            step = c_star;
                            rewinds += 1;
                            steps_rolled_back += fs.step - c_star;
                            rec.mark(
                                &format!(
                                    "rewind to step {c_star} after rank {dead} died \
                                     (dp {} -> {})",
                                    m.dp,
                                    w.active_groups.len()
                                ),
                                "chaos",
                            );
                            rec.cut("failover recovery", "chaos");
                        }
                        Err(StepErr::Comm(e)) => {
                            return Err(anyhow!(
                                "rank {rank} step {step}: unrecoverable comm failure: {e}"
                            ));
                        }
                        Err(StepErr::Other(e)) => return Err(e),
                    }
                }

                let (ep_injected, corruptions, repairs) = ep.chaos_counters();
                let mut injected = local_injected;
                for (k, v) in ep_injected {
                    *injected.entry(k).or_insert(0) += v;
                }
                for mk in ep.take_chaos_marks() {
                    rec.mark(&mk, "chaos");
                }
                Ok(WorkerOut {
                    logs,
                    rec: rec.finish(),
                    crashed,
                    retired,
                    active: w.active_groups.clone(),
                    rewinds,
                    steps_rolled_back,
                    degraded_steps,
                    dead_seen,
                    injected,
                    corruptions,
                    repairs,
                })
            };
            body()
        };
        // Channel hygiene so the join is deadlock-free: retired ranks
        // park with their mailbox open; every other exit path releases
        // them (a crashed rank's closed channel is itself the signal).
        match &out {
            Ok(wo) if wo.retired => ep.park_until_shutdown(),
            Ok(wo) if wo.crashed => {}
            Ok(wo) => {
                if chaos_on {
                    let act: Vec<usize> = (0..m.pp)
                        .flat_map(|s| wo.active.iter().map(move |&g| m.rank_of(s, g)))
                        .collect();
                    for r in 0..n_ranks {
                        if r != rank && !act.contains(&r) {
                            ep.send_shutdown(r);
                        }
                    }
                }
            }
            Err(_) => {
                if chaos_on {
                    // best-effort: never leave a parked rank waiting on a
                    // shutdown that will not come
                    for r in 0..n_ranks {
                        if r != rank {
                            ep.send_shutdown(r);
                        }
                    }
                }
            }
        }
        out
    });

    let mut outs: Vec<WorkerOut> = Vec::with_capacity(n_ranks);
    for r in results {
        outs.push(r?);
    }
    let eligible: Vec<usize> =
        (0..n_ranks).filter(|&r| !outs[r].crashed && !outs[r].retired).collect();
    let designated = *eligible.first().context("no surviving rank completed the run")?;
    if outs[designated].logs.len() != steps {
        bail!("run committed {} of {steps} step(s)", outs[designated].logs.len());
    }
    // Every surviving rank all-reduced the same stats: trajectories must
    // agree (crashed/retired ranks hold truncated histories and are
    // exempt).
    for &r in eligible.iter().skip(1) {
        for (a, b) in outs[designated].logs.iter().zip(&outs[r].logs) {
            if (a.ce_loss - b.ce_loss).abs() > 1e-4 * a.ce_loss.abs().max(1.0) {
                bail!("rank {r} diverged at step {}: {} vs {}", a.step, a.ce_loss, b.ce_loss);
            }
        }
    }

    let chaos = plan.map(|p| {
        let d = &outs[designated];
        let mut injected: BTreeMap<String, usize> = BTreeMap::new();
        let mut corruptions = 0usize;
        let mut repairs = 0usize;
        let mut dead: Vec<usize> = Vec::new();
        for o in &outs {
            for (k, v) in &o.injected {
                *injected.entry(k.clone()).or_insert(0) += *v;
            }
            corruptions += o.corruptions;
            repairs += o.repairs;
            for &dr in &o.dead_seen {
                if !dead.contains(&dr) {
                    dead.push(dr);
                }
            }
        }
        dead.sort_unstable();
        ChaosReport {
            seed: p.seed,
            plan_digest: p.digest(),
            ckpt_every: p.ckpt_every,
            injected,
            corruptions_detected: corruptions,
            repairs_served: repairs,
            dead_ranks: dead,
            rewinds: d.rewinds,
            steps_rolled_back: d.steps_rolled_back,
            degraded_steps: d.degraded_steps,
            committed_steps: d.logs.len(),
            final_dp: d.active.len(),
        }
    });

    let recordings: Vec<Recording> = outs.iter().map(|o| o.rec.clone()).collect();
    let total_secs = recordings.iter().map(|r| r.end_s).fold(0.0, f64::max);
    Ok(RunOutcome {
        report: TrainReport {
            mode: format!("mapped pp{} dp{} mb{}", m.pp, m.dp, m.n_micro),
            steps: outs[designated].logs.clone(),
            total_secs,
        },
        recordings,
        chaos,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_picks_largest_divisor_within_target() {
        assert_eq!(MiniMapping::scale(4, 4, 2), MiniMapping { pp: 4, dp: 1, n_micro: 2 });
        assert_eq!(MiniMapping::scale(3, 4, 2), MiniMapping { pp: 2, dp: 2, n_micro: 2 });
        assert_eq!(MiniMapping::scale(8, 6, 1), MiniMapping { pp: 6, dp: 1, n_micro: 1 });
        assert_eq!(MiniMapping::scale(1, 6, 1), MiniMapping { pp: 1, dp: 6, n_micro: 1 });
    }

    #[test]
    fn rank_layout_is_stage_major() {
        let m = MiniMapping { pp: 2, dp: 3, n_micro: 1 };
        assert_eq!(m.ranks(), 6);
        assert_eq!(m.stage_of(4), 1);
        assert_eq!(m.group_of(4), 1);
        assert_eq!(m.rank_of(1, 1), 4);
        assert_eq!(m.ep_group(4), vec![3, 4, 5]);
        assert_eq!(m.ep_group(1), vec![0, 1, 2]);
    }

    #[test]
    fn mapped_run_trains_and_records() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let m = MiniMapping { pp: 2, dp: 2, n_micro: 2 };
        let out = run_mapped(&engine, &art, m, 8, 11, false).unwrap();

        assert_eq!(out.report.steps.len(), 8);
        assert!(
            out.report.last_loss() < out.report.first_loss(),
            "loss should fall: {} -> {}",
            out.report.first_loss(),
            out.report.last_loss()
        );
        assert!(out.chaos.is_none());
        assert_eq!(out.recordings.len(), 4);
        for rec in &out.recordings {
            // spans tile [0, end] exactly (partition by construction)
            let mut cursor = 0.0;
            for s in &rec.spans {
                assert_eq!(s.start_s, cursor);
                cursor = s.end_s;
            }
            assert_eq!(cursor, rec.end_s);
            assert!(rec.spans.iter().any(|s| s.cat == "ep"));
            assert!(rec.spans.iter().any(|s| s.cat == "dp"));
        }
        // with pp=2 every rank is on an interior pipeline edge: stage 0
        // sends forward activations, stage 1 sends backward gradients
        for r in 0..4 {
            assert!(
                out.recordings[r].spans.iter().any(|s| s.cat == "pp"),
                "rank {r} has no pp span"
            );
            assert!(
                out.recordings[r].spans.iter().any(|s| s.cat == "bubble"),
                "rank {r} has no bubble span"
            );
        }
        let totals = out.cat_totals();
        assert!(totals.contains_key("compute") && totals.contains_key("ep"));
    }

    #[test]
    fn single_rank_mapping_degenerates_to_dp1() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let m = MiniMapping { pp: 1, dp: 1, n_micro: 2 };
        let out = run_mapped(&engine, &art, m, 3, 7, false).unwrap();
        assert_eq!(out.recordings.len(), 1);
        // no pipeline edges, no bubble waits
        assert!(out.recordings[0].spans.iter().all(|s| s.cat != "pp" && s.cat != "bubble"));
        assert!(out.report.last_loss().is_finite());
    }

    #[test]
    fn invalid_mappings_are_rejected() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let bad_dp = MiniMapping { pp: 1, dp: 3, n_micro: 1 }; // 3 does not divide 8 experts
        assert!(run_mapped(&engine, &art, bad_dp, 1, 0, false).is_err());
        let zero = MiniMapping { pp: 0, dp: 1, n_micro: 1 };
        assert!(run_mapped(&engine, &art, zero, 1, 0, false).is_err());
    }

    #[test]
    fn retirement_shrinks_group_and_router() {
        let m = MiniMapping { pp: 2, dp: 2, n_micro: 1 };
        let cfg = HostCfg {
            vocab: 17,
            d_model: 8,
            d_ff: 16,
            n_experts: 8,
            top_k: 2,
            batch: 1,
            seq_len: 4,
        };
        let mut w = Worker {
            cfg,
            m,
            stage: 1,
            group: 0,
            active_groups: vec![0, 1],
            ep_group: m.ep_group(2),
            router: make_router(&cfg, m, &[0, 1]),
        };
        assert_eq!(w.ep_group, vec![2, 3]);
        assert_eq!(w.fabric_ranks(), vec![0, 1, 2, 3]);
        w.retire_group(1);
        assert_eq!(w.active_groups, vec![0]);
        assert_eq!(w.ep_group, vec![2]);
        assert_eq!(w.fabric_ranks(), vec![0, 2]);
    }

    #[test]
    fn out_of_grid_plans_are_rejected() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let m = MiniMapping { pp: 1, dp: 2, n_micro: 1 };
        let plan = FaultPlan {
            seed: 1,
            ckpt_every: 2,
            faults: vec![PlannedFault {
                rank: 9,
                step: 0,
                micro: 0,
                purpose: pipeline::TAG_FWD,
                kind: FaultKind::Stall,
                amount: 5,
            }],
        };
        assert!(run_mapped_chaos(&engine, &art, m, 2, 0, false, Some(&plan)).is_err());
    }
}
