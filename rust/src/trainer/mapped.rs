//! `lumos run`'s execution driver: a planner-chosen PP×DP mapping
//! executed rank-for-rank on the miniature cluster, every phase timed by
//! the per-rank flight recorder ([`crate::obs::record`]).
//!
//! Rank layout is stage-major: `rank = stage * dp + group`, so a
//! pipeline stage's DP peers (`stage` fixed, `group` varying) are
//! contiguous and form that stage's expert-parallel group — experts are
//! partitioned `n_experts / dp` per peer, dispatch/combine run as real
//! group all-to-alls ([`Endpoint::all_to_all_group`]) with
//! manifest-carrying payloads, and the stage follows its 1F1B schedule
//! ([`crate::coordinator::pipeline::one_f_one_b`]) with real blocking
//! p2p activation/gradient sends between stages.
//!
//! **Miniature simplification (by design):** the host model is one MoE
//! block, so pipeline stages cannot split layers. Every rank holds the
//! full model; stages of one DP group run the *same* microbatch (the
//! tokens are a pure function of `(group, step, micro)`), and the
//! inter-stage payloads are real activation-sized tensors that enforce
//! the schedule's dependencies without being consumed numerically. The
//! stage decomposition therefore shapes the *schedule and
//! communication* — what the flight recorder observes — while the
//! numerics stay pure data-parallel: gradients are averaged over
//! microbatches, ring-all-reduced over the full fabric, and applied as
//! identical Adam updates, exactly like [`super::train_dp`]. The driver
//! cross-checks itself every backward: the distributed forward's
//! cross-entropy (through routing, dispatch, expert MLPs, combine) must
//! match the fused `grad_step` entry's loss on the same microbatch.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::comm::{self, Endpoint};
use crate::coordinator::pipeline::{self, one_f_one_b, Action};
use crate::coordinator::router::{unpack_a2a_manifest, Router, RouterConfig};
use crate::obs::record::{Recorder, Recording};
use crate::runtime::{host, Artifact, Engine, HostCfg, Tensor};
use crate::trainer::{Corpus, StepLog, TrainReport};
use crate::util::rng::Rng;

/// A miniature execution mapping: `pp` pipeline stages × `dp`
/// data-parallel groups (= expert-parallel width), `n_micro`
/// microbatches per step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MiniMapping {
    pub pp: usize,
    pub dp: usize,
    pub n_micro: usize,
}

impl MiniMapping {
    pub fn ranks(&self) -> usize {
        self.pp * self.dp
    }

    pub fn stage_of(&self, rank: usize) -> usize {
        rank / self.dp
    }

    pub fn group_of(&self, rank: usize) -> usize {
        rank % self.dp
    }

    pub fn rank_of(&self, stage: usize, group: usize) -> usize {
        stage * self.dp + group
    }

    /// The expert-parallel group of `rank`: its stage's DP peers, in
    /// ascending rank order. Position in the group == `group_of`.
    pub fn ep_group(&self, rank: usize) -> Vec<usize> {
        let s = self.stage_of(rank);
        (0..self.dp).map(|g| self.rank_of(s, g)).collect()
    }

    /// Scale a planner-chosen pipeline depth down to `ranks` host
    /// workers: the largest divisor of `ranks` not exceeding
    /// `target_pp` becomes `pp`, the rest is DP width.
    pub fn scale(target_pp: usize, ranks: usize, n_micro: usize) -> MiniMapping {
        assert!(ranks >= 1 && n_micro >= 1);
        let mut pp = 1;
        for d in 1..=ranks {
            if ranks % d == 0 && d <= target_pp.max(1) {
                pp = d;
            }
        }
        MiniMapping { pp, dp: ranks / pp, n_micro }
    }
}

/// What one mapped run produces: the loss trajectory plus every rank's
/// flight recording (merge with [`crate::obs::record::to_trace`]).
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub report: TrainReport,
    pub recordings: Vec<Recording>,
}

impl RunOutcome {
    /// Total recorded seconds per span category, summed over all ranks —
    /// the executed-side column of the three-way gap report.
    pub fn cat_totals(&self) -> BTreeMap<String, f64> {
        let mut out: BTreeMap<String, f64> = BTreeMap::new();
        for rec in &self.recordings {
            for s in &rec.spans {
                *out.entry(s.cat.clone()).or_default() += s.end_s - s.start_s;
            }
        }
        out
    }
}

/// Per-worker context shared by the forward/backward handlers.
struct Worker {
    cfg: HostCfg,
    m: MiniMapping,
    stage: usize,
    group: usize,
    ep_group: Vec<usize>,
    router: Router,
}

/// Forward state handed from a microbatch's forward to its backward.
struct MicroFwd {
    dist_ce: f64,
}

impl Worker {
    /// The microbatch token tensor: a pure function of
    /// `(group, step, micro)`, so all stages of one DP group see
    /// identical data while groups shard the corpus.
    fn micro_tokens(&self, corpus: &Corpus, seed: u64, step: usize, micro: usize) -> Tensor {
        let mix = 0x9E37_79B9_7F4A_7C15u64
            .wrapping_mul(1 + self.group as u64)
            .wrapping_add((step as u64) << 24)
            .wrapping_add(micro as u64);
        let mut rng = Rng::new(seed ^ mix);
        let row = self.cfg.seq_len + 1;
        let mut data = Vec::with_capacity(self.cfg.batch * row);
        for _ in 0..self.cfg.batch {
            data.extend(corpus.sample_sequence(row, &mut rng).into_iter().map(|t| t as i32));
        }
        Tensor::I32(data, vec![self.cfg.batch, row])
    }

    /// The distributed forward of one microbatch: gate locally, dispatch
    /// tokens to their expert owners over the group all-to-all, run the
    /// local experts, combine the returns, and score the next-token
    /// cross-entropy. Every phase is a recorder cut.
    fn forward(
        &self,
        ep: &mut Endpoint,
        rec: &mut Recorder,
        params: &host::HostParams,
        tokens: &Tensor,
        step: usize,
        micro: usize,
    ) -> Result<MicroFwd> {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let ids = tokens.as_i32()?;
        let row = cfg.seq_len + 1;

        if self.stage > 0 {
            let src = self.m.rank_of(self.stage - 1, self.group);
            let _upstream = ep.recv(src, pipeline::tag(step, micro, pipeline::TAG_FWD));
            rec.cut(&format!("recv fwd {micro}"), "bubble");
        }

        // Gate every prediction position: embedding, router softmax,
        // deterministic top-k.
        let n_tok = cfg.predictions();
        let mut xs: Vec<Vec<f64>> = Vec::with_capacity(n_tok);
        let mut probs: Vec<Vec<f64>> = Vec::with_capacity(n_tok);
        let mut choices: Vec<Vec<usize>> = Vec::with_capacity(n_tok);
        for b in 0..cfg.batch {
            for t in 0..cfg.seq_len {
                let tok = ids[b * row + t] as usize;
                let x = host::embed_vec(cfg, params, tok);
                let pr = host::gate_probs(cfg, params, &x);
                choices.push(host::top_k_experts(&pr, cfg.top_k));
                probs.push(pr);
                xs.push(x);
            }
        }
        rec.cut(&format!("gate {micro}"), "compute");

        // Dispatch: manifest-carrying all-to-all to the expert owners.
        let route = self.router.route(&choices);
        let feats: Vec<Vec<f32>> =
            xs.iter().map(|x| x.iter().map(|&v| v as f32).collect()).collect();
        let packed = self.router.pack_a2a_manifest(&route, &feats);
        let tag = pipeline::tag(step, micro, pipeline::TAG_DISPATCH);
        let recvd = ep.all_to_all_group(&self.ep_group, packed, tag);
        rec.cut(&format!("dispatch a2a {micro}"), "ep");

        // Expert compute on everything received, reply in sender order.
        let mut replies: Vec<Vec<f32>> = Vec::with_capacity(recvd.len());
        let mut n_routed = 0usize;
        for payload in &recvd {
            let routed = unpack_a2a_manifest(payload, d);
            let mut out = Vec::with_capacity(routed.len() * d);
            for rt in &routed {
                let x: Vec<f64> = rt.features.iter().map(|&v| v as f64).collect();
                let y = host::expert_forward(cfg, params, rt.expert, &x);
                out.extend(y.iter().map(|&v| v as f32));
                n_routed += 1;
            }
            replies.push(out);
        }
        rec.cut_args(
            &format!("expert fwd {micro}"),
            "compute",
            &[("routed_tokens", n_routed as f64)],
        );

        let tag = pipeline::tag(step, micro, pipeline::TAG_COMBINE);
        let returned = ep.all_to_all_group(&self.ep_group, replies, tag);
        rec.cut(&format!("combine a2a {micro}"), "ep");

        // Combine: pair each reply chunk with this rank's assignments in
        // route order, weight by the renormalized gate, add residual,
        // score cross-entropy.
        let mut ys: Vec<Vec<f64>> = vec![vec![0.0; d]; n_tok];
        let mut pos = vec![0usize; self.ep_group.len()];
        for a in &route.assignments {
            let off = pos[a.rank] * d;
            pos[a.rank] += 1;
            let chunk = &returned[a.rank][off..off + d];
            let topk = &choices[a.token];
            let w = host::renorm_weights(&probs[a.token], topk);
            let wi = topk
                .iter()
                .position(|&e| e == a.expert)
                // lumos: allow(panic-path) -- the router only grants experts the token chose
                .expect("assignment expert not in the token's top-k");
            for (di, &v) in chunk.iter().enumerate() {
                ys[a.token][di] += w[wi] * v as f64;
            }
        }
        let mut ce = 0.0;
        let mut h_flat: Vec<f32> = Vec::with_capacity(n_tok * d);
        for (ti, x) in xs.iter().enumerate() {
            let (b, t) = (ti / cfg.seq_len, ti % cfg.seq_len);
            let target = ids[b * row + t + 1] as usize;
            let h: Vec<f64> = x.iter().zip(&ys[ti]).map(|(a, b)| a + b).collect();
            ce += host::output_ce(cfg, params, &h, target);
            h_flat.extend(h.iter().map(|&v| v as f32));
        }
        ce /= n_tok as f64;
        rec.cut_args(
            &format!("fwd {micro}"),
            "compute",
            &[("ce", ce), ("dropped", route.dropped.len() as f64)],
        );

        if self.stage + 1 < self.m.pp {
            let dst = self.m.rank_of(self.stage + 1, self.group);
            ep.send(dst, pipeline::tag(step, micro, pipeline::TAG_FWD), h_flat);
            rec.cut(&format!("send fwd {micro}"), "pp");
        }
        Ok(MicroFwd { dist_ce: ce })
    }
}

/// Execute `steps` training steps of `art` under mapping `m` on
/// `m.ranks()` worker threads. Returns rank-0's report plus every
/// rank's flight recording.
pub fn run_mapped(
    engine: &Engine,
    art: &Artifact,
    m: MiniMapping,
    steps: usize,
    seed: u64,
    verbose: bool,
) -> Result<RunOutcome> {
    if m.pp == 0 || m.dp == 0 || m.n_micro == 0 {
        bail!("mapping must have pp, dp, n_micro >= 1");
    }
    let cfg = HostCfg {
        vocab: art.cfg_usize("vocab")?,
        d_model: art.cfg_usize("d_model")?,
        d_ff: art.cfg_usize("d_ff")?,
        n_experts: art.cfg_usize("n_experts")?,
        top_k: art.cfg_usize("top_k")?,
        batch: art.cfg_usize("batch")?,
        seq_len: art.cfg_usize("seq_len")?,
    };
    if cfg.total_param_elements() != art.total_param_elements {
        bail!("mapped driver needs a host-shaped artifact (param layout mismatch)");
    }
    if cfg.n_experts % m.dp != 0 {
        bail!("dp={} must divide n_experts={} for expert placement", m.dp, cfg.n_experts);
    }

    let init = engine.load(art, "init")?;
    let grad = engine.load(art, "grad_step")?;
    let apply = engine.load(art, "apply_update")?;
    let n_params = art.n_params;
    let n_ranks = m.ranks();

    // Identical initial state on every rank (same seed through init).
    let state0 = Arc::new(init.execute(&[Tensor::scalar_u32(seed as u32)])?);

    let results = comm::run_workers(n_ranks, move |mut ep| -> Result<(Vec<StepLog>, Recording)> {
        let rank = ep.rank;
        let w = Worker {
            cfg,
            m,
            stage: m.stage_of(rank),
            group: m.group_of(rank),
            ep_group: m.ep_group(rank),
            router: Router::new(RouterConfig {
                n_experts: cfg.n_experts,
                top_k: cfg.top_k,
                experts_per_rank: cfg.n_experts / m.dp,
                // every token fits: a token hits an expert at most once
                capacity: cfg.predictions(),
                max_devices_per_token: None,
            }),
        };
        let corpus = Corpus::markov(cfg.vocab, seed ^ 0xC0FFEE);
        let sched = one_f_one_b(m.pp, w.stage, m.n_micro);
        let mut state: Vec<Tensor> = (*state0).clone();
        let mut rec = Recorder::start(rank);
        let mut logs = Vec::with_capacity(steps);

        for step in 0..steps {
            let step_t0 = rec.now();
            let bytes0 = ep.bytes_sent;
            rec.mark(&format!("step {step}"), "step");
            let params = host::HostParams::from_tensors(&state[..n_params])?;
            let mut grads_acc = host::zero_grads(&cfg);
            let mut fwd: Vec<Option<MicroFwd>> = (0..m.n_micro).map(|_| None).collect();
            let (mut ce_sum, mut aux_sum) = (0.0, 0.0);

            for action in &sched {
                let micro = action.micro();
                match action {
                    Action::Forward(_) => {
                        let tokens = w.micro_tokens(&corpus, seed, step, micro);
                        fwd[micro] =
                            Some(w.forward(&mut ep, &mut rec, &params, &tokens, step, micro)?);
                    }
                    Action::Backward(_) => {
                        if w.stage + 1 < m.pp {
                            let src = m.rank_of(w.stage + 1, w.group);
                            let _g = ep.recv(src, pipeline::tag(step, micro, pipeline::TAG_BWD));
                            rec.cut(&format!("recv bwd {micro}"), "bubble");
                        }
                        let tokens = w.micro_tokens(&corpus, seed, step, micro);
                        let mut inputs: Vec<Tensor> = state[..n_params].to_vec();
                        inputs.push(tokens);
                        let mut gout = grad.execute(&inputs)?;
                        let aux = gout.pop().context("aux")?.scalar_value()?;
                        let ce = gout.pop().context("ce")?.scalar_value()?;
                        // Self-check: the distributed forward and the
                        // fused entry saw the same microbatch — their
                        // losses must agree.
                        let dist = fwd[micro].as_ref().context("backward before forward")?;
                        if (ce - dist.dist_ce).abs() > 1e-3 * ce.abs().max(1e-3) {
                            bail!(
                                "rank {rank} step {step} micro {micro}: distributed fwd ce \
                                 {:.6} != entry ce {ce:.6}",
                                dist.dist_ce
                            );
                        }
                        ce_sum += ce;
                        aux_sum += aux;
                        for (acc, gt) in grads_acc.iter_mut().zip(&gout) {
                            for (a, &v) in acc.iter_mut().zip(gt.as_f32()?) {
                                *a += v as f64;
                            }
                        }
                        rec.cut_args(&format!("bwd {micro}"), "compute", &[("ce", ce)]);
                        if w.stage > 0 {
                            let dst = m.rank_of(w.stage - 1, w.group);
                            let proxy = vec![0.0f32; cfg.predictions() * cfg.d_model];
                            ep.send(dst, pipeline::tag(step, micro, pipeline::TAG_BWD), proxy);
                            rec.cut(&format!("send bwd {micro}"), "pp");
                        }
                    }
                }
            }

            // Average over microbatches, all-reduce over the full fabric
            // (stages hold duplicate grads; /n_ranks yields the mean over
            // the dp data shards), identical Adam update everywhere.
            let mut grad_tensors: Vec<Tensor> = grads_acc
                .iter()
                .zip(cfg.param_shapes())
                .map(|(buf, (_, shape))| {
                    let data = buf.iter().map(|&v| (v / m.n_micro as f64) as f32).collect();
                    Tensor::F32(data, shape)
                })
                .collect();
            for (gi, gt) in grad_tensors.iter_mut().enumerate() {
                let data = gt.as_f32_mut()?;
                ep.all_reduce_sum(data, pipeline::tag(step, gi, pipeline::TAG_GRADS));
                for v in data.iter_mut() {
                    *v /= n_ranks as f32;
                }
            }
            rec.cut("grad all-reduce", "dp");
            let mut inputs = state.clone();
            inputs.extend(grad_tensors);
            state = apply.execute(&inputs)?;
            rec.cut("apply", "compute");

            let nm = m.n_micro as f64;
            let mut stats = vec![(ce_sum / nm) as f32, (aux_sum / nm) as f32];
            ep.all_reduce_sum(&mut stats, pipeline::tag(step, n_params, pipeline::TAG_STATS));
            rec.cut("stats all-reduce", "dp");
            rec.counter("bytes sent", ep.bytes_sent as f64);

            let log = StepLog {
                step,
                ce_loss: (stats[0] / n_ranks as f32) as f64,
                aux_loss: (stats[1] / n_ranks as f32) as f64,
                wall_secs: rec.now() - step_t0,
                comm_bytes: ep.bytes_sent - bytes0,
            };
            if verbose && rank == 0 && (step < 5 || step % 10 == 0) {
                eprintln!(
                    "[run pp{} dp{} mb{}] step {:>4}  ce {:.4}  aux {:.4}  ({:.3}s, {} kB comm)",
                    m.pp,
                    m.dp,
                    m.n_micro,
                    step,
                    log.ce_loss,
                    log.aux_loss,
                    log.wall_secs,
                    log.comm_bytes / 1000
                );
            }
            logs.push(log);
        }
        Ok((logs, rec.finish()))
    });

    let mut per_rank: Vec<Vec<StepLog>> = Vec::with_capacity(n_ranks);
    let mut recordings: Vec<Recording> = Vec::with_capacity(n_ranks);
    for r in results {
        let (logs, rec) = r?;
        per_rank.push(logs);
        recordings.push(rec);
    }
    // Every rank all-reduced the same stats: trajectories must agree.
    for r in 1..per_rank.len() {
        for (a, b) in per_rank[0].iter().zip(&per_rank[r]) {
            if (a.ce_loss - b.ce_loss).abs() > 1e-4 * a.ce_loss.abs().max(1.0) {
                bail!("rank {r} diverged at step {}: {} vs {}", a.step, a.ce_loss, b.ce_loss);
            }
        }
    }
    let total_secs = recordings.iter().map(|r| r.end_s).fold(0.0, f64::max);
    Ok(RunOutcome {
        report: TrainReport {
            mode: format!("mapped pp{} dp{} mb{}", m.pp, m.dp, m.n_micro),
            steps: per_rank.swap_remove(0),
            total_secs,
        },
        recordings,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_picks_largest_divisor_within_target() {
        assert_eq!(MiniMapping::scale(4, 4, 2), MiniMapping { pp: 4, dp: 1, n_micro: 2 });
        assert_eq!(MiniMapping::scale(3, 4, 2), MiniMapping { pp: 2, dp: 2, n_micro: 2 });
        assert_eq!(MiniMapping::scale(8, 6, 1), MiniMapping { pp: 6, dp: 1, n_micro: 1 });
        assert_eq!(MiniMapping::scale(1, 6, 1), MiniMapping { pp: 1, dp: 6, n_micro: 1 });
    }

    #[test]
    fn rank_layout_is_stage_major() {
        let m = MiniMapping { pp: 2, dp: 3, n_micro: 1 };
        assert_eq!(m.ranks(), 6);
        assert_eq!(m.stage_of(4), 1);
        assert_eq!(m.group_of(4), 1);
        assert_eq!(m.rank_of(1, 1), 4);
        assert_eq!(m.ep_group(4), vec![3, 4, 5]);
        assert_eq!(m.ep_group(1), vec![0, 1, 2]);
    }

    #[test]
    fn mapped_run_trains_and_records() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let m = MiniMapping { pp: 2, dp: 2, n_micro: 2 };
        let out = run_mapped(&engine, &art, m, 8, 11, false).unwrap();

        assert_eq!(out.report.steps.len(), 8);
        assert!(
            out.report.last_loss() < out.report.first_loss(),
            "loss should fall: {} -> {}",
            out.report.first_loss(),
            out.report.last_loss()
        );
        assert_eq!(out.recordings.len(), 4);
        for rec in &out.recordings {
            // spans tile [0, end] exactly (partition by construction)
            let mut cursor = 0.0;
            for s in &rec.spans {
                assert_eq!(s.start_s, cursor);
                cursor = s.end_s;
            }
            assert_eq!(cursor, rec.end_s);
            assert!(rec.spans.iter().any(|s| s.cat == "ep"));
            assert!(rec.spans.iter().any(|s| s.cat == "dp"));
        }
        // with pp=2 every rank is on an interior pipeline edge: stage 0
        // sends forward activations, stage 1 sends backward gradients
        for r in 0..4 {
            assert!(
                out.recordings[r].spans.iter().any(|s| s.cat == "pp"),
                "rank {r} has no pp span"
            );
            assert!(
                out.recordings[r].spans.iter().any(|s| s.cat == "bubble"),
                "rank {r} has no bubble span"
            );
        }
        let totals = out.cat_totals();
        assert!(totals.contains_key("compute") && totals.contains_key("ep"));
    }

    #[test]
    fn single_rank_mapping_degenerates_to_dp1() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let m = MiniMapping { pp: 1, dp: 1, n_micro: 2 };
        let out = run_mapped(&engine, &art, m, 3, 7, false).unwrap();
        assert_eq!(out.recordings.len(), 1);
        // no pipeline edges, no bubble waits
        assert!(out.recordings[0].spans.iter().all(|s| s.cat != "pp" && s.cat != "bubble"));
        assert!(out.report.last_loss().is_finite());
    }

    #[test]
    fn invalid_mappings_are_rejected() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let bad_dp = MiniMapping { pp: 1, dp: 3, n_micro: 1 }; // 3 does not divide 8 experts
        assert!(run_mapped(&engine, &art, bad_dp, 1, 0, false).is_err());
        let zero = MiniMapping { pp: 0, dp: 1, n_micro: 1 };
        assert!(run_mapped(&engine, &art, zero, 1, 0, false).is_err());
    }
}
