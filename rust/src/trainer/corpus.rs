//! Synthetic training corpus: a sparse first-order Markov language over the
//! model's vocabulary.
//!
//! The paper trains on a proprietary 13T-token mixture; per the
//! substitution rule we need a corpus with *learnable structure* so the
//! loss curve demonstrates real optimization, not noise-fitting. A Markov
//! chain with a few successors per state has entropy far below uniform:
//! the model's cross-entropy should fall from ~ln(vocab) toward the chain
//! entropy as it learns the transition table.

use crate::util::rng::Rng;

/// A first-order Markov chain over `vocab` tokens.
#[derive(Debug, Clone)]
pub struct Corpus {
    vocab: usize,
    /// per-state successor lists (token -> candidates)
    successors: Vec<Vec<u32>>,
    /// weights parallel to `successors`
    weights: Vec<Vec<f64>>,
}

impl Corpus {
    /// Build a chain where each token has `branching` likely successors
    /// with Zipf-ish weights. Deterministic in `seed`.
    pub fn markov(vocab: usize, seed: u64) -> Corpus {
        let branching = 4.min(vocab);
        let mut rng = Rng::new(seed);
        let mut successors = Vec::with_capacity(vocab);
        let mut weights = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let succ: Vec<u32> = rng
                .sample_indices(vocab, branching)
                .into_iter()
                .map(|x| x as u32)
                .collect();
            let w: Vec<f64> = (0..branching).map(|i| 1.0 / (i + 1) as f64).collect();
            successors.push(succ);
            weights.push(w);
        }
        Corpus { vocab, successors, weights }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Sample a token sequence of `len` starting from a random state.
    pub fn sample_sequence(&self, len: usize, rng: &mut Rng) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        let mut state = rng.below(self.vocab as u64) as usize;
        for _ in 0..len {
            out.push(state as u32);
            let next_idx = rng.choice_weighted(&self.weights[state]);
            state = self.successors[state][next_idx] as usize;
        }
        out
    }

    /// Entropy rate of the chain in nats/token (the loss floor a perfect
    /// model converges to, modulo the uniform start state).
    pub fn entropy_rate(&self) -> f64 {
        // stationary distribution approximated as uniform (successor sets
        // are uniformly random, so the chain is near doubly-stochastic)
        let mut h = 0.0;
        for w in &self.weights {
            let total: f64 = w.iter().sum();
            for &x in w {
                let p = x / total;
                h -= p * p.ln();
            }
        }
        h / self.weights.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = Corpus::markov(64, 1);
        let b = Corpus::markov(64, 1);
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(2);
        assert_eq!(a.sample_sequence(32, &mut r1), b.sample_sequence(32, &mut r2));
    }

    #[test]
    fn sequences_respect_transitions() {
        let c = Corpus::markov(32, 3);
        let mut rng = Rng::new(4);
        let seq = c.sample_sequence(200, &mut rng);
        for w in seq.windows(2) {
            assert!(c.successors[w[0] as usize].contains(&w[1]));
        }
    }

    #[test]
    fn entropy_well_below_uniform() {
        let c = Corpus::markov(128, 5);
        let h = c.entropy_rate();
        let uniform = (128f64).ln();
        assert!(h < uniform / 2.0, "h={h} uniform={uniform}");
        assert!(h > 0.5, "chain should not be deterministic: {h}");
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::markov(16, 6);
        let mut rng = Rng::new(7);
        assert!(c.sample_sequence(100, &mut rng).iter().all(|&t| (t as usize) < 16));
    }
}
