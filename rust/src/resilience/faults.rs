//! Seeded Monte Carlo failure sampling from the [`crate::hw::reliability`]
//! FIT composition.
//!
//! A cluster's failure behaviour is the superposition of three Poisson
//! processes derived from per-component FIT rates (failures per 1e9
//! device-hours):
//!
//! - **scale-up field failures** — a field-replaceable unit on an in-pod
//!   link (external laser module, pluggable, connector reseat). The link
//!   runs degraded (fail-in-place) until a technician swaps the unit.
//! - **scale-out field failures** — same, on the Ethernet NIC pluggables.
//! - **GPU-tray failures** — co-packaged silicon (PIC, SerDes) or, for
//!   integrated-laser CPO, the lasers themselves: the tray comes out, the
//!   job checkpoint-restarts on the surviving DP replicas (§II.C.3).
//!
//! Determinism: every trial draws from its own [`Rng`] stream, forked from
//! the engine seed by trial index *before* any work is distributed, so
//! results are byte-identical for any `--jobs` count and independent of
//! trial execution order (property-tested in `tests/resilience_prop.rs`).

use crate::resilience::{FabricReliability, RepairModel};
use crate::util::rng::Rng;

/// What failed, which decides both the degradation and the repair path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Field-replaceable scale-up unit: the GPU's in-pod injection runs
    /// degraded until the swap.
    ScaleUpLink,
    /// Field-replaceable scale-out pluggable: the GPU's NIC runs degraded
    /// until the swap.
    ScaleOutLink,
    /// Tray-impacting failure: checkpoint-restart, one DP replica out
    /// until the tray is serviced.
    GpuTray,
}

/// One sampled failure.
#[derive(Debug, Clone)]
pub struct FaultEvent {
    /// Arrival time, hours since trace start.
    pub at_h: f64,
    pub kind: FaultKind,
    /// Affected GPU (uniform over the cluster).
    pub gpu: usize,
    /// Sampled repair duration, hours (exponential around the
    /// [`RepairModel`] mean for the kind).
    pub repair_h: f64,
}

/// On-demand sampler of the superposed failure process for one
/// (cluster size, fabric, repair) triple. Owns its RNG stream.
#[derive(Debug, Clone)]
pub struct FaultProcess {
    lam_up_h: f64,
    lam_out_h: f64,
    lam_tray_h: f64,
    field_repair_h: f64,
    tray_repair_h: f64,
    n_gpus: usize,
    clock_h: f64,
    rng: Rng,
}

impl FaultProcess {
    pub fn new(
        fabric: &FabricReliability,
        repair: &RepairModel,
        n_gpus: usize,
        rng: Rng,
    ) -> FaultProcess {
        Self::from_rates(
            fabric.field_rate_up_per_hour(n_gpus),
            fabric.field_rate_out_per_hour(n_gpus),
            fabric.tray_rate_per_hour(n_gpus),
            repair,
            n_gpus,
            rng,
        )
    }

    /// Build directly from cluster-wide rates (per hour) — the
    /// [`crate::resilience::goodput`] engine's entry point, which has
    /// already reduced the fabric to rates.
    pub fn from_rates(
        lam_up_h: f64,
        lam_out_h: f64,
        lam_tray_h: f64,
        repair: &RepairModel,
        n_gpus: usize,
        rng: Rng,
    ) -> FaultProcess {
        FaultProcess {
            lam_up_h,
            lam_out_h,
            lam_tray_h,
            field_repair_h: repair.field_repair_hours,
            tray_repair_h: repair.tray_repair_hours,
            n_gpus: n_gpus.max(1),
            clock_h: 0.0,
            rng,
        }
    }

    /// Total failure rate, per hour.
    pub fn total_rate_per_hour(&self) -> f64 {
        self.lam_up_h + self.lam_out_h + self.lam_tray_h
    }
}

/// Samples the next failure on demand: exponential inter-arrival over the
/// superposed rate, kind by rate weight, GPU uniform, repair exponential.
/// The iterator is infinite unless the composed rate is zero.
impl Iterator for FaultProcess {
    type Item = FaultEvent;

    fn next(&mut self) -> Option<FaultEvent> {
        let total = self.total_rate_per_hour();
        if total <= 0.0 {
            return None;
        }
        self.clock_h += self.rng.exp(total);
        let u = self.rng.f64() * total;
        let (kind, mean_repair) = if u < self.lam_up_h {
            (FaultKind::ScaleUpLink, self.field_repair_h)
        } else if u < self.lam_up_h + self.lam_out_h {
            (FaultKind::ScaleOutLink, self.field_repair_h)
        } else {
            (FaultKind::GpuTray, self.tray_repair_h)
        };
        Some(FaultEvent {
            at_h: self.clock_h,
            kind,
            gpu: self.rng.below(self.n_gpus as u64) as usize,
            repair_h: self.rng.exp(1.0 / mean_repair),
        })
    }
}

/// Sample a full failure trace over `horizon_h` hours (the batch form of
/// [`FaultProcess`]; the goodput engine samples on demand instead).
pub fn sample_trace(
    fabric: &FabricReliability,
    repair: &RepairModel,
    n_gpus: usize,
    horizon_h: f64,
    rng: Rng,
) -> Vec<FaultEvent> {
    let mut events = Vec::new();
    for ev in FaultProcess::new(fabric, repair, n_gpus, rng) {
        if ev.at_h > horizon_h {
            break;
        }
        events.push(ev);
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    fn psg() -> FabricReliability {
        FabricReliability::passage()
    }

    #[test]
    fn trace_is_deterministic_from_the_seed() {
        let repair = RepairModel::default();
        let a = sample_trace(&psg(), &repair, 32_768, 100.0, Rng::new(7));
        let b = sample_trace(&psg(), &repair, 32_768, 100.0, Rng::new(7));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_h.to_bits(), y.at_h.to_bits());
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.gpu, y.gpu);
        }
        let c = sample_trace(&psg(), &repair, 32_768, 100.0, Rng::new(8));
        assert!(a.len() != c.len() || a[0].at_h != c[0].at_h);
    }

    #[test]
    fn rates_match_the_fit_arithmetic() {
        // Passage at 32k GPUs: field failures a few per hour (lasers
        // dominate), tray events well under one per hour (external lasers
        // keep the co-packaged FIT small).
        let repair = RepairModel::default();
        let horizon = 2_000.0;
        let trace = sample_trace(&psg(), &repair, 32_768, horizon, Rng::new(1));
        let trays = trace.iter().filter(|e| e.kind == FaultKind::GpuTray).count();
        let fields = trace.len() - trays;
        let lam_field = psg().field_rate_up_per_hour(32_768)
            + psg().field_rate_out_per_hour(32_768);
        let lam_tray = psg().tray_rate_per_hour(32_768);
        assert!((fields as f64 / horizon - lam_field).abs() / lam_field < 0.1);
        assert!((trays as f64 / horizon - lam_tray).abs() / lam_tray < 0.35);
        assert!(trace.windows(2).all(|w| w[0].at_h <= w[1].at_h));
        assert!(trace.iter().all(|e| e.gpu < 32_768 && e.repair_h > 0.0));
    }

    #[test]
    fn integrated_lasers_flip_failures_into_tray_events() {
        let repair = RepairModel::default();
        let count = |fab: &FabricReliability| {
            sample_trace(fab, &repair, 4_096, 1_000.0, Rng::new(3))
                .iter()
                .filter(|e| e.kind == FaultKind::GpuTray)
                .count()
        };
        let cpo = count(&FabricReliability::cpo_integrated());
        let ext = count(&psg());
        assert!(cpo > 10 * ext.max(1), "cpo {cpo} vs external {ext}");
    }
}
