//! Composing failure rates, degraded step times and checkpoint-restart
//! into **availability-adjusted effective time-to-train**.
//!
//! Model (per (workload, cluster, mapping, fabric) point):
//!
//! - Checkpointing at the Young/Daly optimal interval
//!   `τ* = sqrt(2·C·MTBF_tray)` costs a `1 + C/τ*` overhead on all
//!   productive time and bounds the rewind after a tray event to `τ*/2`
//!   in expectation.
//! - Field-replaceable link failures leave the job running **degraded**
//!   (fail-in-place): between failure and swap the step runs at the
//!   slowest member's rate (see [`crate::resilience::degrade`]). In the
//!   closed form the steady-state probability that at least one unit of a
//!   class is down is `1 − exp(−λ·MTTR)` (M/G/∞ occupancy).
//! - Tray events force a checkpoint-restart: the job rewinds an expected
//!   `τ*/2` of work, pays the restart latency, and runs on `dp − 1`
//!   replicas until the tray returns.
//!
//! [`expected`] is the deterministic closed form (what the figures tables
//! and the planner's availability objective use); [`monte_carlo_trial`]
//! samples one wall-clock trajectory from the same inputs (what
//! `lumos resilience --trials N` averages). The two agree within a few
//! percent on the paper clusters (pinned in `tests/resilience_golden.rs`).

use crate::resilience::faults::{FaultKind, FaultProcess};
use crate::resilience::RepairModel;
use crate::util::rng::Rng;

/// Everything the goodput composition needs, pre-reduced to scalars so a
/// Monte Carlo trial is pure arithmetic (no network model in the loop).
#[derive(Debug, Clone)]
pub struct GoodputInputs {
    /// Healthy step time, seconds.
    pub healthy_step: f64,
    /// Step time with one scale-up lane failed on the slowest GPU.
    pub degraded_up_step: f64,
    /// Step time with one scale-out pluggable failed on the slowest GPU.
    pub degraded_out_step: f64,
    /// Healthy time-to-train (the work target), seconds.
    pub healthy_ttt: f64,
    /// DP replica count of the mapping (tray blast radius: one replica out
    /// during tray repair).
    pub dp: usize,
    /// Field-replaceable scale-up failures per hour, cluster-wide.
    pub lam_up_field_h: f64,
    /// Field-replaceable scale-out failures per hour, cluster-wide.
    pub lam_out_field_h: f64,
    /// Tray-impacting failures per hour, cluster-wide.
    pub lam_tray_h: f64,
    pub repair: RepairModel,
}

/// The availability accounting for one point.
#[derive(Debug, Clone)]
pub struct GoodputReport {
    /// Expected wall-clock time-to-train including failures
    /// (`f64::INFINITY` when failures destroy work faster than the job
    /// creates it — the integrated-laser-CPO-at-scale regime).
    pub effective_ttt: f64,
    /// `healthy_ttt / effective_ttt` (0 when divergent).
    pub availability: f64,
    /// Young/Daly optimal checkpoint interval, seconds (∞ when no tray
    /// failures).
    pub checkpoint_interval_s: f64,
    /// Steady-state probability at least one scale-up link is degraded.
    pub degraded_fraction_up: f64,
    /// Steady-state probability at least one scale-out link is degraded.
    pub degraded_fraction_out: f64,
    /// Expected step-time inflation from fail-in-place degradation (≥ 1).
    pub expected_slowdown: f64,
    /// Cluster-wide mean time between tray events, hours.
    pub tray_mtbf_h: f64,
}

/// Deterministic closed-form expectation of the goodput composition.
pub fn expected(inp: &GoodputInputs) -> GoodputReport {
    let r = &inp.repair;
    let fu = 1.0 - (-inp.lam_up_field_h * r.field_repair_hours).exp();
    let fo = 1.0 - (-inp.lam_out_field_h * r.field_repair_hours).exp();
    let sh = inp.healthy_step;
    let slow = 1.0
        + fu * (inp.degraded_up_step / sh - 1.0)
        + fo * (inp.degraded_out_step / sh - 1.0);

    let (tau, ckpt, tray_mtbf_h) = if inp.lam_tray_h > 0.0 {
        let mtbf_s = 3600.0 / inp.lam_tray_h;
        let tau = (2.0 * r.checkpoint_write_s * mtbf_s).sqrt();
        (tau, 1.0 + r.checkpoint_write_s / tau, mtbf_s / 3600.0)
    } else {
        (f64::INFINITY, 1.0, f64::INFINITY)
    };

    let g = slow * ckpt; // wall seconds per healthy-work second
    let effective_ttt = if inp.lam_tray_h > 0.0 {
        // Per tray event: rewind τ/2 of work (g wall-seconds each), the
        // restart latency, and one replica of dp out for the repair.
        let loss_s = g * tau / 2.0
            + r.restart_s
            + r.tray_repair_hours * 3600.0 / inp.dp as f64;
        let denom = 1.0 - inp.lam_tray_h / 3600.0 * loss_s;
        if denom > 0.0 {
            inp.healthy_ttt * g / denom
        } else {
            f64::INFINITY
        }
    } else {
        inp.healthy_ttt * g
    };
    GoodputReport {
        effective_ttt,
        availability: if effective_ttt.is_finite() {
            inp.healthy_ttt / effective_ttt
        } else {
            0.0
        },
        checkpoint_interval_s: tau,
        degraded_fraction_up: fu,
        degraded_fraction_out: fo,
        expected_slowdown: slow,
        tray_mtbf_h,
    }
}

/// One sampled wall-clock trajectory: walk a [`FaultProcess`] trace
/// sampled from the inputs' rates, accruing work at the current
/// (degraded, checkpoint-taxed, replica-reduced) rate until the work
/// target is met. Returns the trial's effective time-to-train in seconds
/// (`INFINITY` if the trial exceeds 100× the healthy duration — the
/// divergent regime). `rng` is the trial's stream; the fault trace and
/// the rewind draws fork from it, so one stream fully determines the
/// trial.
pub fn monte_carlo_trial(inp: &GoodputInputs, rng: &mut Rng) -> f64 {
    let r = &inp.repair;
    let target = inp.healthy_ttt;
    let wall_cap = 100.0 * target;
    let sh = inp.healthy_step;

    let mut process = FaultProcess::from_rates(
        inp.lam_up_field_h,
        inp.lam_out_field_h,
        inp.lam_tray_h,
        r,
        1, // goodput is placement-blind: which GPU failed does not matter
        rng.fork(1),
    );
    let mut local = rng.fork(2);
    let tau = if inp.lam_tray_h > 0.0 {
        (2.0 * r.checkpoint_write_s * 3600.0 / inp.lam_tray_h).sqrt()
    } else {
        f64::INFINITY
    };
    let ckpt = if tau.is_finite() { 1.0 + r.checkpoint_write_s / tau } else { 1.0 };

    let mut now = 0.0f64;
    let mut work = 0.0f64;
    // active repair completion times, per class
    let mut rep_up: Vec<f64> = Vec::new();
    let mut rep_out: Vec<f64> = Vec::new();
    let mut rep_tray: Vec<f64> = Vec::new();
    let mut pending = process.next();

    while work < target {
        if now > wall_cap {
            return f64::INFINITY;
        }
        rep_up.retain(|&t| t > now);
        rep_out.retain(|&t| t > now);
        rep_tray.retain(|&t| t > now);
        let mut step = sh;
        if !rep_up.is_empty() {
            step = step.max(inp.degraded_up_step);
        }
        if !rep_out.is_empty() {
            step = step.max(inp.degraded_out_step);
        }
        let replicas = inp.dp.saturating_sub(rep_tray.len());
        let rate = (sh / step) / ckpt * replicas as f64 / inp.dp as f64;

        // A failure that arrived while the clock was stalled (restart)
        // applies immediately; repair completions are always in the
        // future (retained above).
        let next_fail =
            pending.as_ref().map_or(f64::INFINITY, |e| (e.at_h * 3600.0).max(now));
        let min_of = |xs: &[f64]| xs.iter().copied().fold(f64::INFINITY, f64::min);
        let horizon = next_fail
            .min(min_of(&rep_up))
            .min(min_of(&rep_out))
            .min(min_of(&rep_tray));
        if rate > 0.0 && work + rate * (horizon - now) >= target {
            now += (target - work) / rate;
            break;
        }
        work += rate * (horizon - now);
        now = horizon;
        if pending.is_some() && horizon >= next_fail {
            // lumos: allow(panic-path) -- guarded by the pending.is_some() branch above
            let ev = pending.take().expect("checked is_some");
            match ev.kind {
                FaultKind::ScaleUpLink => rep_up.push(now + ev.repair_h * 3600.0),
                FaultKind::ScaleOutLink => rep_out.push(now + ev.repair_h * 3600.0),
                FaultKind::GpuTray => {
                    work = (work - local.f64() * tau).max(0.0);
                    now += r.restart_s;
                    rep_tray.push(now + ev.repair_h * 3600.0);
                }
            }
            pending = process.next();
        }
    }
    now
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs() -> GoodputInputs {
        GoodputInputs {
            healthy_step: 1.0,
            degraded_up_step: 1.01,
            degraded_out_step: 1.5,
            healthy_ttt: 3.0e5,
            dp: 256,
            lam_up_field_h: 5.0,
            lam_out_field_h: 0.25,
            lam_tray_h: 0.07,
            repair: RepairModel::default(),
        }
    }

    #[test]
    fn expected_is_sane_and_monotone_in_rates() {
        let base = expected(&inputs());
        assert!(base.effective_ttt > inputs().healthy_ttt);
        assert!(base.availability > 0.0 && base.availability < 1.0);
        assert!(base.expected_slowdown >= 1.0);
        let mut worse = inputs();
        worse.lam_tray_h *= 4.0;
        let w = expected(&worse);
        assert!(w.effective_ttt > base.effective_ttt);
        assert!(w.checkpoint_interval_s < base.checkpoint_interval_s);
    }

    #[test]
    fn no_failures_means_only_checkpoint_free_run() {
        let mut inp = inputs();
        inp.lam_up_field_h = 0.0;
        inp.lam_out_field_h = 0.0;
        inp.lam_tray_h = 0.0;
        let r = expected(&inp);
        assert_eq!(r.effective_ttt.to_bits(), inp.healthy_ttt.to_bits());
        assert_eq!(r.availability, 1.0);
        assert!(r.checkpoint_interval_s.is_infinite());
        let mut rng = Rng::new(1);
        let t = monte_carlo_trial(&inp, &mut rng);
        assert!((t - inp.healthy_ttt).abs() / inp.healthy_ttt < 1e-12);
    }

    #[test]
    fn divergent_regimes_report_infinity() {
        let mut inp = inputs();
        inp.lam_tray_h = 400.0; // tray event every 9 s: nothing survives
        let r = expected(&inp);
        assert!(r.effective_ttt.is_infinite());
        assert_eq!(r.availability, 0.0);
        let mut rng = Rng::new(2);
        inp.healthy_ttt = 1.0e3; // keep the capped trial cheap
        assert!(monte_carlo_trial(&inp, &mut rng).is_infinite());
    }

    #[test]
    fn monte_carlo_mean_tracks_the_closed_form() {
        let inp = inputs();
        let cf = expected(&inp).effective_ttt;
        let mut base = Rng::new(42);
        let trials = 64;
        let mean: f64 = (0..trials)
            .map(|t| {
                let mut rng = base.fork(t);
                monte_carlo_trial(&inp, &mut rng)
            })
            .sum::<f64>()
            / trials as f64;
        assert!((mean - cf).abs() / cf < 0.15, "mc {mean} vs closed form {cf}");
    }

    #[test]
    fn trials_are_deterministic_and_order_independent() {
        let inp = inputs();
        let streams = |seed: u64| {
            let mut base = Rng::new(seed);
            (0..16).map(|t| base.fork(t)).collect::<Vec<_>>()
        };
        let forward: Vec<f64> = streams(7)
            .iter()
            .map(|s| monte_carlo_trial(&inp, &mut s.clone()))
            .collect();
        let mut reversed: Vec<f64> = streams(7)
            .iter()
            .rev()
            .map(|s| monte_carlo_trial(&inp, &mut s.clone()))
            .collect();
        reversed.reverse();
        for (a, b) in forward.iter().zip(&reversed) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let other: Vec<f64> = streams(8)
            .iter()
            .map(|s| monte_carlo_trial(&inp, &mut s.clone()))
            .collect();
        assert_ne!(forward, other);
    }
}
