//! Failure-aware effective time-to-train: closing the loop from FIT rates
//! to the paper's 2.7× headline.
//!
//! The paper's serviceability argument (§II.C.3, §III.d) — lasers dominate
//! optics failure rates, and external field-replaceable lasers keep
//! failures link-local instead of GPU-tray events — is qualitative in the
//! text and was a dead end in this repo: [`crate::hw::reliability`]
//! computes FIT compositions that nothing converted into lost training
//! time. This subsystem quantifies it end-to-end:
//!
//! 1. [`faults`] — seeded Monte Carlo failure traces from the FIT
//!    composition of a [`FabricReliability`] profile (per-component
//!    lasers/PIC/SerDes/connectors with field-unit vs GPU-tray blast
//!    radius), byte-identical for any `--jobs` via per-trial forked
//!    [`crate::util::rng::Rng`] streams.
//! 2. [`degrade`] — lowers a failure into a degraded fabric: the
//!    analytical model re-priced at the slowest member's bandwidth, and
//!    the [`crate::timeline`] step DAG re-simulated on a
//!    [`crate::netsim::Network`] with the failed link's capacity removed
//!    (fail-in-place).
//! 3. [`goodput`] — composes rates, degraded intervals and
//!    checkpoint-restart (Young/Daly optimal interval from the tray MTBF)
//!    into **availability-adjusted effective time-to-train**, as a closed
//!    form and as Monte Carlo trials.
//!
//! Surfaced as `lumos resilience` (CLI), `lumos figures --resilience`
//! (the integrated-vs-external-laser TTT delta — the §III.d argument as a
//! number), and the planner's optional availability objective
//! ([`crate::planner::AvailabilityObjective`]). Related work grounds the
//! framing: arXiv 2507.14000 sells photonic fabrics on exactly this
//! system-level accounting, and arXiv 2603.21313 argues
//! reliability/serviceability — not pJ/bit — is what stalls CPO
//! deployment.

pub mod degrade;
pub mod faults;
pub mod goodput;

use crate::hw::reliability::LinkReliability;
use crate::model::Workload;
use crate::parallel::{Mapping, Parallelism};
use crate::perf::{check_feasible, PerfKnobs};
use crate::sweep::engine::{run_indexed, ClusterCache, ClusterKey};
use crate::topology::cluster::Cluster;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::fmt_time;
use crate::util::table::Table;

pub use degrade::{
    analytical_degraded_steps, degraded_cluster, simulated_degraded_steps, DegradedMode,
    DegradedSteps,
};
pub use faults::{sample_trace, FaultEvent, FaultKind, FaultProcess};
pub use goodput::{expected, monte_carlo_trial, GoodputInputs, GoodputReport};

/// Service-time and checkpointing parameters of the repair model.
#[derive(Debug, Clone)]
pub struct RepairModel {
    /// Mean time to swap a field-replaceable unit (external laser module,
    /// pluggable), hours — dispatch + swap; the link runs degraded
    /// meanwhile (fail-in-place).
    pub field_repair_hours: f64,
    /// Mean time to service a GPU tray, hours — one DP replica out.
    pub tray_repair_hours: f64,
    /// Blocking checkpoint write time, seconds (~1.7 GB/GPU of optimizer
    /// state to local NVMe with asynchronous draining).
    pub checkpoint_write_s: f64,
    /// Job restart latency after a tray event (relaunch + checkpoint
    /// load), seconds.
    pub restart_s: f64,
}

impl Default for RepairModel {
    fn default() -> Self {
        RepairModel {
            field_repair_hours: 2.0,
            tray_repair_hours: 8.0,
            checkpoint_write_s: 30.0,
            restart_s: 600.0,
        }
    }
}

/// Reliability profile of a cluster build-out: the scale-up link design,
/// the scale-out NIC design, and how many of each a GPU carries.
#[derive(Debug, Clone)]
pub struct FabricReliability {
    pub name: String,
    pub scale_up: LinkReliability,
    /// Scale-up lanes per GPU (32 Tb/s over 56G×8λ fibers ≈ 72; the §II.C
    /// "rails" count).
    pub scale_up_links_per_gpu: usize,
    pub scale_out: LinkReliability,
    /// Scale-out pluggables per GPU (1.6 Tb/s as 2×800G DR8).
    pub scale_out_links_per_gpu: usize,
}

impl FabricReliability {
    fn with_scale_up(name: &str, scale_up: LinkReliability) -> FabricReliability {
        FabricReliability {
            name: name.to_string(),
            scale_up,
            scale_up_links_per_gpu: 72,
            scale_out: LinkReliability::pluggable(8.0),
            scale_out_links_per_gpu: 2,
        }
    }

    /// Passage: external field-replaceable lasers feed the interposer
    /// (§III.d) — link failures stay link-local.
    pub fn passage() -> FabricReliability {
        let link = LinkReliability::passage_external_laser(4.0);
        Self::with_scale_up("Passage (external laser)", link)
    }

    /// In-package-laser CPO at the same bandwidth: a laser failure is a
    /// GPU-tray event.
    pub fn cpo_integrated() -> FabricReliability {
        Self::with_scale_up("CPO (integrated laser)", LinkReliability::cpo_integrated_laser(4.0))
    }

    /// Pluggable-module scale-up (lasers in the module: field unit).
    pub fn pluggable_scale_up() -> FabricReliability {
        Self::with_scale_up("Pluggable scale-up", LinkReliability::pluggable(4.0))
    }

    /// The electrical alternative: copper in-pod links (no optics), the
    /// same Ethernet pluggables for scale-out.
    pub fn electrical() -> FabricReliability {
        Self::with_scale_up("Electrical (copper)", LinkReliability::copper())
    }

    /// The profile a cluster preset implies: Passage-named clusters get
    /// external-laser optics, everything else copper scale-up.
    pub fn default_for(cluster: &Cluster) -> FabricReliability {
        if cluster.spec.name.starts_with("Passage") {
            FabricReliability::passage()
        } else {
            FabricReliability::electrical()
        }
    }

    /// CLI name lookup (`--tech passage | cpo | electrical | pluggable`).
    pub fn from_cli_name(name: &str) -> Option<FabricReliability> {
        match name {
            "passage" => Some(FabricReliability::passage()),
            "cpo" => Some(FabricReliability::cpo_integrated()),
            "electrical" => Some(FabricReliability::electrical()),
            "pluggable" => Some(FabricReliability::pluggable_scale_up()),
            _ => None,
        }
    }

    /// Field-replaceable scale-up failures per hour, cluster-wide.
    pub fn field_rate_up_per_hour(&self, n_gpus: usize) -> f64 {
        self.scale_up.field_impact_fit()
            * (self.scale_up_links_per_gpu * n_gpus) as f64
            / 1e9
    }

    /// Field-replaceable scale-out failures per hour, cluster-wide.
    pub fn field_rate_out_per_hour(&self, n_gpus: usize) -> f64 {
        self.scale_out.field_impact_fit()
            * (self.scale_out_links_per_gpu * n_gpus) as f64
            / 1e9
    }

    /// GPU-tray-impacting failures per hour, cluster-wide (both link
    /// classes contribute their co-packaged FIT).
    pub fn tray_rate_per_hour(&self, n_gpus: usize) -> f64 {
        (self.scale_up.tray_impact_fit() * self.scale_up_links_per_gpu as f64
            + self.scale_out.tray_impact_fit() * self.scale_out_links_per_gpu as f64)
            * n_gpus as f64
            / 1e9
    }

    pub fn tray_events_per_year(&self, n_gpus: usize) -> f64 {
        self.tray_rate_per_hour(n_gpus) * 8760.0
    }

    /// Mean time between *any* link failure, hours.
    pub fn link_mtbf_hours(&self, n_gpus: usize) -> f64 {
        let fit_per_gpu = self.scale_up.link_fit() * self.scale_up_links_per_gpu as f64
            + self.scale_out.link_fit() * self.scale_out_links_per_gpu as f64;
        1e9 / (fit_per_gpu * n_gpus as f64)
    }
}

/// Where the degraded-step ratios the goodput composition prices come
/// from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegradeSource {
    /// Closed-form slowest-member pricing: the whole cluster's domain
    /// bandwidth scaled down ([`analytical_degraded_steps`]). Conservative
    /// — every collective everywhere runs at the degraded rate.
    Analytical,
    /// Ratios measured by re-simulating the timeline step DAG with one
    /// victim GPU's links degraded in place
    /// ([`simulated_degraded_steps`]); the blast radius emerges from
    /// max-min sharing and task barriers. The default — this is the
    /// closed-the-loop form the incremental dep engine made affordable.
    Simulated,
}

impl DegradeSource {
    /// CLI name lookup (`--degrade analytical | simulated`).
    pub fn from_cli_name(name: &str) -> Option<DegradeSource> {
        match name {
            "analytical" => Some(DegradeSource::Analytical),
            "simulated" => Some(DegradeSource::Simulated),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DegradeSource::Analytical => "analytical",
            DegradeSource::Simulated => "simulated",
        }
    }
}

/// Engine parameters shared by every assessment in one run.
#[derive(Debug, Clone)]
pub struct ResilienceSpec {
    pub repair: RepairModel,
    pub seed: u64,
    /// Monte Carlo trials per assessment; 0 = closed form only (the
    /// figures path).
    pub trials: usize,
    /// Degraded-step pricing mode (default: [`DegradeSource::Simulated`];
    /// falls back to analytical per point when the mapping cannot be
    /// simulated, recorded in [`Assessment::degrade_source`]).
    pub degrade: DegradeSource,
}

impl Default for ResilienceSpec {
    fn default() -> Self {
        ResilienceSpec {
            repair: RepairModel::default(),
            seed: 7,
            trials: 128,
            degrade: DegradeSource::Simulated,
        }
    }
}

/// One point's full resilience accounting.
#[derive(Debug, Clone)]
pub struct Assessment {
    pub cluster: String,
    pub config_name: String,
    pub fabric: String,
    pub mapping: Mapping,
    pub steps: DegradedSteps,
    /// Where `steps`' degraded ratios actually came from — may differ from
    /// the requested [`ResilienceSpec::degrade`] when the simulated path
    /// was unavailable for this point and the engine fell back.
    pub degrade_source: DegradeSource,
    /// Why the simulated path was unavailable (`None` when `degrade_source`
    /// matches the request) — surfaced so a fallback is never silent.
    pub degrade_note: Option<String>,
    pub inputs: GoodputInputs,
    /// Closed-form expectation.
    pub expected: GoodputReport,
    pub tray_per_year: f64,
    pub link_mtbf_h: f64,
    /// Monte Carlo trials behind the `mc_*` aggregates (0 = closed form
    /// copied through).
    pub trials: usize,
    pub mc_mean_ttt: f64,
    pub mc_min_ttt: f64,
    pub mc_max_ttt: f64,
}

impl Assessment {
    /// Effective TTT minus healthy TTT (what failures cost), seconds.
    pub fn ttt_lost_s(&self) -> f64 {
        self.expected.effective_ttt - self.steps.healthy_ttt
    }
}

/// Assess one (workload, cluster, mapping) point under `fabric`:
/// degraded steps per `spec.degrade` (timeline-measured ratios by
/// default, analytical fallback recorded in the result), closed-form
/// goodput, and `spec.trials` Monte Carlo trajectories on `jobs` worker
/// threads (trial streams are forked from the seed in index order before
/// any work is distributed, so output is byte-identical for any `jobs`).
pub fn assess(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
    fabric: &FabricReliability,
    spec: &ResilienceSpec,
    jobs: usize,
) -> Assessment {
    let n = cluster.spec.n_gpus;
    let (steps, degrade_source, degrade_note) = match spec.degrade {
        DegradeSource::Analytical => (
            analytical_degraded_steps(w, cluster, map, knobs, fabric),
            DegradeSource::Analytical,
            None,
        ),
        DegradeSource::Simulated => match simulated_degraded_steps(w, cluster, map, knobs, fabric)
        {
            Ok(s) => (s, DegradeSource::Simulated, None),
            // DAG guard fired (or the point is infeasible, which the
            // analytical path would assert on too): fall back to the
            // closed form and carry the reason — a fallback must never
            // be silent.
            Err(e) => (
                analytical_degraded_steps(w, cluster, map, knobs, fabric),
                DegradeSource::Analytical,
                Some(e.to_string()),
            ),
        },
    };
    let inputs = GoodputInputs {
        healthy_step: steps.healthy_step,
        degraded_up_step: steps.degraded_up_step,
        degraded_out_step: steps.degraded_out_step,
        healthy_ttt: steps.healthy_ttt,
        dp: map.par.dp,
        lam_up_field_h: fabric.field_rate_up_per_hour(n),
        lam_out_field_h: fabric.field_rate_out_per_hour(n),
        lam_tray_h: fabric.tray_rate_per_hour(n),
        repair: spec.repair.clone(),
    };
    let report = expected(&inputs);
    let (mc_mean, mc_min, mc_max) = if spec.trials == 0 {
        (report.effective_ttt, report.effective_ttt, report.effective_ttt)
    } else {
        let mut base = Rng::new(spec.seed);
        let streams: Vec<Rng> = (0..spec.trials).map(|t| base.fork(t as u64)).collect();
        let results = run_indexed(spec.trials, jobs, |i| {
            let mut rng = streams[i].clone();
            monte_carlo_trial(&inputs, &mut rng)
        });
        let mean = results.iter().sum::<f64>() / results.len() as f64;
        let min = results.iter().copied().fold(f64::INFINITY, f64::min);
        let max = results.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        (mean, min, max)
    };
    Assessment {
        cluster: cluster.spec.name.clone(),
        config_name: format!(
            "E{}/k{}/m{}",
            w.moe.total_experts, w.moe.active_per_token, w.moe.granularity
        ),
        fabric: fabric.name.clone(),
        mapping: map.clone(),
        steps,
        degrade_source,
        degrade_note,
        inputs,
        expected: report,
        tray_per_year: fabric.tray_events_per_year(n),
        link_mtbf_h: fabric.link_mtbf_hours(n),
        trials: spec.trials,
        mc_mean_ttt: mc_mean,
        mc_min_ttt: mc_min,
        mc_max_ttt: mc_max,
    }
}

/// A mapping to assess on `cluster`: the paper's TP16×PP8×DP256 when the
/// cluster is (within 2%) the paper scale, otherwise a TP16×PP1 layout
/// that fills the cluster (the pod-scale golden scenario uses this on one
/// 512-GPU pod).
pub fn default_mapping(w: &Workload, cluster: &Cluster) -> Result<Mapping, String> {
    let n = cluster.spec.n_gpus;
    let paper = Parallelism::paper();
    let delta = (paper.n_gpus() as f64 - n as f64).abs() / n as f64;
    if delta <= 0.02 && paper.tp <= cluster.spec.pod_size {
        if let Ok(m) = Mapping::try_new(paper, w.moe) {
            if check_feasible(w, &m).is_ok() {
                return Ok(m);
            }
        }
    }
    let tp = 16;
    if n % tp != 0 {
        return Err(format!("no default mapping: {n} GPUs is not a multiple of TP {tp}"));
    }
    let par = Parallelism { tp, pp: 1, dp: n / tp };
    let m = Mapping::try_new(par, w.moe).map_err(|e| format!("no default mapping: {e}"))?;
    check_feasible(w, &m).map_err(|e| format!("default mapping infeasible: {e}"))?;
    Ok(m)
}

/// One row of the headline comparison: the same Table IV config assessed
/// on Passage (external-laser optics) and the 144-pod electrical
/// alternative (copper + the same Ethernet pluggables).
#[derive(Debug, Clone)]
pub struct PairedRow {
    pub config: usize,
    pub passage: Assessment,
    pub electrical: Assessment,
}

impl PairedRow {
    /// Healthy Passage-vs-Electrical speedup (the Fig. 11 ratio).
    pub fn healthy_speedup(&self) -> f64 {
        self.electrical.steps.healthy_ttt / self.passage.steps.healthy_ttt
    }

    /// Availability-adjusted speedup (closed form).
    pub fn adjusted_speedup(&self) -> f64 {
        self.electrical.expected.effective_ttt / self.passage.expected.effective_ttt
    }
}

/// Assess the paper's headline pair for each config in `configs`, with
/// per-row seeds derived from the *config index* (not the list position),
/// so the same (seed, config) always draws the same trials regardless of
/// which subset of configs a run requests — and deterministic for any
/// `jobs`.
pub fn paper_pairs(
    configs: &[usize],
    knobs: &PerfKnobs,
    spec: &ResilienceSpec,
    jobs: usize,
    cache: &ClusterCache,
) -> Vec<PairedRow> {
    let passage = cache.get(&ClusterKey::Passage512);
    let electrical = cache.get(&ClusterKey::Electrical144);
    let fab_p = FabricReliability::passage();
    let fab_e = FabricReliability::electrical();
    configs
        .iter()
        .map(|&cfg| {
            let w = Workload::paper_gpt_4p7t(cfg);
            // lumos: allow(panic-path) -- §VI preset: every paper config maps onto Passage-512
            let map = default_mapping(&w, &passage).expect("paper mapping fits Passage-512");
            let spec_p =
                ResilienceSpec { seed: spec.seed.wrapping_add(2 * cfg as u64), ..spec.clone() };
            let spec_e = ResilienceSpec {
                seed: spec.seed.wrapping_add(2 * cfg as u64 + 1),
                ..spec.clone()
            };
            PairedRow {
                config: cfg,
                passage: assess(&w, &passage, &map, knobs, &fab_p, &spec_p, jobs),
                electrical: assess(&w, &electrical, &map, knobs, &fab_e, &spec_e, jobs),
            }
        })
        .collect()
}

/// The §III.d golden scenario: Config 4 on one 512-GPU Passage pod —
/// identical performance, three laser placements; only serviceability
/// differs.
pub fn pod_serviceability(
    knobs: &PerfKnobs,
    spec: &ResilienceSpec,
    jobs: usize,
    cache: &ClusterCache,
) -> Vec<Assessment> {
    let cluster = cache.get(&ClusterKey::custom(512, 512, 32_000.0));
    let w = Workload::paper_gpt_4p7t(4);
    // lumos: allow(panic-path) -- §III.d preset: Config 4 always fits one 512-GPU pod
    let map = default_mapping(&w, &cluster).expect("TP16×PP1×DP32 fits one pod");
    [
        FabricReliability::passage(),
        FabricReliability::cpo_integrated(),
        FabricReliability::pluggable_scale_up(),
    ]
    .iter()
    .enumerate()
    .map(|(i, fabric)| {
        let s = ResilienceSpec { seed: spec.seed.wrapping_add(100 + i as u64), ..spec.clone() };
        assess(&w, &cluster, &map, knobs, fabric, &s, jobs)
    })
    .collect()
}

/// Format a possibly-divergent duration: [`fmt_time`] when finite,
/// `"diverges"` otherwise (the shared rendering rule for effective-TTT
/// cells — the planner's adjusted column uses it too).
pub fn fmt_ttt(secs: f64) -> String {
    if secs.is_finite() {
        fmt_time(secs)
    } else {
        "diverges".to_string()
    }
}

/// Divergence-aware ratio cell: `"{:.2}x"` when finite, `"—"` otherwise.
fn fmt_ratio(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.2}x")
    } else {
        "—".to_string()
    }
}

/// The headline artifact: availability-adjusted Passage-vs-Electrical-144
/// speedup for every Table IV config.
pub fn speedup_table(rows: &[PairedRow]) -> Table {
    let trials = rows.first().map_or(0, |r| r.passage.trials);
    let source = if trials == 0 {
        "closed form".to_string()
    } else {
        format!("closed form, {trials} trials")
    };
    let mut t = Table::new(
        &format!("Resilience: availability-adjusted time-to-train ({source})"),
        &[
            "Config",
            "Passage eff TTT",
            "avail",
            "Electr-144 eff TTT",
            "avail",
            "mc mean (P / E)",
            "healthy speedup",
            "adjusted speedup",
        ],
    );
    for r in rows {
        t.row(&[
            format!("Config {}", r.config),
            fmt_ttt(r.passage.expected.effective_ttt),
            format!("{:.1}%", 100.0 * r.passage.expected.availability),
            fmt_ttt(r.electrical.expected.effective_ttt),
            format!("{:.1}%", 100.0 * r.electrical.expected.availability),
            if r.passage.trials == 0 {
                "—".to_string() // closed form only: no independent MC ran
            } else {
                format!(
                    "{} / {}",
                    fmt_ttt(r.passage.mc_mean_ttt),
                    fmt_ttt(r.electrical.mc_mean_ttt)
                )
            },
            fmt_ratio(r.healthy_speedup()),
            fmt_ratio(r.adjusted_speedup()),
        ]);
    }
    t
}

/// The §III.d artifact: what laser placement alone costs in effective TTT
/// on otherwise identical hardware.
pub fn serviceability_table(rows: &[Assessment]) -> Table {
    let mut t = Table::new(
        "Serviceability: laser placement on one 512-GPU pod (Config 4)",
        &[
            "Link design",
            "tray events/yr",
            "tray MTBF",
            "ckpt interval",
            "eff TTT",
            "TTT lost",
            "avail",
        ],
    );
    for a in rows {
        t.row(&[
            a.fabric.clone(),
            format!("{:.1}", a.tray_per_year),
            fmt_ttt(a.expected.tray_mtbf_h * 3600.0),
            fmt_ttt(a.expected.checkpoint_interval_s),
            fmt_ttt(a.expected.effective_ttt),
            fmt_ttt(a.ttt_lost_s()),
            format!("{:.2}%", 100.0 * a.expected.availability),
        ]);
    }
    t
}

/// The degrade-source summary the tables report, shared with the JSON
/// artifacts: the uniform source name, or `"mixed"` when assessments
/// disagree (some point fell back to analytical pricing).
pub fn degrade_summary<'a>(mut rows: impl Iterator<Item = &'a Assessment>) -> &'static str {
    match rows.next() {
        None => "analytical",
        Some(first) => {
            if rows.all(|a| a.degrade_source == first.degrade_source) {
                first.degrade_source.name()
            } else {
                "mixed"
            }
        }
    }
}

/// Deterministic run counters over a set of assessments — the `"metrics"`
/// key of the resilience JSON artifacts. Carries what the tables already
/// report (trial pool size, degrade-mode fallbacks) in machine-readable
/// form, aggregated in row order.
pub fn assessment_metrics<'a>(rows: impl Iterator<Item = &'a Assessment>) -> crate::obs::Metrics {
    let mut m = crate::obs::Metrics::new();
    for a in rows {
        m.inc("assessments", 1);
        m.inc("mc_trials", a.trials as u64);
        match a.degrade_source {
            DegradeSource::Analytical => m.inc("degrade_analytical", 1),
            DegradeSource::Simulated => m.inc("degrade_simulated", 1),
        }
        if a.degrade_note.is_some() {
            m.inc("degrade_fallbacks", 1);
        }
        m.observe("healthy_step_s", a.steps.healthy_step);
        m.observe("availability", a.expected.availability);
    }
    m
}

/// Detailed per-assessment table (the `lumos resilience --cluster ...`
/// payload): one row per config.
pub fn assessment_table(rows: &[Assessment]) -> Table {
    let (cluster, fabric) = rows
        .first()
        .map(|a| (a.cluster.clone(), a.fabric.clone()))
        .unwrap_or_default();
    let src = degrade_summary(rows.iter());
    let mut t = Table::new(
        &format!("Resilience: {cluster} under {fabric} ({src} degraded steps)"),
        &[
            "Config",
            "healthy TTT",
            "degr up/out step",
            "tray MTBF",
            "eff TTT",
            "mc mean",
            "mc min..max",
            "avail",
        ],
    );
    for a in rows {
        t.row(&[
            a.config_name.clone(),
            fmt_ttt(a.steps.healthy_ttt),
            format!("{:.3}x/{:.3}x", a.steps.up_ratio(), a.steps.out_ratio()),
            fmt_ttt(a.expected.tray_mtbf_h * 3600.0),
            fmt_ttt(a.expected.effective_ttt),
            fmt_ttt(a.mc_mean_ttt),
            format!("{}..{}", fmt_ttt(a.mc_min_ttt), fmt_ttt(a.mc_max_ttt)),
            format!("{:.2}%", 100.0 * a.expected.availability),
        ]);
    }
    t
}

/// JSON number, or `null` for non-finite values (divergent regimes) — the
/// shared serialization rule for effective-TTT fields.
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

/// Machine-readable form of one assessment (deterministic serialization;
/// divergent values serialize as `null`).
pub fn assessment_json(a: &Assessment) -> Json {
    Json::obj(vec![
        ("cluster", Json::str(&a.cluster)),
        ("config", Json::str(&a.config_name)),
        ("fabric", Json::str(&a.fabric)),
        ("healthy_ttt_s", Json::num(a.steps.healthy_ttt)),
        ("healthy_step_s", Json::num(a.steps.healthy_step)),
        ("degrade_source", Json::str(a.degrade_source.name())),
        (
            "degrade_fallback_reason",
            a.degrade_note.as_deref().map_or(Json::Null, Json::str),
        ),
        ("degraded_up_step_ratio", Json::num(a.steps.up_ratio())),
        ("degraded_out_step_ratio", Json::num(a.steps.out_ratio())),
        ("effective_ttt_s", num_or_null(a.expected.effective_ttt)),
        ("availability", Json::num(a.expected.availability)),
        ("checkpoint_interval_s", num_or_null(a.expected.checkpoint_interval_s)),
        ("expected_slowdown", Json::num(a.expected.expected_slowdown)),
        ("degraded_fraction_up", Json::num(a.expected.degraded_fraction_up)),
        ("degraded_fraction_out", Json::num(a.expected.degraded_fraction_out)),
        ("tray_mtbf_h", num_or_null(a.expected.tray_mtbf_h)),
        ("tray_events_per_year", Json::num(a.tray_per_year)),
        ("link_mtbf_h", Json::num(a.link_mtbf_h)),
        (
            "mc",
            Json::obj(vec![
                ("trials", Json::num(a.trials as f64)),
                ("mean_ttt_s", num_or_null(a.mc_mean_ttt)),
                ("min_ttt_s", num_or_null(a.mc_min_ttt)),
                ("max_ttt_s", num_or_null(a.mc_max_ttt)),
            ]),
        ),
    ])
}

/// Machine-readable form of the paired headline run
/// (`lumos resilience --json`).
pub fn paired_json(rows: &[PairedRow], seed: u64, trials: usize) -> Json {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("config", Json::num(r.config as f64)),
                ("passage", assessment_json(&r.passage)),
                ("electrical", assessment_json(&r.electrical)),
                ("healthy_speedup", Json::num(r.healthy_speedup())),
                ("adjusted_speedup", num_or_null(r.adjusted_speedup())),
            ])
        })
        .collect();
    let all = rows.iter().flat_map(|r| [&r.passage, &r.electrical]);
    Json::obj(vec![
        ("seed", Json::num(seed as f64)),
        ("trials", Json::num(trials as f64)),
        (
            "degrade_source",
            Json::str(degrade_summary(rows.iter().flat_map(|r| [&r.passage, &r.electrical]))),
        ),
        ("metrics", assessment_metrics(all).to_json()),
        ("rows", Json::Arr(rows_json)),
    ])
}

/// Machine-readable form of a per-cluster assessment run
/// (`lumos resilience --cluster ... --json`): the seed, trial pool size,
/// degrade summary and `"metrics"` alongside the rows — previously the
/// CLI emitted a bare row array that dropped everything the table header
/// reports.
pub fn assessments_json(rows: &[Assessment], seed: u64, trials: usize) -> Json {
    Json::obj(vec![
        ("seed", Json::num(seed as f64)),
        ("trials", Json::num(trials as f64)),
        ("degrade_source", Json::str(degrade_summary(rows.iter()))),
        ("metrics", assessment_metrics(rows.iter()).to_json()),
        ("rows", Json::Arr(rows.iter().map(assessment_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_rates_compose_into_cluster_rates() {
        let fab = FabricReliability::passage();
        // 72 external-laser links (2150 field FIT each) + 2 pluggables
        // (4100 field FIT each) per GPU at 32k GPUs ≈ 5.3 field events/h.
        let up = fab.field_rate_up_per_hour(32_768);
        let out = fab.field_rate_out_per_hour(32_768);
        assert!((up - 5.07).abs() < 0.02, "{up}");
        assert!((out - 0.269).abs() < 0.01, "{out}");
        // tray events stay rare: co-packaged PIC+SerDes only.
        let tray = fab.tray_rate_per_hour(32_768);
        assert!((tray - 0.0728).abs() < 0.001, "{tray}");
        // integrated lasers make trays ~65x more frequent
        let cpo = FabricReliability::cpo_integrated().tray_rate_per_hour(32_768);
        assert!(cpo > 60.0 * tray, "{cpo} vs {tray}");
    }

    #[test]
    fn default_mapping_covers_paper_and_pod_scales() {
        let w = Workload::paper_gpt_4p7t(4);
        let paper = default_mapping(&w, &Cluster::passage_512(32_768)).unwrap();
        assert_eq!(paper.par, Parallelism::paper());
        let pod = default_mapping(&w, &Cluster::custom(512, 512, 32_000.0)).unwrap();
        assert_eq!((pod.par.tp, pod.par.pp, pod.par.dp), (16, 1, 32));
        assert!(default_mapping(&w, &Cluster::custom(24, 8, 32_000.0)).is_err());
    }

    #[test]
    fn assessment_is_byte_identical_across_job_counts() {
        let knobs = PerfKnobs::default();
        let cache = ClusterCache::new();
        // analytical degraded steps: the jobs-determinism contract is about
        // the Monte Carlo pool, and the analytical mode keeps this test
        // cheap (the simulated mode is deterministic serial code either
        // way — pinned by the golden suite)
        let spec = ResilienceSpec {
            trials: 32,
            degrade: DegradeSource::Analytical,
            ..ResilienceSpec::default()
        };
        let serial = paper_pairs(&[4], &knobs, &spec, 1, &cache);
        let parallel = paper_pairs(&[4], &knobs, &spec, 4, &cache);
        assert_eq!(
            speedup_table(&serial).render(),
            speedup_table(&parallel).render()
        );
        assert_eq!(
            serial[0].passage.mc_mean_ttt.to_bits(),
            parallel[0].passage.mc_mean_ttt.to_bits()
        );
        assert_eq!(
            paired_json(&serial, 7, 32).to_string_pretty(),
            paired_json(&parallel, 7, 32).to_string_pretty()
        );
    }

    #[test]
    fn artifacts_render() {
        let knobs = PerfKnobs::default();
        let cache = ClusterCache::new();
        let spec = ResilienceSpec {
            trials: 0,
            degrade: DegradeSource::Analytical,
            ..ResilienceSpec::default()
        };
        let rows = paper_pairs(&[1, 4], &knobs, &spec, 1, &cache);
        let r = speedup_table(&rows).render();
        assert!(r.contains("adjusted speedup"), "{r}");
        assert!(r.contains("Config 4"), "{r}");
        // pod assessments run the default simulated degrade path (small
        // pp=1 slice DAGs, cheap) — the rendered artifacts carry the source
        let pods = pod_serviceability(
            &knobs,
            &ResilienceSpec { trials: 0, ..ResilienceSpec::default() },
            1,
            &cache,
        );
        let s = serviceability_table(&pods).render();
        assert!(s.contains("CPO (integrated laser)"), "{s}");
        assert!(s.contains("tray events/yr"), "{s}");
        let a = assessment_table(&pods).render();
        assert!(a.contains("mc mean"), "{a}");
        assert!(a.contains("simulated degraded steps"), "{a}");
        let j = assessment_json(&pods[0]).to_string_pretty();
        assert!(j.contains("\"effective_ttt_s\""), "{j}");
        assert!(j.contains("\"degrade_source\""), "{j}");
        // the paired and per-cluster JSON artifacts carry what the table
        // headers report: trials, degrade summary, and run metrics
        let p = paired_json(&rows, 7, 0);
        assert_eq!(p.get("trials").as_f64(), Some(0.0));
        assert_eq!(p.get("degrade_source").as_str(), Some("analytical"));
        assert_eq!(p.get("metrics").get("assessments").as_f64(), Some(4.0));
        let c = assessments_json(&pods, 7, 0);
        assert_eq!(c.get("degrade_source").as_str(), Some("simulated"));
        assert_eq!(c.get("metrics").get("degrade_fallbacks").as_f64(), None);
        assert_eq!(c.get("metrics").get("degrade_simulated").as_f64(), Some(3.0));
        assert_eq!(c.get("rows").as_arr().map(|r| r.len()), Some(3));
    }

    #[test]
    fn simulated_degrade_falls_back_when_the_point_cannot_simulate() {
        use crate::model::MoeConfig;
        let knobs = PerfKnobs::default();
        let cluster = Cluster::passage_512(32_768);
        // a lowering even the lifted DAG cap rejects: assess must fall
        // back to analytical pricing and record that it did
        let huge = Mapping::try_with_microbatch(
            Parallelism { tp: 64, pp: 120, dp: 32 },
            MoeConfig::paper_config(4),
            1,
        )
        .unwrap();
        let w = Workload::paper_gpt_4p7t(4);
        let spec = ResilienceSpec { trials: 0, ..ResilienceSpec::default() };
        assert_eq!(spec.degrade, DegradeSource::Simulated);
        let a = assess(&w, &cluster, &huge, &knobs, &FabricReliability::passage(), &spec, 1);
        assert_eq!(a.degrade_source, DegradeSource::Analytical);
        // the fallback carries its reason — never silent
        let note = a.degrade_note.as_deref().unwrap_or("");
        assert!(note.contains("too large"), "{note}");
        let j = assessment_json(&a).to_string_pretty();
        assert!(j.contains("\"degrade_fallback_reason\""), "{j}");
        assert!(a.expected.effective_ttt > a.steps.healthy_ttt);
    }
}
