//! Lowering a failure into a degraded fabric and re-pricing the training
//! step on it — the fail-in-place half of the resilience loop.
//!
//! Two consistent views of the same degradation:
//!
//! - **Analytical** ([`analytical_degraded_steps`]): every collective in
//!   the perf model is barrier-synchronous, so a group containing one GPU
//!   that lost a fraction `f` of a domain's lanes finishes at that slowest
//!   member's rate — pricing the step on a cluster whose domain bandwidth
//!   is scaled by `(1 - f)` ([`degraded_cluster`]) is exact for the
//!   ring/all-to-all schedules the model costs. This is the cheap path the
//!   goodput engine and the planner's availability objective evaluate per
//!   mapping.
//! - **Simulated** ([`simulate_degraded_step`]): the [`crate::timeline`]
//!   task DAG re-executed on a slice [`crate::netsim::Network`] with the
//!   victim GPU's link capacity actually removed
//!   ([`crate::netsim::Network::scale_node_links`]). The blast radius
//!   *emerges* from max-min sharing + task barriers instead of being
//!   assumed; `tests/resilience_golden.rs` pins that both views move the
//!   same way.
//!
//! The asymmetry the paper's serviceability argument rides on falls out
//! here: the same failed scale-out pluggable costs the 144-pod electrical
//! fabric its (dominant, spilled) expert all-to-all bandwidth, while on
//! Passage it only touches the mostly-overlapped DP sync and thin PP
//! traffic.

use crate::model::Workload;
use crate::parallel::Mapping;
use crate::perf::{evaluate, PerfKnobs};
use crate::resilience::FabricReliability;
use crate::timeline::{self, TimelineError, TimelineReport};
use crate::topology::cluster::Cluster;

/// Which network domain the failed link belonged to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DegradedMode {
    /// One of the victim GPU's scale-up lanes is out.
    ScaleUpLink,
    /// One of the victim GPU's scale-out NIC pluggables is out.
    ScaleOutLink,
}

/// Clone `cluster` with the affected domain's per-GPU bandwidth scaled by
/// `(1 - lost_fraction)` — the slowest-member rate every barrier
/// collective in the analytical model runs at.
pub fn degraded_cluster(cluster: &Cluster, mode: DegradedMode, lost_fraction: f64) -> Cluster {
    assert!((0.0..=1.0).contains(&lost_fraction), "lost fraction {lost_fraction}");
    let mut spec = cluster.spec.clone();
    match mode {
        DegradedMode::ScaleUpLink => spec.scale_up.gbps_per_gpu *= 1.0 - lost_fraction,
        DegradedMode::ScaleOutLink => spec.scale_out.gbps_per_gpu *= 1.0 - lost_fraction,
    }
    Cluster::new(spec)
}

/// Analytical step times of one (workload, cluster, mapping) point in the
/// healthy state and under a single worst-placed link failure per domain.
#[derive(Debug, Clone)]
pub struct DegradedSteps {
    pub healthy_step: f64,
    pub healthy_ttt: f64,
    /// Step time with one scale-up lane (of `fabric.scale_up_links_per_gpu`)
    /// failed on the slowest GPU.
    pub degraded_up_step: f64,
    /// Step time with one scale-out pluggable (of
    /// `fabric.scale_out_links_per_gpu`) failed on the slowest GPU.
    pub degraded_out_step: f64,
}

impl DegradedSteps {
    /// Degraded-over-healthy step ratio for the scale-up failure (≥ 1).
    pub fn up_ratio(&self) -> f64 {
        self.degraded_up_step / self.healthy_step
    }

    /// Degraded-over-healthy step ratio for the scale-out failure (≥ 1).
    pub fn out_ratio(&self) -> f64 {
        self.degraded_out_step / self.healthy_step
    }
}

/// Evaluate the healthy and single-failure degraded step times with the
/// analytical model (three [`evaluate`] calls). Callers must have passed
/// [`crate::perf::check_feasible`].
pub fn analytical_degraded_steps(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
    fabric: &FabricReliability,
) -> DegradedSteps {
    let healthy = evaluate(w, cluster, map, knobs);
    let up = degraded_cluster(
        cluster,
        DegradedMode::ScaleUpLink,
        1.0 / fabric.scale_up_links_per_gpu as f64,
    );
    let out = degraded_cluster(
        cluster,
        DegradedMode::ScaleOutLink,
        1.0 / fabric.scale_out_links_per_gpu as f64,
    );
    DegradedSteps {
        healthy_step: healthy.step_time,
        healthy_ttt: healthy.time_to_train_s,
        degraded_up_step: evaluate(w, &up, map, knobs).step_time,
        degraded_out_step: evaluate(w, &out, map, knobs).step_time,
    }
}

/// [`DegradedSteps`] with the degradation ratios *measured* on the
/// timeline step DAG instead of assumed by the closed form: the step is
/// simulated healthy and with one victim GPU's links degraded in place
/// ([`simulate_degraded_step`], losing `1/links_per_gpu` of the domain's
/// lanes — the single worst-placed failure the fabric profile implies),
/// and the measured degraded/healthy *ratios* are applied to the
/// analytical healthy step. Anchoring at the analytical healthy step keeps
/// the work target and the healthy TTT identical between the two modes, so
/// feeding these steps into [`crate::resilience::goodput`] changes only
/// the degradation pricing — exactly the quantity the simulator measures
/// better (a single victim's blast radius emerges from max-min sharing and
/// task barriers instead of the slowest-member whole-cluster bound the
/// analytical mode charges).
///
/// Errors when the mapping cannot be simulated (fails
/// [`crate::perf::check_feasible`], or the DAG guard fires);
/// [`crate::resilience::assess`] falls back to
/// [`analytical_degraded_steps`] then and records which source it used.
pub fn simulated_degraded_steps(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
    fabric: &FabricReliability,
) -> Result<DegradedSteps, TimelineError> {
    // clean error instead of the perf model's divisibility asserts
    crate::perf::check_feasible(w, map).map_err(TimelineError::Infeasible)?;
    let healthy_ana = evaluate(w, cluster, map, knobs);
    // one lowering, three fabric states
    let dag = timeline::lower_step(w, cluster, map, knobs).map_err(TimelineError::TooLarge)?;
    let healthy_sim = timeline::simulate_lowered(w, &dag, |_| {});
    let up_lost = 1.0 / fabric.scale_up_links_per_gpu as f64;
    let out_lost = 1.0 / fabric.scale_out_links_per_gpu as f64;
    let up =
        timeline::simulate_lowered(w, &dag, |net| net.scale_node_links(0, 1.0 - up_lost, 1.0));
    let out =
        timeline::simulate_lowered(w, &dag, |net| net.scale_node_links(0, 1.0, 1.0 - out_lost));
    // Degradation can only slow the step; clamp away float noise so the
    // goodput composition never sees a speedup from a failure.
    let ratio = |d: f64| (d / healthy_sim.step_time).max(1.0);
    Ok(DegradedSteps {
        healthy_step: healthy_ana.step_time,
        healthy_ttt: healthy_ana.time_to_train_s,
        degraded_up_step: healthy_ana.step_time * ratio(up.step_time),
        degraded_out_step: healthy_ana.step_time * ratio(out.step_time),
    })
}

/// Re-simulate the full step DAG with the victim GPU's links degraded in
/// place: stage-0 local rank 0 of the [`crate::timeline`] slice loses
/// `lost_fraction` of the chosen domain's capacity.
pub fn simulate_degraded_step(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
    mode: DegradedMode,
    lost_fraction: f64,
) -> Result<TimelineReport, TimelineError> {
    assert!((0.0..=1.0).contains(&lost_fraction), "lost fraction {lost_fraction}");
    let (up_f, nic_f) = match mode {
        DegradedMode::ScaleUpLink => (1.0 - lost_fraction, 1.0),
        DegradedMode::ScaleOutLink => (1.0, 1.0 - lost_fraction),
    };
    timeline::simulate_step_with(w, cluster, map, knobs, |net| {
        net.scale_node_links(0, up_f, nic_f)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MoeConfig;
    use crate::parallel::Parallelism;

    fn point(cfg: usize) -> (Workload, Mapping) {
        let w = Workload::paper_gpt_4p7t(cfg);
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(cfg));
        (w, m)
    }

    #[test]
    fn degradation_never_speeds_the_step_up() {
        let knobs = PerfKnobs::default();
        let fabric = FabricReliability::passage();
        for cluster in [Cluster::passage_512(32_768), Cluster::electrical_144(32_256)] {
            let (w, m) = point(4);
            let s = analytical_degraded_steps(&w, &cluster, &m, &knobs, &fabric);
            assert!(s.up_ratio() >= 1.0 && s.out_ratio() >= 1.0, "{s:?}");
        }
    }

    #[test]
    fn scale_out_failure_hits_the_spilled_fabric_hardest() {
        // The §III.d asymmetry: the same failed NIC pluggable costs the
        // 144-pod electrical fabric its spilled expert all-to-all, while
        // Passage (EP in-pod) barely notices.
        let knobs = PerfKnobs::default();
        let (w, m) = point(4);
        let psg = analytical_degraded_steps(
            &w,
            &Cluster::passage_512(32_768),
            &m,
            &knobs,
            &FabricReliability::passage(),
        );
        let alt = analytical_degraded_steps(
            &w,
            &Cluster::electrical_144(32_256),
            &m,
            &knobs,
            &FabricReliability::electrical(),
        );
        assert!(alt.out_ratio() > 1.3, "{}", alt.out_ratio());
        assert!(psg.out_ratio() < 1.05, "{}", psg.out_ratio());
        assert!(alt.out_ratio() > 10.0 * (psg.out_ratio() - 1.0) + 1.0);
    }

    #[test]
    fn simulated_and_analytical_degradation_move_together() {
        let knobs = PerfKnobs::default();
        let (w, m) = point(4);
        let cluster = Cluster::electrical_144(32_256);
        let healthy = timeline::simulate_step(&w, &cluster, &m, &knobs).unwrap();
        let degraded =
            simulate_degraded_step(&w, &cluster, &m, &knobs, DegradedMode::ScaleOutLink, 0.5)
                .unwrap();
        assert!(degraded.step_time > healthy.step_time);
        let ana = analytical_degraded_steps(
            &w,
            &cluster,
            &m,
            &knobs,
            &FabricReliability::electrical(),
        );
        // both views agree the scale-out failure is a material slowdown
        assert!(degraded.step_time / healthy.step_time > 1.1);
        assert!(ana.out_ratio() > 1.1);
    }

    #[test]
    fn simulated_degraded_steps_keep_the_healthy_anchor() {
        // Measured mode must change only the degradation pricing: healthy
        // step/TTT stay bit-identical to the analytical mode, and the
        // measured degraded steps never undercut the healthy one.
        let knobs = PerfKnobs::default();
        let (w, m) = point(4);
        let cluster = Cluster::passage_512(32_768);
        let fabric = FabricReliability::passage();
        let ana = analytical_degraded_steps(&w, &cluster, &m, &knobs, &fabric);
        let sim = simulated_degraded_steps(&w, &cluster, &m, &knobs, &fabric).unwrap();
        assert_eq!(sim.healthy_step.to_bits(), ana.healthy_step.to_bits());
        assert_eq!(sim.healthy_ttt.to_bits(), ana.healthy_ttt.to_bits());
        assert!(sim.up_ratio() >= 1.0 && sim.out_ratio() >= 1.0, "{sim:?}");
    }

    #[test]
    fn degraded_cluster_scales_only_the_chosen_domain() {
        let c = Cluster::passage_512(32_768);
        let up = degraded_cluster(&c, DegradedMode::ScaleUpLink, 0.25);
        assert!((up.spec.scale_up.gbps_per_gpu - 24_000.0).abs() < 1e-9);
        assert!((up.spec.scale_out.gbps_per_gpu - 1_600.0).abs() < 1e-9);
        let out = degraded_cluster(&c, DegradedMode::ScaleOutLink, 0.5);
        assert!((out.spec.scale_up.gbps_per_gpu - 32_000.0).abs() < 1e-9);
        assert!((out.spec.scale_out.gbps_per_gpu - 800.0).abs() < 1e-9);
    }
}
