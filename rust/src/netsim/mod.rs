//! Flow-level discrete-event network simulator.
//!
//! Validates the Hockney α+β abstraction the paper's performance model
//! rests on (§V.A): collective schedules from [`crate::collectives`] are
//! replayed over an explicit link graph with max-min fair bandwidth
//! sharing, reproducing congestion effects the closed-form model can only
//! approximate — most importantly the derating of dense all-to-all traffic
//! crossing an oversubscribed scale-out fabric (the `a2a_efficiency`
//! parameter of [`crate::topology::cluster::DomainSpec`]).
//!
//! Model: GPUs inject into per-GPU uplinks; an SLS pod's switching core is
//! non-blocking (§II.B — full bisection), so contention appears only at
//! injection/ejection. The scale-out network adds per-pod uplinks with an
//! oversubscription factor, where incast and pod-level aggregation bite.

use std::collections::BTreeMap;

use crate::collectives::CommSchedule;

/// Directed link with finite capacity.
#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// Capacity in bytes/second.
    pub capacity: f64,
}

/// A flow traverses a fixed path of links.
#[derive(Debug, Clone)]
pub struct Flow {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
    pub path: Vec<usize>,
}

/// The link graph + topology metadata.
#[derive(Debug, Clone)]
pub struct Network {
    pub links: Vec<Link>,
    /// GPU count.
    pub n_nodes: usize,
    /// per-node (uplink, downlink) link ids
    up: Vec<usize>,
    down: Vec<usize>,
    /// pod uplink/downlink per pod (empty when single-pod)
    pod_up: Vec<usize>,
    pod_down: Vec<usize>,
    pod_size: usize,
    /// fixed per-flow latency (propagation + software), seconds
    pub base_latency: f64,
}

impl Network {
    /// Non-blocking SLS pod: per-GPU uplink+downlink of `gbps`.
    pub fn sls(n: usize, gbps: f64, latency_s: f64) -> Network {
        let mut links = Vec::with_capacity(2 * n);
        let bps = gbps * 1e9 / 8.0;
        let (mut up, mut down) = (Vec::new(), Vec::new());
        for i in 0..n {
            up.push(links.len());
            links.push(Link { name: format!("gpu{i}-up"), capacity: bps });
            down.push(links.len());
            links.push(Link { name: format!("gpu{i}-down"), capacity: bps });
        }
        Network {
            links,
            n_nodes: n,
            up,
            down,
            pod_up: Vec::new(),
            pod_down: Vec::new(),
            pod_size: n,
            base_latency: latency_s,
        }
    }

    /// Two-level cluster: pods with per-GPU scale-up injection `up_gbps`
    /// plus a scale-out NIC per GPU (`out_gbps`) feeding a per-pod uplink
    /// oversubscribed by `oversub` (≥ 1.0).
    pub fn cluster(
        n: usize,
        pod_size: usize,
        up_gbps: f64,
        out_gbps: f64,
        oversub: f64,
        latency_s: f64,
    ) -> Network {
        assert!(pod_size <= n && oversub >= 1.0);
        let n_pods = n.div_ceil(pod_size);
        let mut links = Vec::new();
        let (mut up, mut down) = (Vec::new(), Vec::new());
        let up_bps = up_gbps * 1e9 / 8.0;
        let out_bps = out_gbps * 1e9 / 8.0;
        for i in 0..n {
            up.push(links.len());
            links.push(Link { name: format!("gpu{i}-up"), capacity: up_bps });
            down.push(links.len());
            links.push(Link { name: format!("gpu{i}-down"), capacity: up_bps });
        }
        let (mut pod_up, mut pod_down) = (Vec::new(), Vec::new());
        for p in 0..n_pods {
            let members = pod_size.min(n - p * pod_size) as f64;
            let cap = members * out_bps / oversub;
            pod_up.push(links.len());
            links.push(Link { name: format!("pod{p}-up"), capacity: cap });
            pod_down.push(links.len());
            links.push(Link { name: format!("pod{p}-down"), capacity: cap });
        }
        Network {
            links,
            n_nodes: n,
            up,
            down,
            pod_up,
            pod_down,
            pod_size,
            base_latency: latency_s,
        }
    }

    fn pod_of(&self, node: usize) -> usize {
        node / self.pod_size
    }

    /// Path for a src→dst transfer. In-pod: up + down. Cross-pod: up,
    /// pod-uplink, remote pod-downlink, down.
    pub fn path(&self, src: usize, dst: usize) -> Vec<usize> {
        assert!(src < self.n_nodes && dst < self.n_nodes && src != dst);
        let (ps, pd) = (self.pod_of(src), self.pod_of(dst));
        if ps == pd {
            vec![self.up[src], self.down[dst]]
        } else {
            vec![self.up[src], self.pod_up[ps], self.pod_down[pd], self.down[dst]]
        }
    }

    pub fn flow(&self, src: usize, dst: usize, bytes: f64) -> Flow {
        Flow { src, dst, bytes, path: self.path(src, dst) }
    }
}

/// Result of simulating a batch of flows.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time of the whole batch, seconds.
    pub makespan: f64,
    /// Completion time per flow.
    pub flow_times: Vec<f64>,
    /// Events processed (for perf accounting).
    pub events: usize,
}

/// Max-min fair progressive-filling fluid simulation: recompute rates at
/// every flow completion. O(completions × links) — fine for collective
/// schedules at pod scale.
pub fn simulate(net: &Network, flows: &[Flow]) -> SimResult {
    #[derive(Clone)]
    struct Active {
        idx: usize,
        remaining: f64,
        rate: f64,
    }
    let mut active: Vec<Active> = flows
        .iter()
        .enumerate()
        .filter(|(_, f)| f.bytes > 0.0)
        .map(|(i, f)| Active { idx: i, remaining: f.bytes, rate: 0.0 })
        .collect();
    let mut flow_times = vec![net.base_latency; flows.len()];
    let mut now = 0.0f64;
    let mut events = 0usize;

    while !active.is_empty() {
        events += 1;
        // --- progressive filling ------------------------------------------
        let mut frozen = vec![false; active.len()];
        let mut link_cap: Vec<f64> = net.links.iter().map(|l| l.capacity).collect();
        let mut link_users: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (ai, a) in active.iter().enumerate() {
            for &l in &flows[a.idx].path {
                link_users.entry(l).or_default().push(ai);
            }
        }
        let mut remaining_users: BTreeMap<usize, usize> =
            link_users.iter().map(|(&l, v)| (l, v.len())).collect();
        let mut unfrozen = active.len();
        while unfrozen > 0 {
            // bottleneck link = min fair share among links with users
            let mut best: Option<(usize, f64)> = None;
            for (&l, &users) in &remaining_users {
                if users == 0 {
                    continue;
                }
                let share = link_cap[l] / users as f64;
                if best.map_or(true, |(_, s)| share < s) {
                    best = Some((l, share));
                }
            }
            let Some((bl, share)) = best else { break };
            // freeze all unfrozen flows through the bottleneck at `share`
            for &ai in &link_users[&bl] {
                if frozen[ai] {
                    continue;
                }
                frozen[ai] = true;
                unfrozen -= 1;
                active[ai].rate = share;
                for &l in &flows[active[ai].idx].path {
                    link_cap[l] -= share;
                    if link_cap[l] < 0.0 {
                        link_cap[l] = 0.0;
                    }
                    *remaining_users.get_mut(&l).unwrap() -= 1;
                }
            }
        }

        // --- advance to next completion -----------------------------------
        let dt = active
            .iter()
            .map(|a| if a.rate > 0.0 { a.remaining / a.rate } else { f64::INFINITY })
            .fold(f64::INFINITY, f64::min);
        assert!(dt.is_finite(), "deadlocked flows (zero rate)");
        now += dt;
        for a in &mut active {
            a.remaining -= a.rate * dt;
        }
        active.retain(|a| {
            if a.remaining <= 1e-9 {
                flow_times[a.idx] = now + net.base_latency;
                false
            } else {
                true
            }
        });
    }

    SimResult { makespan: now + net.base_latency, flow_times, events }
}

/// Replay a collective schedule (step barriers respected) and return the
/// total completion time.
pub fn replay_schedule(net: &Network, sched: &CommSchedule) -> SimResult {
    let mut total = 0.0;
    let mut events = 0;
    let n_steps = sched.n_steps();
    let mut flow_times = Vec::new();
    for step in 0..n_steps {
        let flows: Vec<Flow> = sched
            .ops
            .iter()
            .filter(|o| o.step == step && o.src != o.dst)
            .map(|o| net.flow(o.src, o.dst, o.bytes))
            .collect();
        if flows.is_empty() {
            continue;
        }
        let r = simulate(net, &flows);
        total += r.makespan;
        events += r.events;
        flow_times.extend(r.flow_times.iter().map(|t| t + total));
    }
    SimResult { makespan: total, flow_times, events }
}

/// Measured effective all-to-all efficiency: ideal injection-bandwidth-
/// bound time / simulated time, for a group spanning `span` nodes of a
/// *single-pod* network where each rank contributes `bytes_per_rank`.
/// (For cross-pod traffic the right baseline is the scale-out NIC — see
/// tests/analytical_stack.rs.)
pub fn measure_a2a_efficiency(net: &Network, span: usize, bytes_per_rank: f64) -> f64 {
    assert!(net.pod_up.is_empty(), "single-pod networks only");
    let sched = crate::collectives::pairwise_a2a_schedule(span, bytes_per_rank);
    let sim = replay_schedule(net, &sched);
    // Ideal: every rank streams its payload at full injection bandwidth.
    let inj = net.links[net.up[0]].capacity;
    let ideal = (span as f64 - 1.0) / span as f64 * bytes_per_rank / inj;
    (ideal / sim.makespan).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives as coll;
    use crate::topology::cluster::DomainSpec;

    #[test]
    fn single_flow_is_bandwidth_bound() {
        let net = Network::sls(4, 800.0, 0.0); // 100 GB/s
        let r = simulate(&net, &[net.flow(0, 1, 1e9)]);
        assert!((r.makespan - 0.01).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    fn incast_shares_the_downlink() {
        let net = Network::sls(4, 800.0, 0.0);
        // 3 senders into node 0: downlink is the bottleneck.
        let flows: Vec<Flow> = (1..4).map(|s| net.flow(s, 0, 1e9)).collect();
        let r = simulate(&net, &flows);
        assert!((r.makespan - 0.03).abs() < 1e-6, "{}", r.makespan);
    }

    #[test]
    fn disjoint_flows_run_in_parallel() {
        let net = Network::sls(4, 800.0, 0.0);
        let flows = vec![net.flow(0, 1, 1e9), net.flow(2, 3, 1e9)];
        let r = simulate(&net, &flows);
        assert!((r.makespan - 0.01).abs() < 1e-9);
    }

    #[test]
    fn ring_allreduce_matches_hockney_on_sls() {
        let n = 16;
        let bytes = 64e6;
        let net = Network::sls(n, 800.0, 0.0);
        let sched = coll::ring_all_reduce_schedule(n, bytes);
        let sim = replay_schedule(&net, &sched);
        let dom = DomainSpec {
            name: "t".into(),
            gbps_per_gpu: 800.0,
            latency_s: 0.0,
            a2a_efficiency: 1.0,
        };
        let model = coll::all_reduce_time(&dom, n, bytes);
        let err = (sim.makespan - model).abs() / model;
        assert!(err < 0.02, "sim {} vs model {}", sim.makespan, model);
    }

    #[test]
    fn in_pod_a2a_is_nearly_ideal() {
        let net = Network::sls(32, 800.0, 0.0);
        let eff = measure_a2a_efficiency(&net, 32, 32e6);
        assert!(eff > 0.95, "{eff}");
    }

    #[test]
    fn cross_pod_a2a_is_derated_by_oversubscription() {
        // 4 pods of 8; scale-out NIC 100 Gb/s per GPU, 2:1 oversubscribed.
        let net = Network::cluster(32, 8, 800.0, 100.0, 2.0, 0.0);
        // Uniform a2a across all 32 ranks: 24/31 of traffic crosses pods
        // through uplinks with half the aggregate NIC capacity.
        let sched = coll::pairwise_a2a_schedule(32, 32e6);
        let sim = replay_schedule(&net, &sched);
        // Ideal time if scale-out NICs were uncontended: cross bytes / NIC.
        let cross = 32e6 * 24.0 / 31.0;
        let ideal = cross / (100.0e9 / 8.0);
        let eff = ideal / sim.makespan;
        assert!(eff < 0.75, "efficiency {eff} suspiciously high");
        assert!(eff > 0.3, "efficiency {eff} suspiciously low");
    }

    #[test]
    fn cross_pod_paths_use_pod_links() {
        let net = Network::cluster(16, 8, 800.0, 100.0, 1.0, 0.0);
        let p = net.path(0, 12);
        assert_eq!(p.len(), 4);
        let p2 = net.path(0, 3);
        assert_eq!(p2.len(), 2);
    }

    #[test]
    fn latency_added_per_flow() {
        let net = Network::sls(2, 800.0, 5e-6);
        let r = simulate(&net, &[net.flow(0, 1, 8e5)]);
        // 8e5 B / 100 GB/s = 8 µs + 5 µs latency
        assert!((r.makespan - (8e-6 + 5e-6)).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn zero_capacity_deadlocks_loudly() {
        let mut net = Network::sls(2, 800.0, 0.0);
        net.links[0].capacity = 0.0;
        simulate(&net, &[net.flow(0, 1, 1.0)]);
    }
}
