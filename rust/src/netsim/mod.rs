//! Flow-level discrete-event network simulator.
//!
//! Validates the Hockney α+β abstraction the paper's performance model
//! rests on (§V.A): collective schedules from [`crate::collectives`] are
//! replayed over an explicit link graph with max-min fair bandwidth
//! sharing, reproducing congestion effects the closed-form model can only
//! approximate — most importantly the derating of dense all-to-all traffic
//! crossing an oversubscribed scale-out fabric (the `a2a_efficiency`
//! parameter of [`crate::topology::cluster::DomainSpec`]).
//!
//! Model: GPUs inject into per-GPU uplinks; an SLS pod's switching core is
//! non-blocking (§II.B — full bisection), so contention appears only at
//! injection/ejection. The scale-out network adds per-pod uplinks with an
//! oversubscription factor, where incast and pod-level aggregation bite.
//!
//! # Fast path
//!
//! The production entry points ([`simulate`], [`replay_schedule`]) run an
//! *incremental* progressive-filling engine ([`Simulator`]): on each flow
//! completion only the connected component of flows/links reachable from
//! the completed flows is re-allocated, and all per-link/per-flow buffers
//! are reused across events (and across schedule steps). Max-min fairness
//! decomposes exactly over connected components of the flow–link sharing
//! graph, so this is not an approximation; [`simulate_reference`] keeps
//! the original full-recompute implementation and the property tests in
//! `tests/netsim_prop.rs` assert the two agree to ≤ 1e-9 relative.
//!
//! The dependency-driven engine ([`dep::simulate_dag`], the
//! [`crate::timeline`] substrate) uses the same component-local re-fill on
//! every admit/finish instant (see [`DagSimulator`]), with
//! [`simulate_dag_reference`] as its full-recompute oracle — that is what
//! lifted the `timeline::MAX_DAG_NODES` cap and made step simulation cheap
//! enough for the planner's inner loop.

pub mod dep;

use std::collections::BTreeMap;

use crate::collectives::CommSchedule;

pub use dep::{
    replay_schedule_dependent, schedule_chain_dag, schedule_rank_dag, simulate_dag,
    simulate_dag_observed, simulate_dag_reference, simulate_dag_scan, simulate_dag_stats, DagNode,
    DagResult, DagSimulator, DagWork, DepObserver, DepStats, NoObserver,
};

/// Directed link with finite capacity.
#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// Capacity in bytes/second.
    pub capacity: f64,
}

/// A flow traverses a fixed path of links.
#[derive(Debug, Clone)]
pub struct Flow {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
    pub path: Vec<usize>,
}

/// The link graph + topology metadata.
#[derive(Debug, Clone)]
pub struct Network {
    pub links: Vec<Link>,
    /// GPU count.
    pub n_nodes: usize,
    /// per-node (uplink, downlink) link ids
    up: Vec<usize>,
    down: Vec<usize>,
    /// per-node scale-out NIC link ids (empty unless built by
    /// [`Network::two_level`]); when present, cross-pod paths ride the NICs
    /// instead of the scale-up injection links
    nic_up: Vec<usize>,
    nic_down: Vec<usize>,
    /// pod uplink/downlink per pod (empty when single-pod)
    pod_up: Vec<usize>,
    pod_down: Vec<usize>,
    pod_size: usize,
    /// fixed per-flow latency (propagation + software), seconds
    pub base_latency: f64,
}

impl Network {
    /// Non-blocking SLS pod: per-GPU uplink+downlink of `gbps`.
    pub fn sls(n: usize, gbps: f64, latency_s: f64) -> Network {
        let mut links = Vec::with_capacity(2 * n);
        let bps = gbps * 1e9 / 8.0;
        let (mut up, mut down) = (Vec::new(), Vec::new());
        for i in 0..n {
            up.push(links.len());
            links.push(Link { name: format!("gpu{i}-up"), capacity: bps });
            down.push(links.len());
            links.push(Link { name: format!("gpu{i}-down"), capacity: bps });
        }
        Network {
            links,
            n_nodes: n,
            up,
            down,
            nic_up: Vec::new(),
            nic_down: Vec::new(),
            pod_up: Vec::new(),
            pod_down: Vec::new(),
            pod_size: n,
            base_latency: latency_s,
        }
    }

    /// Two-level cluster: pods with per-GPU scale-up injection `up_gbps`
    /// plus a scale-out NIC per GPU (`out_gbps`) feeding a per-pod uplink
    /// oversubscribed by `oversub` (≥ 1.0).
    pub fn cluster(
        n: usize,
        pod_size: usize,
        up_gbps: f64,
        out_gbps: f64,
        oversub: f64,
        latency_s: f64,
    ) -> Network {
        assert!(pod_size <= n && oversub >= 1.0);
        let n_pods = n.div_ceil(pod_size);
        let mut links = Vec::new();
        let (mut up, mut down) = (Vec::new(), Vec::new());
        let up_bps = up_gbps * 1e9 / 8.0;
        let out_bps = out_gbps * 1e9 / 8.0;
        for i in 0..n {
            up.push(links.len());
            links.push(Link { name: format!("gpu{i}-up"), capacity: up_bps });
            down.push(links.len());
            links.push(Link { name: format!("gpu{i}-down"), capacity: up_bps });
        }
        let (mut pod_up, mut pod_down) = (Vec::new(), Vec::new());
        for p in 0..n_pods {
            let members = pod_size.min(n - p * pod_size) as f64;
            let cap = members * out_bps / oversub;
            pod_up.push(links.len());
            links.push(Link { name: format!("pod{p}-up"), capacity: cap });
            pod_down.push(links.len());
            links.push(Link { name: format!("pod{p}-down"), capacity: cap });
        }
        Network {
            links,
            n_nodes: n,
            up,
            down,
            nic_up: Vec::new(),
            nic_down: Vec::new(),
            pod_up,
            pod_down,
            pod_size,
            base_latency: latency_s,
        }
    }

    /// Two-level cluster with *explicit per-GPU scale-out NICs*: scale-up
    /// injection of `up_gbps` inside a pod, a `nic_gbps` NIC per GPU for
    /// pod-crossing traffic, and per-pod uplinks sized to the members'
    /// aggregate NIC bandwidth (no oversubscription — the NICs are where
    /// sparse cross-pod traffic like pipeline p2p must be rate-limited,
    /// which [`Network::cluster`]'s shared-uplink-only model cannot do).
    /// This is the fabric model [`crate::timeline`] executes on.
    pub fn two_level(
        n: usize,
        pod_size: usize,
        up_gbps: f64,
        nic_gbps: f64,
        latency_s: f64,
    ) -> Network {
        assert!(pod_size > 0 && n > 0);
        let n_pods = n.div_ceil(pod_size);
        let up_bps = up_gbps * 1e9 / 8.0;
        let nic_bps = nic_gbps * 1e9 / 8.0;
        let mut links = Vec::with_capacity(4 * n + 2 * n_pods);
        let (mut up, mut down) = (Vec::new(), Vec::new());
        let (mut nic_up, mut nic_down) = (Vec::new(), Vec::new());
        for i in 0..n {
            up.push(links.len());
            links.push(Link { name: format!("gpu{i}-up"), capacity: up_bps });
            down.push(links.len());
            links.push(Link { name: format!("gpu{i}-down"), capacity: up_bps });
            nic_up.push(links.len());
            links.push(Link { name: format!("gpu{i}-nic-up"), capacity: nic_bps });
            nic_down.push(links.len());
            links.push(Link { name: format!("gpu{i}-nic-down"), capacity: nic_bps });
        }
        let (mut pod_up, mut pod_down) = (Vec::new(), Vec::new());
        for p in 0..n_pods {
            let members = pod_size.min(n - p * pod_size) as f64;
            pod_up.push(links.len());
            links.push(Link { name: format!("pod{p}-up"), capacity: members * nic_bps });
            pod_down.push(links.len());
            links.push(Link { name: format!("pod{p}-down"), capacity: members * nic_bps });
        }
        Network {
            links,
            n_nodes: n,
            up,
            down,
            nic_up,
            nic_down,
            pod_up,
            pod_down,
            pod_size,
            base_latency: latency_s,
        }
    }

    fn pod_of(&self, node: usize) -> usize {
        node / self.pod_size
    }

    /// Path for a src→dst transfer. In-pod: up + down. Cross-pod: up,
    /// pod-uplink, remote pod-downlink, down — via the per-GPU NICs instead
    /// of the scale-up injection links when the network has them
    /// ([`Network::two_level`]).
    pub fn path(&self, src: usize, dst: usize) -> Vec<usize> {
        assert!(src < self.n_nodes && dst < self.n_nodes && src != dst);
        let (ps, pd) = (self.pod_of(src), self.pod_of(dst));
        if ps == pd {
            vec![self.up[src], self.down[dst]]
        } else if self.nic_up.is_empty() {
            vec![self.up[src], self.pod_up[ps], self.pod_down[pd], self.down[dst]]
        } else {
            vec![self.nic_up[src], self.pod_up[ps], self.pod_down[pd], self.nic_down[dst]]
        }
    }

    pub fn flow(&self, src: usize, dst: usize, bytes: f64) -> Flow {
        Flow { src, dst, bytes, path: self.path(src, dst) }
    }

    /// Fail-in-place degradation of one GPU's injection capacity: scale its
    /// scale-up up/down links by `up_factor` and (when the network has
    /// per-GPU NICs, [`Network::two_level`]) its NIC links by `nic_factor`.
    /// A failed lane out of `k` parallel lanes is `factor = 1 - 1/k`; a dead
    /// link is `0.0`. The [`crate::resilience`] degraded re-simulation and
    /// the degraded-fabric bench series build on this.
    pub fn scale_node_links(&mut self, node: usize, up_factor: f64, nic_factor: f64) {
        assert!(node < self.n_nodes, "node {node} out of range");
        assert!(up_factor >= 0.0 && nic_factor >= 0.0, "negative capacity factor");
        self.links[self.up[node]].capacity *= up_factor;
        self.links[self.down[node]].capacity *= up_factor;
        if !self.nic_up.is_empty() {
            self.links[self.nic_up[node]].capacity *= nic_factor;
            self.links[self.nic_down[node]].capacity *= nic_factor;
        }
    }
}

/// Result of simulating a batch of flows.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time of the whole batch, seconds.
    pub makespan: f64,
    /// Completion time per flow.
    pub flow_times: Vec<f64>,
    /// Events processed (for perf accounting).
    pub events: usize,
}

// ---------------------------------------------------------------------------
// Incremental max-min engine (the production fast path)
// ---------------------------------------------------------------------------

/// Reusable max-min fluid simulation state.
///
/// All per-flow and per-link buffers live here and are recycled across
/// completion events and across [`Simulator::simulate`] calls (the replay
/// loop runs one `Simulator` over every step of a schedule), so the steady
/// state of a replay allocates nothing per event.
///
/// Invariants maintained between events (asserted by the property tests):
/// - `rate` holds the exact max-min fair allocation of the current active
///   set: the sum of rates over any link never exceeds its capacity, and
///   every flow is bottlenecked on at least one saturated link.
/// - On a completion, only the connected component (flows ↔ shared links)
///   containing the completed flows is re-filled; max-min decomposes over
///   components, so untouched flows keep exact rates.
#[derive(Debug, Default)]
pub struct Simulator {
    // indexed by flow id
    remaining: Vec<f64>,
    rate: Vec<f64>,
    frozen: Vec<bool>,
    in_set: Vec<bool>,
    /// alive flow ids, in original flow order
    active: Vec<usize>,
    // indexed by link id
    link_flows: Vec<Vec<usize>>,
    link_cap: Vec<f64>,
    link_users: Vec<usize>,
    link_in_set: Vec<bool>,
    // scratch work lists
    set_flows: Vec<usize>,
    set_links: Vec<usize>,
    link_stack: Vec<usize>,
    completed: Vec<usize>,
}

impl Simulator {
    pub fn new() -> Simulator {
        Simulator::default()
    }

    fn reset(&mut self, net: &Network, flows: &[Flow]) {
        let nf = flows.len();
        let nl = net.links.len();
        self.remaining.clear();
        self.remaining.extend(flows.iter().map(|f| f.bytes));
        self.rate.clear();
        self.rate.resize(nf, 0.0);
        self.frozen.clear();
        self.frozen.resize(nf, false);
        self.in_set.clear();
        self.in_set.resize(nf, false);
        self.active.clear();
        for v in &mut self.link_flows {
            v.clear();
        }
        if self.link_flows.len() < nl {
            self.link_flows.resize_with(nl, Vec::new);
        }
        self.link_cap.clear();
        self.link_cap.resize(nl, 0.0);
        self.link_users.clear();
        self.link_users.resize(nl, 0);
        self.link_in_set.clear();
        self.link_in_set.resize(nl, false);
        self.set_flows.clear();
        self.set_links.clear();
        self.link_stack.clear();
        self.completed.clear();
        for (i, f) in flows.iter().enumerate() {
            if f.bytes > 0.0 {
                self.active.push(i);
                for &l in &f.path {
                    self.link_flows[l].push(i);
                }
            }
        }
    }

    /// Progressive filling restricted to `set_flows` / `set_links`.
    ///
    /// Preconditions: `set_links` covers every link on every set flow's
    /// path, `link_in_set[l]` is true exactly for set links (cleared here),
    /// and every alive user of a set link is a set flow (the component
    /// closure). Bottleneck ties break toward the lowest link id, matching
    /// [`simulate_reference`]'s `BTreeMap` iteration order.
    fn fill(&mut self, net: &Network, flows: &[Flow]) {
        self.set_links.sort_unstable();
        for &l in &self.set_links {
            self.link_cap[l] = net.links[l].capacity;
            self.link_users[l] = self.link_flows[l].len();
            self.link_in_set[l] = false;
        }
        for &fi in &self.set_flows {
            self.frozen[fi] = false;
        }
        let mut unfrozen = self.set_flows.len();
        while unfrozen > 0 {
            // bottleneck link = min fair share among set links with users
            let mut best: Option<(usize, f64)> = None;
            for &l in &self.set_links {
                let users = self.link_users[l];
                if users == 0 {
                    continue;
                }
                let share = self.link_cap[l] / users as f64;
                let better = match best {
                    None => true,
                    Some((_, s)) => share < s,
                };
                if better {
                    best = Some((l, share));
                }
            }
            let Some((bl, share)) = best else { break };
            // freeze all unfrozen flows through the bottleneck at `share`
            for &fi in &self.link_flows[bl] {
                if self.frozen[fi] {
                    continue;
                }
                self.frozen[fi] = true;
                unfrozen -= 1;
                self.rate[fi] = share;
                for &l in &flows[fi].path {
                    let c = self.link_cap[l] - share;
                    self.link_cap[l] = if c < 0.0 { 0.0 } else { c };
                    self.link_users[l] -= 1;
                }
            }
        }
    }

    /// Seed the fill set with every alive flow (initial allocation).
    fn seed_all(&mut self, flows: &[Flow]) {
        self.set_flows.clear();
        self.set_links.clear();
        for &fi in &self.active {
            self.set_flows.push(fi);
            for &l in &flows[fi].path {
                if !self.link_in_set[l] {
                    self.link_in_set[l] = true;
                    self.set_links.push(l);
                }
            }
        }
    }

    /// Remove completed flows from the link adjacency and collect the
    /// connected component(s) they belonged to into `set_flows`/`set_links`
    /// (transitive closure over shared links).
    fn seed_component_of_completed(&mut self, flows: &[Flow]) {
        self.set_flows.clear();
        self.set_links.clear();
        self.link_stack.clear();
        for &fi in &self.completed {
            for &l in &flows[fi].path {
                if let Some(pos) = self.link_flows[l].iter().position(|&x| x == fi) {
                    // ordered remove keeps link user lists in flow order
                    self.link_flows[l].remove(pos);
                }
                if !self.link_in_set[l] {
                    self.link_in_set[l] = true;
                    self.set_links.push(l);
                    self.link_stack.push(l);
                }
            }
        }
        while let Some(l) = self.link_stack.pop() {
            for &fi in &self.link_flows[l] {
                if self.in_set[fi] {
                    continue;
                }
                self.in_set[fi] = true;
                self.set_flows.push(fi);
                for &l2 in &flows[fi].path {
                    if !self.link_in_set[l2] {
                        self.link_in_set[l2] = true;
                        self.set_links.push(l2);
                        self.link_stack.push(l2);
                    }
                }
            }
        }
        for &fi in &self.set_flows {
            self.in_set[fi] = false;
        }
    }

    /// Run the fluid simulation for one batch of flows.
    pub fn simulate(&mut self, net: &Network, flows: &[Flow]) -> SimResult {
        self.reset(net, flows);
        let mut flow_times = vec![net.base_latency; flows.len()];
        let mut now = 0.0f64;
        let mut events = 0usize;

        self.seed_all(flows);
        self.fill(net, flows);

        while !self.active.is_empty() {
            events += 1;
            // --- advance to next completion -------------------------------
            let mut dt = f64::INFINITY;
            for &fi in &self.active {
                if self.rate[fi] > 0.0 {
                    let t = self.remaining[fi] / self.rate[fi];
                    if t < dt {
                        dt = t;
                    }
                }
            }
            assert!(dt.is_finite(), "deadlocked flows (zero rate)");
            now += dt;
            self.completed.clear();
            let mut w = 0;
            for r in 0..self.active.len() {
                let fi = self.active[r];
                self.remaining[fi] -= self.rate[fi] * dt;
                if self.remaining[fi] <= 1e-9 {
                    flow_times[fi] = now + net.base_latency;
                    self.completed.push(fi);
                } else {
                    self.active[w] = fi;
                    w += 1;
                }
            }
            self.active.truncate(w);
            if self.active.is_empty() {
                break;
            }
            // --- re-allocate only the affected component ------------------
            self.seed_component_of_completed(flows);
            self.fill(net, flows);
        }

        SimResult { makespan: now + net.base_latency, flow_times, events }
    }

    /// The instantaneous max-min fair allocation (bytes/s per flow) of a
    /// flow batch before anything completes. Zero-byte flows get rate 0.
    pub fn fair_rates(&mut self, net: &Network, flows: &[Flow]) -> Vec<f64> {
        self.reset(net, flows);
        self.seed_all(flows);
        self.fill(net, flows);
        self.rate[..flows.len()].to_vec()
    }
}

/// Max-min fair progressive-filling fluid simulation (incremental engine;
/// see [`Simulator`]). One-shot convenience wrapper.
pub fn simulate(net: &Network, flows: &[Flow]) -> SimResult {
    Simulator::new().simulate(net, flows)
}

/// Instantaneous max-min allocation — see [`Simulator::fair_rates`].
pub fn fair_rates(net: &Network, flows: &[Flow]) -> Vec<f64> {
    Simulator::new().fair_rates(net, flows)
}

// ---------------------------------------------------------------------------
// Reference implementation (full recompute per completion)
// ---------------------------------------------------------------------------

/// The original O(completions × links) implementation: every completion
/// rebuilds the whole allocation from scratch. Kept as the oracle for the
/// incremental engine (property tests assert agreement ≤ 1e-9 relative)
/// and for before/after benchmarking in `benches/bench_netsim.rs`.
pub fn simulate_reference(net: &Network, flows: &[Flow]) -> SimResult {
    #[derive(Clone)]
    struct Active {
        idx: usize,
        remaining: f64,
        rate: f64,
    }
    let mut active: Vec<Active> = flows
        .iter()
        .enumerate()
        .filter(|(_, f)| f.bytes > 0.0)
        .map(|(i, f)| Active { idx: i, remaining: f.bytes, rate: 0.0 })
        .collect();
    let mut flow_times = vec![net.base_latency; flows.len()];
    let mut now = 0.0f64;
    let mut events = 0usize;

    while !active.is_empty() {
        events += 1;
        // --- progressive filling ------------------------------------------
        let mut frozen = vec![false; active.len()];
        let mut link_cap: Vec<f64> = net.links.iter().map(|l| l.capacity).collect();
        let mut link_users: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (ai, a) in active.iter().enumerate() {
            for &l in &flows[a.idx].path {
                link_users.entry(l).or_default().push(ai);
            }
        }
        let mut remaining_users: BTreeMap<usize, usize> =
            link_users.iter().map(|(&l, v)| (l, v.len())).collect();
        let mut unfrozen = active.len();
        while unfrozen > 0 {
            // bottleneck link = min fair share among links with users
            let mut best: Option<(usize, f64)> = None;
            for (&l, &users) in &remaining_users {
                if users == 0 {
                    continue;
                }
                let share = link_cap[l] / users as f64;
                let better = match best {
                    None => true,
                    Some((_, s)) => share < s,
                };
                if better {
                    best = Some((l, share));
                }
            }
            let Some((bl, share)) = best else { break };
            // freeze all unfrozen flows through the bottleneck at `share`
            for &ai in &link_users[&bl] {
                if frozen[ai] {
                    continue;
                }
                frozen[ai] = true;
                unfrozen -= 1;
                active[ai].rate = share;
                for &l in &flows[active[ai].idx].path {
                    link_cap[l] -= share;
                    if link_cap[l] < 0.0 {
                        link_cap[l] = 0.0;
                    }
                    // lumos: allow(panic-path) -- every active flow's path links are keys by construction
                    *remaining_users.get_mut(&l).unwrap() -= 1;
                }
            }
        }

        // --- advance to next completion -----------------------------------
        let dt = active
            .iter()
            .map(|a| if a.rate > 0.0 { a.remaining / a.rate } else { f64::INFINITY })
            .fold(f64::INFINITY, f64::min);
        assert!(dt.is_finite(), "deadlocked flows (zero rate)");
        now += dt;
        for a in &mut active {
            a.remaining -= a.rate * dt;
        }
        active.retain(|a| {
            if a.remaining <= 1e-9 {
                flow_times[a.idx] = now + net.base_latency;
                false
            } else {
                true
            }
        });
    }

    SimResult { makespan: now + net.base_latency, flow_times, events }
}

// ---------------------------------------------------------------------------
// Schedule replay
// ---------------------------------------------------------------------------

/// Replay a collective schedule (step barriers respected) and return the
/// total completion time. One [`Simulator`] is reused across steps, so the
/// per-event buffers are allocated once per replay.
pub fn replay_schedule(net: &Network, sched: &CommSchedule) -> SimResult {
    let mut sim = Simulator::new();
    let mut total = 0.0;
    let mut events = 0;
    let n_steps = sched.n_steps();
    let mut flow_times = Vec::new();
    for step in 0..n_steps {
        let flows: Vec<Flow> = sched
            .ops
            .iter()
            .filter(|o| o.step == step && o.src != o.dst)
            .map(|o| net.flow(o.src, o.dst, o.bytes))
            .collect();
        if flows.is_empty() {
            continue;
        }
        let r = sim.simulate(net, &flows);
        // per-flow completion times are relative to the *start* of this
        // step: offset by the pre-step total, not the post-step one
        let step_start = total;
        total += r.makespan;
        events += r.events;
        flow_times.extend(r.flow_times.iter().map(|t| t + step_start));
    }
    SimResult { makespan: total, flow_times, events }
}

/// Measured effective all-to-all efficiency: ideal injection-bandwidth-
/// bound time / simulated time, for a group spanning `span` nodes of a
/// *single-pod* network where each rank contributes `bytes_per_rank`.
/// (For cross-pod traffic the right baseline is the scale-out NIC — see
/// tests/analytical_stack.rs.)
pub fn measure_a2a_efficiency(net: &Network, span: usize, bytes_per_rank: f64) -> f64 {
    assert!(net.pod_up.is_empty(), "single-pod networks only");
    let sched = crate::collectives::pairwise_a2a_schedule(span, bytes_per_rank);
    let sim = replay_schedule(net, &sched);
    // Ideal: every rank streams its payload at full injection bandwidth.
    let inj = net.links[net.up[0]].capacity;
    let ideal = (span as f64 - 1.0) / span as f64 * bytes_per_rank / inj;
    (ideal / sim.makespan).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives as coll;
    use crate::topology::cluster::DomainSpec;

    #[test]
    fn single_flow_is_bandwidth_bound() {
        let net = Network::sls(4, 800.0, 0.0); // 100 GB/s
        let r = simulate(&net, &[net.flow(0, 1, 1e9)]);
        assert!((r.makespan - 0.01).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    fn degraded_node_slows_only_flows_through_it() {
        let mut net = Network::sls(4, 800.0, 0.0);
        net.scale_node_links(0, 0.5, 1.0); // node 0 loses half its lanes
        let r = simulate(&net, &[net.flow(0, 1, 1e9), net.flow(2, 3, 1e9)]);
        assert!((r.flow_times[0] - 0.02).abs() < 1e-9, "{}", r.flow_times[0]);
        assert!((r.flow_times[1] - 0.01).abs() < 1e-9, "{}", r.flow_times[1]);
        // NIC factor is a no-op on single-level networks; on two-level it
        // scales the NIC pair.
        let mut two = Network::two_level(16, 8, 800.0, 100.0, 0.0);
        two.scale_node_links(0, 1.0, 0.5);
        let slow = simulate(&two, &[two.flow(0, 12, 1e8)]);
        let fast = simulate(&two, &[two.flow(1, 12, 1e8)]);
        assert!(slow.makespan > 1.9 * fast.makespan, "{} vs {}", slow.makespan, fast.makespan);
    }

    #[test]
    fn incast_shares_the_downlink() {
        let net = Network::sls(4, 800.0, 0.0);
        // 3 senders into node 0: downlink is the bottleneck.
        let flows: Vec<Flow> = (1..4).map(|s| net.flow(s, 0, 1e9)).collect();
        let r = simulate(&net, &flows);
        assert!((r.makespan - 0.03).abs() < 1e-6, "{}", r.makespan);
    }

    #[test]
    fn disjoint_flows_run_in_parallel() {
        let net = Network::sls(4, 800.0, 0.0);
        let flows = vec![net.flow(0, 1, 1e9), net.flow(2, 3, 1e9)];
        let r = simulate(&net, &flows);
        assert!((r.makespan - 0.01).abs() < 1e-9);
    }

    #[test]
    fn ring_allreduce_matches_hockney_on_sls() {
        let n = 16;
        let bytes = 64e6;
        let net = Network::sls(n, 800.0, 0.0);
        let sched = coll::ring_all_reduce_schedule(n, bytes);
        let sim = replay_schedule(&net, &sched);
        let dom = DomainSpec {
            name: "t".into(),
            gbps_per_gpu: 800.0,
            latency_s: 0.0,
            a2a_efficiency: 1.0,
        };
        let model = coll::all_reduce_time(&dom, n, bytes);
        let err = (sim.makespan - model).abs() / model;
        assert!(err < 0.02, "sim {} vs model {}", sim.makespan, model);
    }

    #[test]
    fn in_pod_a2a_is_nearly_ideal() {
        let net = Network::sls(32, 800.0, 0.0);
        let eff = measure_a2a_efficiency(&net, 32, 32e6);
        assert!(eff > 0.95, "{eff}");
    }

    #[test]
    fn cross_pod_a2a_is_derated_by_oversubscription() {
        // 4 pods of 8; scale-out NIC 100 Gb/s per GPU, 2:1 oversubscribed.
        let net = Network::cluster(32, 8, 800.0, 100.0, 2.0, 0.0);
        // Uniform a2a across all 32 ranks: 24/31 of traffic crosses pods
        // through uplinks with half the aggregate NIC capacity.
        let sched = coll::pairwise_a2a_schedule(32, 32e6);
        let sim = replay_schedule(&net, &sched);
        // Ideal time if scale-out NICs were uncontended: cross bytes / NIC.
        let cross = 32e6 * 24.0 / 31.0;
        let ideal = cross / (100.0e9 / 8.0);
        let eff = ideal / sim.makespan;
        assert!(eff < 0.75, "efficiency {eff} suspiciously high");
        assert!(eff > 0.3, "efficiency {eff} suspiciously low");
    }

    #[test]
    fn cross_pod_paths_use_pod_links() {
        let net = Network::cluster(16, 8, 800.0, 100.0, 1.0, 0.0);
        let p = net.path(0, 12);
        assert_eq!(p.len(), 4);
        let p2 = net.path(0, 3);
        assert_eq!(p2.len(), 2);
    }

    #[test]
    fn two_level_cross_pod_rides_the_nics() {
        let net = Network::two_level(16, 8, 800.0, 100.0, 0.0);
        // in-pod: scale-up rate (100 GB/s)
        let r = simulate(&net, &[net.flow(0, 1, 1e9)]);
        assert!((r.makespan - 0.01).abs() < 1e-9, "{}", r.makespan);
        // cross-pod: a single flow is NIC-bound (12.5 GB/s), not
        // pod-uplink-bound (the uplink has the members' aggregate capacity)
        let r = simulate(&net, &[net.flow(0, 12, 1e9)]);
        assert!((r.makespan - 0.08).abs() < 1e-9, "{}", r.makespan);
        let p = net.path(0, 12);
        assert_eq!(p.len(), 4);
        assert!(net.links[p[0]].name.contains("nic"), "{}", net.links[p[0]].name);
    }

    #[test]
    fn latency_added_per_flow() {
        let net = Network::sls(2, 800.0, 5e-6);
        let r = simulate(&net, &[net.flow(0, 1, 8e5)]);
        // 8e5 B / 100 GB/s = 8 µs + 5 µs latency
        assert!((r.makespan - (8e-6 + 5e-6)).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn zero_capacity_deadlocks_loudly() {
        let mut net = Network::sls(2, 800.0, 0.0);
        net.links[0].capacity = 0.0;
        simulate(&net, &[net.flow(0, 1, 1.0)]);
    }

    // --------------------------------------------------- incremental engine

    /// Uneven flow sizes over shared links force staggered completions, so
    /// the incremental path has to re-fill components repeatedly.
    fn staggered_case() -> (Network, Vec<Flow>) {
        let net = Network::cluster(16, 4, 800.0, 100.0, 2.0, 0.0);
        let mut flows = Vec::new();
        for s in 0..16usize {
            for d in 0..16usize {
                if s != d {
                    flows.push(net.flow(s, d, 1e6 * (1 + (s * 7 + d * 3) % 11) as f64));
                }
            }
        }
        (net, flows)
    }

    #[test]
    fn incremental_matches_reference_on_staggered_mesh() {
        let (net, flows) = staggered_case();
        let fast = simulate(&net, &flows);
        let slow = simulate_reference(&net, &flows);
        let rel = (fast.makespan - slow.makespan).abs() / slow.makespan;
        assert!(rel <= 1e-9, "makespan {} vs {}", fast.makespan, slow.makespan);
        assert_eq!(fast.flow_times.len(), slow.flow_times.len());
        for (i, (a, b)) in fast.flow_times.iter().zip(&slow.flow_times).enumerate() {
            assert!((a - b).abs() <= 1e-9 * b.max(1e-30), "flow {i}: {a} vs {b}");
        }
        assert!(fast.events > 0 && slow.events > 0);
    }

    #[test]
    fn simulator_reuse_is_stateless_across_batches() {
        let (net, flows) = staggered_case();
        let mut sim = Simulator::new();
        let first = sim.simulate(&net, &flows);
        // run an unrelated batch in between to dirty the buffers
        let small = Network::sls(4, 800.0, 0.0);
        sim.simulate(&small, &[small.flow(0, 1, 1e9)]);
        let second = sim.simulate(&net, &flows);
        assert_eq!(first.makespan, second.makespan);
        assert_eq!(first.flow_times, second.flow_times);
    }

    #[test]
    fn fair_rates_respect_capacity_and_saturate_bottleneck() {
        let net = Network::sls(4, 800.0, 0.0);
        let flows: Vec<Flow> = (1..4).map(|s| net.flow(s, 0, 1e9)).collect();
        let rates = fair_rates(&net, &flows);
        let down0 = net.links[net.down[0]].capacity;
        let sum: f64 = rates.iter().sum();
        assert!(sum <= down0 * (1.0 + 1e-12));
        assert!((sum - down0).abs() < 1e-6 * down0, "bottleneck not saturated");
    }

    // ------------------------------------------------------ replay offsets

    #[test]
    fn replayed_flow_times_never_exceed_makespan() {
        // Regression: per-flow completion times used to be offset by the
        // *post*-step running total, double-counting each step's makespan.
        let net = Network::sls(8, 800.0, 1e-6);
        let sched = coll::ring_all_reduce_schedule(8, 64e6);
        let r = replay_schedule(&net, &sched);
        assert!(!r.flow_times.is_empty());
        for (i, &t) in r.flow_times.iter().enumerate() {
            assert!(t <= r.makespan + 1e-12, "flow {i}: {t} > makespan {}", r.makespan);
            assert!(t > 0.0);
        }
        // the last step's flows must finish exactly at the makespan
        let last_max = r.flow_times.iter().cloned().fold(0.0f64, f64::max);
        assert!((last_max - r.makespan).abs() < 1e-12, "{last_max} vs {}", r.makespan);
    }
}
