//! Dependency-driven discrete-event engine: flows (and fixed-duration
//! delays) are admitted the moment their predecessors finish, instead of at
//! bulk-synchronous step barriers.
//!
//! This is the execution substrate of [`crate::timeline`]: a training step
//! lowers to a DAG of compute [`DagWork::Delay`]s and communication
//! [`DagWork::Flow`]s, and compute/comm overlap *emerges* from the
//! dependency structure rather than from an overlap knob. It also gives the
//! schedule replayer step-level pipelining ([`replay_schedule_dependent`]):
//! a rank starts its next-step transfer as soon as *its own* current-step
//! transfers finish, so steps with disjoint flows overlap.
//!
//! Semantics (kept aligned with the bulk-synchronous oracle):
//!
//! - A node is *ready* when every dependency has finished; ready flows join
//!   the max-min fair fluid allocation immediately.
//! - A flow finishes `base_latency` after its last byte (implemented as a
//!   completion pseudo-delay), exactly like [`super::simulate`]'s per-flow
//!   `+ base_latency`.
//! - With full step barriers as dependencies ([`schedule_chain_dag`] — the
//!   degenerate chain case) the engine reproduces [`super::replay_schedule`]
//!   to ≤ 1e-9 relative; `tests/netsim_prop.rs` pins this property.
//!
//! # Incremental fast path
//!
//! The production entry point ([`simulate_dag`], backed by the reusable
//! [`DagSimulator`]) is *component-incremental*, the same idea as
//! [`super::Simulator`]: on each admit/finish instant only the connected
//! component of links/flows whose bottleneck set could have changed is
//! re-filled (max-min fairness decomposes exactly over connected components
//! of the flow–link sharing graph, so untouched flows keep exact rates),
//! all per-node/per-link buffers are reused across events, and exact-tie
//! batching collapses the symmetric rounds DAG workloads produce (hundreds
//! of bit-equal per-GPU links) into one pass. This is what lifted
//! `timeline::MAX_DAG_NODES` out of the planner's way — deep-PP ×
//! fine-microbatch step DAGs keep thousands of flows concurrently active,
//! and a full per-event recompute made them impractical to simulate.
//!
//! # Lazy completion-time heap
//!
//! The incremental re-fill left one O(active) cost per event: the dt scan
//! over every active flow and delay to find the next completion. The
//! production loop ([`DagSimulator::simulate`]) replaces it with a
//! predicted-completion min-heap that is invalidated *lazily*: each entry
//! carries a per-node generation counter, a flow's entry is re-predicted
//! only when the re-fill changes its rate (bit-exact comparison — the
//! component re-fill already guarantees untouched flows keep identical
//! rates), and stale entries are discarded when popped. Delay-only events —
//! the overwhelming majority in timeline DAGs — now cost O(log active)
//! instead of O(active). The eager dt-scan loop is kept verbatim as
//! [`DagSimulator::simulate_scan`] (the PR 5 baseline) for benchmarking and
//! cross-checking; `benches/bench_netsim.rs` records heap-vs-scan series.
//!
//! [`simulate_dag_reference`] keeps the original full-recompute
//! implementation as the oracle: `tests/netsim_prop.rs` asserts the two
//! agree to ≤ 1e-9 relative on randomized DAGs, and
//! `benches/bench_netsim.rs` records the before/after series
//! (`BENCH_netsim.json`).

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BinaryHeap};

use crate::collectives::CommSchedule;

use super::Network;

/// What a DAG node does once admitted.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DagWork {
    /// Local work of fixed duration (compute, software latency). Occupies
    /// no links.
    Delay(f64),
    /// A network transfer along `Network::path(src, dst)`.
    Flow { src: usize, dst: usize, bytes: f64 },
}

/// One node of a task DAG. Dependencies must point at earlier nodes (the
/// builder emits nodes in a topological order), which rules out cycles.
#[derive(Debug, Clone, PartialEq)]
pub struct DagNode {
    pub work: DagWork,
    pub deps: Vec<usize>,
}

impl DagNode {
    pub fn delay(duration_s: f64, deps: Vec<usize>) -> DagNode {
        DagNode { work: DagWork::Delay(duration_s), deps }
    }

    pub fn flow(src: usize, dst: usize, bytes: f64, deps: Vec<usize>) -> DagNode {
        DagNode { work: DagWork::Flow { src, dst, bytes }, deps }
    }
}

/// Result of executing a DAG.
#[derive(Debug, Clone)]
pub struct DagResult {
    /// Completion time of the last node, seconds.
    pub makespan: f64,
    /// Per-node finish time (latency included for flows).
    pub finish: Vec<f64>,
    /// Fluid events processed (completions/admissions batched per instant).
    pub events: usize,
}

/// Deterministic work counters for one engine run — how much admission,
/// component re-fill, and lazy-heap maintenance a simulation actually did.
///
/// Every field is an order-independent `u64` tally of the *serial* event
/// loop, so sums over simulations merged in a fixed (index) order are
/// byte-stable across `--jobs N`; this is what the `"metrics"` key of the
/// JSON outputs aggregates. Reset at the start of every run; read back via
/// [`DagSimulator::stats`] or the [`simulate_dag_stats`] /
/// [`simulate_dag_observed`] wrappers.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DepStats {
    /// Flows admitted into the fluid network (zero-byte/self flows that
    /// degenerate to latency pseudo-delays are not counted).
    pub admitted_flows: u64,
    /// Positive-duration delays admitted (compute, software latency).
    pub admitted_delays: u64,
    /// Component re-fills (one per event instant with dirty links).
    pub refills: u64,
    /// Total flows touched across all re-fills (component sizes summed).
    pub refill_flows: u64,
    /// Largest single re-fill component, in flows.
    pub refill_flows_max: u64,
    /// Lazy-heap settlements: flows whose rate changed in a re-fill and
    /// had their completion prediction re-aimed.
    pub settlements: u64,
    /// Superseded heap entries discarded on pop (generation mismatch).
    pub stale_pops: u64,
}

/// Hooks into the dependency engine's event loop, for tracing.
///
/// Every method defaults to a no-op, so [`NoObserver`] monomorphizes the
/// production loop to exactly the un-instrumented code. Times are
/// *simulated* seconds and node ids are DAG indices — everything an
/// observer sees is deterministic and independent of `--jobs`.
pub trait DepObserver {
    /// When true, the engine computes the mean utilization of the links it
    /// just re-filled (one extra pass over the component's links) before
    /// each [`DepObserver::refill`] call. Off by default so observers that
    /// ignore utilization keep the hot path free of the cost.
    const UTILIZATION: bool = false;

    /// A flow joined the max-min allocation at `now`.
    fn flow_admitted(&mut self, _node: usize, _now: f64) {}
    /// A re-fill changed the flow's rate at `now`; `rate` is the new one.
    fn flow_settled(&mut self, _node: usize, _now: f64, _rate: f64) {}
    /// The flow's last byte completed at `now` (latency tail may follow).
    fn flow_finished(&mut self, _node: usize, _now: f64) {}
    /// A component re-fill finished at `now`. `active_flows` counts all
    /// in-flight flows, `touched_links` the re-filled component's links,
    /// and `mean_util` their mean utilization (0.0 unless
    /// [`DepObserver::UTILIZATION`]).
    fn refill(&mut self, _now: f64, _active_flows: usize, _touched_links: usize, _mean_util: f64) {}
}

/// The default do-nothing observer: [`DagSimulator::simulate`] with
/// `NoObserver` compiles to the un-instrumented production loop.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoObserver;

impl DepObserver for NoObserver {}

// ---------------------------------------------------------------------------
// Incremental engine (the production fast path)
// ---------------------------------------------------------------------------

/// One predicted completion in the lazy min-heap. `gen` must match the
/// node's current generation for the entry to be live; settlement (a rate
/// change in the re-fill) and completion both bump the generation, so every
/// superseded entry is discarded the moment it surfaces. Ordering is
/// (time, node, gen) under `total_cmp`, so pop order is deterministic even
/// across exact completion-time ties.
#[derive(Debug, Clone, Copy)]
struct HeapEntry {
    /// Predicted completion instant, seconds.
    time: f64,
    node: usize,
    gen: u32,
    /// True for timed work (`remaining` counts seconds: delays, latency
    /// tails of finished flows); false for byte-counted flows.
    timed: bool,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for HeapEntry {}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.node.cmp(&other.node))
            .then(self.gen.cmp(&other.gen))
    }
}

/// Reusable incremental DAG simulation state.
///
/// All per-node and per-link buffers live here and are recycled across
/// events and across [`DagSimulator::simulate`] calls, so the steady state
/// of a simulation allocates almost nothing per event (only flow paths).
///
/// Invariants maintained between events (asserted by the property tests
/// through the oracle comparison):
/// - `rate` holds the exact max-min fair allocation of the current active
///   flow set: the sum of rates over any link never exceeds its capacity,
///   and every flow is bottlenecked on at least one saturated link.
/// - Every admit/finish marks the links it touched *dirty*; before the
///   clock advances, only the connected component(s) (flows ↔ shared
///   links) reachable from dirty links are re-filled. Max-min decomposes
///   over components, so untouched flows keep exact rates.
/// - Bottleneck rounds freeze every link whose fair share ties the
///   bottleneck *exactly* (bit-equal); max-min is unique, so batching the
///   tie is equivalent to the reference's one-link-per-round order but
///   collapses symmetric rounds into one pass.
#[derive(Debug, Default)]
pub struct DagSimulator {
    // per-node state
    indeg: Vec<usize>,
    succ: Vec<Vec<usize>>,
    remaining: Vec<f64>,
    rate: Vec<f64>,
    frozen: Vec<bool>,
    in_set: Vec<bool>,
    paths: Vec<Vec<usize>>,
    finish: Vec<f64>,
    ready: Vec<usize>,
    active_flows: Vec<usize>,
    active_delays: Vec<usize>,
    // per-link state
    link_flows: Vec<Vec<usize>>,
    link_cap: Vec<f64>,
    link_users: Vec<usize>,
    link_in_set: Vec<bool>,
    link_dirty: Vec<bool>,
    // scratch work lists
    dirty_links: Vec<usize>,
    set_flows: Vec<usize>,
    set_links: Vec<usize>,
    link_stack: Vec<usize>,
    tied: Vec<usize>,
    born: Vec<usize>,
    // lazy completion-time heap (see module docs §Lazy completion heap):
    // `remaining[i]` is valid as of `upd[i]`; `gen[i]` invalidates
    // superseded heap entries without touching the heap.
    upd: Vec<f64>,
    gen: Vec<u32>,
    heap: BinaryHeap<Reverse<HeapEntry>>,
    // deterministic work counters for the current run (see [`DepStats`])
    stats: DepStats,
}

impl DagSimulator {
    pub fn new() -> DagSimulator {
        DagSimulator::default()
    }

    fn reset(&mut self, net: &Network, nodes: &[DagNode]) {
        let n = nodes.len();
        let nl = net.links.len();
        // One deep-PP simulation can grow the reusable buffers to millions
        // of entries; don't let that peak stay resident for the rest of
        // the thread's life once the workload shrinks back down. The
        // per-node/per-link vectors only ever grow, so their lengths track
        // the largest run so far — release everything when the new run is
        // far smaller than a large high-water mark (steady-state reuse at
        // similar sizes is untouched).
        const SHRINK_ABOVE: usize = 1 << 18;
        if (self.succ.len() > SHRINK_ABOVE && n < self.succ.len() / 4)
            || (self.link_flows.len() > SHRINK_ABOVE && nl < self.link_flows.len() / 4)
        {
            *self = DagSimulator::default();
        }
        self.indeg.clear();
        self.indeg.resize(n, 0);
        for v in &mut self.succ {
            v.clear();
        }
        if self.succ.len() < n {
            self.succ.resize_with(n, Vec::new);
        }
        self.remaining.clear();
        self.remaining.extend(nodes.iter().map(|nd| match nd.work {
            DagWork::Delay(d) => d,
            DagWork::Flow { bytes, .. } => bytes,
        }));
        self.rate.clear();
        self.rate.resize(n, 0.0);
        self.frozen.clear();
        self.frozen.resize(n, false);
        self.in_set.clear();
        self.in_set.resize(n, false);
        for v in &mut self.paths {
            v.clear();
        }
        if self.paths.len() < n {
            self.paths.resize_with(n, Vec::new);
        }
        self.finish.clear();
        self.finish.resize(n, 0.0);
        self.ready.clear();
        self.active_flows.clear();
        self.active_delays.clear();
        for v in &mut self.link_flows {
            v.clear();
        }
        if self.link_flows.len() < nl {
            self.link_flows.resize_with(nl, Vec::new);
        }
        self.link_cap.clear();
        self.link_cap.resize(nl, 0.0);
        self.link_users.clear();
        self.link_users.resize(nl, 0);
        self.link_in_set.clear();
        self.link_in_set.resize(nl, false);
        self.link_dirty.clear();
        self.link_dirty.resize(nl, false);
        self.dirty_links.clear();
        self.set_flows.clear();
        self.set_links.clear();
        self.link_stack.clear();
        self.tied.clear();
        self.born.clear();
        self.upd.clear();
        self.upd.resize(n, 0.0);
        self.gen.clear();
        self.gen.resize(n, 0);
        self.heap.clear();
        self.stats = DepStats::default();
        for (i, node) in nodes.iter().enumerate() {
            self.indeg[i] = node.deps.len();
            for &d in &node.deps {
                assert!(
                    d < i,
                    "node {i} depends on later/own node {d}: emit in topological order"
                );
                self.succ[d].push(i);
            }
            if node.deps.is_empty() {
                self.ready.push(i);
            }
        }
    }

    /// Collect the connected component(s) reachable from the dirty links
    /// into `set_flows`/`set_links` (transitive closure over shared links).
    fn seed_dirty_component(&mut self) {
        self.set_flows.clear();
        self.set_links.clear();
        self.link_stack.clear();
        for &l in &self.dirty_links {
            self.link_dirty[l] = false;
            if !self.link_in_set[l] {
                self.link_in_set[l] = true;
                self.set_links.push(l);
                self.link_stack.push(l);
            }
        }
        self.dirty_links.clear();
        while let Some(l) = self.link_stack.pop() {
            // the closure walk reads `link_flows`/`paths` and writes the
            // disjoint set/stack fields, so plain iteration borrows fine
            for &fi in &self.link_flows[l] {
                if self.in_set[fi] {
                    continue;
                }
                self.in_set[fi] = true;
                self.set_flows.push(fi);
                for &l2 in &self.paths[fi] {
                    if !self.link_in_set[l2] {
                        self.link_in_set[l2] = true;
                        self.set_links.push(l2);
                        self.link_stack.push(l2);
                    }
                }
            }
        }
        for &fi in &self.set_flows {
            self.in_set[fi] = false;
        }
    }

    /// Progressive filling restricted to `set_flows` / `set_links`, with
    /// exact-tie batching.
    ///
    /// Preconditions: `set_links` covers every link on every set flow's
    /// path, `link_in_set[l]` is true exactly for set links (cleared here),
    /// and every alive user of a set link is a set flow (the component
    /// closure). Bottleneck candidates are scanned in ascending link id
    /// (matching the reference's `BTreeMap` iteration order), and every
    /// link whose share ties the bottleneck bit-exactly freezes in the same
    /// round — equivalent rates, one pass over symmetric rounds.
    ///
    /// In `lazy` mode (the heap-driven [`DagSimulator::simulate`] loop) a
    /// flow whose new share differs from its old rate is *settled*: its
    /// residual bytes are brought forward to `now` under the old rate, its
    /// generation is bumped (invalidating the old heap entry in place), and
    /// a fresh completion prediction is pushed. Flows whose share comes out
    /// bit-identical keep their old entry — linear extrapolation from
    /// `upd[i]` stays exact under an unchanged rate, so the entry is still
    /// the true completion time and the heap is untouched.
    fn fill<O: DepObserver>(&mut self, net: &Network, now: f64, lazy: bool, obs: &mut O) {
        self.stats.refills += 1;
        let component = self.set_flows.len() as u64;
        self.stats.refill_flows += component;
        if component > self.stats.refill_flows_max {
            self.stats.refill_flows_max = component;
        }
        self.set_links.sort_unstable();
        for &l in &self.set_links {
            self.link_cap[l] = net.links[l].capacity;
            self.link_users[l] = self.link_flows[l].len();
            self.link_in_set[l] = false;
        }
        for &fi in &self.set_flows {
            self.frozen[fi] = false;
        }
        let mut unfrozen = self.set_flows.len();
        while unfrozen > 0 {
            // bottleneck = min fair share among set links with users
            let mut best: Option<f64> = None;
            for &l in &self.set_links {
                let users = self.link_users[l];
                if users == 0 {
                    continue;
                }
                let share = self.link_cap[l] / users as f64;
                let better = match best {
                    None => true,
                    Some(s) => share < s,
                };
                if better {
                    best = Some(share);
                }
            }
            let Some(share) = best else { break };
            // Freeze all flows of every link whose share ties the
            // bottleneck exactly. The tie list is collected before any
            // freezing so float drift inside the round cannot shrink it.
            self.tied.clear();
            for &l in &self.set_links {
                let users = self.link_users[l];
                if users > 0 && self.link_cap[l] / users as f64 == share {
                    self.tied.push(l);
                }
            }
            for ti in 0..self.tied.len() {
                let bl = self.tied[ti];
                for pi in 0..self.link_flows[bl].len() {
                    let fi = self.link_flows[bl][pi];
                    if self.frozen[fi] {
                        continue;
                    }
                    self.frozen[fi] = true;
                    unfrozen -= 1;
                    let old = self.rate[fi];
                    self.rate[fi] = share;
                    if lazy && share != old {
                        // settle the residual bytes at the old rate, then
                        // re-aim the completion entry at the new one
                        self.remaining[fi] -= old * (now - self.upd[fi]);
                        self.upd[fi] = now;
                        self.gen[fi] = self.gen[fi].wrapping_add(1);
                        self.stats.settlements += 1;
                        obs.flow_settled(fi, now, share);
                        if share > 0.0 {
                            self.heap.push(Reverse(HeapEntry {
                                time: now + self.remaining[fi] / share,
                                node: fi,
                                gen: self.gen[fi],
                                timed: false,
                            }));
                        }
                    }
                    for &l in &self.paths[fi] {
                        let c = self.link_cap[l] - share;
                        self.link_cap[l] = if c < 0.0 { 0.0 } else { c };
                        self.link_users[l] -= 1;
                    }
                }
            }
        }
    }

    /// Execute `nodes` on `net`: dependency-driven admission over a max-min
    /// fair fluid network. Panics on an unsatisfiable DAG (forward
    /// dependency) or a zero-rate deadlock, mirroring [`super::simulate`].
    ///
    /// This is the lazy-heap production loop: the next completion comes
    /// from the predicted-completion min-heap (`O(log active)` per event)
    /// instead of [`DagSimulator::simulate_scan`]'s `O(active)` dt scan.
    /// Only flows whose rate changed in the component re-fill touch the
    /// heap; everything else keeps its prediction. Agreement with the
    /// oracle ≤ 1e-9 relative is pinned in `tests/netsim_prop.rs`.
    pub fn simulate(&mut self, net: &Network, nodes: &[DagNode]) -> DagResult {
        self.simulate_with(net, nodes, &mut NoObserver)
    }

    /// [`DagSimulator::simulate`] with tracing hooks: `obs` sees every
    /// flow admission, settlement, and completion plus every component
    /// re-fill, all keyed on simulated time. With [`NoObserver`] the hooks
    /// monomorphize away and this *is* the production loop.
    pub fn simulate_with<O: DepObserver>(
        &mut self,
        net: &Network,
        nodes: &[DagNode],
        obs: &mut O,
    ) -> DagResult {
        self.reset(net, nodes);
        let n = nodes.len();
        let mut now = 0.0f64;
        let mut done = 0usize;
        let mut events = 0usize;
        // live work counts (the heap loop has no active_* vecs to measure)
        let mut live_flows = 0usize;
        let mut live_delays = 0usize;

        // Completion helper: records finish, unlocks successors into ready.
        macro_rules! complete {
            ($i:expr) => {{
                let i = $i;
                self.finish[i] = now;
                done += 1;
                for &s in &self.succ[i] {
                    self.indeg[s] -= 1;
                    if self.indeg[s] == 0 {
                        self.ready.push(s);
                    }
                }
            }};
        }

        loop {
            // Admit everything ready; zero-work nodes complete instantly
            // and may cascade more ready nodes. Admitted delays get their
            // completion entry immediately (it never moves); admitted
            // flows join the link adjacency, mark their links dirty, and
            // get their first entry from the settlement in `fill`.
            while let Some(i) = self.ready.pop() {
                match nodes[i].work {
                    DagWork::Delay(d) => {
                        if d <= 0.0 {
                            complete!(i);
                        } else {
                            self.upd[i] = now;
                            live_delays += 1;
                            self.stats.admitted_delays += 1;
                            self.heap.push(Reverse(HeapEntry {
                                time: now + d,
                                node: i,
                                gen: self.gen[i],
                                timed: true,
                            }));
                        }
                    }
                    DagWork::Flow { src, dst, bytes } => {
                        if bytes <= 0.0 || src == dst {
                            // a zero-byte "flow" still pays the base
                            // latency, matching `simulate`'s per-flow
                            // `+ base_latency`
                            if net.base_latency > 0.0 {
                                self.remaining[i] = net.base_latency;
                                self.upd[i] = now;
                                live_delays += 1;
                                self.heap.push(Reverse(HeapEntry {
                                    time: now + net.base_latency,
                                    node: i,
                                    gen: self.gen[i],
                                    timed: true,
                                }));
                            } else {
                                complete!(i);
                            }
                        } else {
                            let path = net.path(src, dst);
                            for &l in &path {
                                self.link_flows[l].push(i);
                                if !self.link_dirty[l] {
                                    self.link_dirty[l] = true;
                                    self.dirty_links.push(l);
                                }
                            }
                            self.paths[i] = path;
                            self.upd[i] = now;
                            live_flows += 1;
                            self.stats.admitted_flows += 1;
                            obs.flow_admitted(i, now);
                        }
                    }
                }
            }
            if done == n {
                break;
            }
            assert!(
                live_flows > 0 || live_delays > 0,
                "dag deadlocked: {} of {n} nodes stuck",
                n - done
            );
            events += 1;

            // --- re-fill only the component(s) the admits/finishes touched
            if !self.dirty_links.is_empty() {
                self.seed_dirty_component();
                self.fill(net, now, true, obs);
                // after `fill`, `link_cap` holds each set link's residual
                // capacity, so utilization is 1 - residual/capacity
                let mean_util = if O::UTILIZATION && !self.set_links.is_empty() {
                    let mut acc = 0.0;
                    for &l in &self.set_links {
                        acc += 1.0 - self.link_cap[l] / net.links[l].capacity;
                    }
                    acc / self.set_links.len() as f64
                } else {
                    0.0
                };
                obs.refill(now, live_flows, self.set_links.len(), mean_util);
            }

            // --- advance to the next predicted completion ----------------
            let t = loop {
                match self.heap.peek() {
                    Some(&Reverse(e)) if e.gen == self.gen[e.node] => break e.time,
                    Some(_) => {
                        self.heap.pop();
                        self.stats.stale_pops += 1;
                    }
                    // lumos: allow(panic-path) -- zero-rate deadlock, the same contract violation the scan loop's dt assert catches
                    None => panic!("deadlocked flows (zero rate)"),
                }
            };
            if t > now {
                now = t;
            }

            // Batch-complete everything due at `now`, mirroring the scan
            // loop's per-kind tolerances (≤ 1e-9 bytes for flows, ≤ 1e-9 s
            // for timed work). Completed flows leave the link adjacency
            // and mark their links dirty for the next event's re-fill; a
            // flow owing latency becomes a timed entry at `now +
            // base_latency`.
            while let Some(&Reverse(e)) = self.heap.peek() {
                if e.gen != self.gen[e.node] {
                    self.heap.pop();
                    self.stats.stale_pops += 1;
                    continue;
                }
                let i = e.node;
                let rem = if e.timed {
                    self.remaining[i] - (now - self.upd[i])
                } else {
                    self.remaining[i] - self.rate[i] * (now - self.upd[i])
                };
                if rem > 1e-9 {
                    if e.time <= now {
                        // the prediction rounded short of the last byte:
                        // settle and re-aim at the residue (ε-sized, so
                        // the follow-up event lands ~immediately)
                        self.heap.pop();
                        self.remaining[i] = rem;
                        self.upd[i] = now;
                        let again = if e.timed { now + rem } else { now + rem / self.rate[i] };
                        self.heap.push(Reverse(HeapEntry { time: again, ..e }));
                        continue;
                    }
                    break;
                }
                self.heap.pop();
                self.gen[i] = self.gen[i].wrapping_add(1);
                if e.timed {
                    live_delays -= 1;
                    complete!(i);
                } else {
                    live_flows -= 1;
                    obs.flow_finished(i, now);
                    self.rate[i] = 0.0;
                    for &l in &self.paths[i] {
                        if let Some(pos) = self.link_flows[l].iter().position(|&x| x == i) {
                            // ordered remove keeps link user lists in
                            // admission order
                            self.link_flows[l].remove(pos);
                        }
                        if !self.link_dirty[l] {
                            self.link_dirty[l] = true;
                            self.dirty_links.push(l);
                        }
                    }
                    if net.base_latency > 0.0 {
                        self.remaining[i] = net.base_latency;
                        self.upd[i] = now;
                        live_delays += 1;
                        self.heap.push(Reverse(HeapEntry {
                            time: now + net.base_latency,
                            node: i,
                            gen: self.gen[i],
                            timed: true,
                        }));
                    } else {
                        complete!(i);
                    }
                }
            }
        }

        let makespan = self.finish.iter().cloned().fold(0.0f64, f64::max);
        DagResult { makespan, finish: self.finish.clone(), events }
    }

    /// Execute `nodes` with the eager per-event dt scan over all active
    /// work — the PR 5 loop, kept verbatim as the measured baseline for
    /// the lazy heap (`benches/bench_netsim.rs` heap-vs-scan series) and
    /// as a second independent cross-check of [`DagSimulator::simulate`].
    pub fn simulate_scan(&mut self, net: &Network, nodes: &[DagNode]) -> DagResult {
        self.reset(net, nodes);
        let n = nodes.len();
        let mut now = 0.0f64;
        let mut done = 0usize;
        let mut events = 0usize;

        // Completion helper: records finish, unlocks successors into ready.
        macro_rules! complete {
            ($i:expr) => {{
                let i = $i;
                self.finish[i] = now;
                done += 1;
                for &s in &self.succ[i] {
                    self.indeg[s] -= 1;
                    if self.indeg[s] == 0 {
                        self.ready.push(s);
                    }
                }
            }};
        }

        loop {
            // Admit everything ready; zero-work nodes complete instantly
            // and may cascade more ready nodes. Admitted flows join the
            // link adjacency and mark their links dirty.
            while let Some(i) = self.ready.pop() {
                match nodes[i].work {
                    DagWork::Delay(d) => {
                        if d <= 0.0 {
                            complete!(i);
                        } else {
                            self.active_delays.push(i);
                            self.stats.admitted_delays += 1;
                        }
                    }
                    DagWork::Flow { src, dst, bytes } => {
                        if bytes <= 0.0 || src == dst {
                            // a zero-byte "flow" still pays the base
                            // latency, matching `simulate`'s per-flow
                            // `+ base_latency`
                            if net.base_latency > 0.0 {
                                self.remaining[i] = net.base_latency;
                                self.active_delays.push(i);
                            } else {
                                complete!(i);
                            }
                        } else {
                            let path = net.path(src, dst);
                            for &l in &path {
                                self.link_flows[l].push(i);
                                if !self.link_dirty[l] {
                                    self.link_dirty[l] = true;
                                    self.dirty_links.push(l);
                                }
                            }
                            self.paths[i] = path;
                            self.active_flows.push(i);
                            self.stats.admitted_flows += 1;
                        }
                    }
                }
            }
            if done == n {
                break;
            }
            assert!(
                !self.active_flows.is_empty() || !self.active_delays.is_empty(),
                "dag deadlocked: {} of {n} nodes stuck",
                n - done
            );
            events += 1;

            // --- re-fill only the component(s) the admits/finishes touched
            if !self.dirty_links.is_empty() {
                self.seed_dirty_component();
                self.fill(net, now, false, &mut NoObserver);
            }

            // --- advance to the next completion ---------------------------
            let mut dt = f64::INFINITY;
            for &i in &self.active_flows {
                let r = self.rate[i];
                if r > 0.0 {
                    let t = self.remaining[i] / r;
                    if t < dt {
                        dt = t;
                    }
                }
            }
            for &i in &self.active_delays {
                if self.remaining[i] < dt {
                    dt = self.remaining[i];
                }
            }
            assert!(dt.is_finite(), "deadlocked flows (zero rate)");
            now += dt;

            // Flow completions first; a completed flow owing latency
            // becomes a *newborn* delay that must not absorb this event's
            // dt. Completed flows leave the link adjacency and mark their
            // links dirty for the next event's component re-fill.
            self.born.clear();
            let mut w = 0;
            for r in 0..self.active_flows.len() {
                let i = self.active_flows[r];
                self.remaining[i] -= self.rate[i] * dt;
                if self.remaining[i] <= 1e-9 {
                    self.rate[i] = 0.0;
                    for &l in &self.paths[i] {
                        if let Some(pos) = self.link_flows[l].iter().position(|&x| x == i) {
                            // ordered remove keeps link user lists in
                            // admission order
                            self.link_flows[l].remove(pos);
                        }
                        if !self.link_dirty[l] {
                            self.link_dirty[l] = true;
                            self.dirty_links.push(l);
                        }
                    }
                    if net.base_latency > 0.0 {
                        self.remaining[i] = net.base_latency;
                        self.born.push(i);
                    } else {
                        complete!(i);
                    }
                } else {
                    self.active_flows[w] = i;
                    w += 1;
                }
            }
            self.active_flows.truncate(w);
            let mut w = 0;
            for r in 0..self.active_delays.len() {
                let i = self.active_delays[r];
                self.remaining[i] -= dt;
                if self.remaining[i] <= 1e-9 {
                    complete!(i);
                } else {
                    self.active_delays[w] = i;
                    w += 1;
                }
            }
            self.active_delays.truncate(w);
            self.active_delays.extend_from_slice(&self.born);
        }

        let makespan = self.finish.iter().cloned().fold(0.0f64, f64::max);
        DagResult { makespan, finish: self.finish.clone(), events }
    }

    /// Work counters of the most recent run (reset at the start of each).
    pub fn stats(&self) -> DepStats {
        self.stats
    }
}

/// Execute `nodes` on `net` with the incremental engine (see
/// [`DagSimulator`]). Convenience entry point: a thread-local simulator is
/// reused across calls, so repeated callers ([`crate::timeline`] inside
/// `plan --rerank-sim`, `validate --deep`, the resilience degraded
/// re-simulations) get the buffer reuse without threading a simulator
/// through their APIs. Reuse is observationally pure — `reset` rebuilds
/// every per-run field, pinned by the reuse property test in
/// `tests/netsim_prop.rs`.
pub fn simulate_dag(net: &Network, nodes: &[DagNode]) -> DagResult {
    SIM.with(|sim| sim.borrow_mut().simulate(net, nodes))
}

thread_local! {
    /// Shared reusable simulator for [`simulate_dag`] and its stats/
    /// observer variants, so mixed callers on one thread still reuse the
    /// same grown buffers.
    static SIM: std::cell::RefCell<DagSimulator> =
        std::cell::RefCell::new(DagSimulator::new());
}

/// [`simulate_dag`] plus the run's deterministic work counters
/// ([`DepStats`]) — the pair every `"metrics"`-emitting caller wants.
pub fn simulate_dag_stats(net: &Network, nodes: &[DagNode]) -> (DagResult, DepStats) {
    SIM.with(|sim| {
        let mut sim = sim.borrow_mut();
        let result = sim.simulate(net, nodes);
        let stats = sim.stats();
        (result, stats)
    })
}

/// [`simulate_dag`] with tracing hooks: `obs` sees every admission,
/// settlement, completion, and component re-fill on simulated time (see
/// [`DepObserver`]). Returns the run's [`DepStats`] alongside the result.
pub fn simulate_dag_observed<O: DepObserver>(
    net: &Network,
    nodes: &[DagNode],
    obs: &mut O,
) -> (DagResult, DepStats) {
    SIM.with(|sim| {
        let mut sim = sim.borrow_mut();
        let result = sim.simulate_with(net, nodes, obs);
        let stats = sim.stats();
        (result, stats)
    })
}

/// [`simulate_dag`] on the eager dt-scan loop
/// ([`DagSimulator::simulate_scan`], the PR 5 baseline) with the same
/// thread-local buffer reuse, so heap-vs-scan comparisons measure the
/// event loop and not allocator noise.
pub fn simulate_dag_scan(net: &Network, nodes: &[DagNode]) -> DagResult {
    thread_local! {
        static SIM: std::cell::RefCell<DagSimulator> =
            std::cell::RefCell::new(DagSimulator::new());
    }
    SIM.with(|sim| sim.borrow_mut().simulate_scan(net, nodes))
}

// ---------------------------------------------------------------------------
// Reference implementation (full recompute per event)
// ---------------------------------------------------------------------------

/// The original implementation: every admit/finish instant rebuilds the
/// whole max-min allocation from scratch (the shape of
/// [`super::simulate_reference`]). Kept as the oracle for the incremental
/// engine — property tests assert agreement ≤ 1e-9 relative — and for
/// before/after benchmarking in `benches/bench_netsim.rs`.
pub fn simulate_dag_reference(net: &Network, nodes: &[DagNode]) -> DagResult {
    let n = nodes.len();
    let mut indeg = vec![0usize; n];
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in nodes.iter().enumerate() {
        indeg[i] = node.deps.len();
        for &d in &node.deps {
            assert!(d < i, "node {i} depends on later/own node {d}: emit in topological order");
            succ[d].push(i);
        }
    }

    let mut remaining: Vec<f64> = nodes
        .iter()
        .map(|nd| match nd.work {
            DagWork::Delay(d) => d,
            DagWork::Flow { bytes, .. } => bytes,
        })
        .collect();
    let mut paths: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut finish = vec![0.0f64; n];

    let mut active_flows: Vec<usize> = Vec::new();
    let mut active_delays: Vec<usize> = Vec::new();
    // Admission/completion order at one instant never affects the fluid
    // math (rates are recomputed after the ready set fully drains), so the
    // ready stack needs no ordering discipline.
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut now = 0.0f64;
    let mut done = 0usize;
    let mut events = 0usize;

    // Completion helper: records finish, unlocks successors into `ready`.
    macro_rules! complete {
        ($i:expr) => {{
            let i = $i;
            finish[i] = now;
            done += 1;
            for &s in &succ[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(s);
                }
            }
        }};
    }

    loop {
        // Admit everything ready; zero-work nodes complete instantly and
        // may cascade more ready nodes.
        while let Some(i) = ready.pop() {
            match nodes[i].work {
                DagWork::Delay(d) => {
                    if d <= 0.0 {
                        complete!(i);
                    } else {
                        active_delays.push(i);
                    }
                }
                DagWork::Flow { src, dst, bytes } => {
                    if bytes <= 0.0 || src == dst {
                        // a zero-byte "flow" still pays the base latency,
                        // matching `simulate`'s per-flow `+ base_latency`
                        if net.base_latency > 0.0 {
                            remaining[i] = net.base_latency;
                            active_delays.push(i);
                        } else {
                            complete!(i);
                        }
                    } else {
                        paths[i] = net.path(src, dst);
                        active_flows.push(i);
                    }
                }
            }
        }
        if done == n {
            break;
        }
        assert!(
            !active_flows.is_empty() || !active_delays.is_empty(),
            "dag deadlocked: {} of {n} nodes stuck",
            n - done
        );
        events += 1;

        // --- max-min rates over the active flows (full progressive fill,
        // the deterministic shape of `simulate_reference`) ----------------
        let mut rate: BTreeMap<usize, f64> = BTreeMap::new();
        if !active_flows.is_empty() {
            let mut link_users: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for &i in &active_flows {
                for &l in &paths[i] {
                    link_users.entry(l).or_default().push(i);
                }
            }
            let mut link_cap: BTreeMap<usize, f64> =
                link_users.keys().map(|&l| (l, net.links[l].capacity)).collect();
            let mut users: BTreeMap<usize, usize> =
                link_users.iter().map(|(&l, v)| (l, v.len())).collect();
            let mut unfrozen = active_flows.len();
            let mut tied: Vec<usize> = Vec::new();
            while unfrozen > 0 {
                let mut best: Option<f64> = None;
                for (&l, &u) in &users {
                    if u == 0 {
                        continue;
                    }
                    let share = link_cap[&l] / u as f64;
                    let better = match best {
                        None => true,
                        Some(s) => share < s,
                    };
                    if better {
                        best = Some(share);
                    }
                }
                let Some(share) = best else { break };
                // Freeze every link whose share ties the bottleneck
                // *exactly* (bit-equal). Max-min is unique, and freezing a
                // tied link's flows at `share` leaves the other tied
                // links' shares at `share` too, so batching is equivalent
                // to the reference's one-link-per-round order — but
                // collapses the symmetric rounds DAG workloads produce
                // (hundreds of equal per-GPU links) into one pass.
                tied.clear();
                tied.extend(
                    users
                        .iter()
                        .filter(|&(&l, &u)| u > 0 && link_cap[&l] / u as f64 == share)
                        .map(|(&l, _)| l),
                );
                for &bl in &tied {
                    for &fi in &link_users[&bl] {
                        if rate.contains_key(&fi) {
                            continue;
                        }
                        rate.insert(fi, share);
                        unfrozen -= 1;
                        for &l in &paths[fi] {
                            // lumos: allow(panic-path) -- admit() inserted every path link into both maps
                            let c = link_cap.get_mut(&l).unwrap();
                            *c = (*c - share).max(0.0);
                            // lumos: allow(panic-path) -- admit() inserted every path link into both maps
                            *users.get_mut(&l).unwrap() -= 1;
                        }
                    }
                }
            }
        }

        // --- advance to the next completion -------------------------------
        let mut dt = f64::INFINITY;
        for &i in &active_flows {
            if let Some(&r) = rate.get(&i) {
                if r > 0.0 {
                    dt = dt.min(remaining[i] / r);
                }
            }
        }
        for &i in &active_delays {
            dt = dt.min(remaining[i]);
        }
        assert!(dt.is_finite(), "deadlocked flows (zero rate)");
        now += dt;

        // Flow completions first; a completed flow owing latency becomes a
        // *newborn* delay that must not absorb this event's dt.
        let mut born: Vec<usize> = Vec::new();
        let mut w = 0;
        for r in 0..active_flows.len() {
            let i = active_flows[r];
            remaining[i] -= rate.get(&i).copied().unwrap_or(0.0) * dt;
            if remaining[i] <= 1e-9 {
                if net.base_latency > 0.0 {
                    remaining[i] = net.base_latency;
                    born.push(i);
                } else {
                    complete!(i);
                }
            } else {
                active_flows[w] = i;
                w += 1;
            }
        }
        active_flows.truncate(w);
        let mut w = 0;
        for r in 0..active_delays.len() {
            let i = active_delays[r];
            remaining[i] -= dt;
            if remaining[i] <= 1e-9 {
                complete!(i);
            } else {
                active_delays[w] = i;
                w += 1;
            }
        }
        active_delays.truncate(w);
        active_delays.extend(born);
    }

    let makespan = finish.iter().cloned().fold(0.0f64, f64::max);
    DagResult { makespan, finish, events }
}

// ---------------------------------------------------------------------------
// CommSchedule lowerings
// ---------------------------------------------------------------------------

/// Lower a schedule to the *degenerate chain* DAG: every flow of step `s+1`
/// depends on every flow of the previous non-empty step — exactly the bulk-
/// synchronous barrier [`super::replay_schedule`] imposes. Nodes appear in
/// step-major op order (the same order `replay_schedule` reports flow
/// times), so `DagResult::finish` aligns 1:1 with `SimResult::flow_times`.
pub fn schedule_chain_dag(sched: &CommSchedule) -> Vec<DagNode> {
    let mut nodes = Vec::new();
    let mut prev: Vec<usize> = Vec::new();
    for step in 0..sched.n_steps() {
        let mut cur = Vec::new();
        for op in sched.ops.iter().filter(|o| o.step == step && o.src != o.dst) {
            nodes.push(DagNode::flow(op.src, op.dst, op.bytes, prev.clone()));
            cur.push(nodes.len() - 1);
        }
        if !cur.is_empty() {
            prev = cur;
        }
    }
    nodes
}

/// Lower a schedule to the *rank-local* dependency DAG: a flow waits only
/// for the most recent earlier-step flows touching its own src or dst rank.
/// Steps whose flows are disjoint overlap — the schedule-level pipelining
/// the bulk-synchronous replayer cannot express.
///
/// Note that rank-local admission is not universally faster under max-min
/// sharing: an early-admitted flow can contend with a previous step's
/// stragglers. On disjoint-step schedules it is a pure win (pinned by the
/// netsim property tests).
pub fn schedule_rank_dag(sched: &CommSchedule) -> Vec<DagNode> {
    let mut nodes = Vec::new();
    // rank -> node ids of the most recent step that touched it
    let mut last: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for step in 0..sched.n_steps() {
        let mut cur: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for op in sched.ops.iter().filter(|o| o.step == step && o.src != o.dst) {
            let mut deps: Vec<usize> = Vec::new();
            for r in [op.src, op.dst] {
                if let Some(ids) = last.get(&r) {
                    deps.extend(ids.iter().copied());
                }
            }
            deps.sort_unstable();
            deps.dedup();
            nodes.push(DagNode::flow(op.src, op.dst, op.bytes, deps));
            let id = nodes.len() - 1;
            cur.entry(op.src).or_default().push(id);
            cur.entry(op.dst).or_default().push(id);
        }
        for (r, ids) in cur {
            last.insert(r, ids);
        }
    }
    nodes
}

/// Replay `sched` with rank-local dependencies instead of step barriers.
pub fn replay_schedule_dependent(net: &Network, sched: &CommSchedule) -> DagResult {
    simulate_dag(net, &schedule_rank_dag(sched))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives as coll;
    use crate::netsim::replay_schedule;

    #[test]
    fn single_flow_matches_batch_sim() {
        let net = Network::sls(4, 800.0, 5e-6);
        let dag = vec![DagNode::flow(0, 1, 1e9, vec![])];
        let r = simulate_dag(&net, &dag);
        // 1e9 B at 100 GB/s + 5 µs latency
        assert!((r.makespan - (0.01 + 5e-6)).abs() < 1e-12, "{}", r.makespan);
        assert_eq!(r.finish.len(), 1);
    }

    #[test]
    fn chain_dag_equals_bulk_synchronous_replay() {
        for (net, sched) in [
            (Network::sls(8, 800.0, 1e-6), coll::ring_all_reduce_schedule(8, 64e6)),
            (Network::sls(6, 1_600.0, 0.0), coll::pairwise_a2a_schedule(6, 16e6)),
            (
                Network::cluster(12, 4, 800.0, 100.0, 2.0, 5e-6),
                coll::pairwise_a2a_schedule(12, 8e6),
            ),
        ] {
            let bulk = replay_schedule(&net, &sched);
            let dag = simulate_dag(&net, &schedule_chain_dag(&sched));
            let rel = (dag.makespan - bulk.makespan).abs() / bulk.makespan;
            assert!(rel <= 1e-9, "{} vs {}", dag.makespan, bulk.makespan);
            assert_eq!(dag.finish.len(), bulk.flow_times.len());
            for (a, b) in dag.finish.iter().zip(&bulk.flow_times) {
                assert!((a - b).abs() <= 1e-9 * b.max(1e-30), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn incremental_matches_reference_on_staggered_dag() {
        // Uneven flow sizes over shared links with rank-local admission:
        // completions cascade one at a time, admissions land mid-flight, so
        // the incremental path re-fills components repeatedly.
        let net = Network::cluster(16, 4, 800.0, 100.0, 2.0, 5e-6);
        let mut ops = Vec::new();
        for step in 0..6usize {
            for s in 0..16usize {
                let d = (s * 5 + step * 3 + 1) % 16;
                ops.push(coll::CommOp {
                    step,
                    src: s,
                    dst: d,
                    bytes: 1e6 * (1 + (s * 7 + d * 3 + step) % 11) as f64,
                });
            }
        }
        let sched = coll::CommSchedule::new("staggered", 16, ops);
        let dag = schedule_rank_dag(&sched);
        let fast = simulate_dag(&net, &dag);
        let slow = simulate_dag_reference(&net, &dag);
        let rel = (fast.makespan - slow.makespan).abs() / slow.makespan;
        assert!(rel <= 1e-9, "makespan {} vs {}", fast.makespan, slow.makespan);
        for (i, (a, b)) in fast.finish.iter().zip(&slow.finish).enumerate() {
            assert!((a - b).abs() <= 1e-9 * b.max(1e-30), "node {i}: {a} vs {b}");
        }
        assert!(fast.events > 0 && slow.events > 0);
    }

    #[test]
    fn heap_loop_matches_scan_loop_on_staggered_dag() {
        // Same workload as the incremental-vs-reference test: admissions
        // land mid-flight, so rates change repeatedly and the lazy heap
        // must settle/invalidate on every re-fill.
        let net = Network::cluster(16, 4, 800.0, 100.0, 2.0, 5e-6);
        let mut ops = Vec::new();
        for step in 0..6usize {
            for s in 0..16usize {
                let d = (s * 5 + step * 3 + 1) % 16;
                ops.push(coll::CommOp {
                    step,
                    src: s,
                    dst: d,
                    bytes: 1e6 * (1 + (s * 7 + d * 3 + step) % 11) as f64,
                });
            }
        }
        let sched = coll::CommSchedule::new("staggered", 16, ops);
        let dag = schedule_rank_dag(&sched);
        let mut sim = DagSimulator::new();
        let heap = sim.simulate(&net, &dag);
        let scan = sim.simulate_scan(&net, &dag);
        let rel = (heap.makespan - scan.makespan).abs() / scan.makespan;
        assert!(rel <= 1e-9, "makespan {} vs {}", heap.makespan, scan.makespan);
        for (i, (a, b)) in heap.finish.iter().zip(&scan.finish).enumerate() {
            assert!((a - b).abs() <= 1e-9 * b.max(1e-30), "node {i}: {a} vs {b}");
        }
    }

    #[test]
    fn heap_invalidation_tracks_serial_rate_changes() {
        // One long flow whose fair share changes at every event: short
        // flows join and leave its bottleneck link one after another, so
        // the long flow's heap entry is invalidated and re-predicted many
        // times before it finally completes.
        let net = Network::sls(4, 800.0, 0.0);
        let mut dag = vec![DagNode::flow(1, 0, 4e9, vec![])];
        let mut gate: Option<usize> = None;
        for _ in 0..8 {
            let deps = match gate {
                None => vec![],
                Some(g) => vec![g],
            };
            dag.push(DagNode::flow(2, 0, 2e8, deps));
            gate = Some(dag.len() - 1);
        }
        let heap = simulate_dag(&net, &dag);
        let scan = simulate_dag_scan(&net, &dag);
        let reference = simulate_dag_reference(&net, &dag);
        for (i, (a, b)) in heap.finish.iter().zip(&reference.finish).enumerate() {
            assert!((a - b).abs() <= 1e-9 * b.max(1e-30), "node {i}: {a} vs {b}");
        }
        let rel = (heap.makespan - scan.makespan).abs() / scan.makespan;
        assert!(rel <= 1e-9, "{} vs {}", heap.makespan, scan.makespan);
    }

    #[test]
    fn dag_simulator_reuse_is_stateless_across_dags() {
        let net = Network::cluster(12, 4, 800.0, 100.0, 2.0, 5e-6);
        let sched = coll::pairwise_a2a_schedule(12, 8e6);
        let dag = schedule_rank_dag(&sched);
        let mut sim = DagSimulator::new();
        let first = sim.simulate(&net, &dag);
        // a brand-new simulator is the ground truth for "no leaked state"
        let fresh = DagSimulator::new().simulate(&net, &dag);
        assert_eq!(first.makespan, fresh.makespan);
        assert_eq!(first.finish, fresh.finish);
        // run an unrelated DAG in between to dirty the buffers
        let small = Network::sls(4, 800.0, 0.0);
        sim.simulate(&small, &[DagNode::flow(0, 1, 1e9, vec![]), DagNode::delay(1e-3, vec![0])]);
        let second = sim.simulate(&net, &dag);
        assert_eq!(first.makespan, second.makespan);
        assert_eq!(first.finish, second.finish);
    }

    #[test]
    fn stats_count_engine_work_deterministically() {
        let net = Network::cluster(16, 4, 800.0, 100.0, 2.0, 5e-6);
        let mut ops = Vec::new();
        for step in 0..6usize {
            for s in 0..16usize {
                let d = (s * 5 + step * 3 + 1) % 16;
                ops.push(coll::CommOp {
                    step,
                    src: s,
                    dst: d,
                    bytes: 1e6 * (1 + (s * 7 + d * 3 + step) % 11) as f64,
                });
            }
        }
        let sched = coll::CommSchedule::new("staggered", 16, ops);
        let dag = schedule_rank_dag(&sched);
        let (r1, s1) = simulate_dag_stats(&net, &dag);
        let (r2, s2) = simulate_dag_stats(&net, &dag);
        assert_eq!(r1.makespan, r2.makespan, "reused simulator must be pure");
        assert_eq!(s1, s2, "work counters must be run-deterministic");
        // every op is a real flow here, and each gets at least one
        // settlement (its first rate assignment)
        assert_eq!(s1.admitted_flows as usize, dag.len());
        assert_eq!(s1.admitted_delays, 0);
        assert!(s1.refills > 0);
        assert!(s1.settlements >= s1.admitted_flows);
        assert!(s1.refill_flows >= s1.refill_flows_max);
        assert!(s1.refill_flows_max >= 1);
    }

    #[test]
    fn observer_hooks_fire_in_simulated_time_order() {
        #[derive(Default)]
        struct Rec {
            admits: Vec<(usize, f64)>,
            finishes: Vec<(usize, f64)>,
            refill_utils: Vec<f64>,
        }
        impl DepObserver for Rec {
            const UTILIZATION: bool = true;
            fn flow_admitted(&mut self, node: usize, now: f64) {
                self.admits.push((node, now));
            }
            fn flow_finished(&mut self, node: usize, now: f64) {
                self.finishes.push((node, now));
            }
            fn refill(&mut self, _now: f64, _active: usize, links: usize, mean_util: f64) {
                assert!(links > 0, "refill observed with no touched links");
                self.refill_utils.push(mean_util);
            }
        }
        let net = Network::cluster(12, 4, 800.0, 100.0, 2.0, 5e-6);
        let sched = coll::pairwise_a2a_schedule(12, 8e6);
        let dag = schedule_rank_dag(&sched);
        let mut rec = Rec::default();
        let (result, stats) = simulate_dag_observed(&net, &dag, &mut rec);
        let plain = simulate_dag(&net, &dag);
        assert_eq!(result.makespan, plain.makespan, "observer must not perturb the run");
        assert_eq!(rec.admits.len() as u64, stats.admitted_flows);
        assert_eq!(rec.finishes.len() as u64, stats.admitted_flows);
        assert_eq!(rec.refill_utils.len() as u64, stats.refills);
        for w in [&rec.admits, &rec.finishes] {
            assert!(w.windows(2).all(|p| p[0].1 <= p[1].1), "hook times must be non-decreasing");
        }
        assert!(rec.refill_utils.iter().all(|&u| (0.0..=1.0).contains(&u)));
    }

    #[test]
    fn disjoint_steps_overlap_under_rank_deps() {
        // 4 steps that share no ranks: bulk-sync serializes them, the
        // dependency engine runs them all at t=0.
        let net = Network::sls(8, 800.0, 0.0);
        let ops: Vec<coll::CommOp> = (0..4)
            .map(|s| coll::CommOp { step: s, src: 2 * s, dst: 2 * s + 1, bytes: 1e9 })
            .collect();
        let sched = coll::CommSchedule::new("disjoint", 8, ops);
        let bulk = replay_schedule(&net, &sched);
        let dep = replay_schedule_dependent(&net, &sched);
        assert!((bulk.makespan - 0.04).abs() < 1e-9, "{}", bulk.makespan);
        assert!((dep.makespan - 0.01).abs() < 1e-9, "{}", dep.makespan);
    }

    #[test]
    fn delays_chain_and_mix_with_flows() {
        let net = Network::sls(2, 800.0, 0.0);
        // delay 1 ms -> flow 1e9 (10 ms) -> delay 2 ms, vs an independent
        // 5 ms delay: makespan = 13 ms.
        let dag = vec![
            DagNode::delay(1e-3, vec![]),
            DagNode::flow(0, 1, 1e9, vec![0]),
            DagNode::delay(2e-3, vec![1]),
            DagNode::delay(5e-3, vec![]),
        ];
        let r = simulate_dag(&net, &dag);
        assert!((r.makespan - 13e-3).abs() < 1e-12, "{}", r.makespan);
        assert!((r.finish[3] - 5e-3).abs() < 1e-12);
    }

    #[test]
    fn zero_work_nodes_complete_instantly() {
        let net = Network::sls(2, 800.0, 0.0);
        let dag = vec![
            DagNode::delay(0.0, vec![]),
            DagNode::flow(0, 1, 0.0, vec![0]),
            DagNode::delay(1e-3, vec![1]),
        ];
        let r = simulate_dag(&net, &dag);
        assert!((r.makespan - 1e-3).abs() < 1e-12);
        assert_eq!(r.finish[0], 0.0);
        assert_eq!(r.finish[1], 0.0);
    }

    #[test]
    fn contending_admissions_share_links() {
        // Two flows into the same downlink admitted at different times: the
        // second is admitted when the first is half done; they then share.
        let net = Network::sls(4, 800.0, 0.0);
        let dag = vec![
            DagNode::flow(1, 0, 1e9, vec![]),              // starts at 0
            DagNode::delay(0.005, vec![]),                 // gate at 5 ms
            DagNode::flow(2, 0, 1e9, vec![1]),             // joins mid-flight
        ];
        let r = simulate_dag(&net, &dag);
        // flow 0: 5 ms alone (half done) + 10 ms shared = 15 ms.
        assert!((r.finish[0] - 0.015).abs() < 1e-9, "{}", r.finish[0]);
        // flow 2: 10 ms shared + 5 ms alone = ends at 20 ms.
        assert!((r.makespan - 0.020).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    #[should_panic(expected = "topological")]
    fn forward_deps_are_rejected() {
        let net = Network::sls(2, 800.0, 0.0);
        simulate_dag(&net, &[DagNode::delay(1.0, vec![1]), DagNode::delay(1.0, vec![])]);
    }

    #[test]
    #[should_panic(expected = "topological")]
    fn reference_rejects_forward_deps_too() {
        let net = Network::sls(2, 800.0, 0.0);
        simulate_dag_reference(
            &net,
            &[DagNode::delay(1.0, vec![1]), DagNode::delay(1.0, vec![])],
        );
    }
}
