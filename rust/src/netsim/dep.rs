//! Dependency-driven discrete-event engine: flows (and fixed-duration
//! delays) are admitted the moment their predecessors finish, instead of at
//! bulk-synchronous step barriers.
//!
//! This is the execution substrate of [`crate::timeline`]: a training step
//! lowers to a DAG of compute [`DagWork::Delay`]s and communication
//! [`DagWork::Flow`]s, and compute/comm overlap *emerges* from the
//! dependency structure rather than from an overlap knob. It also gives the
//! schedule replayer step-level pipelining ([`replay_schedule_dependent`]):
//! a rank starts its next-step transfer as soon as *its own* current-step
//! transfers finish, so steps with disjoint flows overlap.
//!
//! Semantics (kept aligned with the bulk-synchronous oracle):
//!
//! - A node is *ready* when every dependency has finished; ready flows join
//!   the max-min fair fluid allocation immediately.
//! - A flow finishes `base_latency` after its last byte (implemented as a
//!   completion pseudo-delay), exactly like [`super::simulate`]'s per-flow
//!   `+ base_latency`.
//! - With full step barriers as dependencies ([`schedule_chain_dag`] — the
//!   degenerate chain case) the engine reproduces [`super::replay_schedule`]
//!   to ≤ 1e-9 relative; `tests/netsim_prop.rs` pins this property.
//!
//! The per-event allocation is a full progressive-filling recompute over the
//! active flow set (the shape of [`super::simulate_reference`], which the
//! incremental engine is property-tested against). Timeline DAGs lower
//! collectives to a handful of aggregate flows per task, so active sets stay
//! small and the recompute is not the bottleneck; making this engine
//! component-incremental like [`super::Simulator`] is listed in ROADMAP.

use std::collections::BTreeMap;

use crate::collectives::CommSchedule;

use super::Network;

/// What a DAG node does once admitted.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DagWork {
    /// Local work of fixed duration (compute, software latency). Occupies
    /// no links.
    Delay(f64),
    /// A network transfer along `Network::path(src, dst)`.
    Flow { src: usize, dst: usize, bytes: f64 },
}

/// One node of a task DAG. Dependencies must point at earlier nodes (the
/// builder emits nodes in a topological order), which rules out cycles.
#[derive(Debug, Clone, PartialEq)]
pub struct DagNode {
    pub work: DagWork,
    pub deps: Vec<usize>,
}

impl DagNode {
    pub fn delay(duration_s: f64, deps: Vec<usize>) -> DagNode {
        DagNode { work: DagWork::Delay(duration_s), deps }
    }

    pub fn flow(src: usize, dst: usize, bytes: f64, deps: Vec<usize>) -> DagNode {
        DagNode { work: DagWork::Flow { src, dst, bytes }, deps }
    }
}

/// Result of executing a DAG.
#[derive(Debug, Clone)]
pub struct DagResult {
    /// Completion time of the last node, seconds.
    pub makespan: f64,
    /// Per-node finish time (latency included for flows).
    pub finish: Vec<f64>,
    /// Fluid events processed (completions/admissions batched per instant).
    pub events: usize,
}

/// Execute `nodes` on `net`: dependency-driven admission over a max-min
/// fair fluid network. Panics on an unsatisfiable DAG (forward dependency)
/// or a zero-rate deadlock, mirroring [`super::simulate`].
pub fn simulate_dag(net: &Network, nodes: &[DagNode]) -> DagResult {
    let n = nodes.len();
    let mut indeg = vec![0usize; n];
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in nodes.iter().enumerate() {
        indeg[i] = node.deps.len();
        for &d in &node.deps {
            assert!(d < i, "node {i} depends on later/own node {d}: emit in topological order");
            succ[d].push(i);
        }
    }

    let mut remaining: Vec<f64> = nodes
        .iter()
        .map(|nd| match nd.work {
            DagWork::Delay(d) => d,
            DagWork::Flow { bytes, .. } => bytes,
        })
        .collect();
    let mut paths: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut finish = vec![0.0f64; n];

    let mut active_flows: Vec<usize> = Vec::new();
    let mut active_delays: Vec<usize> = Vec::new();
    // Admission/completion order at one instant never affects the fluid
    // math (rates are recomputed after the ready set fully drains), so the
    // ready stack needs no ordering discipline.
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut now = 0.0f64;
    let mut done = 0usize;
    let mut events = 0usize;

    // Completion helper: records finish, unlocks successors into `ready`.
    macro_rules! complete {
        ($i:expr) => {{
            let i = $i;
            finish[i] = now;
            done += 1;
            for &s in &succ[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(s);
                }
            }
        }};
    }

    loop {
        // Admit everything ready; zero-work nodes complete instantly and
        // may cascade more ready nodes.
        while let Some(i) = ready.pop() {
            match nodes[i].work {
                DagWork::Delay(d) => {
                    if d <= 0.0 {
                        complete!(i);
                    } else {
                        active_delays.push(i);
                    }
                }
                DagWork::Flow { src, dst, bytes } => {
                    if bytes <= 0.0 || src == dst {
                        // a zero-byte "flow" still pays the base latency,
                        // matching `simulate`'s per-flow `+ base_latency`
                        if net.base_latency > 0.0 {
                            remaining[i] = net.base_latency;
                            active_delays.push(i);
                        } else {
                            complete!(i);
                        }
                    } else {
                        paths[i] = net.path(src, dst);
                        active_flows.push(i);
                    }
                }
            }
        }
        if done == n {
            break;
        }
        assert!(
            !active_flows.is_empty() || !active_delays.is_empty(),
            "dag deadlocked: {} of {n} nodes stuck",
            n - done
        );
        events += 1;

        // --- max-min rates over the active flows (full progressive fill,
        // the deterministic shape of `simulate_reference`) ----------------
        let mut rate: BTreeMap<usize, f64> = BTreeMap::new();
        if !active_flows.is_empty() {
            let mut link_users: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for &i in &active_flows {
                for &l in &paths[i] {
                    link_users.entry(l).or_default().push(i);
                }
            }
            let mut link_cap: BTreeMap<usize, f64> =
                link_users.keys().map(|&l| (l, net.links[l].capacity)).collect();
            let mut users: BTreeMap<usize, usize> =
                link_users.iter().map(|(&l, v)| (l, v.len())).collect();
            let mut unfrozen = active_flows.len();
            let mut tied: Vec<usize> = Vec::new();
            while unfrozen > 0 {
                let mut best: Option<f64> = None;
                for (&l, &u) in &users {
                    if u == 0 {
                        continue;
                    }
                    let share = link_cap[&l] / u as f64;
                    let better = match best {
                        None => true,
                        Some(s) => share < s,
                    };
                    if better {
                        best = Some(share);
                    }
                }
                let Some(share) = best else { break };
                // Freeze every link whose share ties the bottleneck
                // *exactly* (bit-equal). Max-min is unique, and freezing a
                // tied link's flows at `share` leaves the other tied
                // links' shares at `share` too, so batching is equivalent
                // to the reference's one-link-per-round order — but
                // collapses the symmetric rounds DAG workloads produce
                // (hundreds of equal per-GPU links) into one pass.
                tied.clear();
                tied.extend(
                    users
                        .iter()
                        .filter(|&(&l, &u)| u > 0 && link_cap[&l] / u as f64 == share)
                        .map(|(&l, _)| l),
                );
                for &bl in &tied {
                    for &fi in &link_users[&bl] {
                        if rate.contains_key(&fi) {
                            continue;
                        }
                        rate.insert(fi, share);
                        unfrozen -= 1;
                        for &l in &paths[fi] {
                            let c = link_cap.get_mut(&l).unwrap();
                            *c = (*c - share).max(0.0);
                            *users.get_mut(&l).unwrap() -= 1;
                        }
                    }
                }
            }
        }

        // --- advance to the next completion -------------------------------
        let mut dt = f64::INFINITY;
        for &i in &active_flows {
            if let Some(&r) = rate.get(&i) {
                if r > 0.0 {
                    dt = dt.min(remaining[i] / r);
                }
            }
        }
        for &i in &active_delays {
            dt = dt.min(remaining[i]);
        }
        assert!(dt.is_finite(), "deadlocked flows (zero rate)");
        now += dt;

        // Flow completions first; a completed flow owing latency becomes a
        // *newborn* delay that must not absorb this event's dt.
        let mut born: Vec<usize> = Vec::new();
        let mut w = 0;
        for r in 0..active_flows.len() {
            let i = active_flows[r];
            remaining[i] -= rate.get(&i).copied().unwrap_or(0.0) * dt;
            if remaining[i] <= 1e-9 {
                if net.base_latency > 0.0 {
                    remaining[i] = net.base_latency;
                    born.push(i);
                } else {
                    complete!(i);
                }
            } else {
                active_flows[w] = i;
                w += 1;
            }
        }
        active_flows.truncate(w);
        let mut w = 0;
        for r in 0..active_delays.len() {
            let i = active_delays[r];
            remaining[i] -= dt;
            if remaining[i] <= 1e-9 {
                complete!(i);
            } else {
                active_delays[w] = i;
                w += 1;
            }
        }
        active_delays.truncate(w);
        active_delays.extend(born);
    }

    let makespan = finish.iter().cloned().fold(0.0f64, f64::max);
    DagResult { makespan, finish, events }
}

// ---------------------------------------------------------------------------
// CommSchedule lowerings
// ---------------------------------------------------------------------------

/// Lower a schedule to the *degenerate chain* DAG: every flow of step `s+1`
/// depends on every flow of the previous non-empty step — exactly the bulk-
/// synchronous barrier [`super::replay_schedule`] imposes. Nodes appear in
/// step-major op order (the same order `replay_schedule` reports flow
/// times), so `DagResult::finish` aligns 1:1 with `SimResult::flow_times`.
pub fn schedule_chain_dag(sched: &CommSchedule) -> Vec<DagNode> {
    let mut nodes = Vec::new();
    let mut prev: Vec<usize> = Vec::new();
    for step in 0..sched.n_steps() {
        let mut cur = Vec::new();
        for op in sched.ops.iter().filter(|o| o.step == step && o.src != o.dst) {
            nodes.push(DagNode::flow(op.src, op.dst, op.bytes, prev.clone()));
            cur.push(nodes.len() - 1);
        }
        if !cur.is_empty() {
            prev = cur;
        }
    }
    nodes
}

/// Lower a schedule to the *rank-local* dependency DAG: a flow waits only
/// for the most recent earlier-step flows touching its own src or dst rank.
/// Steps whose flows are disjoint overlap — the schedule-level pipelining
/// the bulk-synchronous replayer cannot express.
///
/// Note that rank-local admission is not universally faster under max-min
/// sharing: an early-admitted flow can contend with a previous step's
/// stragglers. On disjoint-step schedules it is a pure win (pinned by the
/// netsim property tests).
pub fn schedule_rank_dag(sched: &CommSchedule) -> Vec<DagNode> {
    let mut nodes = Vec::new();
    // rank -> node ids of the most recent step that touched it
    let mut last: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for step in 0..sched.n_steps() {
        let mut cur: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for op in sched.ops.iter().filter(|o| o.step == step && o.src != o.dst) {
            let mut deps: Vec<usize> = Vec::new();
            for r in [op.src, op.dst] {
                if let Some(ids) = last.get(&r) {
                    deps.extend(ids.iter().copied());
                }
            }
            deps.sort_unstable();
            deps.dedup();
            nodes.push(DagNode::flow(op.src, op.dst, op.bytes, deps));
            let id = nodes.len() - 1;
            cur.entry(op.src).or_default().push(id);
            cur.entry(op.dst).or_default().push(id);
        }
        for (r, ids) in cur {
            last.insert(r, ids);
        }
    }
    nodes
}

/// Replay `sched` with rank-local dependencies instead of step barriers.
pub fn replay_schedule_dependent(net: &Network, sched: &CommSchedule) -> DagResult {
    simulate_dag(net, &schedule_rank_dag(sched))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives as coll;
    use crate::netsim::replay_schedule;

    #[test]
    fn single_flow_matches_batch_sim() {
        let net = Network::sls(4, 800.0, 5e-6);
        let dag = vec![DagNode::flow(0, 1, 1e9, vec![])];
        let r = simulate_dag(&net, &dag);
        // 1e9 B at 100 GB/s + 5 µs latency
        assert!((r.makespan - (0.01 + 5e-6)).abs() < 1e-12, "{}", r.makespan);
        assert_eq!(r.finish.len(), 1);
    }

    #[test]
    fn chain_dag_equals_bulk_synchronous_replay() {
        for (net, sched) in [
            (Network::sls(8, 800.0, 1e-6), coll::ring_all_reduce_schedule(8, 64e6)),
            (Network::sls(6, 1_600.0, 0.0), coll::pairwise_a2a_schedule(6, 16e6)),
            (
                Network::cluster(12, 4, 800.0, 100.0, 2.0, 5e-6),
                coll::pairwise_a2a_schedule(12, 8e6),
            ),
        ] {
            let bulk = replay_schedule(&net, &sched);
            let dag = simulate_dag(&net, &schedule_chain_dag(&sched));
            let rel = (dag.makespan - bulk.makespan).abs() / bulk.makespan;
            assert!(rel <= 1e-9, "{} vs {}", dag.makespan, bulk.makespan);
            assert_eq!(dag.finish.len(), bulk.flow_times.len());
            for (a, b) in dag.finish.iter().zip(&bulk.flow_times) {
                assert!((a - b).abs() <= 1e-9 * b.max(1e-30), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn disjoint_steps_overlap_under_rank_deps() {
        // 4 steps that share no ranks: bulk-sync serializes them, the
        // dependency engine runs them all at t=0.
        let net = Network::sls(8, 800.0, 0.0);
        let ops: Vec<coll::CommOp> = (0..4)
            .map(|s| coll::CommOp { step: s, src: 2 * s, dst: 2 * s + 1, bytes: 1e9 })
            .collect();
        let sched = coll::CommSchedule::new("disjoint", 8, ops);
        let bulk = replay_schedule(&net, &sched);
        let dep = replay_schedule_dependent(&net, &sched);
        assert!((bulk.makespan - 0.04).abs() < 1e-9, "{}", bulk.makespan);
        assert!((dep.makespan - 0.01).abs() < 1e-9, "{}", dep.makespan);
    }

    #[test]
    fn delays_chain_and_mix_with_flows() {
        let net = Network::sls(2, 800.0, 0.0);
        // delay 1 ms -> flow 1e9 (10 ms) -> delay 2 ms, vs an independent
        // 5 ms delay: makespan = 13 ms.
        let dag = vec![
            DagNode::delay(1e-3, vec![]),
            DagNode::flow(0, 1, 1e9, vec![0]),
            DagNode::delay(2e-3, vec![1]),
            DagNode::delay(5e-3, vec![]),
        ];
        let r = simulate_dag(&net, &dag);
        assert!((r.makespan - 13e-3).abs() < 1e-12, "{}", r.makespan);
        assert!((r.finish[3] - 5e-3).abs() < 1e-12);
    }

    #[test]
    fn zero_work_nodes_complete_instantly() {
        let net = Network::sls(2, 800.0, 0.0);
        let dag = vec![
            DagNode::delay(0.0, vec![]),
            DagNode::flow(0, 1, 0.0, vec![0]),
            DagNode::delay(1e-3, vec![1]),
        ];
        let r = simulate_dag(&net, &dag);
        assert!((r.makespan - 1e-3).abs() < 1e-12);
        assert_eq!(r.finish[0], 0.0);
        assert_eq!(r.finish[1], 0.0);
    }

    #[test]
    fn contending_admissions_share_links() {
        // Two flows into the same downlink admitted at different times: the
        // second is admitted when the first is half done; they then share.
        let net = Network::sls(4, 800.0, 0.0);
        let dag = vec![
            DagNode::flow(1, 0, 1e9, vec![]),              // starts at 0
            DagNode::delay(0.005, vec![]),                 // gate at 5 ms
            DagNode::flow(2, 0, 1e9, vec![1]),             // joins mid-flight
        ];
        let r = simulate_dag(&net, &dag);
        // flow 0: 5 ms alone (half done) + 10 ms shared = 15 ms.
        assert!((r.finish[0] - 0.015).abs() < 1e-9, "{}", r.finish[0]);
        // flow 2: 10 ms shared + 5 ms alone = ends at 20 ms.
        assert!((r.makespan - 0.020).abs() < 1e-9, "{}", r.makespan);
    }

    #[test]
    #[should_panic(expected = "topological")]
    fn forward_deps_are_rejected() {
        let net = Network::sls(2, 800.0, 0.0);
        simulate_dag(&net, &[DagNode::delay(1.0, vec![1]), DagNode::delay(1.0, vec![])]);
    }
}
