//! Reliability & serviceability model (paper §II.C.3, §III.d).
//!
//! The paper's argument for external lasers: lasers dominate optics failure
//! rates and are temperature-sensitive, so field-replaceable *external*
//! laser modules keep the expensive GPU package serviceable, while
//! in-package lasers (or pluggable modules with integrated lasers) turn a
//! laser failure into a GPU-tray event. This module quantifies that with a
//! standard FIT (failures per 1e9 device-hours) composition.

/// FIT rates for link components (industry-typical orders of magnitude;
/// the *ratios* drive the conclusions, as in the paper's qualitative
/// argument).
#[derive(Debug, Clone)]
pub struct FitRates {
    /// one laser diode
    pub laser: f64,
    /// photonic IC (modulators, waveguides, TIA)
    pub pic: f64,
    /// SerDes/retimer electrical path
    pub electrical: f64,
    /// fiber connector (contamination-driven)
    pub connector: f64,
}

impl Default for FitRates {
    fn default() -> Self {
        // Lasers fail 1-2 orders of magnitude more often than passive
        // photonics or silicon (§II.C.3: "failing at higher rates compared
        // to copper connections").
        FitRates { laser: 500.0, pic: 20.0, electrical: 10.0, connector: 50.0 }
    }
}

/// Where the failing component sits, which determines the blast radius of
/// a replacement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Replaceable {
    /// Swap a pluggable module / external laser unit: minutes, link-local.
    FieldUnit,
    /// Re-seat or replace the GPU tray: hours, takes the GPU out.
    GpuTray,
}

/// A link design point for reliability accounting.
#[derive(Debug, Clone)]
pub struct LinkReliability {
    pub name: &'static str,
    pub lasers_per_link: f64,
    pub laser_location: Replaceable,
    pub connectors_per_link: f64,
    pub fits: FitRates,
}

impl LinkReliability {
    /// Pluggable/LPO module: lasers inside the module (field unit).
    pub fn pluggable(lasers: f64) -> Self {
        LinkReliability {
            name: "pluggable/LPO module",
            lasers_per_link: lasers,
            laser_location: Replaceable::FieldUnit,
            connectors_per_link: 2.0,
            fits: FitRates::default(),
        }
    }

    /// In-package laser CPO: laser failure costs the package.
    pub fn cpo_integrated_laser(lasers: f64) -> Self {
        LinkReliability {
            name: "CPO (integrated laser)",
            lasers_per_link: lasers,
            laser_location: Replaceable::GpuTray,
            connectors_per_link: 2.0,
            fits: FitRates::default(),
        }
    }

    /// Passage: external laser module feeding the interposer (§III.d).
    pub fn passage_external_laser(lasers: f64) -> Self {
        LinkReliability {
            name: "Passage (external laser)",
            lasers_per_link: lasers,
            laser_location: Replaceable::FieldUnit,
            connectors_per_link: 2.0 + 1.0, // + laser feed fiber
            fits: FitRates::default(),
        }
    }

    /// Copper scale-up cabling (the electrical alternative's in-pod links):
    /// no optics at all — SerDes plus connectors only. The SerDes sits on
    /// the tray; cable/connector reseats are field service.
    pub fn copper() -> Self {
        LinkReliability {
            name: "copper scale-up",
            lasers_per_link: 0.0,
            laser_location: Replaceable::FieldUnit, // vacuous: no lasers
            connectors_per_link: 2.0,
            fits: FitRates { pic: 0.0, ..FitRates::default() },
        }
    }

    /// Total link FIT.
    pub fn link_fit(&self) -> f64 {
        self.lasers_per_link * self.fits.laser
            + self.fits.pic
            + self.fits.electrical
            + self.connectors_per_link * self.fits.connector
    }

    /// FIT attributable to components whose failure takes the GPU tray.
    pub fn tray_impact_fit(&self) -> f64 {
        let mut fit = self.fits.pic + self.fits.electrical; // co-packaged silicon
        if self.laser_location == Replaceable::GpuTray {
            fit += self.lasers_per_link * self.fits.laser;
        }
        fit
    }

    /// FIT attributable to field-replaceable components (swap a module or
    /// reseat a connector without touching the tray): the complement of
    /// [`LinkReliability::tray_impact_fit`].
    pub fn field_impact_fit(&self) -> f64 {
        self.link_fit() - self.tray_impact_fit()
    }

    /// Expected GPU-tray-impacting failures per year for a pod.
    pub fn tray_failures_per_year(&self, links: usize) -> f64 {
        self.tray_impact_fit() * links as f64 * 8760.0 / 1e9
    }

    /// Mean time between *any* link failure in a pod, hours.
    pub fn pod_mtbf_hours(&self, links: usize) -> f64 {
        1e9 / (self.link_fit() * links as f64)
    }
}

/// Rack-level power budget check (§II.B: 120 kW racks; GTC: 20 kW just for
/// an optical NVLink spine would be untenable).
#[derive(Debug, Clone)]
pub struct RackBudget {
    pub rack_kw: f64,
    pub gpus_per_rack: usize,
    pub gpu_compute_kw: f64,
    /// non-IT overhead per rack (fans, CDU, BMC...)
    pub overhead_kw: f64,
}

impl RackBudget {
    pub fn frontier() -> Self {
        RackBudget { rack_kw: 120.0, gpus_per_rack: 72, gpu_compute_kw: 1.4, overhead_kw: 10.0 }
    }

    /// kW left for scale-up interconnect after compute + overhead.
    pub fn interconnect_headroom_kw(&self) -> f64 {
        self.rack_kw - self.gpus_per_rack as f64 * self.gpu_compute_kw - self.overhead_kw
    }

    /// Does a tech fit the rack budget at `gbps` per GPU (GPU-side power
    /// only; switch trays are separate)?
    pub fn fits(&self, tech: &crate::hw::optics::InterconnectTech, gbps: f64) -> bool {
        let optics_kw = tech.power_w(gbps) * self.gpus_per_rack as f64 / 1000.0;
        optics_kw <= self.interconnect_headroom_kw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::optics::{cpo_2p5d, lpo_dr8, passage_interposer, pluggable_osfp};

    #[test]
    fn external_laser_minimizes_tray_impact() {
        let cpo = LinkReliability::cpo_integrated_laser(4.0);
        let psg = LinkReliability::passage_external_laser(4.0);
        let plug = LinkReliability::pluggable(4.0);
        // Integrated laser makes tray-impacting failures dominated by the
        // laser; external/module lasers remove that term.
        assert!(cpo.tray_impact_fit() > 10.0 * psg.tray_impact_fit());
        assert_eq!(psg.tray_impact_fit(), plug.tray_impact_fit());
    }

    #[test]
    fn laser_dominates_link_fit() {
        let l = LinkReliability::passage_external_laser(4.0);
        assert!(l.lasers_per_link * l.fits.laser > 0.5 * l.link_fit());
    }

    #[test]
    fn pod_scale_failure_arithmetic() {
        // 512-GPU pod, 72 links each (rails): failures are a when, not if.
        let l = LinkReliability::cpo_integrated_laser(4.0);
        let links = 512 * 72;
        let per_year = l.tray_failures_per_year(links);
        assert!(per_year > 100.0, "{per_year}"); // tray events/year: untenable
        let psg = LinkReliability::passage_external_laser(4.0);
        assert!(psg.tray_failures_per_year(links) < per_year / 10.0);
        assert!(l.pod_mtbf_hours(links) < 100.0);
    }

    #[test]
    fn copper_has_no_optics_and_minimal_tray_impact() {
        let cu = LinkReliability::copper();
        assert_eq!(cu.lasers_per_link, 0.0);
        assert!((cu.link_fit() - 110.0).abs() < 1e-9);
        assert!((cu.tray_impact_fit() - 10.0).abs() < 1e-9);
        // field + tray partition the link FIT exactly
        for l in [
            LinkReliability::copper(),
            LinkReliability::passage_external_laser(4.0),
            LinkReliability::cpo_integrated_laser(4.0),
        ] {
            assert!((l.field_impact_fit() + l.tray_impact_fit() - l.link_fit()).abs() < 1e-9);
        }
        // copper fails an order of magnitude less often than any optics
        assert!(cu.link_fit() * 10.0 < LinkReliability::passage_external_laser(4.0).link_fit());
    }

    #[test]
    fn rack_budget_gtc_anecdote() {
        // §II.B: pluggable optics for a 72-GPU spine ≈ 20 kW class — does
        // not fit; Passage at the same bandwidth does.
        let rack = RackBudget::frontier();
        assert!(rack.interconnect_headroom_kw() > 0.0);
        assert!(!rack.fits(&pluggable_osfp(), 14_400.0));
        assert!(rack.fits(&passage_interposer(), 14_400.0));
        // 21 pJ/bit * 14.4 Tb/s * 72 GPUs ≈ 21.8 kW — the GTC number.
        let kw = pluggable_osfp().power_w(14_400.0) * 72.0 / 1000.0;
        assert!((kw - 21.8).abs() < 0.5, "{kw}");
    }

    #[test]
    fn budget_ordering_matches_energy_table() {
        let rack = RackBudget::frontier();
        let headroom = rack.interconnect_headroom_kw();
        let kw = |t: &crate::hw::optics::InterconnectTech| {
            t.power_w(32_000.0) * rack.gpus_per_rack as f64 / 1000.0
        };
        assert!(kw(&passage_interposer()) < kw(&cpo_2p5d()));
        assert!(kw(&cpo_2p5d()) < kw(&lpo_dr8()));
        // At 32 Tb/s, even LPO-class racks blow most of the headroom.
        assert!(kw(&lpo_dr8()) > 0.8 * headroom);
    }
}
