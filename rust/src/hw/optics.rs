//! Interconnect technology models (paper §II.C, §III, §IV, Tables II–III).
//!
//! Each [`InterconnectTech`] decomposes a link's energy into in-package
//! (host SerDes + any on-package optics) and off-package (module / external
//! laser) components, and carries the geometry needed by the area model
//! (Fig. 8): module footprints, OE footprints, beachfront, fiber pitch.

use crate::hw::serdes::{Serdes, SERDES_224G_LR, SERDES_56G_NRZ};

/// Technology families compared by the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TechKind {
    /// Passive copper (DAC) — zero optics power, ~1 m reach.
    Copper,
    /// Conventional retimed pluggable module (OSFP class).
    Pluggable,
    /// Linear pluggable optics: DSP removed from module.
    Lpo,
    /// 2.5D optical engine, 2D-integrated co-packaged optics.
    Cpo,
    /// Lightmatter Passage: 3D optical interposer.
    Passage,
}

/// A scale-up interconnect technology design point.
#[derive(Debug, Clone)]
pub struct InterconnectTech {
    pub kind: TechKind,
    pub name: &'static str,
    /// Host/in-package SerDes driving the link.
    pub serdes: Serdes,
    /// In-package optics energy (OE PIC/EIC on package), pJ/bit.
    pub optics_in_pkg_pj: f64,
    /// Off-package energy (pluggable module electronics or external laser),
    /// pJ/bit.
    pub off_pkg_pj: f64,
    /// Maximum reach in meters.
    pub reach_m: f64,
    /// Wavelengths multiplexed per fiber (1 = single-lambda).
    pub lambdas_per_fiber: usize,
    /// Areal bandwidth density for on-board modules, Gb/s per mm² (0 if
    /// co-packaged). LPO/pluggable consume board area, not package area.
    pub board_density_gbps_mm2: f64,
    /// Package-area expansion density, Gb/s per mm² of *added* package area
    /// (OE + beachfront for CPO; fiber-attach ring for Passage).
    pub pkg_density_gbps_mm2: f64,
}

impl InterconnectTech {
    /// Total energy per bit (optics + PHY + laser), Table III bottom row.
    pub fn total_pj_per_bit(&self) -> f64 {
        self.in_pkg_pj_per_bit() + self.off_pkg_pj
    }

    /// In-package pJ/bit (host SerDes + on-package optics), Table III row 1.
    pub fn in_pkg_pj_per_bit(&self) -> f64 {
        self.serdes.pj_per_bit + self.optics_in_pkg_pj
    }

    /// Link power in watts for `gbps` of (unidirectional) bandwidth.
    pub fn power_w(&self, gbps: f64) -> f64 {
        self.total_pj_per_bit() * gbps / 1000.0
    }

    /// In-package power only (competes with compute for the package budget).
    pub fn in_pkg_power_w(&self, gbps: f64) -> f64 {
        self.in_pkg_pj_per_bit() * gbps / 1000.0
    }

    /// Board area consumed by modules for `gbps`, mm² (0 for co-packaged).
    pub fn board_area_mm2(&self, gbps: f64) -> f64 {
        if self.board_density_gbps_mm2 == 0.0 {
            0.0
        } else {
            gbps / self.board_density_gbps_mm2
        }
    }

    /// Added package area for `gbps`, mm² (0 for board-pluggable).
    pub fn pkg_area_mm2(&self, gbps: f64) -> f64 {
        if self.pkg_density_gbps_mm2 == 0.0 {
            0.0
        } else {
            gbps / self.pkg_density_gbps_mm2
        }
    }
}

// --------------------------------------------------------------------------
// Catalog (paper's design points)
// --------------------------------------------------------------------------

/// Passive copper: SerDes only; reach limits pod to a rack (§II.C.2).
pub fn dac_copper() -> InterconnectTech {
    InterconnectTech {
        kind: TechKind::Copper,
        name: "DAC copper (224G)",
        serdes: SERDES_224G_LR,
        optics_in_pkg_pj: 0.0,
        off_pkg_pj: 0.0,
        reach_m: 1.0,
        lambdas_per_fiber: 0,
        board_density_gbps_mm2: 0.0,
        pkg_density_gbps_mm2: 0.0,
    }
}

/// Conventional retimed pluggable optical module: 5 (host) + 16 (module)
/// = 21 pJ/bit (Table II), >2000 mm² per module.
pub fn pluggable_osfp() -> InterconnectTech {
    InterconnectTech {
        kind: TechKind::Pluggable,
        name: "Pluggable OSFP (retimed)",
        serdes: SERDES_224G_LR,
        optics_in_pkg_pj: 0.0,
        off_pkg_pj: 16.0,
        reach_m: 500.0,
        lambdas_per_fiber: 1,
        // OSFP-XD: 105.8 x 22.58 mm = 2389 mm²; 3.2T per module.
        board_density_gbps_mm2: 3200.0 / (105.8 * 22.58),
        pkg_density_gbps_mm2: 0.0,
    }
}

/// 1.6T DR8 LPO, 224G/lane: 5 (host SerDes) + 8 (module) = 13 pJ/bit
/// (Table III col 1).
pub fn lpo_dr8() -> InterconnectTech {
    InterconnectTech {
        kind: TechKind::Lpo,
        name: "1.6T DR8 LPO 224G",
        serdes: SERDES_224G_LR,
        optics_in_pkg_pj: 0.0,
        off_pkg_pj: 8.0,
        reach_m: 500.0,
        lambdas_per_fiber: 1,
        // §IV.B.a: OSFP-XD form factor, 3.2T extra-dense module
        // -> 1.3 Gb/s/mm².
        board_density_gbps_mm2: 3200.0 / (105.8 * 22.58),
        pkg_density_gbps_mm2: 0.0,
    }
}

/// 224G 2.5D CPO with 2D integration: host 5 + OE in-package 4.7 + laser
/// 2.3 = 12 pJ/bit (Table III col 2, from the Bailly/Broadcom reference).
pub fn cpo_2p5d() -> InterconnectTech {
    InterconnectTech {
        kind: TechKind::Cpo,
        name: "224G 2.5D CPO (2D integrated)",
        serdes: SERDES_224G_LR,
        optics_in_pkg_pj: 4.7,
        off_pkg_pj: 2.3,
        reach_m: 500.0,
        lambdas_per_fiber: 1,
        board_density_gbps_mm2: 0.0,
        // §IV.B.b: 15x25 mm OE @ 12.8T = 34 Gb/s/mm², ~24 Gb/s/mm² with
        // beachfront. Use the with-beachfront figure — Fig 8 counts both.
        pkg_density_gbps_mm2: 24.4,
    }
}

/// Passage optical interposer, 56G ×8λ: SerDes 2 + PIC 1.2 + laser 1.1
/// = 4.3 pJ/bit (Table III col 3).
pub fn passage_interposer() -> InterconnectTech {
    InterconnectTech {
        kind: TechKind::Passage,
        name: "56Gx8λ Passage interposer",
        serdes: SERDES_56G_NRZ,
        optics_in_pkg_pj: 1.2,
        off_pkg_pj: 1.1, // external laser
        reach_m: 500.0,
        lambdas_per_fiber: 8,
        board_density_gbps_mm2: 0.0,
        // §IV.B.c: 127 µm fibers, 4/mm of shoreline, 2TX+2RX per 5 mm²
        // of fiber-attach ring -> 160 Gb/s/mm² of added package area.
        pkg_density_gbps_mm2: 160.0,
    }
}

/// All techs compared in Fig. 7 / Fig. 8, in paper order.
pub fn catalog() -> Vec<InterconnectTech> {
    vec![pluggable_osfp(), lpo_dr8(), cpo_2p5d(), passage_interposer()]
}

/// Passage WDM fiber capacity (§III.a): up to 16 λ × 112G PAM-4
/// = 1.792 Tb/s per fiber.
pub fn passage_fiber_capacity_gbps(lambdas: usize, gbps_per_lambda: f64) -> f64 {
    lambdas as f64 * gbps_per_lambda
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_totals() {
        assert!((lpo_dr8().total_pj_per_bit() - 13.0).abs() < 1e-9);
        assert!((cpo_2p5d().total_pj_per_bit() - 12.0).abs() < 1e-9);
        assert!((passage_interposer().total_pj_per_bit() - 4.3).abs() < 1e-9);
    }

    #[test]
    fn table3_in_vs_off_package_split() {
        let cpo = cpo_2p5d();
        assert!((cpo.in_pkg_pj_per_bit() - 9.7).abs() < 1e-9);
        assert!((cpo.off_pkg_pj - 2.3).abs() < 1e-9);
        let p = passage_interposer();
        assert!((p.in_pkg_pj_per_bit() - 3.2).abs() < 1e-9);
        assert!((p.off_pkg_pj - 1.1).abs() < 1e-9);
        let lpo = lpo_dr8();
        assert!((lpo.in_pkg_pj_per_bit() - 5.0).abs() < 1e-9);
        assert!((lpo.off_pkg_pj - 8.0).abs() < 1e-9);
    }

    #[test]
    fn table2_pluggable_is_21pj() {
        assert!((pluggable_osfp().total_pj_per_bit() - 21.0).abs() < 1e-9);
    }

    #[test]
    fn power_parity_threshold() {
        // §II.C.3: at 5 pJ/bit optics ≈ copper parity; 14.4 Tb/s -> 72 W.
        let hypothetical = InterconnectTech {
            off_pkg_pj: 5.0 - SERDES_224G_LR.pj_per_bit,
            ..dac_copper()
        };
        assert!((hypothetical.power_w(14_400.0) - 72.0).abs() < 1e-6);
    }

    #[test]
    fn passage_wdm_fiber_capacity() {
        assert!((passage_fiber_capacity_gbps(16, 112.0) - 1792.0).abs() < 1e-9);
    }

    #[test]
    fn area_model_hooks() {
        // 32 Tb/s: LPO >20,000 mm² of board; Passage ~200 mm² of package.
        assert!(lpo_dr8().board_area_mm2(32_000.0) > 20_000.0);
        assert!((passage_interposer().pkg_area_mm2(32_000.0) - 200.0).abs() < 1.0);
        assert_eq!(passage_interposer().board_area_mm2(32_000.0), 0.0);
        assert_eq!(lpo_dr8().pkg_area_mm2(32_000.0), 0.0);
    }
}
