//! GPU and switch package geometry models (paper §II.C.1, §IV.C, Fig. 3).
//!
//! A 2027-28 class GPU package: 4 logic reticles (26 × 33 mm), 16 HBM4
//! stacks (13 × 11 mm) on north/south, I/O on east/west. Shoreline is the
//! contended resource: HBM takes two sides, SerDes the other two.

use crate::hw::optics::{InterconnectTech, TechKind};
use crate::hw::serdes::Serdes;

/// Full-reticle dimensions, mm (paper §IV.C.a).
pub const RETICLE_MM: (f64, f64) = (26.0, 33.0);
/// HBM4 stack footprint, mm.
pub const HBM_MM: (f64, f64) = (13.0, 11.0);

/// GPU package configuration (Fig. 3: 4×1 reticles, HBM north/south).
#[derive(Debug, Clone)]
pub struct GpuPackage {
    pub n_reticles: usize,
    pub n_hbm: usize,
    /// Unidirectional scale-up I/O bandwidth target, Gb/s.
    pub scaleup_gbps: f64,
    /// HBM bandwidth, Gb/s (209 Tb/s for 16 × HBM4 @ 6.4 GT/s).
    pub hbm_gbps: f64,
    /// Compute throughput, BF16 FLOP/s (8.5 PFLOPS in the paper's study).
    pub flops: f64,
}

impl GpuPackage {
    /// The paper's 2028 design point (§IV.C.a, §VI).
    pub fn frontier_2028() -> Self {
        GpuPackage {
            n_reticles: 4,
            n_hbm: 16,
            scaleup_gbps: 32_000.0,
            hbm_gbps: 209_000.0,
            flops: 8.5e15,
        }
    }

    /// Base package silicon area: logic + HBM (mm²). Substrate margins are
    /// excluded — the paper's 23% / 3.5% growth figures are relative to
    /// this silicon budget.
    pub fn base_area_mm2(&self) -> f64 {
        self.n_reticles as f64 * RETICLE_MM.0 * RETICLE_MM.1
            + self.n_hbm as f64 * HBM_MM.0 * HBM_MM.1
    }

    /// HBM : scale-up bandwidth ratio (§IV.C.a quotes 6.67:1 at 26 TB/s
    /// memory and 32 Tb/s scale-up... i.e. 209/32 ≈ 6.5:1).
    pub fn hbm_to_scaleup_ratio(&self) -> f64 {
        self.hbm_gbps / self.scaleup_gbps
    }

    /// Shoreline available for SerDes: east+west edges of the reticle row
    /// (north/south are consumed by HBM, Fig. 3).
    pub fn io_shoreline_mm(&self) -> f64 {
        2.0 * RETICLE_MM.1
    }

    /// Package growth fraction when adding `tech` optics for the scale-up
    /// bandwidth (0 for board-level module techs).
    pub fn pkg_growth_fraction(&self, tech: &InterconnectTech) -> f64 {
        tech.pkg_area_mm2(self.scaleup_gbps) / self.base_area_mm2()
    }
}

/// Scale-up switch package (§IV.C.b design point).
#[derive(Debug, Clone)]
pub struct SwitchPackage {
    /// Usable switching bandwidth, Gb/s (200 Tb/s).
    pub fabric_gbps: f64,
    /// Raw SerDes bandwidth incl. overheads, Gb/s (229 Tb/s).
    pub raw_gbps: f64,
    /// Port count (512 × 448G raw).
    pub ports: usize,
    /// Raw bandwidth per port, Gb/s.
    pub port_gbps: f64,
}

impl SwitchPackage {
    /// The paper's SLS switch design point: 512 × 448G, 200 Tb/s usable.
    pub fn sls_512() -> Self {
        SwitchPackage {
            fabric_gbps: 200_000.0,
            raw_gbps: 229_376.0, // 512 * 448
            ports: 512,
            port_gbps: 448.0,
        }
    }

    /// Shoreline required to place the SerDes for the raw bandwidth with
    /// perimeter I/O (LPO/CPO hosts). 1.5D stacking assumed (§IV.C.b).
    pub fn required_shoreline_mm(&self, serdes: &Serdes) -> f64 {
        serdes.shoreline_mm(self.raw_gbps, 1.5)
    }

    /// Reticles needed when SerDes must sit on the perimeter. The paper's
    /// point: 256 mm does not fit on two reticles' combined free edges, so
    /// LPO/CPO switches go to 4 reticles; Passage (area I/O) needs 2.
    pub fn reticles_needed(&self, tech: &InterconnectTech) -> usize {
        if tech.kind == TechKind::Passage {
            return 2; // fabric area only; SerDes distributed via 3D TSVs
        }
        let need = self.required_shoreline_mm(&tech.serdes);
        for n in 2..=8 {
            // Each added reticle contributes its perimeter minus the edges
            // lost to inter-reticle stitching; take the paper's coarse
            // "combined edges of n full reticles" accounting.
            let have = n as f64 * 2.0 * (RETICLE_MM.0 + RETICLE_MM.1) - (n as f64 - 1.0) * 2.0 * RETICLE_MM.0;
            if have >= need {
                return n;
            }
        }
        8
    }

    /// Power saved per switch package by using `a` instead of `b`
    /// (Table III energies × fabric bandwidth). §IV.C.b: CPO→Passage at
    /// 200 Tb/s saves ~1.5 kW.
    pub fn power_saving_w(&self, a: &InterconnectTech, b: &InterconnectTech) -> f64 {
        (a.total_pj_per_bit() - b.total_pj_per_bit()) * self.fabric_gbps / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::optics::{cpo_2p5d, lpo_dr8, passage_interposer};

    #[test]
    fn base_area_matches_paper_geometry() {
        let gpu = GpuPackage::frontier_2028();
        // 4*858 + 16*143 = 3432 + 2288 = 5720 mm²
        assert!((gpu.base_area_mm2() - 5720.0).abs() < 1e-9);
    }

    #[test]
    fn package_growth_cpo_23pct_passage_3p5pct() {
        let gpu = GpuPackage::frontier_2028();
        let cpo = gpu.pkg_growth_fraction(&cpo_2p5d());
        let psg = gpu.pkg_growth_fraction(&passage_interposer());
        assert!((cpo - 0.23).abs() < 0.01, "cpo {cpo}");
        assert!((psg - 0.035).abs() < 0.003, "passage {psg}");
        assert_eq!(gpu.pkg_growth_fraction(&lpo_dr8()), 0.0);
    }

    #[test]
    fn hbm_ratio_in_spec_range() {
        let r = GpuPackage::frontier_2028().hbm_to_scaleup_ratio();
        assert!(r > 6.0 && r < 7.0, "{r}");
    }

    #[test]
    fn switch_shoreline_forces_4_reticles_for_cpo() {
        let sw = SwitchPackage::sls_512();
        let need = sw.required_shoreline_mm(&cpo_2p5d().serdes);
        assert!((need - 256.0).abs() < 1.0, "{need}");
        assert_eq!(sw.reticles_needed(&cpo_2p5d()), 4);
        assert_eq!(sw.reticles_needed(&lpo_dr8()), 4);
        assert_eq!(sw.reticles_needed(&passage_interposer()), 2);
    }

    #[test]
    fn switch_power_saving_about_1p5kw() {
        let sw = SwitchPackage::sls_512();
        let w = sw.power_saving_w(&cpo_2p5d(), &passage_interposer());
        assert!((w - 1540.0).abs() < 10.0, "{w}");
    }

    #[test]
    fn port_arithmetic() {
        let sw = SwitchPackage::sls_512();
        assert_eq!(sw.ports as f64 * sw.port_gbps, sw.raw_gbps);
    }
}
