//! Area accounting for a 32 Tb/s GPU (paper Fig. 8 and §IV.B).
//!
//! Fig. 8 compares, per technology: the GPU package itself (logic + HBM),
//! optics on package, package beachfront expansion, and board expansion
//! (pluggable modules). The paper's headline ratios: LPO needs >20,000 mm²
//! of board; CPO ~1312 mm² of added package; Passage ~200 mm² — a 123× and
//! 6.6× reduction in additional optical area respectively.

use crate::hw::optics::InterconnectTech;
use crate::hw::package::GpuPackage;

/// Area breakdown for one GPU + interconnect technology (all mm²).
#[derive(Debug, Clone)]
pub struct AreaBreakdown {
    pub tech: String,
    /// Logic + HBM silicon.
    pub gpu_base: f64,
    /// Added package area (OE + beachfront, or fiber-attach ring).
    pub pkg_expansion: f64,
    /// Board area consumed by pluggable modules.
    pub board_expansion: f64,
}

impl AreaBreakdown {
    pub fn compute(gpu: &GpuPackage, tech: &InterconnectTech) -> Self {
        AreaBreakdown {
            tech: tech.name.to_string(),
            gpu_base: gpu.base_area_mm2(),
            pkg_expansion: tech.pkg_area_mm2(gpu.scaleup_gbps),
            board_expansion: tech.board_area_mm2(gpu.scaleup_gbps),
        }
    }

    /// All area beyond the GPU silicon itself.
    pub fn additional(&self) -> f64 {
        self.pkg_expansion + self.board_expansion
    }

    pub fn total(&self) -> f64 {
        self.gpu_base + self.additional()
    }
}

/// Additional-optical-area ratio of `a` over `b` at a given port bandwidth
/// (§IV.B.c quotes 123× vs LPO and 6.6× vs CPO for a 400 Gb/s port).
pub fn additional_area_ratio(
    a: &InterconnectTech,
    b: &InterconnectTech,
    port_gbps: f64,
) -> f64 {
    let area = |t: &InterconnectTech| t.pkg_area_mm2(port_gbps) + t.board_area_mm2(port_gbps);
    area(a) / area(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::optics::{cpo_2p5d, lpo_dr8, passage_interposer};

    #[test]
    fn fig8_lpo_board_over_20k() {
        let gpu = GpuPackage::frontier_2028();
        let b = AreaBreakdown::compute(&gpu, &lpo_dr8());
        assert!(b.board_expansion > 20_000.0, "{}", b.board_expansion);
        assert_eq!(b.pkg_expansion, 0.0);
    }

    #[test]
    fn fig8_cpo_about_1312() {
        let gpu = GpuPackage::frontier_2028();
        let b = AreaBreakdown::compute(&gpu, &cpo_2p5d());
        assert!((b.pkg_expansion - 1312.0).abs() < 20.0, "{}", b.pkg_expansion);
    }

    #[test]
    fn fig8_passage_about_200() {
        let gpu = GpuPackage::frontier_2028();
        let b = AreaBreakdown::compute(&gpu, &passage_interposer());
        assert!((b.pkg_expansion - 200.0).abs() < 5.0, "{}", b.pkg_expansion);
        assert_eq!(b.board_expansion, 0.0);
    }

    #[test]
    fn port_area_ratios_123x_and_6p6x() {
        let lpo_vs_passage = additional_area_ratio(&lpo_dr8(), &passage_interposer(), 400.0);
        let cpo_vs_passage = additional_area_ratio(&cpo_2p5d(), &passage_interposer(), 400.0);
        // Paper quotes 123× and 6.6×; our first-principles densities land
        // within ~5%.
        assert!((lpo_vs_passage - 123.0).abs() < 8.0, "{lpo_vs_passage}");
        assert!((cpo_vs_passage - 6.6).abs() < 0.4, "{cpo_vs_passage}");
    }

    #[test]
    fn additional_is_sum_of_expansions() {
        let gpu = GpuPackage::frontier_2028();
        let b = AreaBreakdown::compute(&gpu, &cpo_2p5d());
        assert_eq!(b.additional(), b.pkg_expansion);
        assert_eq!(b.total(), b.gpu_base + b.pkg_expansion);
    }
}
