//! SerDes catalog (paper §III–IV).
//!
//! Energy numbers and shoreline geometry from the paper's cited sources:
//! 224G-LR 5 pJ/bit (Synopsys 3 pJ/b transceiver + DSP, §IV.A.a), 112G-LR
//! 4.5–6 pJ/bit [15][16], 112G-XSR 1 pJ/bit (Tonietto [23]), 56G-NRZ
//! 2 pJ/bit (conservative doubling, §IV.A.d), and 3 mm of shoreline per
//! ×8 224G macro (§IV.C.b).

/// Modulation scheme of a SerDes lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Modulation {
    Nrz,
    Pam4,
}

/// A SerDes design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Serdes {
    pub name: &'static str,
    /// Per-lane raw rate in Gb/s.
    pub gbps_per_lane: f64,
    pub modulation: Modulation,
    /// Energy, pJ/bit, including DSP where the design requires one.
    pub pj_per_bit: f64,
    /// Does the design rely on a DSP (long-reach equalization)?
    pub has_dsp: bool,
    /// Reach class in meters over the intended medium.
    pub reach_m: f64,
    /// Shoreline per ×8 macro, mm (only meaningful for perimeter SerDes).
    pub shoreline_mm_per_macro8: f64,
}

/// 224 Gb/s PAM-4 long-reach (DSP): the electrical scale-up baseline.
pub const SERDES_224G_LR: Serdes = Serdes {
    name: "224G-LR PAM-4",
    gbps_per_lane: 224.0,
    modulation: Modulation::Pam4,
    pj_per_bit: 5.0,
    has_dsp: true,
    reach_m: 1.0,
    shoreline_mm_per_macro8: 3.0,
};

/// 112 Gb/s PAM-4 long-reach (DSP).
pub const SERDES_112G_LR: Serdes = Serdes {
    name: "112G-LR PAM-4",
    gbps_per_lane: 112.0,
    modulation: Modulation::Pam4,
    pj_per_bit: 5.0,
    has_dsp: true,
    reach_m: 1.0,
    shoreline_mm_per_macro8: 2.0,
};

/// 112 Gb/s PAM-4 extra-short-reach (no DSP; <100 µm drive in Passage).
pub const SERDES_112G_XSR: Serdes = Serdes {
    name: "112G-XSR PAM-4",
    gbps_per_lane: 112.0,
    modulation: Modulation::Pam4,
    pj_per_bit: 1.0,
    has_dsp: false,
    reach_m: 0.0001,
    shoreline_mm_per_macro8: 0.0, // area-distributed under 3D stacking
};

/// 56 Gb/s NRZ short-reach (Passage WDM lane; conservative 2 pJ/bit).
pub const SERDES_56G_NRZ: Serdes = Serdes {
    name: "56G-NRZ XSR",
    gbps_per_lane: 56.0,
    modulation: Modulation::Nrz,
    pj_per_bit: 2.0,
    has_dsp: false,
    reach_m: 0.0001,
    shoreline_mm_per_macro8: 0.0,
};

impl Serdes {
    /// Lanes needed to carry `port_gbps` of raw bandwidth.
    pub fn lanes_for_port(&self, port_gbps: f64) -> usize {
        (port_gbps / self.gbps_per_lane).ceil() as usize
    }

    /// Power in watts to drive `gbps` of raw bandwidth (one direction).
    pub fn power_w(&self, gbps: f64) -> f64 {
        self.pj_per_bit * gbps / 1000.0 // pJ/bit * Gb/s = mW; /1000 -> W
    }

    /// Shoreline (mm) to place enough ×8 macros for `gbps` total,
    /// with an optional stacking factor (1.5D stacking fits 1.5 macro rows
    /// per unit shoreline, §IV.C.b).
    pub fn shoreline_mm(&self, gbps: f64, stacking: f64) -> f64 {
        if self.shoreline_mm_per_macro8 == 0.0 {
            return 0.0; // 3D: SerDes distributed over the die area
        }
        let macro_bw = 8.0 * self.gbps_per_lane;
        let macros = (gbps / macro_bw).ceil();
        macros * self.shoreline_mm_per_macro8 / stacking
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_for_448g_port() {
        assert_eq!(SERDES_224G_LR.lanes_for_port(448.0), 2);
        assert_eq!(SERDES_112G_LR.lanes_for_port(448.0), 4);
        assert_eq!(SERDES_56G_NRZ.lanes_for_port(448.0), 8);
    }

    #[test]
    fn power_scales_with_bandwidth() {
        // 32 Tb/s at 5 pJ/bit = 160 W
        let w = SERDES_224G_LR.power_w(32_000.0);
        assert!((w - 160.0).abs() < 1e-9);
    }

    #[test]
    fn paper_switch_shoreline_case() {
        // §IV.C.b: 229 Tb/s raw needs 128 ×8-224G macros; at 3 mm per macro
        // with 1.5D stacking -> 256 mm of shoreline.
        let mm = SERDES_224G_LR.shoreline_mm(229_376.0, 1.5);
        assert!((mm - 256.0).abs() < 1.0, "{mm}");
    }

    #[test]
    fn xsr_has_no_shoreline_requirement() {
        assert_eq!(SERDES_112G_XSR.shoreline_mm(32_000.0, 1.0), 0.0);
        assert!(!SERDES_112G_XSR.has_dsp);
    }
}
