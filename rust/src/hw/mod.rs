//! Hardware technology models (paper §II–IV): SerDes, interconnect optics,
//! package geometry, power and area accounting. These feed both the
//! standalone design-space figures (Tables I–III, Figs 7–8) and the network
//! parameters of the performance model.

pub mod area;
pub mod optics;
pub mod package;
pub mod power;
pub mod reliability;
pub mod serdes;

pub use area::{additional_area_ratio, AreaBreakdown};
pub use optics::{catalog, cpo_2p5d, dac_copper, lpo_dr8, passage_interposer,
                 pluggable_osfp, InterconnectTech, TechKind};
pub use package::{GpuPackage, SwitchPackage};
pub use power::{fig7_comparison, pod_optics_power_kw, PowerBreakdown};
pub use reliability::{FitRates, LinkReliability, RackBudget, Replaceable};
pub use serdes::{Modulation, Serdes, SERDES_112G_LR, SERDES_112G_XSR,
                 SERDES_224G_LR, SERDES_56G_NRZ};
