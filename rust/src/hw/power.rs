//! GPU-level optics power accounting (paper Fig. 7) and pod-level power
//! (the GTC "20 kW just for the NVLink spine" framing, §II.B).

use crate::hw::optics::InterconnectTech;

/// Power breakdown for driving `gbps` of unidirectional scale-up I/O.
#[derive(Debug, Clone)]
pub struct PowerBreakdown {
    pub tech: String,
    pub gbps: f64,
    pub serdes_w: f64,
    pub optics_in_pkg_w: f64,
    pub off_pkg_w: f64,
}

impl PowerBreakdown {
    pub fn compute(tech: &InterconnectTech, gbps: f64) -> Self {
        PowerBreakdown {
            tech: tech.name.to_string(),
            gbps,
            serdes_w: tech.serdes.pj_per_bit * gbps / 1000.0,
            optics_in_pkg_w: tech.optics_in_pkg_pj * gbps / 1000.0,
            off_pkg_w: tech.off_pkg_pj * gbps / 1000.0,
        }
    }

    pub fn in_pkg_w(&self) -> f64 {
        self.serdes_w + self.optics_in_pkg_w
    }

    pub fn total_w(&self) -> f64 {
        self.in_pkg_w() + self.off_pkg_w
    }
}

/// Fig. 7 comparison at the paper's 32 Tb/s GPU design point: returns
/// (breakdowns, passage_advantage_over_best_conventional).
pub fn fig7_comparison(gbps: f64) -> (Vec<PowerBreakdown>, f64) {
    use crate::hw::optics::{catalog, TechKind};
    let breakdowns: Vec<PowerBreakdown> = catalog()
        .iter()
        .map(|t| PowerBreakdown::compute(t, gbps))
        .collect();
    let passage = breakdowns
        .iter()
        .find(|b| b.tech.contains("Passage"))
        // lumos: allow(panic-path) -- the static catalog always contains the Passage entry
        .expect("catalog has passage");
    let best_conventional = catalog()
        .iter()
        .zip(&breakdowns)
        .filter(|(t, _)| matches!(t.kind, TechKind::Lpo | TechKind::Cpo))
        .map(|(_, b)| b.total_w())
        .fold(f64::INFINITY, f64::min);
    (breakdowns.clone(), best_conventional / passage.total_w())
}

/// Pod-level optics power: `n_gpus` × per-GPU I/O power plus switch-side
/// power for the same traffic (SLS: every bit crosses one switch).
pub fn pod_optics_power_kw(
    tech: &InterconnectTech,
    n_gpus: usize,
    gbps_per_gpu: f64,
    switch_fraction: f64,
) -> f64 {
    let gpu_side = tech.power_w(gbps_per_gpu) * n_gpus as f64;
    gpu_side * (1.0 + switch_fraction) / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::optics::{cpo_2p5d, lpo_dr8, passage_interposer, pluggable_osfp};

    const GBPS: f64 = 32_000.0;

    #[test]
    fn fig7_absolute_totals() {
        assert!((PowerBreakdown::compute(&pluggable_osfp(), GBPS).total_w() - 672.0).abs() < 1e-6);
        assert!((PowerBreakdown::compute(&lpo_dr8(), GBPS).total_w() - 416.0).abs() < 1e-6);
        assert!((PowerBreakdown::compute(&cpo_2p5d(), GBPS).total_w() - 384.0).abs() < 1e-6);
        assert!((PowerBreakdown::compute(&passage_interposer(), GBPS).total_w() - 137.6).abs() < 0.1);
    }

    #[test]
    fn fig7_passage_2p8x_advantage() {
        let (_, adv) = fig7_comparison(GBPS);
        // Paper: "2.8× less power of Passage interposer over conventional
        // optics" (vs the 12 pJ/bit CPO class).
        assert!((adv - 2.79).abs() < 0.05, "advantage {adv}");
    }

    #[test]
    fn in_vs_off_package_split_passage() {
        let b = PowerBreakdown::compute(&passage_interposer(), GBPS);
        // 2 pJ/b serdes + 1.2 PIC in package; 1.1 laser off package.
        assert!((b.in_pkg_w() - 102.4).abs() < 0.1);
        assert!((b.off_pkg_w - 35.2).abs() < 0.1);
    }

    #[test]
    fn twenty_pj_per_bit_is_infeasible() {
        // §II.C.3: at 20 pJ/bit, 14.4 Tb/s costs 288 W of the GPU budget.
        let w: f64 = 20.0 * 14_400.0 / 1000.0;
        assert!((w - 288.0).abs() < 1e-9);
    }

    #[test]
    fn pod_power_scales_linearly() {
        let p1 = pod_optics_power_kw(&lpo_dr8(), 72, 14_400.0, 1.0);
        let p2 = pod_optics_power_kw(&lpo_dr8(), 144, 14_400.0, 1.0);
        assert!((p2 / p1 - 2.0).abs() < 1e-9);
        // 72 GPUs at 14.4 Tb/s, 13 pJ/bit, GPU+switch sides ≈ 27 kW — the
        // right order of magnitude vs GTC's "20 kW for the spine".
        assert!(p1 > 15.0 && p1 < 40.0, "{p1}");
    }
}
