//! 4D parallelism mapping: TP × DP × PP plus expert parallelism overlaid on
//! DP ranks (paper §V.B, Fig. 9).
//!
//! Placement policy (§VI): tensor-parallel groups are placed in the
//! high-bandwidth domain first; expert-parallel groups are placed there too
//! if the pod has room. The GPU id layout makes both policies geometric:
//! TP innermost (contiguous), then DP (so the `ep_dp_ranks` consecutive DP
//! ranks forming an EP group are contiguous GPUs), then PP outermost.
//!
//! Mapping validity is a checkable predicate ([`Mapping::try_with_microbatch`],
//! [`MappingError`]) rather than only a panic, and [`enumerate_candidates`]
//! walks the full legal (TP, PP, DP, microbatch, experts-per-rank) space for
//! a (workload, cluster) pair — the [`crate::planner`] search space.

use crate::model::{MoeConfig, Workload};
use crate::topology::cluster::{Cluster, Domain};

/// Degrees of the three base parallelism dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
}

impl Parallelism {
    /// The paper's fixed setup: TP 16 × PP 8 × DP 256 = 32,768 GPUs.
    pub fn paper() -> Self {
        Parallelism { tp: 16, pp: 8, dp: 256 }
    }

    pub fn n_gpus(&self) -> usize {
        self.tp * self.pp * self.dp
    }
}

/// Why a (parallelism, MoE, microbatch) tuple is not a legal mapping.
///
/// The checkable counterpart of the panics [`Mapping::new`] raises — the
/// planner filters candidates with [`Mapping::try_with_microbatch`] instead
/// of crashing on the first illegal point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MappingError {
    /// A parallelism degree (or the microbatch) is zero.
    ZeroDegree,
    /// `total_experts` is not a multiple of `experts_per_dp_rank`.
    ExpertsIndivisible { total_experts: usize, experts_per_dp_rank: usize },
    /// `tp` cannot be split into `experts_per_dp_rank` expert-TP subgroups.
    ExpertTpIndivisible { tp: usize, experts_per_dp_rank: usize },
    /// `dp` does not hold a whole number of EP groups.
    IncompleteEpGroups { dp: usize, ep_dp_ranks: usize },
}

impl std::fmt::Display for MappingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            MappingError::ZeroDegree => {
                write!(f, "parallelism degrees and microbatch must be nonzero")
            }
            MappingError::ExpertsIndivisible { total_experts, experts_per_dp_rank } => write!(
                f,
                "total_experts {total_experts} must divide into experts_per_dp_rank \
                 {experts_per_dp_rank}"
            ),
            MappingError::ExpertTpIndivisible { tp, experts_per_dp_rank } => write!(
                f,
                "tp {tp} must divide into experts_per_dp_rank {experts_per_dp_rank}"
            ),
            MappingError::IncompleteEpGroups { dp, ep_dp_ranks } => write!(
                f,
                "dp {dp} must contain whole EP groups of {ep_dp_ranks} ranks"
            ),
        }
    }
}

impl std::error::Error for MappingError {}

/// Logical coordinates of one GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankCoord {
    pub dp: usize,
    pub pp: usize,
    pub tp: usize,
}

/// The rank mapping + MoE group structure + microbatch schedule grain.
///
/// `microbatch_seqs` (sequences per 1F1B microbatch) lives here — not in
/// [`crate::perf::PerfKnobs`] — because it is part of the searched mapping:
/// it trades activation memory against pipeline bubble, per point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    pub par: Parallelism,
    pub moe: MoeConfig,
    /// Sequences per microbatch (1F1B schedule grain).
    pub microbatch_seqs: usize,
}

impl Mapping {
    /// Panicking constructor (microbatch 1); use [`Mapping::try_new`] to
    /// check instead of crash.
    pub fn new(par: Parallelism, moe: MoeConfig) -> Self {
        match Self::try_new(par, moe) {
            Ok(m) => m,
            // lumos: allow(panic-path) -- documented panicking constructor; try_new is the checked form
            Err(e) => panic!("{e}"),
        }
    }

    /// Checkable constructor (microbatch 1).
    pub fn try_new(par: Parallelism, moe: MoeConfig) -> Result<Self, MappingError> {
        Self::try_with_microbatch(par, moe, 1)
    }

    /// Full checkable constructor: every divisibility constraint the group
    /// geometry relies on, as a predicate.
    pub fn try_with_microbatch(
        par: Parallelism,
        moe: MoeConfig,
        microbatch_seqs: usize,
    ) -> Result<Self, MappingError> {
        if par.tp == 0 || par.pp == 0 || par.dp == 0 || microbatch_seqs == 0 {
            return Err(MappingError::ZeroDegree);
        }
        if moe.experts_per_dp_rank == 0 || moe.total_experts % moe.experts_per_dp_rank != 0 {
            return Err(MappingError::ExpertsIndivisible {
                total_experts: moe.total_experts,
                experts_per_dp_rank: moe.experts_per_dp_rank,
            });
        }
        if par.tp % moe.experts_per_dp_rank != 0 {
            return Err(MappingError::ExpertTpIndivisible {
                tp: par.tp,
                experts_per_dp_rank: moe.experts_per_dp_rank,
            });
        }
        if par.dp % moe.ep_dp_ranks() != 0 {
            return Err(MappingError::IncompleteEpGroups {
                dp: par.dp,
                ep_dp_ranks: moe.ep_dp_ranks(),
            });
        }
        Ok(Mapping { par, moe, microbatch_seqs })
    }

    /// Same mapping at a different microbatch grain.
    pub fn with_microbatch(mut self, microbatch_seqs: usize) -> Self {
        assert!(microbatch_seqs > 0, "microbatch must be nonzero");
        self.microbatch_seqs = microbatch_seqs;
        self
    }

    /// 1F1B microbatches per step per DP rank under `w` — the one place
    /// `global_batch / dp / microbatch_seqs` is derived (floored at 1 for
    /// callers probing non-enumerated mappings; the enumeration guarantees
    /// exact divisibility).
    pub fn n_micro(&self, w: &Workload) -> usize {
        (w.global_batch / self.par.dp / self.microbatch_seqs).max(1)
    }

    /// GPU id for a coordinate (TP innermost, DP middle, PP outermost).
    pub fn gpu_of(&self, c: RankCoord) -> usize {
        assert!(c.dp < self.par.dp && c.pp < self.par.pp && c.tp < self.par.tp);
        (c.pp * self.par.dp + c.dp) * self.par.tp + c.tp
    }

    /// Inverse of `gpu_of`.
    pub fn coord_of(&self, gpu: usize) -> RankCoord {
        assert!(gpu < self.par.n_gpus());
        let tp = gpu % self.par.tp;
        let rest = gpu / self.par.tp;
        let dp = rest % self.par.dp;
        let pp = rest / self.par.dp;
        RankCoord { dp, pp, tp }
    }

    // -- group geometry ------------------------------------------------------

    /// GPUs of one tensor-parallel group (fixed dp, pp).
    pub fn tp_group(&self, dp: usize, pp: usize) -> Vec<usize> {
        (0..self.par.tp).map(|tp| self.gpu_of(RankCoord { dp, pp, tp })).collect()
    }

    /// Expert-TP subgroup size: the TP group is subdivided into
    /// `experts_per_dp_rank` groups, one per co-located expert (Fig. 9b).
    pub fn expert_tp(&self) -> usize {
        self.par.tp / self.moe.experts_per_dp_rank
    }

    /// Number of DP ranks in one EP group (one complete expert set).
    pub fn ep_dp_ranks(&self) -> usize {
        self.moe.ep_dp_ranks()
    }

    /// GPUs of the EP group containing DP rank `dp` at stage `pp`:
    /// `ep_dp_ranks` consecutive DP ranks × full TP width.
    pub fn ep_group(&self, dp: usize, pp: usize) -> Vec<usize> {
        let w = self.ep_dp_ranks();
        let start = dp / w * w;
        (start..start + w)
            .flat_map(|d| self.tp_group(d, pp))
            .collect()
    }

    /// Span of the EP group in consecutive GPU ids.
    pub fn ep_span_gpus(&self) -> usize {
        self.ep_dp_ranks() * self.par.tp
    }

    /// Complete expert sets in the system (gradient-sync replicas of each
    /// expert, §V.B).
    pub fn n_complete_expert_sets(&self) -> usize {
        self.par.dp / self.ep_dp_ranks()
    }

    /// Span (consecutive GPU ids) of a data-parallel gradient-sync group
    /// for the shared (attention) parameters: all DP ranks of a stage.
    pub fn dp_span_gpus(&self) -> usize {
        self.par.dp * self.par.tp
    }

    // -- placement / domain assignment ---------------------------------------

    /// Does the full EP group fit inside one scale-up pod?
    pub fn ep_fits_pod(&self, cluster: &Cluster) -> bool {
        self.ep_span_gpus() <= cluster.spec.pod_size
    }

    /// Domain carrying EP all-to-all traffic under the TP-first policy.
    pub fn ep_domain(&self, cluster: &Cluster) -> Domain {
        cluster.domain_for_span(self.ep_span_gpus())
    }
}

// ---------------------------------------------------------------------------
// Candidate enumeration (the planner's search space)
// ---------------------------------------------------------------------------

/// Sorted divisors of `n` (ascending — keeps enumeration deterministic).
fn divisors(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            out.push(i);
            if i != n / i {
                out.push(n / i);
            }
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

/// Every structurally legal (TP, PP, DP, microbatch, experts_per_dp_rank)
/// mapping of `w` onto `cluster`, in deterministic order (TP, then PP, then
/// experts-per-rank, then microbatch, all ascending).
///
/// Legality (EXPERIMENTS.md §Planner) — everything short of HBM capacity,
/// which is [`crate::perf`]'s job:
///
/// 1. `tp · pp · dp == cluster.n_gpus` — the mapping partitions every GPU;
/// 2. `n_heads % tp == 0` — attention heads shard evenly over TP ranks;
/// 3. `tp <= pod_size` — TP collectives ride the scale-up domain (the
///    TP-first placement policy the perf model costs);
/// 4. `pp <= n_layers` — every stage holds at least one layer (the
///    analytical model permits fractional layers per stage, matching the
///    seed's continuous approximation);
/// 5. `global_batch % dp == 0` — whole sequences per DP rank;
/// 6. the [`Mapping::try_with_microbatch`] divisibility predicate (expert-TP
///    subgroups, whole EP groups);
/// 7. `d_ff_expert % expert_tp == 0` — expert FFN shards evenly;
/// 8. `microbatch_seqs` divides the per-rank sequence count.
pub fn enumerate_candidates(w: &Workload, cluster: &Cluster) -> Vec<Mapping> {
    let n = cluster.spec.n_gpus;
    let mut out = Vec::new();
    for &tp in &divisors(n) {
        if tp > cluster.spec.pod_size || w.n_heads % tp != 0 {
            continue;
        }
        for &pp in &divisors(n / tp) {
            if pp > w.n_layers {
                continue;
            }
            let dp = n / (tp * pp);
            if w.global_batch % dp != 0 {
                continue;
            }
            let seqs_per_rank = w.global_batch / dp;
            for &epr in &divisors(w.moe.total_experts) {
                if tp % epr != 0 || w.d_ff_expert() % (tp / epr) != 0 {
                    continue;
                }
                let moe = MoeConfig { experts_per_dp_rank: epr, ..w.moe };
                for &mb in &divisors(seqs_per_rank) {
                    let par = Parallelism { tp, pp, dp };
                    if let Ok(m) = Mapping::try_with_microbatch(par, moe, mb) {
                        out.push(m);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn paper_mapping(cfg: usize) -> Mapping {
        Mapping::new(Parallelism::paper(), MoeConfig::paper_config(cfg))
    }

    #[test]
    fn paper_dimensions() {
        let m = paper_mapping(4);
        assert_eq!(m.par.n_gpus(), 32_768);
        assert_eq!(m.ep_span_gpus(), 512);
        assert_eq!(m.n_complete_expert_sets(), 8);
        assert_eq!(m.expert_tp(), 2); // 16 / 8 experts per rank
        assert_eq!(paper_mapping(1).expert_tp(), 16);
    }

    #[test]
    fn ep_fits_passage_not_electrical() {
        use crate::topology::cluster::Cluster;
        let m = paper_mapping(1);
        assert!(m.ep_fits_pod(&Cluster::passage_512(32_768)));
        assert!(!m.ep_fits_pod(&Cluster::electrical_144(32_256)));
        assert_eq!(m.ep_domain(&Cluster::passage_512(32_768)), Domain::ScaleUp);
        assert_eq!(m.ep_domain(&Cluster::electrical_144(32_256)), Domain::ScaleOut);
    }

    #[test]
    fn mapping_is_bijective() {
        check("gpu_of/coord_of roundtrip", 256, |g| {
            let m = paper_mapping(*g.choose(&[1, 2, 3, 4]));
            let gpu = g.usize(0, m.par.n_gpus() - 1);
            let c = m.coord_of(gpu);
            prop_assert!(m.gpu_of(c) == gpu, "roundtrip failed at {gpu}");
            Ok(())
        });
    }

    #[test]
    fn tp_groups_are_contiguous() {
        check("tp group contiguity", 128, |g| {
            let m = paper_mapping(g.usize(1, 4));
            let dp = g.usize(0, m.par.dp - 1);
            let pp = g.usize(0, m.par.pp - 1);
            let grp = m.tp_group(dp, pp);
            for w in grp.windows(2) {
                prop_assert!(w[1] == w[0] + 1, "gap in tp group");
            }
            Ok(())
        });
    }

    #[test]
    fn ep_groups_partition_dp_ranks() {
        let m = paper_mapping(2);
        // Every GPU belongs to exactly one EP group per stage.
        let mut seen = vec![0u32; m.par.dp * m.par.tp];
        let w = m.ep_dp_ranks();
        for dp_block in (0..m.par.dp).step_by(w) {
            for gpu in m.ep_group(dp_block, 0) {
                seen[gpu] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn ep_group_span_is_contiguous() {
        check("ep group contiguous span", 64, |g| {
            let m = paper_mapping(g.usize(1, 4));
            let dp = g.usize(0, m.par.dp - 1);
            let pp = g.usize(0, m.par.pp - 1);
            let grp = m.ep_group(dp, pp);
            let min = *grp.iter().min().unwrap();
            let max = *grp.iter().max().unwrap();
            prop_assert!(grp.len() == m.ep_span_gpus(), "bad group size");
            prop_assert!(max - min + 1 == grp.len(), "EP group not contiguous");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "divide")]
    fn rejects_indivisible_expert_tp() {
        Mapping::new(
            Parallelism { tp: 4, pp: 1, dp: 32 },
            MoeConfig { total_experts: 24, active_per_token: 3, granularity: 3, experts_per_dp_rank: 3 },
        );
    }

    #[test]
    fn try_new_is_a_predicate_not_a_panic() {
        let moe = MoeConfig {
            total_experts: 24,
            active_per_token: 3,
            granularity: 3,
            experts_per_dp_rank: 3,
        };
        let bad = Mapping::try_new(Parallelism { tp: 4, pp: 1, dp: 32 }, moe);
        assert_eq!(
            bad,
            Err(MappingError::ExpertTpIndivisible { tp: 4, experts_per_dp_rank: 3 })
        );
        let short_dp = Mapping::try_new(Parallelism { tp: 6, pp: 1, dp: 12 }, moe);
        assert_eq!(short_dp, Err(MappingError::IncompleteEpGroups { dp: 12, ep_dp_ranks: 8 }));
        let par = Parallelism { tp: 6, pp: 1, dp: 16 };
        assert_eq!(
            Mapping::try_with_microbatch(par, moe, 0),
            Err(MappingError::ZeroDegree)
        );
        let ok = Mapping::try_with_microbatch(par, moe, 2).unwrap();
        assert_eq!(ok.microbatch_seqs, 2);
        assert_eq!(ok.expert_tp(), 2);
    }

    #[test]
    fn microbatch_defaults_to_one_and_builds() {
        let m = paper_mapping(4);
        assert_eq!(m.microbatch_seqs, 1);
        assert_eq!(m.clone().with_microbatch(4).microbatch_seqs, 4);
    }

    #[test]
    fn divisors_are_sorted_and_complete() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(144), vec![1, 2, 3, 4, 6, 8, 9, 12, 16, 18, 24, 36, 48, 72, 144]);
    }

    #[test]
    fn candidates_partition_cluster_and_satisfy_constraints() {
        use crate::model::Workload;
        use crate::topology::cluster::Cluster;
        let w = Workload::paper_gpt_4p7t(4);
        let cluster = Cluster::passage_512(32_768);
        let cands = enumerate_candidates(&w, &cluster);
        assert!(cands.len() > 100, "{}", cands.len());
        for m in &cands {
            assert_eq!(m.par.n_gpus(), cluster.spec.n_gpus);
            assert!(m.par.tp <= cluster.spec.pod_size);
            assert_eq!(w.n_heads % m.par.tp, 0);
            assert!(m.par.pp <= w.n_layers);
            assert_eq!(w.global_batch % m.par.dp, 0);
            assert_eq!((w.global_batch / m.par.dp) % m.microbatch_seqs, 0);
            assert_eq!(w.d_ff_expert() % m.expert_tp(), 0);
        }
        // The paper's own mapping is in the set.
        let paper = Mapping::new(Parallelism::paper(), w.moe);
        assert!(cands.contains(&paper));
    }
}
