//! 4D parallelism mapping: TP × DP × PP plus expert parallelism overlaid on
//! DP ranks (paper §V.B, Fig. 9).
//!
//! Placement policy (§VI): tensor-parallel groups are placed in the
//! high-bandwidth domain first; expert-parallel groups are placed there too
//! if the pod has room. The GPU id layout makes both policies geometric:
//! TP innermost (contiguous), then DP (so the `ep_dp_ranks` consecutive DP
//! ranks forming an EP group are contiguous GPUs), then PP outermost.

use crate::model::MoeConfig;
use crate::topology::cluster::{Cluster, Domain};

/// Degrees of the three base parallelism dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
}

impl Parallelism {
    /// The paper's fixed setup: TP 16 × PP 8 × DP 256 = 32,768 GPUs.
    pub fn paper() -> Self {
        Parallelism { tp: 16, pp: 8, dp: 256 }
    }

    pub fn n_gpus(&self) -> usize {
        self.tp * self.pp * self.dp
    }
}

/// Logical coordinates of one GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankCoord {
    pub dp: usize,
    pub pp: usize,
    pub tp: usize,
}

/// The rank mapping + MoE group structure.
#[derive(Debug, Clone)]
pub struct Mapping {
    pub par: Parallelism,
    pub moe: MoeConfig,
}

impl Mapping {
    pub fn new(par: Parallelism, moe: MoeConfig) -> Self {
        assert!(par.tp % moe.experts_per_dp_rank == 0,
                "tp {} must divide into experts_per_dp_rank {}",
                par.tp, moe.experts_per_dp_rank);
        assert!(par.dp % moe.ep_dp_ranks() == 0,
                "dp {} must contain whole EP groups of {} ranks",
                par.dp, moe.ep_dp_ranks());
        Mapping { par, moe }
    }

    /// GPU id for a coordinate (TP innermost, DP middle, PP outermost).
    pub fn gpu_of(&self, c: RankCoord) -> usize {
        assert!(c.dp < self.par.dp && c.pp < self.par.pp && c.tp < self.par.tp);
        (c.pp * self.par.dp + c.dp) * self.par.tp + c.tp
    }

    /// Inverse of `gpu_of`.
    pub fn coord_of(&self, gpu: usize) -> RankCoord {
        assert!(gpu < self.par.n_gpus());
        let tp = gpu % self.par.tp;
        let rest = gpu / self.par.tp;
        let dp = rest % self.par.dp;
        let pp = rest / self.par.dp;
        RankCoord { dp, pp, tp }
    }

    // -- group geometry ------------------------------------------------------

    /// GPUs of one tensor-parallel group (fixed dp, pp).
    pub fn tp_group(&self, dp: usize, pp: usize) -> Vec<usize> {
        (0..self.par.tp).map(|tp| self.gpu_of(RankCoord { dp, pp, tp })).collect()
    }

    /// Expert-TP subgroup size: the TP group is subdivided into
    /// `experts_per_dp_rank` groups, one per co-located expert (Fig. 9b).
    pub fn expert_tp(&self) -> usize {
        self.par.tp / self.moe.experts_per_dp_rank
    }

    /// Number of DP ranks in one EP group (one complete expert set).
    pub fn ep_dp_ranks(&self) -> usize {
        self.moe.ep_dp_ranks()
    }

    /// GPUs of the EP group containing DP rank `dp` at stage `pp`:
    /// `ep_dp_ranks` consecutive DP ranks × full TP width.
    pub fn ep_group(&self, dp: usize, pp: usize) -> Vec<usize> {
        let w = self.ep_dp_ranks();
        let start = dp / w * w;
        (start..start + w)
            .flat_map(|d| self.tp_group(d, pp))
            .collect()
    }

    /// Span of the EP group in consecutive GPU ids.
    pub fn ep_span_gpus(&self) -> usize {
        self.ep_dp_ranks() * self.par.tp
    }

    /// Complete expert sets in the system (gradient-sync replicas of each
    /// expert, §V.B).
    pub fn n_complete_expert_sets(&self) -> usize {
        self.par.dp / self.ep_dp_ranks()
    }

    /// Span (consecutive GPU ids) of a data-parallel gradient-sync group
    /// for the shared (attention) parameters: all DP ranks of a stage.
    pub fn dp_span_gpus(&self) -> usize {
        self.par.dp * self.par.tp
    }

    // -- placement / domain assignment ---------------------------------------

    /// Does the full EP group fit inside one scale-up pod?
    pub fn ep_fits_pod(&self, cluster: &Cluster) -> bool {
        self.ep_span_gpus() <= cluster.spec.pod_size
    }

    /// Domain carrying EP all-to-all traffic under the TP-first policy.
    pub fn ep_domain(&self, cluster: &Cluster) -> Domain {
        cluster.domain_for_span(self.ep_span_gpus())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn paper_mapping(cfg: usize) -> Mapping {
        Mapping::new(Parallelism::paper(), MoeConfig::paper_config(cfg))
    }

    #[test]
    fn paper_dimensions() {
        let m = paper_mapping(4);
        assert_eq!(m.par.n_gpus(), 32_768);
        assert_eq!(m.ep_span_gpus(), 512);
        assert_eq!(m.n_complete_expert_sets(), 8);
        assert_eq!(m.expert_tp(), 2); // 16 / 8 experts per rank
        assert_eq!(paper_mapping(1).expert_tp(), 16);
    }

    #[test]
    fn ep_fits_passage_not_electrical() {
        use crate::topology::cluster::Cluster;
        let m = paper_mapping(1);
        assert!(m.ep_fits_pod(&Cluster::passage_512(32_768)));
        assert!(!m.ep_fits_pod(&Cluster::electrical_144(32_256)));
        assert_eq!(m.ep_domain(&Cluster::passage_512(32_768)), Domain::ScaleUp);
        assert_eq!(m.ep_domain(&Cluster::electrical_144(32_256)), Domain::ScaleOut);
    }

    #[test]
    fn mapping_is_bijective() {
        check("gpu_of/coord_of roundtrip", 256, |g| {
            let m = paper_mapping(*g.choose(&[1, 2, 3, 4]));
            let gpu = g.usize(0, m.par.n_gpus() - 1);
            let c = m.coord_of(gpu);
            prop_assert!(m.gpu_of(c) == gpu, "roundtrip failed at {gpu}");
            Ok(())
        });
    }

    #[test]
    fn tp_groups_are_contiguous() {
        check("tp group contiguity", 128, |g| {
            let m = paper_mapping(g.usize(1, 4));
            let dp = g.usize(0, m.par.dp - 1);
            let pp = g.usize(0, m.par.pp - 1);
            let grp = m.tp_group(dp, pp);
            for w in grp.windows(2) {
                prop_assert!(w[1] == w[0] + 1, "gap in tp group");
            }
            Ok(())
        });
    }

    #[test]
    fn ep_groups_partition_dp_ranks() {
        let m = paper_mapping(2);
        // Every GPU belongs to exactly one EP group per stage.
        let mut seen = vec![0u32; m.par.dp * m.par.tp];
        let w = m.ep_dp_ranks();
        for dp_block in (0..m.par.dp).step_by(w) {
            for gpu in m.ep_group(dp_block, 0) {
                seen[gpu] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn ep_group_span_is_contiguous() {
        check("ep group contiguous span", 64, |g| {
            let m = paper_mapping(g.usize(1, 4));
            let dp = g.usize(0, m.par.dp - 1);
            let pp = g.usize(0, m.par.pp - 1);
            let grp = m.ep_group(dp, pp);
            let min = *grp.iter().min().unwrap();
            let max = *grp.iter().max().unwrap();
            prop_assert!(grp.len() == m.ep_span_gpus(), "bad group size");
            prop_assert!(max - min + 1 == grp.len(), "EP group not contiguous");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "divide")]
    fn rejects_indivisible_expert_tp() {
        Mapping::new(
            Parallelism { tp: 4, pp: 1, dp: 32 },
            MoeConfig { total_experts: 24, active_per_token: 3, granularity: 3, experts_per_dp_rank: 3 },
        );
    }
}
