//! AOT artifact bundle: `manifest.json` + per-entrypoint HLO text files, as
//! written by `python/compile/aot.py`. This is the only contract between the
//! build-time python stack and the runtime rust stack.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::TensorSpec;
use crate::util::json::Json;

/// One lowered entrypoint (init / train_step / ...).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed manifest for one model variant (e.g. `artifacts/tiny`).
#[derive(Debug, Clone)]
pub struct Artifact {
    pub dir: PathBuf,
    pub n_params: usize,
    pub total_param_elements: usize,
    pub param_names: Vec<String>,
    pub entrypoints: BTreeMap<String, EntrySpec>,
    /// Raw model config echo (vocab, d_model, n_experts, ...).
    pub config: Json,
}

impl Artifact {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifact> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        if j.get("format").as_str() != Some("hlo-text-v1") {
            bail!("unsupported manifest format {:?}", j.get("format"));
        }
        let n_params = j
            .get("n_params")
            .as_usize()
            .ok_or_else(|| anyhow!("manifest missing n_params"))?;
        let total_param_elements = j
            .get("total_param_elements")
            .as_usize()
            .ok_or_else(|| anyhow!("manifest missing total_param_elements"))?;
        let param_names: Vec<String> = j
            .get("param_names")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing param_names"))?
            .iter()
            .map(|v| v.as_str().map(str::to_string).ok_or_else(|| anyhow!("bad param name")))
            .collect::<Result<_>>()?;
        if param_names.len() != n_params {
            bail!("param_names len {} != n_params {}", param_names.len(), n_params);
        }

        let mut entrypoints = BTreeMap::new();
        let eps = j
            .get("entrypoints")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing entrypoints"))?;
        for (name, spec) in eps {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                spec.get(key)
                    .as_arr()
                    .ok_or_else(|| anyhow!("entry '{name}' missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let entry = EntrySpec {
                name: name.clone(),
                file: spec
                    .get("file")
                    .as_str()
                    .ok_or_else(|| anyhow!("entry '{name}' missing file"))?
                    .to_string(),
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
            };
            let hlo = dir.join(&entry.file);
            if !hlo.exists() {
                bail!("entry '{name}': HLO file {} missing", hlo.display());
            }
            entrypoints.insert(name.clone(), entry);
        }

        Ok(Artifact {
            dir,
            n_params,
            total_param_elements,
            param_names,
            entrypoints,
            config: j.get("config").clone(),
        })
    }

    /// The in-memory manifest of the pure-Rust host miniature (see
    /// [`crate::runtime::host`]): same entrypoint names, state layout
    /// and config keys as an on-disk artifact, but nothing on disk —
    /// `file` fields carry the `"<builtin>"` sentinel and
    /// [`Artifact::hlo_path`] must never be consulted (the host engine
    /// does not).
    pub fn host_miniature() -> Artifact {
        Self::host_with(crate::runtime::host::HostCfg::miniature())
    }

    /// [`Artifact::host_miniature`] with explicit model dims.
    pub fn host_with(cfg: crate::runtime::host::HostCfg) -> Artifact {
        let entrypoints = crate::runtime::host::entry_specs(&cfg);
        let param_names: Vec<String> =
            cfg.param_shapes().into_iter().map(|(n, _)| n.to_string()).collect();
        let num = |v: usize| Json::num(v as f64);
        Artifact {
            dir: PathBuf::from("<host>"),
            n_params: param_names.len(),
            total_param_elements: cfg.total_param_elements(),
            param_names,
            entrypoints,
            config: Json::obj(vec![
                ("vocab", num(cfg.vocab)),
                ("d_model", num(cfg.d_model)),
                ("d_ff", num(cfg.d_ff)),
                ("n_experts", num(cfg.n_experts)),
                ("top_k", num(cfg.top_k)),
                ("batch", num(cfg.batch)),
                ("seq_len", num(cfg.seq_len)),
            ]),
        }
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entrypoints
            .get(name)
            .ok_or_else(|| anyhow!("artifact has no entrypoint '{name}' (have: {:?})",
                                   self.entrypoints.keys().collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Convenience accessors into the echoed model config.
    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .as_usize()
            .ok_or_else(|| anyhow!("model config missing '{key}'"))
    }

    /// State layout helper: the flat state is [params, m, v, step].
    pub fn state_len(&self) -> usize {
        3 * self.n_params + 1
    }
}

/// Locate the artifacts root: $LUMOS_ARTIFACTS or ./artifacts relative to cwd
/// (walking up a couple of levels so tests work from target dirs).
pub fn artifacts_root() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("LUMOS_ARTIFACTS") {
        return Ok(PathBuf::from(p));
    }
    let mut dir = std::env::current_dir()?;
    for _ in 0..4 {
        let cand = dir.join("artifacts");
        if cand.join("tiny").join("manifest.json").exists()
            || cand.join("e2e").join("manifest.json").exists()
        {
            return Ok(cand);
        }
        if !dir.pop() {
            break;
        }
    }
    bail!("artifacts/ not found; run `make artifacts` (or set LUMOS_ARTIFACTS)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_artifact(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("fn.hlo.txt"), "HloModule fake").unwrap();
        let manifest = r#"{
            "format": "hlo-text-v1",
            "n_params": 2,
            "total_param_elements": 10,
            "param_names": ["a", "b"],
            "config": {"d_model": 8},
            "entrypoints": {
                "fn": {
                    "file": "fn.hlo.txt",
                    "inputs": [{"name": "x", "shape": [2], "dtype": "f32"}],
                    "outputs": [{"name": "y", "shape": [2], "dtype": "f32"}]
                }
            }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lumos-artifact-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn host_miniature_is_a_complete_artifact() {
        let a = Artifact::host_miniature();
        assert_eq!(a.n_params, 7);
        assert_eq!(a.state_len(), 22);
        assert_eq!(a.param_names.len(), a.n_params);
        for name in ["init", "grad_step", "apply_update", "train_step"] {
            let e = a.entry(name).unwrap();
            assert_eq!(e.file, "<builtin>");
        }
        assert_eq!(a.cfg_usize("batch").unwrap(), 2);
        assert_eq!(a.cfg_usize("seq_len").unwrap(), 16);
        assert_eq!(a.cfg_usize("vocab").unwrap(), 64);
        let init = a.entry("init").unwrap();
        assert_eq!(init.outputs.len(), a.state_len());
        let total: usize = init.outputs[..a.n_params]
            .iter()
            .map(|s| s.elements())
            .sum();
        assert_eq!(total, a.total_param_elements);
    }

    #[test]
    fn loads_valid_manifest() {
        let d = tmpdir("ok");
        write_fake_artifact(&d);
        let a = Artifact::load(&d).unwrap();
        assert_eq!(a.n_params, 2);
        assert_eq!(a.state_len(), 7);
        assert_eq!(a.cfg_usize("d_model").unwrap(), 8);
        let e = a.entry("fn").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2]);
        assert!(a.entry("nope").is_err());
    }

    #[test]
    fn missing_hlo_file_is_error() {
        let d = tmpdir("missing");
        write_fake_artifact(&d);
        std::fs::remove_file(d.join("fn.hlo.txt")).unwrap();
        let err = Artifact::load(&d).unwrap_err();
        assert!(err.to_string().contains("missing"));
    }

    #[test]
    fn bad_format_is_error() {
        let d = tmpdir("badfmt");
        write_fake_artifact(&d);
        let text = std::fs::read_to_string(d.join("manifest.json"))
            .unwrap()
            .replace("hlo-text-v1", "hlo-text-v9");
        std::fs::write(d.join("manifest.json"), text).unwrap();
        assert!(Artifact::load(&d).is_err());
    }
}
