//! Execution engine: compile artifacts once, execute many times from the
//! (Python-free) hot path.
//!
//! Two backends behind one API:
//!
//! - **PJRT** ([`Engine::cpu`]) wraps the `xla` crate (xla_extension
//!   0.5.1, CPU PJRT): `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `client.compile` → `execute`.
//! - **Host** ([`Engine::host`]) dispatches the same entry names to the
//!   pure-Rust miniature in [`crate::runtime::host`] — no PJRT, no HLO
//!   files, same manifest-validated [`Tensor`] contract.
//!
//! Per-entry [`EntryStats`] count compiles, cache hits and executions
//! with wall time routed through the quarantined
//! [`crate::obs::record::Stopwatch`] capture helper (`lumos run --json`
//! surfaces them under `"metrics"`).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::obs::record::Stopwatch;
use crate::runtime::artifact::{Artifact, EntrySpec};
use crate::runtime::host::{self, HostCfg, HostEntry};
use crate::runtime::tensor::Tensor;
use crate::util::sync::lock;

/// Global lock serializing every call into the `xla` crate.
///
/// SAFETY CONTRACT: the crate's wrappers hold `Rc<PjRtClientInternal>`
/// (non-atomic refcounts) and raw C pointers, so they are not thread-safe
/// by construction even though the underlying PJRT C++ client is. All
/// refcount mutations happen inside `Engine::load` and
/// `CompiledEntry::execute`, which take this lock for their whole body and
/// return only plain host data ([`Tensor`]). That makes the `unsafe impl
/// Send/Sync` below sound: the wrapped values are never touched
/// concurrently. (The coordinator's DP workers lose no real parallelism —
/// XLA:CPU already parallelizes one execution across cores. The host
/// backend holds no xla values and never takes this lock.)
static XLA_LOCK: Mutex<()> = Mutex::new(());

/// Shared backend + compile cache. Cheap to clone.
#[derive(Clone)]
pub struct Engine {
    inner: Arc<EngineInner>,
}

// SAFETY: see XLA_LOCK.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
// SAFETY: see XLA_LOCK.
unsafe impl Send for CompiledEntry {}
unsafe impl Sync for CompiledEntry {}

enum Backend {
    Pjrt(xla::PjRtClient),
    Host,
}

struct EngineInner {
    backend: Backend,
    /// entry name -> compiled executable (compilation is expensive; cache).
    cache: Mutex<BTreeMap<String, Arc<CompiledEntry>>>,
}

enum EntryExe {
    Pjrt(xla::PjRtLoadedExecutable),
    Host { kind: HostEntry, cfg: HostCfg },
}

/// A compiled entrypoint bound to its manifest spec.
pub struct CompiledEntry {
    pub spec: EntrySpec,
    exe: EntryExe,
    /// Execution statistics (for EXPERIMENTS.md §Perf and `run --json`).
    stats: Mutex<EntryStats>,
}

#[derive(Debug, Clone, Default)]
pub struct EntryStats {
    pub executions: u64,
    pub total_secs: f64,
    /// Times this entry was actually compiled/bound (1 per cache entry).
    pub compiles: u64,
    /// Cache hits served by [`Engine::load`] after the first load.
    pub cache_hits: u64,
}

/// Read the host-miniature model dims out of an artifact's config echo.
fn host_cfg(artifact: &Artifact) -> Result<HostCfg> {
    Ok(HostCfg {
        vocab: artifact.cfg_usize("vocab")?,
        d_model: artifact.cfg_usize("d_model")?,
        d_ff: artifact.cfg_usize("d_ff")?,
        n_experts: artifact.cfg_usize("n_experts")?,
        top_k: artifact.cfg_usize("top_k")?,
        batch: artifact.cfg_usize("batch")?,
        seq_len: artifact.cfg_usize("seq_len")?,
    })
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            inner: Arc::new(EngineInner {
                backend: Backend::Pjrt(client),
                cache: Mutex::new(BTreeMap::new()),
            }),
        })
    }

    /// Create the pure-Rust host engine (always available; see
    /// [`crate::runtime::host`]).
    pub fn host() -> Engine {
        Engine {
            inner: Arc::new(EngineInner {
                backend: Backend::Host,
                cache: Mutex::new(BTreeMap::new()),
            }),
        }
    }

    pub fn platform(&self) -> String {
        match &self.inner.backend {
            Backend::Pjrt(client) => client.platform_name(),
            Backend::Host => "host".to_string(),
        }
    }

    /// Load + compile an entrypoint (cached per engine by artifact-dir+name).
    pub fn load(&self, artifact: &Artifact, entry_name: &str) -> Result<Arc<CompiledEntry>> {
        let entry = artifact.entry(entry_name)?.clone();
        let key = format!("{}::{}", artifact.dir.display(), entry_name);
        if let Some(hit) = lock(&self.inner.cache).get(&key) {
            lock(&hit.stats).cache_hits += 1;
            return Ok(hit.clone());
        }
        let mut stats = EntryStats { compiles: 1, ..EntryStats::default() };
        let exe = match &self.inner.backend {
            Backend::Host => EntryExe::Host {
                kind: HostEntry::from_name(entry_name)?,
                cfg: host_cfg(artifact)?,
            },
            Backend::Pjrt(client) => {
                let _xla = lock(&XLA_LOCK);
                let path = artifact.hlo_path(&entry);
                let mut watch = Stopwatch::start();
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 artifact path")?,
                )
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling entry '{entry_name}'"))?;
                let compile_secs = watch.lap();
                stats.total_secs += compile_secs;
                eprintln!(
                    "[runtime] compiled '{entry_name}' ({}) in {compile_secs:.2}s",
                    path.file_name().unwrap_or_default().to_string_lossy(),
                );
                EntryExe::Pjrt(exe)
            }
        };
        let compiled =
            Arc::new(CompiledEntry { spec: entry, exe, stats: Mutex::new(stats) });
        lock(&self.inner.cache).insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Snapshot of every cached entry's stats, in cache-key order (the
    /// `"metrics"` payload of `lumos run --json`).
    pub fn entry_stats(&self) -> Vec<(String, EntryStats)> {
        lock(&self.inner.cache)
            .iter()
            .map(|(k, v)| (k.clone(), v.stats()))
            .collect()
    }
}

/// Opaque host-side value kept in XLA literal form (no Vec<f32> copies).
/// The fast path for step loops: feed the previous step's outputs straight
/// back in. Use [`CompiledEntry::execute_literals`] to produce/consume.
pub struct LitVal(pub(crate) xla::Literal);

// SAFETY: see XLA_LOCK — literals are plain host buffers with no shared
// refcounts; creation/consumption happens under the lock.
unsafe impl Send for LitVal {}
unsafe impl Sync for LitVal {}

impl LitVal {
    pub fn from_tensor(t: &Tensor) -> Result<LitVal> {
        Ok(LitVal(t.to_literal()?))
    }

    pub fn to_tensor(&self) -> Result<Tensor> {
        Tensor::from_literal(&self.0)
    }

    /// Scalar fast path (losses/metrics) without full conversion.
    pub fn scalar_f32(&self) -> Result<f64> {
        Ok(self.0.get_first_element::<f32>()? as f64)
    }
}

impl CompiledEntry {
    fn record_execution(&self, elapsed: f64) {
        let mut st = lock(&self.stats);
        st.executions += 1;
        st.total_secs += elapsed;
    }

    /// Execute with literal-form values: the hot-loop path. Skips the
    /// Tensor<->Vec conversions of [`CompiledEntry::execute`] on PJRT
    /// (the remaining copies are PJRT's own host<->device transfers); on
    /// the host backend it simply round-trips through [`Tensor`].
    /// Arity is checked; shapes are trusted (they come from a previous
    /// execution or a validated tensor).
    pub fn execute_literals(&self, inputs: &[&LitVal]) -> Result<Vec<LitVal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "entry '{}': got {} inputs, manifest expects {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let exe = match &self.exe {
            EntryExe::Host { kind, cfg } => {
                let tensors: Vec<Tensor> =
                    inputs.iter().map(|v| v.to_tensor()).collect::<Result<_>>()?;
                let mut watch = Stopwatch::start();
                let out = host::execute_entry(cfg, *kind, &tensors)?;
                self.record_execution(watch.lap());
                return out.iter().map(LitVal::from_tensor).collect();
            }
            EntryExe::Pjrt(exe) => exe,
        };
        let _xla = lock(&XLA_LOCK);
        let literals: Vec<&xla::Literal> = inputs.iter().map(|v| &v.0).collect();
        let mut watch = Stopwatch::start();
        let mut replicas = exe.execute::<&xla::Literal>(&literals)?;
        self.record_execution(watch.lap());
        if replicas.is_empty() || replicas[0].is_empty() {
            bail!("entry '{}': empty execution result", self.spec.name);
        }
        let outputs = replicas.remove(0);
        let mut out = Vec::with_capacity(self.spec.outputs.len());
        if outputs.len() == 1 && self.spec.outputs.len() != 1 {
            let mut root = outputs[0].to_literal_sync()?;
            out.extend(root.decompose_tuple()?.into_iter().map(LitVal));
        } else {
            for buf in &outputs {
                let mut lit = buf.to_literal_sync()?;
                match lit.decompose_tuple() {
                    Ok(elems) if !elems.is_empty() => out.extend(elems.into_iter().map(LitVal)),
                    _ => out.push(LitVal(lit)),
                }
            }
        }
        if out.len() != self.spec.outputs.len() {
            bail!(
                "entry '{}': got {} outputs, manifest expects {}",
                self.spec.name,
                out.len(),
                self.spec.outputs.len()
            );
        }
        Ok(out)
    }

    /// Execute with host tensors, validating shapes/dtypes against the
    /// manifest, and return host tensors (tuple outputs are flattened).
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "entry '{}': got {} inputs, manifest expects {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if !t.matches(s) {
                bail!(
                    "entry '{}': input '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        let tensors = match &self.exe {
            EntryExe::Host { kind, cfg } => {
                let mut watch = Stopwatch::start();
                let out = host::execute_entry(cfg, *kind, inputs)?;
                self.record_execution(watch.lap());
                out
            }
            EntryExe::Pjrt(exe) => {
                let _xla = lock(&XLA_LOCK);
                let literals: Vec<xla::Literal> = inputs
                    .iter()
                    .map(Tensor::to_literal)
                    .collect::<Result<_>>()?;

                let mut watch = Stopwatch::start();
                let mut replicas = exe.execute::<xla::Literal>(&literals)?;
                self.record_execution(watch.lap());

                if replicas.is_empty() || replicas[0].is_empty() {
                    bail!("entry '{}': empty execution result", self.spec.name);
                }
                let outputs = replicas.remove(0);

                // jax lowers with return_tuple=True: a single tuple buffer comes
                // back; decompose it into the manifest's flattened outputs. If the
                // runtime ever hands back untupled buffers, pass them through.
                let mut literals_out: Vec<xla::Literal> =
                    Vec::with_capacity(self.spec.outputs.len());
                if outputs.len() == 1 && self.spec.outputs.len() != 1 {
                    let mut root = outputs[0].to_literal_sync()?;
                    literals_out.extend(root.decompose_tuple()?);
                } else {
                    for buf in &outputs {
                        let mut lit = buf.to_literal_sync()?;
                        // A 1-output entry lowered with return_tuple=True still
                        // wraps the value in a 1-tuple.
                        match lit.decompose_tuple() {
                            Ok(elems) if !elems.is_empty() => literals_out.extend(elems),
                            _ => literals_out.push(lit),
                        }
                    }
                }
                if literals_out.len() != self.spec.outputs.len() {
                    bail!(
                        "entry '{}': got {} outputs, manifest expects {}",
                        self.spec.name,
                        literals_out.len(),
                        self.spec.outputs.len()
                    );
                }
                literals_out
                    .iter()
                    .map(Tensor::from_literal)
                    .collect::<Result<Vec<Tensor>>>()?
            }
        };
        for (t, s) in tensors.iter().zip(&self.spec.outputs) {
            if !t.matches(s) {
                bail!(
                    "entry '{}': output '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        Ok(tensors)
    }

    pub fn stats(&self) -> EntryStats {
        lock(&self.stats).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Artifact;

    #[test]
    fn host_engine_runs_the_trainer_contract() {
        let engine = Engine::host();
        assert_eq!(engine.platform(), "host");
        let art = Artifact::host_miniature();
        let init = engine.load(&art, "init").unwrap();
        let state = init.execute(&[Tensor::scalar_u32(1)]).unwrap();
        assert_eq!(state.len(), art.state_len());
        // cache: second load of the same entry is a hit
        let again = engine.load(&art, "init").unwrap();
        let st = again.stats();
        assert_eq!(st.compiles, 1);
        assert_eq!(st.cache_hits, 1);
        assert_eq!(st.executions, 1);
        assert!(st.total_secs >= 0.0);
        let stats = engine.entry_stats();
        assert_eq!(stats.len(), 1);
        assert!(stats[0].0.ends_with("::init"));
    }

    #[test]
    fn host_engine_rejects_bad_shapes() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let init = engine.load(&art, "init").unwrap();
        let err = init.execute(&[Tensor::scalar_i32(1)]).unwrap_err();
        assert!(err.to_string().contains("expects"));
        assert!(engine.load(&art, "nope").is_err());
    }

    #[test]
    fn host_engine_literal_path_matches_tensor_path() {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let init = engine.load(&art, "init").unwrap();
        let seed = Tensor::scalar_u32(5);
        let direct = init.execute(&[seed.clone()]).unwrap();
        let lit = LitVal::from_tensor(&seed).unwrap();
        let via_lit = init.execute_literals(&[&lit]).unwrap();
        assert_eq!(via_lit.len(), direct.len());
        for (a, b) in via_lit.iter().zip(&direct) {
            assert_eq!(&a.to_tensor().unwrap(), b);
        }
    }
}
