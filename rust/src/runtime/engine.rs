//! PJRT execution engine: compile HLO-text artifacts once, execute many
//! times from the (Python-free) hot path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::{Artifact, EntrySpec};
use crate::runtime::tensor::Tensor;
use crate::util::sync::lock;

/// Global lock serializing every call into the `xla` crate.
///
/// SAFETY CONTRACT: the crate's wrappers hold `Rc<PjRtClientInternal>`
/// (non-atomic refcounts) and raw C pointers, so they are not thread-safe
/// by construction even though the underlying PJRT C++ client is. All
/// refcount mutations happen inside `Engine::load` and
/// `CompiledEntry::execute`, which take this lock for their whole body and
/// return only plain host data ([`Tensor`]). That makes the `unsafe impl
/// Send/Sync` below sound: the wrapped values are never touched
/// concurrently. (The coordinator's DP workers lose no real parallelism —
/// XLA:CPU already parallelizes one execution across cores.)
static XLA_LOCK: Mutex<()> = Mutex::new(());

/// Shared PJRT client + compile cache. Cheap to clone.
#[derive(Clone)]
pub struct Engine {
    inner: Arc<EngineInner>,
}

// SAFETY: see XLA_LOCK.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
// SAFETY: see XLA_LOCK.
unsafe impl Send for CompiledEntry {}
unsafe impl Sync for CompiledEntry {}

struct EngineInner {
    client: xla::PjRtClient,
    /// entry name -> compiled executable (compilation is expensive; cache).
    cache: Mutex<BTreeMap<String, Arc<CompiledEntry>>>,
}

/// A compiled entrypoint bound to its manifest spec.
pub struct CompiledEntry {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
    /// Execution statistics (for EXPERIMENTS.md §Perf).
    stats: Mutex<EntryStats>,
}

#[derive(Debug, Clone, Default)]
pub struct EntryStats {
    pub executions: u64,
    pub total_secs: f64,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            inner: Arc::new(EngineInner { client, cache: Mutex::new(BTreeMap::new()) }),
        })
    }

    pub fn platform(&self) -> String {
        self.inner.client.platform_name()
    }

    /// Load + compile an entrypoint (cached per engine by artifact-dir+name).
    pub fn load(&self, artifact: &Artifact, entry_name: &str) -> Result<Arc<CompiledEntry>> {
        let entry = artifact.entry(entry_name)?.clone();
        let key = format!("{}::{}", artifact.dir.display(), entry_name);
        if let Some(hit) = lock(&self.inner.cache).get(&key) {
            return Ok(hit.clone());
        }
        let _xla = lock(&XLA_LOCK);
        let path = artifact.hlo_path(&entry);
        // lumos: allow(wallclock) -- compile-time reporting to stderr, not part of any result
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .client
            .compile(&comp)
            .with_context(|| format!("compiling entry '{entry_name}'"))?;
        let compiled = Arc::new(CompiledEntry {
            spec: entry,
            exe,
            stats: Mutex::new(EntryStats::default()),
        });
        eprintln!(
            "[runtime] compiled '{entry_name}' ({}) in {:.2}s",
            path.file_name().unwrap_or_default().to_string_lossy(),
            t0.elapsed().as_secs_f64()
        );
        lock(&self.inner.cache).insert(key, compiled.clone());
        Ok(compiled)
    }
}

/// Opaque host-side value kept in XLA literal form (no Vec<f32> copies).
/// The fast path for step loops: feed the previous step's outputs straight
/// back in. Use [`CompiledEntry::execute_literals`] to produce/consume.
pub struct LitVal(pub(crate) xla::Literal);

// SAFETY: see XLA_LOCK — literals are plain host buffers with no shared
// refcounts; creation/consumption happens under the lock.
unsafe impl Send for LitVal {}
unsafe impl Sync for LitVal {}

impl LitVal {
    pub fn from_tensor(t: &Tensor) -> Result<LitVal> {
        Ok(LitVal(t.to_literal()?))
    }

    pub fn to_tensor(&self) -> Result<Tensor> {
        Tensor::from_literal(&self.0)
    }

    /// Scalar fast path (losses/metrics) without full conversion.
    pub fn scalar_f32(&self) -> Result<f64> {
        Ok(self.0.get_first_element::<f32>()? as f64)
    }
}

impl CompiledEntry {
    /// Execute with literal-form values: the hot-loop path. Skips the
    /// Tensor<->Vec conversions of [`CompiledEntry::execute`] (the
    /// remaining copies are PJRT's own host<->device transfers).
    /// Arity is checked; shapes are trusted (they come from a previous
    /// execution or a validated tensor).
    pub fn execute_literals(&self, inputs: &[&LitVal]) -> Result<Vec<LitVal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "entry '{}': got {} inputs, manifest expects {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let _xla = lock(&XLA_LOCK);
        let literals: Vec<&xla::Literal> = inputs.iter().map(|v| &v.0).collect();
        // lumos: allow(wallclock) -- EntryStats execution timing is the measurement payload
        let t0 = Instant::now();
        let mut replicas = self.exe.execute::<&xla::Literal>(&literals)?;
        let elapsed = t0.elapsed().as_secs_f64();
        {
            let mut st = lock(&self.stats);
            st.executions += 1;
            st.total_secs += elapsed;
        }
        if replicas.is_empty() || replicas[0].is_empty() {
            bail!("entry '{}': empty execution result", self.spec.name);
        }
        let outputs = replicas.remove(0);
        let mut out = Vec::with_capacity(self.spec.outputs.len());
        if outputs.len() == 1 && self.spec.outputs.len() != 1 {
            let mut root = outputs[0].to_literal_sync()?;
            out.extend(root.decompose_tuple()?.into_iter().map(LitVal));
        } else {
            for buf in &outputs {
                let mut lit = buf.to_literal_sync()?;
                match lit.decompose_tuple() {
                    Ok(elems) if !elems.is_empty() => out.extend(elems.into_iter().map(LitVal)),
                    _ => out.push(LitVal(lit)),
                }
            }
        }
        if out.len() != self.spec.outputs.len() {
            bail!(
                "entry '{}': got {} outputs, manifest expects {}",
                self.spec.name,
                out.len(),
                self.spec.outputs.len()
            );
        }
        Ok(out)
    }

    /// Execute with host tensors, validating shapes/dtypes against the
    /// manifest, and return host tensors (tuple outputs are flattened).
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "entry '{}': got {} inputs, manifest expects {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if !t.matches(s) {
                bail!(
                    "entry '{}': input '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        let _xla = lock(&XLA_LOCK);
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<_>>()?;

        // lumos: allow(wallclock) -- EntryStats execution timing is the measurement payload
        let t0 = Instant::now();
        let mut replicas = self.exe.execute::<xla::Literal>(&literals)?;
        let elapsed = t0.elapsed().as_secs_f64();
        {
            let mut st = lock(&self.stats);
            st.executions += 1;
            st.total_secs += elapsed;
        }

        if replicas.is_empty() || replicas[0].is_empty() {
            bail!("entry '{}': empty execution result", self.spec.name);
        }
        let outputs = replicas.remove(0);

        // jax lowers with return_tuple=True: a single tuple buffer comes
        // back; decompose it into the manifest's flattened outputs. If the
        // runtime ever hands back untupled buffers, pass them through.
        let mut literals_out: Vec<xla::Literal> = Vec::with_capacity(self.spec.outputs.len());
        if outputs.len() == 1 && self.spec.outputs.len() != 1 {
            let mut root = outputs[0].to_literal_sync()?;
            literals_out.extend(root.decompose_tuple()?);
        } else {
            for buf in &outputs {
                let mut lit = buf.to_literal_sync()?;
                // A 1-output entry lowered with return_tuple=True still
                // wraps the value in a 1-tuple.
                match lit.decompose_tuple() {
                    Ok(elems) if !elems.is_empty() => literals_out.extend(elems),
                    _ => literals_out.push(lit),
                }
            }
        }
        if literals_out.len() != self.spec.outputs.len() {
            bail!(
                "entry '{}': got {} outputs, manifest expects {}",
                self.spec.name,
                literals_out.len(),
                self.spec.outputs.len()
            );
        }
        let tensors: Vec<Tensor> = literals_out
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<_>>()?;
        for (t, s) in tensors.iter().zip(&self.spec.outputs) {
            if !t.matches(s) {
                bail!(
                    "entry '{}': output '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        Ok(tensors)
    }

    pub fn stats(&self) -> EntryStats {
        lock(&self.stats).clone()
    }
}
