//! Host-side tensors bridged to/from `xla::Literal`.
//!
//! Only the dtypes the AOT manifest emits (f32, i32, u32) are supported;
//! everything else is an explicit error rather than silent reinterpretation.

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of a manifest tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn from_manifest(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            other => bail!("unsupported manifest dtype '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Shape + dtype of one executable input/output, parsed from manifest.json.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn from_json(j: &Json) -> Result<Self> {
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("spec missing name"))?
            .to_string();
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("spec '{name}' missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim in '{name}'")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::from_manifest(
            j.get("dtype").as_str().ok_or_else(|| anyhow!("spec '{name}' missing dtype"))?,
        )?;
        Ok(Self { name, shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U32(Vec<u32>, Vec<usize>),
}

impl Tensor {
    pub fn zeros(spec: &TensorSpec) -> Tensor {
        let n = spec.elements();
        match spec.dtype {
            DType::F32 => Tensor::F32(vec![0.0; n], spec.shape.clone()),
            DType::I32 => Tensor::I32(vec![0; n], spec.shape.clone()),
            DType::U32 => Tensor::U32(vec![0; n], spec.shape.clone()),
        }
    }

    pub fn scalar_u32(v: u32) -> Tensor {
        Tensor::U32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::I32(vec![v], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) | Tensor::U32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32(..) => DType::F32,
            Tensor::I32(..) => DType::I32,
            Tensor::U32(..) => DType::U32,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            other => bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    /// First element as f64 (for scalar losses/metrics).
    pub fn scalar_value(&self) -> Result<f64> {
        match self {
            Tensor::F32(d, _) => Ok(*d.first().context("empty tensor")? as f64),
            Tensor::I32(d, _) => Ok(*d.first().context("empty tensor")? as f64),
            Tensor::U32(d, _) => Ok(*d.first().context("empty tensor")? as f64),
        }
    }

    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.dtype() == spec.dtype && self.shape() == spec.shape.as_slice()
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(d, _) => xla::Literal::vec1(d),
            Tensor::I32(d, _) => xla::Literal::vec1(d),
            Tensor::U32(d, _) => xla::Literal::vec1(d),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Convert from an XLA literal (copies).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        use xla::ElementType as E;
        match shape.ty() {
            E::F32 => Ok(Tensor::F32(lit.to_vec()?, dims)),
            E::S32 => Ok(Tensor::I32(lit.to_vec()?, dims)),
            E::U32 => Ok(Tensor::U32(lit.to_vec()?, dims)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec { name: "t".into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn spec_parses_from_json() {
        let j = Json::parse(r#"{"name": "w", "shape": [2, 3], "dtype": "f32"}"#).unwrap();
        let s = TensorSpec::from_json(&j).unwrap();
        assert_eq!(s.shape, vec![2, 3]);
        assert_eq!(s.dtype, DType::F32);
        assert_eq!(s.elements(), 6);
    }

    #[test]
    fn spec_rejects_bad_dtype() {
        let j = Json::parse(r#"{"name": "w", "shape": [], "dtype": "f64"}"#).unwrap();
        assert!(TensorSpec::from_json(&j).is_err());
    }

    #[test]
    fn zeros_matches_spec() {
        let s = spec(&[4, 2], DType::I32);
        let t = Tensor::zeros(&s);
        assert!(t.matches(&s));
        assert_eq!(t.elements(), 8);
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let t = Tensor::zeros(&spec(&[2], DType::F32));
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_scalar_u32() {
        let t = Tensor::scalar_u32(7);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::I32(vec![-1, 0, 5], vec![3]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }
}
