//! Pure-Rust host backend: a miniature MoE language model whose
//! entrypoints mirror the AOT artifact contract (`init` / `grad_step` /
//! `apply_update` / `train_step`), so the whole `trainer` stack — and
//! `lumos run`'s planner-mapped driver — executes offline, with no PJRT
//! and no `artifacts/` directory.
//!
//! The model is one MoE block: token embedding → softmax gate → top-k of
//! `n_experts` two-layer ReLU experts (gate-weighted, renormalized over
//! the selected k) → residual → tied-style output projection →
//! cross-entropy on the next token. The backward pass is exact manual
//! backprop, *including* the gate path (renormalized-top-k jacobian
//! through the softmax); the only non-differentiated term is the
//! switch-style load-balance metric reported as `aux` (matching how the
//! seed's Python model reports but does not weight it). A
//! finite-difference check in the unit tests pins every parameter
//! tensor's gradient.
//!
//! Token-level pieces (embed / gate / expert forward / combine / output
//! CE) are public so `trainer::mapped` can run the *same* math split
//! across ranks — dispatching real expert payloads through
//! `coordinator::comm` — and assert the distributed forward agrees with
//! the fused entry.
//!
//! Everything is `f64` internally and `f32` at the tensor boundary, and
//! nothing here reads a clock or ambient entropy: `init` derives all
//! parameters from the seed via [`crate::util::rng::Rng`].

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::runtime::artifact::EntrySpec;
use crate::runtime::tensor::{DType, Tensor, TensorSpec};
use crate::util::rng::Rng;

/// Parameter tensor order (the flat state is `[params, m, v, step]`).
pub const N_PARAMS: usize = 7;
const P_EMBED: usize = 0;
const P_WG: usize = 1;
const P_W1: usize = 2;
const P_B1: usize = 3;
const P_W2: usize = 4;
const P_B2: usize = 5;
const P_WO: usize = 6;

/// Adam hyperparameters (fixed, like the AOT artifacts bake theirs in).
const LR: f64 = 1e-2;
const BETA1: f64 = 0.9;
const BETA2: f64 = 0.999;
const EPS: f64 = 1e-8;

/// Model dimensions of the host miniature.
#[derive(Debug, Clone, Copy)]
pub struct HostCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub batch: usize,
    pub seq_len: usize,
}

impl HostCfg {
    /// The default host-executable miniature (~10.8k parameters).
    pub fn miniature() -> HostCfg {
        HostCfg { vocab: 64, d_model: 16, d_ff: 32, n_experts: 8, top_k: 2, batch: 2, seq_len: 16 }
    }

    /// `(name, shape)` of each parameter tensor, in state order.
    pub fn param_shapes(&self) -> Vec<(&'static str, Vec<usize>)> {
        let (v, d, f, e) = (self.vocab, self.d_model, self.d_ff, self.n_experts);
        vec![
            ("embed", vec![v, d]),
            ("router/wg", vec![e, d]),
            ("experts/w1", vec![e, f, d]),
            ("experts/b1", vec![e, f]),
            ("experts/w2", vec![e, d, f]),
            ("experts/b2", vec![e, d]),
            ("out/wo", vec![v, d]),
        ]
    }

    pub fn total_param_elements(&self) -> usize {
        self.param_shapes().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Predictions per batch (`tokens` carries `seq_len + 1` ids per row).
    pub fn predictions(&self) -> usize {
        self.batch * self.seq_len
    }
}

/// The parameters as flat `f64` buffers, state order (accumulation and
/// the finite-difference tests both want full double precision; tensors
/// at the entry boundary are `f32`).
#[derive(Debug, Clone)]
pub struct HostParams {
    pub t: Vec<Vec<f64>>,
}

impl HostParams {
    pub fn from_tensors(params: &[Tensor]) -> Result<HostParams> {
        if params.len() != N_PARAMS {
            bail!("host params: got {} tensors, want {N_PARAMS}", params.len());
        }
        let mut t = Vec::with_capacity(N_PARAMS);
        for p in params {
            t.push(p.as_f32()?.iter().map(|&x| x as f64).collect());
        }
        Ok(HostParams { t })
    }
}

/// Zeroed gradient buffers matching [`HostCfg::param_shapes`].
pub fn zero_grads(cfg: &HostCfg) -> Vec<Vec<f64>> {
    cfg.param_shapes().iter().map(|(_, s)| vec![0.0; s.iter().product()]).collect()
}

// ---- token-level forward pieces (shared with trainer::mapped) -------------

/// Embedding row of token `tok`.
pub fn embed_vec(cfg: &HostCfg, p: &HostParams, tok: usize) -> Vec<f64> {
    let d = cfg.d_model;
    p.t[P_EMBED][tok * d..(tok + 1) * d].to_vec()
}

/// Softmax router probabilities over the experts for activation `x`.
pub fn gate_probs(cfg: &HostCfg, p: &HostParams, x: &[f64]) -> Vec<f64> {
    let d = cfg.d_model;
    let mut scores = Vec::with_capacity(cfg.n_experts);
    for e in 0..cfg.n_experts {
        let w = &p.t[P_WG][e * d..(e + 1) * d];
        scores.push(w.iter().zip(x).map(|(a, b)| a * b).sum::<f64>());
    }
    softmax(&mut scores);
    scores
}

/// Top-k expert ids in preference order: descending probability,
/// ascending index on ties — fully deterministic.
pub fn top_k_experts(probs: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]).then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

/// Combine weights: the selected probabilities renormalized to sum 1.
pub fn renorm_weights(probs: &[f64], topk: &[usize]) -> Vec<f64> {
    let sum: f64 = topk.iter().map(|&e| probs[e]).sum();
    topk.iter().map(|&e| probs[e] / sum).collect()
}

/// One expert's two-layer ReLU MLP on `x` (forward only).
pub fn expert_forward(cfg: &HostCfg, p: &HostParams, e: usize, x: &[f64]) -> Vec<f64> {
    expert_fwd_full(cfg, p, e, x).0
}

/// `(y, pre)` where `pre` is the pre-ReLU hidden (backward needs it).
fn expert_fwd_full(cfg: &HostCfg, p: &HostParams, e: usize, x: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let w1 = &p.t[P_W1][e * f * d..(e + 1) * f * d];
    let b1 = &p.t[P_B1][e * f..(e + 1) * f];
    let w2 = &p.t[P_W2][e * d * f..(e + 1) * d * f];
    let b2 = &p.t[P_B2][e * d..(e + 1) * d];
    let mut pre = Vec::with_capacity(f);
    for fi in 0..f {
        let row = &w1[fi * d..(fi + 1) * d];
        pre.push(b1[fi] + row.iter().zip(x).map(|(a, b)| a * b).sum::<f64>());
    }
    let mut y = Vec::with_capacity(d);
    for di in 0..d {
        let row = &w2[di * f..(di + 1) * f];
        let mut acc = b2[di];
        for fi in 0..f {
            acc += row[fi] * pre[fi].max(0.0);
        }
        y.push(acc);
    }
    (y, pre)
}

/// Output logits over the vocab for the post-residual activation `h`.
pub fn output_logits(cfg: &HostCfg, p: &HostParams, h: &[f64]) -> Vec<f64> {
    let d = cfg.d_model;
    let mut logits = Vec::with_capacity(cfg.vocab);
    for v in 0..cfg.vocab {
        let row = &p.t[P_WO][v * d..(v + 1) * d];
        logits.push(row.iter().zip(h).map(|(a, b)| a * b).sum::<f64>());
    }
    logits
}

/// Cross-entropy of the next-token prediction from activation `h`.
pub fn output_ce(cfg: &HostCfg, p: &HostParams, h: &[f64], target: usize) -> f64 {
    let mut q = output_logits(cfg, p, h);
    softmax(&mut q);
    -q[target].max(1e-30).ln()
}

fn softmax(v: &mut [f64]) {
    let max = v.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for x in v.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
}

// ---- fused loss / gradients ------------------------------------------------

/// Mean cross-entropy + aux metric over `tokens` (`batch` rows of
/// `seq_len + 1` ids). Forward only.
pub fn loss_only(cfg: &HostCfg, p: &HostParams, tokens: &[i32]) -> Result<(f64, f64)> {
    let (_, ce, aux) = loss_and_grads(cfg, p, tokens)?;
    Ok((ce, aux))
}

/// Full forward + exact manual backward over one batch. Returns
/// per-parameter gradient buffers (state order), the mean cross-entropy,
/// and the (non-differentiated) load-balance aux metric.
pub fn loss_and_grads(
    cfg: &HostCfg,
    p: &HostParams,
    tokens: &[i32],
) -> Result<(Vec<Vec<f64>>, f64, f64)> {
    let (vsz, d, f) = (cfg.vocab, cfg.d_model, cfg.d_ff);
    let row = cfg.seq_len + 1;
    if tokens.len() != cfg.batch * row {
        bail!("host tokens: got {} ids, want {}x{}", tokens.len(), cfg.batch, row);
    }
    let n = cfg.predictions() as f64;
    let w = 1.0 / n;
    let mut g = zero_grads(cfg);
    let mut ce_total = 0.0;
    // aux bookkeeping: expert slot counts + mean router probability.
    let mut slot_counts = vec![0.0f64; cfg.n_experts];
    let mut prob_sums = vec![0.0f64; cfg.n_experts];

    for b in 0..cfg.batch {
        for t in 0..cfg.seq_len {
            let tok = tokens[b * row + t] as usize;
            let target = tokens[b * row + t + 1] as usize;
            if tok >= vsz || target >= vsz {
                bail!("host tokens: id out of vocab range");
            }
            // forward
            let x = embed_vec(cfg, p, tok);
            let probs = gate_probs(cfg, p, &x);
            let topk = top_k_experts(&probs, cfg.top_k);
            let what = renorm_weights(&probs, &topk);
            let ssum: f64 = topk.iter().map(|&e| probs[e]).sum();
            let experts: Vec<(Vec<f64>, Vec<f64>)> =
                topk.iter().map(|&e| expert_fwd_full(cfg, p, e, &x)).collect();
            let mut y = vec![0.0; d];
            for (we, (ye, _)) in what.iter().zip(&experts) {
                for (yd, v) in y.iter_mut().zip(ye) {
                    *yd += we * v;
                }
            }
            let h: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
            let mut q = output_logits(cfg, p, &h);
            softmax(&mut q);
            ce_total += -q[target].max(1e-30).ln();
            for (e, pe) in probs.iter().enumerate() {
                prob_sums[e] += pe;
            }
            for &e in &topk {
                slot_counts[e] += 1.0;
            }

            // backward (upstream scale w = 1/N)
            let mut dh = vec![0.0; d];
            for v in 0..vsz {
                let dl = (q[v] - if v == target { 1.0 } else { 0.0 }) * w;
                let wo = &p.t[P_WO][v * d..(v + 1) * d];
                for di in 0..d {
                    g[P_WO][v * d + di] += dl * h[di];
                    dh[di] += dl * wo[di];
                }
            }
            let mut dx = dh.clone(); // residual path
            let dy = &dh;

            // experts + combine weights
            let mut a = vec![0.0; cfg.top_k]; // dL/d(what_j)
            for (j, (ye, _)) in experts.iter().enumerate() {
                a[j] = ye.iter().zip(dy).map(|(p0, p1)| p0 * p1).sum();
            }
            for (j, &e) in topk.iter().enumerate() {
                let (_, pre) = &experts[j];
                let dye: Vec<f64> = dy.iter().map(|v| v * what[j]).collect();
                let w2 = &p.t[P_W2][e * d * f..(e + 1) * d * f];
                let mut dh1 = vec![0.0; f];
                for di in 0..d {
                    g[P_B2][e * d + di] += dye[di];
                    for fi in 0..f {
                        g[P_W2][e * d * f + di * f + fi] += dye[di] * pre[fi].max(0.0);
                        dh1[fi] += dye[di] * w2[di * f + fi];
                    }
                }
                let w1 = &p.t[P_W1][e * f * d..(e + 1) * f * d];
                for fi in 0..f {
                    if pre[fi] <= 0.0 {
                        continue;
                    }
                    let dpre = dh1[fi];
                    g[P_B1][e * f + fi] += dpre;
                    for di in 0..d {
                        g[P_W1][e * f * d + fi * d + di] += dpre * x[di];
                        dx[di] += dpre * w1[fi * d + di];
                    }
                }
            }

            // gate: what_j = p_j / ssum for j in topk, then softmax jacobian
            let wa: f64 = what.iter().zip(&a).map(|(p0, p1)| p0 * p1).sum();
            let mut gprob = vec![0.0; cfg.n_experts]; // dL/dp_e
            for (j, &e) in topk.iter().enumerate() {
                gprob[e] = (a[j] - wa) / ssum;
            }
            let gdot: f64 = probs.iter().zip(&gprob).map(|(p0, p1)| p0 * p1).sum();
            for e in 0..cfg.n_experts {
                let dscore = probs[e] * (gprob[e] - gdot);
                let wg = &p.t[P_WG][e * d..(e + 1) * d];
                for di in 0..d {
                    g[P_WG][e * d + di] += dscore * x[di];
                    dx[di] += dscore * wg[di];
                }
            }

            for di in 0..d {
                g[P_EMBED][tok * d + di] += dx[di];
            }
        }
    }

    // switch-style load balance: E * sum_e f_e * P_e (1.0 at balance)
    let slots = n * cfg.top_k as f64;
    let aux = (cfg.n_experts as f64)
        * slot_counts
            .iter()
            .zip(&prob_sums)
            .map(|(c, s)| (c / slots) * (s / n))
            .sum::<f64>();
    Ok((g, ce_total * w, aux))
}

// ---- state / entries -------------------------------------------------------

/// Seed-deterministic parameter init (state order, `f32` tensors).
pub fn init_params(cfg: &HostCfg, seed: u32) -> Vec<Tensor> {
    let mut rng = Rng::new(seed as u64 ^ 0x1005_7A61);
    let d_in = |shape: &[usize]| *shape.last().unwrap_or(&1) as f64;
    let mut out = Vec::with_capacity(N_PARAMS);
    for (i, (_, shape)) in cfg.param_shapes().into_iter().enumerate() {
        let n: usize = shape.iter().product();
        let scale = match i {
            P_EMBED => 0.5,
            P_B1 | P_B2 => 0.0,
            _ => 1.0 / d_in(&shape).sqrt(),
        };
        let data: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
        out.push(Tensor::F32(data, shape));
    }
    out
}

/// Fresh optimizer state: `[params, m=0, v=0, step=0]` (22 tensors).
pub fn init_state(cfg: &HostCfg, seed: u32) -> Vec<Tensor> {
    let params = init_params(cfg, seed);
    let mut state = params.clone();
    for _ in 0..2 {
        for p in &params {
            state.push(Tensor::F32(vec![0.0; p.elements()], p.shape().to_vec()));
        }
    }
    state.push(Tensor::F32(vec![0.0], vec![]));
    state
}

/// One Adam step: `state' = adam(state, grads)` (bias-corrected, state
/// order `[params, m, v, step]`).
pub fn adam_update(state: &[Tensor], grads: &[Tensor]) -> Result<Vec<Tensor>> {
    if state.len() != 3 * N_PARAMS + 1 || grads.len() != N_PARAMS {
        bail!("adam: got {} state / {} grad tensors", state.len(), grads.len());
    }
    let step = state[3 * N_PARAMS].scalar_value()? + 1.0;
    let bc1 = 1.0 - BETA1.powf(step);
    let bc2 = 1.0 - BETA2.powf(step);
    let mut out = state.to_vec();
    for i in 0..N_PARAMS {
        let g: Vec<f64> = grads[i].as_f32()?.iter().map(|&x| x as f64).collect();
        let mut pv: Vec<f64> = out[i].as_f32()?.iter().map(|&x| x as f64).collect();
        let mut mv: Vec<f64> =
            out[N_PARAMS + i].as_f32()?.iter().map(|&x| x as f64).collect();
        let mut vv: Vec<f64> =
            out[2 * N_PARAMS + i].as_f32()?.iter().map(|&x| x as f64).collect();
        for k in 0..g.len() {
            mv[k] = BETA1 * mv[k] + (1.0 - BETA1) * g[k];
            vv[k] = BETA2 * vv[k] + (1.0 - BETA2) * g[k] * g[k];
            let mhat = mv[k] / bc1;
            let vhat = vv[k] / bc2;
            pv[k] -= LR * mhat / (vhat.sqrt() + EPS);
        }
        write_f32(&mut out[i], &pv)?;
        write_f32(&mut out[N_PARAMS + i], &mv)?;
        write_f32(&mut out[2 * N_PARAMS + i], &vv)?;
    }
    out[3 * N_PARAMS] = Tensor::F32(vec![step as f32], vec![]);
    Ok(out)
}

fn write_f32(t: &mut Tensor, data: &[f64]) -> Result<()> {
    let dst = t.as_f32_mut()?;
    for (d, s) in dst.iter_mut().zip(data) {
        *d = *s as f32;
    }
    Ok(())
}

/// Entry kinds the host backend can execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostEntry {
    Init,
    GradStep,
    ApplyUpdate,
    TrainStep,
}

impl HostEntry {
    pub fn from_name(name: &str) -> Result<HostEntry> {
        match name {
            "init" => Ok(HostEntry::Init),
            "grad_step" => Ok(HostEntry::GradStep),
            "apply_update" => Ok(HostEntry::ApplyUpdate),
            "train_step" => Ok(HostEntry::TrainStep),
            other => Err(anyhow!("host backend has no entrypoint '{other}'")),
        }
    }
}

fn scalar_f32(v: f64) -> Tensor {
    Tensor::F32(vec![v as f32], vec![])
}

/// Execute a host entrypoint on validated inputs (the engine checks
/// shapes against the manifest before calling this).
pub fn execute_entry(cfg: &HostCfg, kind: HostEntry, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    match kind {
        HostEntry::Init => {
            let seed = inputs[0].scalar_value()? as u32;
            Ok(init_state(cfg, seed))
        }
        HostEntry::GradStep => {
            let p = HostParams::from_tensors(&inputs[..N_PARAMS])?;
            let tokens = inputs[N_PARAMS].as_i32()?;
            let (g, ce, aux) = loss_and_grads(cfg, &p, tokens)?;
            let mut out = grads_to_tensors(cfg, &g);
            out.push(scalar_f32(ce));
            out.push(scalar_f32(aux));
            Ok(out)
        }
        HostEntry::ApplyUpdate => {
            let state = &inputs[..3 * N_PARAMS + 1];
            let grads = &inputs[3 * N_PARAMS + 1..];
            adam_update(state, grads)
        }
        HostEntry::TrainStep => {
            let state = &inputs[..3 * N_PARAMS + 1];
            let tokens = inputs[3 * N_PARAMS + 1].as_i32()?;
            let p = HostParams::from_tensors(&state[..N_PARAMS])?;
            let (g, ce, aux) = loss_and_grads(cfg, &p, tokens)?;
            let grads = grads_to_tensors(cfg, &g);
            let mut out = adam_update(state, &grads)?;
            out.push(scalar_f32(ce));
            out.push(scalar_f32(aux));
            Ok(out)
        }
    }
}

fn grads_to_tensors(cfg: &HostCfg, g: &[Vec<f64>]) -> Vec<Tensor> {
    cfg.param_shapes()
        .into_iter()
        .zip(g)
        .map(|((_, shape), buf)| {
            Tensor::F32(buf.iter().map(|&x| x as f32).collect(), shape)
        })
        .collect()
}

/// The manifest-style entrypoint specs of the host miniature, keyed by
/// name (`file` is the `"<builtin>"` sentinel — nothing is on disk).
pub fn entry_specs(cfg: &HostCfg) -> BTreeMap<String, EntrySpec> {
    let f32s = |name: &str, shape: Vec<usize>| TensorSpec {
        name: name.to_string(),
        shape,
        dtype: DType::F32,
    };
    let params: Vec<TensorSpec> =
        cfg.param_shapes().into_iter().map(|(n, s)| f32s(n, s)).collect();
    let mut state: Vec<TensorSpec> = params.clone();
    for prefix in ["m", "v"] {
        for p in &params {
            state.push(f32s(&format!("{prefix}/{}", p.name), p.shape.clone()));
        }
    }
    state.push(f32s("step", vec![]));
    let grads: Vec<TensorSpec> =
        params.iter().map(|p| f32s(&format!("grad/{}", p.name), p.shape.clone())).collect();
    let tokens = TensorSpec {
        name: "tokens".to_string(),
        shape: vec![cfg.batch, cfg.seq_len + 1],
        dtype: DType::I32,
    };
    let seed = TensorSpec { name: "seed".to_string(), shape: vec![], dtype: DType::U32 };
    let entry = |name: &str, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| EntrySpec {
        name: name.to_string(),
        file: "<builtin>".to_string(),
        inputs,
        outputs,
    };
    let mut out = BTreeMap::new();
    out.insert("init".to_string(), entry("init", vec![seed], state.clone()));
    let mut gs_in = params.clone();
    gs_in.push(tokens.clone());
    let mut gs_out = grads.clone();
    gs_out.push(f32s("ce", vec![]));
    gs_out.push(f32s("aux", vec![]));
    out.insert("grad_step".to_string(), entry("grad_step", gs_in, gs_out));
    let mut ap_in = state.clone();
    ap_in.extend(grads.clone());
    out.insert("apply_update".to_string(), entry("apply_update", ap_in, state.clone()));
    let mut ts_in = state.clone();
    ts_in.push(tokens);
    let mut ts_out = state;
    ts_out.push(f32s("ce", vec![]));
    ts_out.push(f32s("aux", vec![]));
    out.insert("train_step".to_string(), entry("train_step", ts_in, ts_out));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens(cfg: &HostCfg, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..cfg.batch * (cfg.seq_len + 1))
            .map(|_| rng.below(cfg.vocab as u64) as i32)
            .collect()
    }

    fn params(cfg: &HostCfg) -> HostParams {
        HostParams::from_tensors(&init_params(cfg, 7)).unwrap()
    }

    #[test]
    fn finite_difference_gradient_check() {
        let cfg = HostCfg {
            vocab: 12,
            d_model: 6,
            d_ff: 8,
            n_experts: 4,
            top_k: 2,
            batch: 1,
            seq_len: 5,
        };
        let p = params(&cfg);
        let toks = tokens(&cfg, 42);
        let (g, _, _) = loss_and_grads(&cfg, &p, &toks).unwrap();
        let mut rng = Rng::new(1);
        let mut checked = 0usize;
        for pi in 0..N_PARAMS {
            for _ in 0..6 {
                let k = rng.below(p.t[pi].len() as u64) as usize;
                let h = 1e-5;
                let mut pp = p.clone();
                pp.t[pi][k] += h;
                let (up, _) = loss_only(&cfg, &pp, &toks).unwrap();
                pp.t[pi][k] -= 2.0 * h;
                let (dn, _) = loss_only(&cfg, &pp, &toks).unwrap();
                let fd = (up - dn) / (2.0 * h);
                let an = g[pi][k];
                let tol = 1e-4 * an.abs().max(fd.abs()).max(1e-3);
                assert!(
                    (fd - an).abs() <= tol,
                    "param {pi} idx {k}: fd {fd:.8} vs analytic {an:.8}"
                );
                checked += 1;
            }
        }
        assert_eq!(checked, 6 * N_PARAMS);
    }

    #[test]
    fn train_step_entry_decreases_loss() {
        let cfg = HostCfg::miniature();
        let mut state = init_state(&cfg, 3);
        let toks = Tensor::I32(tokens(&cfg, 9), vec![cfg.batch, cfg.seq_len + 1]);
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for step in 0..12 {
            let mut inputs = state.clone();
            inputs.push(toks.clone());
            let mut out = execute_entry(&cfg, HostEntry::TrainStep, &inputs).unwrap();
            let aux = out.pop().unwrap().scalar_value().unwrap();
            let ce = out.pop().unwrap().scalar_value().unwrap();
            assert!(aux.is_finite() && aux > 0.0);
            state = out;
            if step == 0 {
                first = ce;
            }
            last = ce;
        }
        assert!(first.is_finite() && last.is_finite());
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn grad_step_matches_train_step_losses() {
        let cfg = HostCfg::miniature();
        let state = init_state(&cfg, 5);
        let toks = Tensor::I32(tokens(&cfg, 11), vec![cfg.batch, cfg.seq_len + 1]);
        let mut gs_in = state[..N_PARAMS].to_vec();
        gs_in.push(toks.clone());
        let mut gout = execute_entry(&cfg, HostEntry::GradStep, &gs_in).unwrap();
        let aux_g = gout.pop().unwrap().scalar_value().unwrap();
        let ce_g = gout.pop().unwrap().scalar_value().unwrap();
        let mut ts_in = state.clone();
        ts_in.push(toks);
        let mut tout = execute_entry(&cfg, HostEntry::TrainStep, &ts_in).unwrap();
        let aux_t = tout.pop().unwrap().scalar_value().unwrap();
        let ce_t = tout.pop().unwrap().scalar_value().unwrap();
        assert!((ce_g - ce_t).abs() < 1e-9);
        assert!((aux_g - aux_t).abs() < 1e-9);
        // and apply_update(state, grads) == train_step's state output
        let mut ap_in = state;
        ap_in.extend(gout);
        let applied = execute_entry(&cfg, HostEntry::ApplyUpdate, &ap_in).unwrap();
        assert_eq!(applied.len(), 3 * N_PARAMS + 1);
        for (a, b) in applied.iter().zip(&tout) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn specs_cover_every_entry_and_match_execution() {
        let cfg = HostCfg::miniature();
        let specs = entry_specs(&cfg);
        assert_eq!(specs.len(), 4);
        let init = &specs["init"];
        assert_eq!(init.outputs.len(), 3 * N_PARAMS + 1);
        let out = execute_entry(&cfg, HostEntry::Init, &[Tensor::scalar_u32(1)]).unwrap();
        assert_eq!(out.len(), init.outputs.len());
        for (t, s) in out.iter().zip(&init.outputs) {
            assert!(t.matches(s), "init output {} mismatch", s.name);
        }
    }

    #[test]
    fn top_k_is_deterministic_on_ties() {
        assert_eq!(top_k_experts(&[0.25, 0.25, 0.25, 0.25], 2), vec![0, 1]);
        assert_eq!(top_k_experts(&[0.1, 0.4, 0.1, 0.4], 2), vec![1, 3]);
    }
}
