//! PJRT runtime: load AOT artifacts (HLO text + manifest) and execute them
//! from the Rust hot path. Python never runs here.
//!
//! ```no_run
//! use lumos::runtime::{artifacts_root, Artifact, Engine, Tensor};
//! let root = artifacts_root().unwrap();
//! let art = Artifact::load(root.join("tiny")).unwrap();
//! let engine = Engine::cpu().unwrap();
//! let init = engine.load(&art, "init").unwrap();
//! let state = init.execute(&[Tensor::scalar_u32(0)]).unwrap();
//! ```

mod artifact;
mod engine;
mod tensor;

pub use artifact::{artifacts_root, Artifact, EntrySpec};
pub use engine::{CompiledEntry, Engine, EntryStats, LitVal};
pub use tensor::{DType, Tensor, TensorSpec};
