//! Runtime: load AOT artifacts (HLO text + manifest) and execute them
//! from the Rust hot path. Python never runs here.
//!
//! Two backends behind one [`Engine`]:
//!
//! - [`Engine::cpu`] — PJRT via the `xla` crate (requires the real
//!   `xla_extension` build; the vendored offline shim errors cleanly).
//! - [`Engine::host`] — the pure-Rust MoE miniature in [`host`], whose
//!   entrypoints mirror the artifact contract exactly, so every trainer
//!   path (and `lumos run`) works with no PJRT and no `artifacts/` dir
//!   via [`Artifact::host_miniature`].
//!
//! ```no_run
//! use lumos::runtime::{artifacts_root, Artifact, Engine, Tensor};
//! let root = artifacts_root().unwrap();
//! let art = Artifact::load(root.join("tiny")).unwrap();
//! let engine = Engine::cpu().unwrap();
//! let init = engine.load(&art, "init").unwrap();
//! let state = init.execute(&[Tensor::scalar_u32(0)]).unwrap();
//! ```

mod artifact;
mod engine;
pub mod host;
mod tensor;

pub use artifact::{artifacts_root, Artifact, EntrySpec};
pub use engine::{CompiledEntry, Engine, EntryStats, LitVal};
pub use host::HostCfg;
pub use tensor::{DType, Tensor, TensorSpec};
