//! Single-Layer-Switch (SLS) scale-up fabric (paper §II.B, Fig. 2).
//!
//! Every GPU connects one port to every switch ("rail"); any GPU pair is
//! one switch hop apart at full bandwidth. Pod size is capped by switch
//! radix: a 512-port switch supports at most 512 GPUs.

use crate::hw::package::SwitchPackage;

/// An SLS pod: `n_gpus` GPUs × `n_rails` switches.
#[derive(Debug, Clone)]
pub struct SlsFabric {
    pub n_gpus: usize,
    /// Per-GPU unidirectional injection bandwidth, Gb/s.
    pub gbps_per_gpu: f64,
    /// Raw bandwidth of one GPU-to-switch port, Gb/s.
    pub port_gbps: f64,
    pub switch: SwitchPackage,
}

impl SlsFabric {
    /// The paper's design point: 448G ports into 512-port switches.
    pub fn new(n_gpus: usize, gbps_per_gpu: f64) -> Self {
        SlsFabric { n_gpus, gbps_per_gpu, port_gbps: 448.0, switch: SwitchPackage::sls_512() }
    }

    /// Number of rails (switches) needed to deliver the per-GPU bandwidth.
    pub fn n_rails(&self) -> usize {
        (self.gbps_per_gpu / self.port_gbps).ceil() as usize
    }

    /// Radix feasibility: SLS supports at most one GPU per switch port.
    pub fn fits_radix(&self) -> bool {
        self.n_gpus <= self.switch.ports
    }

    /// Hop count between any two distinct GPUs (the SLS invariant).
    pub fn hops(&self, a: usize, b: usize) -> usize {
        assert!(a < self.n_gpus && b < self.n_gpus);
        usize::from(a != b) * 2 // GPU→switch→GPU
    }

    /// Bisection bandwidth of the pod, Gb/s (full bisection by design).
    pub fn bisection_gbps(&self) -> f64 {
        self.n_gpus as f64 / 2.0 * self.gbps_per_gpu
    }

    /// Total switch packages (= rails) and aggregate switch fabric Gb/s.
    pub fn switch_count(&self) -> usize {
        self.n_rails()
    }

    /// Whether the switch fabric capacity covers all GPU ports on a rail.
    pub fn rail_is_nonblocking(&self) -> bool {
        self.n_gpus as f64 * self.port_gbps <= self.switch.raw_gbps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rail_counts() {
        // 32 Tb/s over 448G ports -> 72 rails; 14.4 Tb/s -> 33 rails.
        assert_eq!(SlsFabric::new(512, 32_000.0).n_rails(), 72);
        assert_eq!(SlsFabric::new(144, 14_400.0).n_rails(), 33);
    }

    #[test]
    fn radix_caps_pod_size() {
        assert!(SlsFabric::new(512, 32_000.0).fits_radix());
        assert!(!SlsFabric::new(513, 32_000.0).fits_radix());
    }

    #[test]
    fn sls_is_single_hop() {
        let f = SlsFabric::new(512, 32_000.0);
        assert_eq!(f.hops(3, 3), 0);
        assert_eq!(f.hops(0, 511), 2);
    }

    #[test]
    fn full_bisection() {
        let f = SlsFabric::new(512, 32_000.0);
        assert!((f.bisection_gbps() - 256.0 * 32_000.0).abs() < 1e-6);
        assert!(f.rail_is_nonblocking());
    }
}
