//! Two-level cluster: scale-up pods (SLS) stitched by a scale-out network.
//!
//! Matches the paper's evaluation setup (§VI): 32,768 GPUs; pods of 144
//! (electrical, 14.4 Tb/s/GPU) or 512 (Passage, 32 Tb/s/GPU); 1.6 Tb/s/GPU
//! Ethernet between pods.

use crate::hw::package::GpuPackage;

/// Which network a communication group runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    ScaleUp,
    ScaleOut,
}

/// Bandwidth/latency envelope of one network domain.
#[derive(Debug, Clone)]
pub struct DomainSpec {
    pub name: String,
    /// Per-GPU unidirectional injection bandwidth, Gb/s.
    pub gbps_per_gpu: f64,
    /// Startup latency per transfer (Hockney α), seconds.
    pub latency_s: f64,
    /// Effective fraction of line rate achievable by dense all-to-all
    /// traffic (congestion/incast derate; cross-validated by netsim).
    pub a2a_efficiency: f64,
}

impl DomainSpec {
    /// Bytes/second usable by one GPU, for bandwidth-bound transfers.
    pub fn bytes_per_sec(&self) -> f64 {
        self.gbps_per_gpu * 1e9 / 8.0
    }
}

/// Cluster parameters (construction-time description).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub n_gpus: usize,
    /// GPUs per scale-up pod.
    pub pod_size: usize,
    pub scale_up: DomainSpec,
    pub scale_out: DomainSpec,
    pub gpu: GpuPackage,
}

/// A realized cluster (validated spec + derived facts).
#[derive(Debug, Clone)]
pub struct Cluster {
    pub spec: ClusterSpec,
}

impl Cluster {
    pub fn new(spec: ClusterSpec) -> Self {
        assert!(spec.n_gpus > 0 && spec.pod_size > 0);
        assert!(
            spec.n_gpus % spec.pod_size == 0,
            "n_gpus {} not divisible by pod_size {}",
            spec.n_gpus,
            spec.pod_size
        );
        Cluster { spec }
    }

    /// The paper's Passage configuration: 512-GPU pods at 32 Tb/s.
    pub fn passage_512(n_gpus: usize) -> Self {
        Cluster::new(ClusterSpec {
            name: "Passage-512".into(),
            n_gpus,
            pod_size: 512,
            scale_up: DomainSpec {
                name: "Passage SLS".into(),
                gbps_per_gpu: 32_000.0,
                latency_s: 200e-9, // §Table I: 100-250 ns
                a2a_efficiency: 0.95,
            },
            scale_out: scale_out_ethernet(),
            gpu: GpuPackage::frontier_2028(),
        })
    }

    /// The paper's electrical alternative: 144-GPU pods at 14.4 Tb/s.
    pub fn electrical_144(n_gpus: usize) -> Self {
        Cluster::new(ClusterSpec {
            name: "Electrical-144".into(),
            n_gpus,
            pod_size: 144,
            scale_up: DomainSpec {
                name: "Electrical SLS".into(),
                gbps_per_gpu: 14_400.0,
                latency_s: 200e-9,
                a2a_efficiency: 0.95,
            },
            scale_out: scale_out_ethernet(),
            gpu: GpuPackage::frontier_2028(),
        })
    }

    /// Fig. 10's bandwidth-isolation scenario: the electrical technology
    /// hypothetically scaled to a 512 radix.
    pub fn electrical_512(n_gpus: usize) -> Self {
        let mut c = Cluster::electrical_144(144); // borrow the domain specs
        c.spec.name = "Electrical-512 (hypothetical)".into();
        c.spec.pod_size = 512;
        c.spec.n_gpus = n_gpus;
        assert!(n_gpus % 512 == 0);
        c
    }

    /// Custom pod/bandwidth point (for the pod_scaling example & ablations).
    pub fn custom(n_gpus: usize, pod_size: usize, scaleup_gbps: f64) -> Self {
        Cluster::new(ClusterSpec {
            name: format!("pod{pod_size}@{:.1}T", scaleup_gbps / 1000.0),
            n_gpus,
            pod_size,
            scale_up: DomainSpec {
                name: "SLS".into(),
                gbps_per_gpu: scaleup_gbps,
                latency_s: 200e-9,
                a2a_efficiency: 0.95,
            },
            scale_out: scale_out_ethernet(),
            gpu: GpuPackage::frontier_2028(),
        })
    }

    pub fn n_pods(&self) -> usize {
        self.spec.n_gpus / self.spec.pod_size
    }

    pub fn pod_of(&self, gpu: usize) -> usize {
        assert!(gpu < self.spec.n_gpus);
        gpu / self.spec.pod_size
    }

    /// Domain spec for a group that spans `span` consecutive GPUs: in-pod
    /// groups ride the scale-up network, larger groups the scale-out.
    pub fn domain_for_span(&self, span: usize) -> Domain {
        if span <= self.spec.pod_size {
            Domain::ScaleUp
        } else {
            Domain::ScaleOut
        }
    }

    pub fn domain(&self, d: Domain) -> &DomainSpec {
        match d {
            Domain::ScaleUp => &self.spec.scale_up,
            Domain::ScaleOut => &self.spec.scale_out,
        }
    }

    /// Fraction of uniform all-to-all traffic from a group of `span` GPUs
    /// (pod-major placement) that crosses pod boundaries.
    pub fn cross_pod_fraction(&self, span: usize) -> f64 {
        if span <= self.spec.pod_size {
            return 0.0;
        }
        let in_pod_peers = self.spec.pod_size.min(span);
        1.0 - in_pod_peers as f64 / span as f64
    }
}

/// §VI: each Ethernet link provides 1600 Gb/s unidirectional.
pub fn scale_out_ethernet() -> DomainSpec {
    DomainSpec {
        name: "Ethernet scale-out".into(),
        gbps_per_gpu: 1_600.0,
        latency_s: 5e-6, // Table I: 2-10 µs
        // Dense all-to-all over a multi-tier fat-tree sustains well below
        // line rate (incast + ECMP imbalance); netsim_validate measures
        // ~0.6 for pod-crossing a2a. Keep in sync with netsim results.
        a2a_efficiency: 0.6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_shapes() {
        let p = Cluster::passage_512(32_768);
        assert_eq!(p.n_pods(), 64);
        let e = Cluster::electrical_144(32_256); // 224 pods
        assert_eq!(e.n_pods(), 224);
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn rejects_ragged_pods() {
        Cluster::electrical_144(32_768); // 32768 % 144 != 0
    }

    #[test]
    fn pod_membership() {
        let c = Cluster::passage_512(1024);
        assert_eq!(c.pod_of(0), 0);
        assert_eq!(c.pod_of(511), 0);
        assert_eq!(c.pod_of(512), 1);
    }

    #[test]
    fn domain_selection_by_span() {
        let c = Cluster::electrical_144(1440);
        assert_eq!(c.domain_for_span(16), Domain::ScaleUp);
        assert_eq!(c.domain_for_span(144), Domain::ScaleUp);
        assert_eq!(c.domain_for_span(512), Domain::ScaleOut);
    }

    #[test]
    fn cross_pod_fraction_monotone() {
        let c = Cluster::electrical_144(1440);
        assert_eq!(c.cross_pod_fraction(144), 0.0);
        let f512 = c.cross_pod_fraction(512);
        let f1024 = c.cross_pod_fraction(1024);
        assert!(f512 > 0.7 && f512 < 0.73, "{f512}"); // 1 - 144/512
        assert!(f1024 > f512);
    }

    #[test]
    fn bandwidth_units() {
        let c = Cluster::passage_512(512);
        assert!((c.spec.scale_up.bytes_per_sec() - 4e12).abs() < 1e6);
    }
}
