//! Multi-dimensional torus (paper §II.B: the TPU-style alternative).
//!
//! Included as the comparison topology: efficient scaling for ring
//! collectives but large network diameter, which penalizes the
//! non-deterministic all-to-all traffic of expert parallelism. The
//! `torus_vs_sls` ablation bench quantifies exactly that trade.

/// A d-dimensional torus with per-dimension extents.
#[derive(Debug, Clone)]
pub struct Torus {
    pub dims: Vec<usize>,
    /// Per-link unidirectional bandwidth, Gb/s (each node has 2 links per
    /// dimension).
    pub link_gbps: f64,
}

impl Torus {
    pub fn new(dims: Vec<usize>, link_gbps: f64) -> Self {
        assert!(!dims.is_empty() && dims.iter().all(|&d| d >= 2));
        Torus { dims, link_gbps }
    }

    pub fn n_nodes(&self) -> usize {
        self.dims.iter().product()
    }

    /// Coordinates of node `i` (row-major).
    pub fn coords(&self, i: usize) -> Vec<usize> {
        assert!(i < self.n_nodes());
        let mut rem = i;
        let mut out = Vec::with_capacity(self.dims.len());
        for &d in self.dims.iter().rev() {
            out.push(rem % d);
            rem /= d;
        }
        out.reverse();
        out
    }

    /// Minimal hop count between two nodes (per-dimension ring distance).
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ca, cb) = (self.coords(a), self.coords(b));
        ca.iter()
            .zip(&cb)
            .zip(&self.dims)
            .map(|((&x, &y), &d)| {
                let diff = x.abs_diff(y);
                diff.min(d - diff)
            })
            .sum()
    }

    /// Network diameter (worst-case hops).
    pub fn diameter(&self) -> usize {
        self.dims.iter().map(|&d| d / 2).sum()
    }

    /// Average hop distance of uniform traffic (exact per-dimension mean).
    pub fn mean_hops(&self) -> f64 {
        self.dims
            .iter()
            .map(|&d| {
                let mut total = 0usize;
                for x in 0..d {
                    let diff = x.min(d - x);
                    total += diff;
                }
                total as f64 / d as f64
            })
            .sum()
    }

    /// Per-node injection bandwidth, Gb/s (2 links per dimension).
    pub fn injection_gbps(&self) -> f64 {
        2.0 * self.dims.len() as f64 * self.link_gbps
    }

    /// Effective per-node all-to-all bandwidth: uniform traffic consumes
    /// `mean_hops` link traversals per byte, so the usable fraction of
    /// injection bandwidth shrinks by that factor.
    pub fn a2a_effective_gbps(&self) -> f64 {
        self.injection_gbps() / self.mean_hops().max(1.0)
    }

    /// Bisection bandwidth, Gb/s: cut across the largest dimension.
    pub fn bisection_gbps(&self) -> f64 {
        // lumos: allow(panic-path) -- dims is nonempty by construction (checked in new)
        let dmax = *self.dims.iter().max().unwrap();
        let cross_section = self.n_nodes() / dmax;
        // 2 directed links per node pair crossing the cut, both wrap & mid.
        2.0 * 2.0 * cross_section as f64 * self.link_gbps / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_roundtrip() {
        let t = Torus::new(vec![4, 4, 4], 100.0);
        assert_eq!(t.n_nodes(), 64);
        assert_eq!(t.coords(0), vec![0, 0, 0]);
        assert_eq!(t.coords(63), vec![3, 3, 3]);
        assert_eq!(t.coords(21), vec![1, 1, 1]);
    }

    #[test]
    fn hops_wrap_around() {
        let t = Torus::new(vec![8], 100.0);
        assert_eq!(t.hops(0, 7), 1); // wrap link
        assert_eq!(t.hops(0, 4), 4);
        assert_eq!(t.diameter(), 4);
    }

    #[test]
    fn sls_beats_torus_for_a2a() {
        // 512 nodes: 8x8x8 torus with fat links vs SLS flat fabric.
        let t = Torus::new(vec![8, 8, 8], 32_000.0 / 6.0);
        assert!((t.injection_gbps() - 32_000.0).abs() < 1e-6);
        // Uniform a2a pays mean_hops≈6 traversals: effective per-node
        // bandwidth collapses well below injection.
        assert!(t.a2a_effective_gbps() < 0.2 * t.injection_gbps());
    }

    #[test]
    fn mean_hops_reasonable() {
        let t = Torus::new(vec![4, 4], 100.0);
        // per dim mean = (0+1+2+1)/4 = 1.0 -> total 2.0
        assert!((t.mean_hops() - 2.0).abs() < 1e-12);
    }
}
