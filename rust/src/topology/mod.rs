//! Network topologies (paper §II.B): the scale-up SLS fabric, the Ethernet
//! scale-out network, and the two-level cluster combining them.
//!
//! The performance model needs per-domain bandwidth/latency (`DomainSpec`)
//! plus structural facts (rails, switch radix, pod membership); the netsim
//! builds its link graph from the same structures.

pub mod cluster;
pub mod sls;
pub mod torus;

pub use cluster::{scale_out_ethernet, Cluster, ClusterSpec, Domain, DomainSpec};
pub use sls::SlsFabric;
pub use torus::Torus;
