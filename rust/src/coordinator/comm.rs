//! In-process communication fabric for the miniature cluster: typed
//! mailboxes between worker threads plus real collective algorithms (ring
//! all-reduce / all-gather, pairwise all-to-all, barrier) over them.
//!
//! These are the same algorithms whose Hockney costs drive the performance
//! model and whose schedules the netsim replays — here they move real
//! `f32` payloads (gradients, routed tokens) between the PJRT executables.
//!
//! Under chaos supervision ([`Endpoint::enable_chaos`]) the fabric grows a
//! fault/repair protocol (DESIGN.md §Chaos & supervision): every frame
//! carries an FNV checksum and a failover **epoch**; receives poll with a
//! bounded logical retry budget instead of blocking forever; repair
//! requests ([`MsgKind::Resend`]) double as liveness probes; and a dead
//! peer (closed channel) turns into a broadcast [`MsgKind::Failover`]
//! notice that surfaces as [`CommError::Failover`] so the trainer can
//! rewind and continue degraded. All abort paths are typed
//! [`CommError`]s — the fabric itself never panics on a peer failure.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use crate::chaos::{FaultKind, PlannedFault};

/// Poll interval of a supervised receive. The *deadline* is the logical
/// retry budget (poll count), not a wall-time duration — see DESIGN.md.
const POLL_MS: u64 = 5;
/// Send a repair-request/liveness probe every this many empty polls.
const NACK_EVERY: u64 = 20;
/// Default logical retry budget: 1200 polls (~6 s at 5 ms/poll).
const DEFAULT_RETRY_BUDGET: u64 = 1200;
/// Control tag that releases a parked (retired) rank at end of run.
pub const TAG_SHUTDOWN: u64 = u64::MAX;

/// Wire kind of a fabric frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// Payload frame (collectives, p2p activations).
    Data,
    /// Repair request for (requester, tag); also the liveness probe.
    Resend,
    /// `dead` has been detected dead; abort the step and fail over.
    Failover { dead: usize },
}

/// A tagged message between ranks.
#[derive(Debug)]
pub struct Msg {
    pub src: usize,
    pub tag: u64,
    /// Failover epoch the frame belongs to; stale-epoch frames are
    /// discarded after a rewind.
    pub epoch: u64,
    pub kind: MsgKind,
    /// FNV-1a checksum of `data` (0 = unchecked, healthy fast path).
    pub crc: u64,
    pub data: Vec<f32>,
}

/// Typed communication failure. Every variant is reachable by design
/// under fault injection; none indicates a caller bug except
/// [`CommError::NotInGroup`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommError {
    /// The channel to `peer` is closed (peer thread exited).
    Closed { peer: usize },
    /// A supervised receive exhausted its logical retry budget.
    Timeout { src: usize, tag: u64, attempts: u64 },
    /// Rank `dead` was detected dead; the step must be abandoned and the
    /// fabric reformed without its DP group.
    Failover { dead: usize },
    /// The calling rank is not a member of the collective's group.
    NotInGroup { rank: usize },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Closed { peer } => write!(f, "channel to rank {peer} closed"),
            CommError::Timeout { src, tag, attempts } => write!(
                f,
                "recv from rank {src} tag {tag:#x} timed out after {attempts} poll(s)"
            ),
            CommError::Failover { dead } => {
                write!(f, "rank {dead} declared dead; failover required")
            }
            CommError::NotInGroup { rank } => {
                write!(f, "rank {rank} is not a member of the collective group")
            }
        }
    }
}

impl std::error::Error for CommError {}

pub type CommResult<T> = Result<T, CommError>;

/// FNV-1a over the payload's f32 bit patterns — the frame checksum that
/// catches injected corruption.
fn checksum(data: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in data {
        for b in v.to_bits().to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    // 0 means "unchecked"; keep real checksums nonzero.
    h | 1
}

/// Supervision state of a chaos-enabled endpoint.
struct SupState {
    /// planned faults for this rank (fired flag consumes each once).
    faults: Vec<(PlannedFault, bool)>,
    /// frames withheld by an injected drop/corrupt, kept for repair:
    /// (dst, tag) -> original payload.
    withheld: BTreeMap<(usize, u64), Vec<f32>>,
    /// ranks this endpoint knows are dead (failover completed).
    dead: BTreeSet<usize>,
    /// chaos event log, drained into the flight recorder by the trainer.
    marks: Vec<String>,
    injected: BTreeMap<&'static str, usize>,
    corruptions_detected: usize,
    repairs_served: usize,
    retry_budget: u64,
}

/// Per-rank endpoint of the fabric.
pub struct Endpoint {
    pub rank: usize,
    pub n_ranks: usize,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// out-of-order arrivals parked until matched: (epoch, src, tag).
    parked: BTreeMap<(u64, usize, u64), VecDeque<Vec<f32>>>,
    barrier: Arc<Barrier>,
    /// current failover epoch (bumped by [`Endpoint::complete_failover`]).
    epoch: u64,
    sup: Option<Box<SupState>>,
    /// bytes sent (metrics)
    pub bytes_sent: u64,
}

/// Build a fully-connected fabric of `n` endpoints.
pub fn fabric(n: usize) -> Vec<Endpoint> {
    assert!(n > 0);
    let mut senders = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Msg>();
        senders.push(tx);
        receivers.push(rx);
    }
    let barrier = Arc::new(Barrier::new(n));
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Endpoint {
            rank,
            n_ranks: n,
            senders: senders.clone(),
            inbox,
            parked: BTreeMap::new(),
            barrier: barrier.clone(),
            epoch: 0,
            sup: None,
            bytes_sent: 0,
        })
        .collect()
}

impl Endpoint {
    // ---------------------------------------------------------------------
    // Supervision surface
    // ---------------------------------------------------------------------

    /// Arm chaos supervision with this rank's planned faults. Also turns
    /// on frame checksums, epoch tracking, and bounded-retry receives.
    pub fn enable_chaos(&mut self, faults: Vec<PlannedFault>) {
        self.sup = Some(Box::new(SupState {
            faults: faults.into_iter().map(|f| (f, false)).collect(),
            withheld: BTreeMap::new(),
            dead: BTreeSet::new(),
            marks: Vec::new(),
            injected: BTreeMap::new(),
            corruptions_detected: 0,
            repairs_served: 0,
            retry_budget: DEFAULT_RETRY_BUDGET,
        }));
    }

    pub fn is_supervised(&self) -> bool {
        self.sup.is_some()
    }

    /// Override the logical retry budget (polls, not seconds). Tests use
    /// a small budget to exercise the timeout path quickly.
    pub fn set_retry_budget(&mut self, polls: u64) {
        if let Some(sup) = self.sup.as_mut() {
            sup.retry_budget = polls.max(1);
        }
    }

    /// Drain the chaos event log (inject/detect/repair/failover marks).
    pub fn take_chaos_marks(&mut self) -> Vec<String> {
        self.sup.as_mut().map(|s| std::mem::take(&mut s.marks)).unwrap_or_default()
    }

    /// (injected per kind, corruptions detected, repairs served).
    pub fn chaos_counters(&self) -> (BTreeMap<String, usize>, usize, usize) {
        match self.sup.as_ref() {
            Some(s) => (
                s.injected.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
                s.corruptions_detected,
                s.repairs_served,
            ),
            None => (BTreeMap::new(), 0, 0),
        }
    }

    /// Finish a failover: record `dead`, drop repair state, discard every
    /// frame of the aborted epoch, and open the next epoch. All survivors
    /// call this exactly once per unique dead rank (duplicate Failover
    /// notices are discarded by the known-dead check), so epochs stay in
    /// lockstep without a clock.
    pub fn complete_failover(&mut self, dead: usize) {
        let epoch = self.epoch;
        if let Some(sup) = self.sup.as_mut() {
            sup.dead.insert(dead);
            sup.withheld.clear();
            sup.marks.push(format!("failover complete: rank {dead} out, epoch {epoch} -> {}", epoch + 1));
        }
        self.parked.retain(|&(e, _, _), _| e > epoch);
        self.epoch += 1;
    }

    /// Detect a dead peer: log it, notify every other rank, and return
    /// the [`CommError::Failover`] the caller propagates.
    fn declare_dead(&mut self, dead: usize) -> CommError {
        let rank = self.rank;
        let epoch = self.epoch;
        let mut fresh = false;
        if let Some(sup) = self.sup.as_mut() {
            if !sup.dead.contains(&dead) {
                fresh = true;
                sup.marks.push(format!("detect dead rank {dead} at rank {rank}"));
            }
        }
        if fresh {
            for dst in 0..self.n_ranks {
                if dst != rank && dst != dead {
                    // best-effort: a peer that is itself dead is fine
                    let _ = self.senders[dst].send(Msg {
                        src: rank,
                        tag: 0,
                        epoch,
                        kind: MsgKind::Failover { dead },
                        crc: 0,
                        data: Vec::new(),
                    });
                }
            }
        }
        CommError::Failover { dead }
    }

    /// Park a retired rank: keep the mailbox open (so late frames from
    /// the failover window never hit a closed channel and cascade into
    /// spurious death declarations) and drain everything until the
    /// survivors' end-of-run [`Endpoint::send_shutdown`].
    pub fn park_until_shutdown(&mut self) {
        loop {
            match self.inbox.recv() {
                Ok(m) => {
                    if m.tag == TAG_SHUTDOWN {
                        return;
                    }
                }
                // every sender gone: the run is over anyway
                Err(_) => return,
            }
        }
    }

    /// Release a parked rank (best-effort; a crashed rank's channel is
    /// already closed and that is fine).
    pub fn send_shutdown(&mut self, dst: usize) {
        let _ = self.senders[dst].send(Msg {
            src: self.rank,
            tag: TAG_SHUTDOWN,
            epoch: self.epoch,
            kind: MsgKind::Data,
            crc: 0,
            data: Vec::new(),
        });
    }

    // ---------------------------------------------------------------------
    // Point-to-point
    // ---------------------------------------------------------------------

    pub fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> CommResult<()> {
        if self.sup.is_some() {
            return self.send_supervised(dst, tag, data);
        }
        self.bytes_sent += (data.len() * 4) as u64;
        self.senders[dst]
            .send(Msg { src: self.rank, tag, epoch: self.epoch, kind: MsgKind::Data, crc: 0, data })
            .map_err(|_| CommError::Closed { peer: dst })
    }

    /// Supervised send: match planned drop/corrupt/degrade faults on the
    /// tag's logical coordinates, withhold originals for repair, checksum
    /// every frame, and turn a closed channel into a failover.
    fn send_supervised(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> CommResult<()> {
        let step = crate::coordinator::pipeline::tag_step(tag);
        let slot = crate::coordinator::pipeline::tag_slot(tag);
        let purpose = crate::coordinator::pipeline::tag_purpose(tag);
        let mut delay_ms = 0u64;
        let mut drop_frame = false;
        let mut flip_bit: Option<u64> = None;
        if let Some(sup) = self.sup.as_mut() {
            let rank = self.rank;
            for (f, fired) in sup.faults.iter_mut() {
                if f.step != step {
                    continue;
                }
                match f.kind {
                    FaultKind::Drop if !*fired && f.micro == slot && f.purpose == purpose => {
                        *fired = true;
                        *sup.injected.entry("drop").or_insert(0) += 1;
                        sup.marks
                            .push(format!("inject drop rank {rank} -> {dst} tag {tag:#x}"));
                        drop_frame = true;
                    }
                    FaultKind::Corrupt if !*fired && f.micro == slot && f.purpose == purpose => {
                        *fired = true;
                        *sup.injected.entry("corrupt").or_insert(0) += 1;
                        sup.marks.push(format!(
                            "inject corrupt rank {rank} -> {dst} tag {tag:#x} bit {}",
                            f.amount
                        ));
                        flip_bit = Some(f.amount);
                    }
                    FaultKind::LinkDegrade => {
                        if !*fired {
                            *fired = true;
                            *sup.injected.entry("degrade").or_insert(0) += 1;
                            sup.marks.push(format!(
                                "inject degrade rank {rank} step {step} +{} ms/frame",
                                f.amount
                            ));
                        }
                        delay_ms += f.amount;
                    }
                    _ => {}
                }
            }
        }
        let crc = checksum(&data);
        if drop_frame || flip_bit.is_some() {
            if let Some(sup) = self.sup.as_mut() {
                sup.withheld.insert((dst, tag), data.clone());
            }
        }
        if drop_frame {
            // the receiver's repair request will fetch the withheld copy
            return Ok(());
        }
        let mut payload = data;
        if let Some(bit) = flip_bit {
            if !payload.is_empty() {
                let i = (bit as usize) % payload.len();
                // mantissa bits only: finite stays finite
                payload[i] = f32::from_bits(payload[i].to_bits() ^ (1u32 << (bit % 23)));
            }
        }
        if delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(delay_ms));
        }
        let nbytes = (payload.len() * 4) as u64;
        let m = Msg { src: self.rank, tag, epoch: self.epoch, kind: MsgKind::Data, crc, data: payload };
        if self.senders[dst].send(m).is_err() {
            return Err(self.declare_dead(dst));
        }
        self.bytes_sent += nbytes;
        Ok(())
    }

    /// Receive the message with (src, tag), parking unrelated arrivals.
    pub fn recv(&mut self, src: usize, tag: u64) -> CommResult<Vec<f32>> {
        if let Some(q) = self.parked.get_mut(&(self.epoch, src, tag)) {
            if let Some(m) = q.pop_front() {
                return Ok(m);
            }
        }
        if self.sup.is_some() {
            return self.recv_supervised(src, tag);
        }
        loop {
            let m = self.inbox.recv().map_err(|_| CommError::Closed { peer: src })?;
            if let Some(data) = self.admit(m, src, tag)? {
                return Ok(data);
            }
        }
    }

    /// Supervised receive: poll with a bounded logical retry budget,
    /// sending a repair-request probe every [`NACK_EVERY`] empty polls.
    /// The probe doubles as the liveness check — a closed channel is a
    /// death certificate.
    fn recv_supervised(&mut self, src: usize, tag: u64) -> CommResult<Vec<f32>> {
        let budget =
            self.sup.as_ref().map(|s| s.retry_budget).unwrap_or(DEFAULT_RETRY_BUDGET);
        let mut attempts: u64 = 0;
        loop {
            match self.inbox.recv_timeout(Duration::from_millis(POLL_MS)) {
                Ok(m) => {
                    if let Some(data) = self.admit(m, src, tag)? {
                        return Ok(data);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    attempts = attempts + 1;
                    if attempts % NACK_EVERY == 0 {
                        let probe = Msg {
                            src: self.rank,
                            tag,
                            epoch: self.epoch,
                            kind: MsgKind::Resend,
                            crc: 0,
                            data: Vec::new(),
                        };
                        if self.senders[src].send(probe).is_err() {
                            return Err(self.declare_dead(src));
                        }
                    }
                    if attempts >= budget {
                        return Err(CommError::Timeout { src, tag, attempts });
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(CommError::Closed { peer: src });
                }
            }
        }
    }

    /// Process one inbound frame. Returns `Ok(Some(data))` when it
    /// matches (want_src, want_tag) in the current epoch; `Ok(None)` when
    /// it was parked, served, repaired, or discarded; `Err` on a failover
    /// notice or a detected death.
    fn admit(&mut self, m: Msg, want_src: usize, want_tag: u64) -> CommResult<Option<Vec<f32>>> {
        match m.kind {
            MsgKind::Failover { dead } => {
                if let Some(sup) = self.sup.as_mut() {
                    if sup.dead.contains(&dead) {
                        return Ok(None); // duplicate notice, already handled
                    }
                    sup.marks.push(format!(
                        "failover notice: rank {dead} declared dead by rank {}",
                        m.src
                    ));
                    return Err(CommError::Failover { dead });
                }
                Ok(None)
            }
            MsgKind::Resend => {
                let rank = self.rank;
                let epoch = self.epoch;
                let mut served: Option<(usize, u64, Vec<f32>)> = None;
                if let Some(sup) = self.sup.as_mut() {
                    if let Some(payload) = sup.withheld.remove(&(m.src, m.tag)) {
                        sup.repairs_served += 1;
                        sup.marks
                            .push(format!("repair: resend tag {:#x} to rank {}", m.tag, m.src));
                        served = Some((m.src, m.tag, payload));
                    }
                }
                if let Some((dst, tag, payload)) = served {
                    self.bytes_sent += (payload.len() * 4) as u64;
                    let crc = checksum(&payload);
                    // requester death surfaces through its own failover
                    let _ = self.senders[dst].send(Msg {
                        src: rank,
                        tag,
                        epoch,
                        kind: MsgKind::Data,
                        crc,
                        data: payload,
                    });
                }
                Ok(None)
            }
            MsgKind::Data => {
                if m.epoch < self.epoch {
                    return Ok(None); // stale frame from a rolled-back epoch
                }
                if self.sup.is_some() && m.crc != 0 && checksum(&m.data) != m.crc {
                    let rank = self.rank;
                    if let Some(sup) = self.sup.as_mut() {
                        sup.corruptions_detected += 1;
                        sup.marks.push(format!(
                            "detect corrupt frame src {} tag {:#x} at rank {rank}",
                            m.src, m.tag
                        ));
                    }
                    let nack = Msg {
                        src: rank,
                        tag: m.tag,
                        epoch: self.epoch,
                        kind: MsgKind::Resend,
                        crc: 0,
                        data: Vec::new(),
                    };
                    if self.senders[m.src].send(nack).is_err() {
                        return Err(self.declare_dead(m.src));
                    }
                    return Ok(None);
                }
                if m.src == want_src && m.tag == want_tag && m.epoch == self.epoch {
                    return Ok(Some(m.data));
                }
                self.parked.entry((m.epoch, m.src, m.tag)).or_default().push_back(m.data);
                Ok(None)
            }
        }
    }

    pub fn barrier(&self) {
        self.barrier.wait();
    }

    // ---------------------------------------------------------------------
    // Collectives (ring algorithms over the mailboxes)
    // ---------------------------------------------------------------------

    /// In-place ring all-reduce (sum) over the full fabric. All ranks
    /// must pass equal lengths. Reduce-scatter phase then all-gather
    /// phase; 2(n-1) hops, exactly the schedule
    /// `collectives::ring_all_reduce_schedule` costs.
    pub fn all_reduce_sum(&mut self, data: &mut [f32], tag_base: u64) -> CommResult<()> {
        let full: Vec<usize> = (0..self.n_ranks).collect();
        self.all_reduce_sum_group(&full, data, tag_base)
    }

    /// Ring all-reduce restricted to a subgroup of the fabric (every
    /// member passes the same sorted `group` containing its own rank).
    /// With `group == 0..n_ranks` this is bit-identical to
    /// [`Endpoint::all_reduce_sum`]; after a failover the trainer passes
    /// the surviving ranks.
    pub fn all_reduce_sum_group(
        &mut self,
        group: &[usize],
        data: &mut [f32],
        tag_base: u64,
    ) -> CommResult<()> {
        let n = group.len();
        if n <= 1 {
            return Ok(());
        }
        let me = group
            .iter()
            .position(|&r| r == self.rank)
            .ok_or(CommError::NotInGroup { rank: self.rank })?;
        let next = group[(me + 1) % n];
        let prev = group[(me + n - 1) % n];
        let chunks = chunk_ranges(data.len(), n);

        // reduce-scatter: after n-1 steps, position p owns the full sum of
        // chunk (p+1) mod n.
        for step in 0..n - 1 {
            let send_idx = (me + n - step) % n;
            let recv_idx = (me + n - step - 1) % n;
            let out = data[chunks[send_idx].clone()].to_vec();
            self.send(next, tag_base + step as u64, out)?;
            let inc = self.recv(prev, tag_base + step as u64)?;
            let dst = &mut data[chunks[recv_idx].clone()];
            debug_assert_eq!(inc.len(), dst.len());
            for (d, s) in dst.iter_mut().zip(&inc) {
                *d += s;
            }
        }
        // all-gather: circulate the finished chunks.
        for step in 0..n - 1 {
            let send_idx = (me + 1 + n - step) % n;
            let recv_idx = (me + n - step) % n;
            let out = data[chunks[send_idx].clone()].to_vec();
            self.send(next, tag_base + (n + step) as u64, out)?;
            let inc = self.recv(prev, tag_base + (n + step) as u64)?;
            data[chunks[recv_idx].clone()].copy_from_slice(&inc);
        }
        Ok(())
    }

    /// Ring all-gather: each rank contributes `local`; returns all ranks'
    /// contributions concatenated in rank order (equal lengths required).
    pub fn all_gather(&mut self, local: &[f32], tag_base: u64) -> CommResult<Vec<f32>> {
        let n = self.n_ranks;
        let len = local.len();
        let mut out = vec![0.0f32; len * n];
        out[self.rank * len..(self.rank + 1) * len].copy_from_slice(local);
        if n == 1 {
            return Ok(out);
        }
        let next = (self.rank + 1) % n;
        let prev = (self.rank + n - 1) % n;
        for step in 0..n - 1 {
            let send_idx = (self.rank + n - step) % n;
            let recv_idx = (self.rank + n - step - 1) % n;
            let buf = out[send_idx * len..(send_idx + 1) * len].to_vec();
            self.send(next, tag_base + step as u64, buf)?;
            let inc = self.recv(prev, tag_base + step as u64)?;
            out[recv_idx * len..(recv_idx + 1) * len].copy_from_slice(&inc);
        }
        Ok(out)
    }

    /// Pairwise all-to-all: `chunks[d]` goes to rank d; returns the chunks
    /// received from every rank (index = source). Chunk lengths may vary.
    pub fn all_to_all(
        &mut self,
        mut chunks: Vec<Vec<f32>>,
        tag_base: u64,
    ) -> CommResult<Vec<Vec<f32>>> {
        let n = self.n_ranks;
        assert_eq!(chunks.len(), n, "need one chunk per destination");
        let mut out: Vec<Vec<f32>> = (0..n).map(|_| Vec::new()).collect();
        out[self.rank] = std::mem::take(&mut chunks[self.rank]);
        for step in 1..n {
            let dst = (self.rank + step) % n;
            let src = (self.rank + n - step) % n;
            self.send(dst, tag_base + step as u64, std::mem::take(&mut chunks[dst]))?;
            out[src] = self.recv(src, tag_base + step as u64)?;
        }
        Ok(out)
    }

    /// Pairwise all-to-all restricted to a subgroup of the fabric:
    /// `group` lists the participating global ranks (every member calls
    /// with the same list, which must contain its own rank) and
    /// `chunks[i]` goes to `group[i]`. Returns the chunks received,
    /// indexed by group position. This is the EP dispatch/combine
    /// primitive of the mapped driver: each pipeline stage's DP peers
    /// form one expert-parallel group.
    pub fn all_to_all_group(
        &mut self,
        group: &[usize],
        mut chunks: Vec<Vec<f32>>,
        tag_base: u64,
    ) -> CommResult<Vec<Vec<f32>>> {
        let n = group.len();
        assert_eq!(chunks.len(), n, "need one chunk per group member");
        let me = group
            .iter()
            .position(|&r| r == self.rank)
            .ok_or(CommError::NotInGroup { rank: self.rank })?;
        let mut out: Vec<Vec<f32>> = (0..n).map(|_| Vec::new()).collect();
        out[me] = std::mem::take(&mut chunks[me]);
        for step in 1..n {
            let di = (me + step) % n;
            let si = (me + n - step) % n;
            self.send(group[di], tag_base + step as u64, std::mem::take(&mut chunks[di]))?;
            out[si] = self.recv(group[si], tag_base + step as u64)?;
        }
        Ok(out)
    }

    /// Broadcast from `root` (linear; used for small control payloads).
    pub fn broadcast(&mut self, root: usize, data: &mut Vec<f32>, tag: u64) -> CommResult<()> {
        if self.rank == root {
            for dst in 0..self.n_ranks {
                if dst != root {
                    self.send(dst, tag, data.clone())?;
                }
            }
        } else {
            *data = self.recv(root, tag)?;
        }
        Ok(())
    }
}

/// Split `len` into `n` contiguous ranges (first `len % n` get +1).
pub fn chunk_ranges(len: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    out
}

/// Run `f(endpoint)` on `n` worker threads and collect results in rank
/// order. Panics in workers propagate.
pub fn run_workers<R: Send + 'static>(
    n: usize,
    f: impl Fn(Endpoint) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let f = Arc::new(f);
    let mut handles = Vec::with_capacity(n);
    for ep in fabric(n) {
        let f = f.clone();
        handles.push(std::thread::spawn(move || f(ep)));
    }
    handles
        .into_iter()
        .map(|h| match h.join() {
            Ok(r) => r,
            // re-raise the worker's panic payload on the caller thread
            Err(p) => std::panic::resume_unwind(p),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{tag, TAG_DISPATCH, TAG_FWD};

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (len, n) in [(10, 3), (7, 7), (5, 8), (0, 2), (16, 4)] {
            let r = chunk_ranges(len, n);
            assert_eq!(r.len(), n);
            assert_eq!(r.iter().map(|c| c.len()).sum::<usize>(), len);
            for w in r.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        let results = run_workers(4, |mut ep| {
            let mut data: Vec<f32> = (0..10).map(|i| (ep.rank * 10 + i) as f32).collect();
            ep.all_reduce_sum(&mut data, 100).unwrap();
            data
        });
        // element j: sum over ranks of (r*10 + j) = 60 + 4j
        for r in &results {
            for (j, &v) in r.iter().enumerate() {
                assert_eq!(v, 60.0 + 4.0 * j as f32);
            }
        }
    }

    #[test]
    fn all_reduce_handles_ragged_lengths() {
        // length not divisible by n: chunk_ranges covers the remainder.
        let results = run_workers(3, |mut ep| {
            let mut data = vec![1.0f32; 7];
            ep.all_reduce_sum(&mut data, 0).unwrap();
            data
        });
        for r in &results {
            assert!(r.iter().all(|&v| v == 3.0), "{r:?}");
        }
    }

    #[test]
    fn group_all_reduce_sums_within_groups() {
        // Two disjoint groups over one 4-rank fabric: {0, 2} and {1, 3}.
        let results = run_workers(4, |mut ep| {
            let group: Vec<usize> = if ep.rank % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            let mut data = vec![ep.rank as f32; 5];
            ep.all_reduce_sum_group(&group, &mut data, 100).unwrap();
            data
        });
        for (rank, r) in results.iter().enumerate() {
            let want = if rank % 2 == 0 { 2.0 } else { 4.0 }; // 0+2 / 1+3
            assert!(r.iter().all(|&v| v == want), "rank {rank}: {r:?}");
        }
    }

    #[test]
    fn group_all_reduce_rejects_non_members() {
        let results = run_workers(2, |mut ep| {
            if ep.rank == 0 {
                let mut d = vec![1.0];
                ep.all_reduce_sum_group(&[1], &mut d, 0)
            } else {
                Ok(())
            }
        });
        assert_eq!(results[0], Err(CommError::NotInGroup { rank: 0 }));
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let results = run_workers(3, |mut ep| {
            let local = vec![ep.rank as f32; 2];
            ep.all_gather(&local, 7).unwrap()
        });
        for r in &results {
            assert_eq!(r, &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn all_to_all_transposes() {
        let results = run_workers(4, |mut ep| {
            // send [rank, dst] to each dst
            let chunks: Vec<Vec<f32>> =
                (0..4).map(|d| vec![ep.rank as f32, d as f32]).collect();
            ep.all_to_all(chunks, 9).unwrap()
        });
        for (rank, r) in results.iter().enumerate() {
            for (src, chunk) in r.iter().enumerate() {
                assert_eq!(chunk, &[src as f32, rank as f32]);
            }
        }
    }

    #[test]
    fn all_to_all_with_ragged_chunks() {
        let results = run_workers(3, |mut ep| {
            let chunks: Vec<Vec<f32>> =
                (0..3).map(|d| vec![ep.rank as f32; d]).collect(); // len = dst
            ep.all_to_all(chunks, 3).unwrap()
        });
        for (rank, r) in results.iter().enumerate() {
            for (src, chunk) in r.iter().enumerate() {
                assert_eq!(chunk.len(), rank, "src {src}");
                assert!(chunk.iter().all(|&v| v == src as f32));
            }
        }
    }

    #[test]
    fn group_all_to_all_transposes_within_groups() {
        // Two disjoint groups over one 4-rank fabric: {0, 2} and {1, 3}.
        // Each member sends [rank, dst] to every group peer; concurrent
        // groups must not cross-talk even on the same tag base.
        let results = run_workers(4, |mut ep| {
            let group: Vec<usize> = if ep.rank % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            let chunks: Vec<Vec<f32>> =
                group.iter().map(|&d| vec![ep.rank as f32, d as f32]).collect();
            (group.clone(), ep.all_to_all_group(&group, chunks, 11).unwrap())
        });
        for (rank, (group, got)) in results.iter().enumerate() {
            for (i, chunk) in got.iter().enumerate() {
                assert_eq!(chunk, &[group[i] as f32, rank as f32]);
            }
        }
    }

    #[test]
    fn group_all_to_all_carries_ragged_chunks() {
        let results = run_workers(3, |mut ep| {
            let group = [0usize, 1, 2];
            let chunks: Vec<Vec<f32>> = (0..3).map(|d| vec![ep.rank as f32; d + 1]).collect();
            ep.all_to_all_group(&group, chunks, 17).unwrap()
        });
        for (rank, got) in results.iter().enumerate() {
            for (src, chunk) in got.iter().enumerate() {
                assert_eq!(chunk.len(), rank + 1, "src {src}");
                assert!(chunk.iter().all(|&v| v == src as f32));
            }
        }
    }

    #[test]
    fn broadcast_from_root() {
        let results = run_workers(4, |mut ep| {
            let mut data = if ep.rank == 2 { vec![42.0, 7.0] } else { vec![] };
            ep.broadcast(2, &mut data, 5).unwrap();
            data
        });
        for r in results {
            assert_eq!(r, vec![42.0, 7.0]);
        }
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        let results = run_workers(2, |mut ep| {
            if ep.rank == 0 {
                ep.send(1, 2, vec![2.0]).unwrap();
                ep.send(1, 1, vec![1.0]).unwrap();
                vec![]
            } else {
                // request tag 1 first even though tag 2 arrives first
                let a = ep.recv(0, 1).unwrap();
                let b = ep.recv(0, 2).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(results[1], vec![1.0, 2.0]);
    }

    #[test]
    fn single_rank_collectives_are_noops() {
        let results = run_workers(1, |mut ep| {
            let mut d = vec![5.0];
            ep.all_reduce_sum(&mut d, 0).unwrap();
            let g = ep.all_gather(&d, 1).unwrap();
            (d, g)
        });
        assert_eq!(results[0].0, vec![5.0]);
        assert_eq!(results[0].1, vec![5.0]);
    }

    // -- supervision ------------------------------------------------------

    #[test]
    fn supervised_recv_times_out_on_silent_peer() {
        // budget < NACK_EVERY: exhaust the retry budget before any probe.
        let results = run_workers(2, |mut ep| {
            if ep.rank == 1 {
                ep.enable_chaos(Vec::new());
                ep.set_retry_budget(8);
                Some(ep.recv(0, 5))
            } else {
                // stay alive past the peer's budget so only the timeout
                // path (not death detection) can fire
                std::thread::sleep(Duration::from_millis(200));
                None
            }
        });
        match results[1] {
            Some(Err(CommError::Timeout { src: 0, tag: 5, attempts })) => {
                assert_eq!(attempts, 8);
            }
            ref other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn dead_peer_detection_fails_over() {
        let results = run_workers(2, |mut ep| {
            if ep.rank == 1 {
                ep.enable_chaos(Vec::new());
                Some(ep.recv(0, 5))
            } else {
                None // exit immediately: channel closes
            }
        });
        assert_eq!(results[1], Some(Err(CommError::Failover { dead: 0 })));
    }

    #[test]
    fn drop_fault_recovers_by_resend() {
        let t = tag(0, 0, TAG_FWD);
        let ack = tag(0, 1, TAG_FWD);
        let results = run_workers(2, move |mut ep| {
            if ep.rank == 0 {
                ep.enable_chaos(vec![PlannedFault {
                    rank: 0,
                    step: 0,
                    micro: 0,
                    purpose: TAG_FWD,
                    kind: FaultKind::Drop,
                    amount: 0,
                }]);
                ep.send(1, t, vec![1.0, 2.0, 3.0]).unwrap();
                // serving the repair request happens inside this recv
                let got = ep.recv(1, ack).unwrap();
                assert_eq!(got, vec![9.0]);
                let (injected, _, repairs) = ep.chaos_counters();
                (injected.get("drop").copied(), repairs, Vec::new())
            } else {
                ep.enable_chaos(Vec::new());
                let data = ep.recv(0, t).unwrap();
                ep.send(0, ack, vec![9.0]).unwrap();
                (None, 0, data)
            }
        });
        assert_eq!(results[0].0, Some(1), "drop injected");
        assert_eq!(results[0].1, 1, "repair served");
        assert_eq!(results[1].2, vec![1.0, 2.0, 3.0], "payload repaired intact");
    }

    #[test]
    fn corrupt_fault_detected_and_repaired() {
        let t = tag(2, 1, TAG_DISPATCH);
        let ack = tag(2, 2, TAG_DISPATCH);
        let results = run_workers(2, move |mut ep| {
            if ep.rank == 0 {
                ep.enable_chaos(vec![PlannedFault {
                    rank: 0,
                    step: 2,
                    micro: 1,
                    purpose: TAG_DISPATCH,
                    kind: FaultKind::Corrupt,
                    amount: 3,
                }]);
                ep.send(1, t, vec![4.0, 5.0]).unwrap();
                let _ = ep.recv(1, ack).unwrap();
                let (injected, _, repairs) = ep.chaos_counters();
                (injected.get("corrupt").copied(), repairs, 0, Vec::new())
            } else {
                ep.enable_chaos(Vec::new());
                let data = ep.recv(0, t).unwrap();
                ep.send(0, ack, vec![0.0]).unwrap();
                let (_, corruptions, _) = ep.chaos_counters();
                (None, 0, corruptions, data)
            }
        });
        assert_eq!(results[0].0, Some(1), "corrupt injected");
        assert_eq!(results[0].1, 1, "repair served");
        assert_eq!(results[1].2, 1, "corruption detected by checksum");
        assert_eq!(results[1].3, vec![4.0, 5.0], "payload repaired intact");
    }

    #[test]
    fn complete_failover_purges_stale_epochs() {
        let mut eps = fabric(2);
        let mut a = eps.remove(0);
        let mut b = eps.remove(0);
        a.enable_chaos(Vec::new());
        b.enable_chaos(Vec::new());
        // park an epoch-0 frame at b, then fail over: it must vanish
        b.send(0, 0, Vec::new()).unwrap(); // keep b's channel warm (self-consistency)
        a.send(1, 7, vec![1.0]).unwrap();
        b.set_retry_budget(200);
        let got = b.recv(0, 7).unwrap();
        assert_eq!(got, vec![1.0]);
        a.send(1, 8, vec![2.0]).unwrap();
        // b parks tag 8 while looking for tag 9... simulate by failing over first
        b.complete_failover(0);
        b.set_retry_budget(2);
        // the stale epoch-0 frame for tag 8 is discarded on arrival
        assert_eq!(b.recv(0, 8), Err(CommError::Timeout { src: 0, tag: 8, attempts: 2 }));
    }
}
