//! In-process communication fabric for the miniature cluster: typed
//! mailboxes between worker threads plus real collective algorithms (ring
//! all-reduce / all-gather, pairwise all-to-all, barrier) over them.
//!
//! These are the same algorithms whose Hockney costs drive the performance
//! model and whose schedules the netsim replays — here they move real
//! `f32` payloads (gradients, routed tokens) between the PJRT executables.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

/// A tagged message between ranks.
#[derive(Debug)]
pub struct Msg {
    pub src: usize,
    pub tag: u64,
    pub data: Vec<f32>,
}

/// Per-rank endpoint of the fabric.
pub struct Endpoint {
    pub rank: usize,
    pub n_ranks: usize,
    senders: Vec<Sender<Msg>>,
    inbox: Receiver<Msg>,
    /// out-of-order arrivals parked until matched
    parked: BTreeMap<(usize, u64), VecDeque<Vec<f32>>>,
    barrier: Arc<Barrier>,
    /// bytes sent (metrics)
    pub bytes_sent: u64,
}

/// Build a fully-connected fabric of `n` endpoints.
pub fn fabric(n: usize) -> Vec<Endpoint> {
    assert!(n > 0);
    let mut senders = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Msg>();
        senders.push(tx);
        receivers.push(rx);
    }
    let barrier = Arc::new(Barrier::new(n));
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| Endpoint {
            rank,
            n_ranks: n,
            senders: senders.clone(),
            inbox,
            parked: BTreeMap::new(),
            barrier: barrier.clone(),
            bytes_sent: 0,
        })
        .collect()
}

impl Endpoint {
    pub fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) {
        self.bytes_sent += (data.len() * 4) as u64;
        self.senders[dst]
            .send(Msg { src: self.rank, tag, data })
            // lumos: allow(panic-path) -- a closed channel means a peer already panicked; propagate the abort
            .expect("peer hung up");
    }

    /// Receive the message with (src, tag), parking unrelated arrivals.
    pub fn recv(&mut self, src: usize, tag: u64) -> Vec<f32> {
        if let Some(q) = self.parked.get_mut(&(src, tag)) {
            if let Some(m) = q.pop_front() {
                return m;
            }
        }
        loop {
            // lumos: allow(panic-path) -- a closed fabric means a peer already panicked; propagate the abort
            let m = self.inbox.recv().expect("fabric closed");
            if m.src == src && m.tag == tag {
                return m.data;
            }
            self.parked.entry((m.src, m.tag)).or_default().push_back(m.data);
        }
    }

    pub fn barrier(&self) {
        self.barrier.wait();
    }

    // ---------------------------------------------------------------------
    // Collectives (ring algorithms over the mailboxes)
    // ---------------------------------------------------------------------

    /// In-place ring all-reduce (sum). All ranks must pass equal lengths.
    /// Reduce-scatter phase then all-gather phase; 2(n-1) hops, exactly the
    /// schedule `collectives::ring_all_reduce_schedule` costs.
    pub fn all_reduce_sum(&mut self, data: &mut [f32], tag_base: u64) {
        let n = self.n_ranks;
        if n == 1 {
            return;
        }
        let next = (self.rank + 1) % n;
        let prev = (self.rank + n - 1) % n;
        let chunks = chunk_ranges(data.len(), n);

        // reduce-scatter: after n-1 steps, rank r owns the full sum of
        // chunk (r+1) mod n.
        for step in 0..n - 1 {
            let send_idx = (self.rank + n - step) % n;
            let recv_idx = (self.rank + n - step - 1) % n;
            let out = data[chunks[send_idx].clone()].to_vec();
            self.send(next, tag_base + step as u64, out);
            let inc = self.recv(prev, tag_base + step as u64);
            let dst = &mut data[chunks[recv_idx].clone()];
            debug_assert_eq!(inc.len(), dst.len());
            for (d, s) in dst.iter_mut().zip(&inc) {
                *d += s;
            }
        }
        // all-gather: circulate the finished chunks.
        for step in 0..n - 1 {
            let send_idx = (self.rank + 1 + n - step) % n;
            let recv_idx = (self.rank + n - step) % n;
            let out = data[chunks[send_idx].clone()].to_vec();
            self.send(next, tag_base + (n + step) as u64, out);
            let inc = self.recv(prev, tag_base + (n + step) as u64);
            data[chunks[recv_idx].clone()].copy_from_slice(&inc);
        }
    }

    /// Ring all-gather: each rank contributes `local`; returns all ranks'
    /// contributions concatenated in rank order (equal lengths required).
    pub fn all_gather(&mut self, local: &[f32], tag_base: u64) -> Vec<f32> {
        let n = self.n_ranks;
        let len = local.len();
        let mut out = vec![0.0f32; len * n];
        out[self.rank * len..(self.rank + 1) * len].copy_from_slice(local);
        if n == 1 {
            return out;
        }
        let next = (self.rank + 1) % n;
        let prev = (self.rank + n - 1) % n;
        for step in 0..n - 1 {
            let send_idx = (self.rank + n - step) % n;
            let recv_idx = (self.rank + n - step - 1) % n;
            let buf = out[send_idx * len..(send_idx + 1) * len].to_vec();
            self.send(next, tag_base + step as u64, buf);
            let inc = self.recv(prev, tag_base + step as u64);
            out[recv_idx * len..(recv_idx + 1) * len].copy_from_slice(&inc);
        }
        out
    }

    /// Pairwise all-to-all: `chunks[d]` goes to rank d; returns the chunks
    /// received from every rank (index = source). Chunk lengths may vary.
    pub fn all_to_all(&mut self, mut chunks: Vec<Vec<f32>>, tag_base: u64) -> Vec<Vec<f32>> {
        let n = self.n_ranks;
        assert_eq!(chunks.len(), n, "need one chunk per destination");
        let mut out: Vec<Vec<f32>> = (0..n).map(|_| Vec::new()).collect();
        out[self.rank] = std::mem::take(&mut chunks[self.rank]);
        for step in 1..n {
            let dst = (self.rank + step) % n;
            let src = (self.rank + n - step) % n;
            self.send(dst, tag_base + step as u64, std::mem::take(&mut chunks[dst]));
            out[src] = self.recv(src, tag_base + step as u64);
        }
        out
    }

    /// Pairwise all-to-all restricted to a subgroup of the fabric:
    /// `group` lists the participating global ranks (every member calls
    /// with the same list, which must contain its own rank) and
    /// `chunks[i]` goes to `group[i]`. Returns the chunks received,
    /// indexed by group position. This is the EP dispatch/combine
    /// primitive of the mapped driver: each pipeline stage's DP peers
    /// form one expert-parallel group.
    pub fn all_to_all_group(
        &mut self,
        group: &[usize],
        mut chunks: Vec<Vec<f32>>,
        tag_base: u64,
    ) -> Vec<Vec<f32>> {
        let n = group.len();
        assert_eq!(chunks.len(), n, "need one chunk per group member");
        let me = group
            .iter()
            .position(|&r| r == self.rank)
            // lumos: allow(panic-path) -- caller bug: a rank outside the group joined its collective
            .expect("calling rank not in group");
        let mut out: Vec<Vec<f32>> = (0..n).map(|_| Vec::new()).collect();
        out[me] = std::mem::take(&mut chunks[me]);
        for step in 1..n {
            let di = (me + step) % n;
            let si = (me + n - step) % n;
            self.send(group[di], tag_base + step as u64, std::mem::take(&mut chunks[di]));
            out[si] = self.recv(group[si], tag_base + step as u64);
        }
        out
    }

    /// Broadcast from `root` (linear; used for small control payloads).
    pub fn broadcast(&mut self, root: usize, data: &mut Vec<f32>, tag: u64) {
        if self.rank == root {
            for dst in 0..self.n_ranks {
                if dst != root {
                    self.send(dst, tag, data.clone());
                }
            }
        } else {
            *data = self.recv(root, tag);
        }
    }
}

/// Split `len` into `n` contiguous ranges (first `len % n` get +1).
pub fn chunk_ranges(len: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    out
}

/// Run `f(endpoint)` on `n` worker threads and collect results in rank
/// order. Panics in workers propagate.
pub fn run_workers<R: Send + 'static>(
    n: usize,
    f: impl Fn(Endpoint) -> R + Send + Sync + 'static,
) -> Vec<R> {
    let f = Arc::new(f);
    let mut handles = Vec::with_capacity(n);
    for ep in fabric(n) {
        let f = f.clone();
        handles.push(std::thread::spawn(move || f(ep)));
    }
    handles
        .into_iter()
        // lumos: allow(panic-path) -- run_workers propagates worker panics to the caller by design
        .map(|h| h.join().expect("worker panicked"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (len, n) in [(10, 3), (7, 7), (5, 8), (0, 2), (16, 4)] {
            let r = chunk_ranges(len, n);
            assert_eq!(r.len(), n);
            assert_eq!(r.iter().map(|c| c.len()).sum::<usize>(), len);
            for w in r.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
        }
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        let results = run_workers(4, |mut ep| {
            let mut data: Vec<f32> = (0..10).map(|i| (ep.rank * 10 + i) as f32).collect();
            ep.all_reduce_sum(&mut data, 100);
            data
        });
        // element j: sum over ranks of (r*10 + j) = 60 + 4j
        for r in &results {
            for (j, &v) in r.iter().enumerate() {
                assert_eq!(v, 60.0 + 4.0 * j as f32);
            }
        }
    }

    #[test]
    fn all_reduce_handles_ragged_lengths() {
        // length not divisible by n: chunk_ranges covers the remainder.
        let results = run_workers(3, |mut ep| {
            let mut data = vec![1.0f32; 7];
            ep.all_reduce_sum(&mut data, 0);
            data
        });
        for r in &results {
            assert!(r.iter().all(|&v| v == 3.0), "{r:?}");
        }
    }

    #[test]
    fn all_gather_orders_by_rank() {
        let results = run_workers(3, |mut ep| {
            let local = vec![ep.rank as f32; 2];
            ep.all_gather(&local, 7)
        });
        for r in &results {
            assert_eq!(r, &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn all_to_all_transposes() {
        let results = run_workers(4, |mut ep| {
            // send [rank, dst] to each dst
            let chunks: Vec<Vec<f32>> =
                (0..4).map(|d| vec![ep.rank as f32, d as f32]).collect();
            ep.all_to_all(chunks, 9)
        });
        for (rank, r) in results.iter().enumerate() {
            for (src, chunk) in r.iter().enumerate() {
                assert_eq!(chunk, &[src as f32, rank as f32]);
            }
        }
    }

    #[test]
    fn all_to_all_with_ragged_chunks() {
        let results = run_workers(3, |mut ep| {
            let chunks: Vec<Vec<f32>> =
                (0..3).map(|d| vec![ep.rank as f32; d]).collect(); // len = dst
            ep.all_to_all(chunks, 3)
        });
        for (rank, r) in results.iter().enumerate() {
            for (src, chunk) in r.iter().enumerate() {
                assert_eq!(chunk.len(), rank, "src {src}");
                assert!(chunk.iter().all(|&v| v == src as f32));
            }
        }
    }

    #[test]
    fn group_all_to_all_transposes_within_groups() {
        // Two disjoint groups over one 4-rank fabric: {0, 2} and {1, 3}.
        // Each member sends [rank, dst] to every group peer; concurrent
        // groups must not cross-talk even on the same tag base.
        let results = run_workers(4, |mut ep| {
            let group: Vec<usize> = if ep.rank % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            let chunks: Vec<Vec<f32>> =
                group.iter().map(|&d| vec![ep.rank as f32, d as f32]).collect();
            (group.clone(), ep.all_to_all_group(&group, chunks, 11))
        });
        for (rank, (group, got)) in results.iter().enumerate() {
            for (i, chunk) in got.iter().enumerate() {
                assert_eq!(chunk, &[group[i] as f32, rank as f32]);
            }
        }
    }

    #[test]
    fn group_all_to_all_carries_ragged_chunks() {
        let results = run_workers(3, |mut ep| {
            let group = [0usize, 1, 2];
            let chunks: Vec<Vec<f32>> = (0..3).map(|d| vec![ep.rank as f32; d + 1]).collect();
            ep.all_to_all_group(&group, chunks, 17)
        });
        for (rank, got) in results.iter().enumerate() {
            for (src, chunk) in got.iter().enumerate() {
                assert_eq!(chunk.len(), rank + 1, "src {src}");
                assert!(chunk.iter().all(|&v| v == src as f32));
            }
        }
    }

    #[test]
    fn broadcast_from_root() {
        let results = run_workers(4, |mut ep| {
            let mut data = if ep.rank == 2 { vec![42.0, 7.0] } else { vec![] };
            ep.broadcast(2, &mut data, 5);
            data
        });
        for r in results {
            assert_eq!(r, vec![42.0, 7.0]);
        }
    }

    #[test]
    fn out_of_order_tags_are_parked() {
        let results = run_workers(2, |mut ep| {
            if ep.rank == 0 {
                ep.send(1, 2, vec![2.0]);
                ep.send(1, 1, vec![1.0]);
                vec![]
            } else {
                // request tag 1 first even though tag 2 arrives first
                let a = ep.recv(0, 1);
                let b = ep.recv(0, 2);
                vec![a[0], b[0]]
            }
        });
        assert_eq!(results[1], vec![1.0, 2.0]);
    }

    #[test]
    fn single_rank_collectives_are_noops() {
        let results = run_workers(1, |mut ep| {
            let mut d = vec![5.0];
            ep.all_reduce_sum(&mut d, 0);
            let g = ep.all_gather(&d, 1);
            (d, g)
        });
        assert_eq!(results[0].0, vec![5.0]);
        assert_eq!(results[0].1, vec![5.0]);
    }
}
