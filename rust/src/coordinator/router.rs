//! Expert-parallel token router: the L3 coordination piece of MoE training
//! (paper §II.A, §V.B). Maps each token's top-k expert choices to
//! destination ranks, enforces per-expert capacity (GShard-style), tracks
//! drops and per-expert load, and packs per-destination payloads for the
//! all-to-all.
//!
//! The paper's closing §VI point — Passage's high-bandwidth domain
//! "eliminates strict routing constraints" like device-limited routing —
//! is exercised by the `max_devices_per_token` knob (DeepSeek-V2-style
//! M-device restriction) and the `routing_restriction` ablation bench.

use crate::util::rng::Rng;

/// Static routing configuration for one MoE layer.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub n_experts: usize,
    pub top_k: usize,
    /// Experts hosted per EP rank.
    pub experts_per_rank: usize,
    /// Per-expert token capacity per routing round.
    pub capacity: usize,
    /// Optional device-limited routing: each token's experts must sit on
    /// at most M distinct ranks (None = unrestricted — the Passage case).
    pub max_devices_per_token: Option<usize>,
    /// Optional degraded-fabric remap after a failover:
    /// `(owners, n_peers)` where `owners[expert]` is the group *position*
    /// now hosting that expert among the `n_peers` surviving EP peers
    /// (see [`crate::chaos::degraded_owners`]). None = the healthy
    /// block layout.
    pub remap: Option<(Vec<usize>, usize)>,
}

impl RouterConfig {
    pub fn n_ranks(&self) -> usize {
        if let Some((_, n_peers)) = &self.remap {
            return *n_peers;
        }
        assert_eq!(self.n_experts % self.experts_per_rank, 0);
        self.n_experts / self.experts_per_rank
    }

    pub fn rank_of_expert(&self, e: usize) -> usize {
        if let Some((owners, _)) = &self.remap {
            return owners[e];
        }
        e / self.experts_per_rank
    }
}

/// One routed token instance (token replicated per selected expert).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub token: usize,
    pub expert: usize,
    pub rank: usize,
    /// Slot within the expert's capacity buffer.
    pub slot: usize,
}

/// Result of routing one batch of tokens.
#[derive(Debug, Clone)]
pub struct RouteResult {
    pub assignments: Vec<Assignment>,
    /// (token, expert) pairs dropped by capacity overflow.
    pub dropped: Vec<(usize, usize)>,
    /// tokens accepted per expert.
    pub expert_load: Vec<usize>,
    /// token-instances destined to each rank (a2a payload sizes).
    pub per_rank_tokens: Vec<usize>,
}

impl RouteResult {
    /// Load-imbalance factor: max/mean expert load (1.0 = perfectly even).
    pub fn imbalance(&self) -> f64 {
        let max = self.expert_load.iter().copied().max().unwrap_or(0) as f64;
        let mean = self.expert_load.iter().sum::<usize>() as f64
            / self.expert_load.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    pub fn drop_rate(&self, n_tokens: usize, top_k: usize) -> f64 {
        self.dropped.len() as f64 / (n_tokens * top_k) as f64
    }
}

/// The router itself (stateless between batches apart from config).
#[derive(Debug, Clone)]
pub struct Router {
    pub cfg: RouterConfig,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        assert!(cfg.top_k <= cfg.n_experts);
        Router { cfg }
    }

    /// Route tokens given their top-k expert preference lists (ordered by
    /// gate score). Capacity is granted in (slot, token) order, matching
    /// the L2 model's GShard cumsum dispatch (model.py `_route`).
    pub fn route(&self, choices: &[Vec<usize>]) -> RouteResult {
        let e = self.cfg.n_experts;
        let mut load = vec![0usize; e];
        let mut assignments = Vec::new();
        let mut dropped = Vec::new();
        let mut per_rank = vec![0usize; self.cfg.n_ranks()];

        for slot in 0..self.cfg.top_k {
            for (token, prefs) in choices.iter().enumerate() {
                let Some(&expert) = prefs.get(slot) else { continue };
                assert!(expert < e, "expert {expert} out of range");
                if let Some(m) = self.cfg.max_devices_per_token {
                    // count distinct ranks already used by this token
                    let used: std::collections::BTreeSet<usize> = assignments
                        .iter()
                        .filter(|a: &&Assignment| a.token == token)
                        .map(|a| a.rank)
                        .collect();
                    let rank = self.cfg.rank_of_expert(expert);
                    if !used.contains(&rank) && used.len() >= m {
                        dropped.push((token, expert));
                        continue;
                    }
                }
                if load[expert] >= self.cfg.capacity {
                    dropped.push((token, expert));
                    continue;
                }
                let rank = self.cfg.rank_of_expert(expert);
                assignments.push(Assignment { token, expert, rank, slot: load[expert] });
                load[expert] += 1;
                per_rank[rank] += 1;
            }
        }
        RouteResult { assignments, dropped, expert_load: load, per_rank_tokens: per_rank }
    }

    /// Pack per-destination-rank payloads for the all-to-all: each
    /// assignment contributes the token's feature vector.
    pub fn pack_a2a(
        &self,
        result: &RouteResult,
        features: &[Vec<f32>],
    ) -> Vec<Vec<f32>> {
        let d = features.first().map_or(0, Vec::len);
        let mut out: Vec<Vec<f32>> = (0..self.cfg.n_ranks()).map(|_| Vec::new()).collect();
        for a in &result.assignments {
            out[a.rank].extend_from_slice(&features[a.token]);
        }
        for (r, buf) in out.iter().enumerate() {
            debug_assert_eq!(buf.len(), result.per_rank_tokens[r] * d);
        }
        out
    }

    /// Like [`Router::pack_a2a`], but each destination payload is
    /// self-describing: a manifest header tells the receiving rank which
    /// (token, expert) pair every feature vector belongs to, so the
    /// expert owner can run the right expert with no out-of-band
    /// metadata exchange. Layout per destination rank:
    ///
    /// `[n, token_0, expert_0, .., token_{n-1}, expert_{n-1}, feat_0 (d
    /// floats), .., feat_{n-1}]`
    ///
    /// Header values ride in the f32 payload itself, which is exact
    /// below 2^24 — far above any microbatch token index or expert id.
    /// Entries appear in route order (the same order `pack_a2a` uses),
    /// so the sender can pair the combine-phase reply chunks with its
    /// own per-rank assignment list positionally.
    pub fn pack_a2a_manifest(&self, result: &RouteResult, features: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let n_ranks = self.cfg.n_ranks();
        let mut out: Vec<Vec<f32>> = (0..n_ranks).map(|_| Vec::new()).collect();
        for (r, buf) in out.iter_mut().enumerate() {
            buf.push(result.per_rank_tokens[r] as f32);
        }
        for a in &result.assignments {
            out[a.rank].push(a.token as f32);
            out[a.rank].push(a.expert as f32);
        }
        for a in &result.assignments {
            out[a.rank].extend_from_slice(&features[a.token]);
        }
        out
    }

    /// Draw top-k expert choices from a Zipf popularity distribution
    /// (workload generator for router/bench/netsim studies).
    pub fn synthetic_choices(
        &self,
        n_tokens: usize,
        zipf_alpha: f64,
        rng: &mut Rng,
    ) -> Vec<Vec<usize>> {
        let e = self.cfg.n_experts;
        // Random expert permutation so popularity isn't tied to rank order.
        let mut perm: Vec<usize> = (0..e).collect();
        rng.shuffle(&mut perm);
        (0..n_tokens)
            .map(|_| {
                let mut picks = Vec::with_capacity(self.cfg.top_k);
                while picks.len() < self.cfg.top_k {
                    let c = perm[rng.zipf(e, zipf_alpha)];
                    if !picks.contains(&c) {
                        picks.push(c);
                    }
                }
                picks
            })
            .collect()
    }
}

/// One routed token instance as decoded by the receiving rank from a
/// [`Router::pack_a2a_manifest`] payload. `token` is the *sender's*
/// token index; the receiver treats it as an opaque correlation id.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutedToken {
    pub token: usize,
    pub expert: usize,
    pub features: Vec<f32>,
}

/// Inverse of [`Router::pack_a2a_manifest`] for one received payload
/// with feature dimension `d`. Panics on a malformed payload — peers
/// are in-process workers, so a bad frame is a programming error.
pub fn unpack_a2a_manifest(payload: &[f32], d: usize) -> Vec<RoutedToken> {
    assert!(!payload.is_empty(), "manifest payload missing count header");
    let n = payload[0] as usize;
    assert_eq!(payload.len(), 1 + n * (2 + d), "malformed manifest payload");
    let feats = &payload[1 + 2 * n..];
    (0..n)
        .map(|i| RoutedToken {
            token: payload[1 + 2 * i] as usize,
            expert: payload[2 + 2 * i] as usize,
            features: feats[i * d..(i + 1) * d].to_vec(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn cfg(e: usize, k: usize, epr: usize, cap: usize) -> RouterConfig {
        RouterConfig {
            n_experts: e,
            top_k: k,
            experts_per_rank: epr,
            capacity: cap,
            max_devices_per_token: None,
            remap: None,
        }
    }

    #[test]
    fn routes_everything_with_headroom() {
        let r = Router::new(cfg(4, 2, 2, 100));
        let choices = vec![vec![0, 1], vec![2, 3], vec![1, 2]];
        let res = r.route(&choices);
        assert_eq!(res.assignments.len(), 6);
        assert!(res.dropped.is_empty());
        assert_eq!(res.expert_load, vec![1, 2, 2, 1]);
        assert_eq!(res.per_rank_tokens, vec![3, 3]);
    }

    #[test]
    fn capacity_overflow_drops_in_order() {
        let r = Router::new(cfg(2, 1, 1, 2));
        let choices: Vec<Vec<usize>> = (0..5).map(|_| vec![0]).collect();
        let res = r.route(&choices);
        assert_eq!(res.expert_load[0], 2);
        assert_eq!(res.dropped.len(), 3);
        // earliest tokens won the slots
        assert_eq!(res.assignments[0].token, 0);
        assert_eq!(res.assignments[1].token, 1);
    }

    #[test]
    fn slots_are_dense_and_unique_per_expert() {
        let r = Router::new(cfg(3, 2, 3, 8));
        let mut rng = Rng::new(1);
        let choices = r.synthetic_choices(20, 1.0, &mut rng);
        let res = r.route(&choices);
        for e in 0..3 {
            let mut slots: Vec<usize> = res
                .assignments
                .iter()
                .filter(|a| a.expert == e)
                .map(|a| a.slot)
                .collect();
            slots.sort_unstable();
            let expect: Vec<usize> = (0..slots.len()).collect();
            assert_eq!(slots, expect);
        }
    }

    #[test]
    fn device_limited_routing_restricts_ranks() {
        let mut c = cfg(8, 4, 1, 100); // 8 ranks, 1 expert each
        c.max_devices_per_token = Some(2);
        let r = Router::new(c);
        let choices = vec![vec![0, 1, 2, 3]];
        let res = r.route(&choices);
        let ranks: std::collections::BTreeSet<usize> =
            res.assignments.iter().map(|a| a.rank).collect();
        assert!(ranks.len() <= 2);
        assert_eq!(res.dropped.len(), 2);
    }

    #[test]
    fn pack_a2a_sizes_match_loads() {
        let r = Router::new(cfg(4, 2, 2, 10));
        let choices = vec![vec![0, 2], vec![3, 1]];
        let res = r.route(&choices);
        let feats = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let packed = r.pack_a2a(&res, &feats);
        assert_eq!(packed.len(), 2);
        let total: usize = packed.iter().map(Vec::len).sum();
        assert_eq!(total, res.assignments.len() * 2);
    }

    #[test]
    fn manifest_round_trips_per_rank() {
        let r = Router::new(cfg(4, 2, 2, 10));
        let choices = vec![vec![0, 2], vec![3, 1], vec![2, 0]];
        let res = r.route(&choices);
        let d = 3;
        let feats: Vec<Vec<f32>> =
            (0..3).map(|t| (0..d).map(|j| (10 * t + j) as f32).collect()).collect();
        let packed = r.pack_a2a_manifest(&res, &feats);
        assert_eq!(packed.len(), 2);
        for (rank, payload) in packed.iter().enumerate() {
            let got = unpack_a2a_manifest(payload, d);
            let want: Vec<RoutedToken> = res
                .assignments
                .iter()
                .filter(|a| a.rank == rank)
                .map(|a| RoutedToken {
                    token: a.token,
                    expert: a.expert,
                    features: feats[a.token].clone(),
                })
                .collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn empty_manifest_payload_unpacks_to_nothing() {
        let r = Router::new(cfg(2, 1, 1, 4));
        // both tokens pick expert 0 -> rank 1 receives nothing
        let res = r.route(&[vec![0], vec![0]]);
        let feats = vec![vec![1.0f32], vec![2.0]];
        let packed = r.pack_a2a_manifest(&res, &feats);
        assert_eq!(packed[1], vec![0.0]);
        assert!(unpack_a2a_manifest(&packed[1], 1).is_empty());
    }

    #[test]
    fn conservation_property() {
        check("routed + dropped == offered", 64, |g| {
            let e = [2usize, 4, 8][g.usize(0, 2)];
            let k = g.usize(1, e.min(3));
            let cap = g.usize(1, 16);
            let n = g.usize(1, 64);
            let r = Router::new(cfg(e, k, 1, cap));
            let mut rng = Rng::new(g.u64(1 << 30));
            let choices = r.synthetic_choices(n, 1.0, &mut rng);
            let res = r.route(&choices);
            prop_assert!(
                res.assignments.len() + res.dropped.len() == n * k,
                "conservation violated: {} + {} != {}",
                res.assignments.len(),
                res.dropped.len(),
                n * k
            );
            for (&l, _) in res.expert_load.iter().zip(0..) {
                prop_assert!(l <= cap, "capacity exceeded");
            }
            let rank_sum: usize = res.per_rank_tokens.iter().sum();
            prop_assert!(rank_sum == res.assignments.len(), "per-rank mismatch");
            Ok(())
        });
    }

    #[test]
    fn remap_redirects_experts_to_surviving_peers() {
        // 4 experts over dp=2 (2 per rank); group 0 retired, group 1
        // survives alone as position 0 of a 1-peer fabric.
        let mut c = cfg(4, 2, 2, 10);
        c.remap = Some((crate::chaos::degraded_owners(4, 2, &[1]), 1));
        let r = Router::new(c);
        assert_eq!(r.cfg.n_ranks(), 1);
        for e in 0..4 {
            assert_eq!(r.cfg.rank_of_expert(e), 0);
        }
        let res = r.route(&[vec![0, 3], vec![1, 2]]);
        assert_eq!(res.per_rank_tokens, vec![4]);
        let packed = r.pack_a2a_manifest(&res, &[vec![1.0], vec![2.0]]);
        assert_eq!(packed.len(), 1);
        assert_eq!(unpack_a2a_manifest(&packed[0], 1).len(), 4);
    }

    #[test]
    fn skew_increases_imbalance_and_drops() {
        let r = Router::new(cfg(8, 2, 1, 24));
        let mut rng = Rng::new(7);
        let uniform = r.route(&r.synthetic_choices(64, 0.01, &mut rng));
        let skewed = r.route(&r.synthetic_choices(64, 2.0, &mut rng));
        assert!(skewed.imbalance() > uniform.imbalance());
        assert!(skewed.dropped.len() >= uniform.dropped.len());
    }
}
