//! The L3 coordinator: a miniature distributed-MoE-training runtime.
//!
//! The paper's system contribution is the *fabric* (what a bigger scale-up
//! domain buys); the coordination patterns it accelerates are implemented
//! here at laptop scale and moved onto real threads with real payloads:
//!
//! - [`comm`]: worker fabric + ring all-reduce / all-gather, pairwise
//!   all-to-all, broadcast, barrier — the algorithms the Hockney models
//!   cost and the netsim replays.
//! - [`router`]: top-k expert routing with capacity, drops, device-limited
//!   routing, and all-to-all payload packing.
//! - [`pipeline`]: 1F1B microbatch schedule with machine-checked
//!   invariants (the bubble model used by [`crate::perf`]).
//!
//! [`crate::trainer`] composes these with the PJRT runtime into real
//! data-parallel MoE training.

pub mod comm;
pub mod pipeline;
pub mod router;

pub use comm::{chunk_ranges, fabric, run_workers, CommError, CommResult, Endpoint, Msg, MsgKind};
pub use pipeline::{one_f_one_b, simulate_slots, Action};
pub use router::{unpack_a2a_manifest, Assignment, RoutedToken, RouteResult, Router, RouterConfig};
