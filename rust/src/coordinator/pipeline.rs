//! 1F1B pipeline-parallel microbatch scheduler (paper §V.A: pipeline
//! parallelism is one of the modeled strategies; the bubble model in
//! [`crate::perf`] assumes this schedule — here it is constructed
//! explicitly and its invariants are machine-checked).

/// One action in a stage's schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    Forward(usize),
    Backward(usize),
}

impl Action {
    /// Stable display label ("fwd 3" / "bwd 3"): the span-name stem the
    /// execution flight recorder uses, so recorded traces stay
    /// comparable across hosts and runs.
    pub fn label(&self) -> String {
        match self {
            Action::Forward(i) => format!("fwd {i}"),
            Action::Backward(i) => format!("bwd {i}"),
        }
    }

    pub fn micro(&self) -> usize {
        match self {
            Action::Forward(i) | Action::Backward(i) => *i,
        }
    }

    /// The message-tag purpose this action's traffic uses — the logical
    /// coordinate the chaos fault planner keys worker-side faults on.
    pub fn purpose(&self) -> u64 {
        match self {
            Action::Forward(_) => TAG_FWD,
            Action::Backward(_) => TAG_BWD,
        }
    }
}

/// Message-tag purposes for the mapped driver's sends. Combined with
/// [`tag`], these give every (step, microbatch, purpose) a disjoint tag
/// range so out-of-order arrivals park under the right key.
pub const TAG_FWD: u64 = 1;
pub const TAG_BWD: u64 = 2;
pub const TAG_DISPATCH: u64 = 3;
pub const TAG_COMBINE: u64 = 4;
pub const TAG_GRADS: u64 = 5;
pub const TAG_STATS: u64 = 6;

/// Tag-space layout for the mapped driver: step in the high bits, then a
/// microbatch (or gradient-tensor) slot, then the purpose, with the low
/// 8 bits left free for a collective's internal hop counter (ring
/// all-reduce uses `tag_base..tag_base + 2(n-1)`, group all-to-all
/// `tag_base + 1..tag_base + n` — both fit for fabrics up to 128 ranks).
pub fn tag(step: usize, slot: usize, purpose: u64) -> u64 {
    ((step as u64) << 32) | ((slot as u64) << 12) | (purpose << 8)
}

/// Inverse of [`tag`]: the step a wire tag belongs to. The chaos layer
/// uses these to match planned faults against live traffic by logical
/// coordinate instead of wall time.
pub fn tag_step(t: u64) -> usize {
    (t >> 32) as usize
}

/// Inverse of [`tag`]: the microbatch / gradient-tensor slot.
pub fn tag_slot(t: u64) -> usize {
    ((t >> 12) & 0xF_FFFF) as usize
}

/// Inverse of [`tag`]: the purpose (TAG_FWD .. TAG_STATS).
pub fn tag_purpose(t: u64) -> u64 {
    (t >> 8) & 0xF
}

/// Per-stage ordered action list for 1F1B with `n_micro` microbatches over
/// `pp` stages: a warmup of `pp-1-stage` forwards, then alternating 1F1B,
/// then drain.
pub fn one_f_one_b(pp: usize, stage: usize, n_micro: usize) -> Vec<Action> {
    assert!(stage < pp && n_micro >= 1);
    let warmup = (pp - 1 - stage).min(n_micro);
    let mut out = Vec::with_capacity(2 * n_micro);
    let mut next_f = 0;
    let mut next_b = 0;
    for _ in 0..warmup {
        out.push(Action::Forward(next_f));
        next_f += 1;
    }
    while next_b < n_micro {
        if next_f < n_micro {
            out.push(Action::Forward(next_f));
            next_f += 1;
        }
        out.push(Action::Backward(next_b));
        next_b += 1;
    }
    out
}

/// Simulate the schedule's timing: every action costs one slot; an action
/// can run only when its dependency completed (F_i on stage s needs F_i on
/// s-1; B_i on stage s needs B_i on s+1; B_i also needs F_i locally).
/// Returns per-stage completion time in slots.
pub fn simulate_slots(pp: usize, n_micro: usize) -> Vec<usize> {
    let schedules: Vec<Vec<Action>> = (0..pp).map(|s| one_f_one_b(pp, s, n_micro)).collect();
    let mut f_done = vec![vec![usize::MAX; n_micro]; pp];
    let mut b_done = vec![vec![usize::MAX; n_micro]; pp];
    let mut cursor = vec![0usize; pp]; // next action index per stage
    let mut clock = vec![0usize; pp]; // stage-local time
    let mut progressed = true;
    while progressed {
        progressed = false;
        for s in 0..pp {
            while cursor[s] < schedules[s].len() {
                let a = schedules[s][cursor[s]];
                let ready_at = match a {
                    Action::Forward(i) => {
                        if s == 0 {
                            0
                        } else if f_done[s - 1][i] == usize::MAX {
                            break;
                        } else {
                            f_done[s - 1][i]
                        }
                    }
                    Action::Backward(i) => {
                        let up = if s == pp - 1 {
                            if f_done[s][i] == usize::MAX {
                                break;
                            }
                            f_done[s][i]
                        } else if b_done[s + 1][i] == usize::MAX {
                            break;
                        } else {
                            b_done[s + 1][i]
                        };
                        if f_done[s][i] == usize::MAX {
                            break;
                        }
                        up.max(f_done[s][i])
                    }
                };
                let start = clock[s].max(ready_at);
                let end = start + 1;
                match a {
                    Action::Forward(i) => f_done[s][i] = end,
                    Action::Backward(i) => b_done[s][i] = end,
                }
                clock[s] = end;
                cursor[s] += 1;
                progressed = true;
            }
        }
    }
    assert!(cursor.iter().zip(&schedules).all(|(&c, s)| c == s.len()), "schedule deadlocked");
    clock
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn every_microbatch_runs_once_each_direction() {
        check("1f1b completeness", 128, |g| {
            let pp = g.usize(1, 8);
            let stage = g.usize(0, pp - 1);
            let n_micro = g.usize(1, 32);
            let sched = one_f_one_b(pp, stage, n_micro);
            let mut f = vec![0; n_micro];
            let mut b = vec![0; n_micro];
            for a in &sched {
                match a {
                    Action::Forward(i) => f[*i] += 1,
                    Action::Backward(i) => b[*i] += 1,
                }
            }
            prop_assert!(f.iter().all(|&c| c == 1), "forward multiplicity");
            prop_assert!(b.iter().all(|&c| c == 1), "backward multiplicity");
            Ok(())
        });
    }

    #[test]
    fn backward_never_precedes_local_forward() {
        check("1f1b causality", 128, |g| {
            let pp = g.usize(1, 8);
            let stage = g.usize(0, pp - 1);
            let n_micro = g.usize(1, 32);
            let sched = one_f_one_b(pp, stage, n_micro);
            let mut seen_f = vec![false; n_micro];
            for a in &sched {
                match a {
                    Action::Forward(i) => seen_f[*i] = true,
                    Action::Backward(i) => {
                        prop_assert!(seen_f[*i], "B{} before F{}", i, i)
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn in_flight_microbatches_bounded_by_depth() {
        // 1F1B's memory guarantee: at most pp microbatches have run F but
        // not yet B on any stage.
        check("1f1b activation bound", 64, |g| {
            let pp = g.usize(1, 8);
            let stage = g.usize(0, pp - 1);
            let n_micro = g.usize(1, 32);
            let sched = one_f_one_b(pp, stage, n_micro);
            let mut inflight: i64 = 0;
            for a in &sched {
                match a {
                    Action::Forward(_) => inflight += 1,
                    Action::Backward(_) => inflight -= 1,
                }
                prop_assert!(
                    inflight <= pp as i64,
                    "stage {} holds {} activations (pp={})",
                    stage,
                    inflight,
                    pp
                );
            }
            Ok(())
        });
    }

    #[test]
    fn makespan_matches_bubble_model() {
        // With F and B each one slot, total = 2*(n_micro + pp - 1) slots —
        // the (n_micro + pp - 1) factor the perf engine uses.
        for (pp, m) in [(4, 8), (8, 16), (2, 4), (1, 5)] {
            let clocks = simulate_slots(pp, m);
            let makespan = *clocks.iter().max().unwrap();
            assert_eq!(makespan, 2 * (m + pp - 1), "pp={pp} m={m}");
        }
    }

    #[test]
    fn single_stage_has_no_bubble() {
        let clocks = simulate_slots(1, 10);
        assert_eq!(clocks[0], 20);
    }

    #[test]
    fn action_labels_and_micro() {
        assert_eq!(Action::Forward(3).label(), "fwd 3");
        assert_eq!(Action::Backward(0).label(), "bwd 0");
        assert_eq!(Action::Forward(7).micro(), 7);
        assert_eq!(Action::Backward(7).micro(), 7);
        assert_eq!(Action::Forward(1).purpose(), TAG_FWD);
        assert_eq!(Action::Backward(1).purpose(), TAG_BWD);
    }

    #[test]
    fn tag_decomposition_round_trips() {
        for step in [0usize, 1, 7, 4095] {
            for slot in [0usize, 3, 1023] {
                for purpose in [TAG_FWD, TAG_BWD, TAG_DISPATCH, TAG_COMBINE, TAG_GRADS, TAG_STATS]
                {
                    let t = tag(step, slot, purpose);
                    assert_eq!(tag_step(t), step);
                    assert_eq!(tag_slot(t), slot);
                    assert_eq!(tag_purpose(t), purpose);
                    // the low 8 hop-counter bits never leak upward
                    assert_eq!(tag_purpose(t + 255), purpose);
                }
            }
        }
    }

    #[test]
    fn tag_ranges_are_disjoint() {
        // Distinct (step, slot, purpose) triples must be >= 256 apart so
        // a collective's internal hop counter never crosses into a
        // neighboring range.
        let mut tags: Vec<u64> = Vec::new();
        for step in 0..3 {
            for slot in 0..4 {
                for purpose in [TAG_FWD, TAG_BWD, TAG_DISPATCH, TAG_COMBINE, TAG_GRADS, TAG_STATS]
                {
                    tags.push(tag(step, slot, purpose));
                }
            }
        }
        tags.sort_unstable();
        for w in tags.windows(2) {
            assert!(w[1] - w[0] >= 256, "tag ranges overlap: {} {}", w[0], w[1]);
        }
    }
}
