//! Collective communication cost models and explicit schedules.
//!
//! Cost side (paper §V.A): Hockney α+βn models for all-gather,
//! reduce-scatter, all-reduce, all-to-all and point-to-point on a given
//! [`DomainSpec`], including the hierarchical (pod-crossing) all-to-all the
//! 144-pod system is forced into.
//!
//! Schedule side: the same algorithms emit explicit `(step, src, dst,
//! bytes)` operation lists consumed by two independent validators — the
//! [`crate::netsim`] packet simulator (checks the α/β abstraction holds
//! under congestion) and the [`crate::coordinator`] runtime (executes them
//! with real buffers).

use crate::topology::cluster::{Cluster, Domain, DomainSpec};

/// One point-to-point transfer in an explicit schedule. Steps synchronize:
/// all ops of step `s` complete before step `s+1` starts (bulk-synchronous
/// approximation of the algorithms' dependency structure).
#[derive(Debug, Clone, PartialEq)]
pub struct CommOp {
    pub step: usize,
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
}

/// A schedule plus metadata for validation.
///
/// `domain` and `group` tag where the schedule runs: which network domain
/// carries it, and which global rank ids its rank-local indices map to.
/// Untagged schedules (the plain algorithm generators) leave both `None`;
/// [`hierarchical_a2a_schedules`] tags its two phases with the domain
/// each one rides, and callers placing a schedule on a concrete cluster
/// attach the rank group. (The [`crate::timeline`] lowering prices the
/// same splits but emits aggregate flows directly — see
/// `timeline::lower` — so replaying a tagged schedule through
/// [`crate::netsim`] is the validation path for the tags.)
#[derive(Debug, Clone)]
pub struct CommSchedule {
    pub name: String,
    pub n_ranks: usize,
    pub ops: Vec<CommOp>,
    /// Network domain this schedule's traffic rides, when known.
    pub domain: Option<Domain>,
    /// Global rank ids of the participating group (`ops` use indices into
    /// this list), when the schedule is placed on a concrete cluster.
    pub group: Option<Vec<usize>>,
}

impl CommSchedule {
    /// Untagged schedule (algorithm only, no placement).
    pub fn new(name: &str, n_ranks: usize, ops: Vec<CommOp>) -> CommSchedule {
        CommSchedule { name: name.to_string(), n_ranks, ops, domain: None, group: None }
    }

    /// Tag the network domain carrying this schedule.
    pub fn with_domain(mut self, domain: Domain) -> CommSchedule {
        self.domain = Some(domain);
        self
    }

    /// Tag the global rank group. The group must cover every rank index
    /// the ops actually use (checked), so `group[op.src]` is always valid
    /// for a consumer placing this schedule on a cluster.
    pub fn with_group(mut self, group: Vec<usize>) -> CommSchedule {
        assert!(group.len() >= self.n_ranks, "group smaller than n_ranks");
        for op in &self.ops {
            assert!(
                op.src < group.len() && op.dst < group.len(),
                "op ({}, {}) outside the {}-rank group",
                op.src,
                op.dst,
                group.len()
            );
        }
        self.group = Some(group);
        self
    }

    pub fn n_steps(&self) -> usize {
        self.ops.iter().map(|o| o.step + 1).max().unwrap_or(0)
    }

    pub fn total_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.bytes).sum()
    }

    /// Max bytes sent by any single rank in one step, summed over steps
    /// (the bandwidth-bound critical path under a non-blocking fabric).
    pub fn critical_bytes(&self) -> f64 {
        let mut per_step_rank = std::collections::BTreeMap::<(usize, usize), f64>::new();
        for op in &self.ops {
            *per_step_rank.entry((op.step, op.src)).or_insert(0.0) += op.bytes;
        }
        let mut per_step = std::collections::BTreeMap::<usize, f64>::new();
        for ((step, _), b) in per_step_rank {
            let e = per_step.entry(step).or_insert(0.0);
            if b > *e {
                *e = b;
            }
        }
        per_step.values().sum()
    }
}

// ---------------------------------------------------------------------------
// Hockney cost models (α + βn)
// ---------------------------------------------------------------------------

/// Point-to-point: α + n/B.
pub fn p2p_time(dom: &DomainSpec, bytes: f64) -> f64 {
    dom.latency_s + bytes / dom.bytes_per_sec()
}

/// Ring all-reduce of `bytes` per rank over `n` ranks:
/// 2(n-1) steps of `bytes/n`, i.e. 2(n-1)/n · bytes / B + 2(n-1) α.
pub fn all_reduce_time(dom: &DomainSpec, n: usize, bytes: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    2.0 * (nf - 1.0) * dom.latency_s + 2.0 * (nf - 1.0) / nf * bytes / dom.bytes_per_sec()
}

/// Ring all-gather: each rank ends with `bytes` total gathered from shards
/// of `bytes/n`: (n-1)/n · bytes / B + (n-1) α.
pub fn all_gather_time(dom: &DomainSpec, n: usize, bytes: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    (nf - 1.0) * dom.latency_s + (nf - 1.0) / nf * bytes / dom.bytes_per_sec()
}

/// Reduce-scatter: same cost shape as all-gather.
pub fn reduce_scatter_time(dom: &DomainSpec, n: usize, bytes: f64) -> f64 {
    all_gather_time(dom, n, bytes)
}

/// Pairwise all-to-all where each rank contributes `bytes_per_rank` total
/// payload (spread over the n-1 peers): (n-1)/n · bytes / (B·η) + (n-1) α,
/// with η the domain's dense-a2a efficiency derate.
pub fn all_to_all_time(dom: &DomainSpec, n: usize, bytes_per_rank: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    (nf - 1.0) * dom.latency_s
        + (nf - 1.0) / nf * bytes_per_rank / (dom.bytes_per_sec() * dom.a2a_efficiency)
}

/// Hierarchical all-to-all for a group of `span` GPUs on `cluster`
/// (pod-major placement). In-pod traffic rides the scale-up network; the
/// pod-crossing fraction rides scale-out. The two phases overlap (different
/// NICs), so the time is the max of the phases.
pub fn hierarchical_a2a_time(cluster: &Cluster, span: usize, bytes_per_rank: f64) -> f64 {
    let up = cluster.domain(Domain::ScaleUp);
    if span <= cluster.spec.pod_size {
        return all_to_all_time(up, span, bytes_per_rank);
    }
    let out = cluster.domain(Domain::ScaleOut);
    let cross = cluster.cross_pod_fraction(span);
    let t_up = all_to_all_time(up, cluster.spec.pod_size, bytes_per_rank * (1.0 - cross));
    let t_out = (span as f64 - 1.0) * out.latency_s
        + bytes_per_rank * cross / (out.bytes_per_sec() * out.a2a_efficiency);
    t_up.max(t_out)
}

/// Hierarchical all-reduce over `span` ranks: intra-pod ring reduce-scatter
/// + inter-pod ring all-reduce on the shard + intra-pod all-gather.
pub fn hierarchical_all_reduce_time(cluster: &Cluster, span: usize, bytes: f64) -> f64 {
    let pod = cluster.spec.pod_size;
    if span <= pod {
        return all_reduce_time(cluster.domain(Domain::ScaleUp), span, bytes);
    }
    let up = cluster.domain(Domain::ScaleUp);
    let out = cluster.domain(Domain::ScaleOut);
    let n_pods = (span + pod - 1) / pod;
    reduce_scatter_time(up, pod, bytes)
        + all_reduce_time(out, n_pods, bytes / pod as f64)
        + all_gather_time(up, pod, bytes)
}

// ---------------------------------------------------------------------------
// Explicit schedules (for netsim + coordinator validation)
// ---------------------------------------------------------------------------

/// Ring all-reduce schedule: reduce-scatter then all-gather, `bytes/n` per
/// hop, 2(n-1) steps.
pub fn ring_all_reduce_schedule(n: usize, bytes: f64) -> CommSchedule {
    let mut ops = Vec::new();
    if n > 1 {
        let shard = bytes / n as f64;
        for step in 0..2 * (n - 1) {
            for rank in 0..n {
                ops.push(CommOp { step, src: rank, dst: (rank + 1) % n, bytes: shard });
            }
        }
    }
    CommSchedule::new(&format!("ring-allreduce-{n}"), n, ops)
}

/// Ring all-gather schedule: (n-1) steps of `bytes/n`.
pub fn ring_all_gather_schedule(n: usize, bytes: f64) -> CommSchedule {
    let mut ops = Vec::new();
    if n > 1 {
        let shard = bytes / n as f64;
        for step in 0..(n - 1) {
            for rank in 0..n {
                ops.push(CommOp { step, src: rank, dst: (rank + 1) % n, bytes: shard });
            }
        }
    }
    CommSchedule::new(&format!("ring-allgather-{n}"), n, ops)
}

/// Pairwise-exchange all-to-all: n-1 steps; at step s, rank r sends its
/// chunk for rank (r+s) mod n (linear shift generalizes to odd n).
pub fn pairwise_a2a_schedule(n: usize, bytes_per_rank: f64) -> CommSchedule {
    let mut ops = Vec::new();
    if n > 1 {
        let chunk = bytes_per_rank / (n - 1) as f64;
        for step in 1..n {
            for rank in 0..n {
                ops.push(CommOp { step: step - 1, src: rank, dst: (rank + step) % n, bytes: chunk });
            }
        }
    }
    CommSchedule::new(&format!("pairwise-a2a-{n}"), n, ops)
}

/// Explicit schedules for the hierarchical (pod-crossing) all-to-all that
/// [`hierarchical_a2a_time`] costs: an in-pod phase (pairwise exchange
/// inside each pod, tagged [`Domain::ScaleUp`]) and a pod-crossing phase
/// (each rank cycles through its other-pod peers, tagged
/// [`Domain::ScaleOut`]). The two phases ride different NICs and overlap,
/// matching the cost model's `max(t_up, t_out)` composition — replay them
/// independently, not concatenated.
///
/// Placement is pod-major over `span` ranks with pods of `pod_size` (the
/// last pod may be partial). Every peer receives the uniform per-peer
/// chunk `bytes_per_rank / (span-1)`, so the phase split reproduces the
/// cost model's `cross_pod_fraction` up to partial-pod geometry (which the
/// averaged Hockney fractions smooth over). For `span <= pod_size` the
/// in-pod phase is the flat pairwise exchange and the cross phase is empty.
pub fn hierarchical_a2a_schedules(
    pod_size: usize,
    span: usize,
    bytes_per_rank: f64,
) -> (CommSchedule, CommSchedule) {
    assert!(pod_size > 0 && span > 0);
    let pod_of = |r: usize| r / pod_size;
    let members = |p: usize| pod_size.min(span - p * pod_size);
    let chunk = if span > 1 { bytes_per_rank / (span - 1) as f64 } else { 0.0 };

    // In-pod phase: pairwise exchange within each pod, all pods in
    // lockstep on shared step ids 0..pod_members-2.
    let mut in_ops = Vec::new();
    for r in 0..span {
        let p = pod_of(r);
        let m = members(p);
        if m <= 1 {
            continue;
        }
        let base = p * pod_size;
        for step in 1..m {
            in_ops.push(CommOp {
                step: step - 1,
                src: r,
                dst: base + ((r - base) + step) % m,
                bytes: chunk,
            });
        }
    }
    let in_pod = CommSchedule::new(&format!("hier-a2a-inpod-{span}x{pod_size}"), span, in_ops)
        .with_domain(Domain::ScaleUp);

    // Cross phase: at step t each rank sends to its t-th other-pod peer,
    // rotated by its in-pod index so a pod's senders fan out instead of
    // converging on one destination.
    let mut x_ops = Vec::new();
    if span > pod_size {
        for r in 0..span {
            let p = pod_of(r);
            let peers: Vec<usize> = (0..span).filter(|&d| pod_of(d) != p).collect();
            let rot = r - p * pod_size;
            for (t, _) in peers.iter().enumerate() {
                x_ops.push(CommOp {
                    step: t,
                    src: r,
                    dst: peers[(t + rot) % peers.len()],
                    bytes: chunk,
                });
            }
        }
    }
    let cross_pod = CommSchedule::new(&format!("hier-a2a-cross-{span}x{pod_size}"), span, x_ops)
        .with_domain(Domain::ScaleOut);
    (in_pod, cross_pod)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::cluster::Cluster;

    fn dom(gbps: f64, lat: f64) -> DomainSpec {
        DomainSpec { name: "t".into(), gbps_per_gpu: gbps, latency_s: lat, a2a_efficiency: 1.0 }
    }

    #[test]
    fn hockney_limits() {
        let d = dom(8_000.0, 1e-6); // 1 TB/s
        // Large message: bandwidth term dominates; 2(n-1)/n -> 2.
        let t = all_reduce_time(&d, 1024, 1e12);
        assert!((t / 2.0 - 1.0).abs() < 0.01, "{t}");
        // n=1 is free.
        assert_eq!(all_reduce_time(&d, 1, 1e12), 0.0);
        assert_eq!(all_to_all_time(&d, 1, 1e12), 0.0);
    }

    #[test]
    fn latency_term_scales_with_ranks() {
        let d = dom(8_000.0, 1e-6);
        let t = all_gather_time(&d, 17, 0.0);
        assert!((t - 16e-6).abs() < 1e-12);
    }

    #[test]
    fn a2a_efficiency_derates_bandwidth() {
        let mut d = dom(8_000.0, 0.0);
        let t1 = all_to_all_time(&d, 8, 1e9);
        d.a2a_efficiency = 0.5;
        let t2 = all_to_all_time(&d, 8, 1e9);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn hierarchical_a2a_prefers_pod_when_it_fits() {
        let c = Cluster::passage_512(1024);
        let in_pod = hierarchical_a2a_time(&c, 512, 1e9);
        let cross = hierarchical_a2a_time(&c, 1024, 1e9);
        assert!(cross > 5.0 * in_pod, "in={in_pod} cross={cross}");
    }

    #[test]
    fn hierarchical_allreduce_decomposes() {
        let c = Cluster::passage_512(2048);
        let t = hierarchical_all_reduce_time(&c, 1024, 1e9);
        assert!(t > 0.0);
        // must exceed a pure in-pod all-reduce of the same bytes
        assert!(t > all_reduce_time(c.domain(Domain::ScaleUp), 512, 1e9));
    }

    #[test]
    fn ring_allreduce_schedule_shape() {
        let s = ring_all_reduce_schedule(4, 4000.0);
        assert_eq!(s.n_steps(), 6); // 2(n-1)
        assert_eq!(s.ops.len(), 6 * 4);
        // every rank sends exactly bytes/n per step
        assert!((s.critical_bytes() - 6.0 * 1000.0).abs() < 1e-9);
    }

    #[test]
    fn a2a_schedule_volume_conservation() {
        let n = 8;
        let per_rank = 7_000.0;
        let s = pairwise_a2a_schedule(n, per_rank);
        assert_eq!(s.n_steps(), n - 1);
        assert!((s.total_bytes() - n as f64 * per_rank).abs() < 1e-6);
        // each (src,dst) pair appears exactly once
        let mut pairs = std::collections::BTreeSet::new();
        for op in &s.ops {
            assert!(op.src != op.dst);
            assert!(pairs.insert((op.src, op.dst)));
        }
        assert_eq!(pairs.len(), n * (n - 1));
    }

    #[test]
    fn hierarchical_a2a_schedule_consistent_with_hockney_cost() {
        // The explicit pod-crossing a2a schedules must reproduce the terms
        // hierarchical_a2a_time charges, on both §VI pod sizes: 144 (the
        // paper's 512-rank EP group spans 4 pods, the last partial) and
        // 512 (two full Passage-sized pods).
        let bytes = 1e9;
        for (pod, span, cluster) in [
            (144usize, 512usize, Cluster::electrical_144(32_256)),
            (512, 1024, Cluster::passage_512(32_768)),
        ] {
            let (in_pod, cross_s) = hierarchical_a2a_schedules(pod, span, bytes);
            assert_eq!(in_pod.domain, Some(Domain::ScaleUp));
            assert_eq!(cross_s.domain, Some(Domain::ScaleOut));
            let cross = cluster.cross_pod_fraction(span);
            assert!(cross > 0.0);
            // volume conservation: the two phases together move the full
            // uniform a2a, split near the cost model's cross fraction
            // (exact when pods divide the span; partial pods shift a bit)
            let total = in_pod.total_bytes() + cross_s.total_bytes();
            let uniform = span as f64 * bytes;
            assert!((total - uniform).abs() / uniform < 1e-9, "{total} vs {uniform}");
            let in_total = span as f64 * (1.0 - cross) * bytes;
            let x_total = span as f64 * cross * bytes;
            assert!((in_pod.total_bytes() - in_total).abs() / in_total < 0.10);
            assert!((cross_s.total_bytes() - x_total).abs() / x_total < 0.05);
            // step counts: pod-1 in-pod barriers; the cross phase needs one
            // step per other-pod peer (ranks in a partial pod have more)
            assert_eq!(in_pod.n_steps(), pod - 1);
            assert!(cross_s.n_steps() >= span - pod && cross_s.n_steps() < span);
            // bandwidth-term consistency: critical bytes over the domain
            // rate reproduce the Hockney β-terms of hierarchical_a2a_time
            let up = cluster.domain(Domain::ScaleUp);
            let out = cluster.domain(Domain::ScaleOut);
            let beta_up = (pod as f64 - 1.0) / pod as f64 * (1.0 - cross) * bytes
                / (up.bytes_per_sec() * up.a2a_efficiency);
            let t_in = in_pod.critical_bytes() / (up.bytes_per_sec() * up.a2a_efficiency);
            assert!((t_in - beta_up).abs() / beta_up < 0.02, "{t_in} vs {beta_up}");
            let beta_out =
                cross * bytes / (out.bytes_per_sec() * out.a2a_efficiency);
            let t_x = cross_s.critical_bytes() / (out.bytes_per_sec() * out.a2a_efficiency);
            // partial pods stretch the tail (their ranks spread the same
            // payload over more, smaller steps): β ≤ critical ≤ 1.2 β
            assert!(t_x >= beta_out * (1.0 - 1e-9), "{t_x} vs {beta_out}");
            assert!(t_x <= beta_out * 1.2, "{t_x} vs {beta_out}");
            // every op really crosses pods / stays in-pod
            for op in &cross_s.ops {
                assert_ne!(op.src / pod, op.dst / pod);
            }
            for op in &in_pod.ops {
                assert_eq!(op.src / pod, op.dst / pod);
                assert_ne!(op.src, op.dst);
            }
        }
        // degenerate: span within one pod = flat pairwise, empty cross
        let (flat, none) = hierarchical_a2a_schedules(512, 32, 1e6);
        assert_eq!(none.ops.len(), 0);
        assert_eq!(flat.n_steps(), 31);
        assert!((flat.total_bytes() - 32.0 * 1e6).abs() < 1e-3);
    }

    #[test]
    fn schedule_tags_round_trip() {
        let s = pairwise_a2a_schedule(4, 1e6)
            .with_domain(Domain::ScaleUp)
            .with_group(vec![8, 9, 10, 11]);
        assert_eq!(s.domain, Some(Domain::ScaleUp));
        assert_eq!(s.group.as_deref(), Some(&[8, 9, 10, 11][..]));
    }

    #[test]
    fn schedule_cost_matches_hockney_bandwidth_term() {
        // critical_bytes / B should equal the Hockney β-term for the ring.
        let d = dom(800.0, 0.0); // 100 GB/s
        let bytes = 1e9;
        let n = 16;
        let sched = ring_all_reduce_schedule(n, bytes);
        let t_sched = sched.critical_bytes() / d.bytes_per_sec();
        let t_model = all_reduce_time(&d, n, bytes);
        assert!((t_sched - t_model).abs() / t_model < 1e-9);
    }
}
