//! Lowering: (Workload, Mapping, Cluster) → per-rank task DAG on a
//! representative slice network.
//!
//! # The slice
//!
//! Simulating all 32,768 GPUs flow-by-flow is neither tractable nor
//! necessary: under the pod-major placement every DP column of a stage is
//! in lockstep, so one *representative* pipeline column — the EP group
//! containing TP group 0, at every stage — carries the full dependency
//! structure. Each pipeline stage gets its own pod-aligned block of a
//! [`Network::two_level`] slice (stage boundaries are priced on the
//! scale-out fabric, matching the analytical model's placement
//! assumption), sized to the EP span rounded up to whole pods.
//!
//! # Aggregate flows
//!
//! Each communication task lowers to a handful of *aggregate* flows — one
//! per representative rank — that preserve every per-link byte total of
//! the explicit [`crate::collectives`] schedules:
//!
//! - ring all-reduce over g ranks → g neighbor flows of `2(g-1)/g · bytes`
//!   (per-uplink/downlink load of the full 2(g-1)-step schedule);
//! - all-to-all → one in-pod permutation flow per rank plus one
//!   pod-crossing flow per rank, with the Hockney `a2a_efficiency` derate
//!   applied as a wire-byte inflation (netsim derives that derate
//!   independently; see `measure_a2a_efficiency`);
//! - the serial α terms of each schedule become explicit `Delay` nodes in
//!   front of the task's flows.
//!
//! Because the slice's pod uplinks carry the members' aggregate NIC
//! bandwidth (oversubscription is an input parameter the §VI clusters set
//! to 1), the max-min rates of the representative flows equal the rates
//! they would get with every symmetric column present — dropping the
//! other columns loses no contention. Cross-pod flows whose true peers
//! live outside the slice (DP gradient rings) are routed to the
//! *geometric proxy* — the same local rank in the next stage's pod — which
//! preserves per-NIC and per-pod-uplink loads.

use crate::coordinator::pipeline::{one_f_one_b, Action};
use crate::model::Workload;
use crate::netsim::{DagNode, Network};
use crate::parallel::Mapping;
use crate::perf::{a2a_alpha, step_volumes, PerfKnobs, StepVolumes};
use crate::topology::cluster::{Cluster, Domain};

/// Which bucket of the per-phase breakdown a critical-chain task fills.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Compute,
    TpComm,
    EpComm,
    PpComm,
    DpComm,
}

/// One serialized task on a stage's chain (the attribution spine):
/// `ends` are the node ids whose completion ends the task, `deps` the node
/// ids whose completion allowed it to start. [`lower_step`] records stage
/// 0 only (the attribution walk's input); [`lower_step_traced`] records
/// every stage, tagged by `stage`, for per-rank event timelines.
#[derive(Debug, Clone)]
pub struct ChainTask {
    /// Pipeline stage this task ran on (0 for every task on the
    /// [`lower_step`] chain).
    pub stage: usize,
    pub phase: Phase,
    pub ends: Vec<usize>,
    pub deps: Vec<usize>,
}

/// A lowered training step, ready for [`crate::netsim::simulate_dag`].
pub struct StepDag {
    pub net: Network,
    pub nodes: Vec<DagNode>,
    /// Stage-0 tasks in execution order; every instant of the simulated
    /// step is either inside exactly one of these or is pipeline bubble.
    pub chain: Vec<ChainTask>,
    pub vols: StepVolumes,
}

/// Refuse to build DAGs whose size would make flow-level simulation
/// impractical. With the component-incremental dependency engine
/// ([`crate::netsim::DagSimulator`]) the per-event cost no longer grows
/// with the whole active flow set, so this is a memory/latency guard
/// against truly pathological lowerings, not a performance cliff: the
/// deep-PP × fine-microbatch mappings the planner explores (~0.3–1.2 M
/// nodes) now lower and simulate. Before the incremental engine the cap
/// sat at 300 k nodes ([`super::DEEP_REGION_MIN_NODES`] — `lumos validate
/// --deep` sweeps that previously-rejected region). The §VI paper-mapping
/// DAGs are ~18 k nodes.
pub const MAX_DAG_NODES: usize = 5_000_000;

/// Estimated node count for a (mapping, workload) point — used to reject
/// oversized lowerings before allocating anything.
pub fn estimate_nodes(map: &Mapping, n_micro: usize) -> usize {
    let tp = map.par.tp;
    let blocks = 2 * map.par.pp * n_micro;
    // per block: compute + (α + tp flows) TP + (2α + 2·tp flows) EP +
    // (α + tp flows) PP, plus per-stage DP tasks
    blocks * (5 + 4 * tp) + map.par.pp * (4 + 4 * tp)
}

/// Value slots of the candidate-dependent parameter table.
///
/// The builder reads every per-candidate number through this table
/// ([`Builder::params`]) and records which slot each node's value came
/// from ([`Builder::tags`]), so a lowered DAG can be *re-parameterized*
/// for another candidate by rewriting node values slot-by-slot — the
/// skeleton cache in [`super::cache`]. Every branch the builder takes
/// depends only on the structural geometry plus the zero-pattern of this
/// table, both captured by [`super::SkeletonCache`]'s key; that is what
/// makes a cached skeleton provably reusable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub(crate) enum Slot {
    /// Literal 0.0 (degenerate placeholder delays).
    Zero = 0,
    /// Forward-block matmul time (⅓ of fwd+bwd per microbatch).
    ComputeF,
    /// Backward-block matmul time (⅔ — 2 matmuls per weight vs 1).
    ComputeB,
    /// TP + expert-TP ring all-reduce wire bytes per block.
    TpBytes,
    TpAlpha,
    /// EP all-to-all, in-pod part.
    EpInBytes,
    EpInAlpha,
    /// EP all-to-all, pod-crossing part.
    EpXBytes,
    EpXAlpha,
    /// Pipeline p2p transfer per boundary.
    PpBytes,
    /// Scale-out latency fronting each pipeline send.
    OutLat,
    /// DP sync, small branch: ring inside one pod.
    DpRingBytes,
    DpRingAlpha,
    /// DP sync, big branch: in-pod reduce-scatter / all-gather legs.
    DpPodBytes,
    DpPodAlpha,
    /// DP sync, big branch: inter-pod cross ring.
    DpXBytes,
    DpXAlpha,
    /// Expert-set gradient ring.
    ExBytes,
    ExAlpha,
}

/// Number of [`Slot`] variants (table width).
pub(crate) const N_SLOTS: usize = 19;

/// Everything the builder consumes, split into *structural* fields —
/// which, together with the zero-pattern of `params`, fully determine the
/// DAG skeleton — and the candidate-value table. Produced by
/// [`step_params`]; consumed by [`build_from_params`] (and hashed into a
/// cache key by [`super::SkeletonCache`]).
pub(crate) struct StepParams {
    pub(crate) pod: usize,
    pub(crate) span: usize,
    pub(crate) stride: usize,
    pub(crate) pp: usize,
    pub(crate) tp: usize,
    pub(crate) n_blocks: usize,
    /// DP sync shape: 0 = none, 1 = in-pod ring, 2 = hierarchical
    /// reduce-scatter → cross ring → all-gather.
    pub(crate) dp_branch: u8,
    pub(crate) expert_ring: bool,
    pub(crate) up_gbps: f64,
    pub(crate) out_gbps: f64,
    pub(crate) est: usize,
    pub(crate) params: [f64; N_SLOTS],
    pub(crate) vols: StepVolumes,
}

struct Builder {
    nodes: Vec<DagNode>,
    /// `Slot` of every node's value, parallel to `nodes` — the
    /// re-parameterization map the skeleton cache replays.
    tags: Vec<u8>,
    chain: Vec<ChainTask>,
    /// Record chain entries for every stage (trace lowering) instead of
    /// stage 0 only. Not part of the skeleton key: it changes only the
    /// attribution chain, never the node/flow structure.
    full_chain: bool,
    /// stage-local geometry (all part of the skeleton key)
    pod: usize,
    span: usize,
    stride: usize,
    pp: usize,
    tp: usize,
    dp_branch: u8,
    expert_ring: bool,
    /// candidate-value table, indexed by [`Slot`]
    params: [f64; N_SLOTS],
}

impl Builder {
    fn gid(&self, stage: usize, local: usize) -> usize {
        stage * self.stride + local
    }

    fn val(&self, s: Slot) -> f64 {
        self.params[s as usize]
    }

    fn delay(&mut self, dur: Slot, deps: Vec<usize>) -> usize {
        self.nodes.push(DagNode::delay(self.val(dur), deps));
        self.tags.push(dur as u8);
        self.nodes.len() - 1
    }

    fn flow(&mut self, src: usize, dst: usize, bytes: Slot, deps: Vec<usize>) -> usize {
        self.nodes.push(DagNode::flow(src, dst, self.val(bytes), deps));
        self.tags.push(bytes as u8);
        self.nodes.len() - 1
    }

    /// Record an attribution entry — stage 0 only unless `full_chain`
    /// (the planner's hot path never pays the pp× chain memory).
    fn record(&mut self, stage: usize, phase: Phase, ends: &[usize], deps: &[usize]) {
        if stage == 0 || self.full_chain {
            self.chain.push(ChainTask { stage, phase, ends: ends.to_vec(), deps: deps.to_vec() });
        }
    }

    /// In-pod peer for the slice-local rank `l` of an a2a over `span`
    /// ranks (half-rotation within the rank's pod).
    fn a2a_in_peer(&self, l: usize) -> usize {
        let base = l / self.pod * self.pod;
        let members = self.pod.min(self.span - base);
        base + ((l - base) + (members / 2).max(1)) % members
    }

    /// In-pod ring neighbor used by the DP gradient phases.
    fn pod_neighbor(&self, l: usize) -> usize {
        let base = l / self.pod * self.pod;
        let members = self.pod.min(self.span - base);
        base + ((l - base) + 1) % members
    }

    /// Lower one aggregate communication task for `stage`. The task's
    /// in-pod part sends `in_bytes` per representative rank to
    /// `perm_in(l)` behind an `in_alpha` startup delay; the pod-crossing
    /// part sends `x_bytes` to local rank `x_perm(l)` of stage block
    /// `x_stage` behind `x_alpha`. Either part may be absent. Returns the
    /// node ids whose completion ends the task.
    #[allow(clippy::too_many_arguments)]
    fn comm_group(
        &mut self,
        stage: usize,
        deps: &[usize],
        in_bytes: Slot,
        in_alpha: Slot,
        x_bytes: Slot,
        x_alpha: Slot,
        perm_in: impl Fn(&Self, usize) -> usize,
        x_stage: usize,
        x_perm: impl Fn(&Self, usize) -> usize,
    ) -> Vec<usize> {
        let tp = self.tp;
        let mut ends = Vec::new();
        if self.val(in_bytes) > 0.0 {
            let fdeps = if self.val(in_alpha) > 0.0 {
                vec![self.delay(in_alpha, deps.to_vec())]
            } else {
                deps.to_vec()
            };
            for l in 0..tp {
                let dst = perm_in(self, l);
                if dst != l {
                    ends.push(self.flow(
                        self.gid(stage, l),
                        self.gid(stage, dst),
                        in_bytes,
                        fdeps.clone(),
                    ));
                }
            }
            if ends.is_empty() {
                // degenerate single-rank group: only the startup term
                ends = fdeps;
            }
        } else if self.val(in_alpha) > 0.0 {
            ends.push(self.delay(in_alpha, deps.to_vec()));
        }
        if self.val(x_bytes) > 0.0 {
            let fdeps = if self.val(x_alpha) > 0.0 {
                vec![self.delay(x_alpha, deps.to_vec())]
            } else {
                deps.to_vec()
            };
            for l in 0..tp {
                let dst = x_perm(self, l);
                ends.push(self.flow(
                    self.gid(stage, l),
                    self.gid(x_stage, dst),
                    x_bytes,
                    fdeps.clone(),
                ));
            }
        } else if self.val(x_alpha) > 0.0 {
            ends.push(self.delay(x_alpha, deps.to_vec()));
        }
        if ends.is_empty() {
            ends.push(self.delay(Slot::Zero, deps.to_vec()));
        }
        ends
    }

    /// One F or B block on `stage`'s chain: compute, TP collectives, EP
    /// all-to-all, then the pipeline send (if any). Returns the chain tail.
    fn build_block(
        &mut self,
        stage: usize,
        action: Action,
        prev: &[usize],
        pp_arrival: Option<&[usize]>,
    ) -> Vec<usize> {
        let mut deps = prev.to_vec();
        if let Some(arr) = pp_arrival {
            deps.extend_from_slice(arr);
        }
        // backward is 2× forward (2 matmuls vs 1 per weight)
        let cdur = match action {
            Action::Forward(_) => Slot::ComputeF,
            Action::Backward(_) => Slot::ComputeB,
        };
        let cnode = self.delay(cdur, deps.clone());
        self.record(stage, Phase::Compute, &[cnode], &deps);

        let tp = self.tp;
        let tail = if self.val(Slot::TpBytes) > 0.0 || self.val(Slot::TpAlpha) > 0.0 {
            let ends = self.comm_group(
                stage,
                &[cnode],
                Slot::TpBytes,
                Slot::TpAlpha,
                Slot::Zero,
                Slot::Zero,
                |_, l| if tp > 1 { (l + 1) % tp } else { l },
                stage,
                |_, l| l,
            );
            self.record(stage, Phase::TpComm, &ends, &[cnode]);
            ends
        } else {
            vec![cnode]
        };

        let ep_ends = self.comm_group(
            stage,
            &tail,
            Slot::EpInBytes,
            Slot::EpInAlpha,
            Slot::EpXBytes,
            Slot::EpXAlpha,
            |b, l| b.a2a_in_peer(l),
            stage,
            |b, l| ((l / b.pod + 1) * b.pod + (l % b.pod)) % b.stride,
        );
        self.record(stage, Phase::EpComm, &ep_ends, &tail);

        // pipeline p2p: activations forward, gradients backward
        let pp = self.pp;
        let to = match action {
            Action::Forward(_) if stage < pp - 1 => Some(stage + 1),
            Action::Backward(_) if stage > 0 => Some(stage - 1),
            _ => None,
        };
        match to {
            Some(dst_stage) => {
                let d = self.delay(Slot::OutLat, ep_ends.clone());
                let mut ids = Vec::with_capacity(tp);
                for l in 0..tp {
                    ids.push(self.flow(
                        self.gid(stage, l),
                        self.gid(dst_stage, l),
                        Slot::PpBytes,
                        vec![d],
                    ));
                }
                self.record(stage, Phase::PpComm, &ids, &ep_ends);
                ids
            }
            None => ep_ends,
        }
    }

    /// The end-of-step DP gradient sync for `stage`: hierarchical shared
    /// all-reduce (in-pod reduce-scatter → inter-pod ring → in-pod
    /// all-gather) plus the expert-set ring, as in
    /// `collectives::hierarchical_all_reduce_time`.
    fn build_dp(&mut self, stage: usize, prev: &[usize]) -> Vec<usize> {
        // proxy target for flows whose true peers are outside the slice
        let nxt = if self.pp > 1 { (stage + 1) % self.pp } else { self.pp };
        let mut tail: Vec<usize> = prev.to_vec();
        if self.dp_branch == 1 {
            let dp_deps = tail.clone();
            let ends = self.comm_group(
                stage,
                &dp_deps,
                Slot::DpRingBytes,
                Slot::DpRingAlpha,
                Slot::Zero,
                Slot::Zero,
                |b, l| b.pod_neighbor(l),
                stage,
                |_, l| l,
            );
            self.record(stage, Phase::DpComm, &ends, &dp_deps);
            tail = ends;
        } else if self.dp_branch == 2 {
            let rs_deps = tail.clone();
            let rs = self.comm_group(
                stage,
                &rs_deps,
                Slot::DpPodBytes,
                Slot::DpPodAlpha,
                Slot::Zero,
                Slot::Zero,
                |b, l| b.pod_neighbor(l),
                stage,
                |_, l| l,
            );
            self.record(stage, Phase::DpComm, &rs, &rs_deps);
            let xr = self.comm_group(
                stage,
                &rs,
                Slot::Zero,
                Slot::Zero,
                Slot::DpXBytes,
                Slot::DpXAlpha,
                |_, l| l,
                nxt,
                |_, l| l,
            );
            self.record(stage, Phase::DpComm, &xr, &rs);
            let ag = self.comm_group(
                stage,
                &xr,
                Slot::DpPodBytes,
                Slot::DpPodAlpha,
                Slot::Zero,
                Slot::Zero,
                |b, l| b.pod_neighbor(l),
                stage,
                |_, l| l,
            );
            self.record(stage, Phase::DpComm, &ag, &xr);
            tail = ag;
        }
        if self.expert_ring {
            let ex_deps = tail.clone();
            let ex = self.comm_group(
                stage,
                &ex_deps,
                Slot::Zero,
                Slot::Zero,
                Slot::ExBytes,
                Slot::ExAlpha,
                |_, l| l,
                nxt,
                |_, l| l,
            );
            self.record(stage, Phase::DpComm, &ex, &ex_deps);
            tail = ex;
        }
        tail
    }
}

/// Compute the structural geometry and the full [`Slot`] value table for a
/// candidate — everything [`build_from_params`] needs, with no further
/// reference to the cluster or mapping. Errors on oversized lowerings
/// (same guard [`lower_step`] always had).
pub(crate) fn step_params(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
) -> Result<StepParams, String> {
    let vols = step_volumes(w, cluster, map, knobs);
    let est = estimate_nodes(map, vols.n_micro);
    if est > MAX_DAG_NODES {
        return Err(format!(
            "step DAG too large to lower (~{est} nodes > {MAX_DAG_NODES}); \
             use the analytical model for this mapping"
        ));
    }
    let pod = cluster.spec.pod_size;
    let span = map.ep_span_gpus();
    let stride = span.div_ceil(pod) * pod;
    let pp = map.par.pp;
    // pp == 1 gets a phantom pod block as the proxy target for cross-pod
    // DP traffic (otherwise those flows would self-target)
    let n_blocks = if pp > 1 { pp } else { 2 };
    let up = cluster.domain(Domain::ScaleUp);
    let out = cluster.domain(Domain::ScaleOut);

    let mut params = [0.0f64; N_SLOTS];
    params[Slot::ComputeF as usize] = vols.compute_per_micro / 3.0;
    params[Slot::ComputeB as usize] = 2.0 * vols.compute_per_micro / 3.0;
    params[Slot::PpBytes as usize] = vols.pp_bytes;
    params[Slot::OutLat as usize] = out.latency_s;

    let tp = map.par.tp;
    let etp = map.expert_tp();
    let l = vols.layers_per_stage;
    // Per-direction TP wire bytes: the ring all-reduce after attention
    // (tp ranks) and after the expert FFN (expert-TP subgroup), per layer.
    params[Slot::TpBytes as usize] = l
        * (2.0 * (tp as f64 - 1.0) / tp as f64 + 2.0 * (etp as f64 - 1.0) / etp as f64)
        * vols.act_bytes;
    params[Slot::TpAlpha as usize] =
        l * (2.0 * (tp as f64 - 1.0) + 2.0 * (etp as f64 - 1.0)) * up.latency_s;

    // Per-direction EP bytes: dispatch + combine (2 a2a) per layer, split
    // into the in-pod and pod-crossing parts, inflated by the calibrated
    // congestion derates (netsim measures those independently).
    let cross = cluster.cross_pod_fraction(span);
    let in_frac = if span <= pod {
        (span as f64 - 1.0) / span as f64
    } else {
        1.0 - cross
    };
    params[Slot::EpInBytes as usize] = 2.0 * l * in_frac * vols.a2a_bytes / up.a2a_efficiency;
    params[Slot::EpXBytes as usize] = 2.0 * l * cross * vols.a2a_bytes / out.a2a_efficiency;
    params[Slot::EpInAlpha as usize] = 2.0 * l * a2a_alpha(up.latency_s, span.min(pod));
    params[Slot::EpXAlpha as usize] =
        if span > pod { 2.0 * l * a2a_alpha(out.latency_s, span) } else { 0.0 };

    // DP gradient sync, as in collectives::hierarchical_all_reduce_time:
    // one ring inside the pod when the DP group fits, otherwise in-pod
    // reduce-scatter → inter-pod ring → in-pod all-gather.
    let dp_span = map.dp_span_gpus().min(cluster.spec.n_gpus);
    let b_sh = vols.shared_grad_bytes;
    let dp_branch: u8 = if dp_span <= 1 {
        0
    } else if dp_span <= pod {
        1
    } else {
        2
    };
    match dp_branch {
        1 => {
            let n = dp_span as f64;
            params[Slot::DpRingBytes as usize] = 2.0 * (n - 1.0) / n * b_sh;
            params[Slot::DpRingAlpha as usize] = 2.0 * (n - 1.0) * up.latency_s;
        }
        2 => {
            let podf = pod as f64;
            let npd = dp_span.div_ceil(pod) as f64;
            params[Slot::DpPodBytes as usize] = (podf - 1.0) / podf * b_sh;
            params[Slot::DpPodAlpha as usize] = (podf - 1.0) * up.latency_s;
            params[Slot::DpXBytes as usize] = 2.0 * (npd - 1.0) / npd * b_sh / podf;
            params[Slot::DpXAlpha as usize] = 2.0 * (npd - 1.0) * out.latency_s;
        }
        _ => {}
    }
    let n_sets = map.n_complete_expert_sets();
    let expert_ring = n_sets > 1;
    if expert_ring {
        let ns = n_sets as f64;
        params[Slot::ExBytes as usize] = 2.0 * (ns - 1.0) / ns * vols.expert_grad_bytes;
        params[Slot::ExAlpha as usize] = 2.0 * (ns - 1.0) * out.latency_s;
    }

    Ok(StepParams {
        pod,
        span,
        stride,
        pp,
        tp,
        n_blocks,
        dp_branch,
        expert_ring,
        up_gbps: up.gbps_per_gpu,
        out_gbps: out.gbps_per_gpu,
        est,
        params,
        vols,
    })
}

/// Build the DAG from a prepared parameter table. Deliberately has no
/// access to the workload/cluster/mapping: every branch below depends only
/// on `sp`'s structural fields and the zero-pattern of `sp.params`, which
/// is what lets [`super::SkeletonCache`] key skeletons on exactly those.
/// `full_chain` records attribution entries for every stage (trace
/// lowering) instead of stage 0 only; it does not affect the nodes.
pub(crate) fn build_from_params(sp: StepParams, full_chain: bool) -> (StepDag, Vec<u8>) {
    let net = Network::two_level(
        sp.n_blocks * sp.stride,
        sp.pod,
        sp.up_gbps,
        sp.out_gbps,
        0.0, // α terms are explicit Delay nodes
    );
    let pp = sp.pp;
    let n_micro = sp.vols.n_micro;
    let mut b = Builder {
        nodes: Vec::with_capacity(sp.est),
        tags: Vec::with_capacity(sp.est),
        chain: Vec::new(),
        full_chain,
        pod: sp.pod,
        span: sp.span,
        stride: sp.stride,
        pp,
        tp: sp.tp,
        dp_branch: sp.dp_branch,
        expert_ring: sp.expert_ring,
        params: sp.params,
    };

    // Multi-pass 1F1B construction: a stage's next block can be built once
    // the pipeline transfer it waits on exists (F needs the upstream F's
    // send, B the downstream B's send) — the same dependency sweep
    // coordinator::pipeline::simulate_slots runs.
    let schedules: Vec<Vec<Action>> = (0..pp).map(|s| one_f_one_b(pp, s, n_micro)).collect();
    // ppf[s][i] / ppb[s][i]: node ids of stage s's pipeline send for
    // microbatch i (empty until built)
    let mut ppf = vec![vec![Vec::<usize>::new(); n_micro]; pp];
    let mut ppb = vec![vec![Vec::<usize>::new(); n_micro]; pp];
    let mut cursor = vec![0usize; pp];
    let mut tails: Vec<Vec<usize>> = vec![Vec::new(); pp];
    let mut dp_done = vec![false; pp];
    let mut progressed = true;
    while progressed {
        progressed = false;
        for s in 0..pp {
            while cursor[s] < schedules[s].len() {
                let action = schedules[s][cursor[s]];
                let arrival: Option<&[usize]> = match action {
                    Action::Forward(i) if s > 0 => {
                        if ppf[s - 1][i].is_empty() {
                            break;
                        }
                        Some(ppf[s - 1][i].as_slice())
                    }
                    Action::Backward(i) if s < pp - 1 => {
                        if ppb[s + 1][i].is_empty() {
                            break;
                        }
                        Some(ppb[s + 1][i].as_slice())
                    }
                    _ => None,
                };
                let prev = tails[s].clone();
                let tail = b.build_block(s, action, &prev, arrival);
                match action {
                    Action::Forward(i) if s < pp - 1 => ppf[s][i] = tail.clone(),
                    Action::Backward(i) if s > 0 => ppb[s][i] = tail.clone(),
                    _ => {}
                }
                tails[s] = tail;
                cursor[s] += 1;
                progressed = true;
            }
            if cursor[s] == schedules[s].len() && !dp_done[s] {
                let prev = tails[s].clone();
                tails[s] = b.build_dp(s, &prev);
                dp_done[s] = true;
                progressed = true;
            }
        }
    }
    assert!(
        cursor.iter().zip(&schedules).all(|(&c, sch)| c == sch.len()),
        "1F1B DAG construction deadlocked"
    );

    (StepDag { net, nodes: b.nodes, chain: b.chain, vols: sp.vols }, b.tags)
}

/// Build the step DAG. Preconditions (divisibility) are the same as
/// [`crate::perf::evaluate`]'s; callers go through
/// [`crate::perf::check_feasible`] first.
pub fn lower_step(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
) -> Result<StepDag, String> {
    Ok(build_from_params(step_params(w, cluster, map, knobs)?, false).0)
}

/// [`lower_step`] with the full per-stage attribution chain: every stage's
/// tasks are recorded in `chain` (tagged with [`ChainTask::stage`]), which
/// is what `obs::trace::step_trace` turns into one span track per
/// pipeline stage. The nodes — and therefore the simulation — are
/// bit-identical to [`lower_step`]'s; only the chain grows (×pp), so the
/// planner's hot path keeps using [`lower_step`] / the skeleton cache.
pub fn lower_step_traced(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
) -> Result<StepDag, String> {
    Ok(build_from_params(step_params(w, cluster, map, knobs)?, true).0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MoeConfig;
    use crate::parallel::Parallelism;

    fn paper_point(cfg: usize) -> (Workload, Cluster, Mapping) {
        let w = Workload::paper_gpt_4p7t(cfg);
        let c = Cluster::passage_512(32_768);
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(cfg));
        (w, c, m)
    }

    #[test]
    fn paper_dag_has_expected_shape() {
        let (w, c, m) = paper_point(4);
        let knobs = PerfKnobs::default();
        let dag = lower_step(&w, &c, &m, &knobs).unwrap();
        // 8 stages × one pod each; EP group == pod on Passage
        assert_eq!(dag.net.n_nodes, 8 * 512);
        assert!(dag.nodes.len() > 1000);
        assert!(dag.nodes.len() <= estimate_nodes(&m, dag.vols.n_micro));
        // stage-0 chain: 16 F + 16 B blocks of (comp, tp, ep) + 15 B-sends
        // (B blocks at stage 0 don't send) + 16 F-sends + DP tasks
        let comp = dag.chain.iter().filter(|t| t.phase == Phase::Compute).count();
        assert_eq!(comp, 2 * dag.vols.n_micro);
        let dp = dag.chain.iter().filter(|t| t.phase == Phase::DpComm).count();
        assert!(dp >= 2, "{dp}"); // hierarchical shared sync + expert ring
        // deps are topological (simulate_dag asserts this too)
        for (i, n) in dag.nodes.iter().enumerate() {
            for &d in &n.deps {
                assert!(d < i);
            }
        }
    }

    #[test]
    fn oversized_mappings_are_rejected() {
        let (w, c, _) = paper_point(4);
        // pathological depth × grain × width: ~8M nodes; must error with
        // guidance, not grind (the lifted cap is a memory guard, so only
        // truly degenerate lowerings hit it now)
        let m = Mapping::try_with_microbatch(
            Parallelism { tp: 64, pp: 120, dp: 32 },
            MoeConfig::paper_config(4),
            1,
        )
        .unwrap();
        assert!(estimate_nodes(&m, 128) > MAX_DAG_NODES);
        let err = lower_step(&w, &c, &m, &PerfKnobs::default());
        assert!(err.is_err());
    }

    #[test]
    fn deep_pp_mappings_lower_below_the_lifted_cap() {
        // The previously-rejected region (estimate > 300k, the old cap):
        // a deep-PP × fine-microbatch mapping must now lower cleanly.
        let (w, c, _) = paper_point(4);
        let m = Mapping::try_with_microbatch(
            Parallelism { tp: 8, pp: 64, dp: 64 },
            MoeConfig::paper_config(4),
            1,
        )
        .unwrap();
        let est = estimate_nodes(&m, m.n_micro(&w));
        assert!(est > crate::timeline::DEEP_REGION_MIN_NODES && est <= MAX_DAG_NODES, "{est}");
        let dag = lower_step(&w, &c, &m, &PerfKnobs::default()).unwrap();
        // the estimate is the (conservative) rejection gate; the actual
        // lowering stays below it (~229k nodes for this point)
        assert!(dag.nodes.len() > 100_000);
        assert!(dag.nodes.len() <= est);
    }

    #[test]
    fn phantom_block_exists_only_for_pp1() {
        let (w, c, _) = paper_point(2);
        let m = Mapping::try_with_microbatch(
            Parallelism { tp: 16, pp: 1, dp: 2048 },
            MoeConfig::paper_config(2),
            1,
        )
        .unwrap();
        let knobs = PerfKnobs::default();
        let dag = lower_step(&w, &c, &m, &knobs).unwrap();
        assert_eq!(dag.net.n_nodes, 2 * 512); // stage block + phantom
    }
}
