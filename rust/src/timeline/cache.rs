//! Skeleton cache: amortize [`lower_step`](super::lower_step) across
//! planner candidates.
//!
//! Mappings sharing their structural geometry lower to the *same* DAG
//! skeleton — identical node kinds, dependency lists, flow endpoints and
//! slice network — and differ only in the numbers: flow byte-sizes and
//! delay durations. The builder reads every such number through the
//! [`Slot`] table and records which slot each node's value came from, so
//! a cached skeleton can be re-parameterized for a new candidate by
//! rewriting node values slot-by-slot. That rewrite is bit-equal to a
//! fresh lowering by construction (both write `params[slot]` verbatim into
//! the node), which the skeleton-cache property test pins.
//!
//! # What makes a cached skeleton reusable
//!
//! [`build_from_params`] takes no reference to the workload, cluster or
//! mapping: every branch it takes depends only on the structural fields of
//! `StepParams` (pod, span, stride, pp, tp, n_micro, the DP-branch
//! selector, the expert-ring flag, the slice network's two bandwidths) and
//! the zero-pattern of the slot table (`comm_group` emits a flow group,
//! a bare α delay, or a placeholder depending on which slots are
//! non-zero). [`SkeletonKey`] is exactly that tuple, so key equality ⇒
//! skeleton equality, with no appeal to how the candidate was derived.

use crate::model::Workload;
use crate::netsim::DagWork;
use crate::parallel::Mapping;
use crate::perf::PerfKnobs;
use crate::topology::cluster::Cluster;

use super::lower::{build_from_params, step_params, StepParams};
use super::StepDag;

/// Structural identity of a lowered step DAG — see the module docs for
/// why these fields (and nothing else) determine the skeleton.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SkeletonKey {
    pod: usize,
    span: usize,
    stride: usize,
    pp: usize,
    tp: usize,
    n_micro: usize,
    dp_branch: u8,
    expert_ring: bool,
    /// Slice-network bandwidths, compared bit-exactly (they parameterize
    /// `Network::two_level`, which is part of the skeleton).
    up_gbps_bits: u64,
    out_gbps_bits: u64,
    /// Bit i set ⇔ `params[i] > 0.0` — the builder's emit/skip decisions.
    zero_mask: u32,
}

fn key_of(sp: &StepParams) -> SkeletonKey {
    let mut zero_mask = 0u32;
    for (i, &v) in sp.params.iter().enumerate() {
        if v > 0.0 {
            zero_mask |= 1 << i;
        }
    }
    SkeletonKey {
        pod: sp.pod,
        span: sp.span,
        stride: sp.stride,
        pp: sp.pp,
        tp: sp.tp,
        n_micro: sp.vols.n_micro,
        dp_branch: sp.dp_branch,
        expert_ring: sp.expert_ring,
        up_gbps_bits: sp.up_gbps.to_bits(),
        out_gbps_bits: sp.out_gbps.to_bits(),
        zero_mask,
    }
}

struct Entry {
    key: SkeletonKey,
    dag: StepDag,
    /// `Slot` of every node's value, parallel to `dag.nodes`.
    tags: Vec<u8>,
    /// LRU stamp (logical clock tick of last use).
    stamp: u64,
}

/// Keep at most this many skeletons alive; deep-PP skeletons run to ~1 M
/// nodes each, and planner sweeps revisit only a handful of shapes at a
/// time (candidates are enumerated in mapping order, so shapes cluster).
pub const MAX_CACHED_SKELETONS: usize = 4;

/// A small LRU of lowered DAG skeletons, re-parameterized in place per
/// candidate. One per planner worker thread; results are bit-identical to
/// fresh [`lower_step`](super::lower_step) calls regardless of cache
/// state, so per-worker caches cannot perturb deterministic output.
#[derive(Default)]
pub struct SkeletonCache {
    entries: Vec<Entry>,
    clock: u64,
    hits: u64,
    misses: u64,
}

impl SkeletonCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Candidates that reused a cached skeleton (re-parameterize only).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Candidates that paid a full lowering.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// [`lower_step`](super::lower_step) through the cache: on a skeleton
    /// hit, rewrite the cached DAG's node values (and volumes) in place
    /// instead of rebuilding it. The returned DAG is bit-equal to a fresh
    /// lowering either way.
    pub fn lower(
        &mut self,
        w: &Workload,
        cluster: &Cluster,
        map: &Mapping,
        knobs: &PerfKnobs,
    ) -> Result<&StepDag, String> {
        let sp = step_params(w, cluster, map, knobs)?;
        let key = key_of(&sp);
        self.clock += 1;
        if let Some(idx) = self.entries.iter().position(|e| e.key == key) {
            self.hits += 1;
            let entry = &mut self.entries[idx];
            entry.stamp = self.clock;
            debug_assert_eq!(entry.tags.len(), entry.dag.nodes.len());
            for (node, &tag) in entry.dag.nodes.iter_mut().zip(&entry.tags) {
                let v = sp.params[tag as usize];
                match &mut node.work {
                    DagWork::Delay(d) => *d = v,
                    DagWork::Flow { bytes, .. } => *bytes = v,
                }
            }
            entry.dag.vols = sp.vols;
            return Ok(&self.entries[idx].dag);
        }
        self.misses += 1;
        let (dag, tags) = build_from_params(sp, false);
        if self.entries.len() >= MAX_CACHED_SKELETONS {
            // evict the least-recently-used skeleton
            if let Some(lru) = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
            {
                self.entries.swap_remove(lru);
            }
        }
        self.entries.push(Entry { key, dag, tags, stamp: self.clock });
        let idx = self.entries.len() - 1;
        Ok(&self.entries[idx].dag)
    }
}

/// Serial-equivalent cache accounting: replay the candidates' skeleton
/// keys, in the given order, against an LRU of [`MAX_CACHED_SKELETONS`]
/// entries — the `(hits, misses)` a *single serial* [`SkeletonCache`]
/// would report on this sequence. The actual per-worker thread-local
/// caches see worker-dependent subsequences, so their counters vary with
/// `--jobs`; this replay is worker-count-invariant by construction, which
/// is why the planner's `"metrics"` JSON reports it instead. Candidates
/// the size guard rejects are skipped (counted as neither). Cost is
/// [`step_params`] arithmetic only — nothing is lowered.
pub fn replay_reuse(
    w: &Workload,
    cluster: &Cluster,
    maps: &[&Mapping],
    knobs: &PerfKnobs,
) -> (u64, u64) {
    let mut lru: Vec<(SkeletonKey, u64)> = Vec::new();
    let mut clock = 0u64;
    let (mut hits, mut misses) = (0u64, 0u64);
    for map in maps {
        let Ok(sp) = step_params(w, cluster, map, knobs) else {
            continue;
        };
        let key = key_of(&sp);
        clock += 1;
        if let Some(e) = lru.iter_mut().find(|e| e.0 == key) {
            e.1 = clock;
            hits += 1;
            continue;
        }
        misses += 1;
        if lru.len() >= MAX_CACHED_SKELETONS {
            // same eviction rule as SkeletonCache::lower
            if let Some(i) = lru.iter().enumerate().min_by_key(|(_, e)| e.1).map(|(i, _)| i) {
                lru.swap_remove(i);
            }
        }
        lru.push((key, clock));
    }
    (hits, misses)
}

#[cfg(test)]
mod tests {
    use super::super::lower_step;
    use super::*;
    use crate::model::MoeConfig;
    use crate::parallel::Parallelism;

    fn paper_setup() -> (Workload, Cluster, Mapping) {
        let w = Workload::paper_gpt_4p7t(4);
        let c = Cluster::passage_512(32_768);
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(4));
        (w, c, m)
    }

    fn assert_dags_bit_equal(a: &StepDag, b: &StepDag) {
        assert_eq!(a.nodes.len(), b.nodes.len());
        for (x, y) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(x.deps, y.deps);
            match (&x.work, &y.work) {
                (DagWork::Delay(dx), DagWork::Delay(dy)) => {
                    assert_eq!(dx.to_bits(), dy.to_bits());
                }
                (
                    DagWork::Flow { src: sx, dst: dx, bytes: bx },
                    DagWork::Flow { src: sy, dst: dy, bytes: by },
                ) => {
                    assert_eq!((sx, dx), (sy, dy));
                    assert_eq!(bx.to_bits(), by.to_bits());
                }
                _ => panic!("node kind mismatch"),
            }
        }
        assert_eq!(a.net.n_nodes, b.net.n_nodes);
        assert_eq!(a.chain.len(), b.chain.len());
    }

    #[test]
    fn cache_hit_reparameterization_matches_fresh_lowering() {
        let (w, c, m) = paper_setup();
        // same skeleton, different values: mfu scales compute durations,
        // comm_dtype_bytes scales the TP/EP byte sizes
        let knobs_a = PerfKnobs::default();
        let knobs_b = PerfKnobs { mfu: 0.55, comm_dtype_bytes: 2.0, ..PerfKnobs::default() };
        let mut cache = SkeletonCache::new();
        cache.lower(&w, &c, &m, &knobs_a).unwrap();
        // second candidate: same skeleton, re-parameterized in place
        let fresh = lower_step(&w, &c, &m, &knobs_b).unwrap();
        let cached = cache.lower(&w, &c, &m, &knobs_b).unwrap();
        assert_dags_bit_equal(cached, &fresh);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn distinct_skeletons_do_not_collide() {
        let (w, c, m) = paper_setup();
        let knobs = PerfKnobs::default();
        let deep = Mapping::try_with_microbatch(
            Parallelism { tp: 8, pp: 64, dp: 64 },
            MoeConfig::paper_config(4),
            1,
        )
        .unwrap();
        let mut cache = SkeletonCache::new();
        for mapping in [&m, &deep] {
            let fresh = lower_step(&w, &c, mapping, &knobs).unwrap();
            let cached = cache.lower(&w, &c, mapping, &knobs).unwrap();
            assert_dags_bit_equal(cached, &fresh);
        }
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        // revisiting the first shape after the second still re-parameterizes
        let fresh = lower_step(&w, &c, &m, &knobs).unwrap();
        let cached = cache.lower(&w, &c, &m, &knobs).unwrap();
        assert_dags_bit_equal(cached, &fresh);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn replay_reuse_matches_a_serial_cache() {
        let (w, c, m) = paper_setup();
        let knobs = PerfKnobs::default();
        let deep = Mapping::try_with_microbatch(
            Parallelism { tp: 8, pp: 64, dp: 64 },
            MoeConfig::paper_config(4),
            1,
        )
        .unwrap();
        let seq = [&m, &deep, &m, &m, &deep];
        let mut cache = SkeletonCache::new();
        for mp in &seq {
            cache.lower(&w, &c, mp, &knobs).unwrap();
        }
        let (hits, misses) = replay_reuse(&w, &c, &seq, &knobs);
        assert_eq!((hits, misses), (cache.hits(), cache.misses()));
        assert_eq!((hits, misses), (3, 2));
    }

    #[test]
    fn eviction_keeps_the_cache_bounded_and_correct() {
        let (w, c, _) = paper_setup();
        let knobs = PerfKnobs::default();
        // more distinct skeletons than MAX_CACHED_SKELETONS: microbatch
        // grain (n_micro is structural) plus two deeper-PP shapes
        let mut shapes: Vec<Mapping> = [1, 2, 4, 8]
            .iter()
            .map(|&mb| {
                Mapping::try_with_microbatch(
                    Parallelism::paper(),
                    MoeConfig::paper_config(4),
                    mb,
                )
                .unwrap()
            })
            .collect();
        for pp in [16, 32] {
            shapes.push(
                Mapping::try_with_microbatch(
                    Parallelism { tp: 8, pp, dp: 4096 / pp },
                    MoeConfig::paper_config(4),
                    1,
                )
                .unwrap(),
            );
        }
        let mut cache = SkeletonCache::new();
        for m in &shapes {
            let fresh = lower_step(&w, &c, m, &knobs).unwrap();
            let cached = cache.lower(&w, &c, m, &knobs).unwrap();
            assert_dags_bit_equal(cached, &fresh);
        }
        assert_eq!(cache.misses(), shapes.len() as u64);
        // evicted shape rebuilds correctly on revisit
        let fresh = lower_step(&w, &c, &shapes[0], &knobs).unwrap();
        let cached = cache.lower(&w, &c, &shapes[0], &knobs).unwrap();
        assert_dags_bit_equal(cached, &fresh);
    }
}
