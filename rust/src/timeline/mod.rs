//! Discrete-event training-step simulator: the closed-loop cross-check of
//! the analytical model and the planner.
//!
//! The paper's headline numbers (§VI, the 2.7× time-to-train) come from a
//! closed-form Hockney α+β model with hand-tuned overlap knobs. This
//! subsystem replays an *entire* training step — the 1F1B pipeline
//! interleaved with TP all-reduces, EP all-to-alls, pipeline transfers and
//! the DP gradient sync, all competing on the two-level fabric — as a task
//! DAG on the dependency-driven netsim engine ([`crate::netsim::dep`]).
//! Compute/comm overlap and pipeline bubbles *emerge* from the dependency
//! structure instead of being assumed via `PerfKnobs` scalars, which makes
//! the comparison meaningful: the analytical-vs-simulated gap measures how
//! much the closed form leans on its overlap assumptions.
//!
//! Flow-level step replay is how related photonic-fabric evaluations
//! ground their analytical speedups (arXiv:2507.14000, arXiv:2510.03943);
//! measured gaps for the §VI clusters are tabulated in EXPERIMENTS.md
//! §Validate (Passage-512 sits within a few percent; the electrical
//! 144-pod alternative exposes the EP-overlap credit the closed form
//! grants, which *strengthens* the paper's claim).
//!
//! Entry points: [`simulate_step`] (one mapping), [`validate_mapping`]
//! (simulate + analytical + gap), `lumos validate` (CLI, including
//! `--plan-top K` to cross-check the planner's best mappings and `--deep`
//! to sweep the deep-PP × fine-microbatch region the pre-incremental
//! engine rejected — see [`DEEP_REGION_MIN_NODES`]) and
//! `sweep::validate_gap_table` (the `figures --validate` artifact).
//! Simulation runs on the component-incremental
//! [`crate::netsim::DagSimulator`], which is what makes per-candidate
//! re-simulation cheap enough to sit inside the planner's search loop
//! (`lumos plan --rerank-sim`).

mod cache;
mod lower;

pub use cache::{replay_reuse, SkeletonCache, MAX_CACHED_SKELETONS};
pub use lower::{
    estimate_nodes, lower_step, lower_step_traced, ChainTask, Phase, StepDag, MAX_DAG_NODES,
};

use crate::model::Workload;
use crate::netsim::{simulate_dag_stats, DepStats};
use crate::parallel::{enumerate_candidates, Mapping};
use crate::perf::memory::MemoryBreakdown;
use crate::perf::{evaluate_feasible, Infeasible, PerfKnobs, PerfReport};
use crate::topology::cluster::Cluster;
use crate::util::json::Json;
use crate::util::stats::fmt_time;
use crate::util::table::Table;

/// The DAG-size cap *before* the dependency engine went
/// component-incremental (PR 5 lifted [`MAX_DAG_NODES`] from this value):
/// mappings whose lowering exceeds it — the deep-PP × fine-microbatch
/// corner of the search space — used to be rejected outright, so the
/// planner's `--rerank-sim` and `lumos validate --plan-top` silently fell
/// back to the analytical model exactly where its overlap credits are
/// least trustworthy. `lumos validate --deep` sweeps this
/// previously-rejected region end-to-end.
pub const DEEP_REGION_MIN_NODES: usize = 300_000;

/// Deterministic grid over the previously-rejected deep-PP region: every
/// feasible enumerated mapping whose lowered DAG estimate lies in
/// `(DEEP_REGION_MIN_NODES, MAX_DAG_NODES]`, ordered by estimated node
/// count (smallest first — the band just past the old cap) with the
/// mapping tuple as tie-break, truncated to `top`.
pub fn deep_candidates(w: &Workload, cluster: &Cluster, top: usize) -> Vec<Mapping> {
    let mut out: Vec<(usize, Mapping)> = enumerate_candidates(w, cluster)
        .into_iter()
        .filter_map(|m| {
            let est = estimate_nodes(&m, m.n_micro(w));
            if est > DEEP_REGION_MIN_NODES
                && est <= MAX_DAG_NODES
                && crate::perf::check_feasible(w, &m).is_ok()
            {
                Some((est, m))
            } else {
                None
            }
        })
        .collect();
    out.sort_by_key(|(est, m)| {
        (*est, m.par.tp, m.par.pp, m.par.dp, m.microbatch_seqs, m.moe.experts_per_dp_rank)
    });
    out.truncate(top);
    out.into_iter().map(|(_, m)| m).collect()
}

/// Where the simulated step time went, measured on the stage-0 chain
/// (the stage whose last gradient sync ends the step). The fields
/// partition `[0, step_time]` exactly: `total() == step_time` to float
/// round-off.
#[derive(Debug, Clone, Default)]
pub struct PhaseBreakdown {
    /// Forward/backward matmul time.
    pub compute: f64,
    /// Exposed TP + expert-TP all-reduce time.
    pub tp_comm: f64,
    /// Exposed EP all-to-all time (dispatch + combine, both directions).
    pub ep_comm: f64,
    /// Exposed pipeline p2p send time.
    pub pp_comm: f64,
    /// Exposed DP gradient sync time (shared + expert).
    pub dp_comm: f64,
    /// Pipeline bubble: stage-0 idle time waiting on other stages.
    pub bubble: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.tp_comm + self.ep_comm + self.pp_comm + self.dp_comm + self.bubble
    }

    /// `(label, seconds, share-of-total)` rows in the canonical phase
    /// order shared with `obs::diff::PHASE_ORDER` — the common currency
    /// of the three-way (analytical / simulated / executed) gap report.
    pub fn rows(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total();
        let share = |x: f64| if total > 0.0 { x / total } else { 0.0 };
        vec![
            ("compute", self.compute, share(self.compute)),
            ("tp", self.tp_comm, share(self.tp_comm)),
            ("ep", self.ep_comm, share(self.ep_comm)),
            ("pp", self.pp_comm, share(self.pp_comm)),
            ("dp", self.dp_comm, share(self.dp_comm)),
            ("bubble", self.bubble, share(self.bubble)),
        ]
    }
}

/// The analytical model's own per-phase split of its step time: the
/// closed form prices `(n_micro + pp - 1)` microbatch slots plus the
/// non-overlapped DP sync, so `n_micro` slots' worth of each phase is
/// "real" work and the remaining `(pp - 1)` slots are the 1F1B bubble.
/// Sums to `PerfReport::step_time` up to float round-off — the
/// analytical column of the three-way gap report.
pub fn analytical_phases(b: &crate::perf::StepBreakdown, knobs: &PerfKnobs) -> PhaseBreakdown {
    let n = b.n_micro as f64;
    PhaseBreakdown {
        compute: n * b.compute_per_micro,
        tp_comm: n * b.tp_comm_per_micro,
        ep_comm: n * b.ep_a2a_per_micro,
        pp_comm: n * b.pp_comm_per_micro,
        dp_comm: (1.0 - knobs.dp_overlap) * b.dp_comm_per_step,
        bubble: (b.pp - 1) as f64 * b.micro_time(),
    }
}

/// Fold per-category span totals (as produced by a parsed Chrome trace
/// or `trainer::RunOutcome::cat_totals`) into a [`PhaseBreakdown`]. The
/// category names are the shared span vocabulary: `compute`, `tp`, `ep`,
/// `pp`, `dp`, `bubble`; anything else (e.g. the executed trace's
/// `step` instants) is ignored.
pub fn phases_from_cat_totals(totals: &std::collections::BTreeMap<String, f64>) -> PhaseBreakdown {
    let g = |k: &str| totals.get(k).copied().unwrap_or(0.0);
    PhaseBreakdown {
        compute: g("compute"),
        tp_comm: g("tp"),
        ep_comm: g("ep"),
        pp_comm: g("pp"),
        dp_comm: g("dp"),
        bubble: g("bubble"),
    }
}

/// Result of simulating one training step.
#[derive(Debug, Clone)]
pub struct TimelineReport {
    /// Simulated step time, seconds.
    pub step_time: f64,
    /// Simulated time-to-train (step × steps to the token target).
    pub time_to_train_s: f64,
    pub phases: PhaseBreakdown,
    /// DAG size / event count (simulation cost accounting).
    pub nodes: usize,
    pub events: usize,
    /// Dependency-engine work counters for this simulation run
    /// (settlements, re-fills, component sizes — deterministic, fed into
    /// the `"metrics"` JSON key).
    pub dep: DepStats,
}

/// Why a point cannot be simulated.
#[derive(Debug, Clone)]
pub enum TimelineError {
    /// The mapping fails the perf model's own feasibility predicate.
    Infeasible(Infeasible),
    /// The lowered DAG would exceed [`MAX_DAG_NODES`].
    TooLarge(String),
}

impl std::fmt::Display for TimelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TimelineError::Infeasible(e) => write!(f, "infeasible mapping: {e}"),
            TimelineError::TooLarge(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for TimelineError {}

/// Simulate one training step of `(w, map)` on `cluster`.
///
/// `knobs` supplies the calibration constants shared with the analytical
/// model (`mfu`, wire dtype, the netsim-derived a2a efficiency lives on
/// the cluster) — but *not* the overlap fractions: overlap is decided by
/// the DAG.
pub fn simulate_step(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
) -> Result<TimelineReport, TimelineError> {
    simulate_step_with(w, cluster, map, knobs, |_| {})
}

/// [`simulate_step`] with a hook that may edit the lowered slice network
/// before simulation — the fail-in-place path: [`crate::resilience`]
/// removes a failed link's capacity
/// ([`crate::netsim::Network::scale_node_links`]) and re-simulates the step
/// on the degraded fabric.
pub fn simulate_step_with(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
    tweak: impl FnOnce(&mut crate::netsim::Network),
) -> Result<TimelineReport, TimelineError> {
    let dag = lower_step(w, cluster, map, knobs).map_err(TimelineError::TooLarge)?;
    Ok(simulate_lowered(w, &dag, tweak))
}

/// [`simulate_step`] through a caller-owned [`SkeletonCache`]: candidates
/// sharing a DAG skeleton skip [`lower_step`] and pay only slot-value
/// rewriting plus simulation. Bit-identical to [`simulate_step`]
/// regardless of cache state (the cache's re-parameterization is bit-equal
/// to fresh lowering by construction, pinned by its property test), which
/// is why the planner can hand each pool worker its own cache without
/// perturbing deterministic output.
pub fn simulate_step_cached(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
    cache: &mut SkeletonCache,
) -> Result<TimelineReport, TimelineError> {
    let dag = cache.lower(w, cluster, map, knobs).map_err(TimelineError::TooLarge)?;
    Ok(simulate_on(w, dag))
}

/// Simulate an already-lowered step DAG, applying `tweak` to a copy of its
/// slice network first. The lowering is reusable across fabric states, so
/// callers that re-simulate one mapping under several degradations (the
/// [`crate::resilience`] healthy/up/out sweep) lower once and call this
/// per state instead of paying [`lower_step`] three times.
pub fn simulate_lowered(
    w: &Workload,
    dag: &StepDag,
    tweak: impl FnOnce(&mut crate::netsim::Network),
) -> TimelineReport {
    let mut net = dag.net.clone();
    tweak(&mut net);
    simulate_attributed(w, dag, &net)
}

/// Simulate a lowered DAG on its own (untweaked) slice network, skipping
/// the defensive network clone — the planner's hot path.
fn simulate_on(w: &Workload, dag: &StepDag) -> TimelineReport {
    simulate_attributed(w, dag, &dag.net)
}

fn simulate_attributed(w: &Workload, dag: &StepDag, net: &crate::netsim::Network) -> TimelineReport {
    let (result, dep) = simulate_dag_stats(net, &dag.nodes);
    let phases = spans_breakdown(&stage_spans(&dag.chain, 0, &result.finish, result.makespan));
    TimelineReport {
        step_time: result.makespan,
        time_to_train_s: result.makespan * w.steps_to_target(),
        phases,
        nodes: dag.nodes.len(),
        events: result.events,
        dep,
    }
}

/// One attributed interval on a stage's serialized chain: a phase task,
/// or pipeline bubble when `phase` is `None`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageSpan {
    pub phase: Option<Phase>,
    pub start: f64,
    pub end: f64,
}

/// Attribution walk over `stage`'s chain entries: the stage's chain is
/// serialized, so each instant belongs to exactly one task (a phase span)
/// or to the bubble (waiting on another stage). The returned spans
/// partition `[0, makespan]` exactly — `obs::trace` renders them as one
/// Perfetto track per stage, and [`spans_breakdown`] folds them into the
/// `lumos validate` per-phase columns (bit-identical to the historical
/// inline walk for stage 0).
pub fn stage_spans(
    chain: &[ChainTask],
    stage: usize,
    finish: &[f64],
    makespan: f64,
) -> Vec<StageSpan> {
    let fin = |ids: &[usize]| ids.iter().map(|&i| finish[i]).fold(0.0f64, f64::max);
    let mut spans = Vec::new();
    let mut cursor = 0.0f64;
    for task in chain.iter().filter(|t| t.stage == stage) {
        let start = fin(&task.deps).max(cursor);
        let end = fin(&task.ends);
        if end > cursor {
            if start > cursor {
                spans.push(StageSpan { phase: None, start: cursor, end: start });
            }
            spans.push(StageSpan { phase: Some(task.phase), start, end });
            cursor = end;
        }
    }
    if makespan > cursor {
        spans.push(StageSpan { phase: None, start: cursor, end: makespan });
    }
    spans
}

/// Fold [`stage_spans`] output into a [`PhaseBreakdown`] (span durations
/// accumulate per bucket in span order, so the sums are bit-equal to the
/// pre-refactor inline accumulation).
pub fn spans_breakdown(spans: &[StageSpan]) -> PhaseBreakdown {
    let mut p = PhaseBreakdown::default();
    for s in spans {
        let bucket = match s.phase {
            None => &mut p.bubble,
            Some(Phase::Compute) => &mut p.compute,
            Some(Phase::TpComm) => &mut p.tp_comm,
            Some(Phase::EpComm) => &mut p.ep_comm,
            Some(Phase::PpComm) => &mut p.pp_comm,
            Some(Phase::DpComm) => &mut p.dp_comm,
        };
        *bucket += s.end - s.start;
    }
    p
}

/// One mapping's analytical-vs-simulated comparison.
#[derive(Debug, Clone)]
pub struct Validation {
    pub mapping: Mapping,
    pub memory: MemoryBreakdown,
    pub analytical: PerfReport,
    pub simulated: TimelineReport,
}

impl Validation {
    /// Relative step-time gap: (simulated − analytical) / analytical.
    pub fn gap(&self) -> f64 {
        (self.simulated.step_time - self.analytical.step_time) / self.analytical.step_time
    }
}

/// Evaluate the analytical model *and* the simulator on one point.
pub fn validate_mapping(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
) -> Result<Validation, TimelineError> {
    let (analytical, memory) =
        evaluate_feasible(w, cluster, map, knobs).map_err(TimelineError::Infeasible)?;
    let simulated = simulate_step(w, cluster, map, knobs)?;
    Ok(Validation { mapping: map.clone(), memory, analytical, simulated })
}

fn mapping_label(m: &Mapping) -> String {
    format!(
        "TP{}×PP{}×DP{}/mb{}/epr{}",
        m.par.tp, m.par.pp, m.par.dp, m.microbatch_seqs, m.moe.experts_per_dp_rank
    )
}

/// Render validations as the `lumos validate` table. The per-phase columns
/// partition the simulated step exactly (acceptance: they sum to it).
pub fn validation_table(cluster: &str, config: &str, rows: &[Validation]) -> Table {
    let mut t = Table::new(
        &format!("Validate: {cluster} / {config} — analytical vs simulated step"),
        &[
            "mapping", "ana step", "sim step", "gap", "compute", "TP", "EP", "PP", "DP",
            "bubble",
        ],
    );
    for v in rows {
        let p = &v.simulated.phases;
        t.row(&[
            mapping_label(&v.mapping),
            fmt_time(v.analytical.step_time),
            fmt_time(v.simulated.step_time),
            format!("{:+.1}%", 100.0 * v.gap()),
            fmt_time(p.compute),
            fmt_time(p.tp_comm),
            fmt_time(p.ep_comm),
            fmt_time(p.pp_comm),
            fmt_time(p.dp_comm),
            fmt_time(p.bubble),
        ]);
    }
    t
}

fn mapping_json(m: &Mapping) -> Json {
    Json::obj(vec![
        ("tp", Json::num(m.par.tp as f64)),
        ("pp", Json::num(m.par.pp as f64)),
        ("dp", Json::num(m.par.dp as f64)),
        ("microbatch_seqs", Json::num(m.microbatch_seqs as f64)),
        ("experts_per_dp_rank", Json::num(m.moe.experts_per_dp_rank as f64)),
    ])
}

/// Machine-readable form of the validation (`lumos validate --json`).
pub fn validation_json(cluster: &str, config: &str, rows: &[Validation]) -> Json {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|v| {
            let p = &v.simulated.phases;
            Json::obj(vec![
                ("mapping", mapping_json(&v.mapping)),
                ("analytical_step_s", Json::num(v.analytical.step_time)),
                ("simulated_step_s", Json::num(v.simulated.step_time)),
                ("gap", Json::num(v.gap())),
                ("analytical_time_to_train_s", Json::num(v.analytical.time_to_train_s)),
                ("simulated_time_to_train_s", Json::num(v.simulated.time_to_train_s)),
                (
                    "phases",
                    Json::obj(vec![
                        ("compute", Json::num(p.compute)),
                        ("tp_comm", Json::num(p.tp_comm)),
                        ("ep_comm", Json::num(p.ep_comm)),
                        ("pp_comm", Json::num(p.pp_comm)),
                        ("dp_comm", Json::num(p.dp_comm)),
                        ("bubble", Json::num(p.bubble)),
                    ]),
                ),
                ("dag_nodes", Json::num(v.simulated.nodes as f64)),
                ("sim_events", Json::num(v.simulated.events as f64)),
                ("hbm_utilization", Json::num(v.memory.utilization())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("cluster", Json::str(cluster)),
        ("config", Json::str(config)),
        ("metrics", validation_metrics(rows).to_json()),
        ("rows", Json::Arr(rows_json)),
    ])
}

/// Deterministic counters for a validation run (the `"metrics"` key of
/// `lumos validate --json`): DAG sizes, simulator event counts, and the
/// dependency engine's work counters summed over the rows in row order.
pub fn validation_metrics(rows: &[Validation]) -> crate::obs::Metrics {
    let mut m = crate::obs::Metrics::new();
    m.inc("rows", rows.len() as u64);
    for v in rows {
        m.inc("dag_nodes", v.simulated.nodes as u64);
        m.inc("sim_events", v.simulated.events as u64);
        let d = &v.simulated.dep;
        m.inc("sim_admitted_flows", d.admitted_flows);
        m.inc("sim_admitted_delays", d.admitted_delays);
        m.inc("sim_refills", d.refills);
        m.inc("sim_refill_flows", d.refill_flows);
        m.inc("sim_heap_settlements", d.settlements);
        m.inc("sim_heap_stale_pops", d.stale_pops);
        m.observe("sim_refill_component_flows_max", d.refill_flows_max as f64);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MoeConfig;
    use crate::parallel::Parallelism;

    fn paper_validation(cfg: usize) -> Validation {
        let w = Workload::paper_gpt_4p7t(cfg);
        let c = Cluster::passage_512(32_768);
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(cfg));
        validate_mapping(&w, &c, &m, &PerfKnobs::default()).unwrap()
    }

    #[test]
    fn phases_partition_the_simulated_step() {
        let v = paper_validation(4);
        let p = &v.simulated.phases;
        let rel = (p.total() - v.simulated.step_time).abs() / v.simulated.step_time;
        assert!(rel <= 1e-9, "phases sum {} vs step {}", p.total(), v.simulated.step_time);
        for (name, x) in [
            ("compute", p.compute),
            ("tp", p.tp_comm),
            ("ep", p.ep_comm),
            ("pp", p.pp_comm),
            ("dp", p.dp_comm),
            ("bubble", p.bubble),
        ] {
            assert!(x >= 0.0, "{name} negative: {x}");
        }
        assert!(p.compute > 0.0 && p.tp_comm > 0.0 && p.bubble > 0.0);
    }

    #[test]
    fn analytical_phases_sum_to_the_analytical_step() {
        let v = paper_validation(4);
        let knobs = PerfKnobs::default();
        let p = analytical_phases(&v.analytical.breakdown, &knobs);
        let ana = v.analytical.step_time;
        let rel = (p.total() - ana).abs() / ana;
        assert!(rel <= 1e-9, "analytical phases sum {} vs step {ana}", p.total());
        assert!(p.compute > 0.0 && p.ep_comm > 0.0 && p.bubble > 0.0);
        let rows = p.rows();
        assert_eq!(rows.len(), 6);
        let share_sum: f64 = rows.iter().map(|r| r.2).sum();
        assert!((share_sum - 1.0).abs() < 1e-9);
        assert_eq!(rows[0].0, "compute");
        assert_eq!(rows[5].0, "bubble");
    }

    #[test]
    fn cat_totals_fold_into_phases() {
        let mut t = std::collections::BTreeMap::new();
        t.insert("compute".to_string(), 2.0);
        t.insert("ep".to_string(), 0.5);
        t.insert("bubble".to_string(), 0.25);
        t.insert("step".to_string(), 99.0); // ignored: not a phase
        let p = phases_from_cat_totals(&t);
        assert_eq!(p.compute, 2.0);
        assert_eq!(p.ep_comm, 0.5);
        assert_eq!(p.bubble, 0.25);
        assert_eq!(p.tp_comm, 0.0);
        assert_eq!(p.total(), 2.75);
    }

    #[test]
    fn bubble_matches_the_1f1b_fraction() {
        // Stage 0 idles for ~ (pp-1)/(n_micro+pp-1) of the pipelined part.
        let v = paper_validation(4);
        let p = &v.simulated.phases;
        let pipelined = v.simulated.step_time - p.dp_comm;
        let frac = p.bubble / pipelined;
        let model = v.analytical.breakdown.bubble_fraction();
        assert!((frac - model).abs() < 0.05, "sim bubble {frac} vs 1F1B {model}");
    }

    #[test]
    fn degraded_slice_network_slows_the_simulated_step() {
        let w = Workload::paper_gpt_4p7t(4);
        let c = Cluster::passage_512(32_768);
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(4));
        let knobs = PerfKnobs::default();
        let healthy = simulate_step(&w, &c, &m, &knobs).unwrap();
        // GPU 0 (stage 0, rank 0) loses half its scale-up lanes: every
        // barrier collective it participates in slows to its rate.
        let degraded =
            simulate_step_with(&w, &c, &m, &knobs, |net| net.scale_node_links(0, 0.5, 1.0))
                .unwrap();
        assert!(degraded.step_time > healthy.step_time);
    }

    #[test]
    fn deep_candidates_cover_the_previously_rejected_region() {
        let w = Workload::paper_gpt_4p7t(4);
        let c = Cluster::passage_512(32_768);
        let deep = deep_candidates(&w, &c, 3);
        assert!(!deep.is_empty(), "no deep-PP candidates on Passage-512/config 4");
        let mut last_est = 0usize;
        for m in &deep {
            let est = estimate_nodes(m, m.n_micro(&w));
            assert!(est > DEEP_REGION_MIN_NODES && est <= MAX_DAG_NODES, "{est}");
            assert!(est >= last_est, "not ordered by estimate");
            last_est = est;
            assert!(crate::perf::check_feasible(&w, m).is_ok());
        }
        // deterministic
        assert_eq!(deep, deep_candidates(&w, &c, 3));
    }

    #[test]
    fn cached_simulation_is_bit_identical_to_fresh() {
        let w = Workload::paper_gpt_4p7t(4);
        let c = Cluster::passage_512(32_768);
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(4));
        let knobs = PerfKnobs::default();
        let fresh = simulate_step(&w, &c, &m, &knobs).unwrap();
        let mut cache = SkeletonCache::new();
        // first call lowers, second re-parameterizes the cached skeleton;
        // both must be bit-identical to the uncached path
        for _ in 0..2 {
            let cached = simulate_step_cached(&w, &c, &m, &knobs, &mut cache).unwrap();
            assert_eq!(cached.step_time.to_bits(), fresh.step_time.to_bits());
            assert_eq!(cached.events, fresh.events);
            assert_eq!(cached.phases.bubble.to_bits(), fresh.phases.bubble.to_bits());
        }
    }

    #[test]
    fn infeasible_mappings_error_cleanly() {
        let w = Workload::paper_gpt_4p7t(4);
        let c = Cluster::passage_512(32_768);
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(4))
            .with_microbatch(5); // 16 seqs/rank not divisible
        assert!(matches!(
            validate_mapping(&w, &c, &m, &PerfKnobs::default()),
            Err(TimelineError::Infeasible(_))
        ));
    }

    #[test]
    fn validation_artifacts_render() {
        let v = paper_validation(1);
        let t = validation_table("Passage-512", "E32/k1/m1", &[v.clone()]);
        let r = t.render();
        assert!(r.contains("TP16×PP8×DP256"), "{r}");
        assert!(r.contains("gap"), "{r}");
        let j = validation_json("Passage-512", "E32/k1/m1", &[v]);
        let s = j.to_string_pretty();
        assert!(s.contains("\"simulated_step_s\""), "{s}");
        assert!(s.contains("\"bubble\""), "{s}");
        // deterministic serialization
        let j2 = Json::parse(&s).unwrap();
        assert_eq!(j2.get("cluster").as_str(), Some("Passage-512"));
    }
}
