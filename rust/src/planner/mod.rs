//! Parallelism planner: end-to-end search of the 4D mapping space.
//!
//! The paper's headline claim is that the 8× larger scale-up domain
//! "affords new opportunities for multi-dimensional parallelism" — this
//! module makes that claim checkable. For a (workload, cluster) pair it
//! enumerates every legal (TP, PP, DP, microbatch, experts-per-rank)
//! mapping ([`crate::parallel::enumerate_candidates`]), prunes points that
//! fail the feasibility predicate ([`crate::perf::check_feasible`]: model
//! divisibility + HBM capacity), scores the survivors on the
//! [`crate::sweep::engine`] worker pool, and returns a deterministically
//! ranked plan.
//!
//! Determinism contract (same as `lumos sweep`): candidates are enumerated
//! in a fixed order, every evaluation is a pure function, grid results come
//! back in job order, and the final sort is keyed on
//! (`time_to_train`, TP, PP, DP, microbatch, experts-per-rank) under
//! `f64::total_cmp` — so `lumos plan --jobs N` is byte-identical for any N.
//!
//! Search methodology and headline planner results are documented in
//! EXPERIMENTS.md §Planner.

use std::cmp::Ordering;

use crate::model::Workload;
use crate::parallel::{enumerate_candidates, Mapping, Parallelism};
use crate::perf::memory::MemoryBreakdown;
use crate::perf::{check_feasible, evaluate, PerfKnobs, PerfReport};
use crate::sweep::engine::{run_grid_with_cache, ClusterCache, ClusterKey, EvalJob};
use crate::util::json::Json;
use crate::util::stats::fmt_time;
use crate::util::table::Table;

/// One planning problem: map `workload` onto `cluster`.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    pub workload: Workload,
    pub cluster: ClusterKey,
    pub knobs: PerfKnobs,
    /// Keep at most this many ranked plans (0 = all feasible points).
    pub top: usize,
}

impl PlanRequest {
    /// Plan the paper's Config `cfg` (Table IV) onto `cluster`.
    pub fn paper(cluster: ClusterKey, cfg: usize, knobs: &PerfKnobs) -> PlanRequest {
        PlanRequest {
            workload: Workload::paper_gpt_4p7t(cfg),
            cluster,
            knobs: knobs.clone(),
            top: 0,
        }
    }

    /// Limit the ranked result to the best `top` plans.
    pub fn with_top(mut self, top: usize) -> Self {
        self.top = top;
        self
    }
}

/// One scored, HBM-feasible mapping.
#[derive(Debug, Clone)]
pub struct RankedPlan {
    pub mapping: Mapping,
    pub memory: MemoryBreakdown,
    pub report: PerfReport,
}

/// The planner's answer: ranked feasible plans plus search accounting.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub cluster: String,
    pub config_name: String,
    /// Structurally legal candidates enumerated.
    pub enumerated: usize,
    /// Candidates pruned by the feasibility predicate (HBM capacity —
    /// enumeration already guarantees the divisibility constraints).
    pub pruned: usize,
    /// Feasible plans, best time-to-train first.
    pub ranked: Vec<RankedPlan>,
    /// The paper's fixed TP16×PP8×DP256 mapping evaluated on this cluster,
    /// when applicable (see [`paper_baseline`]).
    pub paper_baseline: Option<PerfReport>,
}

impl PlanOutcome {
    /// The winning plan (the search space is never empty for the paper
    /// clusters, but a degenerate custom cluster can prune everything).
    pub fn best(&self) -> Option<&RankedPlan> {
        self.ranked.first()
    }
}

/// Deterministic ranking: time-to-train under `total_cmp`, ties broken on
/// the mapping tuple so the order never depends on evaluation order.
fn rank_order(a: &RankedPlan, b: &RankedPlan) -> Ordering {
    let key = |p: &RankedPlan| {
        (
            p.mapping.par.tp,
            p.mapping.par.pp,
            p.mapping.par.dp,
            p.mapping.microbatch_seqs,
            p.mapping.moe.experts_per_dp_rank,
        )
    };
    a.report
        .time_to_train_s
        .total_cmp(&b.report.time_to_train_s)
        .then_with(|| key(a).cmp(&key(b)))
}

/// The paper's fixed mapping evaluated on `cluster` as a comparison
/// baseline — `Some` only when its divisibility holds for `w`, its TP
/// groups fit the pod (the model prices TP collectives on the scale-up
/// domain), and the mapping size is within 2% of the cluster (the §VI
/// precedent: the 32,768-GPU mapping is scored on the 32,256-GPU
/// electrical cluster).
pub fn paper_baseline(
    w: &Workload,
    cluster: &crate::topology::cluster::Cluster,
    knobs: &PerfKnobs,
) -> Option<PerfReport> {
    let par = Parallelism::paper();
    let map = Mapping::try_new(par, w.moe).ok()?;
    // The baseline obeys the same feasibility predicate the ranked plans
    // do (divisibility + HBM) plus the TP-in-pod placement constraint.
    if check_feasible(w, &map).is_err() || par.tp > cluster.spec.pod_size {
        return None;
    }
    let delta = (par.n_gpus() as f64 - cluster.spec.n_gpus as f64).abs();
    if delta / cluster.spec.n_gpus as f64 > 0.02 {
        return None;
    }
    Some(evaluate(w, cluster, &map, knobs))
}

/// Run the search on `jobs` worker threads (fresh cluster cache).
pub fn plan(req: &PlanRequest, jobs: usize) -> PlanOutcome {
    let cache = ClusterCache::new();
    plan_with_cache(req, jobs, &cache)
}

/// [`plan`] against a caller-owned [`ClusterCache`], so several searches in
/// one command (e.g. the planner figures) share cluster construction.
pub fn plan_with_cache(req: &PlanRequest, jobs: usize, cache: &ClusterCache) -> PlanOutcome {
    let cluster = cache.get(&req.cluster);
    let candidates = enumerate_candidates(&req.workload, &cluster);
    let enumerated = candidates.len();

    let mut feasible: Vec<(Mapping, MemoryBreakdown)> = Vec::new();
    for m in candidates {
        if let Ok(mem) = check_feasible(&req.workload, &m) {
            feasible.push((m, mem));
        }
    }
    let pruned = enumerated - feasible.len();

    let grid: Vec<EvalJob> = feasible
        .iter()
        .map(|(m, _)| {
            EvalJob::mapped(req.cluster.clone(), req.workload.clone(), m.clone(), &req.knobs)
        })
        .collect();
    let reports = run_grid_with_cache(&grid, jobs, cache);

    let mut ranked: Vec<RankedPlan> = feasible
        .into_iter()
        .zip(reports)
        .map(|((mapping, memory), report)| RankedPlan { mapping, memory, report })
        .collect();
    ranked.sort_by(rank_order);
    if req.top > 0 {
        ranked.truncate(req.top);
    }

    let paper = paper_baseline(&req.workload, &cluster, &req.knobs);
    let (cluster_name, config_name) = match ranked.first() {
        Some(p) => (p.report.cluster.clone(), p.report.config_name.clone()),
        None => (cluster.spec.name.clone(), String::new()),
    };
    PlanOutcome {
        cluster: cluster_name,
        config_name,
        enumerated,
        pruned,
        ranked,
        paper_baseline: paper,
    }
}

/// Render the ranked result (all rows of `outcome.ranked`; pre-truncate via
/// [`PlanRequest::with_top`]). Pure string output — the `lumos plan` CLI and
/// the planner figures print it, and it is byte-identical for any worker
/// count.
pub fn ranked_table(outcome: &PlanOutcome) -> Table {
    // `ranked` may be truncated by `with_top`; the feasible count comes
    // from the search accounting, so the title stays honest either way.
    let feasible = outcome.enumerated - outcome.pruned;
    let title = format!(
        "Plan: {} / {} — {} candidates, {} pruned (HBM), showing {} of {} feasible",
        outcome.cluster,
        outcome.config_name,
        outcome.enumerated,
        outcome.pruned,
        outcome.ranked.len(),
        feasible,
    );
    let header = [
        "#", "TP", "PP", "DP", "micro", "exp/rank", "EP domain", "HBM", "step", "TTT",
        "vs paper map",
    ];
    let mut t = Table::new(&title, &header);
    for (i, p) in outcome.ranked.iter().enumerate() {
        let vs_paper = match &outcome.paper_baseline {
            Some(b) => format!("{:.2}x", b.time_to_train_s / p.report.time_to_train_s),
            None => "—".to_string(),
        };
        t.row(&[
            format!("{}", i + 1),
            format!("{}", p.mapping.par.tp),
            format!("{}", p.mapping.par.pp),
            format!("{}", p.mapping.par.dp),
            format!("{}", p.mapping.microbatch_seqs),
            format!("{}", p.mapping.moe.experts_per_dp_rank),
            format!("{:?}", p.report.breakdown.ep_placement),
            format!("{:.0}%", 100.0 * p.memory.utilization()),
            fmt_time(p.report.step_time),
            fmt_time(p.report.time_to_train_s),
            vs_paper,
        ]);
    }
    t
}

/// Machine-readable form of a plan outcome (`lumos plan --json`):
/// mapping + timing per ranked plan, plus the search accounting
/// (enumerated / pruned / feasible) and the paper baseline when present.
/// Keys are sorted (BTreeMap), so serialization is deterministic and
/// byte-identical for any worker count.
pub fn outcome_json(outcome: &PlanOutcome) -> Json {
    let ranked: Vec<Json> = outcome
        .ranked
        .iter()
        .map(|p| {
            Json::obj(vec![
                (
                    "mapping",
                    Json::obj(vec![
                        ("tp", Json::num(p.mapping.par.tp as f64)),
                        ("pp", Json::num(p.mapping.par.pp as f64)),
                        ("dp", Json::num(p.mapping.par.dp as f64)),
                        ("microbatch_seqs", Json::num(p.mapping.microbatch_seqs as f64)),
                        (
                            "experts_per_dp_rank",
                            Json::num(p.mapping.moe.experts_per_dp_rank as f64),
                        ),
                    ]),
                ),
                ("step_time_s", Json::num(p.report.step_time)),
                ("time_to_train_s", Json::num(p.report.time_to_train_s)),
                ("comm_fraction", Json::num(p.report.comm_fraction)),
                ("achieved_mfu", Json::num(p.report.achieved_mfu)),
                ("hbm_utilization", Json::num(p.memory.utilization())),
                (
                    "ep_placement",
                    Json::str(&format!("{:?}", p.report.breakdown.ep_placement)),
                ),
            ])
        })
        .collect();
    let baseline = match &outcome.paper_baseline {
        Some(b) => Json::obj(vec![
            ("step_time_s", Json::num(b.step_time)),
            ("time_to_train_s", Json::num(b.time_to_train_s)),
        ]),
        None => Json::Null,
    };
    Json::obj(vec![
        ("cluster", Json::str(&outcome.cluster)),
        ("config", Json::str(&outcome.config_name)),
        ("enumerated", Json::num(outcome.enumerated as f64)),
        ("pruned", Json::num(outcome.pruned as f64)),
        ("feasible", Json::num((outcome.enumerated - outcome.pruned) as f64)),
        ("paper_baseline", baseline),
        ("ranked", Json::Arr(ranked)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(cluster: ClusterKey, cfg: usize) -> PlanRequest {
        PlanRequest::paper(cluster, cfg, &PerfKnobs::default())
    }

    #[test]
    fn plan_ranks_only_feasible_points_best_first() {
        // Config 1 has the heaviest per-expert state, so some enumerated
        // points genuinely exceed HBM and must be pruned.
        let out = plan(&req(ClusterKey::Passage512, 1), 2);
        assert!(out.pruned > 0, "expected HBM pruning on config 1");
        assert_eq!(out.enumerated, out.pruned + out.ranked.len());
        for p in &out.ranked {
            assert!(p.memory.fits());
        }
        for w in out.ranked.windows(2) {
            assert!(w[0].report.time_to_train_s <= w[1].report.time_to_train_s);
        }
    }

    #[test]
    fn top_k_truncates_after_ranking() {
        let full = plan(&req(ClusterKey::Passage512, 4), 2);
        let top3 = plan(&req(ClusterKey::Passage512, 4).with_top(3), 2);
        assert_eq!(top3.ranked.len(), 3);
        for (a, b) in full.ranked.iter().take(3).zip(&top3.ranked) {
            assert_eq!(a.mapping, b.mapping);
        }
        // accounting reflects the whole search, not the truncation
        assert_eq!(full.enumerated, top3.enumerated);
        assert_eq!(full.pruned, top3.pruned);
    }

    #[test]
    fn serial_and_parallel_plans_are_identical() {
        let r = req(ClusterKey::Electrical144, 4);
        let a = plan(&r, 1);
        let b = plan(&r, 4);
        assert_eq!(a.ranked.len(), b.ranked.len());
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.mapping, y.mapping);
            assert_eq!(
                x.report.time_to_train_s.to_bits(),
                y.report.time_to_train_s.to_bits()
            );
        }
        assert_eq!(ranked_table(&a).render(), ranked_table(&b).render());
    }

    #[test]
    fn paper_baseline_follows_the_section6_precedent() {
        let knobs = PerfKnobs::default();
        let w = Workload::paper_gpt_4p7t(4);
        // exact size and the 1.5%-smaller electrical cluster: baseline exists
        for key in [ClusterKey::Passage512, ClusterKey::Electrical144] {
            assert!(paper_baseline(&w, &key.build(), &knobs).is_some(), "{key:?}");
        }
        // a cluster a quarter the size: the fixed mapping is not comparable
        let small = ClusterKey::custom(8_192, 512, 32_000.0).build();
        assert!(paper_baseline(&w, &small, &knobs).is_none());
    }

    #[test]
    fn outcome_json_is_deterministic_and_complete() {
        let r = req(ClusterKey::Passage512, 4).with_top(3);
        let a = outcome_json(&plan(&r, 1)).to_string_pretty();
        let b = outcome_json(&plan(&r, 4)).to_string_pretty();
        assert_eq!(a, b, "plan --json must be byte-identical across job counts");
        let j = Json::parse(&a).unwrap();
        assert_eq!(j.get("ranked").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("feasible").as_usize().unwrap(),
            j.get("enumerated").as_usize().unwrap() - j.get("pruned").as_usize().unwrap()
        );
        let top = j.get("ranked").at(0);
        assert!(top.get("time_to_train_s").as_f64().unwrap() > 0.0);
        assert!(top.get("mapping").get("tp").as_usize().unwrap() > 0);
        assert!(j.get("paper_baseline").get("step_time_s").as_f64().is_some());
    }

    #[test]
    fn ranked_table_renders_mapping_columns() {
        let out = plan(&req(ClusterKey::Passage512, 4).with_top(5), 2);
        let r = ranked_table(&out).render();
        assert!(r.contains("TP"), "{r}");
        assert!(r.contains("vs paper map"), "{r}");
        assert!(r.contains("ScaleUp"), "{r}");
        assert_eq!(r.lines().count(), 3 + 5); // title + header + sep + 5 rows
    }
}
