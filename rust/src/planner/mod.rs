//! Parallelism planner: end-to-end search of the 4D mapping space.
//!
//! The paper's headline claim is that the 8× larger scale-up domain
//! "affords new opportunities for multi-dimensional parallelism" — this
//! module makes that claim checkable. For a (workload, cluster) pair it
//! enumerates every legal (TP, PP, DP, microbatch, experts-per-rank)
//! mapping ([`crate::parallel::enumerate_candidates`]), prunes points that
//! fail the feasibility predicate ([`crate::perf::check_feasible`]: model
//! divisibility + HBM capacity), scores the survivors on the
//! [`crate::sweep::engine`] worker pool, and returns a deterministically
//! ranked plan.
//!
//! Determinism contract (same as `lumos sweep`): candidates are enumerated
//! in a fixed order, every evaluation is a pure function, grid results come
//! back in job order, and the final sort is keyed on
//! (`time_to_train`, TP, PP, DP, microbatch, experts-per-rank) under
//! `f64::total_cmp` — so `lumos plan --jobs N` is byte-identical for any N.
//!
//! Search methodology and headline planner results are documented in
//! EXPERIMENTS.md §Planner.

use std::cmp::Ordering;

use crate::model::Workload;
use crate::parallel::{enumerate_candidates, Mapping, Parallelism};
use crate::perf::memory::MemoryBreakdown;
use crate::perf::{check_feasible, evaluate, PerfKnobs, PerfReport};
use crate::resilience::{self, FabricReliability, GoodputInputs, RepairModel};
use crate::sweep::engine::{run_grid_with_cache, ClusterCache, ClusterKey, EvalJob};
use crate::timeline::{self, TimelineReport};
use crate::topology::cluster::Cluster;
use crate::util::json::Json;
use crate::util::stats::fmt_time;
use crate::util::table::Table;

/// Optional availability-adjusted objective (`lumos plan --availability`):
/// rank on the [`crate::resilience`] effective time-to-train instead of
/// the healthy one, so mappings that expose large scale-out communication
/// (the PP=1/DP-heavy winners whose giant gradient syncs a degraded NIC
/// inflates) pay for their failure blast radius.
#[derive(Debug, Clone)]
pub struct AvailabilityObjective {
    pub fabric: FabricReliability,
    pub repair: RepairModel,
}

impl AvailabilityObjective {
    /// The fabric the cluster preset implies, with default repair times.
    pub fn default_for(cluster: &Cluster) -> AvailabilityObjective {
        AvailabilityObjective {
            fabric: FabricReliability::default_for(cluster),
            repair: RepairModel::default(),
        }
    }
}

/// One planning problem: map `workload` onto `cluster`.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    pub workload: Workload,
    pub cluster: ClusterKey,
    pub knobs: PerfKnobs,
    /// Keep at most this many ranked plans (0 = all feasible points).
    pub top: usize,
    /// Rank on availability-adjusted effective TTT when set.
    pub availability: Option<AvailabilityObjective>,
}

impl PlanRequest {
    /// Plan the paper's Config `cfg` (Table IV) onto `cluster`.
    pub fn paper(cluster: ClusterKey, cfg: usize, knobs: &PerfKnobs) -> PlanRequest {
        PlanRequest {
            workload: Workload::paper_gpt_4p7t(cfg),
            cluster,
            knobs: knobs.clone(),
            top: 0,
            availability: None,
        }
    }

    /// Limit the ranked result to the best `top` plans.
    pub fn with_top(mut self, top: usize) -> Self {
        self.top = top;
        self
    }

    /// Rank on the availability-adjusted objective.
    pub fn with_availability(mut self, objective: AvailabilityObjective) -> Self {
        self.availability = Some(objective);
        self
    }
}

/// One scored, HBM-feasible mapping.
#[derive(Debug, Clone)]
pub struct RankedPlan {
    pub mapping: Mapping,
    pub memory: MemoryBreakdown,
    pub report: PerfReport,
    /// Availability-adjusted effective TTT (populated when the request
    /// carries an [`AvailabilityObjective`]; the ranking key then).
    pub adjusted_ttt: Option<f64>,
}

impl RankedPlan {
    /// The value this plan was ranked on.
    pub fn objective_ttt(&self) -> f64 {
        self.adjusted_ttt.unwrap_or(self.report.time_to_train_s)
    }
}

/// The planner's answer: ranked feasible plans plus search accounting.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub cluster: String,
    pub config_name: String,
    /// Structurally legal candidates enumerated.
    pub enumerated: usize,
    /// Candidates pruned by the feasibility predicate (HBM capacity —
    /// enumeration already guarantees the divisibility constraints).
    pub pruned: usize,
    /// Feasible plans, best time-to-train first.
    pub ranked: Vec<RankedPlan>,
    /// The paper's fixed TP16×PP8×DP256 mapping evaluated on this cluster,
    /// when applicable (see [`paper_baseline`]).
    pub paper_baseline: Option<PerfReport>,
}

impl PlanOutcome {
    /// The winning plan (the search space is never empty for the paper
    /// clusters, but a degenerate custom cluster can prune everything).
    pub fn best(&self) -> Option<&RankedPlan> {
        self.ranked.first()
    }
}

/// Deterministic ranking: the objective (healthy TTT, or the
/// availability-adjusted TTT when requested) under `total_cmp`, ties
/// broken on the mapping tuple so the order never depends on evaluation
/// order.
fn rank_order(a: &RankedPlan, b: &RankedPlan) -> Ordering {
    a.objective_ttt()
        .total_cmp(&b.objective_ttt())
        .then_with(|| mapping_key(&a.mapping).cmp(&mapping_key(&b.mapping)))
}

/// Deterministic tie-break tuple for a mapping.
fn mapping_key(m: &Mapping) -> (usize, usize, usize, usize, usize) {
    (m.par.tp, m.par.pp, m.par.dp, m.microbatch_seqs, m.moe.experts_per_dp_rank)
}

/// Compose already-evaluated degraded step times into the closed-form
/// availability-adjusted effective TTT (see
/// [`crate::resilience::goodput`]).
fn adjusted_ttt_from_steps(
    steps: &resilience::DegradedSteps,
    dp: usize,
    n_gpus: usize,
    objective: &AvailabilityObjective,
) -> f64 {
    let inputs = GoodputInputs {
        healthy_step: steps.healthy_step,
        degraded_up_step: steps.degraded_up_step,
        degraded_out_step: steps.degraded_out_step,
        healthy_ttt: steps.healthy_ttt,
        dp,
        lam_up_field_h: objective.fabric.field_rate_up_per_hour(n_gpus),
        lam_out_field_h: objective.fabric.field_rate_out_per_hour(n_gpus),
        lam_tray_h: objective.fabric.tray_rate_per_hour(n_gpus),
        repair: objective.repair.clone(),
    };
    resilience::expected(&inputs).effective_ttt
}

/// The closed-form availability-adjusted effective TTT of one mapping
/// under `objective` (the one-off form; [`plan_with_cache`] hoists the
/// degraded clusters and reuses its healthy reports instead).
pub fn availability_adjusted_ttt(
    w: &Workload,
    cluster: &Cluster,
    map: &Mapping,
    knobs: &PerfKnobs,
    objective: &AvailabilityObjective,
) -> f64 {
    let steps = resilience::analytical_degraded_steps(w, cluster, map, knobs, &objective.fabric);
    adjusted_ttt_from_steps(&steps, map.par.dp, cluster.spec.n_gpus, objective)
}

/// The paper's fixed mapping evaluated on `cluster` as a comparison
/// baseline — `Some` only when its divisibility holds for `w`, its TP
/// groups fit the pod (the model prices TP collectives on the scale-up
/// domain), and the mapping size is within 2% of the cluster (the §VI
/// precedent: the 32,768-GPU mapping is scored on the 32,256-GPU
/// electrical cluster).
pub fn paper_baseline(
    w: &Workload,
    cluster: &crate::topology::cluster::Cluster,
    knobs: &PerfKnobs,
) -> Option<PerfReport> {
    let par = Parallelism::paper();
    let map = Mapping::try_new(par, w.moe).ok()?;
    // The baseline obeys the same feasibility predicate the ranked plans
    // do (divisibility + HBM) plus the TP-in-pod placement constraint.
    if check_feasible(w, &map).is_err() || par.tp > cluster.spec.pod_size {
        return None;
    }
    let delta = (par.n_gpus() as f64 - cluster.spec.n_gpus as f64).abs();
    if delta / cluster.spec.n_gpus as f64 > 0.02 {
        return None;
    }
    Some(evaluate(w, cluster, &map, knobs))
}

/// Run the search on `jobs` worker threads (fresh cluster cache).
pub fn plan(req: &PlanRequest, jobs: usize) -> PlanOutcome {
    let cache = ClusterCache::new();
    plan_with_cache(req, jobs, &cache)
}

/// [`plan`] against a caller-owned [`ClusterCache`], so several searches in
/// one command (e.g. the planner figures) share cluster construction.
pub fn plan_with_cache(req: &PlanRequest, jobs: usize, cache: &ClusterCache) -> PlanOutcome {
    let cluster = cache.get(&req.cluster);
    let candidates = enumerate_candidates(&req.workload, &cluster);
    let enumerated = candidates.len();

    let mut feasible: Vec<(Mapping, MemoryBreakdown)> = Vec::new();
    for m in candidates {
        if let Ok(mem) = check_feasible(&req.workload, &m) {
            feasible.push((m, mem));
        }
    }
    let pruned = enumerated - feasible.len();

    let grid: Vec<EvalJob> = feasible
        .iter()
        .map(|(m, _)| {
            EvalJob::mapped(req.cluster.clone(), req.workload.clone(), m.clone(), &req.knobs)
        })
        .collect();
    let reports = run_grid_with_cache(&grid, jobs, cache);

    // Availability objective: the degraded clusters depend only on
    // (cluster, fabric), so build them once and score the two degraded
    // evaluations per candidate on the same worker pool as the healthy
    // grid, reusing the healthy report already in hand.
    let adjusted: Option<Vec<f64>> = req.availability.as_ref().map(|obj| {
        use crate::resilience::{degraded_cluster, DegradedMode, DegradedSteps};
        let up = degraded_cluster(
            &cluster,
            DegradedMode::ScaleUpLink,
            1.0 / obj.fabric.scale_up_links_per_gpu as f64,
        );
        let out = degraded_cluster(
            &cluster,
            DegradedMode::ScaleOutLink,
            1.0 / obj.fabric.scale_out_links_per_gpu as f64,
        );
        crate::sweep::engine::run_indexed(feasible.len(), jobs, |i| {
            let (m, _) = &feasible[i];
            let steps = DegradedSteps {
                healthy_step: reports[i].step_time,
                healthy_ttt: reports[i].time_to_train_s,
                degraded_up_step: evaluate(&req.workload, &up, m, &req.knobs).step_time,
                degraded_out_step: evaluate(&req.workload, &out, m, &req.knobs).step_time,
            };
            adjusted_ttt_from_steps(&steps, m.par.dp, cluster.spec.n_gpus, obj)
        })
    });

    let mut ranked: Vec<RankedPlan> = feasible
        .into_iter()
        .zip(reports)
        .enumerate()
        .map(|(i, ((mapping, memory), report))| RankedPlan {
            mapping,
            memory,
            report,
            adjusted_ttt: adjusted.as_ref().map(|a| a[i]),
        })
        .collect();
    ranked.sort_by(rank_order);
    if req.top > 0 {
        ranked.truncate(req.top);
    }

    let paper = paper_baseline(&req.workload, &cluster, &req.knobs);
    let (cluster_name, config_name) = match ranked.first() {
        Some(p) => (p.report.cluster.clone(), p.report.config_name.clone()),
        None => (cluster.spec.name.clone(), String::new()),
    };
    PlanOutcome {
        cluster: cluster_name,
        config_name,
        enumerated,
        pruned,
        ranked,
        paper_baseline: paper,
    }
}

/// Render the ranked result (all rows of `outcome.ranked`; pre-truncate via
/// [`PlanRequest::with_top`]). Pure string output — the `lumos plan` CLI and
/// the planner figures print it, and it is byte-identical for any worker
/// count.
pub fn ranked_table(outcome: &PlanOutcome) -> Table {
    // `ranked` may be truncated by `with_top`; the feasible count comes
    // from the search accounting, so the title stays honest either way.
    let feasible = outcome.enumerated - outcome.pruned;
    let title = format!(
        "Plan: {} / {} — {} candidates, {} pruned (HBM), showing {} of {} feasible",
        outcome.cluster,
        outcome.config_name,
        outcome.enumerated,
        outcome.pruned,
        outcome.ranked.len(),
        feasible,
    );
    let header = [
        "#", "TP", "PP", "DP", "micro", "exp/rank", "EP domain", "HBM", "step", "TTT",
        "eff TTT", "vs paper map",
    ];
    let mut t = Table::new(&title, &header);
    for (i, p) in outcome.ranked.iter().enumerate() {
        let vs_paper = match &outcome.paper_baseline {
            Some(b) => format!("{:.2}x", b.time_to_train_s / p.report.time_to_train_s),
            None => "—".to_string(),
        };
        let eff = match p.adjusted_ttt {
            Some(t) => resilience::fmt_ttt(t),
            None => "—".to_string(),
        };
        t.row(&[
            format!("{}", i + 1),
            format!("{}", p.mapping.par.tp),
            format!("{}", p.mapping.par.pp),
            format!("{}", p.mapping.par.dp),
            format!("{}", p.mapping.microbatch_seqs),
            format!("{}", p.mapping.moe.experts_per_dp_rank),
            format!("{:?}", p.report.breakdown.ep_placement),
            format!("{:.0}%", 100.0 * p.memory.utilization()),
            fmt_time(p.report.step_time),
            fmt_time(p.report.time_to_train_s),
            eff,
            vs_paper,
        ]);
    }
    t
}

/// One plan re-scored on the discrete-event simulator.
#[derive(Debug, Clone)]
pub struct SimScored {
    /// 1-based rank in the analytical ordering.
    pub ana_rank: usize,
    pub plan: RankedPlan,
    pub sim: TimelineReport,
}

impl SimScored {
    /// Relative step-time gap: (simulated − analytical) / analytical.
    pub fn gap(&self) -> f64 {
        (self.sim.step_time - self.plan.report.step_time) / self.plan.report.step_time
    }
}

/// Why the simulator could not score a plan. One typed value rendered
/// identically everywhere it surfaces — the table row, the stderr skip
/// line, and the JSON `skipped_reason` — replacing the stringly-typed
/// reason that let the three renderings drift.
#[derive(Debug, Clone)]
pub enum SkipReason {
    /// The lowered DAG would exceed [`timeline::MAX_DAG_NODES`]
    /// (the message carries the estimate).
    DagTooLarge(String),
    /// The mapping fails the perf model's feasibility predicate.
    Infeasible(String),
}

impl SkipReason {
    fn from_timeline(e: &timeline::TimelineError) -> SkipReason {
        match e {
            timeline::TimelineError::TooLarge(msg) => SkipReason::DagTooLarge(msg.clone()),
            timeline::TimelineError::Infeasible(inf) => SkipReason::Infeasible(inf.to_string()),
        }
    }

    /// Stable machine-readable code (the JSON `skipped_code` field).
    pub fn code(&self) -> &'static str {
        match self {
            SkipReason::DagTooLarge(_) => "dag-too-large",
            SkipReason::Infeasible(_) => "infeasible",
        }
    }
}

impl std::fmt::Display for SkipReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SkipReason::DagTooLarge(msg) => write!(f, "{msg}"),
            SkipReason::Infeasible(msg) => write!(f, "infeasible mapping: {msg}"),
        }
    }
}

/// A plan the simulated re-rank could not score (with the reason): after
/// the `MAX_DAG_NODES` lift the guard only fires on truly pathological
/// lowerings, but when it does the plan must stay visible in the output —
/// a silently dropped row used to read as "this mapping was never a
/// candidate".
#[derive(Debug, Clone)]
pub struct SkippedPlan {
    /// 1-based rank in the analytical ordering.
    pub ana_rank: usize,
    /// The un-simulated plan, analytical report included, so the rendered
    /// row still carries everything the analytical ranking knew.
    pub plan: RankedPlan,
    pub reason: SkipReason,
}

/// Re-rank the top `k` ranked plans on *simulated* step time (`lumos plan
/// --rerank-sim K`): the analytical winners lean on the closed form's
/// overlap credits (EXPERIMENTS.md §Validate measures +60…120% for the
/// PP=1/DP-heavy mappings), so the simulator gets the final word.
/// Deterministic: plans simulate serially in analytical-rank order and
/// sort on simulated TTT under `total_cmp` with the mapping tuple as
/// tie-break. Plans the simulator cannot score are returned as
/// [`SkippedPlan`]s (second return value) and rendered by
/// [`rerank_table`], never dropped.
pub fn rerank_simulated(
    outcome: &PlanOutcome,
    k: usize,
    workload: &Workload,
    cluster: &Cluster,
    knobs: &PerfKnobs,
) -> (Vec<SimScored>, Vec<SkippedPlan>) {
    let mut scored = Vec::new();
    let mut skipped = Vec::new();
    for (i, p) in outcome.ranked.iter().take(k).enumerate() {
        match timeline::simulate_step(workload, cluster, &p.mapping, knobs) {
            Ok(sim) => scored.push(SimScored { ana_rank: i + 1, plan: p.clone(), sim }),
            Err(e) => skipped.push(SkippedPlan {
                ana_rank: i + 1,
                plan: p.clone(),
                reason: SkipReason::from_timeline(&e),
            }),
        }
    }
    scored.sort_by(|a, b| {
        a.sim
            .time_to_train_s
            .total_cmp(&b.sim.time_to_train_s)
            .then_with(|| mapping_key(&a.plan.mapping).cmp(&mapping_key(&b.plan.mapping)))
    });
    (scored, skipped)
}

/// Render a simulated re-rank (companion table to [`ranked_table`]).
/// Skipped plans appear as explicit rows after the scored ones, keyed by
/// their analytical rank, so nothing the re-rank touched is invisible.
pub fn rerank_table(scored: &[SimScored], skipped: &[SkippedPlan]) -> Table {
    let mut title =
        format!("Plan re-rank: top {} by simulated step time", scored.len() + skipped.len());
    if !skipped.is_empty() {
        title.push_str(&format!(" ({} not simulated — see rows)", skipped.len()));
    }
    let mut t = Table::new(
        &title,
        &["sim#", "ana#", "TP", "PP", "DP", "micro", "exp/rank", "ana step", "sim step",
          "gap", "sim TTT"],
    );
    for (i, s) in scored.iter().enumerate() {
        t.row(&[
            format!("{}", i + 1),
            format!("{}", s.ana_rank),
            format!("{}", s.plan.mapping.par.tp),
            format!("{}", s.plan.mapping.par.pp),
            format!("{}", s.plan.mapping.par.dp),
            format!("{}", s.plan.mapping.microbatch_seqs),
            format!("{}", s.plan.mapping.moe.experts_per_dp_rank),
            fmt_time(s.plan.report.step_time),
            fmt_time(s.sim.step_time),
            format!("{:+.1}%", 100.0 * s.gap()),
            fmt_time(s.sim.time_to_train_s),
        ]);
    }
    for s in skipped {
        t.row(&[
            "—".to_string(),
            format!("{}", s.ana_rank),
            format!("{}", s.plan.mapping.par.tp),
            format!("{}", s.plan.mapping.par.pp),
            format!("{}", s.plan.mapping.par.dp),
            format!("{}", s.plan.mapping.microbatch_seqs),
            format!("{}", s.plan.mapping.moe.experts_per_dp_rank),
            fmt_time(s.plan.report.step_time),
            "skipped".to_string(),
            "—".to_string(),
            "—".to_string(),
        ]);
    }
    t
}

/// One `reason` line per skipped plan (stderr companion to
/// [`rerank_table`] — the table carries the mapping, this carries the
/// why).
pub fn rerank_skip_lines(skipped: &[SkippedPlan]) -> Vec<String> {
    skipped
        .iter()
        .map(|s| {
            format!(
                "rerank-sim skipped ana#{} TP{}xPP{}xDP{}/mb{}: {}",
                s.ana_rank,
                s.plan.mapping.par.tp,
                s.plan.mapping.par.pp,
                s.plan.mapping.par.dp,
                s.plan.mapping.microbatch_seqs,
                s.reason
            )
        })
        .collect()
}

/// Default admission margin for [`plan_simulated`]'s analytical prefilter:
/// a feasible candidate is simulated iff its analytical TTT is within
/// `(1 + margin)×` of the best analytical TTT. The analytical model is a
/// *lower bound* in practice — EXPERIMENTS.md §Validate measures the
/// simulator running +4.5…+120% slower, never faster — so a candidate
/// whose closed-form TTT already exceeds `2.25×` the analytical winner
/// cannot beat that winner's simulated time and is safely skipped. The
/// margin is configurable (`--sim-margin`); `f64::INFINITY` disables the
/// prefilter entirely.
pub const DEFAULT_SIM_MARGIN: f64 = 1.25;

/// Result of full-set simulated planning (`lumos plan --objective sim`).
#[derive(Debug, Clone)]
pub struct SimPlan {
    /// Simulated plans, best *simulated* TTT first.
    pub scored: Vec<SimScored>,
    /// Admitted plans the simulator could not score (DAG size guard);
    /// kept visible, never dropped.
    pub skipped: Vec<SkippedPlan>,
    /// Feasible candidates the analytical prefilter did not admit.
    pub prefiltered: usize,
    /// The admission margin used (see [`DEFAULT_SIM_MARGIN`]).
    pub margin: f64,
    /// Serial-equivalent [`timeline::SkeletonCache`] hits over the
    /// admitted sequence ([`timeline::replay_reuse`]): what a single-cache
    /// serial run would have reused. Reported instead of the per-worker
    /// thread-local counters, whose split depends on `--jobs` and would
    /// break byte-identical output.
    pub cache_hits: u64,
    /// Serial-equivalent cache misses (see [`SimPlan::cache_hits`]).
    pub cache_misses: u64,
}

impl SimPlan {
    /// Candidates that went through the simulator (scored or skipped).
    pub fn admitted(&self) -> usize {
        self.scored.len() + self.skipped.len()
    }
}

/// Score the feasible set on the discrete-event simulator and rank on
/// simulated TTT (`lumos plan --objective sim`) — the full-set form of
/// [`rerank_simulated`], affordable because each candidate costs one
/// skeleton-cache re-parameterization plus one lazy-heap simulation
/// instead of a fresh lowering plus a dt-scan event loop.
///
/// `outcome` must carry the *untruncated* ranking (request `top == 0`);
/// the analytical prefilter admits every candidate within
/// `(1 + margin)×` of the best analytical TTT, and the admitted set
/// simulates on `jobs` [`crate::sweep::engine::run_indexed`] workers.
/// Each worker owns a thread-local [`timeline::SkeletonCache`] (and the
/// dependency engine's thread-local `DagSimulator` buffers underneath) —
/// sound because cached re-parameterization is bit-equal to fresh
/// lowering, so results never depend on which worker simulated which
/// candidate, and the final order is (simulated TTT under `total_cmp`,
/// mapping tuple): byte-identical output for any `--jobs N`.
pub fn plan_simulated(
    outcome: &PlanOutcome,
    workload: &Workload,
    cluster: &Cluster,
    knobs: &PerfKnobs,
    margin: f64,
    jobs: usize,
) -> SimPlan {
    let cutoff = outcome
        .ranked
        .first()
        .map(|best| best.report.time_to_train_s * (1.0 + margin))
        .unwrap_or(f64::INFINITY);
    let admitted: Vec<(usize, &RankedPlan)> = outcome
        .ranked
        .iter()
        .enumerate()
        .filter(|(_, p)| p.report.time_to_train_s <= cutoff)
        .collect();
    let prefiltered = outcome.ranked.len() - admitted.len();

    // Jobs-invariant cache accounting: replay the admitted sequence
    // against a serial-equivalent LRU (key arithmetic only, no lowering).
    let admitted_maps: Vec<&Mapping> = admitted.iter().map(|(_, p)| &p.mapping).collect();
    let (cache_hits, cache_misses) = timeline::replay_reuse(workload, cluster, &admitted_maps, knobs);

    use std::cell::RefCell;
    thread_local! {
        static SIM_CACHE: RefCell<timeline::SkeletonCache> =
            RefCell::new(timeline::SkeletonCache::new());
    }
    let results = crate::sweep::engine::run_indexed(admitted.len(), jobs, |i| {
        let (_, p) = &admitted[i];
        SIM_CACHE.with(|c| {
            timeline::simulate_step_cached(workload, cluster, &p.mapping, knobs, &mut c.borrow_mut())
        })
    });

    let mut scored = Vec::new();
    let mut skipped = Vec::new();
    for ((rank0, p), result) in admitted.into_iter().zip(results) {
        match result {
            Ok(sim) => scored.push(SimScored { ana_rank: rank0 + 1, plan: p.clone(), sim }),
            Err(e) => skipped.push(SkippedPlan {
                ana_rank: rank0 + 1,
                plan: p.clone(),
                reason: SkipReason::from_timeline(&e),
            }),
        }
    }
    scored.sort_by(|a, b| {
        a.sim
            .time_to_train_s
            .total_cmp(&b.sim.time_to_train_s)
            .then_with(|| mapping_key(&a.plan.mapping).cmp(&mapping_key(&b.plan.mapping)))
    });
    SimPlan { scored, skipped, prefiltered, margin, cache_hits, cache_misses }
}

/// Render a [`SimPlan`] (`lumos plan --objective sim`). Shows the best
/// `top` simulated rows (0 = all) plus every skipped row; the title keeps
/// the full admission accounting so truncation stays honest.
pub fn sim_table(sim: &SimPlan, top: usize) -> Table {
    let shown = if top > 0 { sim.scored.len().min(top) } else { sim.scored.len() };
    let mut title = format!(
        "Plan (sim objective): {} candidates simulated, {} prefiltered (analytical margin {:.2}), showing {} of {}",
        sim.admitted(),
        sim.prefiltered,
        sim.margin,
        shown,
        sim.scored.len(),
    );
    if !sim.skipped.is_empty() {
        title.push_str(&format!(" ({} not simulated — see rows)", sim.skipped.len()));
    }
    let mut t = Table::new(
        &title,
        &["sim#", "ana#", "TP", "PP", "DP", "micro", "exp/rank", "ana step", "sim step",
          "gap", "sim TTT"],
    );
    for (i, s) in sim.scored.iter().take(shown).enumerate() {
        t.row(&[
            format!("{}", i + 1),
            format!("{}", s.ana_rank),
            format!("{}", s.plan.mapping.par.tp),
            format!("{}", s.plan.mapping.par.pp),
            format!("{}", s.plan.mapping.par.dp),
            format!("{}", s.plan.mapping.microbatch_seqs),
            format!("{}", s.plan.mapping.moe.experts_per_dp_rank),
            fmt_time(s.plan.report.step_time),
            fmt_time(s.sim.step_time),
            format!("{:+.1}%", 100.0 * s.gap()),
            fmt_time(s.sim.time_to_train_s),
        ]);
    }
    for s in &sim.skipped {
        t.row(&[
            "—".to_string(),
            format!("{}", s.ana_rank),
            format!("{}", s.plan.mapping.par.tp),
            format!("{}", s.plan.mapping.par.pp),
            format!("{}", s.plan.mapping.par.dp),
            format!("{}", s.plan.mapping.microbatch_seqs),
            format!("{}", s.plan.mapping.moe.experts_per_dp_rank),
            fmt_time(s.plan.report.step_time),
            "skipped".to_string(),
            "—".to_string(),
            "—".to_string(),
        ]);
    }
    t
}

/// JSON rows for simulated results — shared by the `--objective sim` and
/// `--rerank-sim` sections of [`outcome_json`]. Scored rows first (in
/// simulated order), then skipped rows keyed by analytical rank.
fn sim_rows_json(scored: &[SimScored], skipped: &[SkippedPlan]) -> Json {
    let mut rows: Vec<Json> = scored
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Json::obj(vec![
                ("sim_rank", Json::num((i + 1) as f64)),
                ("ana_rank", Json::num(s.ana_rank as f64)),
                (
                    "mapping",
                    Json::obj(vec![
                        ("tp", Json::num(s.plan.mapping.par.tp as f64)),
                        ("pp", Json::num(s.plan.mapping.par.pp as f64)),
                        ("dp", Json::num(s.plan.mapping.par.dp as f64)),
                        ("microbatch_seqs", Json::num(s.plan.mapping.microbatch_seqs as f64)),
                        (
                            "experts_per_dp_rank",
                            Json::num(s.plan.mapping.moe.experts_per_dp_rank as f64),
                        ),
                    ]),
                ),
                ("analytical_step_s", Json::num(s.plan.report.step_time)),
                ("simulated_step_s", Json::num(s.sim.step_time)),
                ("gap", Json::num(s.gap())),
                ("simulated_time_to_train_s", Json::num(s.sim.time_to_train_s)),
                ("dag_nodes", Json::num(s.sim.nodes as f64)),
            ])
        })
        .collect();
    for s in skipped {
        rows.push(Json::obj(vec![
            ("sim_rank", Json::Null),
            ("ana_rank", Json::num(s.ana_rank as f64)),
            (
                "mapping",
                Json::obj(vec![
                    ("tp", Json::num(s.plan.mapping.par.tp as f64)),
                    ("pp", Json::num(s.plan.mapping.par.pp as f64)),
                    ("dp", Json::num(s.plan.mapping.par.dp as f64)),
                    ("microbatch_seqs", Json::num(s.plan.mapping.microbatch_seqs as f64)),
                    (
                        "experts_per_dp_rank",
                        Json::num(s.plan.mapping.moe.experts_per_dp_rank as f64),
                    ),
                ]),
            ),
            ("analytical_step_s", Json::num(s.plan.report.step_time)),
            ("skipped_code", Json::str(s.reason.code())),
            ("skipped_reason", Json::str(&s.reason.to_string())),
        ]));
    }
    Json::Arr(rows)
}

/// The simulated section of [`outcome_json`] — either a full
/// `--objective sim` run or a top-K `--rerank-sim` (distinguished by
/// `mode`; rerank passes `prefiltered == 0` and the K as `admitted`).
#[derive(Debug, Clone)]
pub struct SimSection<'a> {
    pub mode: &'a str,
    pub scored: &'a [SimScored],
    pub skipped: &'a [SkippedPlan],
    pub prefiltered: usize,
    pub margin: Option<f64>,
    /// Serial-equivalent skeleton-cache (hits, misses) when the run went
    /// through the cached path (`--objective sim`).
    pub cache: Option<(u64, u64)>,
}

impl<'a> SimSection<'a> {
    /// The section for a full-set [`SimPlan`].
    pub fn from_plan(sim: &'a SimPlan) -> SimSection<'a> {
        SimSection {
            mode: "objective-sim",
            scored: &sim.scored,
            skipped: &sim.skipped,
            prefiltered: sim.prefiltered,
            margin: Some(sim.margin),
            cache: Some((sim.cache_hits, sim.cache_misses)),
        }
    }

    /// The section for a top-K [`rerank_simulated`] result.
    pub fn from_rerank(scored: &'a [SimScored], skipped: &'a [SkippedPlan]) -> SimSection<'a> {
        SimSection { mode: "rerank-sim", scored, skipped, prefiltered: 0, margin: None, cache: None }
    }
}

/// The `"metrics"` object of `lumos plan --json`: search-space accounting
/// plus — when a simulated section is present — simulator work counters
/// summed over the scored rows in simulated-rank order (deterministic for
/// any `--jobs N`; cache reuse is the serial-equivalent replay, see
/// [`SimPlan::cache_hits`]).
pub fn outcome_metrics(outcome: &PlanOutcome, sim: Option<&SimSection<'_>>) -> crate::obs::Metrics {
    let mut m = crate::obs::Metrics::new();
    m.inc("enumerated", outcome.enumerated as u64);
    m.inc("pruned", outcome.pruned as u64);
    m.inc("feasible", (outcome.enumerated - outcome.pruned) as u64);
    m.inc("ranked", outcome.ranked.len() as u64);
    if let Some(s) = sim {
        m.inc("sim_scored", s.scored.len() as u64);
        m.inc("sim_skipped", s.skipped.len() as u64);
        m.inc("sim_prefiltered", s.prefiltered as u64);
        if let Some((hits, misses)) = s.cache {
            m.inc("sim_cache_hits", hits);
            m.inc("sim_cache_misses", misses);
        }
        for row in s.scored {
            m.inc("sim_dag_nodes", row.sim.nodes as u64);
            m.inc("sim_events", row.sim.events as u64);
            let d = &row.sim.dep;
            m.inc("sim_admitted_flows", d.admitted_flows);
            m.inc("sim_refills", d.refills);
            m.inc("sim_heap_settlements", d.settlements);
            m.inc("sim_heap_stale_pops", d.stale_pops);
            m.observe("sim_refill_component_flows_max", d.refill_flows_max as f64);
        }
    }
    m
}

/// Machine-readable form of a plan outcome (`lumos plan --json`):
/// mapping + timing per ranked plan, plus the search accounting
/// (enumerated / pruned / feasible) and the paper baseline when present.
/// When `sim` is set (`--objective sim` or `--rerank-sim`), a `simulated`
/// section carries the scored *and* skipped rows — JSON mode no longer
/// drops the simulator's answer. Keys are sorted (BTreeMap), so
/// serialization is deterministic and byte-identical for any worker count.
pub fn outcome_json(outcome: &PlanOutcome, sim: Option<&SimSection<'_>>) -> Json {
    let ranked: Vec<Json> = outcome
        .ranked
        .iter()
        .map(|p| {
            Json::obj(vec![
                (
                    "mapping",
                    Json::obj(vec![
                        ("tp", Json::num(p.mapping.par.tp as f64)),
                        ("pp", Json::num(p.mapping.par.pp as f64)),
                        ("dp", Json::num(p.mapping.par.dp as f64)),
                        ("microbatch_seqs", Json::num(p.mapping.microbatch_seqs as f64)),
                        (
                            "experts_per_dp_rank",
                            Json::num(p.mapping.moe.experts_per_dp_rank as f64),
                        ),
                    ]),
                ),
                ("step_time_s", Json::num(p.report.step_time)),
                ("time_to_train_s", Json::num(p.report.time_to_train_s)),
                (
                    "adjusted_time_to_train_s",
                    p.adjusted_ttt.map_or(Json::Null, resilience::num_or_null),
                ),
                ("comm_fraction", Json::num(p.report.comm_fraction)),
                ("achieved_mfu", Json::num(p.report.achieved_mfu)),
                ("hbm_utilization", Json::num(p.memory.utilization())),
                (
                    "ep_placement",
                    Json::str(&format!("{:?}", p.report.breakdown.ep_placement)),
                ),
            ])
        })
        .collect();
    let baseline = match &outcome.paper_baseline {
        Some(b) => Json::obj(vec![
            ("step_time_s", Json::num(b.step_time)),
            ("time_to_train_s", Json::num(b.time_to_train_s)),
        ]),
        None => Json::Null,
    };
    let mut fields = vec![
        ("cluster", Json::str(&outcome.cluster)),
        ("config", Json::str(&outcome.config_name)),
        ("enumerated", Json::num(outcome.enumerated as f64)),
        ("pruned", Json::num(outcome.pruned as f64)),
        ("feasible", Json::num((outcome.enumerated - outcome.pruned) as f64)),
        ("metrics", outcome_metrics(outcome, sim).to_json()),
        ("paper_baseline", baseline),
        ("ranked", Json::Arr(ranked)),
    ];
    if let Some(s) = sim {
        fields.push((
            "simulated",
            Json::obj(vec![
                ("mode", Json::str(s.mode)),
                ("scored", Json::num(s.scored.len() as f64)),
                ("skipped", Json::num(s.skipped.len() as f64)),
                ("prefiltered", Json::num(s.prefiltered as f64)),
                ("margin", s.margin.map_or(Json::Null, Json::num)),
                ("rows", sim_rows_json(s.scored, s.skipped)),
            ]),
        ));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(cluster: ClusterKey, cfg: usize) -> PlanRequest {
        PlanRequest::paper(cluster, cfg, &PerfKnobs::default())
    }

    #[test]
    fn plan_ranks_only_feasible_points_best_first() {
        // Config 1 has the heaviest per-expert state, so some enumerated
        // points genuinely exceed HBM and must be pruned.
        let out = plan(&req(ClusterKey::Passage512, 1), 2);
        assert!(out.pruned > 0, "expected HBM pruning on config 1");
        assert_eq!(out.enumerated, out.pruned + out.ranked.len());
        for p in &out.ranked {
            assert!(p.memory.fits());
        }
        for w in out.ranked.windows(2) {
            assert!(w[0].report.time_to_train_s <= w[1].report.time_to_train_s);
        }
    }

    #[test]
    fn top_k_truncates_after_ranking() {
        let full = plan(&req(ClusterKey::Passage512, 4), 2);
        let top3 = plan(&req(ClusterKey::Passage512, 4).with_top(3), 2);
        assert_eq!(top3.ranked.len(), 3);
        for (a, b) in full.ranked.iter().take(3).zip(&top3.ranked) {
            assert_eq!(a.mapping, b.mapping);
        }
        // accounting reflects the whole search, not the truncation
        assert_eq!(full.enumerated, top3.enumerated);
        assert_eq!(full.pruned, top3.pruned);
    }

    #[test]
    fn serial_and_parallel_plans_are_identical() {
        let r = req(ClusterKey::Electrical144, 4);
        let a = plan(&r, 1);
        let b = plan(&r, 4);
        assert_eq!(a.ranked.len(), b.ranked.len());
        for (x, y) in a.ranked.iter().zip(&b.ranked) {
            assert_eq!(x.mapping, y.mapping);
            assert_eq!(
                x.report.time_to_train_s.to_bits(),
                y.report.time_to_train_s.to_bits()
            );
        }
        assert_eq!(ranked_table(&a).render(), ranked_table(&b).render());
    }

    #[test]
    fn paper_baseline_follows_the_section6_precedent() {
        let knobs = PerfKnobs::default();
        let w = Workload::paper_gpt_4p7t(4);
        // exact size and the 1.5%-smaller electrical cluster: baseline exists
        for key in [ClusterKey::Passage512, ClusterKey::Electrical144] {
            assert!(paper_baseline(&w, &key.build(), &knobs).is_some(), "{key:?}");
        }
        // a cluster a quarter the size: the fixed mapping is not comparable
        let small = ClusterKey::custom(8_192, 512, 32_000.0).build();
        assert!(paper_baseline(&w, &small, &knobs).is_none());
    }

    #[test]
    fn outcome_json_is_deterministic_and_complete() {
        let r = req(ClusterKey::Passage512, 4).with_top(3);
        let a = outcome_json(&plan(&r, 1), None).to_string_pretty();
        let b = outcome_json(&plan(&r, 4), None).to_string_pretty();
        assert_eq!(a, b, "plan --json must be byte-identical across job counts");
        let j = Json::parse(&a).unwrap();
        assert_eq!(j.get("ranked").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("feasible").as_usize().unwrap(),
            j.get("enumerated").as_usize().unwrap() - j.get("pruned").as_usize().unwrap()
        );
        let top = j.get("ranked").at(0);
        assert!(top.get("time_to_train_s").as_f64().unwrap() > 0.0);
        assert!(top.get("mapping").get("tp").as_usize().unwrap() > 0);
        assert!(j.get("paper_baseline").get("step_time_s").as_f64().is_some());
        // the stable "metrics" key mirrors the search accounting
        let metrics = j.get("metrics");
        assert_eq!(
            metrics.get("enumerated").as_usize(),
            j.get("enumerated").as_usize()
        );
        assert_eq!(metrics.get("feasible").as_usize(), j.get("feasible").as_usize());
    }

    #[test]
    fn ranked_table_renders_mapping_columns() {
        let out = plan(&req(ClusterKey::Passage512, 4).with_top(5), 2);
        let r = ranked_table(&out).render();
        assert!(r.contains("TP"), "{r}");
        assert!(r.contains("vs paper map"), "{r}");
        assert!(r.contains("ScaleUp"), "{r}");
        assert_eq!(r.lines().count(), 3 + 5); // title + header + sep + 5 rows
    }

    #[test]
    fn availability_objective_ranks_on_adjusted_ttt() {
        let cluster = ClusterKey::Passage512.build();
        let obj = AvailabilityObjective::default_for(&cluster);
        let out = plan(
            &req(ClusterKey::Passage512, 4).with_top(8).with_availability(obj),
            2,
        );
        for p in &out.ranked {
            let adj = p.adjusted_ttt.expect("availability runs populate adjusted TTT");
            // failures only cost time
            assert!(adj > p.report.time_to_train_s, "{adj}");
            assert_eq!(p.objective_ttt().to_bits(), adj.to_bits());
        }
        for w in out.ranked.windows(2) {
            assert!(w[0].adjusted_ttt.unwrap() <= w[1].adjusted_ttt.unwrap());
        }
        // adjusted column renders; plain runs show the placeholder
        assert!(!ranked_table(&out).render().contains('—'));
        let plain = plan(&req(ClusterKey::Passage512, 4).with_top(2), 2);
        assert!(plain.ranked[0].adjusted_ttt.is_none());
    }

    #[test]
    fn dp_heavy_mappings_pay_more_under_availability() {
        // The PP=1/DP-heavy winner exposes a giant scale-out gradient sync;
        // a degraded NIC inflates it more than the paper mapping — its
        // availability-adjusted inflation must be strictly larger.
        use crate::model::MoeConfig;
        let knobs = PerfKnobs::default();
        let cluster = ClusterKey::Passage512.build();
        let obj = AvailabilityObjective::default_for(&cluster);
        let w = Workload::paper_gpt_4p7t(4);
        let inflation = |m: &Mapping| {
            let r = evaluate(&w, &cluster, m, &knobs);
            availability_adjusted_ttt(&w, &cluster, m, &knobs, &obj) / r.time_to_train_s
        };
        let paper = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(4));
        let moe = MoeConfig { experts_per_dp_rank: 4, ..MoeConfig::paper_config(4) };
        let dp_heavy = Mapping::try_new(Parallelism { tp: 8, pp: 1, dp: 4096 }, moe).unwrap();
        assert!(
            inflation(&dp_heavy) > inflation(&paper),
            "{} vs {}",
            inflation(&dp_heavy),
            inflation(&paper)
        );
    }

    #[test]
    fn rerank_simulated_is_deterministic_and_exposes_optimism() {
        let knobs = PerfKnobs::default();
        let out = plan(&req(ClusterKey::Passage512, 4).with_top(3), 2);
        let cluster = ClusterKey::Passage512.build();
        let w = Workload::paper_gpt_4p7t(4);
        let (scored, skipped) = rerank_simulated(&out, 3, &w, &cluster, &knobs);
        assert_eq!(scored.len() + skipped.len(), 3);
        assert!(!scored.is_empty(), "all top plans skipped");
        for s in &scored {
            assert!(s.sim.step_time > 0.0 && s.ana_rank >= 1);
        }
        for pair in scored.windows(2) {
            assert!(pair[0].sim.time_to_train_s <= pair[1].sim.time_to_train_s);
        }
        // the planner's winners lean on the overlap credits: the simulator
        // runs them slower (EXPERIMENTS.md §Validate)
        assert!(scored.iter().any(|s| s.gap() > 0.0));
        let (again, again_skipped) = rerank_simulated(&out, 3, &w, &cluster, &knobs);
        assert_eq!(
            rerank_table(&scored, &skipped).render(),
            rerank_table(&again, &again_skipped).render()
        );
        assert!(rerank_table(&scored, &skipped).render().contains("sim step"));
    }

    #[test]
    fn plan_simulated_scores_the_admitted_set_deterministically() {
        let knobs = PerfKnobs::default();
        let out = plan(&req(ClusterKey::Passage512, 4), 2);
        let cluster = ClusterKey::Passage512.build();
        let w = Workload::paper_gpt_4p7t(4);
        let feasible = out.ranked.len();
        // a tight margin keeps the unit test fast; the CLI smoke runs the
        // default margin over the full feasible set
        let sim1 = plan_simulated(&out, &w, &cluster, &knobs, 0.25, 1);
        let sim4 = plan_simulated(&out, &w, &cluster, &knobs, 0.25, 4);
        // accounting: every feasible plan is either scored, skipped, or
        // prefiltered — nothing vanishes
        assert_eq!(sim1.admitted() + sim1.prefiltered, feasible);
        assert!(!sim1.scored.is_empty());
        // worker count cannot change a byte of the output
        assert_eq!(sim_table(&sim1, 0).render(), sim_table(&sim4, 0).render());
        assert_eq!(
            outcome_json(&out, Some(&SimSection::from_plan(&sim1))).to_string_pretty(),
            outcome_json(&out, Some(&SimSection::from_plan(&sim4))).to_string_pretty()
        );
        // cache accounting is the jobs-invariant serial replay: every
        // simulatable candidate is either a hit or a miss
        assert_eq!(sim1.cache_hits + sim1.cache_misses, sim1.scored.len() as u64);
        assert_eq!((sim4.cache_hits, sim4.cache_misses), (sim1.cache_hits, sim1.cache_misses));
        let j = outcome_json(&out, Some(&SimSection::from_plan(&sim1)));
        let metrics = j.get("metrics");
        assert_eq!(metrics.get("sim_cache_hits").as_usize(), Some(sim1.cache_hits as usize));
        assert!(metrics.get("sim_events").as_f64().unwrap_or(0.0) > 0.0);
        // ranked on simulated TTT
        for pair in sim1.scored.windows(2) {
            assert!(pair[0].sim.time_to_train_s <= pair[1].sim.time_to_train_s);
        }
        // agrees point-for-point with the serial top-K re-rank
        let k = sim1.scored.len().min(3);
        let (rr, _) = rerank_simulated(&out, k, &w, &cluster, &knobs);
        for (a, b) in sim1.scored.iter().zip(rr.iter().take(k)) {
            if a.plan.mapping == b.plan.mapping {
                assert_eq!(a.sim.step_time.to_bits(), b.sim.step_time.to_bits());
            }
        }
    }

    #[test]
    fn sim_prefilter_margin_widens_the_admitted_set() {
        let knobs = PerfKnobs::default();
        let out = plan(&req(ClusterKey::Passage512, 4), 2);
        let cluster = ClusterKey::Passage512.build();
        let w = Workload::paper_gpt_4p7t(4);
        let tight = plan_simulated(&out, &w, &cluster, &knobs, 0.02, 2);
        let wide = plan_simulated(&out, &w, &cluster, &knobs, 0.3, 2);
        assert!(tight.admitted() <= wide.admitted());
        assert!(tight.prefiltered >= wide.prefiltered);
        // the analytical winner is always admitted (its TTT is the cutoff
        // baseline), so neither scored set is empty
        assert!(!tight.scored.is_empty() && !wide.scored.is_empty());
        // accounting: admitted + prefiltered always covers the feasible set
        for sim in [&tight, &wide] {
            assert_eq!(sim.admitted() + sim.prefiltered, out.ranked.len());
        }
        // a wider margin can only improve (or tie) the simulated winner
        let best_tight = tight.scored[0].sim.time_to_train_s;
        let best_wide = wide.scored[0].sim.time_to_train_s;
        assert!(best_wide <= best_tight);
    }

    #[test]
    fn outcome_json_sim_section_carries_scored_and_skipped_rows() {
        let knobs = PerfKnobs::default();
        let out = plan(&req(ClusterKey::Passage512, 4).with_top(3), 2);
        let cluster = ClusterKey::Passage512.build();
        let w = Workload::paper_gpt_4p7t(4);
        let (scored, skipped) = rerank_simulated(&out, 3, &w, &cluster, &knobs);
        let j = outcome_json(&out, Some(&SimSection::from_rerank(&scored, &skipped)));
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        let sim = parsed.get("simulated");
        assert_eq!(sim.get("mode").as_str(), Some("rerank-sim"));
        assert_eq!(
            sim.get("rows").as_arr().unwrap().len(),
            scored.len() + skipped.len()
        );
        let row0 = sim.get("rows").at(0);
        assert!(row0.get("simulated_step_s").as_f64().unwrap() > 0.0);
        assert!(row0.get("gap").as_f64().is_some());
        // without sim results the key is absent (old shape preserved)
        let plain = Json::parse(&outcome_json(&out, None).to_string_pretty()).unwrap();
        assert!(matches!(plain.get("simulated"), Json::Null));
    }

    #[test]
    fn rerank_surfaces_skipped_plans_instead_of_dropping_them() {
        // Build an outcome whose only plan exceeds even the lifted DAG cap
        // (a degenerate lowering); the re-rank must keep it visible as a
        // SkippedPlan row, not silently shrink the table.
        use crate::model::MoeConfig;
        let knobs = PerfKnobs::default();
        let cluster = ClusterKey::Passage512.build();
        let w = Workload::paper_gpt_4p7t(4);
        let huge = Mapping::try_with_microbatch(
            Parallelism { tp: 64, pp: 120, dp: 32 },
            MoeConfig::paper_config(4),
            1,
        )
        .unwrap();
        let report = evaluate(&w, &cluster, &huge, &knobs);
        let memory = crate::perf::memory::memory_breakdown(&w, &huge);
        let outcome = PlanOutcome {
            cluster: cluster.spec.name.clone(),
            config_name: report.config_name.clone(),
            enumerated: 1,
            pruned: 0,
            ranked: vec![RankedPlan { mapping: huge.clone(), memory, report, adjusted_ttt: None }],
            paper_baseline: None,
        };
        let (scored, skipped) = rerank_simulated(&outcome, 1, &w, &cluster, &knobs);
        assert!(scored.is_empty());
        assert_eq!(skipped.len(), 1);
        assert_eq!(skipped[0].ana_rank, 1);
        assert!(
            skipped[0].reason.to_string().contains("too large"),
            "{}",
            skipped[0].reason
        );
        assert_eq!(skipped[0].reason.code(), "dag-too-large");
        // the typed reason renders identically in JSON
        let j = outcome_json(&outcome, Some(&SimSection::from_rerank(&scored, &skipped)));
        let row = j.get("simulated").get("rows").at(0);
        assert_eq!(row.get("skipped_code").as_str(), Some("dag-too-large"));
        assert_eq!(
            row.get("skipped_reason").as_str(),
            Some(skipped[0].reason.to_string().as_str())
        );
        let rendered = rerank_table(&scored, &skipped).render();
        assert!(rendered.contains("skipped"), "{rendered}");
        assert!(rendered.contains("120"), "{rendered}");
        let lines = rerank_skip_lines(&skipped);
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("TP64xPP120xDP32"), "{}", lines[0]);
    }
}
