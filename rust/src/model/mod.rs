//! Analytical transformer/MoE workload costing (paper §V.A, §VI).
//!
//! Decomposes the model into parameter counts, FLOPs (attention + routed
//! expert FFN, forward and backward), routed communication volumes, and
//! memory footprints. The paper's base model: 120 layers, d_model 12288,
//! 128 heads, GPT-style, 4.7 T total parameters in every MoE config (total
//! expert capacity E × d_ff/m is invariant across Table IV's configs).

/// MoE structure of one transformer layer (Table IV row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoeConfig {
    /// Total (fine-grained) experts per layer.
    pub total_experts: usize,
    /// Experts activated per token (top-k).
    pub active_per_token: usize,
    /// Fine-grained segmentation factor m: each original expert of hidden
    /// size `d_ff_base` is split into m experts of `d_ff_base/m`.
    pub granularity: usize,
    /// Experts co-located on one DP rank (Fig. 9b).
    pub experts_per_dp_rank: usize,
}

impl MoeConfig {
    /// Table IV, Configs 1–4.
    pub fn paper_config(i: usize) -> MoeConfig {
        match i {
            1 => MoeConfig { total_experts: 32, active_per_token: 1, granularity: 1, experts_per_dp_rank: 1 },
            2 => MoeConfig { total_experts: 64, active_per_token: 2, granularity: 2, experts_per_dp_rank: 2 },
            3 => MoeConfig { total_experts: 128, active_per_token: 4, granularity: 4, experts_per_dp_rank: 4 },
            4 => MoeConfig { total_experts: 256, active_per_token: 8, granularity: 8, experts_per_dp_rank: 8 },
            // lumos: allow(panic-path) -- documented contract; CLI paths range-check --config first
            _ => panic!("paper configs are 1..=4"),
        }
    }

    /// DP ranks holding one complete set of experts (EP group width in DP
    /// dimension): E / experts-per-rank. 32 for every paper config.
    pub fn ep_dp_ranks(&self) -> usize {
        assert!(self.total_experts % self.experts_per_dp_rank == 0);
        self.total_experts / self.experts_per_dp_rank
    }
}

/// Transformer architecture + training workload.
#[derive(Debug, Clone)]
pub struct Workload {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// Hidden size of one *original* (m=1) expert FFN (4·d_model).
    pub d_ff_base: usize,
    pub vocab: usize,
    pub seq_len: usize,
    /// Global batch size in sequences.
    pub global_batch: usize,
    /// Training corpus size in tokens (13 T in the paper).
    pub target_tokens: f64,
    /// Bytes per element (BF16 = 2).
    pub dtype_bytes: f64,
    pub moe: MoeConfig,
}

impl Workload {
    /// §VI base architecture with the given Table IV config.
    pub fn paper_gpt_4p7t(cfg_index: usize) -> Workload {
        Workload {
            n_layers: 120,
            d_model: 12_288,
            n_heads: 128,
            d_ff_base: 4 * 12_288,
            vocab: 100_000,
            seq_len: 8_192,
            global_batch: 4_096,
            target_tokens: 13e12,
            dtype_bytes: 2.0,
            moe: MoeConfig::paper_config(cfg_index),
        }
    }

    /// Fine-grained expert hidden dim: d_ff_base / m.
    pub fn d_ff_expert(&self) -> usize {
        assert!(self.d_ff_base % self.moe.granularity == 0);
        self.d_ff_base / self.moe.granularity
    }

    pub fn tokens_per_batch(&self) -> f64 {
        (self.global_batch * self.seq_len) as f64
    }

    pub fn steps_to_target(&self) -> f64 {
        self.target_tokens / self.tokens_per_batch()
    }

    // -- parameters ---------------------------------------------------------

    /// Attention parameters per layer (QKVO projections).
    pub fn attn_params_per_layer(&self) -> f64 {
        4.0 * (self.d_model * self.d_model) as f64
    }

    /// All experts of one layer (weights only; biases negligible).
    pub fn expert_params_per_layer(&self) -> f64 {
        self.moe.total_experts as f64 * 2.0 * (self.d_model * self.d_ff_expert()) as f64
    }

    pub fn router_params_per_layer(&self) -> f64 {
        (self.d_model * self.moe.total_experts) as f64
    }

    pub fn embedding_params(&self) -> f64 {
        (self.vocab * self.d_model) as f64
    }

    /// Total model parameters.
    pub fn total_params(&self) -> f64 {
        self.n_layers as f64
            * (self.attn_params_per_layer()
                + self.expert_params_per_layer()
                + self.router_params_per_layer())
            + self.embedding_params()
    }

    /// Parameters touched per token (dense attention + k active experts).
    pub fn active_params_per_token(&self) -> f64 {
        self.n_layers as f64
            * (self.attn_params_per_layer()
                + self.moe.active_per_token as f64 * 2.0
                    * (self.d_model * self.d_ff_expert()) as f64
                + self.router_params_per_layer())
            + self.embedding_params()
    }

    // -- FLOPs --------------------------------------------------------------

    /// Forward matmul FLOPs per token for one layer's attention block:
    /// QKVO projections + score/context matmuls (sequence-quadratic part
    /// amortized per token at full seq_len).
    pub fn attn_flops_per_token_layer(&self) -> f64 {
        let proj = 2.0 * 4.0 * (self.d_model * self.d_model) as f64;
        // QK^T and PV: 2 matmuls of [s, dh] x [dh, s] per head ->
        // per token: 2 * 2 * s * d_model (causal halves it).
        let attn = 2.0 * 2.0 * self.seq_len as f64 * self.d_model as f64 / 2.0;
        proj + attn
    }

    /// Forward FLOPs per token for one layer's routed expert FFN.
    pub fn expert_flops_per_token_layer(&self) -> f64 {
        self.moe.active_per_token as f64
            * 2.0 * 2.0 * (self.d_model * self.d_ff_expert()) as f64
    }

    /// Total forward FLOPs per token (all layers + LM head).
    pub fn fwd_flops_per_token(&self) -> f64 {
        self.n_layers as f64
            * (self.attn_flops_per_token_layer() + self.expert_flops_per_token_layer())
            + 2.0 * self.embedding_params()
    }

    /// Training FLOPs per token (fwd + 2× bwd).
    pub fn train_flops_per_token(&self) -> f64 {
        3.0 * self.fwd_flops_per_token()
    }

    // -- communication volumes ---------------------------------------------

    /// Bytes a token occupies on the wire (its d_model activation vector).
    pub fn token_bytes(&self) -> f64 {
        self.d_model as f64 * self.dtype_bytes
    }

    /// EP all-to-all payload per token per layer per direction:
    /// the token is sent to each of its k experts (dispatch), and the k
    /// partial outputs return (combine).
    pub fn a2a_bytes_per_token_layer(&self) -> f64 {
        self.moe.active_per_token as f64 * self.token_bytes()
    }

    // -- memory --------------------------------------------------------------

    /// Bytes of parameter + gradient + Adam state per parameter
    /// (BF16 param+grad, FP32 moments ≈ 2+2+4+4 = 12; paper-agnostic).
    pub fn state_bytes_per_param(&self) -> f64 {
        12.0
    }

    /// Activation bytes per token per layer kept for backward
    /// (post-attention + post-FFN residuals, ~4 tensors of d_model).
    pub fn activation_bytes_per_token_layer(&self) -> f64 {
        4.0 * self.token_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_total_is_4p7t_for_all_configs() {
        for i in 1..=4 {
            let w = Workload::paper_gpt_4p7t(i);
            let p = w.total_params();
            assert!((p / 1e12 - 4.7).abs() < 0.1, "config {i}: {p}");
        }
    }

    #[test]
    fn total_params_invariant_across_granularity() {
        let p1 = Workload::paper_gpt_4p7t(1).total_params();
        let p4 = Workload::paper_gpt_4p7t(4).total_params();
        // E·d_ff/m is constant; only the (tiny) router grows with E.
        assert!((p1 - p4).abs() / p1 < 1e-3);
    }

    #[test]
    fn active_params_constant_compute_by_design() {
        // §V.C: k grows with m so active compute stays constant.
        let a1 = Workload::paper_gpt_4p7t(1).active_params_per_token();
        let a4 = Workload::paper_gpt_4p7t(4).active_params_per_token();
        // only the (tiny) router d_model×E term grows with config index
        assert!((a1 - a4).abs() / a1 < 2e-3);
        // ~218G active of 4.7T total => sparsity ~21x
        assert!(a1 > 2.0e11 && a1 < 2.6e11, "{a1}");
    }

    #[test]
    fn flops_scale_sanity() {
        let w = Workload::paper_gpt_4p7t(1);
        // 6·active_params is the classic estimate; our explicit count adds
        // the attention-score term, so it must be >= and within 2x.
        let classic = 6.0 * w.active_params_per_token();
        let ours = w.train_flops_per_token();
        assert!(ours >= classic * 0.9 && ours < classic * 2.0, "{ours} vs {classic}");
    }

    #[test]
    fn a2a_volume_scales_with_k() {
        let v1 = Workload::paper_gpt_4p7t(1).a2a_bytes_per_token_layer();
        let v4 = Workload::paper_gpt_4p7t(4).a2a_bytes_per_token_layer();
        assert!((v4 / v1 - 8.0).abs() < 1e-9);
        // One token at 12288 bf16 = 24.6 KB.
        assert!((v1 - 24_576.0).abs() < 1e-9);
    }

    #[test]
    fn ep_dp_ranks_is_32_for_all_paper_configs() {
        for i in 1..=4 {
            assert_eq!(MoeConfig::paper_config(i).ep_dp_ranks(), 32);
        }
    }

    #[test]
    fn steps_to_13t_tokens() {
        let w = Workload::paper_gpt_4p7t(1);
        // 13e12 / (4096*8192) ≈ 387k steps
        assert!((w.steps_to_target() - 387_430.0).abs() < 1_000.0);
    }
}
