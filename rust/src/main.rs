//! `lumos` — CLI entrypoint for the LUMOS co-design framework.
//!
//! Subcommands:
//! - `figures`  regenerate the paper's tables/figures (+ablations)
//! - `model`    evaluate the analytical perf model on one configuration
//! - `sweep`    pod/bandwidth/granularity/grid sweeps (`--jobs N` fans the
//!   evaluation grid over a worker pool; output is identical for any N)
//! - `plan`     search the full (TP, PP, DP, microbatch, experts/rank)
//!   mapping space for a cluster and rank the feasible mappings (`--json`
//!   for machine-readable output)
//! - `validate` discrete-event simulation of a full training step vs the
//!   analytical model (`--plan-top K` cross-checks the planner's best
//!   mappings; `--deep` sweeps the deep-PP × fine-microbatch grid the
//!   pre-incremental engine rejected; `--json` for machine-readable
//!   output)
//! - `resilience` failure-aware effective time-to-train: FIT rates →
//!   failure traces → degraded fabrics → availability-adjusted goodput
//!   (`--seed`/`--trials` seeded Monte Carlo, byte-identical for any
//!   `--jobs`; `--degrade simulated|analytical` picks timeline-measured
//!   vs closed-form degraded-step pricing)
//! - `netsim`   validate Hockney collectives against the packet simulator
//! - `hw`       hardware design-space numbers (energy/area/power)
//! - `train`    run real MoE training from AOT artifacts (single or DP;
//!   `--preset host` uses the in-process host-math backend)
//! - `run`      execute the planner's winning mapping as a flight-recorded
//!   host-backend miniature and report the three-way per-phase gap:
//!   analytical vs simulated vs executed (`--trace exec.json` writes the
//!   merged per-rank recording as a Chrome trace; `--chaos`/`--faults`
//!   inject a seeded deterministic fault plan, supervise the recovery —
//!   checkpoint rewind, DP-replica retirement, message repair — and
//!   report executed vs modeled recovery next to the resilience model)
//! - `trace`    deterministic Chrome/Perfetto trace of one simulated
//!   training step (`--out step.json`, loadable at ui.perfetto.dev;
//!   byte-identical for any `--jobs`; `--check <file>` runs the in-tree
//!   schema checker over an existing trace instead; `--diff A B` aligns
//!   two trace artifacts and reports per-phase share deltas)
//! - `lint`     determinism & concurrency static analysis over the repo's
//!   own sources (non-zero exit on findings; `--json` for the CI gate;
//!   `--audit-wallclock` additionally fails on host-clock reads outside
//!   the allowlisted modules, annotated or not)
//!
//! `plan`, `validate` and `resilience` also take `--trace <path>` to write
//! the trace of the point they ran (the winning mapping's step, the first
//! validated mapping's step, a seeded failure/repair/checkpoint timeline).

use std::process::ExitCode;

use anyhow::Context as _;
use lumos::analysis;
use lumos::config;
use lumos::perf::{evaluate_feasible, PerfKnobs};
use lumos::planner;
use lumos::runtime::{artifacts_root, Artifact, Engine};
use lumos::sweep;
use lumos::sweep::engine::{ClusterCache, ClusterKey};
use lumos::trainer;
use lumos::util::cli::{Args, Command};
use lumos::util::json::Json;
use lumos::util::stats::fmt_time;
use lumos::util::table::Table;

fn cli() -> Command {
    Command::new("lumos", "MoE training over 3D integrated optics — HOTI'25 reproduction")
        .sub(
            Command::new("figures", "regenerate paper tables & figures")
                .flag("all", "print everything")
                .flag("table1", "Table I")
                .flag("table2", "Table II")
                .flag("table3", "Table III")
                .flag("table4", "Table IV")
                .flag("fig7", "Figure 7 (power)")
                .flag("fig8", "Figure 8 (area)")
                .flag("fig10", "Figure 10 (same radix)")
                .flag("fig11", "Figure 11 (system radix)")
                .flag("breakdown", "step-time breakdown (Config 4)")
                .flag("ablations", "extra ablation tables")
                .flag("planner", "planner artifacts (best mapping per cluster, gap ablation)")
                .flag("validate", "analytical-vs-simulated step gap table (timeline)")
                .flag("resilience", "availability-adjusted TTT + laser-serviceability tables")
                .opt_default("jobs", "worker threads for the evaluation grids", "1"),
        )
        .sub(
            Command::new("model", "evaluate the analytical model")
                .opt_default("cluster", "passage-512 | electrical-512 | electrical-144", "passage-512")
                .opt_default("config", "MoE config index 1..4", "4")
                .opt("knobs", "JSON file with calibration knob overrides")
                .opt("workload", "JSON file with workload overrides")
                .opt("microbatch", "sequences per 1F1B microbatch (default 1)")
                .flag("breakdown", "print the per-component breakdown"),
        )
        .sub(
            Command::new("sweep", "parameter sweeps (parallel design-space exploration)")
                .opt_default(
                    "kind",
                    "pod | bandwidth | granularity | grid | topology | routing",
                    "pod",
                )
                .opt_default("jobs", "worker threads for the evaluation grid", "1")
                .opt("pods", "grid kind: comma-separated pod sizes (e.g. 64,144,512)")
                .opt("bandwidths", "grid kind: comma-separated scale-up Gb/s (e.g. 14400,32000)")
                .opt_default("config", "grid kind: MoE config index 1..4", "4")
                .opt("csv", "also write the result grid to this CSV file"),
        )
        .sub(
            Command::new("plan", "search the 4D mapping space for a cluster")
                .opt(
                    "cluster",
                    "passage-512 | electrical-512 | electrical-144 (default passage-512)",
                )
                .opt("gpus", "custom cluster: total GPUs (with --pod-size and --gbps)")
                .opt("pod-size", "custom cluster: GPUs per scale-up pod")
                .opt("gbps", "custom cluster: scale-up Gb/s per GPU")
                .opt_default("config", "MoE config index 1..4", "4")
                .opt_default("top", "ranked mappings to print (0 = all feasible)", "10")
                .opt_default("jobs", "worker threads for the scoring grid", "1")
                .opt("knobs", "JSON file with calibration knob overrides")
                .opt("csv", "also write the ranked plan to this CSV file")
                .opt("rerank-sim", "re-rank the top K plans on simulated step time")
                .opt_default(
                    "objective",
                    "ranking objective: ttt (analytical) | sim (simulate the feasible set)",
                    "ttt",
                )
                .opt(
                    "sim-margin",
                    "sim objective: simulate candidates within (1+margin)x of the best \
                     analytical TTT (default 1.25; inf disables the prefilter)",
                )
                .flag("availability", "rank on failure-adjusted effective TTT (resilience)")
                .opt("trace", "write a Chrome/Perfetto trace of the winner's simulated step here")
                .flag("json", "machine-readable output (util::json, deterministic)"),
        )
        .sub(
            Command::new(
                "validate",
                "discrete-event step simulation vs the analytical model",
            )
            .opt(
                "cluster",
                "passage-512 | electrical-512 | electrical-144 (default passage-512)",
            )
            .opt("gpus", "custom cluster: total GPUs (with --pod-size and --gbps)")
            .opt("pod-size", "custom cluster: GPUs per scale-up pod")
            .opt("gbps", "custom cluster: scale-up Gb/s per GPU")
            .opt_default("config", "MoE config index 1..4", "4")
            .opt_default("plan-top", "also validate the planner's top K mappings", "0")
            .opt_default(
                "deep-top",
                "mappings per --deep grid (deep-PP region, smallest DAG first)",
                "3",
            )
            .opt_default("jobs", "worker threads for the planner scoring grid", "1")
            .opt("knobs", "JSON file with calibration knob overrides")
            .opt("csv", "also write the validation table to this CSV file")
            .flag(
                "deep",
                "also validate the deep-PP x fine-microbatch grid the pre-incremental \
                 engine rejected (DAG estimate > 300k nodes)",
            )
            .opt(
                "trace",
                "write a Chrome/Perfetto trace of the first validated mapping's step here",
            )
            .flag("json", "machine-readable output (util::json, deterministic)"),
        )
        .sub(
            Command::new(
                "resilience",
                "failure-aware effective time-to-train (FIT rates -> goodput)",
            )
            .opt(
                "cluster",
                "passage-512 | electrical-512 | electrical-144 (default: the paired \
                 Passage-vs-Electrical-144 headline comparison)",
            )
            .opt("gpus", "custom cluster: total GPUs (with --pod-size and --gbps)")
            .opt("pod-size", "custom cluster: GPUs per scale-up pod")
            .opt("gbps", "custom cluster: scale-up Gb/s per GPU")
            .opt("config", "MoE config index 1..4 (default: all four)")
            .opt("tech", "passage | cpo | electrical | pluggable (default: by cluster)")
            .opt_default(
                "degrade",
                "degraded-step pricing: simulated (timeline-measured ratios) | analytical",
                "simulated",
            )
            .opt_default("seed", "Monte Carlo seed", "7")
            .opt_default("trials", "Monte Carlo trials (0 = closed form only)", "128")
            .opt_default("jobs", "worker threads for the trial pool", "1")
            .opt("knobs", "JSON file with calibration knob overrides")
            .opt("csv", "also write the result table to this CSV file")
            .opt(
                "trace",
                "write a Chrome/Perfetto failure/repair/checkpoint trace (seeded, 48h \
                 horizon) here",
            )
            .flag("json", "machine-readable output (util::json, deterministic)"),
        )
        .sub(
            Command::new(
                "trace",
                "deterministic Chrome/Perfetto trace of one simulated training step",
            )
            .opt(
                "cluster",
                "passage-512 | electrical-512 | electrical-144 (default passage-512)",
            )
            .opt("gpus", "custom cluster: total GPUs (with --pod-size and --gbps)")
            .opt("pod-size", "custom cluster: GPUs per scale-up pod")
            .opt("gbps", "custom cluster: scale-up Gb/s per GPU")
            .opt_default("config", "MoE config index 1..4", "4")
            .opt_default(
                "jobs",
                "accepted for interface uniformity (the trace build is serial; output is \
                 byte-identical for any value)",
                "1",
            )
            .opt("knobs", "JSON file with calibration knob overrides")
            .opt("out", "write the Chrome trace-event JSON here (omit for the summary only)")
            .opt("profile", "also write wall-clock stage timings (BENCH-style side file) here")
            .opt("check", "schema-check an existing trace file and exit (CI smoke path)")
            .flag(
                "diff",
                "diff two trace files given as positionals (simulated vs executed, or \
                 any pair) and exit",
            )
            .flag("json", "with --diff: machine-readable diff (util::json, deterministic)")
            .flag("events", "include per-flow admit/settle/finish instants (large traces)"),
        )
        .sub(
            Command::new("netsim", "discrete-event fabric validation")
                .flag("validate", "compare Hockney model vs simulation"),
        )
        .sub(Command::new("hw", "hardware design-space summary"))
        .sub(
            Command::new("train", "run real AOT-compiled MoE training")
                .opt_default(
                    "preset",
                    "artifact preset (tiny | e2e | host — host needs no AOT artifacts)",
                    "tiny",
                )
                .opt_default("steps", "training steps", "50")
                .opt_default("workers", "data-parallel workers (1 = fused single)", "1")
                .opt_default("seed", "rng seed", "42")
                .opt("csv", "write the loss curve to this CSV file"),
        )
        .sub(
            Command::new(
                "run",
                "execute the planner's mapping as a flight-recorded host miniature",
            )
            .opt(
                "cluster",
                "passage-512 | electrical-512 | electrical-144 (default passage-512)",
            )
            .opt("gpus", "custom cluster: total GPUs (with --pod-size and --gbps)")
            .opt("pod-size", "custom cluster: GPUs per scale-up pod")
            .opt("gbps", "custom cluster: scale-up Gb/s per GPU")
            .opt_default("config", "MoE config index 1..4", "4")
            .opt_default("ranks", "miniature fabric size (worker threads)", "4")
            .opt_default("steps", "training steps to execute", "4")
            .opt_default("micro", "1F1B microbatches per step", "2")
            .opt_default("seed", "rng seed", "42")
            .opt_default("jobs", "worker threads for the planner scoring grid", "1")
            .opt("pp", "override the miniature pipeline depth (must divide --ranks)")
            .opt("knobs", "JSON file with calibration knob overrides")
            .opt(
                "trace",
                "write the merged per-rank flight recording (Chrome trace JSON) here",
            )
            .flag(
                "chaos",
                "inject a seeded fault plan and supervise recovery \
                 (default spec crash=1,drop=1,stall=1)",
            )
            .opt(
                "faults",
                "chaos fault spec, e.g. crash=1,drop=2,stall=1 \
                 (kinds: stall|crash|hang|drop|corrupt|degrade; implies --chaos)",
            )
            .opt_default("ckpt-every", "chaos in-memory checkpoint cadence (steps)", "2")
            .flag("verbose", "per-step progress to stderr")
            .flag("json", "machine-readable output (wall-clock values live only under \
                 executed keys: report, executed phases, metrics)"),
        )
        .sub(
            Command::new("lint", "determinism & concurrency static analysis")
                .opt("rule", "run only this rule id (repeatable; see --list)")
                .opt_default("jobs", "worker threads for the file scan", "1")
                .flag(
                    "audit-wallclock",
                    "also fail on host-clock reads outside the allowlisted modules, \
                     even when annotated",
                )
                .flag("json", "machine-readable report (util::json, deterministic)")
                .flag("list", "list the rule registry and exit"),
        )
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let root = cli();
    match root.parse(&argv) {
        Err(help_or_err) => {
            println!("{help_or_err}");
            ExitCode::from(u8::from(!help_or_err.contains("USAGE")))
        }
        Ok((chain, args)) => match run(chain.first().map(String::as_str), &args) {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                eprintln!("error: {e:#}");
                ExitCode::FAILURE
            }
        },
    }
}

fn run(sub: Option<&str>, args: &Args) -> anyhow::Result<()> {
    match sub {
        Some("figures") => figures(args),
        Some("model") => model(args),
        Some("sweep") => sweep_cmd(args),
        Some("plan") => plan_cmd(args),
        Some("validate") => validate_cmd(args),
        Some("resilience") => resilience_cmd(args),
        Some("trace") => trace_cmd(args),
        Some("netsim") => netsim_cmd(),
        Some("hw") => {
            let (t7, _) = sweep::fig7();
            let (t8, _) = sweep::fig8();
            for t in [sweep::table2(), sweep::table3()] {
                println!("{}", t.render());
            }
            println!("{}", t7.render());
            println!("{}", t8.render());
            Ok(())
        }
        Some("train") => train(args),
        Some("run") => run_cmd(args),
        Some("lint") => lint_cmd(args),
        _ => {
            println!("{}", cli().help_text());
            Ok(())
        }
    }
}

fn figures(args: &Args) -> anyhow::Result<()> {
    let knobs = PerfKnobs::default();
    let jobs = args.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1);
    // One cluster cache for the whole command: every selected figure's grid
    // shares cluster construction.
    let cache = ClusterCache::new();
    let all = args.flag("all")
        || !["table1", "table2", "table3", "table4", "fig7", "fig8", "fig10", "fig11",
             "breakdown", "ablations", "planner", "validate", "resilience"]
            .iter()
            .any(|f| args.flag(f));
    if all {
        print!("{}", sweep::render_all_cached(&knobs, jobs, &cache));
        return Ok(());
    }
    if args.flag("table1") {
        println!("{}", sweep::table1().render());
    }
    if args.flag("table2") {
        println!("{}", sweep::table2().render());
    }
    if args.flag("table3") {
        println!("{}", sweep::table3().render());
    }
    if args.flag("table4") {
        println!("{}", sweep::table4().render());
    }
    if args.flag("fig7") {
        let (t, c) = sweep::fig7();
        println!("{}\n{}", t.render(), c.render());
    }
    if args.flag("fig8") {
        let (t, c) = sweep::fig8();
        println!("{}\n{}", t.render(), c.render());
    }
    if args.flag("fig10") {
        let (t, c) = sweep::fig10_cached(&knobs, jobs, &cache);
        println!("{}\n{}", t.render(), c.render());
    }
    if args.flag("fig11") {
        let (t, c) = sweep::fig11_cached(&knobs, jobs, &cache);
        println!("{}\n{}", t.render(), c.render());
    }
    if args.flag("breakdown") {
        println!("{}", sweep::breakdown_table_cached(&knobs, &cache).render());
    }
    if args.flag("ablations") {
        for t in [
            sweep::pod_size_sweep_cached(&knobs, jobs, &cache),
            sweep::bandwidth_sweep_cached(&knobs, jobs, &cache),
            sweep::granularity_sweep_cached(&knobs, jobs, &cache),
            sweep::topology_ablation(),
            sweep::routing_restriction_ablation(),
        ] {
            println!("{}", t.render());
        }
    }
    if args.flag("planner") {
        let (best, gap) = sweep::planner_tables_cached(&knobs, jobs, &cache);
        println!("{}", best.render());
        println!("{}", gap.render());
    }
    if args.flag("validate") {
        println!("{}", sweep::validate_gap_table_cached(&knobs, &cache).render());
    }
    if args.flag("resilience") {
        let (speedup, service) = sweep::resilience_tables_cached(&knobs, &cache);
        println!("{}", speedup.render());
        println!("{}", service.render());
    }
    Ok(())
}

fn model(args: &Args) -> anyhow::Result<()> {
    let cluster = config::cluster_preset(args.get("cluster").unwrap_or("passage-512"))?;
    let cfg_idx = args.get_usize("config").map_err(anyhow::Error::msg)?.unwrap_or(4);
    anyhow::ensure!((1..=4).contains(&cfg_idx), "--config must be 1..4, got {cfg_idx}");
    let (knobs, json_microbatch) = match args.get("knobs") {
        Some(path) => {
            let j = Json::parse(&std::fs::read_to_string(path)?).map_err(anyhow::Error::msg)?;
            (config::knobs_from_json(&j), config::microbatch_from_json(&j))
        }
        None => (PerfKnobs::default(), None),
    };
    let workload = match args.get("workload") {
        Some(path) => config::workload_from_json(
            &Json::parse(&std::fs::read_to_string(path)?).map_err(anyhow::Error::msg)?,
        )?,
        None => lumos::model::Workload::paper_gpt_4p7t(cfg_idx),
    };
    // CLI --microbatch wins over a JSON microbatch_seqs override.
    let microbatch = match args.get_usize("microbatch").map_err(anyhow::Error::msg)? {
        Some(mb) => mb,
        None => json_microbatch.unwrap_or(1),
    };
    anyhow::ensure!(microbatch > 0, "--microbatch must be nonzero");
    // Workload overrides are user-controlled: report an incompatible MoE
    // shape as an error, not a panic.
    let map = lumos::parallel::Mapping::try_new(
        lumos::parallel::Parallelism::paper(),
        workload.moe,
    )
    .map_err(|e| anyhow::anyhow!("workload incompatible with the paper mapping: {e}"))?
    .with_microbatch(microbatch);
    let (r, mem) = evaluate_feasible(&workload, &cluster, &map, &knobs)
        .map_err(|e| anyhow::anyhow!("infeasible configuration: {e}"))?;
    println!("cluster          : {}", r.cluster);
    println!("moe config       : {}", r.config_name);
    println!("total params     : {:.2} T", workload.total_params() / 1e12);
    println!("active / token   : {:.1} G", workload.active_params_per_token() / 1e9);
    println!("HBM utilization  : {:.1}%", 100.0 * mem.utilization());
    println!("EP placement     : {:?}", r.breakdown.ep_placement);
    println!("step time        : {}", fmt_time(r.step_time));
    println!("comm fraction    : {:.1}%", 100.0 * r.comm_fraction);
    println!("achieved MFU     : {:.3}", r.achieved_mfu);
    println!("time-to-train    : {}", fmt_time(r.time_to_train_s));
    if args.flag("breakdown") {
        let b = &r.breakdown;
        println!("  compute/micro  : {}", fmt_time(b.compute_per_micro));
        println!("  tp comm/micro  : {}", fmt_time(b.tp_comm_per_micro));
        println!("  ep a2a /micro  : {}", fmt_time(b.ep_a2a_per_micro));
        println!("  pp p2p /micro  : {}", fmt_time(b.pp_comm_per_micro));
        println!("  dp sync/step   : {}", fmt_time(b.dp_comm_per_step));
        println!("  bubble frac    : {:.1}%", 100.0 * b.bubble_fraction());
    }
    Ok(())
}

/// Write `table` as CSV to `path` when `--csv` was given. The confirmation
/// goes to stderr so stdout stays byte-identical across invocations (the
/// serial == parallel diff contract).
fn write_csv(args: &Args, table: &Table) -> anyhow::Result<()> {
    if let Some(path) = args.get("csv") {
        std::fs::write(path, table.to_csv()).with_context(|| format!("writing {path}"))?;
        eprintln!("result grid written to {path}");
    }
    Ok(())
}

/// Write `trace` as Chrome trace-event JSON to `path`. The confirmation
/// goes to stderr so stdout stays byte-identical across invocations.
fn write_trace(path: &str, trace: &lumos::obs::Trace) -> anyhow::Result<()> {
    trace.write(path).with_context(|| format!("writing {path}"))?;
    eprintln!("trace written to {path} ({} events)", trace.len());
    Ok(())
}

/// `--trace <path>` for `plan` and `validate`: build the step trace of
/// `map` and write it. No-op when the flag is absent.
fn emit_step_trace(
    args: &Args,
    w: &lumos::model::Workload,
    cluster: &lumos::topology::cluster::Cluster,
    map: &lumos::parallel::Mapping,
    knobs: &PerfKnobs,
) -> anyhow::Result<()> {
    if let Some(path) = args.get("trace") {
        let st = lumos::obs::step_trace(w, cluster, map, knobs, false).map_err(|e| {
            anyhow::anyhow!(
                "--trace: cannot trace TP{}xPP{}xDP{}: {e}",
                map.par.tp,
                map.par.pp,
                map.par.dp
            )
        })?;
        write_trace(path, &st.trace)?;
    }
    Ok(())
}

fn trace_cmd(args: &Args) -> anyhow::Result<()> {
    use lumos::obs;

    // --diff A B: align two trace artifacts (simulated vs executed, or
    // any pair) by (track, span name, occurrence) and report per-phase
    // share deltas. Output is a pure function of the two files.
    if args.flag("diff") {
        anyhow::ensure!(
            args.positional.len() == 2,
            "--diff takes exactly two trace files: lumos trace --diff A.json B.json \
             (got {})",
            args.positional.len()
        );
        let (pa, pb) = (&args.positional[0], &args.positional[1]);
        let read = |p: &str| -> anyhow::Result<Json> {
            Json::parse(&std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?)
                .map_err(|e| anyhow::anyhow!("{p}: {e}"))
        };
        let d = obs::diff_traces(&read(pa)?, &read(pb)?).map_err(anyhow::Error::msg)?;
        if args.flag("json") {
            println!("{}", obs::diff_json(&d, pa, pb).to_string_pretty());
        } else {
            println!("trace diff: A = {pa}, B = {pb}");
            print!("{}", obs::diff_table(&d, "A", "B"));
        }
        return Ok(());
    }

    // --check: schema-check an existing trace file and exit (the CI smoke
    // path; pure Rust, no external tooling).
    if let Some(path) = args.get("check") {
        let doc = Json::parse(&std::fs::read_to_string(path)?).map_err(anyhow::Error::msg)?;
        let c = obs::check_chrome_trace(&doc).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        println!(
            "{path}: ok — {} event(s): {} span(s) on {} track(s), {} counter sample(s), \
             {} instant(s)",
            c.events, c.spans, c.tracks, c.counters, c.instants
        );
        return Ok(());
    }

    let cfg = args.get_usize("config").map_err(anyhow::Error::msg)?.unwrap_or(4);
    anyhow::ensure!((1..=4).contains(&cfg), "--config must be 1..4, got {cfg}");
    // --jobs is accepted (and validated) for interface uniformity only:
    // the trace build is serial and byte-identical for any value.
    let _jobs = args.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let knobs = knobs_from_args(args)?;
    let key = cluster_key_from_args(args)?;
    let cache = ClusterCache::new();
    let cluster = cache.get(&key);
    // Opt-in wall-clock self-profiling: the only host-clock consumer on
    // this path, quarantined to the --profile side file.
    let mut prof = args.get("profile").map(|_| obs::StageProfiler::start());
    let workload = lumos::model::Workload::paper_gpt_4p7t(cfg);
    let map =
        lumos::resilience::default_mapping(&workload, &cluster).map_err(anyhow::Error::msg)?;
    let st = obs::step_trace(&workload, &cluster, &map, &knobs, args.flag("events")).map_err(
        |e| {
            anyhow::anyhow!(
                "cannot trace TP{}xPP{}xDP{}: {e}",
                map.par.tp,
                map.par.pp,
                map.par.dp
            )
        },
    )?;
    if let Some(p) = prof.as_mut() {
        p.stage("lower+simulate+build");
    }
    let p = &st.report.phases;
    println!("step trace: Config {cfg} on {}", cluster.spec.name);
    println!(
        "  mapping        : TP{}xPP{}xDP{}",
        map.par.tp, map.par.pp, map.par.dp
    );
    println!("  simulated step : {}", fmt_time(st.report.step_time));
    println!("  stage tracks   : {}", st.stages.len());
    println!("  dag nodes      : {}", st.report.nodes);
    println!("  trace events   : {}", st.trace.len());
    println!(
        "  stage-0 spans  : compute {} | tp {} | ep {} | pp {} | dp {} | bubble {}",
        fmt_time(p.compute),
        fmt_time(p.tp_comm),
        fmt_time(p.ep_comm),
        fmt_time(p.pp_comm),
        fmt_time(p.dp_comm),
        fmt_time(p.bubble),
    );
    if let Some(path) = args.get("out") {
        write_trace(path, &st.trace)?;
    }
    if let (Some(p), Some(path)) = (prof.as_mut(), args.get("profile")) {
        p.stage("emit");
        p.write(path).with_context(|| format!("writing {path}"))?;
        eprintln!("wall-clock profile written to {path}");
    }
    Ok(())
}

fn sweep_cmd(args: &Args) -> anyhow::Result<()> {
    let knobs = PerfKnobs::default();
    let jobs = args.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let table = match args.get("kind").unwrap_or("pod") {
        "pod" => sweep::pod_size_sweep_par(&knobs, jobs),
        "bandwidth" => sweep::bandwidth_sweep_par(&knobs, jobs),
        "granularity" => sweep::granularity_sweep_par(&knobs, jobs),
        "grid" => {
            let pods = args
                .get_usize_list("pods")
                .map_err(anyhow::Error::msg)?
                .unwrap_or_else(|| vec![64, 128, 144, 256, 512, 1024]);
            let bws = args
                .get_f64_list("bandwidths")
                .map_err(anyhow::Error::msg)?
                .unwrap_or_else(|| vec![7_200.0, 14_400.0, 32_000.0, 64_000.0]);
            let cfg = args.get_usize("config").map_err(anyhow::Error::msg)?.unwrap_or(4);
            anyhow::ensure!((1..=4).contains(&cfg), "--config must be 1..4, got {cfg}");
            for &pod in &pods {
                anyhow::ensure!(
                    (1..=32_768).contains(&pod),
                    "--pods entries must be in 1..=32768, got {pod}"
                );
            }
            for &bw in &bws {
                anyhow::ensure!(
                    bw.is_finite() && bw > 0.0,
                    "--bandwidths entries must be positive Gb/s, got {bw}"
                );
            }
            sweep::custom_grid(&knobs, &pods, &bws, cfg, jobs)
        }
        "topology" => sweep::topology_ablation(),
        "routing" => sweep::routing_restriction_ablation(),
        other => anyhow::bail!("unknown sweep kind '{other}'"),
    };
    println!("{}", table.render());
    write_csv(args, &table)
}

/// Shared knob-file parsing for `plan`, `validate` and `resilience`.
fn knobs_from_args(args: &Args) -> anyhow::Result<PerfKnobs> {
    Ok(match args.get("knobs") {
        Some(path) => config::knobs_from_json(
            &Json::parse(&std::fs::read_to_string(path)?).map_err(anyhow::Error::msg)?,
        ),
        None => PerfKnobs::default(),
    })
}

/// Shared cluster selection for `plan` and `validate`: a §VI preset, or a
/// custom (--gpus, --pod-size, --gbps) point.
fn cluster_key_from_args(args: &Args) -> anyhow::Result<ClusterKey> {
    let custom = [args.get("gpus"), args.get("pod-size"), args.get("gbps")];
    if custom.iter().any(Option::is_some) {
        anyhow::ensure!(
            custom.iter().all(Option::is_some),
            "custom clusters need all of --gpus, --pod-size and --gbps"
        );
        anyhow::ensure!(
            args.get("cluster").is_none(),
            "--cluster conflicts with --gpus/--pod-size/--gbps (pick a preset or a custom point)"
        );
        let n = args
            .get_usize("gpus")
            .map_err(anyhow::Error::msg)?
            .context("--gpus is required for a custom cluster")?;
        let pod = args
            .get_usize("pod-size")
            .map_err(anyhow::Error::msg)?
            .context("--pod-size is required for a custom cluster")?;
        let gbps = args
            .get_f64("gbps")
            .map_err(anyhow::Error::msg)?
            .context("--gbps is required for a custom cluster")?;
        anyhow::ensure!(
            pod > 0 && n > 0 && n % pod == 0,
            "--gpus must be a multiple of --pod-size"
        );
        anyhow::ensure!(gbps.is_finite() && gbps > 0.0, "--gbps must be positive");
        Ok(ClusterKey::custom(n, pod, gbps))
    } else {
        Ok(match args.get("cluster").unwrap_or("passage-512") {
            "passage-512" => ClusterKey::Passage512,
            "electrical-512" => ClusterKey::Electrical512,
            "electrical-144" => ClusterKey::Electrical144,
            other => anyhow::bail!(
                "unknown cluster preset '{other}' \
                 (have passage-512, electrical-512, electrical-144)"
            ),
        })
    }
}

fn plan_cmd(args: &Args) -> anyhow::Result<()> {
    let cfg = args.get_usize("config").map_err(anyhow::Error::msg)?.unwrap_or(4);
    anyhow::ensure!((1..=4).contains(&cfg), "--config must be 1..4, got {cfg}");
    let top = args.get_usize("top").map_err(anyhow::Error::msg)?.unwrap_or(10);
    let jobs = args.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let rerank = args.get_usize("rerank-sim").map_err(anyhow::Error::msg)?.unwrap_or(0);
    let objective = args.get("objective").unwrap_or("ttt");
    anyhow::ensure!(
        objective == "ttt" || objective == "sim",
        "--objective must be 'ttt' or 'sim', got '{objective}'"
    );
    let margin = match args.get_f64("sim-margin").map_err(anyhow::Error::msg)? {
        Some(m) => {
            anyhow::ensure!(!m.is_nan() && m >= 0.0, "--sim-margin must be >= 0");
            m
        }
        None => planner::DEFAULT_SIM_MARGIN,
    };
    anyhow::ensure!(
        !(objective == "sim" && rerank > 0),
        "--rerank-sim is redundant with --objective sim (the whole admitted set is simulated)"
    );
    let knobs = knobs_from_args(args)?;
    let key = cluster_key_from_args(args)?;

    let cache = ClusterCache::new();
    let cluster = cache.get(&key);
    // the sim objective scores the full feasible ranking, so don't let
    // --top truncate the planner output (it still truncates the table)
    let req_top = if objective == "sim" { 0 } else { top };
    let mut req = planner::PlanRequest::paper(key, cfg, &knobs).with_top(req_top);
    if args.flag("availability") {
        req = req.with_availability(planner::AvailabilityObjective::default_for(&cluster));
    }
    let mut outcome = planner::plan_with_cache(&req, jobs, &cache);
    anyhow::ensure!(
        !outcome.ranked.is_empty(),
        "no feasible mapping for this (workload, cluster) pair \
         ({} candidates enumerated, all pruned)",
        outcome.enumerated
    );
    if objective == "sim" {
        if req.availability.is_some() {
            // stderr keeps stdout byte-identical across job counts
            eprintln!(
                "note: --objective sim orders on *simulated healthy* TTT; the \
                 availability adjustment applies to the analytical ranking only"
            );
        }
        let sim = planner::plan_simulated(&outcome, &req.workload, &cluster, &knobs, margin, jobs);
        let table = planner::sim_table(&sim, top);
        match sim.scored.first() {
            Some(s) => emit_step_trace(args, &req.workload, &cluster, &s.plan.mapping, &knobs)?,
            None => anyhow::ensure!(
                args.get("trace").is_none(),
                "--trace: no simulated plan to trace (every admitted candidate was skipped)"
            ),
        }
        if args.flag("json") {
            if top > 0 {
                outcome.ranked.truncate(top);
            }
            let section = planner::SimSection::from_plan(&sim);
            println!("{}", planner::outcome_json(&outcome, Some(&section)).to_string_pretty());
            return write_csv(args, &table);
        }
        if let Some(b) = &outcome.paper_baseline {
            println!(
                "paper mapping (TP16 x PP8 x DP256): step {}, TTT {}\n",
                fmt_time(b.step_time),
                fmt_time(b.time_to_train_s)
            );
        }
        // skip reasons go to stderr so stdout stays byte-identical
        for line in planner::rerank_skip_lines(&sim.skipped) {
            eprintln!("{line}");
        }
        println!("{}", table.render());
        return write_csv(args, &table);
    }
    emit_step_trace(args, &req.workload, &cluster, &outcome.ranked[0].mapping, &knobs)?;
    if args.flag("json") {
        let rerank_results = (rerank > 0).then(|| {
            planner::rerank_simulated(&outcome, rerank, &req.workload, &cluster, &knobs)
        });
        let section = rerank_results
            .as_ref()
            .map(|(scored, skipped)| planner::SimSection::from_rerank(scored, skipped));
        println!("{}", planner::outcome_json(&outcome, section.as_ref()).to_string_pretty());
        return write_csv(args, &planner::ranked_table(&outcome));
    }
    if let Some(b) = &outcome.paper_baseline {
        println!(
            "paper mapping (TP16 x PP8 x DP256): step {}, TTT {}\n",
            fmt_time(b.step_time),
            fmt_time(b.time_to_train_s)
        );
    }
    let table = planner::ranked_table(&outcome);
    println!("{}", table.render());
    if rerank > 0 {
        if req.availability.is_some() {
            // stderr keeps stdout byte-identical across job counts
            eprintln!(
                "note: --rerank-sim orders on *simulated healthy* TTT; the \
                 availability adjustment applies to the analytical ranking only"
            );
        }
        let (scored, skipped) =
            planner::rerank_simulated(&outcome, rerank, &req.workload, &cluster, &knobs);
        // skipped plans stay visible as table rows; the reasons go to
        // stderr so stdout stays byte-identical across job counts
        for line in planner::rerank_skip_lines(&skipped) {
            eprintln!("{line}");
        }
        println!("{}", planner::rerank_table(&scored, &skipped).render());
    }
    write_csv(args, &table)
}

fn validate_cmd(args: &Args) -> anyhow::Result<()> {
    use lumos::parallel::{Mapping, Parallelism};
    use lumos::timeline;

    let cfg = args.get_usize("config").map_err(anyhow::Error::msg)?.unwrap_or(4);
    anyhow::ensure!((1..=4).contains(&cfg), "--config must be 1..4, got {cfg}");
    let plan_top = args.get_usize("plan-top").map_err(anyhow::Error::msg)?.unwrap_or(0);
    let jobs = args.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let knobs = knobs_from_args(args)?;
    let key = cluster_key_from_args(args)?;

    let cache = ClusterCache::new();
    let cluster = cache.get(&key);
    let workload = lumos::model::Workload::paper_gpt_4p7t(cfg);
    let mut rows = Vec::new();

    // The paper's fixed mapping first, when it is comparable on this
    // cluster (same gate as the planner baseline).
    if planner::paper_baseline(&workload, &cluster, &knobs).is_some() {
        let map = Mapping::try_new(Parallelism::paper(), workload.moe)
            // lumos: allow(panic-path) -- paper_baseline() already built this mapping
            .expect("baseline implies a legal mapping");
        rows.push(
            timeline::validate_mapping(&workload, &cluster, &map, &knobs)
                .map_err(|e| anyhow::anyhow!("paper mapping: {e}"))?,
        );
    }

    // The previously-rejected deep-PP × fine-microbatch region: every grid
    // mapping's lowered DAG exceeds the pre-incremental 300k-node cap, so
    // none of these could simulate before the dep engine went
    // component-incremental.
    if args.flag("deep") {
        let deep_top = args.get_usize("deep-top").map_err(anyhow::Error::msg)?.unwrap_or(3);
        let deep = timeline::deep_candidates(&workload, &cluster, deep_top);
        anyhow::ensure!(
            !deep.is_empty(),
            "no feasible deep-PP mappings (DAG estimate > {} nodes) for this \
             (workload, cluster) pair",
            timeline::DEEP_REGION_MIN_NODES
        );
        for m in deep {
            if rows.iter().any(|v: &timeline::Validation| v.mapping == m) {
                continue;
            }
            rows.push(
                timeline::validate_mapping(&workload, &cluster, &m, &knobs).map_err(|e| {
                    anyhow::anyhow!(
                        "deep mapping TP{}xPP{}xDP{}: {e}",
                        m.par.tp,
                        m.par.pp,
                        m.par.dp
                    )
                })?,
            );
        }
    }

    // Cross-check the planner's best mappings on the same cluster.
    if plan_top > 0 {
        let req = planner::PlanRequest::paper(key.clone(), cfg, &knobs).with_top(plan_top);
        let outcome = planner::plan_with_cache(&req, jobs, &cache);
        for p in &outcome.ranked {
            if rows.iter().any(|v: &timeline::Validation| v.mapping == p.mapping) {
                continue;
            }
            match timeline::validate_mapping(&workload, &cluster, &p.mapping, &knobs) {
                Ok(v) => rows.push(v),
                // stderr keeps stdout byte-identical across job counts
                Err(timeline::TimelineError::TooLarge(msg)) => eprintln!(
                    "skipping TP{}xPP{}xDP{}: {msg}",
                    p.mapping.par.tp, p.mapping.par.pp, p.mapping.par.dp
                ),
                Err(e) => anyhow::bail!("planner mapping failed to validate: {e}"),
            }
        }
    }
    anyhow::ensure!(
        !rows.is_empty(),
        "nothing to validate: the paper mapping does not fit this cluster; \
         use --plan-top K to validate planner-found mappings"
    );
    emit_step_trace(args, &workload, &cluster, &rows[0].mapping, &knobs)?;
    let config_name = rows[0].analytical.config_name.clone();
    let table = timeline::validation_table(&cluster.spec.name, &config_name, &rows);
    if args.flag("json") {
        println!(
            "{}",
            timeline::validation_json(&cluster.spec.name, &config_name, &rows)
                .to_string_pretty()
        );
    } else {
        println!("{}", table.render());
    }
    write_csv(args, &table)
}

fn resilience_cmd(args: &Args) -> anyhow::Result<()> {
    use lumos::model::Workload;
    use lumos::resilience::{self, DegradeSource, FabricReliability, ResilienceSpec};

    let seed = args.get_usize("seed").map_err(anyhow::Error::msg)?.unwrap_or(7) as u64;
    let trials = args.get_usize("trials").map_err(anyhow::Error::msg)?.unwrap_or(128);
    let jobs = args.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let knobs = knobs_from_args(args)?;
    let degrade = match args.get("degrade") {
        Some(name) => DegradeSource::from_cli_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown degrade mode '{name}' (have simulated, analytical)")
        })?,
        None => DegradeSource::Simulated,
    };
    let spec = ResilienceSpec { seed, trials, degrade, ..ResilienceSpec::default() };
    let cache = ClusterCache::new();
    let configs: Vec<usize> = match args.get_usize("config").map_err(anyhow::Error::msg)? {
        Some(c) => {
            anyhow::ensure!((1..=4).contains(&c), "--config must be 1..4, got {c}");
            vec![c]
        }
        None => vec![1, 2, 3, 4],
    };

    // A degrade-source fallback must never be silent: the reason goes to
    // stderr (stdout stays byte-identical across job counts).
    let warn_fallback = |a: &resilience::Assessment| {
        if let Some(note) = &a.degrade_note {
            eprintln!(
                "note: {} / {}: simulated degraded-step pricing unavailable, \
                 using analytical: {note}",
                a.cluster, a.config_name
            );
        }
    };

    // --trace: one seeded failure/repair/checkpoint timeline over a fixed
    // 48-hour horizon, written as Chrome trace-event JSON. Deterministic:
    // the fault trace is a pure function of (fabric, repair, seed).
    const TRACE_HORIZON_H: f64 = 48.0;
    let emit_fault_trace = |fabric: &FabricReliability,
                            n_gpus: usize,
                            ckpt_s: f64|
     -> anyhow::Result<()> {
        if let Some(path) = args.get("trace") {
            let events = resilience::sample_trace(
                fabric,
                &spec.repair,
                n_gpus,
                TRACE_HORIZON_H,
                lumos::util::rng::Rng::new(seed),
            );
            let tr = lumos::obs::resilience_trace(&events, ckpt_s, TRACE_HORIZON_H);
            write_trace(path, &tr)?;
        }
        Ok(())
    };

    let custom = [args.get("gpus"), args.get("pod-size"), args.get("gbps")];
    if args.get("cluster").is_none() && custom.iter().all(Option::is_none) {
        // The headline comparison: Passage (external-laser optics) vs the
        // 144-pod electrical alternative, availability-adjusted.
        anyhow::ensure!(
            args.get("tech").is_none(),
            "--tech needs --cluster (the default run fixes the techs per fabric)"
        );
        let rows = resilience::paper_pairs(&configs, &knobs, &spec, jobs, &cache);
        for r in &rows {
            warn_fallback(&r.passage);
            warn_fallback(&r.electrical);
        }
        emit_fault_trace(
            &FabricReliability::passage(),
            cache.get(&ClusterKey::Passage512).spec.n_gpus,
            rows[0].passage.expected.checkpoint_interval_s,
        )?;
        let table = resilience::speedup_table(&rows);
        if args.flag("json") {
            println!("{}", resilience::paired_json(&rows, seed, trials).to_string_pretty());
            return write_csv(args, &table);
        }
        println!("{}", table.render());
        let pods = resilience::pod_serviceability(&knobs, &spec, jobs, &cache);
        println!("{}", resilience::serviceability_table(&pods).render());
        return write_csv(args, &table);
    }

    let key = cluster_key_from_args(args)?;
    let cluster = cache.get(&key);
    let fabric = match args.get("tech") {
        Some(name) => FabricReliability::from_cli_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown tech '{name}' (have passage, cpo, electrical, pluggable)")
        })?,
        None => FabricReliability::default_for(&cluster),
    };
    let mut rows = Vec::new();
    for &cfg in &configs {
        let w = Workload::paper_gpt_4p7t(cfg);
        let map = resilience::default_mapping(&w, &cluster).map_err(anyhow::Error::msg)?;
        // seed derived from the config index, not the list position, so
        // --config 3 draws the same trials as config 3 of an all-config run
        let s = ResilienceSpec { seed: seed.wrapping_add(cfg as u64), ..spec.clone() };
        let a = resilience::assess(&w, &cluster, &map, &knobs, &fabric, &s, jobs);
        warn_fallback(&a);
        rows.push(a);
    }
    emit_fault_trace(&fabric, cluster.spec.n_gpus, rows[0].expected.checkpoint_interval_s)?;
    let table = resilience::assessment_table(&rows);
    if args.flag("json") {
        println!(
            "{}",
            resilience::assessments_json(&rows, seed, trials).to_string_pretty()
        );
        return write_csv(args, &table);
    }
    println!("{}", table.render());
    write_csv(args, &table)
}

fn netsim_cmd() -> anyhow::Result<()> {
    use lumos::collectives as coll;
    use lumos::netsim::{replay_schedule, Network};
    use lumos::topology::cluster::DomainSpec;
    println!("Hockney-vs-netsim validation (SLS, 64 GPUs, 32 Tb/s):");
    let n = 64;
    let net = Network::sls(n, 32_000.0, 200e-9);
    let dom = DomainSpec {
        name: "passage".into(),
        gbps_per_gpu: 32_000.0,
        latency_s: 200e-9,
        a2a_efficiency: 1.0,
    };
    for (name, sched, model) in [
        (
            "ring all-reduce 256 MB",
            coll::ring_all_reduce_schedule(n, 256e6),
            coll::all_reduce_time(&dom, n, 256e6),
        ),
        (
            "ring all-gather 256 MB",
            coll::ring_all_gather_schedule(n, 256e6),
            coll::all_gather_time(&dom, n, 256e6),
        ),
        (
            "pairwise a2a 64 MB/rank",
            coll::pairwise_a2a_schedule(n, 64e6),
            coll::all_to_all_time(&dom, n, 64e6),
        ),
    ] {
        let sim = replay_schedule(&net, &sched);
        println!(
            "  {name:>24}: model {:>10}  sim {:>10}  err {:+.1}%",
            fmt_time(model),
            fmt_time(sim.makespan),
            100.0 * (sim.makespan - model) / model
        );
    }
    Ok(())
}

fn train(args: &Args) -> anyhow::Result<()> {
    let preset = args.get("preset").unwrap_or("tiny");
    let steps = args.get_usize("steps").map_err(anyhow::Error::msg)?.unwrap_or(50);
    let workers = args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let seed = args.get_usize("seed").map_err(anyhow::Error::msg)?.unwrap_or(42) as u64;

    // `host` is artifact-free: the miniature MoE block computed by the
    // in-process host-math backend (the same pair `lumos run` executes).
    let (art, engine) = if preset == "host" {
        (Artifact::host_miniature(), Engine::host())
    } else {
        (Artifact::load(artifacts_root()?.join(preset))?, Engine::cpu()?)
    };
    println!(
        "training '{preset}' ({} arrays, {:.1}M params) for {steps} steps, {workers} worker(s)",
        art.n_params,
        art.total_param_elements as f64 / 1e6
    );
    let report = if workers <= 1 {
        trainer::train_single(&engine, &art, steps, seed, true)?
    } else {
        trainer::train_dp(&engine, &art, workers, steps, seed, true)?
    };
    println!(
        "loss {:.4} -> {:.4} over {} steps ({} mode, {:.2}s total, {:.2}s/step steady)",
        report.first_loss(),
        report.last_loss(),
        report.steps.len(),
        report.mode,
        report.total_secs,
        report.steady_step_secs(),
    );
    if let Some(path) = args.get("csv") {
        std::fs::write(path, report.to_csv()).with_context(|| format!("writing {path}"))?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

/// `(secs, share)` objects per phase, in the canonical phase order.
fn phase_json(p: &lumos::timeline::PhaseBreakdown) -> Json {
    Json::obj(
        p.rows()
            .into_iter()
            .map(|(k, secs, share)| {
                (k, Json::obj(vec![("secs", Json::num(secs)), ("share", Json::num(share))]))
            })
            .collect(),
    )
}

fn run_cmd(args: &Args) -> anyhow::Result<()> {
    use lumos::chaos;
    use lumos::obs;
    use lumos::timeline;
    use lumos::trainer::MiniMapping;

    let cfg = args.get_usize("config").map_err(anyhow::Error::msg)?.unwrap_or(4);
    anyhow::ensure!((1..=4).contains(&cfg), "--config must be 1..4, got {cfg}");
    let ranks = args.get_usize("ranks").map_err(anyhow::Error::msg)?.unwrap_or(4);
    anyhow::ensure!((1..=64).contains(&ranks), "--ranks must be 1..64, got {ranks}");
    let steps = args.get_usize("steps").map_err(anyhow::Error::msg)?.unwrap_or(4);
    anyhow::ensure!(steps > 0, "--steps must be nonzero");
    let n_micro = args.get_usize("micro").map_err(anyhow::Error::msg)?.unwrap_or(2);
    anyhow::ensure!(n_micro > 0, "--micro must be nonzero");
    let seed = args.get_usize("seed").map_err(anyhow::Error::msg)?.unwrap_or(42) as u64;
    let jobs = args.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let pp_override = args.get_usize("pp").map_err(anyhow::Error::msg)?;
    let ckpt_every = args.get_usize("ckpt-every").map_err(anyhow::Error::msg)?.unwrap_or(2);
    let fault_spec = args.get("faults").map(|s| s.to_string());
    let chaos_on = args.flag("chaos") || fault_spec.is_some();
    let knobs = knobs_from_args(args)?;
    let key = cluster_key_from_args(args)?;
    let cache = ClusterCache::new();
    let cluster = cache.get(&key);

    // The planner picks the mapping shape; the miniature executes it.
    let req = planner::PlanRequest::paper(key, cfg, &knobs).with_top(1);
    let outcome = planner::plan_with_cache(&req, jobs, &cache);
    anyhow::ensure!(
        !outcome.ranked.is_empty(),
        "no feasible mapping for this (workload, cluster) pair \
         ({} candidates enumerated, all pruned)",
        outcome.enumerated
    );
    let win = &outcome.ranked[0];
    let map = &win.mapping;
    let m = match pp_override {
        Some(pp) => {
            anyhow::ensure!(
                pp >= 1 && ranks % pp == 0,
                "--pp {pp} must be >= 1 and divide --ranks {ranks}"
            );
            MiniMapping { pp, dp: ranks / pp, n_micro }
        }
        None => MiniMapping::scale(map.par.pp, ranks, n_micro),
    };

    // Materialize the seeded fault plan before the run so both the
    // injector and the report carry the same digest.
    let chaos_plan = if chaos_on {
        let spec = chaos::ChaosSpec::parse(
            fault_spec.as_deref().unwrap_or("crash=1,drop=1,stall=1"),
        )?;
        let plan =
            chaos::FaultPlan::generate(&spec, seed, m.pp, m.dp, m.n_micro, steps, ckpt_every)?;
        Some((spec.to_string(), plan))
    } else {
        None
    };

    let engine = Engine::host();
    let art = Artifact::host_miniature();
    let out = trainer::run_mapped_chaos(
        &engine,
        &art,
        m,
        steps,
        seed,
        args.flag("verbose"),
        chaos_plan.as_ref().map(|(_, p)| p),
    )?;

    // Three views of where one training step's time goes: the closed
    // form, the discrete-event simulation of the planner's mapping, and
    // the span totals the flight recorder measured on the miniature.
    // Absolute magnitudes differ by design (frontier step vs laptop
    // step); the comparable currency is each phase's share.
    let workload = lumos::model::Workload::paper_gpt_4p7t(cfg);
    let analytical = timeline::analytical_phases(&win.report.breakdown, &knobs);
    let st = obs::step_trace(&workload, &cluster, map, &knobs, false).map_err(|e| {
        anyhow::anyhow!(
            "cannot simulate TP{}xPP{}xDP{}: {e}",
            map.par.tp,
            map.par.pp,
            map.par.dp
        )
    })?;
    let executed = timeline::phases_from_cat_totals(&out.cat_totals());

    if let Some(path) = args.get("trace") {
        write_trace(path, &obs::to_trace(&out.recordings))?;
    }

    if args.flag("json") {
        // Wall-clock-dependent values appear only under executed-side
        // keys: "report", "phases"."executed", and "metrics".
        let metrics = Json::Obj(
            engine
                .entry_stats()
                .into_iter()
                .map(|(name, s)| {
                    (
                        name,
                        Json::obj(vec![
                            ("executions", Json::num(s.executions as f64)),
                            ("total_secs", Json::num(s.total_secs)),
                            ("compiles", Json::num(s.compiles as f64)),
                            ("cache_hits", Json::num(s.cache_hits as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let mut fields = vec![
            ("cluster", Json::str(&cluster.spec.name)),
            ("config", Json::str(&outcome.config_name)),
            ("seed", Json::num(seed as f64)),
            (
                "planner_mapping",
                Json::obj(vec![
                    ("tp", Json::num(map.par.tp as f64)),
                    ("pp", Json::num(map.par.pp as f64)),
                    ("dp", Json::num(map.par.dp as f64)),
                ]),
            ),
            (
                "miniature",
                Json::obj(vec![
                    ("pp", Json::num(m.pp as f64)),
                    ("dp", Json::num(m.dp as f64)),
                    ("n_micro", Json::num(m.n_micro as f64)),
                    ("ranks", Json::num(m.ranks() as f64)),
                ]),
            ),
            ("report", out.report.to_json()),
            (
                "phases",
                Json::obj(vec![
                    ("analytical", phase_json(&analytical)),
                    ("simulated", phase_json(&st.report.phases)),
                    ("executed", phase_json(&executed)),
                ]),
            ),
            ("metrics", metrics),
        ];
        // Full chaos provenance: everything needed to reproduce the run
        // and the executed-vs-modeled recovery comparison. Byte-identical
        // across --jobs and reruns (the CI chaos smoke compares it).
        if let (Some((spec_text, plan)), Some(report)) = (&chaos_plan, &out.chaos) {
            let planned: Vec<Json> = plan
                .faults
                .iter()
                .map(|f| {
                    Json::obj(vec![
                        ("rank", Json::num(f.rank as f64)),
                        ("step", Json::num(f.step as f64)),
                        ("micro", Json::num(f.micro as f64)),
                        ("purpose", Json::num(f.purpose as f64)),
                        ("kind", Json::str(f.kind.as_str())),
                        ("amount", Json::num(f.amount as f64)),
                    ])
                })
                .collect();
            fields.push((
                "chaos",
                Json::obj(vec![
                    ("seed", Json::num(seed as f64)),
                    ("spec", Json::str(spec_text)),
                    ("plan_digest", Json::str(&plan.digest())),
                    ("ckpt_every", Json::num(plan.ckpt_every as f64)),
                    ("planned_faults", Json::Arr(planned)),
                    ("report", report.to_json()),
                    ("modeled", chaos::modeled_recovery(plan, steps).to_json()),
                ]),
            ));
        }
        let j = Json::obj(fields);
        println!("{}", j.to_string_pretty());
        return Ok(());
    }

    println!(
        "run: Config {cfg} on {} — planner winner TP{}xPP{}xDP{}",
        cluster.spec.name, map.par.tp, map.par.pp, map.par.dp
    );
    println!(
        "  miniature      : pp{} x dp{} x mb{} on {} rank(s), {} step(s)",
        m.pp,
        m.dp,
        m.n_micro,
        m.ranks(),
        steps
    );
    let r = &out.report;
    println!(
        "  loss           : {:.4} -> {:.4} ({} mode, {:.2}s total)",
        r.first_loss(),
        r.last_loss(),
        r.mode,
        r.total_secs
    );
    let stats = engine.entry_stats();
    let execs: u64 = stats.iter().map(|(_, s)| s.executions).sum();
    let hits: u64 = stats.iter().map(|(_, s)| s.cache_hits).sum();
    println!(
        "  engine         : {} entries, {} executions, {} cache hits",
        stats.len(),
        execs,
        hits
    );
    if let (Some((spec_text, plan)), Some(report)) = (&chaos_plan, &out.chaos) {
        let modeled = chaos::modeled_recovery(plan, steps);
        println!(
            "chaos recovery (spec {spec_text}, seed {seed}, plan {}, ckpt every {}):",
            plan.digest(),
            plan.ckpt_every
        );
        println!("{}", report.table());
        let exec_ratio = report.degraded_ratio();
        let lo = modeled.expected_degraded_ratio - modeled.ratio_band;
        let hi = modeled.expected_degraded_ratio + modeled.ratio_band;
        let status =
            if (lo..=hi).contains(&exec_ratio) { "within band" } else { "OUTSIDE band" };
        println!(
            "  vs model       : degraded ratio {:.3} executed vs {:.3} ± {:.3} modeled ({status})",
            exec_ratio, modeled.expected_degraded_ratio, modeled.ratio_band
        );
        println!(
            "  vs model       : {} step(s) rolled back vs {:.1} modeled; {} repair(s) vs {} modeled",
            report.steps_rolled_back,
            modeled.expected_rollback_steps,
            report.repairs_served,
            modeled.expected_repairs
        );
    }
    println!("three-way phase shares (% of each view's own step):");
    println!(
        "  {:<8}  {:>10}  {:>10}  {:>10}",
        "phase", "analytical", "simulated", "executed"
    );
    let ana = analytical.rows();
    let sim = st.report.phases.rows();
    let exe = executed.rows();
    for ((a, s), e) in ana.iter().zip(&sim).zip(&exe) {
        println!(
            "  {:<8}  {:>9.1}%  {:>9.1}%  {:>9.1}%",
            a.0,
            100.0 * a.2,
            100.0 * s.2,
            100.0 * e.2
        );
    }
    Ok(())
}

fn lint_cmd(args: &Args) -> anyhow::Result<()> {
    if args.flag("list") {
        print!("{}", analysis::rule_table());
        return Ok(());
    }
    let only: Vec<String> = args.get_all("rule").iter().map(|s| s.to_string()).collect();
    for r in &only {
        anyhow::ensure!(
            analysis::rules::is_rule(r),
            "unknown rule '{r}' (see `lumos lint --list`)"
        );
    }
    let jobs = args.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let paths: Vec<std::path::PathBuf> = if args.positional.is_empty() {
        vec![analysis::default_root()?]
    } else {
        args.positional.iter().map(std::path::PathBuf::from).collect()
    };
    let report = analysis::lint_paths(&paths, &only, jobs)?;
    if args.flag("json") {
        println!("{}", analysis::report_json(&report).to_string_pretty());
    } else {
        for f in &report.findings {
            println!("{f}");
        }
        println!(
            "{} file(s) scanned, {} finding(s), {} suppressed",
            report.files_scanned,
            report.findings.len(),
            report.suppressed
        );
    }
    let audit = if args.flag("audit-wallclock") {
        analysis::wallclock_audit(&paths, jobs)?
    } else {
        Vec::new()
    };
    for f in &audit {
        println!("{f} [outside the wallclock allowlist]");
    }
    anyhow::ensure!(
        report.findings.is_empty(),
        "{} lint finding(s) — fix, or justify with `// lumos: allow(<rule>) -- <reason>`",
        report.findings.len()
    );
    anyhow::ensure!(
        audit.is_empty(),
        "{} wall-clock site(s) outside the allowlisted modules \
         (analysis::WALLCLOCK_ALLOWED) — annotations do not satisfy the audit",
        audit.len()
    );
    Ok(())
}
