//! Every table/figure of the paper's evaluation (plus ablations), rendered
//! from ordered [`engine`] job grids. Each function is pure (returns the
//! artifact); the CLI (`lumos figures ...`, `lumos sweep ...`) and the
//! bench harness print them. The `*_par` variants fan the underlying
//! evaluation grid out over `jobs` worker threads; because grid results
//! come back in job order, their output is byte-identical to the serial
//! path for any `jobs`. The `*_cached` variants additionally run against a
//! caller-owned [`engine::ClusterCache`], so one command rendering many
//! figures (e.g. `lumos figures --all`) builds each cluster exactly once.

use crate::hw;
use crate::model::{MoeConfig, Workload};
use crate::parallel::{Mapping, Parallelism};
use crate::perf::{evaluate_paper_config, PerfKnobs};
use crate::planner;
use crate::sweep::engine::{self, ClusterCache, ClusterKey, EvalJob, PaperGrid};
use crate::timeline;
use crate::topology::torus::Torus;
use crate::util::stats::fmt_time;
use crate::util::table::{BarChart, Table};

// ---------------------------------------------------------------------------
// Tables I, II, III, IV
// ---------------------------------------------------------------------------

/// Table I: scale-up vs scale-out network characteristics.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I: scale-up vs scale-out networks",
        &["Network Type", "no. GPUs", "latency", "Tbps/GPU", "Energy"],
    );
    t.row_str(&["Scale-out", ">100k", "2-10 us", "1.6 Tb/s", "16 pJ/bit"]);
    t.row_str(&["Scale-up", "<1024", "100-250 ns", ">12.8 Tb/s", "<5 pJ/bit"]);
    t
}

/// Table II: legacy optical technology qualities (energy column computed
/// from the hw catalog; qualitative columns from the paper).
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table II: legacy optical technologies",
        &["Quality", "Optical Module", "LPO", "2/2.5D CPO"],
    );
    let plug = hw::pluggable_osfp();
    let lpo = hw::lpo_dr8();
    let cpo = hw::cpo_2p5d();
    t.row(&[
        "Energy Efficiency".into(),
        format!("{:.0} pJ/bit", plug.total_pj_per_bit()),
        format!("{:.0} pJ/bit", lpo.total_pj_per_bit()),
        format!("{:.0} pJ/bit", cpo.total_pj_per_bit()),
    ]);
    t.row_str(&["Bandwidth Density", "Low", "Low", "Medium"]);
    t.row_str(&["Latency", "High (retimed)", "Medium", "Low"]);
    t.row_str(&["Serviceability", "Yes", "Yes", "Ext. laser + coupler"]);
    t.row_str(&["Std. Form Factor", "Yes", "Yes", "No"]);
    t.row_str(&["Interoperability", "Yes", "Co-design w/ host", "Co-design w/ host"]);
    t
}

/// Table III: energy efficiency decomposition of the three §IV designs.
pub fn table3() -> Table {
    let techs = [hw::lpo_dr8(), hw::cpo_2p5d(), hw::passage_interposer()];
    let mut t = Table::new(
        "Table III: energy efficiency (pJ/bit)",
        &["", "1.6T DR8 LPO 224G", "224G 2.5D CPO", "56Gx8λ Passage"],
    );
    let row = |name: &str, f: &dyn Fn(&hw::InterconnectTech) -> f64| {
        let mut cells = vec![name.to_string()];
        cells.extend(techs.iter().map(|x| format!("{:.1}", f(x))));
        cells
    };
    t.row(&row("In-package pJ/bit", &|x| x.in_pkg_pj_per_bit()));
    t.row(&row("Off-package pJ/bit", &|x| x.off_pkg_pj));
    t.row(&row("Total pJ/bit (optics, PHY, laser)", &|x| x.total_pj_per_bit()));
    t
}

/// Table IV: MoE cluster configuration parameters.
pub fn table4() -> Table {
    let mut t = Table::new(
        "Table IV: cluster configuration parameters",
        &["Parameter", "Config 1", "Config 2", "Config 3", "Config 4"],
    );
    let cfgs: Vec<MoeConfig> = (1..=4).map(MoeConfig::paper_config).collect();
    let mut active = vec!["Active / total experts".to_string()];
    let mut gran = vec!["Expert granularity (m)".to_string()];
    let mut per_rank = vec!["Experts per DP rank".to_string()];
    for c in &cfgs {
        active.push(format!("{}/{}", c.active_per_token, c.total_experts));
        gran.push(format!("{}", c.granularity));
        per_rank.push(format!("{}", c.experts_per_dp_rank));
    }
    t.row(&active);
    t.row(&gran);
    t.row(&per_rank);
    t
}

// ---------------------------------------------------------------------------
// Figures 7, 8
// ---------------------------------------------------------------------------

/// Fig. 7: optics power for a 32 Tb/s unidirectional GPU.
pub fn fig7() -> (Table, BarChart) {
    let gbps = 32_000.0;
    let (rows, advantage) = hw::fig7_comparison(gbps);
    let mut t = Table::new(
        &format!(
            "Fig 7: optics power @ 32 Tb/s GPU (Passage {advantage:.1}x less than best conventional)"
        ),
        &["Technology", "SerDes W", "In-pkg optics W", "Off-pkg W", "Total W"],
    );
    let mut chart = BarChart::new("Fig 7: power @ 32 Tb/s (W)", "W");
    for b in &rows {
        t.row(&[
            b.tech.clone(),
            format!("{:.0}", b.serdes_w),
            format!("{:.0}", b.optics_in_pkg_w),
            format!("{:.0}", b.off_pkg_w),
            format!("{:.0}", b.total_w()),
        ]);
        chart.bar(&b.tech, b.total_w());
    }
    (t, chart)
}

/// Fig. 8: area to support 32 Tb/s on a four-reticle GPU.
pub fn fig8() -> (Table, BarChart) {
    let gpu = hw::GpuPackage::frontier_2028();
    let techs = [hw::lpo_dr8(), hw::cpo_2p5d(), hw::passage_interposer()];
    let mut t = Table::new(
        "Fig 8: area for 32 Tb/s unidirectional on a 4-reticle GPU (mm²)",
        &["Technology", "GPU base", "Pkg expansion", "Board expansion", "Pkg growth %"],
    );
    let mut chart = BarChart::new("Fig 8: additional optical area (mm², log-ish scale)", "mm²");
    for tech in &techs {
        let b = hw::AreaBreakdown::compute(&gpu, tech);
        t.row(&[
            b.tech.clone(),
            format!("{:.0}", b.gpu_base),
            format!("{:.0}", b.pkg_expansion),
            format!("{:.0}", b.board_expansion),
            format!("{:.1}%", 100.0 * gpu.pkg_growth_fraction(tech)),
        ]);
        chart.bar(tech.name, b.additional());
    }
    (t, chart)
}

// ---------------------------------------------------------------------------
// Figures 10, 11 (engine-backed)
// ---------------------------------------------------------------------------

fn fig10_11(
    knobs: &PerfKnobs,
    system_radix: bool,
    jobs: usize,
    cache: &ClusterCache,
) -> (Table, BarChart) {
    let alt_key = if system_radix { ClusterKey::Electrical144 } else { ClusterKey::Electrical512 };
    let title = if system_radix {
        "Fig 11: system-specific radix — Passage(512) vs Alternative(144)"
    } else {
        "Fig 10: same radix-512 — Passage(32T) vs Alternative(14.4T)"
    };
    let grid = PaperGrid::new(vec![ClusterKey::Passage512, alt_key], vec![1, 2, 3, 4]);
    let reports = engine::run_grid_with_cache(&grid.jobs(knobs), jobs, cache);
    let base = reports[grid.index(0, 0)].step_time;
    let mut t = Table::new(
        title,
        &["Config", "Passage (rel)", "Alternative (rel)", "Alt/Passage", "Passage step"],
    );
    let mut chart = BarChart::new(title, "x (norm. to Passage C1)");
    for (ki, &i) in grid.configs.iter().enumerate() {
        let p = &reports[grid.index(0, ki)];
        let a = &reports[grid.index(1, ki)];
        t.row(&[
            format!("Config {i}"),
            format!("{:.3}", p.step_time / base),
            format!("{:.3}", a.step_time / base),
            format!("{:.2}x", a.step_time / p.step_time),
            fmt_time(p.step_time),
        ]);
        chart.bar(&format!("C{i} Passage"), p.step_time / base);
        chart.bar(&format!("C{i} Alternative"), a.step_time / base);
    }
    (t, chart)
}

/// Fig. 10: bandwidth isolation (both systems at radix 512).
pub fn fig10(knobs: &PerfKnobs) -> (Table, BarChart) {
    fig10_par(knobs, 1)
}

/// [`fig10`] with the evaluation grid spread over `jobs` workers.
pub fn fig10_par(knobs: &PerfKnobs, jobs: usize) -> (Table, BarChart) {
    fig10_cached(knobs, jobs, &ClusterCache::new())
}

/// [`fig10_par`] against a caller-owned cluster cache.
pub fn fig10_cached(knobs: &PerfKnobs, jobs: usize, cache: &ClusterCache) -> (Table, BarChart) {
    fig10_11(knobs, false, jobs, cache)
}

/// Fig. 11: actual system configurations (512@32T vs 144@14.4T).
pub fn fig11(knobs: &PerfKnobs) -> (Table, BarChart) {
    fig11_par(knobs, 1)
}

/// [`fig11`] with the evaluation grid spread over `jobs` workers.
pub fn fig11_par(knobs: &PerfKnobs, jobs: usize) -> (Table, BarChart) {
    fig11_cached(knobs, jobs, &ClusterCache::new())
}

/// [`fig11_par`] against a caller-owned cluster cache.
pub fn fig11_cached(knobs: &PerfKnobs, jobs: usize, cache: &ClusterCache) -> (Table, BarChart) {
    fig10_11(knobs, true, jobs, cache)
}

/// §VI narrative: per-component step breakdown for Config 4 on both
/// systems (where the 2.7x comes from).
pub fn breakdown_table(knobs: &PerfKnobs) -> Table {
    breakdown_table_cached(knobs, &ClusterCache::new())
}

/// [`breakdown_table`] against a caller-owned cluster cache.
pub fn breakdown_table_cached(knobs: &PerfKnobs, cache: &ClusterCache) -> Table {
    let passage = cache.get(&ClusterKey::Passage512);
    let alt144 = cache.get(&ClusterKey::Electrical144);
    let mut t = Table::new(
        "Step breakdown, Config 4 (per microbatch except DP)",
        &["Component", "Passage-512", "Electrical-144"],
    );
    let p = evaluate_paper_config(&passage, 4, knobs);
    let a = evaluate_paper_config(&alt144, 4, knobs);
    let rows: Vec<(&str, fn(&crate::perf::PerfReport) -> f64)> = vec![
        ("compute / micro", |r| r.breakdown.compute_per_micro),
        ("TP collectives / micro", |r| r.breakdown.tp_comm_per_micro),
        ("EP all-to-all / micro", |r| r.breakdown.ep_a2a_per_micro),
        ("PP p2p / micro", |r| r.breakdown.pp_comm_per_micro),
        ("DP grad sync / step", |r| r.breakdown.dp_comm_per_step),
        ("step time", |r| r.step_time),
        ("time-to-train (13T tok)", |r| r.time_to_train_s),
    ];
    for (name, f) in rows {
        t.row(&[name.to_string(), fmt_time(f(&p)), fmt_time(f(&a))]);
    }
    t.row(&[
        "comm fraction".into(),
        format!("{:.0}%", 100.0 * p.comm_fraction),
        format!("{:.0}%", 100.0 * a.comm_fraction),
    ]);
    t
}

// ---------------------------------------------------------------------------
// Ablations (beyond the paper's figures; engine-backed)
// ---------------------------------------------------------------------------

/// Pod-size sweep at fixed 32 Tb/s: where does the EP spill cliff sit?
pub fn pod_size_sweep(knobs: &PerfKnobs) -> Table {
    pod_size_sweep_par(knobs, 1)
}

/// [`pod_size_sweep`] over `jobs` workers.
pub fn pod_size_sweep_par(knobs: &PerfKnobs, jobs: usize) -> Table {
    pod_size_sweep_cached(knobs, jobs, &ClusterCache::new())
}

/// [`pod_size_sweep_par`] against a caller-owned cluster cache.
pub fn pod_size_sweep_cached(knobs: &PerfKnobs, jobs: usize, cache: &ClusterCache) -> Table {
    let mut t = Table::new(
        "Ablation: pod size sweep (Config 4, 32 Tb/s scale-up)",
        &["Pod size", "EP domain", "Step time", "vs 512-pod"],
    );
    let pods = [64usize, 128, 144, 256, 512, 1024];
    // job 0 is the 512-pod baseline; its key matches the pod=512 grid
    // point, so the memo builds that cluster once.
    let mut grid = vec![EvalJob::paper(ClusterKey::custom(32_768, 512, 32_000.0), 4, knobs)];
    for &pod in &pods {
        grid.push(EvalJob::paper(ClusterKey::custom_pod_aligned(pod, 32_000.0), 4, knobs));
    }
    let reports = engine::run_grid_with_cache(&grid, jobs, cache);
    let base = reports[0].step_time;
    for (pi, &pod) in pods.iter().enumerate() {
        let r = &reports[pi + 1];
        t.row(&[
            format!("{pod}"),
            format!("{:?}", r.breakdown.ep_placement),
            fmt_time(r.step_time),
            format!("{:.2}x", r.step_time / base),
        ]);
    }
    t
}

/// Scale-up bandwidth sweep at fixed radix 512.
pub fn bandwidth_sweep(knobs: &PerfKnobs) -> Table {
    bandwidth_sweep_par(knobs, 1)
}

/// [`bandwidth_sweep`] over `jobs` workers.
pub fn bandwidth_sweep_par(knobs: &PerfKnobs, jobs: usize) -> Table {
    bandwidth_sweep_cached(knobs, jobs, &ClusterCache::new())
}

/// [`bandwidth_sweep_par`] against a caller-owned cluster cache.
pub fn bandwidth_sweep_cached(knobs: &PerfKnobs, jobs: usize, cache: &ClusterCache) -> Table {
    let mut t = Table::new(
        "Ablation: scale-up bandwidth sweep (Config 4, radix 512)",
        &["Gb/s per GPU", "Step time", "Comm fraction", "vs 32T"],
    );
    let bws = [7_200.0, 14_400.0, 21_600.0, 32_000.0, 64_000.0, 128_000.0];
    let mut grid = vec![EvalJob::paper(ClusterKey::custom(32_768, 512, 32_000.0), 4, knobs)];
    for &gbps in &bws {
        grid.push(EvalJob::paper(ClusterKey::custom(32_768, 512, gbps), 4, knobs));
    }
    let reports = engine::run_grid_with_cache(&grid, jobs, cache);
    let base = reports[0].step_time;
    for (bi, &gbps) in bws.iter().enumerate() {
        let r = &reports[bi + 1];
        t.row(&[
            format!("{:.1}T", gbps / 1000.0),
            fmt_time(r.step_time),
            format!("{:.0}%", 100.0 * r.comm_fraction),
            format!("{:.2}x", r.step_time / base),
        ]);
    }
    t
}

/// Expert granularity beyond the paper's Config 4 (m = 16): does the
/// Passage advantage keep growing?
pub fn granularity_sweep(knobs: &PerfKnobs) -> Table {
    granularity_sweep_par(knobs, 1)
}

/// [`granularity_sweep`] over `jobs` workers.
pub fn granularity_sweep_par(knobs: &PerfKnobs, jobs: usize) -> Table {
    granularity_sweep_cached(knobs, jobs, &ClusterCache::new())
}

/// [`granularity_sweep_par`] against a caller-owned cluster cache.
pub fn granularity_sweep_cached(knobs: &PerfKnobs, jobs: usize, cache: &ClusterCache) -> Table {
    let mut t = Table::new(
        "Ablation: finer granularity than Config 4",
        &["m (=k, =experts/rank)", "Total experts", "Passage step", "Alt-144 step", "ratio"],
    );
    let ms = [1usize, 2, 4, 8, 16];
    let mut grid = Vec::with_capacity(2 * ms.len());
    for &m in &ms {
        let moe = MoeConfig {
            total_experts: 32 * m,
            active_per_token: m,
            granularity: m,
            experts_per_dp_rank: m,
        };
        grid.push(EvalJob::custom_moe(ClusterKey::Passage512, moe, knobs));
        grid.push(EvalJob::custom_moe(ClusterKey::Electrical144, moe, knobs));
    }
    let reports = engine::run_grid_with_cache(&grid, jobs, cache);
    for (mi, &m) in ms.iter().enumerate() {
        let p = &reports[2 * mi];
        let a = &reports[2 * mi + 1];
        t.row(&[
            format!("{m}"),
            format!("{}", 32 * m),
            fmt_time(p.step_time),
            fmt_time(a.step_time),
            format!("{:.2}x", a.step_time / p.step_time),
        ]);
    }
    t
}

/// Custom pod-size × bandwidth grid (Config `cfg` step time, normalized to
/// the 512-pod @ 32 Tb/s reference) — the `lumos sweep --kind grid` payload.
pub fn custom_grid(
    knobs: &PerfKnobs,
    pods: &[usize],
    bandwidths_gbps: &[f64],
    cfg: usize,
    jobs: usize,
) -> Table {
    custom_grid_cached(knobs, pods, bandwidths_gbps, cfg, jobs, &ClusterCache::new())
}

/// [`custom_grid`] against a caller-owned cluster cache.
pub fn custom_grid_cached(
    knobs: &PerfKnobs,
    pods: &[usize],
    bandwidths_gbps: &[f64],
    cfg: usize,
    jobs: usize,
    cache: &ClusterCache,
) -> Table {
    assert!(!pods.is_empty() && !bandwidths_gbps.is_empty());
    let mut header: Vec<String> = vec!["pod \\ Gb/s".into()];
    header.extend(bandwidths_gbps.iter().map(|b| format!("{:.1}T", b / 1000.0)));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(
        &format!("Config {cfg} step time vs (pod size, scale-up Gb/s), normalized to 512@32T"),
        &header_refs,
    );
    let mut grid = vec![EvalJob::paper(ClusterKey::custom(32_768, 512, 32_000.0), cfg, knobs)];
    for &pod in pods {
        for &bw in bandwidths_gbps {
            grid.push(EvalJob::paper(ClusterKey::custom_pod_aligned(pod, bw), cfg, knobs));
        }
    }
    let reports = engine::run_grid_with_cache(&grid, jobs, cache);
    let base = reports[0].step_time;
    for (pi, &pod) in pods.iter().enumerate() {
        let mut row = vec![format!("{pod}")];
        for bi in 0..bandwidths_gbps.len() {
            let r = &reports[1 + pi * bandwidths_gbps.len() + bi];
            let marker = match r.breakdown.ep_placement {
                crate::perf::EpPlacement::ScaleUp => "",
                crate::perf::EpPlacement::Hierarchical => "*",
            };
            row.push(format!("{:.2}{}", r.step_time / base, marker));
        }
        t.row(&row);
    }
    t
}

// ---------------------------------------------------------------------------
// Planner artifacts (tentpole: the mapping space, not just the paper point)
// ---------------------------------------------------------------------------

/// The three §VI cluster keys, in presentation order.
fn section6_clusters() -> [ClusterKey; 3] {
    [ClusterKey::Passage512, ClusterKey::Electrical512, ClusterKey::Electrical144]
}

/// Best planner-found mapping per §VI cluster (Config 4): what each fabric
/// *would* run if the mapping were free, not fixed at TP16×PP8×DP256.
pub fn planner_best_table(knobs: &PerfKnobs) -> Table {
    planner_best_table_par(knobs, 1)
}

/// [`planner_best_table`] over `jobs` workers.
pub fn planner_best_table_par(knobs: &PerfKnobs, jobs: usize) -> Table {
    planner_best_table_cached(knobs, jobs, &ClusterCache::new())
}

/// [`planner_best_table_par`] against a caller-owned cluster cache.
pub fn planner_best_table_cached(knobs: &PerfKnobs, jobs: usize, cache: &ClusterCache) -> Table {
    best_table_from(&section6_plans(knobs, jobs, cache))
}

/// One round of §VI plan searches — both planner tables render from this,
/// so `figures --all`/`--planner` runs 3 searches, not 6.
fn section6_plans(
    knobs: &PerfKnobs,
    jobs: usize,
    cache: &ClusterCache,
) -> Vec<planner::PlanOutcome> {
    section6_clusters()
        .into_iter()
        .map(|key| {
            let req = planner::PlanRequest::paper(key, 4, knobs).with_top(1);
            planner::plan_with_cache(&req, jobs, cache)
        })
        .collect()
}

/// Both planner artifacts from a single round of searches.
pub fn planner_tables_cached(
    knobs: &PerfKnobs,
    jobs: usize,
    cache: &ClusterCache,
) -> (Table, Table) {
    let outs = section6_plans(knobs, jobs, cache);
    (best_table_from(&outs), gap_table_from(&outs))
}

fn best_table_from(outs: &[planner::PlanOutcome]) -> Table {
    let mut t = Table::new(
        "Planner: best mapping per cluster (Config 4, full 4D search)",
        &["Cluster", "TP", "PP", "DP", "micro", "exp/rank", "EP domain", "TTT", "vs paper map"],
    );
    for out in outs {
        // lumos: allow(panic-path) -- §VI presets always have a feasible mapping
        let best = out.best().expect("paper clusters always have feasible mappings");
        let vs_paper = match &out.paper_baseline {
            Some(b) => format!("{:.2}x", b.time_to_train_s / best.report.time_to_train_s),
            None => "—".to_string(),
        };
        t.row(&[
            best.report.cluster.clone(),
            format!("{}", best.mapping.par.tp),
            format!("{}", best.mapping.par.pp),
            format!("{}", best.mapping.par.dp),
            format!("{}", best.mapping.microbatch_seqs),
            format!("{}", best.mapping.moe.experts_per_dp_rank),
            format!("{:?}", best.report.breakdown.ep_placement),
            fmt_time(best.report.time_to_train_s),
            vs_paper,
        ]);
    }
    t
}

/// Planner-vs-paper-mapping gap ablation on all three §VI clusters
/// (Config 4), closing with the headline comparison: the Passage advantage
/// over the electrical alternative under the paper's fixed mapping vs with
/// each fabric running its own best mapping.
pub fn planner_gap_table(knobs: &PerfKnobs) -> Table {
    planner_gap_table_par(knobs, 1)
}

/// [`planner_gap_table`] over `jobs` workers.
pub fn planner_gap_table_par(knobs: &PerfKnobs, jobs: usize) -> Table {
    planner_gap_table_cached(knobs, jobs, &ClusterCache::new())
}

/// [`planner_gap_table_par`] against a caller-owned cluster cache.
pub fn planner_gap_table_cached(knobs: &PerfKnobs, jobs: usize, cache: &ClusterCache) -> Table {
    gap_table_from(&section6_plans(knobs, jobs, cache))
}

fn gap_table_from(outs: &[planner::PlanOutcome]) -> Table {
    let mut t = Table::new(
        "Ablation: planner-found vs paper mapping (Config 4)",
        &["Cluster", "Paper-map TTT", "Planner TTT", "Planner gain"],
    );
    let mut planned = Vec::new();
    for out in outs {
        // lumos: allow(panic-path) -- §VI presets always have a feasible mapping and a baseline
        let best_ttt = out.best().expect("feasible").report.time_to_train_s;
        // lumos: allow(panic-path) -- §VI presets always have a feasible mapping and a baseline
        let paper = out.paper_baseline.as_ref().expect("§VI clusters have a baseline");
        t.row(&[
            out.cluster.clone(),
            fmt_time(paper.time_to_train_s),
            fmt_time(best_ttt),
            format!("{:.2}x", paper.time_to_train_s / best_ttt),
        ]);
        planned.push((paper.time_to_train_s, best_ttt));
    }
    // Headline: Passage vs Electrical-144 under both mapping regimes. The
    // planner *widens* the gap — the larger scale-up domain benefits more
    // from mapping freedom, which is the paper's "new opportunities for
    // multi-dimensional parallelism" claim made quantitative.
    let (passage, alt144) = (planned[0], planned[2]);
    t.row(&[
        "Passage-512 vs Electrical-144".into(),
        format!("{:.2}x", alt144.0 / passage.0),
        format!("{:.2}x", alt144.1 / passage.1),
        "speedup".into(),
    ]);
    t
}

/// Resilience artifacts (`lumos figures --resilience`): the
/// availability-adjusted Passage-vs-Electrical-144 speedup per Table IV
/// config, and the integrated-vs-external-laser effective-TTT delta on one
/// pod (the §III.d serviceability argument as a number). Closed-form only
/// (deterministic, no Monte Carlo seed).
pub fn resilience_tables(knobs: &PerfKnobs) -> (Table, Table) {
    resilience_tables_cached(knobs, &ClusterCache::new())
}

/// [`resilience_tables`] against a caller-owned cluster cache.
pub fn resilience_tables_cached(knobs: &PerfKnobs, cache: &ClusterCache) -> (Table, Table) {
    use crate::resilience::{self, DegradeSource, ResilienceSpec};
    // Closed form on analytical degraded ratios: the figures artifact is
    // the calibrated-headline table (EXPERIMENTS.md §Resilience), rendered
    // many times per `figures --all`. The CLI (`lumos resilience`) prices
    // degradation from timeline-measured ratios by default instead.
    let spec = ResilienceSpec {
        trials: 0,
        degrade: DegradeSource::Analytical,
        ..ResilienceSpec::default()
    };
    let pairs = resilience::paper_pairs(&[1, 2, 3, 4], knobs, &spec, 1, cache);
    let pods = resilience::pod_serviceability(knobs, &spec, 1, cache);
    (resilience::speedup_table(&pairs), resilience::serviceability_table(&pods))
}

/// Analytical-vs-simulated step-time gap on the §VI clusters (Config 4,
/// paper mapping): every closed-form headline number next to its
/// discrete-event counterpart — the `lumos figures --validate` artifact.
pub fn validate_gap_table(knobs: &PerfKnobs) -> Table {
    validate_gap_table_cached(knobs, &ClusterCache::new())
}

/// [`validate_gap_table`] against a caller-owned cluster cache.
pub fn validate_gap_table_cached(knobs: &PerfKnobs, cache: &ClusterCache) -> Table {
    let w = Workload::paper_gpt_4p7t(4);
    let map = Mapping::new(Parallelism::paper(), w.moe);
    let mut t = Table::new(
        "Validate: analytical vs simulated step time (Config 4, paper mapping)",
        &["Cluster", "ana step", "sim step", "gap", "bubble", "exposed comm"],
    );
    for key in section6_clusters() {
        let cluster = cache.get(&key);
        let v = timeline::validate_mapping(&w, &cluster, &map, knobs)
            // lumos: allow(panic-path) -- the paper mapping's DAG is under the size cap on §VI clusters
            .expect("paper mapping is simulable on the §VI clusters");
        let p = &v.simulated.phases;
        let comm = p.tp_comm + p.ep_comm + p.pp_comm + p.dp_comm;
        t.row(&[
            v.analytical.cluster.clone(),
            fmt_time(v.analytical.step_time),
            fmt_time(v.simulated.step_time),
            format!("{:+.1}%", 100.0 * v.gap()),
            format!("{:.0}%", 100.0 * p.bubble / v.simulated.step_time),
            format!("{:.0}%", 100.0 * comm / v.simulated.step_time),
        ]);
    }
    t
}

/// Topology ablation: SLS vs torus for uniform all-to-all (why §II.B picks
/// SLS for expert parallelism).
pub fn topology_ablation() -> Table {
    let mut t = Table::new(
        "Ablation: SLS vs 3D torus for 512-GPU all-to-all",
        &["Topology", "Injection Gb/s", "Effective a2a Gb/s", "Diameter"],
    );
    let sls = crate::topology::sls::SlsFabric::new(512, 32_000.0);
    t.row(&[
        "SLS (512-port switches)".into(),
        "32000".into(),
        "32000".into(),
        "2 hops".into(),
    ]);
    let torus = Torus::new(vec![8, 8, 8], 32_000.0 / 6.0);
    t.row(&[
        "8x8x8 torus (equal injection)".into(),
        format!("{:.0}", torus.injection_gbps()),
        format!("{:.0}", torus.a2a_effective_gbps()),
        format!("{} hops", torus.diameter()),
    ]);
    let _ = sls;
    t
}

/// Routing-restriction ablation (§VI closing point): drop rate with and
/// without device-limited routing at matched capacity.
pub fn routing_restriction_ablation() -> Table {
    use crate::coordinator::{Router, RouterConfig};
    use crate::util::rng::Rng;
    let mut t = Table::new(
        "Ablation: device-limited routing (DeepSeek-V2 style) vs unrestricted",
        &["max devices/token", "drop rate", "imbalance (max/mean)"],
    );
    let n_tokens = 4096;
    for limit in [None, Some(4), Some(2), Some(1)] {
        let cfg = RouterConfig {
            n_experts: 64,
            top_k: 8,
            experts_per_rank: 2,
            capacity: n_tokens * 8 / 64 + 64,
            max_devices_per_token: limit,
            remap: None,
        };
        let r = Router::new(cfg);
        let mut rng = Rng::new(4242);
        let choices = r.synthetic_choices(n_tokens, 1.1, &mut rng);
        let res = r.route(&choices);
        t.row(&[
            limit.map_or("unrestricted (Passage)".to_string(), |m| format!("{m}")),
            format!("{:.2}%", 100.0 * res.drop_rate(n_tokens, 8)),
            format!("{:.2}", res.imbalance()),
        ]);
    }
    t
}

/// Everything, rendered (the `lumos figures --all` payload).
pub fn render_all(knobs: &PerfKnobs) -> String {
    render_all_par(knobs, 1)
}

/// [`render_all`] with every perf-model grid spread over `jobs` workers.
pub fn render_all_par(knobs: &PerfKnobs, jobs: usize) -> String {
    render_all_cached(knobs, jobs, &ClusterCache::new())
}

/// [`render_all_par`] against a caller-owned cluster cache: every grid in
/// the command shares one memo, so each distinct cluster is built exactly
/// once across all figures.
pub fn render_all_cached(knobs: &PerfKnobs, jobs: usize, cache: &ClusterCache) -> String {
    let mut out = String::new();
    for t in [table1(), table2(), table3(), table4()] {
        out.push_str(&t.render());
        out.push('\n');
    }
    for (t, c) in [
        fig7(),
        fig8(),
        fig10_cached(knobs, jobs, cache),
        fig11_cached(knobs, jobs, cache),
    ] {
        out.push_str(&t.render());
        out.push('\n');
        out.push_str(&c.render());
        out.push('\n');
    }
    out.push_str(&breakdown_table_cached(knobs, cache).render());
    out.push('\n');
    let (planner_best, planner_gap) = planner_tables_cached(knobs, jobs, cache);
    let (resilience_speedup, resilience_service) = resilience_tables_cached(knobs, cache);
    for t in [
        pod_size_sweep_cached(knobs, jobs, cache),
        bandwidth_sweep_cached(knobs, jobs, cache),
        granularity_sweep_cached(knobs, jobs, cache),
        planner_best,
        planner_gap,
        validate_gap_table_cached(knobs, cache),
        resilience_speedup,
        resilience_service,
        topology_ablation(),
        routing_restriction_ablation(),
    ] {
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_have_expected_shape() {
        assert_eq!(table1().n_rows(), 2);
        assert_eq!(table3().n_rows(), 3);
        assert_eq!(table4().n_rows(), 3);
        assert!(table2().render().contains("21 pJ/bit"));
    }

    #[test]
    fn fig10_11_render_with_paper_ratios() {
        let knobs = PerfKnobs::default();
        let (t10, _) = fig10(&knobs);
        let r10 = t10.render();
        assert!(r10.contains("Config 4"));
        let (t11, _) = fig11(&knobs);
        let r11 = t11.render();
        // headline 2.7x appears in the Fig 11 table
        assert!(r11.contains("2.7"), "{r11}");
    }

    #[test]
    fn parallel_figures_are_byte_identical_to_serial() {
        // The acceptance contract of `lumos sweep --jobs N`: identical
        // artifacts for N ∈ {1, 4}.
        let knobs = PerfKnobs::default();
        let jobs = 4;
        let (t1, c1) = fig10(&knobs);
        let (tn, cn) = fig10_par(&knobs, jobs);
        assert_eq!(t1.render(), tn.render());
        assert_eq!(c1.render(), cn.render());
        let (t1, c1) = fig11(&knobs);
        let (tn, cn) = fig11_par(&knobs, jobs);
        assert_eq!(t1.render(), tn.render());
        assert_eq!(c1.render(), cn.render());
        assert_eq!(
            pod_size_sweep(&knobs).render(),
            pod_size_sweep_par(&knobs, jobs).render()
        );
        assert_eq!(
            bandwidth_sweep(&knobs).render(),
            bandwidth_sweep_par(&knobs, jobs).render()
        );
        assert_eq!(
            granularity_sweep(&knobs).render(),
            granularity_sweep_par(&knobs, jobs).render()
        );
    }

    #[test]
    fn all_figures_share_one_cluster_cache() {
        let knobs = PerfKnobs::default();
        let cache = ClusterCache::new();
        let _ = render_all_cached(&knobs, 2, &cache);
        // Exactly 15 distinct clusters across every grid: the 3 §VI presets
        // (fig10/11, granularity, planner/resilience tables) + 6 pod-sweep
        // customs + 5 more bandwidth-sweep customs (512@32T is shared
        // between the two sweeps) + the single 512-GPU pod of the
        // resilience serviceability scenario. Each is built once for the
        // whole command.
        assert_eq!(cache.built(), 15);
    }

    #[test]
    fn resilience_tables_carry_the_serviceability_numbers() {
        let (speedup, service) = resilience_tables(&PerfKnobs::default());
        let r = speedup.render();
        assert!(r.contains("adjusted speedup"), "{r}");
        assert_eq!(r.lines().count(), 3 + 4); // title + header + sep + 4 configs
        let s = service.render();
        for needle in ["Passage (external laser)", "CPO (integrated laser)", "TTT lost"] {
            assert!(s.contains(needle), "missing {needle}: {s}");
        }
    }

    #[test]
    fn planner_tables_are_byte_identical_across_worker_counts() {
        let knobs = PerfKnobs::default();
        assert_eq!(
            planner_best_table(&knobs).render(),
            planner_best_table_par(&knobs, 4).render()
        );
        assert_eq!(
            planner_gap_table(&knobs).render(),
            planner_gap_table_par(&knobs, 4).render()
        );
    }

    #[test]
    fn planner_gap_table_carries_the_headline_row() {
        let r = planner_gap_table(&PerfKnobs::default()).render();
        assert!(r.contains("Passage-512 vs Electrical-144"), "{r}");
        assert!(r.contains("speedup"), "{r}");
    }

    #[test]
    fn validate_gap_table_covers_all_section6_clusters() {
        let r = validate_gap_table(&PerfKnobs::default()).render();
        for needle in ["Passage-512", "Electrical-512", "Electrical-144"] {
            assert!(r.contains(needle), "missing {needle}: {r}");
        }
        // gaps are rendered as signed percentages
        assert!(r.contains('%'), "{r}");
        assert_eq!(r.lines().count(), 3 + 3); // title + header + sep + 3 rows
    }

    #[test]
    fn pod_sweep_shows_spill_cliff() {
        let t = pod_size_sweep(&PerfKnobs::default());
        let r = t.render();
        assert!(r.contains("Hierarchical"));
        assert!(r.contains("ScaleUp"));
    }

    #[test]
    fn custom_grid_sweeps_requested_points() {
        let t = custom_grid(&PerfKnobs::default(), &[144, 512], &[14_400.0, 32_000.0], 4, 2);
        let r = t.render();
        assert!(r.contains("144"));
        assert!(r.contains("14.4T"));
        // the 512 @ 32T cell is the baseline: exactly 1.00, in-pod EP
        assert!(r.contains("1.00"), "{r}");
        // the 144-pod rows must be marked as spilled
        assert!(r.contains('*'), "{r}");
    }

    #[test]
    fn render_all_is_substantial() {
        let out = render_all(&PerfKnobs::default());
        assert!(out.len() > 4000, "{}", out.len());
        for needle in ["Table I", "Table IV", "Fig 7", "Fig 8", "Fig 10", "Fig 11"] {
            assert!(out.contains(needle), "missing {needle}");
        }
    }

    #[test]
    fn render_all_parallel_matches_serial() {
        let knobs = PerfKnobs::default();
        assert_eq!(render_all(&knobs), render_all_par(&knobs, 4));
    }

    #[test]
    fn routing_ablation_shows_restriction_cost() {
        let t = routing_restriction_ablation();
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        // unrestricted drop rate (row 1) <= limited to 1 device (last row)
        let parse = |line: &str| -> f64 {
            line.split(',').nth(1).unwrap().trim_end_matches('%').parse().unwrap()
        };
        assert!(parse(lines[1]) <= parse(lines[4]));
    }
}
