//! Parallel design-space exploration engine.
//!
//! A sweep is an ordered list of pure [`EvalJob`]s — one (workload ×
//! cluster × mapping × knobs) point each. [`run_grid`] executes the list
//! on a pool of `std::thread` workers (an atomic next-job counter feeds
//! the pool; results flow back over an mpsc channel tagged with their job
//! index) and returns the [`PerfReport`]s **in job order**, so every
//! consumer (tables, figures, CSV) renders byte-identically for any
//! worker count — the contract `lumos sweep --jobs N` relies on.
//!
//! Cluster values are memoized in a shared [`ClusterCache`] keyed by
//! [`ClusterKey`], so a grid that touches the same cluster from hundreds
//! of jobs builds it once. No external crates: the pool is scoped threads
//! + channels from `std` (the vendored-minimal crate set stays minimal).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::model::{MoeConfig, Workload};
use crate::parallel::{Mapping, Parallelism};
use crate::perf::{evaluate, PerfKnobs, PerfReport};
use crate::topology::cluster::Cluster;
use crate::util::sync::lock;

/// Orderable description of a cluster — the memoization key. Bandwidth is
/// keyed by its exact bit pattern (no lossy rounding).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub enum ClusterKey {
    /// The paper's Passage system: 512-GPU pods @ 32 Tb/s, 32,768 GPUs.
    Passage512,
    /// Fig. 10's same-radix electrical hypothetical: 512-GPU pods @ 14.4 Tb/s.
    Electrical512,
    /// The paper's electrical alternative: 144-GPU pods @ 14.4 Tb/s, 32,256 GPUs.
    Electrical144,
    /// Arbitrary (n_gpus, pod_size, scale-up Gb/s) point.
    Custom { n_gpus: usize, pod_size: usize, gbps_bits: u64 },
}

impl ClusterKey {
    /// Custom point; `n_gpus` must be pod-aligned (checked at build time).
    pub fn custom(n_gpus: usize, pod_size: usize, scaleup_gbps: f64) -> ClusterKey {
        ClusterKey::Custom { n_gpus, pod_size, gbps_bits: scaleup_gbps.to_bits() }
    }

    /// Largest pod-aligned job size ≤ 32,768 GPUs for this pod size (how
    /// the ablations size clusters at non-power-of-two pods).
    pub fn custom_pod_aligned(pod_size: usize, scaleup_gbps: f64) -> ClusterKey {
        let n = 32_768 / pod_size * pod_size;
        ClusterKey::custom(n, pod_size, scaleup_gbps)
    }

    /// Construct the cluster this key describes.
    pub fn build(&self) -> Cluster {
        match *self {
            ClusterKey::Passage512 => Cluster::passage_512(32_768),
            ClusterKey::Electrical512 => Cluster::electrical_512(32_768),
            ClusterKey::Electrical144 => Cluster::electrical_144(32_256),
            ClusterKey::Custom { n_gpus, pod_size, gbps_bits } => {
                Cluster::custom(n_gpus, pod_size, f64::from_bits(gbps_bits))
            }
        }
    }
}

/// Shared memo of constructed clusters. Workers hit the lock only long
/// enough to clone an `Arc`; construction happens outside the lock (a
/// same-key race can build twice; the first insert wins).
#[derive(Debug, Default)]
pub struct ClusterCache {
    map: Mutex<BTreeMap<ClusterKey, Arc<Cluster>>>,
}

impl ClusterCache {
    pub fn new() -> ClusterCache {
        ClusterCache::default()
    }

    pub fn get(&self, key: &ClusterKey) -> Arc<Cluster> {
        if let Some(hit) = lock(&self.map).get(key) {
            return hit.clone();
        }
        // Build outside the lock so concurrent first touches of distinct
        // keys don't serialize; a racing duplicate build of the same key
        // is possible and harmless (first insert wins).
        let built = Arc::new(key.build());
        lock(&self.map).entry(key.clone()).or_insert(built).clone()
    }

    /// Distinct clusters constructed so far (memoization observability).
    pub fn built(&self) -> usize {
        lock(&self.map).len()
    }
}

/// One pure evaluation point. Running a job has no side effects, so jobs
/// can execute on any worker in any order; only the result order matters,
/// and [`run_grid`] restores it.
#[derive(Debug, Clone)]
pub struct EvalJob {
    pub cluster: ClusterKey,
    pub workload: Workload,
    pub mapping: Mapping,
    pub knobs: PerfKnobs,
}

impl EvalJob {
    /// The paper's Config `cfg` (Table IV) on `cluster` with the paper's
    /// fixed TP 16 × PP 8 × DP 256 mapping.
    pub fn paper(cluster: ClusterKey, cfg: usize, knobs: &PerfKnobs) -> EvalJob {
        EvalJob {
            cluster,
            workload: Workload::paper_gpt_4p7t(cfg),
            mapping: Mapping::new(Parallelism::paper(), MoeConfig::paper_config(cfg)),
            knobs: knobs.clone(),
        }
    }

    /// An explicit (cluster, workload, mapping) point — unlike
    /// [`EvalJob::paper`], the mapping is free. This is the planner's
    /// constructor: a grid can vary TP/PP/DP/microbatch/experts-per-rank,
    /// not just workload and cluster.
    pub fn mapped(
        cluster: ClusterKey,
        workload: Workload,
        mapping: Mapping,
        knobs: &PerfKnobs,
    ) -> EvalJob {
        EvalJob { cluster, workload, mapping, knobs: knobs.clone() }
    }

    /// A custom MoE shape on the paper's base architecture and mapping.
    pub fn custom_moe(cluster: ClusterKey, moe: MoeConfig, knobs: &PerfKnobs) -> EvalJob {
        let mut workload = Workload::paper_gpt_4p7t(1);
        workload.moe = moe;
        EvalJob {
            cluster,
            workload,
            mapping: Mapping::new(Parallelism::paper(), moe),
            knobs: knobs.clone(),
        }
    }

    /// Evaluate this point (pure; cluster construction memoized in `cache`).
    pub fn run(&self, cache: &ClusterCache) -> PerfReport {
        let cluster = cache.get(&self.cluster);
        evaluate(&self.workload, &cluster, &self.mapping, &self.knobs)
    }
}

/// Execute `jobs` on `workers` threads; results are returned in job order
/// regardless of completion order. `workers == 1` (or a single job) runs
/// inline with no threads spawned — the reference serial path.
pub fn run_grid(jobs: &[EvalJob], workers: usize) -> Vec<PerfReport> {
    let cache = ClusterCache::new();
    run_grid_with_cache(jobs, workers, &cache)
}

/// [`run_grid`] against a caller-owned cache (so several grids in one
/// command share cluster memoization).
pub fn run_grid_with_cache(
    jobs: &[EvalJob],
    workers: usize,
    cache: &ClusterCache,
) -> Vec<PerfReport> {
    run_indexed(jobs.len(), workers, |i| jobs[i].run(cache))
}

/// Execute `job(0..n)` on `workers` threads and return the results **in
/// index order** regardless of completion order — the generic core behind
/// [`run_grid`] (perf-model grids) and the [`crate::resilience`] Monte
/// Carlo trial pool. `job` must be pure per index; `workers <= 1` (or a
/// single item) runs inline with no threads spawned.
pub fn run_indexed<R, F>(n: usize, workers: usize, job: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if workers == 1 {
        return (0..n).map(&job).collect();
    }

    // An atomic next-index counter feeds the pool; workers tag results
    // with their index and send them back over a channel so the main
    // thread can restore deterministic order.
    let next = AtomicUsize::new(0);
    let (res_tx, res_rx) = mpsc::channel::<(usize, R)>();

    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let res_tx = res_tx.clone();
            let next = &next;
            let job = &job;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let result = job(i);
                if res_tx.send((i, result)).is_err() {
                    break;
                }
            });
        }
        drop(res_tx);
        for (i, result) in res_rx {
            out[i] = Some(result);
        }
    });
    out.into_iter()
        // lumos: allow(panic-path) -- the scope join guarantees every index was sent exactly once
        .map(|r| r.expect("worker dropped a job"))
        .collect()
}

/// Cartesian grid helper: clusters × paper configs, row-major in cluster
/// order then config order, with positional lookup into `run_grid` output.
#[derive(Debug, Clone)]
pub struct PaperGrid {
    pub clusters: Vec<ClusterKey>,
    pub configs: Vec<usize>,
}

impl PaperGrid {
    pub fn new(clusters: Vec<ClusterKey>, configs: Vec<usize>) -> PaperGrid {
        PaperGrid { clusters, configs }
    }

    pub fn jobs(&self, knobs: &PerfKnobs) -> Vec<EvalJob> {
        let mut jobs = Vec::with_capacity(self.clusters.len() * self.configs.len());
        for cluster in &self.clusters {
            for &cfg in &self.configs {
                jobs.push(EvalJob::paper(cluster.clone(), cfg, knobs));
            }
        }
        jobs
    }

    /// Index of (cluster `ci`, config `ki`) in the job/result vector.
    pub fn index(&self, ci: usize, ki: usize) -> usize {
        assert!(ci < self.clusters.len() && ki < self.configs.len());
        ci * self.configs.len() + ki
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig11_jobs(knobs: &PerfKnobs) -> Vec<EvalJob> {
        PaperGrid::new(
            vec![ClusterKey::Passage512, ClusterKey::Electrical144],
            vec![1, 2, 3, 4],
        )
        .jobs(knobs)
    }

    #[test]
    fn parallel_results_match_serial_exactly() {
        let knobs = PerfKnobs::default();
        let jobs = fig11_jobs(&knobs);
        let serial = run_grid(&jobs, 1);
        let par = run_grid(&jobs, 4);
        assert_eq!(serial.len(), par.len());
        for (s, p) in serial.iter().zip(&par) {
            // bitwise equality: same pure function, same inputs
            assert_eq!(s.step_time.to_bits(), p.step_time.to_bits());
            assert_eq!(s.time_to_train_s.to_bits(), p.time_to_train_s.to_bits());
            assert_eq!(s.cluster, p.cluster);
            assert_eq!(s.config_name, p.config_name);
        }
    }

    #[test]
    fn grids_can_vary_the_mapping() {
        // EvalJob is not tied to the paper mapping: a grid over enumerated
        // candidates runs and stays deterministic across worker counts.
        let knobs = PerfKnobs::default();
        let w = Workload::paper_gpt_4p7t(2);
        let cluster = ClusterKey::Passage512.build();
        let jobs: Vec<EvalJob> = crate::parallel::enumerate_candidates(&w, &cluster)
            .into_iter()
            .step_by(97) // a spread of the space, not just the smallest tp
            .map(|m| EvalJob::mapped(ClusterKey::Passage512, w.clone(), m, &knobs))
            .collect();
        assert!(jobs.len() >= 8, "{}", jobs.len());
        let serial = run_grid(&jobs, 1);
        let par = run_grid(&jobs, 4);
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(s.step_time.to_bits(), p.step_time.to_bits());
        }
    }

    #[test]
    fn run_indexed_preserves_index_order_for_any_worker_count() {
        let serial = run_indexed(37, 1, |i| i * i);
        for workers in [2, 4, 9] {
            assert_eq!(serial, run_indexed(37, workers, |i| i * i), "workers={workers}");
        }
        assert!(run_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn oversized_worker_count_is_clamped() {
        let knobs = PerfKnobs::default();
        let jobs = vec![EvalJob::paper(ClusterKey::Passage512, 1, &knobs)];
        let r = run_grid(&jobs, 64);
        assert_eq!(r.len(), 1);
        assert!(r[0].step_time > 0.0);
    }

    #[test]
    fn cluster_cache_memoizes() {
        let knobs = PerfKnobs::default();
        let cache = ClusterCache::new();
        let jobs = fig11_jobs(&knobs);
        let _ = run_grid_with_cache(&jobs, 4, &cache);
        // 8 jobs over exactly 2 distinct clusters
        assert_eq!(cache.built(), 2);
    }

    #[test]
    fn custom_keys_are_exact() {
        let k = ClusterKey::custom(1024, 128, 14_400.0);
        let c = k.build();
        assert_eq!(c.spec.pod_size, 128);
        assert!((c.spec.scale_up.gbps_per_gpu - 14_400.0).abs() < 1e-12);
        let aligned = ClusterKey::custom_pod_aligned(144, 32_000.0);
        let c2 = aligned.build();
        assert_eq!(c2.spec.n_gpus % 144, 0);
        assert!(c2.spec.n_gpus <= 32_768);
    }

    #[test]
    fn grid_indexing_is_row_major() {
        let g = PaperGrid::new(
            vec![ClusterKey::Passage512, ClusterKey::Electrical512],
            vec![1, 4],
        );
        let knobs = PerfKnobs::default();
        let jobs = g.jobs(&knobs);
        assert_eq!(jobs.len(), 4);
        assert_eq!(g.index(1, 0), 2);
        assert_eq!(jobs[g.index(1, 1)].workload.moe.total_experts, 256);
    }
}
