//! Design-space sweep subsystem: regenerates every table and figure of the
//! paper's evaluation (plus the ablations DESIGN.md calls out) and sweeps
//! arbitrary pod-size/bandwidth/granularity grids.
//!
//! Structure:
//!
//! - [`engine`] — the parallel execution core. Every table/figure over the
//!   perf model is expressed as an ordered grid of pure
//!   [`engine::EvalJob`]s; [`engine::run_grid`] executes them on a
//!   `std::thread` worker pool (atomic work counter + result channel, memoized
//!   [`Cluster`](crate::topology::cluster::Cluster) construction) and
//!   returns results in job order, so rendered output is byte-identical
//!   for any worker count.
//! - [`figures`] (re-exported here) — the paper's Tables I–IV, Figures
//!   7/8/10/11, the §VI breakdown, the ablation sweeps, the
//!   [`crate::planner`] artifacts (best-mapping-per-cluster,
//!   planner-vs-paper-mapping gap), and the [`crate::timeline`]
//!   analytical-vs-simulated gap table (`figures --validate`), each built
//!   on the engine. `*_par` variants take an explicit worker count; the
//!   plain names are the serial (`jobs = 1`) paths; `*_cached` variants
//!   additionally share a caller-owned [`engine::ClusterCache`] so one
//!   command builds each cluster exactly once across all of its grids.
//!
//! The CLI exposes the pool through `lumos sweep --jobs N` (and
//! `lumos figures --jobs N`); `lumos sweep --kind grid` sweeps custom
//! pod × bandwidth grids without recompiling; `lumos plan` searches the
//! full mapping space; `--csv` exports any sweep/plan grid.

pub mod engine;
mod figures;

pub use figures::*;
