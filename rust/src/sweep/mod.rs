//! Experiment sweep engine: regenerates every table and figure of the
//! paper's evaluation (plus the ablations DESIGN.md calls out) as rendered
//! tables/bar-charts. Each function is pure (returns the artifact); the
//! CLI (`lumos figures ...`) and the bench harness print them.

use crate::hw;
use crate::model::{MoeConfig, Workload};
use crate::parallel::{Mapping, Parallelism};
use crate::perf::{evaluate, evaluate_paper_config, paper_clusters, PerfKnobs};
use crate::topology::cluster::Cluster;
use crate::topology::torus::Torus;
use crate::util::stats::fmt_time;
use crate::util::table::{BarChart, Table};

// ---------------------------------------------------------------------------
// Tables I, II, III, IV
// ---------------------------------------------------------------------------

/// Table I: scale-up vs scale-out network characteristics.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I: scale-up vs scale-out networks",
        &["Network Type", "no. GPUs", "latency", "Tbps/GPU", "Energy"],
    );
    t.row_str(&["Scale-out", ">100k", "2-10 us", "1.6 Tb/s", "16 pJ/bit"]);
    t.row_str(&["Scale-up", "<1024", "100-250 ns", ">12.8 Tb/s", "<5 pJ/bit"]);
    t
}

/// Table II: legacy optical technology qualities (energy column computed
/// from the hw catalog; qualitative columns from the paper).
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table II: legacy optical technologies",
        &["Quality", "Optical Module", "LPO", "2/2.5D CPO"],
    );
    let plug = hw::pluggable_osfp();
    let lpo = hw::lpo_dr8();
    let cpo = hw::cpo_2p5d();
    t.row(&[
        "Energy Efficiency".into(),
        format!("{:.0} pJ/bit", plug.total_pj_per_bit()),
        format!("{:.0} pJ/bit", lpo.total_pj_per_bit()),
        format!("{:.0} pJ/bit", cpo.total_pj_per_bit()),
    ]);
    t.row_str(&["Bandwidth Density", "Low", "Low", "Medium"]);
    t.row_str(&["Latency", "High (retimed)", "Medium", "Low"]);
    t.row_str(&["Serviceability", "Yes", "Yes", "Ext. laser + coupler"]);
    t.row_str(&["Std. Form Factor", "Yes", "Yes", "No"]);
    t.row_str(&["Interoperability", "Yes", "Co-design w/ host", "Co-design w/ host"]);
    t
}

/// Table III: energy efficiency decomposition of the three §IV designs.
pub fn table3() -> Table {
    let techs = [hw::lpo_dr8(), hw::cpo_2p5d(), hw::passage_interposer()];
    let mut t = Table::new(
        "Table III: energy efficiency (pJ/bit)",
        &["", "1.6T DR8 LPO 224G", "224G 2.5D CPO", "56Gx8λ Passage"],
    );
    let row = |name: &str, f: &dyn Fn(&hw::InterconnectTech) -> f64| {
        let mut cells = vec![name.to_string()];
        cells.extend(techs.iter().map(|x| format!("{:.1}", f(x))));
        cells
    };
    t.row(&row("In-package pJ/bit", &|x| x.in_pkg_pj_per_bit()));
    t.row(&row("Off-package pJ/bit", &|x| x.off_pkg_pj));
    t.row(&row("Total pJ/bit (optics, PHY, laser)", &|x| x.total_pj_per_bit()));
    t
}

/// Table IV: MoE cluster configuration parameters.
pub fn table4() -> Table {
    let mut t = Table::new(
        "Table IV: cluster configuration parameters",
        &["Parameter", "Config 1", "Config 2", "Config 3", "Config 4"],
    );
    let cfgs: Vec<MoeConfig> = (1..=4).map(MoeConfig::paper_config).collect();
    let mut active = vec!["Active / total experts".to_string()];
    let mut gran = vec!["Expert granularity (m)".to_string()];
    let mut per_rank = vec!["Experts per DP rank".to_string()];
    for c in &cfgs {
        active.push(format!("{}/{}", c.active_per_token, c.total_experts));
        gran.push(format!("{}", c.granularity));
        per_rank.push(format!("{}", c.experts_per_dp_rank));
    }
    t.row(&active);
    t.row(&gran);
    t.row(&per_rank);
    t
}

// ---------------------------------------------------------------------------
// Figures 7, 8
// ---------------------------------------------------------------------------

/// Fig. 7: optics power for a 32 Tb/s unidirectional GPU.
pub fn fig7() -> (Table, BarChart) {
    let gbps = 32_000.0;
    let (rows, advantage) = hw::fig7_comparison(gbps);
    let mut t = Table::new(
        &format!(
            "Fig 7: optics power @ 32 Tb/s GPU (Passage {advantage:.1}x less than best conventional)"
        ),
        &["Technology", "SerDes W", "In-pkg optics W", "Off-pkg W", "Total W"],
    );
    let mut chart = BarChart::new("Fig 7: power @ 32 Tb/s (W)", "W");
    for b in &rows {
        t.row(&[
            b.tech.clone(),
            format!("{:.0}", b.serdes_w),
            format!("{:.0}", b.optics_in_pkg_w),
            format!("{:.0}", b.off_pkg_w),
            format!("{:.0}", b.total_w()),
        ]);
        chart.bar(&b.tech, b.total_w());
    }
    (t, chart)
}

/// Fig. 8: area to support 32 Tb/s on a four-reticle GPU.
pub fn fig8() -> (Table, BarChart) {
    let gpu = hw::GpuPackage::frontier_2028();
    let techs = [hw::lpo_dr8(), hw::cpo_2p5d(), hw::passage_interposer()];
    let mut t = Table::new(
        "Fig 8: area for 32 Tb/s unidirectional on a 4-reticle GPU (mm²)",
        &["Technology", "GPU base", "Pkg expansion", "Board expansion", "Pkg growth %"],
    );
    let mut chart = BarChart::new("Fig 8: additional optical area (mm², log-ish scale)", "mm²");
    for tech in &techs {
        let b = hw::AreaBreakdown::compute(&gpu, tech);
        t.row(&[
            b.tech.clone(),
            format!("{:.0}", b.gpu_base),
            format!("{:.0}", b.pkg_expansion),
            format!("{:.0}", b.board_expansion),
            format!("{:.1}%", 100.0 * gpu.pkg_growth_fraction(tech)),
        ]);
        chart.bar(tech.name, b.additional());
    }
    (t, chart)
}

// ---------------------------------------------------------------------------
// Figures 10, 11
// ---------------------------------------------------------------------------

fn fig10_11(knobs: &PerfKnobs, system_radix: bool) -> (Table, BarChart) {
    let (passage, alt512, alt144) = paper_clusters();
    let alt = if system_radix { &alt144 } else { &alt512 };
    let title = if system_radix {
        "Fig 11: system-specific radix — Passage(512) vs Alternative(144)"
    } else {
        "Fig 10: same radix-512 — Passage(32T) vs Alternative(14.4T)"
    };
    let base = evaluate_paper_config(&passage, 1, knobs).step_time;
    let mut t = Table::new(
        title,
        &["Config", "Passage (rel)", "Alternative (rel)", "Alt/Passage", "Passage step"],
    );
    let mut chart = BarChart::new(title, "x (norm. to Passage C1)");
    for i in 1..=4 {
        let p = evaluate_paper_config(&passage, i, knobs);
        let a = evaluate_paper_config(alt, i, knobs);
        t.row(&[
            format!("Config {i}"),
            format!("{:.3}", p.step_time / base),
            format!("{:.3}", a.step_time / base),
            format!("{:.2}x", a.step_time / p.step_time),
            fmt_time(p.step_time),
        ]);
        chart.bar(&format!("C{i} Passage"), p.step_time / base);
        chart.bar(&format!("C{i} Alternative"), a.step_time / base);
    }
    (t, chart)
}

/// Fig. 10: bandwidth isolation (both systems at radix 512).
pub fn fig10(knobs: &PerfKnobs) -> (Table, BarChart) {
    fig10_11(knobs, false)
}

/// Fig. 11: actual system configurations (512@32T vs 144@14.4T).
pub fn fig11(knobs: &PerfKnobs) -> (Table, BarChart) {
    fig10_11(knobs, true)
}

/// §VI narrative: per-component step breakdown for Config 4 on both
/// systems (where the 2.7x comes from).
pub fn breakdown_table(knobs: &PerfKnobs) -> Table {
    let (passage, _, alt144) = paper_clusters();
    let mut t = Table::new(
        "Step breakdown, Config 4 (per microbatch except DP)",
        &["Component", "Passage-512", "Electrical-144"],
    );
    let p = evaluate_paper_config(&passage, 4, knobs);
    let a = evaluate_paper_config(&alt144, 4, knobs);
    let rows: Vec<(&str, fn(&crate::perf::PerfReport) -> f64)> = vec![
        ("compute / micro", |r| r.breakdown.compute_per_micro),
        ("TP collectives / micro", |r| r.breakdown.tp_comm_per_micro),
        ("EP all-to-all / micro", |r| r.breakdown.ep_a2a_per_micro),
        ("PP p2p / micro", |r| r.breakdown.pp_comm_per_micro),
        ("DP grad sync / step", |r| r.breakdown.dp_comm_per_step),
        ("step time", |r| r.step_time),
        ("time-to-train (13T tok)", |r| r.time_to_train_s),
    ];
    for (name, f) in rows {
        t.row(&[name.to_string(), fmt_time(f(&p)), fmt_time(f(&a))]);
    }
    t.row(&[
        "comm fraction".into(),
        format!("{:.0}%", 100.0 * p.comm_fraction),
        format!("{:.0}%", 100.0 * a.comm_fraction),
    ]);
    t
}

// ---------------------------------------------------------------------------
// Ablations (beyond the paper's figures)
// ---------------------------------------------------------------------------

/// Pod-size sweep at fixed 32 Tb/s: where does the EP spill cliff sit?
pub fn pod_size_sweep(knobs: &PerfKnobs) -> Table {
    let mut t = Table::new(
        "Ablation: pod size sweep (Config 4, 32 Tb/s scale-up)",
        &["Pod size", "EP domain", "Step time", "vs 512-pod"],
    );
    let base = evaluate_paper_config(&Cluster::custom(32_768, 512, 32_000.0), 4, knobs).step_time;
    for pod in [64, 128, 144, 256, 512, 1024] {
        let n = 32_768 / pod * pod; // pod-aligned job size
        let cluster = Cluster::custom(n, pod, 32_000.0);
        let r = evaluate_paper_config(&cluster, 4, knobs);
        t.row(&[
            format!("{pod}"),
            format!("{:?}", r.breakdown.ep_placement),
            fmt_time(r.step_time),
            format!("{:.2}x", r.step_time / base),
        ]);
    }
    t
}

/// Scale-up bandwidth sweep at fixed radix 512.
pub fn bandwidth_sweep(knobs: &PerfKnobs) -> Table {
    let mut t = Table::new(
        "Ablation: scale-up bandwidth sweep (Config 4, radix 512)",
        &["Gb/s per GPU", "Step time", "Comm fraction", "vs 32T"],
    );
    let base = evaluate_paper_config(&Cluster::custom(32_768, 512, 32_000.0), 4, knobs).step_time;
    for gbps in [7_200.0, 14_400.0, 21_600.0, 32_000.0, 64_000.0, 128_000.0] {
        let r = evaluate_paper_config(&Cluster::custom(32_768, 512, gbps), 4, knobs);
        t.row(&[
            format!("{:.1}T", gbps / 1000.0),
            fmt_time(r.step_time),
            format!("{:.0}%", 100.0 * r.comm_fraction),
            format!("{:.2}x", r.step_time / base),
        ]);
    }
    t
}

/// Expert granularity beyond the paper's Config 4 (m = 16, 32): does the
/// Passage advantage keep growing?
pub fn granularity_sweep(knobs: &PerfKnobs) -> Table {
    let (passage, _, alt144) = paper_clusters();
    let mut t = Table::new(
        "Ablation: finer granularity than Config 4",
        &["m (=k, =experts/rank)", "Total experts", "Passage step", "Alt-144 step", "ratio"],
    );
    for m in [1usize, 2, 4, 8, 16] {
        let moe = MoeConfig {
            total_experts: 32 * m,
            active_per_token: m,
            granularity: m,
            experts_per_dp_rank: m,
        };
        let mut w = Workload::paper_gpt_4p7t(1);
        w.moe = moe;
        let map = Mapping::new(Parallelism::paper(), moe);
        let p = evaluate(&w, &passage, &map, knobs);
        let a = evaluate(&w, &alt144, &map, knobs);
        t.row(&[
            format!("{m}"),
            format!("{}", moe.total_experts),
            fmt_time(p.step_time),
            fmt_time(a.step_time),
            format!("{:.2}x", a.step_time / p.step_time),
        ]);
    }
    t
}

/// Topology ablation: SLS vs torus for uniform all-to-all (why §II.B picks
/// SLS for expert parallelism).
pub fn topology_ablation() -> Table {
    let mut t = Table::new(
        "Ablation: SLS vs 3D torus for 512-GPU all-to-all",
        &["Topology", "Injection Gb/s", "Effective a2a Gb/s", "Diameter"],
    );
    let sls = crate::topology::sls::SlsFabric::new(512, 32_000.0);
    t.row(&[
        "SLS (512-port switches)".into(),
        "32000".into(),
        "32000".into(),
        "2 hops".into(),
    ]);
    let torus = Torus::new(vec![8, 8, 8], 32_000.0 / 6.0);
    t.row(&[
        "8x8x8 torus (equal injection)".into(),
        format!("{:.0}", torus.injection_gbps()),
        format!("{:.0}", torus.a2a_effective_gbps()),
        format!("{} hops", torus.diameter()),
    ]);
    let _ = sls;
    t
}

/// Routing-restriction ablation (§VI closing point): drop rate with and
/// without device-limited routing at matched capacity.
pub fn routing_restriction_ablation() -> Table {
    use crate::coordinator::{Router, RouterConfig};
    use crate::util::rng::Rng;
    let mut t = Table::new(
        "Ablation: device-limited routing (DeepSeek-V2 style) vs unrestricted",
        &["max devices/token", "drop rate", "imbalance (max/mean)"],
    );
    let n_tokens = 4096;
    for limit in [None, Some(4), Some(2), Some(1)] {
        let cfg = RouterConfig {
            n_experts: 64,
            top_k: 8,
            experts_per_rank: 2,
            capacity: n_tokens * 8 / 64 + 64,
            max_devices_per_token: limit,
        };
        let r = Router::new(cfg);
        let mut rng = Rng::new(4242);
        let choices = r.synthetic_choices(n_tokens, 1.1, &mut rng);
        let res = r.route(&choices);
        t.row(&[
            limit.map_or("unrestricted (Passage)".to_string(), |m| format!("{m}")),
            format!("{:.2}%", 100.0 * res.drop_rate(n_tokens, 8)),
            format!("{:.2}", res.imbalance()),
        ]);
    }
    t
}

/// Everything, rendered (the `lumos figures --all` payload).
pub fn render_all(knobs: &PerfKnobs) -> String {
    let mut out = String::new();
    for t in [table1(), table2(), table3(), table4()] {
        out.push_str(&t.render());
        out.push('\n');
    }
    for (t, c) in [fig7(), fig8(), fig10(knobs), fig11(knobs)] {
        out.push_str(&t.render());
        out.push('\n');
        out.push_str(&c.render());
        out.push('\n');
    }
    out.push_str(&breakdown_table(knobs).render());
    out.push('\n');
    for t in [
        pod_size_sweep(knobs),
        bandwidth_sweep(knobs),
        granularity_sweep(knobs),
        topology_ablation(),
        routing_restriction_ablation(),
    ] {
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_have_expected_shape() {
        assert_eq!(table1().n_rows(), 2);
        assert_eq!(table3().n_rows(), 3);
        assert_eq!(table4().n_rows(), 3);
        assert!(table2().render().contains("21 pJ/bit"));
    }

    #[test]
    fn fig10_11_render_with_paper_ratios() {
        let knobs = PerfKnobs::default();
        let (t10, _) = fig10(&knobs);
        let r10 = t10.render();
        assert!(r10.contains("Config 4"));
        let (t11, _) = fig11(&knobs);
        let r11 = t11.render();
        // headline 2.7x appears in the Fig 11 table
        assert!(r11.contains("2.7"), "{r11}");
    }

    #[test]
    fn pod_sweep_shows_spill_cliff() {
        let t = pod_size_sweep(&PerfKnobs::default());
        let r = t.render();
        assert!(r.contains("Hierarchical"));
        assert!(r.contains("ScaleUp"));
    }

    #[test]
    fn render_all_is_substantial() {
        let out = render_all(&PerfKnobs::default());
        assert!(out.len() > 4000, "{}", out.len());
        for needle in ["Table I", "Table IV", "Fig 7", "Fig 8", "Fig 10", "Fig 11"] {
            assert!(out.contains(needle), "missing {needle}");
        }
    }

    #[test]
    fn routing_ablation_shows_restriction_cost() {
        let t = routing_restriction_ablation();
        let csv = t.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        // unrestricted drop rate (row 1) <= limited to 1 device (last row)
        let parse = |line: &str| -> f64 {
            line.split(',').nth(1).unwrap().trim_end_matches('%').parse().unwrap()
        };
        assert!(parse(lines[1]) <= parse(lines[4]));
    }
}
