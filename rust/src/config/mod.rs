//! Experiment configuration: named presets for every entity in the paper's
//! evaluation plus JSON round-tripping so users can define their own
//! clusters/workloads/knobs (`lumos model --config my.json`).

use anyhow::{anyhow, bail, Result};

use crate::model::{MoeConfig, Workload};
use crate::parallel::Parallelism;
use crate::perf::PerfKnobs;
use crate::topology::cluster::Cluster;
use crate::util::json::Json;

/// One fully-specified evaluation point.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub name: String,
    pub workload: Workload,
    pub parallelism: Parallelism,
    pub cluster: Cluster,
    pub knobs: PerfKnobs,
}

impl Experiment {
    /// `cfg` 1..=4 (Table IV) on one of the named clusters:
    /// "passage-512" | "electrical-512" | "electrical-144".
    pub fn paper(cluster: &str, cfg: usize) -> Result<Experiment> {
        let cl = cluster_preset(cluster)?;
        Ok(Experiment {
            name: format!("{cluster}/config{cfg}"),
            workload: Workload::paper_gpt_4p7t(cfg),
            parallelism: Parallelism::paper(),
            cluster: cl,
            knobs: PerfKnobs::default(),
        })
    }
}

/// Named cluster presets (§VI).
pub fn cluster_preset(name: &str) -> Result<Cluster> {
    Ok(match name {
        "passage-512" => Cluster::passage_512(32_768),
        "electrical-512" => Cluster::electrical_512(32_768),
        "electrical-144" => Cluster::electrical_144(32_256),
        other => bail!(
            "unknown cluster preset '{other}' (have passage-512, electrical-512, electrical-144)"
        ),
    })
}

/// Parse a workload override JSON:
/// `{"layers":120,"d_model":12288,...,"config":3}` — any omitted field
/// falls back to the paper workload for `config`.
pub fn workload_from_json(j: &Json) -> Result<Workload> {
    let cfg = j.get("config").as_usize().unwrap_or(1);
    if !(1..=4).contains(&cfg) {
        bail!("config must be 1..=4, got {cfg}");
    }
    let mut w = Workload::paper_gpt_4p7t(cfg);
    let get = |key: &str| j.get(key).as_usize();
    if let Some(v) = get("layers") {
        w.n_layers = v;
    }
    if let Some(v) = get("d_model") {
        w.d_model = v;
        w.d_ff_base = 4 * v;
    }
    if let Some(v) = get("d_ff_base") {
        w.d_ff_base = v;
    }
    if let Some(v) = get("heads") {
        w.n_heads = v;
    }
    if let Some(v) = get("seq_len") {
        w.seq_len = v;
    }
    if let Some(v) = get("global_batch") {
        w.global_batch = v;
    }
    if let Some(v) = j.get("target_tokens").as_f64() {
        w.target_tokens = v;
    }
    if let Some(m) = j.get("moe").as_obj() {
        let g = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("moe override needs '{k}'"))
        };
        w.moe = MoeConfig {
            total_experts: g("total_experts")?,
            active_per_token: g("active_per_token")?,
            granularity: g("granularity")?,
            experts_per_dp_rank: g("experts_per_dp_rank")?,
        };
    }
    Ok(w)
}

/// Parse a cluster override JSON:
/// `{"preset":"passage-512"}` or
/// `{"n_gpus":32768,"pod_size":512,"scaleup_gbps":32000}`.
pub fn cluster_from_json(j: &Json) -> Result<Cluster> {
    if let Some(p) = j.get("preset").as_str() {
        return cluster_preset(p);
    }
    let n = j.get("n_gpus").as_usize().ok_or_else(|| anyhow!("cluster needs n_gpus"))?;
    let pod = j.get("pod_size").as_usize().ok_or_else(|| anyhow!("cluster needs pod_size"))?;
    let bw = j
        .get("scaleup_gbps")
        .as_f64()
        .ok_or_else(|| anyhow!("cluster needs scaleup_gbps"))?;
    Ok(Cluster::custom(n, pod, bw))
}

/// Parse perf knob overrides. (`microbatch_seqs` is not a knob — it lives
/// on the mapping; see [`microbatch_from_json`].)
pub fn knobs_from_json(j: &Json) -> PerfKnobs {
    let mut k = PerfKnobs::default();
    if let Some(v) = j.get("mfu").as_f64() {
        k.mfu = v;
    }
    if let Some(v) = j.get("comm_dtype_bytes").as_f64() {
        k.comm_dtype_bytes = v;
    }
    if let Some(v) = j.get("dp_overlap").as_f64() {
        k.dp_overlap = v;
    }
    if let Some(v) = j.get("ep_overlap").as_f64() {
        k.ep_overlap = v;
    }
    k
}

/// Optional microbatch override from the same JSON file that carries knob
/// overrides — applied to the [`crate::parallel::Mapping`], where the
/// microbatch grain lives since the planner refactor.
pub fn microbatch_from_json(j: &Json) -> Option<usize> {
    j.get("microbatch_seqs").as_usize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["passage-512", "electrical-512", "electrical-144"] {
            assert!(cluster_preset(name).is_ok(), "{name}");
        }
        assert!(cluster_preset("nvlink-9000").is_err());
    }

    #[test]
    fn paper_experiment_builds() {
        let e = Experiment::paper("passage-512", 4).unwrap();
        assert_eq!(e.workload.moe.total_experts, 256);
        assert_eq!(e.parallelism.n_gpus(), 32_768);
    }

    #[test]
    fn workload_overrides_apply() {
        let j = Json::parse(
            r#"{"config": 2, "layers": 24, "seq_len": 2048,
                "moe": {"total_experts": 16, "active_per_token": 2,
                        "granularity": 2, "experts_per_dp_rank": 2}}"#,
        )
        .unwrap();
        let w = workload_from_json(&j).unwrap();
        assert_eq!(w.n_layers, 24);
        assert_eq!(w.seq_len, 2048);
        assert_eq!(w.moe.total_experts, 16);
        // untouched fields keep paper values
        assert_eq!(w.d_model, 12_288);
    }

    #[test]
    fn cluster_json_both_forms() {
        let a = cluster_from_json(&Json::parse(r#"{"preset": "passage-512"}"#).unwrap()).unwrap();
        assert_eq!(a.spec.pod_size, 512);
        let b = cluster_from_json(
            &Json::parse(r#"{"n_gpus": 1024, "pod_size": 128, "scaleup_gbps": 9600}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(b.n_pods(), 8);
    }

    #[test]
    fn bad_config_rejected() {
        assert!(workload_from_json(&Json::parse(r#"{"config": 7}"#).unwrap()).is_err());
    }

    #[test]
    fn knob_overrides() {
        let j = Json::parse(r#"{"mfu": 0.5, "ep_overlap": 0.3, "microbatch_seqs": 4}"#).unwrap();
        let k = knobs_from_json(&j);
        assert_eq!(k.mfu, 0.5);
        assert_eq!(k.ep_overlap, 0.3);
        assert_eq!(k.dp_overlap, 0.9); // default retained
        assert_eq!(microbatch_from_json(&j), Some(4));
        assert_eq!(microbatch_from_json(&Json::parse("{}").unwrap()), None);
    }
}
