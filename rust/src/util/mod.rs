//! Dependency-free substrate utilities (DESIGN.md §Environment deviations):
//! JSON, RNG, property testing, CLI parsing, statistics, table/figure
//! rendering, and a criterion-style bench harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
