//! Declarative command-line parsing (clap substitute; DESIGN.md
//! §Environment deviations).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, repeated
//! options, and positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// One option/flag specification.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.values
            .get(name)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: expected integer, got '{s}'")),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: expected number, got '{s}'")),
        }
    }

    /// Comma-separated list of any parseable type; a missing option yields
    /// `None`, any unparseable item fails with an error naming the option.
    fn get_list<T: std::str::FromStr>(
        &self,
        name: &str,
        what: &str,
    ) -> Result<Option<Vec<T>>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .map(|item| {
                    item.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: expected {what} list, got '{s}'"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// Comma-separated integer list (`--pods 64,128,512`).
    pub fn get_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>, String> {
        self.get_list(name, "integer")
    }

    /// Comma-separated number list (`--bandwidths 14400,32000`).
    pub fn get_f64_list(&self, name: &str) -> Result<Option<Vec<f64>>, String> {
        self.get_list(name, "number")
    }
}

/// A command with options and optional subcommands.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
    pub subs: Vec<Command>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new(), subs: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: false, default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: None });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        help: &'static str,
        default: &'static str,
    ) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: Some(default) });
        self
    }

    pub fn sub(mut self, cmd: Command) -> Self {
        self.subs.push(cmd);
        self
    }

    pub fn help_text(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} ", self.name, self.about, self.name);
        if !self.subs.is_empty() {
            out.push_str("<SUBCOMMAND> ");
        }
        out.push_str("[OPTIONS]\n");
        if !self.subs.is_empty() {
            out.push_str("\nSUBCOMMANDS:\n");
            for s in &self.subs {
                out.push_str(&format!("  {:14} {}\n", s.name, s.about));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let meta = if o.takes_value { " <VALUE>" } else { "" };
                let dft = o
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                out.push_str(&format!("  --{}{:2}  {}{}\n", o.name, meta, o.help, dft));
            }
        }
        out.push_str("  --help  print this help\n");
        out
    }

    /// Parse argv (without the program name). Returns the subcommand chain
    /// (empty for the root) and its Args, or an error/help text.
    pub fn parse(&self, argv: &[String]) -> Result<(Vec<String>, Args), String> {
        let mut i = 0;
        // Descend into subcommands first.
        if i < argv.len() && !argv[i].starts_with('-') && !self.subs.is_empty() {
            let name = &argv[i];
            let sub = self
                .subs
                .iter()
                .find(|s| s.name == name.as_str())
                .ok_or_else(|| format!("unknown subcommand '{name}'\n\n{}", self.help_text()))?;
            let (mut chain, args) = sub.parse(&argv[i + 1..])?;
            chain.insert(0, name.clone());
            return Ok((chain, args));
        }

        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), vec![d.to_string()]);
            }
        }
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option '--{name}'\n\n{}", self.help_text()))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    args.values.entry(name.to_string()).or_default().push(val);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    args.flags.insert(name.to_string(), true);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok((Vec::new(), args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("lumos", "test")
            .sub(
                Command::new("sweep", "run sweeps")
                    .opt_default("pod", "pod size", "512")
                    .opt("bw", "bandwidth")
                    .flag("verbose", "chatty"),
            )
            .sub(Command::new("train", "train").opt("steps", "steps"))
    }

    #[test]
    fn parses_subcommand_options() {
        let (chain, args) = cmd().parse(&sv(&["sweep", "--bw", "32", "--verbose"])).unwrap();
        assert_eq!(chain, vec!["sweep"]);
        assert_eq!(args.get("bw"), Some("32"));
        assert_eq!(args.get("pod"), Some("512")); // default
        assert!(args.flag("verbose"));
    }

    #[test]
    fn equals_form_and_repeats() {
        let (_, args) = cmd()
            .parse(&sv(&["sweep", "--bw=14.4", "--bw=32"]))
            .unwrap();
        assert_eq!(args.get("bw"), Some("32"));
        assert_eq!(args.get_all("bw"), vec!["14.4", "32"]);
        assert_eq!(args.get_f64("bw").unwrap(), Some(32.0));
    }

    #[test]
    fn unknown_rejected_with_help() {
        let e = cmd().parse(&sv(&["sweep", "--nope"])).unwrap_err();
        assert!(e.contains("unknown option"));
        let e = cmd().parse(&sv(&["zzz"])).unwrap_err();
        assert!(e.contains("unknown subcommand"));
    }

    #[test]
    fn help_is_returned_as_err() {
        let e = cmd().parse(&sv(&["sweep", "--help"])).unwrap_err();
        assert!(e.contains("OPTIONS"));
    }

    #[test]
    fn positional_and_typed_errors() {
        let (_, args) = cmd().parse(&sv(&["train", "file.json"])).unwrap();
        assert_eq!(args.positional, vec!["file.json"]);
        let (_, args) = cmd().parse(&sv(&["train", "--steps", "abc"])).unwrap();
        assert!(args.get_usize("steps").is_err());
    }

    #[test]
    fn list_options_parse() {
        let (_, args) = cmd().parse(&sv(&["sweep", "--bw", "64,128, 512"])).unwrap();
        assert_eq!(args.get_usize_list("bw").unwrap(), Some(vec![64, 128, 512]));
        assert_eq!(args.get_f64_list("bw").unwrap(), Some(vec![64.0, 128.0, 512.0]));
        assert_eq!(args.get_usize_list("missing-opt").unwrap(), None);
        let (_, args) = cmd().parse(&sv(&["sweep", "--bw", "64,x"])).unwrap();
        assert!(args.get_usize_list("bw").is_err());
    }
}
