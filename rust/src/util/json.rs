//! Minimal, dependency-free JSON: value model, recursive-descent parser and
//! serializer.
//!
//! The image's vendored crate set has no `serde`/`serde_json` (see DESIGN.md
//! §Environment deviations), so configs and the AOT `manifest.json` are read
//! through this module. It supports the full JSON grammar (RFC 8259) minus
//! `\u` surrogate-pair edge validation, which the manifest never uses.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects preserve deterministic (sorted) key order
/// via `BTreeMap`, which keeps serialization stable for golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and a short message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; `Json::Null` out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{}", n));
        }
    } else {
        // JSON has no NaN/Inf; emit null like most encoders.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").at(0).as_usize(), Some(1));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse("\"caf\\u00e9 λ ≥\"").unwrap();
        assert_eq!(v.as_str(), Some("café λ ≥"));
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"pi": 3.25, "list": [1, "two", false], "o": {}}"#;
        let v = Json::parse(src).unwrap();
        for s in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(4.5).to_string_compact(), "4.5");
    }

    #[test]
    fn missing_lookups_are_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(v.get("zz"), &Json::Null);
        assert_eq!(v.at(3), &Json::Null);
        assert_eq!(v.get("zz").get("deep"), &Json::Null);
    }
}
