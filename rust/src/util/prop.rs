//! Tiny property-based testing framework (proptest substitute — the vendored
//! crate set has no proptest; see DESIGN.md §Environment deviations).
//!
//! Supports seeded generators, a fixed number of cases, and greedy shrinking
//! for integer/vec generators. Failures print the seed and the (shrunk)
//! counterexample so they can be reproduced deterministically.
//!
//! ```ignore
//! use crate::util::prop::{check, Gen};
//! check("sum is commutative", 256, |g| {
//!     let a = g.usize(0, 100);
//!     let b = g.usize(0, 100);
//!     prop_assert!(a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// Result of a single property case.
pub type CaseResult = Result<(), String>;

/// Generator handle passed to each property case: draws are recorded so a
/// failing case can be replayed while shrinking numeric draws toward zero.
pub struct Gen {
    rng: Rng,
    /// Forced values for the first N draws (used during shrinking).
    forced: Vec<u64>,
    /// Values drawn by the current case (raw, pre-range-mapping).
    pub draws: Vec<u64>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64, forced: Vec<u64>) -> Self {
        Self { rng: Rng::new(seed), forced, draws: Vec::new(), cursor: 0 }
    }

    fn draw(&mut self, fresh: u64) -> u64 {
        let v = if self.cursor < self.forced.len() {
            self.forced[self.cursor]
        } else {
            fresh
        };
        self.cursor += 1;
        self.draws.push(v);
        v
    }

    /// usize uniform in [lo, hi] (inclusive; shrinks toward lo).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = (hi - lo) as u64 + 1;
        let fresh = self.rng.below(span);
        lo + (self.draw(fresh) % span) as usize
    }

    /// u64 uniform in [0, n) (shrinks toward 0).
    pub fn u64(&mut self, n: u64) -> u64 {
        let fresh = self.rng.below(n);
        self.draw(fresh) % n
    }

    /// f64 uniform in [lo, hi) (shrinks toward lo).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let raw = self.u64(1 << 53);
        lo + (hi - lo) * (raw as f64 / (1u64 << 53) as f64)
    }

    pub fn bool(&mut self) -> bool {
        self.usize(0, 1) == 1
    }

    /// Vec of length in [0, max_len] with elements from `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }
}

/// Outcome of a `check` run; panics on failure by default via `check`.
#[derive(Debug)]
pub struct PropFailure {
    pub name: String,
    pub seed: u64,
    pub case: usize,
    pub message: String,
    pub shrunk_draws: Vec<u64>,
}

/// Run `cases` random cases of `prop`. Panics with reproduction info on the
/// first failure after greedy shrinking. Seed defaults to a hash of the name
/// so failures reproduce across runs; override with `LUMOS_PROP_SEED`.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> CaseResult) {
    if let Err(f) = check_seeded(name, cases, default_seed(name), &prop) {
        // lumos: allow(panic-path) -- the property harness reports failures by panicking, like assert
        panic!(
            "property '{}' failed (seed={}, case={}): {}\n  shrunk draws: {:?}",
            f.name, f.seed, f.case, f.message, f.shrunk_draws
        );
    }
}

fn default_seed(name: &str) -> u64 {
    if let Ok(s) = std::env::var("LUMOS_PROP_SEED") {
        if let Ok(v) = s.parse() {
            return v;
        }
    }
    // FNV-1a over the name: deterministic, distinct per property.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub fn check_seeded(
    name: &str,
    cases: usize,
    seed: u64,
    prop: &impl Fn(&mut Gen) -> CaseResult,
) -> Result<(), PropFailure> {
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut g = Gen::new(case_seed, Vec::new());
        if let Err(msg) = run_case(prop, &mut g) {
            let (draws, msg) = shrink(prop, case_seed, g.draws.clone(), msg);
            return Err(PropFailure {
                name: name.to_string(),
                seed,
                case,
                message: msg,
                shrunk_draws: draws,
            });
        }
    }
    Ok(())
}

fn run_case(prop: &impl Fn(&mut Gen) -> CaseResult, g: &mut Gen) -> CaseResult {
    prop(g)
}

/// Greedy shrink: try forcing each recorded draw toward 0 (halving), keeping
/// mutations that still fail. Bounded passes so it always terminates.
fn shrink(
    prop: &impl Fn(&mut Gen) -> CaseResult,
    seed: u64,
    mut draws: Vec<u64>,
    mut msg: String,
) -> (Vec<u64>, String) {
    for _pass in 0..8 {
        let mut improved = false;
        for i in 0..draws.len() {
            let mut candidate = draws[i];
            while candidate > 0 {
                candidate /= 2;
                let mut attempt = draws.clone();
                attempt[i] = candidate;
                let mut g = Gen::new(seed, attempt.clone());
                match run_case(prop, &mut g) {
                    Err(new_msg) => {
                        draws = attempt;
                        msg = new_msg;
                        improved = true;
                    }
                    Ok(()) => break,
                }
            }
        }
        if !improved {
            break;
        }
    }
    (draws, msg)
}

/// Assertion macro for property bodies: returns Err(msg) instead of panicking
/// so the shrinker can drive the case.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 128, |g| {
            let a = g.usize(0, 1000);
            let b = g.usize(0, 1000);
            prop_assert!(a + b == b + a, "impossible");
            Ok(())
        });
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        let r = check_seeded("x < 500", 512, 1234, &|g| {
            let x = g.usize(0, 1000);
            prop_assert!(x < 500, "x={x}");
            Ok(())
        });
        let f = r.expect_err("property should fail");
        // Shrinker halves toward the boundary: final value must still fail
        // and be <= any original failing draw.
        assert!(f.shrunk_draws[0] % 1001 >= 500);
        assert!(f.shrunk_draws[0] % 1001 <= 1000);
    }

    #[test]
    fn forced_draws_replay() {
        let mut g = Gen::new(1, vec![42, 7]);
        assert_eq!(g.usize(0, 100), 42);
        assert_eq!(g.usize(0, 100), 7);
    }

    #[test]
    fn vec_and_choose() {
        check("vec elements in range", 64, |g| {
            let v = g.vec(10, |g| g.usize(5, 9));
            for &x in &v {
                prop_assert!((5..=9).contains(&x), "x={x}");
            }
            if !v.is_empty() {
                let c = *g.choose(&v);
                prop_assert!(v.contains(&c), "choose not member");
            }
            Ok(())
        });
    }
}
