//! Shared-state helpers: one place that states the repo's lock-poisoning
//! policy instead of eleven scattered `lock().unwrap()`s.

use std::sync::{Mutex, MutexGuard};

/// Acquire `m`, aborting on poison. A poisoned mutex means another worker
/// already panicked mid-update; every pool in this crate (sweep grids,
/// Monte Carlo trials, the PJRT engine cache) treats that as fatal rather
/// than computing on half-written shared state.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        // lumos: allow(panic-path) -- poisoning means a worker already panicked; propagate the abort
        Err(e) => panic!("poisoned lock: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn lock_gives_access() {
        let m = Mutex::new(41);
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 42);
    }

    #[test]
    #[should_panic(expected = "poisoned lock")]
    fn poisoned_lock_aborts() {
        let m = std::sync::Arc::new(Mutex::new(0));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison it");
        })
        .join();
        lock(&m);
    }
}
