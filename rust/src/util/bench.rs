//! Criterion-style micro/macro bench harness (criterion is not in the
//! vendored crate set; DESIGN.md §Environment deviations).
//!
//! Used by `rust/benches/*.rs` (`harness = false`): warmup, fixed sample
//! count, mean/median/stddev/throughput reporting, and an optional
//! `LUMOS_BENCH_FAST=1` mode so `cargo bench` stays quick in CI.

use std::time::Instant;

use crate::util::stats::{fmt_si, fmt_time, Summary};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Summary,
    /// items (or bytes) processed per iteration, for throughput reporting
    pub items_per_iter: Option<f64>,
    pub unit: &'static str,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mean = self.samples.mean();
        let mut line = format!(
            "{:40} {:>12} ±{:>10}  (median {:>10}, n={})",
            self.name,
            fmt_time(mean),
            fmt_time(self.samples.stddev()),
            fmt_time(self.samples.median()),
            self.samples.len(),
        );
        if let Some(items) = self.items_per_iter {
            if mean > 0.0 {
                line.push_str(&format!("  [{}/s]", fmt_si(items / mean, self.unit)));
            }
        }
        line
    }
}

/// Bench runner with consistent warmup/sampling policy.
pub struct Bencher {
    warmup_iters: usize,
    sample_count: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        let fast = std::env::var("LUMOS_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Self {
            warmup_iters: if fast { 1 } else { 3 },
            sample_count: if fast { 5 } else { 15 },
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, n: usize) -> Self {
        self.sample_count = n;
        self
    }

    /// Time `f` (one call = one sample). Returns mean seconds.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        self.bench_throughput(name, None, "item", &mut f)
    }

    /// Time `f`, reporting `items`/second throughput.
    pub fn bench_items(
        &mut self,
        name: &str,
        items: f64,
        unit: &'static str,
        mut f: impl FnMut(),
    ) -> f64 {
        self.bench_throughput(name, Some(items), unit, &mut f)
    }

    fn bench_throughput(
        &mut self,
        name: &str,
        items: Option<f64>,
        unit: &'static str,
        f: &mut dyn FnMut(),
    ) -> f64 {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Summary::new();
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            f();
            samples.add(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            samples,
            items_per_iter: items,
            unit,
        };
        println!("{}", result.report());
        let mean = result.samples.mean();
        self.results.push(result);
        mean
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        std::env::set_var("LUMOS_BENCH_FAST", "1");
        let mut b = Bencher::new().with_samples(3);
        let mean = b.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(mean >= 0.0);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].samples.len(), 3);
    }

    #[test]
    fn report_contains_throughput() {
        std::env::set_var("LUMOS_BENCH_FAST", "1");
        let mut b = Bencher::new().with_samples(2);
        b.bench_items("tp", 1e6, "B", || {
            black_box(vec![0u8; 1024]);
        });
        assert!(b.results()[0].report().contains("/s]"));
    }
}
