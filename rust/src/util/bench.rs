//! Criterion-style micro/macro bench harness (criterion is not in the
//! vendored crate set; DESIGN.md §Environment deviations).
//!
//! Used by `rust/benches/*.rs` (`harness = false`): warmup, fixed sample
//! count, mean/median/stddev/throughput reporting, and an optional
//! `LUMOS_BENCH_FAST=1` mode so `cargo bench` stays quick in CI.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::{fmt_si, fmt_time, Summary};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Summary,
    /// items (or bytes) processed per iteration, for throughput reporting
    pub items_per_iter: Option<f64>,
    pub unit: &'static str,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mean = self.samples.mean();
        let mut line = format!(
            "{:40} {:>12} ±{:>10}  (median {:>10}, n={})",
            self.name,
            fmt_time(mean),
            fmt_time(self.samples.stddev()),
            fmt_time(self.samples.median()),
            self.samples.len(),
        );
        if let Some(items) = self.items_per_iter {
            if mean > 0.0 {
                line.push_str(&format!("  [{}/s]", fmt_si(items / mean, self.unit)));
            }
        }
        line
    }
}

/// Bench runner with consistent warmup/sampling policy.
pub struct Bencher {
    warmup_iters: usize,
    sample_count: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        let fast = std::env::var("LUMOS_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Self {
            warmup_iters: if fast { 1 } else { 3 },
            sample_count: if fast { 5 } else { 15 },
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, n: usize) -> Self {
        self.sample_count = n;
        self
    }

    /// Time `f` (one call = one sample). Returns mean seconds.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        self.bench_throughput(name, None, "item", &mut f)
    }

    /// Time `f`, reporting `items`/second throughput.
    pub fn bench_items(
        &mut self,
        name: &str,
        items: f64,
        unit: &'static str,
        mut f: impl FnMut(),
    ) -> f64 {
        self.bench_throughput(name, Some(items), unit, &mut f)
    }

    fn bench_throughput(
        &mut self,
        name: &str,
        items: Option<f64>,
        unit: &'static str,
        f: &mut dyn FnMut(),
    ) -> f64 {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Summary::new();
        for _ in 0..self.sample_count {
            // lumos: allow(wallclock) -- the bench harness measures real elapsed time by design
            let t0 = Instant::now();
            f();
            samples.add(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            samples,
            items_per_iter: items,
            unit,
        };
        println!("{}", result.report());
        let mean = result.samples.mean();
        self.results.push(result);
        mean
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Mean seconds of a recorded result by exact name (None if that
    /// benchmark did not run).
    pub fn mean_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|r| r.name == name).map(|r| r.samples.mean())
    }

    /// Machine-readable form of every recorded result: per-series
    /// mean/median/stddev seconds plus throughput where recorded, keyed by
    /// benchmark name (deterministic key order via `util::json`). The
    /// netsim bench writes this as `BENCH_netsim.json` so the perf
    /// trajectory is recorded run over run.
    pub fn to_json(&self) -> Json {
        let series: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mean = r.samples.mean();
                let mut fields = vec![
                    ("name", Json::str(&r.name)),
                    ("mean_s", Json::num(mean)),
                    ("median_s", Json::num(r.samples.median())),
                    ("stddev_s", Json::num(r.samples.stddev())),
                    ("samples", Json::num(r.samples.len() as f64)),
                ];
                if let Some(items) = r.items_per_iter {
                    fields.push(("items_per_iter", Json::num(items)));
                    if mean > 0.0 {
                        fields.push(("items_per_s", Json::num(items / mean)));
                        fields.push(("unit", Json::str(r.unit)));
                    }
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![("series", Json::Arr(series))])
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        std::env::set_var("LUMOS_BENCH_FAST", "1");
        let mut b = Bencher::new().with_samples(3);
        let mean = b.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(mean >= 0.0);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].samples.len(), 3);
    }

    #[test]
    fn report_contains_throughput() {
        std::env::set_var("LUMOS_BENCH_FAST", "1");
        let mut b = Bencher::new().with_samples(2);
        b.bench_items("tp", 1e6, "B", || {
            black_box(vec![0u8; 1024]);
        });
        assert!(b.results()[0].report().contains("/s]"));
    }

    #[test]
    fn json_export_round_trips() {
        std::env::set_var("LUMOS_BENCH_FAST", "1");
        let mut b = Bencher::new().with_samples(2);
        b.bench_items("series-a", 10.0, "flow", || {
            black_box((0..64).sum::<u64>());
        });
        b.bench("series-b", || {
            black_box((0..64).product::<u64>());
        });
        let j = b.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        let series = parsed.get("series").as_arr().unwrap();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].get("name").as_str(), Some("series-a"));
        assert!(series[0].get("mean_s").as_f64().unwrap() >= 0.0);
        assert!(series[0].get("items_per_iter").as_f64().is_some());
        assert!(b.mean_of("series-b").is_some());
        assert!(b.mean_of("missing").is_none());
    }
}
